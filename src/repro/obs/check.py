"""CLI schema checker for exported observability artifacts.

Usage (what CI runs after the traced serve smoke)::

    python -m repro.obs.check trace.json metrics.prom

``*.json`` files route by content: a ``traceEvents`` container validates as
a Chrome trace_event file (including the schema-v2 ``est_pj``/``est_ns``
energy annotations on spans), a ``metrics_schema_version``-stamped object
as a metrics/BENCH payload (hardware-cost ``hw`` blocks checked wherever
they appear; version-1 files predate them and still validate).  Anything
else validates as Prometheus text exposition.  Prints one line per
artifact; exits nonzero on the first invalid one.
"""
from __future__ import annotations

import json
import sys

from repro.obs.export import (
    validate_chrome_trace,
    validate_metrics_json,
    validate_prometheus_text,
)


def check_file(path: str) -> list:
    if path.endswith(".json"):
        with open(path) as f:
            try:
                obj = json.load(f)
            except json.JSONDecodeError as e:
                return [f"invalid JSON: {e}"]
        if isinstance(obj, dict) and "traceEvents" in obj:
            return validate_chrome_trace(obj)
        if isinstance(obj, dict) and "metrics_schema_version" in obj:
            return validate_metrics_json(obj)
        return ["unrecognized JSON artifact: neither a Chrome trace "
                "('traceEvents') nor a stamped metrics payload "
                "('metrics_schema_version')"]
    with open(path) as f:
        return validate_prometheus_text(f.read())


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if not argv:
        print("usage: python -m repro.obs.check <trace.json|metrics.prom>...")
        return 2
    rc = 0
    for path in argv:
        errs = check_file(path)
        if errs:
            rc = 1
            print(f"FAIL {path}")
            for e in errs[:20]:
                print(f"  - {e}")
        else:
            print(f"OK   {path}")
    return rc


if __name__ == "__main__":
    sys.exit(main())
