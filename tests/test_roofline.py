"""Roofline machinery: HLO collective parser + three-term math."""
import pytest

from repro.launch.roofline import (
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS,
    Roofline,
    collective_bytes,
    model_flops,
)

HLO = """
HloModule test
ENTRY %main {
  %ag = bf16[256,4096]{1,0} all-gather(%p0), replica_groups={{0,1}}
  ROOT %all-reduce = f32[128,1024]{1,0} all-reduce(%dot), channel_id=1
  %rs = f32[64,64]{1,0} reduce-scatter(%x), dimensions={0}
  %a2a = (s32[8,8]{1,0}, s32[8,8]{1,0}) all-to-all(%y, %z)
  %cp = bf16[16]{0} collective-permute(%w), source_target_pairs={{0,1}}
  %ars = f32[2,2]{1,0} all-reduce-start(%q)
  %ard = f32[2,2]{1,0} all-reduce-done(%ars)
  %not_a_collective = f32[9]{0} add(%a, %b)
}
"""


def test_collective_parser():
    got = collective_bytes(HLO)
    assert got["all-gather"] == 256 * 4096 * 2
    assert got["all-reduce"] == 128 * 1024 * 4 + 2 * 2 * 4  # incl. -start once
    assert got["reduce-scatter"] == 64 * 64 * 4
    assert got["all-to-all"] == 2 * 8 * 8 * 4  # tuple shape summed
    assert got["collective-permute"] == 16 * 2


def test_roofline_terms_and_bottleneck():
    r = Roofline(
        flops_per_chip=PEAK_FLOPS,          # exactly 1 s of compute
        bytes_per_chip=HBM_BW / 2,          # 0.5 s of HBM
        coll_bytes_per_chip=ICI_BW * 2,     # 2 s of ICI
        chips=256,
        model_flops_global=PEAK_FLOPS * 256 / 2,
    )
    assert r.t_compute == pytest.approx(1.0)
    assert r.t_memory == pytest.approx(0.5)
    assert r.t_collective == pytest.approx(2.0)
    assert r.bottleneck == "collective"
    assert r.useful_flops_fraction == pytest.approx(0.5)
    assert r.roofline_fraction == pytest.approx(0.25)  # 0.5s useful / 2s bound


def test_model_flops_by_kind():
    from repro.configs.registry import LM_SHAPES

    train = next(s for s in LM_SHAPES if s.kind == "train")
    dec = next(s for s in LM_SHAPES if s.name == "decode_32k")
    n = 1e9
    assert model_flops(None, train, n, n) == 6 * n * train.global_batch * train.seq_len
    assert model_flops(None, dec, n, n) == 2 * n * dec.global_batch
