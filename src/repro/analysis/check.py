"""CLI entry for the static verifier: ``python -m repro.analysis.check``.

Three check families, composable per invocation:

* **graph passes** — load a frozen artifact (``--artifact DIR``, repeatable)
  or freeze fresh smoke-scale models from the config zoo (``--configs
  all`` / ``--configs name,name``), trace its decode / chunked-prefill /
  spec-draft step functions under the gather and fused attention backends,
  and run the pass pipeline: multiplier-free (jaxpr taint), no-big-gather,
  no-host-sync, dtype-discipline (optimized HLO).
* **repo lint** — the AST rules in :mod:`repro.analysis.lint` over the
  default source tree (``--lint-only`` for just this, ``--no-lint`` to
  skip).
* **verdict recording** — each checked artifact's ``manifest.json`` gets
  the summary stamped under ``"analysis"`` (``--no-record`` to skip).

Exit status is 1 when any error-severity finding survives the allowlist,
0 otherwise.  ``--json OUT`` dumps the full findings list for CI upload.
"""
from __future__ import annotations

import argparse
import sys
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.analysis.findings import Finding, dump_json, errors, render
from repro.analysis.passes import DEFAULT_ALLOWLIST, run_passes

#: bumped when the verdict dict recorded into artifact manifests changes
VERDICT_SCHEMA = 1


def _build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis.check",
        description="static verifier: multiplier-free serving graphs, "
                    "page-aliasing plans, repo lint",
    )
    p.add_argument("--artifact", action="append", default=[],
                   metavar="DIR", help="frozen DA artifact to check "
                   "(repeatable)")
    p.add_argument("--configs", default=None, metavar="all|name,...",
                   help="freeze smoke-scale models from the config zoo and "
                        "check their serving graphs")
    p.add_argument("--mode", default="auto",
                   help="freeze mode for --configs models (default: auto)")
    p.add_argument("--spec-gamma", type=int, default=2,
                   help="trace the fused speculative draft loop with this "
                        "gamma (0 disables; default 2)")
    p.add_argument("--no-hlo", action="store_true",
                   help="skip compiled-HLO passes (jaxpr taint only)")
    p.add_argument("--allow", action="append", default=[], metavar="SUBSTR",
                   help="extra allowlist entry (matched against a finding's "
                        "where/op; repeatable)")
    p.add_argument("--no-default-allow", action="store_true",
                   help="drop the built-in allowlist "
                        f"{list(DEFAULT_ALLOWLIST)}")
    p.add_argument("--lint-only", action="store_true",
                   help="run only the AST lint rules")
    p.add_argument("--no-lint", action="store_true",
                   help="skip the AST lint rules")
    p.add_argument("--no-record", action="store_true",
                   help="do not stamp the verdict into artifact manifests")
    p.add_argument("--json", default=None, metavar="OUT",
                   help="write the findings list as JSON")
    return p


def _allowlist(args: argparse.Namespace) -> Tuple[str, ...]:
    base = () if args.no_default_allow else DEFAULT_ALLOWLIST
    return tuple(base) + tuple(args.allow)


def check_artifact(
    directory: str,
    *,
    spec_gamma: int = 2,
    compile_hlo: bool = True,
    allow: Sequence[str] = DEFAULT_ALLOWLIST,
) -> Tuple[List[Finding], List[str]]:
    """Graph-pass findings for one on-disk artifact (+ names of the steps
    actually traced).  An artifact without a model config cannot be traced
    — that is itself an error finding, not a silent skip."""
    from repro.analysis.graph import supports_paged_tracing, trace_serving_steps
    from repro.core.freeze import load_artifact

    art = load_artifact(directory)
    if art.model_cfg is None:
        return [Finding(
            pass_name="graph/trace", severity="error",
            op="artifact has no model_cfg",
            hint="re-freeze with model_cfg= so the serving graph can be "
                 "rebuilt and verified",
            where=directory,
        )], []
    if not supports_paged_tracing(art.model_cfg):
        return [Finding(
            pass_name="graph/trace", severity="note",
            op=f"config {art.model_cfg.name} is outside paged-tracer "
               "coverage",
            hint="non-attention mixers serve through the slot runtime "
                 "(ROADMAP open item); embedding-input modalities have no "
                 "token step to trace",
            where=directory,
        )], []
    steps = trace_serving_steps(
        art.params, art.model_cfg, spec_gamma=spec_gamma,
        compile_hlo=compile_hlo,
    )
    return run_passes(steps, allow=allow), [s.name for s in steps]


def check_config(
    name: str,
    *,
    mode: str = "auto",
    spec_gamma: int = 2,
    compile_hlo: bool = True,
    allow: Sequence[str] = DEFAULT_ALLOWLIST,
) -> Tuple[List[Finding], List[str]]:
    """Freeze one zoo config at smoke scale and run the graph passes."""
    import jax

    from repro.analysis.graph import supports_paged_tracing, trace_serving_steps
    from repro.configs.registry import get, reduce_for_smoke
    from repro.core.da import DAConfig
    from repro.core.freeze import freeze_model
    from repro.models.model import init_model

    cfg = reduce_for_smoke(get(name))
    if not supports_paged_tracing(cfg):
        return [Finding(
            pass_name="graph/trace", severity="note",
            op=f"config {name} is outside paged-tracer coverage",
            hint="non-attention mixers serve through the slot runtime "
                 "(ROADMAP open item); embedding-input modalities have no "
                 "token step to trace",
            where=f"configs:{name}",
        )], []
    params = init_model(jax.random.key(0), cfg)
    art = freeze_model(params, DAConfig(x_signed=True), mode=mode,
                       model_cfg=cfg)
    steps = trace_serving_steps(
        art.params, cfg, spec_gamma=spec_gamma, compile_hlo=compile_hlo,
    )
    return run_passes(steps, allow=allow), [s.name for s in steps]


def verdict_of(findings: Sequence[Finding],
               checked: Sequence[str]) -> Dict[str, Any]:
    """The summary dict recorded into an artifact manifest."""
    by_pass: Dict[str, int] = {}
    for f in findings:
        by_pass[f.pass_name] = by_pass.get(f.pass_name, 0) + 1
    n_err = len(errors(findings))
    return {
        "schema": VERDICT_SCHEMA,
        "ok": n_err == 0,
        "errors": n_err,
        "warnings": sum(1 for f in findings if f.severity == "warning"),
        "notes": sum(1 for f in findings if f.severity == "note"),
        "findings_by_pass": by_pass,
        "steps_checked": list(checked),
        "checked_at": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
    }


def main(argv: Optional[Sequence[str]] = None) -> int:
    args = _build_parser().parse_args(argv)
    allow = _allowlist(args)
    findings: List[Finding] = []

    if not args.lint_only:
        for directory in args.artifact:
            fs, checked = check_artifact(
                directory, spec_gamma=args.spec_gamma,
                compile_hlo=not args.no_hlo, allow=allow,
            )
            findings += fs
            print(f"[graph] {directory}: {len(checked)} step(s) traced, "
                  f"{len(fs)} finding(s)")
            if not args.no_record and checked:
                from repro.core.freeze import record_analysis

                record_analysis(directory, verdict_of(fs, checked))
        if args.configs:
            from repro.configs.registry import ARCHS

            names = (sorted(ARCHS) if args.configs == "all"
                     else [n.strip() for n in args.configs.split(",")
                           if n.strip()])
            for name in names:
                try:
                    fs, checked = check_config(
                        name, mode=args.mode, spec_gamma=args.spec_gamma,
                        compile_hlo=not args.no_hlo, allow=allow,
                    )
                except Exception as e:  # a config that cannot even trace
                    fs, checked = [Finding(
                        pass_name="graph/trace", severity="error",
                        op=f"{type(e).__name__}: {e}",
                        hint="freezing or tracing this config crashed — the "
                             "serving graph cannot be verified",
                        where=f"configs:{name}",
                    )], []
                findings += fs
                print(f"[graph] configs:{name}: {len(checked)} step(s) "
                      f"traced, {len(fs)} finding(s)")

    if not args.no_lint:
        from repro.analysis.lint import lint_repo

        fs = lint_repo()
        findings += fs
        print(f"[lint] {len(fs)} finding(s)")

    if findings:
        print(render(findings))
    if args.json:
        dump_json(findings, args.json)
        print(f"findings written to {args.json}")
    n_err = len(errors(findings))
    print(f"analysis: {len(findings)} finding(s), {n_err} error(s)")
    return 1 if n_err else 0


if __name__ == "__main__":
    sys.exit(main())
