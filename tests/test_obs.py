"""Observability layer: registry/instrument semantics, trace ring + span
balance, Chrome-trace / Prometheus export validity, and the two serving
acceptance properties — tokens bit-identical with tracing on/off, and trace
spans reconstructing TTFT/ITL exactly from the shared perf_counter clock."""
import dataclasses
import json

import jax
import numpy as np
import pytest

from repro.configs.registry import ARCHS, reduce_for_smoke
from repro.models.model import init_model
from repro.obs import (
    METRICS_SCHEMA_VERSION,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    Observability,
    TraceRecorder,
    chrome_trace,
    prometheus_text,
    validate_chrome_trace,
    validate_prometheus_text,
)
from repro.obs import check as obs_check
from repro.serve.engine import Request, ServeEngine

KEY = jax.random.key(0)
MAX_NEW = 4


# ---------------------------------------------------------------------------
# instruments / registry (pure)
# ---------------------------------------------------------------------------
def test_counter_labels_and_total():
    reg = MetricsRegistry()
    c = reg.counter("toks", "tokens emitted")
    c.inc()
    c.inc(3, backend="fused")
    c.inc(2, backend="gather")
    assert c.value() == 1
    assert c.value(backend="fused") == 3
    assert c.total == 6
    # get-or-create returns the same instrument; kind conflicts are errors
    assert reg.counter("toks") is c
    with pytest.raises(ValueError):
        reg.gauge("toks")


def test_gauge_last_write_wins():
    g = MetricsRegistry().gauge("lanes")
    g.set(3)
    g.set(1)
    assert g.value() == 1.0


def test_histogram_streaming_percentiles():
    h = MetricsRegistry().histogram("lat", buckets=(0.001, 0.01, 0.1, 1.0))
    for v in [0.0005] * 50 + [0.05] * 50:
        h.observe(v)
    assert h.count() == 100
    assert h.sum() == pytest.approx(50 * 0.0005 + 50 * 0.05)
    # p25 lands in the first bucket, p75 in the 0.1 bucket — the estimate
    # must stay inside the bucket that holds the true quantile
    assert h.percentile(25) <= 0.001
    assert 0.01 <= h.percentile(75) <= 0.1
    # out-of-range observations land in the +Inf bin, not a crash
    h.observe(50.0)
    assert h.count() == 101
    assert h.percentile(100) > 1.0


def test_snapshot_schema_and_determinism():
    reg = MetricsRegistry()
    reg.counter("b").inc(2)
    reg.counter("a").inc(1, mode="x")
    reg.histogram("h").observe(0.01)
    snap = reg.snapshot()
    assert snap["metrics_schema_version"] == METRICS_SCHEMA_VERSION
    assert snap["b"] == 2 and snap["a{mode=x}"] == 1
    assert snap["h"]["count"] == 1
    assert list(snap) == list(reg.snapshot())  # deterministic order


def test_disabled_registry_short_circuits():
    reg = MetricsRegistry(enabled=False)
    c = reg.counter("c")
    g = reg.gauge("g")
    h = reg.histogram("h")
    c.inc(5)
    g.set(2)
    h.observe(0.1)
    assert c.total == 0 and g.value() == 0 and h.count() == 0
    assert isinstance(c, Counter) and isinstance(g, Gauge) \
        and isinstance(h, Histogram)
    # snapshot carries only the schema stamp — no phantom series
    assert reg.snapshot() == {
        "metrics_schema_version": METRICS_SCHEMA_VERSION}


# ---------------------------------------------------------------------------
# trace recorder (pure)
# ---------------------------------------------------------------------------
def test_span_balance_survives_ring_wraparound():
    tr = TraceRecorder(capacity=8)
    for i in range(20):  # 40 events through an 8-slot ring
        with tr.span("work", f"req:{i % 3}"):
            pass
    assert len(tr) == 8
    assert tr.dropped == 32
    # balance is judged on lifetime depth counters, not surviving events —
    # evicted "B" events cannot fake an open span
    assert tr.span_balance() == {}
    tr.begin("open", "req:9")
    assert tr.span_balance() == {"req:9": 1}


def test_span_closes_on_exception():
    tr = TraceRecorder()
    with pytest.raises(RuntimeError):
        with tr.span("work", "t"):
            raise RuntimeError("body failed")
    assert tr.span_balance() == {}


def test_disabled_tracer_records_nothing():
    tr = TraceRecorder(enabled=False)
    tr.begin("a", "t")
    tr.instant("b", "t")
    tr.end("a", "t")
    assert len(tr) == 0 and tr.span_balance() == {}


# ---------------------------------------------------------------------------
# exporters + validators
# ---------------------------------------------------------------------------
def _sample_recorder():
    tr = TraceRecorder()
    tr.instant("submit", "req:0", ts=1.0)
    tr.begin("running", "req:0", ts=1.5)
    tr.complete("tick", "scheduler", 1.4, 0.3, lanes=1)
    tr.instant("token", "req:0", ts=2.0, n=1)
    tr.end("running", "req:0", ts=2.5)
    return tr


def test_chrome_trace_export_is_valid_and_complete():
    tr = _sample_recorder()
    obj = chrome_trace(tr)
    assert validate_chrome_trace(obj) == []
    evs = obj["traceEvents"]
    names = {(e["ph"], e["name"]) for e in evs}
    assert ("i", "submit") in names and ("X", "tick") in names
    # track metadata names every track so Perfetto labels the rows
    meta = {e["args"]["name"] for e in evs if e["ph"] == "M"
            and e["name"] == "thread_name"}
    assert {"req:0", "scheduler"} <= meta
    # timestamps exported in microseconds on the shared clock
    submit = next(e for e in evs if e["name"] == "submit")
    assert submit["ts"] == pytest.approx(1.0e6)
    assert obj["otherData"]["metrics_schema_version"] == \
        METRICS_SCHEMA_VERSION


def test_chrome_trace_validator_catches_imbalance():
    tr = TraceRecorder()
    tr.begin("running", "req:0")  # B without E
    errs = validate_chrome_trace(chrome_trace(tr))
    assert errs and any("balance" in e or "unclosed" in e for e in errs)


def test_prometheus_export_is_valid():
    reg = MetricsRegistry()
    reg.counter("sched_out_tokens", "tokens").inc(12)
    reg.gauge("kv_used_pages").set(3)
    h = reg.histogram("req_ttft_seconds", buckets=(0.1, 1.0))
    h.observe(0.05)
    h.observe(5.0)
    text = prometheus_text(reg)
    assert validate_prometheus_text(text) == []
    lines = text.splitlines()
    assert "# TYPE sched_out_tokens counter" in lines
    assert "sched_out_tokens 12" in lines
    # histogram exports cumulative buckets plus the +Inf/sum/count triple
    assert 'req_ttft_seconds_bucket{le="0.1"} 1' in lines
    assert 'req_ttft_seconds_bucket{le="+Inf"} 2' in lines
    assert "req_ttft_seconds_count 2" in lines


def test_prometheus_validator_catches_garbage():
    assert validate_prometheus_text("not a metric line at all!") != []
    # histogram missing its _count is incomplete
    bad = ("# TYPE h histogram\n"
           'h_bucket{le="+Inf"} 1\n'
           "h_sum 0.5\n")
    assert validate_prometheus_text(bad) != []


def test_check_cli_accepts_valid_rejects_invalid(tmp_path, capsys):
    good_trace = tmp_path / "trace.json"
    good_trace.write_text(json.dumps(chrome_trace(_sample_recorder())))
    reg = MetricsRegistry()
    reg.counter("c").inc()
    good_prom = tmp_path / "metrics.prom"
    good_prom.write_text(prometheus_text(reg))
    assert obs_check.main([str(good_trace), str(good_prom)]) == 0
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"traceEvents": [{"ph": "B"}]}))
    assert obs_check.main([str(bad)]) == 1
    assert obs_check.main([]) == 2


# ---------------------------------------------------------------------------
# serving acceptance: identity, balance, exact latency reconstruction
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(reduce_for_smoke(ARCHS["qwen3-8b"]),
                              moe_dropless=True)
    params = init_model(KEY, cfg)
    rng = np.random.default_rng(7)
    prompts = {uid: rng.integers(0, cfg.vocab, 3 + uid) for uid in range(4)}
    return cfg, params, prompts


def _serve(cfg, params, prompts, **kw):
    eng = ServeEngine(cfg, params, batch_size=2, max_len=32, page_size=8,
                      **kw)
    for uid, pr in prompts.items():
        eng.submit(Request(uid=uid, prompt=pr, max_new_tokens=MAX_NEW))
    done = eng.run()
    return eng, {u: r.generated for u, r in done.items()}


def _comparable_registry_view(reg):
    """Counter totals + histogram observation counts — everything in the
    registry that must be invariant to wall-clock (sums/percentiles of
    timing histograms legitimately differ between runs)."""
    out = {}
    for name, inst in reg.instruments().items():
        if isinstance(inst, Counter):
            out[name] = inst.total
        elif isinstance(inst, Histogram):
            out[name] = inst.count()
    return out


def test_tokens_and_counters_identical_tracing_on_off(setup):
    """Acceptance: tracing must never perturb decode — greedy tokens are
    bit-identical with tracing on vs off, and every counter/observation
    count in the registry agrees."""
    cfg, params, prompts = setup
    eng_off, out_off = _serve(cfg, params, prompts, trace=False)
    eng_on, out_on = _serve(cfg, params, prompts, trace=True)
    assert out_on == out_off
    assert _comparable_registry_view(eng_on.obs.registry) == \
        _comparable_registry_view(eng_off.obs.registry)
    assert len(eng_off.obs.tracer) == 0  # off really is off
    assert len(eng_on.obs.tracer) > 0


def test_span_balance_through_preempt_defrag_spec_stress(setup):
    """Every span opened is closed across the full lifecycle gauntlet:
    admit → forced preempt → re-admit → defrag → speculative rounds (with
    rollback) → finish.  The exported trace validates as Chrome JSON."""
    from repro.core.da import DAConfig
    from repro.core.freeze import freeze_model
    from repro.spec import SpecConfig

    cfg, params, prompts = setup
    art = freeze_model(params, DAConfig(x_signed=True),
                       mode="bitplane_stacked", model_cfg=cfg)
    spec = SpecConfig(provider="bitplane", gamma=2, draft_x_bits=6,
                      disable_below=0.0)
    eng = ServeEngine(cfg, art.params, batch_size=2, max_len=32, page_size=4,
                      spec=spec, trace=True)
    for uid, pr in prompts.items():
        # long enough that one speculative tick cannot finish a request —
        # the preemption below needs a live lane to evict
        eng.submit(Request(uid=uid, prompt=pr, max_new_tokens=12))
    eng.step()
    sched = eng._rt
    victims = [i for i, l in enumerate(sched.lanes) if l is not None]
    assert victims, "tick finished every request; nothing left to preempt"
    sched._preempt(victims[-1])
    sched.defrag()
    done = eng.run()
    assert sorted(done) == sorted(prompts)
    m = eng.metrics()
    assert m["preemptions"] >= 1
    assert m["spec"]["rounds"] > 0
    assert m["pool"]["used_pages"] == 0
    assert eng.obs.tracer.span_balance() == {}
    assert validate_chrome_trace(chrome_trace(eng.obs.tracer)) == []
    snap = eng.metrics_snapshot()
    assert snap["sched_preemptions"] >= 1
    assert snap["spec_rounds"] > 0
    assert validate_prometheus_text(prometheus_text(eng.obs.registry)) == []


def test_trace_reconstructs_ttft_itl_exactly(setup):
    """The token instants carry the SAME perf_counter stamps the scheduler
    wrote into Request.token_times — so TTFT/ITL percentiles recomputed
    from the trace equal latency_metrics() to float precision, not merely
    within sampling noise."""
    cfg, params, prompts = setup
    eng, _ = _serve(cfg, params, prompts, trace=True)
    m = eng.metrics()
    events = list(eng.obs.tracer.events)
    submit_ts, token_ts = {}, {}
    for ev in events:
        if ev.ph == "i" and ev.track.startswith("req:"):
            uid = int(ev.track.split(":")[1])
            if ev.name == "submit":
                submit_ts[uid] = ev.ts
            elif ev.name == "token":
                token_ts.setdefault(uid, []).append(ev.ts)
    assert sorted(token_ts) == sorted(prompts)
    ttft = [token_ts[u][0] - submit_ts[u] for u in sorted(token_ts)]
    itl = [b - a for u in token_ts
           for a, b in zip(token_ts[u], token_ts[u][1:])]
    assert float(np.percentile(ttft, 50)) * 1e3 == \
        pytest.approx(m["ttft_p50_ms"], abs=1e-9)
    assert float(np.percentile(itl, 50)) * 1e3 == \
        pytest.approx(m["itl_p50_ms"], abs=1e-9)
    assert all(len(ts) == MAX_NEW for ts in token_ts.values())


def test_slot_runtime_traces_lifecycle(setup):
    """The legacy slot runtime rides the same Observability bundle: spans
    balance, the trace validates, and the shared metrics() core agrees."""
    cfg, params, prompts = setup
    eng, out = _serve(cfg, params, prompts, runtime="slots", trace=True)
    assert sorted(out) == sorted(prompts)
    assert eng.obs.tracer.span_balance() == {}
    assert validate_chrome_trace(chrome_trace(eng.obs.tracer)) == []
    m = eng.metrics()
    assert m["runtime"] == "slots"
    assert m["out_tokens"] == len(prompts) * MAX_NEW
    assert eng.metrics_snapshot()["sched_out_tokens"] == \
        len(prompts) * MAX_NEW


def test_observability_bundle_defaults():
    obs = Observability.make()
    assert obs.registry.enabled and not obs.tracer.enabled
    obs_t = Observability.make(trace=True)
    assert obs_t.tracer.enabled
    obs_off = Observability.make(metrics=False)
    assert not obs_off.registry.enabled
