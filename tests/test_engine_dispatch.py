"""The engine's shape-aware ``mode="auto"`` dispatch: every shape bucket
resolves to a registered, eligible backend; a missing autotune cache degrades
to the deterministic heuristic; unknown modes fail loudly.
"""
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine
from repro.core.da import DAConfig
from repro.core.engine import (
    BUCKET_SHAPES,
    canonical_mode,
    da_matmul,
    get_backend,
    load_cost_table,
    pack_quantized,
    pack_weights,
    registered_backends,
    select_backend,
    set_cost_table,
    shape_bucket,
)


@pytest.fixture(autouse=True)
def _isolate_cost_table():
    """Each test installs its own cost table; restore lazy state afterwards."""
    yield
    set_cost_table(None)


def test_bucketing_is_total_and_stable():
    """shape_bucket covers all of (M, K, N, bits) space and its 9 cells match
    the representative shapes the autotune benchmark times."""
    cfg_bits = 8
    seen = set()
    for m in (1, 8, 9, 256, 257, 4096):
        for k, n in ((8, 8), (128, 128), (512, 512), (4096, 4096)):
            b = shape_bucket(m, k, n, cfg_bits)
            mb, kb, bits = b.split(":")
            assert mb in {"dec", "mid", "big"} and kb in {"s", "m", "l"}
            assert bits == f"b{cfg_bits}"
            seen.add(b)
    assert len(seen) == 9
    assert seen == {
        shape_bucket(m, k, n, cfg_bits) for m, k, n in BUCKET_SHAPES.values()
    }


@pytest.mark.parametrize("has_luts", [True, False])
@pytest.mark.parametrize("cell", sorted(BUCKET_SHAPES))
def test_auto_returns_registered_backend_for_every_bucket(cell, has_luts):
    """No cache: the fallback policy yields a registered, eligible backend
    for every shape bucket, with and without LUTs."""
    set_cost_table({})  # simulate absent autotune cache
    m, k, n = BUCKET_SHAPES[cell]
    cfg = DAConfig(x_signed=True)
    name = select_backend(m, k, n, cfg, has_luts=has_luts)
    spec = registered_backends()[name]
    assert spec.is_da and spec.supports(cfg, has_luts)


def test_auto_follows_measured_costs():
    """With a cost table present, auto picks the cheapest eligible backend —
    and ignores measurements for ineligible ones (LUT modes without LUTs)."""
    cfg = DAConfig(x_signed=True)
    bucket = shape_bucket(4, 64, 128, cfg.x_bits)
    set_cost_table({bucket: {"onehot": 1.0, "bitplane": 5.0, "int8": 0.1}})
    # int8 is measured cheapest but is not a DA backend: never auto-picked
    assert select_backend(4, 64, 128, cfg, has_luts=True) == "onehot"
    # without LUTs the measured winner is ineligible → next eligible measured
    assert select_backend(4, 64, 128, cfg, has_luts=False) == "bitplane"


def test_auto_fallback_when_bucket_unmeasured():
    """A cache that lacks the bucket behaves exactly like no cache."""
    cfg = DAConfig(x_signed=True)
    other = shape_bucket(512, 2048, 2048, cfg.x_bits)
    set_cost_table({other: {"bitplane": 1.0}})
    with_table = select_backend(4, 64, 128, cfg, has_luts=True)
    set_cost_table({})
    without = select_backend(4, 64, 128, cfg, has_luts=True)
    assert with_table == without


def test_bucket_miss_warns_once_per_bucket_and_backend():
    """A tuned cache that misses the dispatched bucket warns ONCE per
    (bucket, fallback backend) — a decode loop hits the same bucket every
    token and must not spam — while a wholly absent cache stays silent."""
    import warnings as _warnings

    cfg = DAConfig(x_signed=True)
    other = shape_bucket(512, 2048, 2048, cfg.x_bits)
    set_cost_table({other: {"bitplane": 1.0}})
    with pytest.warns(UserWarning, match="no timings"):
        first = select_backend(4, 64, 128, cfg, has_luts=True)
    with _warnings.catch_warnings():
        _warnings.simplefilter("error")  # any repeat warning would raise
        assert select_backend(4, 64, 128, cfg, has_luts=True) == first
        # a different bucket gets its own single warning
        with pytest.warns(UserWarning, match="no timings"):
            select_backend(300, 64, 128, cfg, has_luts=True)
    # installing a fresh table resets the dedup set
    set_cost_table({other: {"bitplane": 1.0}})
    with pytest.warns(UserWarning, match="no timings"):
        select_backend(4, 64, 128, cfg, has_luts=True)
    # no cache at all → heuristic silently (the engine never requires tuning)
    set_cost_table({})
    with _warnings.catch_warnings():
        _warnings.simplefilter("error")
        select_backend(4, 64, 128, cfg, has_luts=True)


def test_cost_table_loads_from_json(tmp_path):
    """The autotune JSON cache round-trips through the loader; junk entries
    (unknown backends, malformed costs) are dropped, not fatal."""
    cfg = DAConfig(x_signed=True)
    bucket = shape_bucket(4, 64, 128, cfg.x_bits)
    p = tmp_path / "autotune.json"
    p.write_text(json.dumps({
        "version": 1, "device": "cpu",
        "table": {bucket: {"lut": 2.0, "bitplane_stacked": 9.0,
                           "not_a_backend": 1e-9, "bitplane": "junk"}},
    }))
    table = load_cost_table(p)
    assert table[bucket] == {"lut": 2.0, "bitplane_stacked": 9.0}
    set_cost_table(table)
    assert select_backend(4, 64, 128, cfg, has_luts=True) == "lut"


def test_cost_table_absent_or_corrupt_is_safe(tmp_path):
    """Missing and corrupt caches degrade to {} — dispatch still works."""
    assert load_cost_table(tmp_path / "nope.json") == {}
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    assert load_cost_table(bad) == {}
    set_cost_table({})
    assert select_backend(1, 16, 16, DAConfig(x_signed=True), True)


def test_unknown_mode_rejected_with_clear_error():
    rng = np.random.default_rng(0)
    w = rng.integers(-128, 128, (16, 8)).astype(np.int32)
    packed = pack_quantized(w, cfg=DAConfig(x_signed=True))
    x = jnp.asarray(rng.normal(size=(2, 16)), dtype=jnp.float32)
    with pytest.raises(ValueError, match="unknown DA mode 'warp'"):
        da_matmul(x, packed, mode="warp")
    with pytest.raises(ValueError, match="registered backends"):
        get_backend("warp9")


def test_legacy_mode_aliases_canonicalize():
    assert canonical_mode("da_lut") == "lut"
    assert canonical_mode("da_bitplane") == "bitplane"
    assert canonical_mode("da_bitplane_stacked") == "bitplane_stacked"
    assert get_backend("da_lut").name == "lut"


def test_auto_dispatch_end_to_end_matches_explicit():
    """mode='auto' (the surface serve/engine.py and core/linear.py use)
    produces the same integers as every explicit backend, whatever it picks."""
    set_cost_table({})
    rng = np.random.default_rng(5)
    x = rng.normal(size=(3, 32)).astype(np.float32)
    w = rng.normal(size=(32, 16)).astype(np.float32)
    packed = pack_weights(jnp.asarray(w))  # mode defaults to "auto"
    y_auto = np.asarray(packed(jnp.asarray(x)))
    y_exp = np.asarray(da_matmul(jnp.asarray(x), packed, mode="bitplane"))
    np.testing.assert_array_equal(y_auto, y_exp)


def test_packed_auto_respects_lut_cell_limit():
    """pack_weights(mode='auto'): LUTs built only when they fit the budget,
    and dispatch adapts (no LUTs → storage-free backend)."""
    rng = np.random.default_rng(9)
    w = jnp.asarray(rng.normal(size=(64, 32)), dtype=jnp.float32)
    small = pack_weights(w)                      # 2^8/8 × 2048 cells: fits
    tight = pack_weights(w, lut_cell_limit=100)       # budget too small
    assert small.has_luts and not tight.has_luts
    set_cost_table({})
    cfg = DAConfig(x_signed=True)
    assert select_backend(4, 64, 32, cfg, small.has_luts) == "lut"
    chosen = select_backend(4, 64, 32, cfg, tight.has_luts)
    assert not registered_backends()[chosen].needs_luts


def test_engine_default_cache_path_env(monkeypatch, tmp_path):
    p = tmp_path / "alt.json"
    monkeypatch.setenv("REPRO_ENGINE_AUTOTUNE", str(p))
    assert engine.default_cache_path() == p


def test_explicit_path_load_is_read_only(tmp_path):
    """load_cost_table(path) inspects without redirecting auto dispatch —
    only default-path loads (or set_cost_table) touch the process table."""
    installed = {"some:bucket:b8": {"bitplane": 1.0}}
    set_cost_table(installed)
    p = tmp_path / "other.json"
    p.write_text(json.dumps({"device": "cpu", "table": {}}))
    assert load_cost_table(p) == {}
    assert load_cost_table() == installed  # process table untouched


def test_cost_table_registry_fingerprint_mismatch_warns(tmp_path):
    """A cache tuned against a different backend registry (renamed/added/
    removed backends) is ignored with a warning — the heuristic fallback
    serves dispatch instead of stale rankings or a KeyError."""
    import jax

    from repro.core.engine import registry_fingerprint

    cfg = DAConfig(x_signed=True)
    bucket = shape_bucket(4, 64, 128, cfg.x_bits)
    stale = tmp_path / "stale.json"
    stale.write_text(json.dumps({
        "version": 1, "device": jax.default_backend(),
        "registry": "00000000", "table": {bucket: {"lut": 1.0}},
    }))
    with pytest.warns(UserWarning, match="different backend registry"):
        assert load_cost_table(stale) == {}
    # a matching fingerprint loads normally; absence of a stamp is accepted
    # (pre-fingerprint caches keep working)
    fresh = tmp_path / "fresh.json"
    fresh.write_text(json.dumps({
        "version": 1, "device": jax.default_backend(),
        "registry": registry_fingerprint(), "table": {bucket: {"lut": 1.0}},
    }))
    assert load_cost_table(fresh) == {bucket: {"lut": 1.0}}


def test_cost_table_unknown_backend_names_warn(tmp_path):
    """Unknown backend names in a cache are dropped with a warning, and
    dispatch still resolves (heuristic covers untimed shapes)."""
    import jax

    cfg = DAConfig(x_signed=True)
    bucket = shape_bucket(4, 64, 128, cfg.x_bits)
    p = tmp_path / "renamed.json"
    p.write_text(json.dumps({
        "version": 1, "device": jax.default_backend(),
        "table": {bucket: {"warp_drive": 0.1, "lut": 2.0}},
    }))
    with pytest.warns(UserWarning, match="unregistered backends"):
        table = load_cost_table(p)
    assert table[bucket] == {"lut": 2.0}
    set_cost_table(table)
    assert select_backend(4, 64, 128, cfg, has_luts=True) == "lut"


def test_cost_table_rejects_other_device(tmp_path):
    """A cache tuned on different hardware must not steer dispatch (a
    TPU-tuned table would send CPU through interpret-mode Pallas)."""
    import jax

    cfg = DAConfig(x_signed=True)
    bucket = shape_bucket(4, 64, 128, cfg.x_bits)
    p = tmp_path / "tuned_elsewhere.json"
    other = "tpu" if jax.default_backend() != "tpu" else "cpu"
    p.write_text(json.dumps(
        {"version": 1, "device": other, "table": {bucket: {"pallas_lut": 0.1}}}
    ))
    assert load_cost_table(p) == {}


def test_explicit_mode_enforces_capabilities():
    """An explicit mode that violates its capability spec errors instead of
    silently computing wrong integers (int8 wraps unsigned codes ≥ 128)."""
    from repro.core.engine import da_vmm as engine_da_vmm

    rng = np.random.default_rng(2)
    w = rng.integers(-128, 128, (16, 8)).astype(np.int32)
    ucfg = DAConfig(x_signed=False)
    packed = pack_quantized(w, cfg=ucfg)
    x = jnp.asarray(rng.integers(0, 256, (2, 16)), dtype=jnp.int32)
    with pytest.raises(ValueError, match="signed"):
        engine_da_vmm(x, packed, mode="int8", cfg=ucfg)


def test_explicit_auto_overrides_packed_mode():
    """mode='auto' at the call site runs shape dispatch even on an artifact
    packed with a concrete default mode; mode=None defers to the artifact.
    (Outputs are bit-identical either way — that's the engine's invariant —
    so the dispatch target is asserted on the resolver.)"""
    from repro.core.engine import _resolve_spec

    cfg = DAConfig(x_signed=True)
    bucket = shape_bucket(3, 32, 16, cfg.x_bits)
    set_cost_table({bucket: {"bitplane_stacked": 1.0, "lut": 50.0}})
    auto = _resolve_spec("auto", 3, 32, 16, cfg, True, default_mode="lut")
    assert auto.name == "bitplane_stacked"  # measured winner, not the default
    deferred = _resolve_spec(None, 3, 32, 16, cfg, True, default_mode="lut")
    assert deferred.name == "lut"
    # and the float path accepts both spellings end-to-end
    rng = np.random.default_rng(6)
    w = jnp.asarray(rng.normal(size=(32, 16)), dtype=jnp.float32)
    packed = pack_weights(w, mode="lut")
    x = jnp.asarray(rng.normal(size=(3, 32)), dtype=jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(da_matmul(x, packed, mode="auto")),
        np.asarray(da_matmul(x, packed)),
    )
