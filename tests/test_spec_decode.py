"""Speculative decoding: acceptance math, partial-bits engine evaluation,
token identity for all three draft providers, page-leak freedom, and the
acceptance-EMA auto-disable.

Fast lane: gamma <= 2 on the smoke model (the nightly benchmark exercises
production-shaped gammas and model sizes)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS, reduce_for_smoke
from repro.core import engine
from repro.core.da import DAConfig, truncate_codes
from repro.core.engine import da_matmul, da_vmm, pack_quantized, pack_weights, \
    set_cost_table
from repro.models.model import init_model
from repro.serve.engine import Request, ServeEngine
from repro.spec import SpecConfig, breakeven_acceptance, greedy_accept

KEY = jax.random.key(0)
MAX_NEW = 4


# ---------------------------------------------------------------------------
# acceptance math (pure)
# ---------------------------------------------------------------------------
def test_greedy_accept_prefix_rules():
    # no drafts match → only the correction token
    assert greedy_accept([5, 6], [1, 2, 3]) == 1
    # first matches, second diverges → matched prefix + correction
    assert greedy_accept([1, 6], [1, 2, 3]) == 2
    # all match → everything + the bonus token
    assert greedy_accept([1, 2], [1, 2, 3]) == 3
    # a later "match" after a divergence never counts (prefix semantics)
    assert greedy_accept([9, 2], [1, 2, 3]) == 1
    with pytest.raises(ValueError):
        greedy_accept([1, 2], [1, 2])  # window must cover drafts + 1


def test_breakeven_is_cost_ratio():
    assert breakeven_acceptance(4, 0.5) == 0.5
    assert breakeven_acceptance(8, 1.5) == 1.0
    assert breakeven_acceptance(2, -1.0) == 0.0


# ---------------------------------------------------------------------------
# partial-bits evaluation in the engine (the DA-native draft pass)
# ---------------------------------------------------------------------------
def test_truncate_codes_is_low_bit_masking():
    cfg = DAConfig(x_signed=True)
    xq = jnp.asarray(np.random.default_rng(0).integers(-128, 128, (4, 16)),
                     dtype=jnp.int32)
    for eff in (8, 5, 2, 1):
        shifted, ecfg, drop = truncate_codes(xq, cfg, eff)
        assert ecfg.x_bits == eff and drop == 8 - eff
        mask = ~((1 << drop) - 1)
        np.testing.assert_array_equal(
            np.asarray(shifted) << drop, np.asarray(xq) & mask)
    with pytest.raises(ValueError):
        truncate_codes(xq, cfg, 0)
    with pytest.raises(ValueError):
        truncate_codes(xq, cfg, 9)


@pytest.mark.parametrize("mode", ["lut", "onehot", "bitplane",
                                  "bitplane_stacked"])
def test_da_vmm_partial_bits_equals_masked_codes(mode, rng):
    """Every backend's x_bits_eff evaluation == the exact product of the
    low-bit-masked codes (the top-plane partial sum, bit-exactly)."""
    cfg = DAConfig(x_signed=True)
    w = rng.integers(-128, 128, (24, 8)).astype(np.int32)
    packed = pack_quantized(w, cfg=cfg)
    xq = jnp.asarray(rng.integers(-128, 128, (3, 24)), dtype=jnp.int32)
    for eff in (8, 4, 2):
        y = np.asarray(da_vmm(xq, packed, mode=mode, cfg=cfg, x_bits_eff=eff))
        ref = (np.asarray(xq) & ~((1 << (8 - eff)) - 1)) @ w
        np.testing.assert_array_equal(y, ref)


def test_da_matmul_x_bits_eff_and_override_context(rng):
    set_cost_table({})
    w = jnp.asarray(rng.normal(size=(32, 16)), dtype=jnp.float32)
    packed = pack_weights(w)
    x = jnp.asarray(rng.normal(size=(3, 32)), dtype=jnp.float32)
    y_full = np.asarray(da_matmul(x, packed))
    # eff == x_bits is exactly the full evaluation
    np.testing.assert_array_equal(
        y_full, np.asarray(da_matmul(x, packed, x_bits_eff=8)))
    y4 = np.asarray(da_matmul(x, packed, x_bits_eff=4))
    assert not np.array_equal(y4, y_full)  # genuinely truncated
    # the trace-time override context drives calls with no explicit arg
    with engine.x_bits_override(4):
        np.testing.assert_array_equal(
            y4, np.asarray(jax.jit(lambda a: da_matmul(a, packed))(x)))
    # and full precision is restored outside the context
    np.testing.assert_array_equal(y_full, np.asarray(da_matmul(x, packed)))
    set_cost_table(None)


# ---------------------------------------------------------------------------
# serving: token identity + leak freedom for all three providers
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(reduce_for_smoke(ARCHS["qwen3-8b"]),
                              moe_dropless=True)
    params = init_model(KEY, cfg)
    rng = np.random.default_rng(7)
    prompts = {uid: rng.integers(0, cfg.vocab, 3 + uid) for uid in range(4)}

    from repro.core.freeze import freeze_model

    art = freeze_model(params, DAConfig(x_signed=True),
                       mode="bitplane_stacked", model_cfg=cfg)
    return cfg, params, art, prompts


def _serve(cfg, params, prompts, spec, **kw):
    eng = ServeEngine(cfg, params, batch_size=2, max_len=32, page_size=4,
                      spec=spec, **kw)
    for uid, pr in prompts.items():
        eng.submit(Request(uid=uid, prompt=pr, max_new_tokens=MAX_NEW))
    done = eng.run()
    return {u: r.generated for u, r in done.items()}, eng.metrics()


@pytest.mark.parametrize("provider", ["bitplane", "layerskip", "artifact"])
def test_spec_decode_token_identical_and_leak_free(setup, provider):
    """Acceptance: greedy spec decode emits EXACTLY the tokens of
    non-speculative greedy decode on the same frozen artifact, for every
    draft provider, and finishes with zero pages held."""
    cfg, params, art, prompts = setup
    if provider == "layerskip":
        serve_params, spec = params, SpecConfig(
            provider="layerskip", gamma=2, disable_below=0.0)
    elif provider == "artifact":
        dcfg = dataclasses.replace(cfg, n_layers=1, name="draft")
        spec = SpecConfig(provider="artifact", gamma=2,
                          draft_params=init_model(jax.random.key(1), dcfg),
                          draft_model_cfg=dcfg, disable_below=0.0)
        serve_params = art.params
    else:
        serve_params, spec = art.params, SpecConfig(
            provider="bitplane", gamma=2, draft_x_bits=6, disable_below=0.0)
    base, _ = _serve(cfg, serve_params, prompts, None)
    out, m = _serve(cfg, serve_params, prompts, spec)
    assert out == base
    assert m["spec"]["rounds"] > 0  # speculation actually ran
    assert m["spec"]["provider"] == provider
    assert m["pool"]["used_pages"] == 0  # rejected drafts leaked nothing


def test_spec_acceptance_ema_auto_disable(setup):
    """A drafter whose proposals never survive verification must be switched
    off per-request by the acceptance-EMA floor — and the output is still
    exactly the baseline (disable changes effort, never tokens)."""
    cfg, _, art, prompts = setup
    base, _ = _serve(cfg, art.params, prompts, None)
    # 1-bit drafts are noise on this model → acceptance ~0 → disable
    spec = SpecConfig(provider="bitplane", gamma=2, draft_x_bits=1,
                      warmup_rounds=1)
    out, m = _serve(cfg, art.params, prompts, spec)
    assert out == base
    assert m["spec"]["disabled_requests"] >= 1
    assert m["spec"]["enabled_requests"] < len(prompts)
    assert m["spec"]["acceptance_rate"] < m["spec"]["disable_floor"]


def test_spec_metrics_surface_in_scheduler(setup):
    cfg, _, art, prompts = setup
    spec = SpecConfig(provider="bitplane", gamma=2, draft_x_bits=6,
                      disable_below=0.0)
    _, m = _serve(cfg, art.params, prompts, spec)
    s = m["spec"]
    for key in ("acceptance_rate", "draft_steps", "verify_steps", "rounds",
                "drafted_tokens", "accepted_drafts", "disabled_requests",
                "enabled_requests", "cost_ratio", "gamma"):
        assert key in s, key
    # draft_steps counts single-token draft forwards (gamma per fused device
    # call), verify_steps counts verify calls, rounds counts lane-rounds
    # (several lanes share one batched call)
    assert s["draft_steps"] == s["gamma"] * s["verify_steps"]
    assert s["drafted_tokens"] == s["gamma"] * s["rounds"]
    assert s["rounds"] >= s["verify_steps"] > 0
    # a non-speculative engine reports spec=None (on/off state is explicit)
    _, m0 = _serve(cfg, art.params, prompts, None)
    assert m0["spec"] is None


def test_artifact_draft_survives_defrag_and_chunked_catch_up(setup):
    """Regression (review findings): the artifact drafter's own pools must
    move under the SAME remap as the target pools when defrag renumbers
    pages, and a long un-ingested context is caught up in
    prefill_chunk-bucketed slices — tokens stay exactly the baseline's
    through both."""
    cfg, _, art, prompts = setup
    dcfg = dataclasses.replace(cfg, n_layers=1, name="draft")
    spec = SpecConfig(provider="artifact", gamma=2,
                      draft_params=init_model(jax.random.key(1), dcfg),
                      draft_model_cfg=dcfg, disable_below=0.0)
    kw = dict(batch_size=2, max_len=32, page_size=4, prefill_chunk=4)
    base = {}
    for with_spec in (None, spec):
        eng = ServeEngine(cfg, art.params, spec=with_spec, **kw)
        for uid, pr in prompts.items():  # prompts up to 6 > chunk → catch-up
            eng.submit(Request(uid=uid, prompt=pr, max_new_tokens=MAX_NEW))
        for _ in range(3):
            eng.step()
        eng._rt.defrag()  # pages renumber; draft pools must move along
        done = eng.run()
        base[with_spec is None] = {u: r.generated for u, r in done.items()}
        assert eng.metrics()["pool"]["used_pages"] == 0
    assert base[False] == base[True]


def test_spec_config_and_engine_validation(setup):
    cfg, params, art, _ = setup
    with pytest.raises(ValueError, match="gamma"):
        SpecConfig(gamma=0)
    with pytest.raises(ValueError, match="greedy"):
        ServeEngine(cfg, art.params, batch_size=2, max_len=32, greedy=False,
                    spec=SpecConfig(provider="bitplane"))
    with pytest.raises(ValueError, match="paged runtime"):
        ServeEngine(cfg, art.params, batch_size=2, max_len=32,
                    runtime="slots", spec="bitplane")
    with pytest.raises(ValueError, match="bit-planes"):
        # float params have no bit-planes to truncate
        ServeEngine(cfg, params, batch_size=2, max_len=32, spec="bitplane")
    with pytest.raises(ValueError, match="unknown draft provider"):
        ServeEngine(cfg, art.params, batch_size=2, max_len=32,
                    spec=SpecConfig(provider="telepathy"))
    with pytest.raises(ValueError, match="draft_artifact"):
        ServeEngine(cfg, art.params, batch_size=2, max_len=32,
                    spec=SpecConfig(provider="artifact"))
