"""Per-workload energy/latency report on the paper's DA hardware model.

    PYTHONPATH=src python benchmarks/energy_report.py            # full
    PYTHONPATH=src python benchmarks/energy_report.py --quick    # CI-sized

Writes ``artifacts/BENCH_energy.json`` (override with ``--out``): the
CONV1 design point (the paper's Table-I geometry, priced straight off the
cost table — the calibration anchor), then one served workload per serving
feature — plain greedy decode, speculative decoding with the truncated-
bitplane drafter (drafts at ``draft_x_bits`` of ``x_bits`` planes →
exactly proportionally fewer read cycles), shared-prefix caching on a
common-system-prompt fleet (cache hits skip prefill compute, so the pJ the
scheduler attributes actually DROPS), and int8/int4 KV pools (same DA
compute, cheaper residency) — each with the scheduler's live
workload-weighted DA-vs-bit-slicing ratios from ``metrics()["hw"]``.

The payload declares ``regress_keys`` so ``python -m repro.obs.regress``
can gate a fresh run against the committed copy, and it validates under
``python -m repro.obs.check`` (schema-stamped, well-formed ``hw`` blocks).
The script itself exits nonzero if the CONV1 energy ratio falls below 10×
— the calibrated model reproducing the paper's headline is the whole
point of the file.

Honest reading of the LM-geometry numbers: the energy win survives scale
(the live ratio is ~14× at K=512 layers — no ADCs/DACs is a per-cycle
saving), but the *latency* ratio drops below 1 because the paper's chained
adder topology pays O(K/L) stagger per read cycle, which CONV1's K=25
never exposed.  The pipelined tree topology the hwmodel also carries
(``adder_topology="tree"``, beyond-paper) stays read-limited at any K;
serving-side topology selection is a follow-up.
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
import time

import jax
import numpy as np

try:  # run as `python benchmarks/energy_report.py` (script dir on sys.path)
    from stamp import stamp_and_write
except ImportError:  # imported as a module from the repo root
    from benchmarks.stamp import stamp_and_write

from repro.configs.registry import ARCHS
from repro.core.da import DAConfig
from repro.core.freeze import freeze_model
from repro.models.model import init_model
from repro.obs.hwcost import HardwareCostModel
from repro.serve.engine import Request, ServeEngine
from repro.spec import SpecConfig

SEED = 0
#: Table I's CONV1 layer: K=25 inputs, N=6 outputs.
CONV1 = ("conv1", 25, 6)


def build_artifact(quick: bool):
    d = 256 if quick else 512
    cfg = dataclasses.replace(
        ARCHS["qwen3-8b"],
        name="qwen3-energy-bench",
        n_layers=4,
        d_model=d,
        n_heads=8,
        n_kv_heads=4,
        head_dim=d // 8,
        d_ff=2 * d,
        vocab=2000 if quick else 8000,
        param_dtype="float32",
        compute_dtype="float32",
        remat=False,
        moe_dropless=True,
    )
    params = init_model(jax.random.key(SEED), cfg)
    # peaked-logit shaping (same as spec_decode.py): tie the LM head to a
    # boosted embedding table and damp the residual writes, so the
    # truncated-bitplane drafter has trained-LM-like margins to accept
    params["embed"]["table"] = params["embed"]["table"] * 4.0
    params["lm_head"]["w"] = params["embed"]["table"].T
    for pos in params["periods"]:
        blk = params["periods"][pos]
        blk["mixer"]["wo"] = blk["mixer"]["wo"] * 0.1
        blk["ffn"]["w_down"] = blk["ffn"]["w_down"] * 0.1
    art = freeze_model(params, DAConfig(x_signed=True), mode="bitplane",
                       model_cfg=cfg)
    return cfg, art


def run_workload(cfg, art, prompts, max_new: int, warm_first: bool = False,
                 **engine_kw) -> dict:
    eng = ServeEngine(cfg, art.params, batch_size=4, max_len=64,
                      page_size=8, **engine_kw)
    t0 = time.perf_counter()
    if warm_first:
        # run the first request alone so its prompt's prefix pages land in
        # the trie before the rest of the fleet admits (a same-tick fleet
        # would otherwise all miss — hits need a finished ingestion)
        eng.submit(Request(uid=0, prompt=prompts[0],
                           max_new_tokens=max_new))
        eng.run()
    for uid, prompt in enumerate(prompts):
        if warm_first and uid == 0:
            continue
        eng.submit(Request(uid=uid, prompt=prompt, max_new_tokens=max_new))
    done = eng.run()
    wall = time.perf_counter() - t0
    m = eng.metrics()
    hw = m["hw"]
    out = {
        "requests": len(prompts),
        "out_tokens": m["out_tokens"],
        "ctx_tokens": m["ctx_tokens"],
        "wall_s": round(wall, 3),
        "pj_per_out_token": hw["pj_per_out_token"],
        "energy_ratio": hw["live"]["energy_ratio"],
        "latency_ratio": hw["live"]["latency_ratio"],
        "hw": hw,
    }
    if m.get("spec"):
        out["acceptance_rate"] = round(m["spec"]["acceptance_rate"], 4)
    if m.get("prefix_cache"):
        out["prefix_hit_rate"] = round(m["prefix_cache"]["hit_rate"], 4)
    assert len(done) == len(prompts)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true", help="CI-sized run")
    ap.add_argument("--out", default="artifacts/BENCH_energy.json")
    args = ap.parse_args(argv)
    quick = args.quick

    # -- the paper's design point, straight off the cost table ---------------
    conv1 = HardwareCostModel.from_shapes([CONV1]).summary()
    print(f"CONV1: {conv1['pj_per_token']:.1f} pJ / "
          f"{conv1['ns_per_token']:.1f} ns per VMM, "
          f"ratios {conv1['ratios']}")

    # -- served workloads ----------------------------------------------------
    cfg, art = build_artifact(quick)
    rng = np.random.default_rng(SEED)
    max_new = 8 if quick else 24
    n_req = 4 if quick else 8
    prompts = [rng.integers(0, cfg.vocab, 6 + u) for u in range(n_req)]
    # shared-system-prompt fleet: one page-aligned common prefix
    shared = rng.integers(0, cfg.vocab, 16)
    shared_prompts = [np.concatenate([shared,
                                      rng.integers(0, cfg.vocab, 2 + u)])
                      for u in range(n_req)]
    spec = SpecConfig(provider="bitplane", gamma=2, draft_x_bits=4,
                      disable_below=0.0)
    workloads = {}
    for name, prm, kw in [
        ("greedy", prompts, {}),
        ("spec", prompts, {"spec": spec}),
        # same shared-prefix fleet with the cache off vs on: the ON run's
        # hits skip prefill compute, so attributed pJ/token drops
        ("prefix_cache_off", shared_prompts, {"warm_first": True}),
        ("prefix_cache", shared_prompts, {"prefix_cache": True,
                                          "warm_first": True}),
        ("kv_int8", prompts, {"kv_dtype": "int8"}),
        ("kv_int4", prompts, {"kv_dtype": "int4"}),
    ]:
        workloads[name] = run_workload(cfg, art, prm, max_new, **kw)
        w = workloads[name]
        print(f"{name:13s} {w['out_tokens']:4d} out-tokens  "
              f"{w['pj_per_out_token']:.3e} pJ/token  "
              f"energy x{w['energy_ratio']:.2f}  "
              f"latency x{w['latency_ratio']:.2f}")

    payload = {
        "benchmark": "energy_report",
        "quick": quick,
        "conv1": {"hw": conv1},
        "workloads": workloads,
        # the load-bearing numbers a fresh run must reproduce (analytic
        # model × deterministic greedy workload — tight by construction)
        "regress_keys": [
            "conv1.hw.pj_per_token",
            "conv1.hw.ns_per_token",
            "conv1.hw.ratios.energy",
            "conv1.hw.ratios.latency",
            "workloads.greedy.hw.pj_per_token",
            "workloads.greedy.energy_ratio",
            "workloads.greedy.latency_ratio",
            "workloads.spec.energy_ratio",
            "workloads.prefix_cache.energy_ratio",
            "workloads.kv_int8.energy_ratio",
            "workloads.kv_int4.energy_ratio",
        ],
    }
    path = stamp_and_write(args.out, payload, seed=SEED)
    print(f"wrote {path}")

    if conv1["ratios"]["energy"] < 10.0:
        print(f"FAIL: CONV1 energy ratio {conv1['ratios']['energy']:.2f} "
              "< 10x — the calibrated model no longer reproduces the "
              "paper's headline")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
