"""Parser hardening tests for the HLO text scanner (repro.analysis.hlo,
re-exported through the legacy repro.launch.hlo_tools surface).

The original single-regex parser missed multi-line op definitions, nested
tuple result types, and layout tiles with parenthesized suffixes — each is
pinned here against hand-built HLO snippets plus a real jit lowering.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.hlo import (
    HloOp,
    bytes_by_op_kind,
    custom_call_target,
    iter_ops,
    op_kinds,
    ops_of_kind,
    shape_bytes,
    shape_dtypes,
    top_collectives,
)

# -- shape/byte accounting ---------------------------------------------------


def test_shape_bytes_scalar_and_tuple():
    assert shape_bytes("f32[2,64]") == 2 * 64 * 4
    assert shape_bytes("s32[]") == 4
    assert shape_bytes("(f32[2,64]{1,0}, (s32[], u8[]))") == 512 + 4 + 1
    assert shape_bytes("token[]") == 0  # unknown dtype contributes nothing


def test_shape_dtypes():
    assert shape_dtypes("(f32[2]{0}, s8[4,4])") == {"f32", "s8"}


# -- logical-line joining ----------------------------------------------------


def test_multiline_op_definition_is_joined():
    txt = (
        "  %long.name.1 = f32[8,128]{1,0}\n"
        "      dot(%a, %b),\n"
        '      metadata={op_name="jit(f)/dot_general"}\n'
    )
    ops = list(iter_ops(txt))
    assert len(ops) == 1
    assert ops[0].kind == "dot"
    assert ops[0].result_bytes == 8 * 128 * 4


def test_wrapped_attribute_line_does_not_start_new_op():
    """A wrapped ``metadata={...}`` continuation has ``key=`` syntax that a
    naive line-anchored regex mistakes for a new op head."""
    txt = (
        "  %x = f32[4]{0} add(%a, %b),\n"
        "      metadata={op_name=\"while(body)/add\" source_file=\"f.py\"}\n"
        "  %y = f32[4]{0} multiply(%x, %b)\n"
    )
    kinds = [op.kind for op in iter_ops(txt)]
    assert kinds == ["add", "multiply"]


def test_nested_tuple_result_type():
    txt = "  %t = (f32[2,64]{1,0}, (s32[], u8[])) tuple(%a, %b, %c)\n"
    ops = list(iter_ops(txt))
    assert len(ops) == 1
    assert ops[0].kind == "tuple"
    assert ops[0].result_bytes == 2 * 64 * 4 + 4 + 1


def test_layout_tile_with_parenthesized_suffix():
    txt = "  %p = f32[8,128]{1,0:T(8,128)} parameter(0)\n"
    ops = list(iter_ops(txt))
    assert len(ops) == 1
    assert ops[0].kind == "parameter"
    assert ops[0].result_bytes == 8 * 128 * 4


def test_region_opener_brace_on_op_line():
    txt = (
        "fused_computation {\n"
        "  %p0 = s8[16]{0} parameter(0)\n"
        "  { %r = s8[16]{0} negate(%p0)\n"
        "}\n"
    )
    kinds = [op.kind for op in iter_ops(txt)]
    assert kinds == ["parameter", "negate"]


def test_custom_call_target_extraction():
    txt = ('  %cc = f32[4]{0} custom-call(%a), '
           'custom_call_target="tpu_custom_call", api_version=1\n')
    (op,) = iter_ops(txt)
    assert op.kind == "custom-call"
    assert custom_call_target(op) == "tpu_custom_call"


def test_collectives_count_start_not_done():
    txt = (
        "  %ag = f32[8]{0} all-gather-start(%a)\n"
        "  %agd = f32[8]{0} all-gather-done(%ag)\n"
        "  %ar = f32[8]{0} all-reduce(%b)\n"
    )
    names = [name for name, _, _ in top_collectives(txt)]
    assert sorted(names) == ["ag", "ar"]


# -- real lowering round-trip ------------------------------------------------


def test_real_jit_lowering_roundtrip():
    def f(x, w):
        return x @ w

    x = jnp.zeros((4, 16), jnp.float32)
    w = jnp.zeros((16, 8), jnp.float32)
    txt = jax.jit(f).lower(x, w).compile().as_text()
    kinds = op_kinds(txt)
    assert sum(kinds.values()) > 0
    dots = ops_of_kind(txt, "dot")
    fusions = ops_of_kind(txt, "fusion")
    assert dots or fusions  # the matmul is a dot, possibly fused
    if dots:
        assert dots[0][1] == 4 * 8 * 4  # [4, 8] f32 result, exact bytes
    agg = dict((k, b) for k, b, _ in bytes_by_op_kind(txt))
    assert "parameter" not in agg  # bookkeeping kinds are excluded


def test_result_bytes_property():
    op = HloOp(name="x", kind="add", type_str="bf16[2,3]", line_no=1,
               text="")
    assert op.result_bytes == 2 * 3 * 2


# -- the legacy shim ---------------------------------------------------------


def test_launch_hlo_tools_reexports_are_identical():
    import repro.analysis.hlo as new
    import repro.launch.hlo_tools as old

    for name in ("HloOp", "iter_ops", "ops_of_kind", "op_kinds",
                 "shape_bytes", "bytes_by_op_kind", "top_ops",
                 "top_collectives"):
        assert getattr(old, name) is getattr(new, name), name


def test_gather_bytes_for_paged_view_shape():
    """The PR 6 regression shape: a gather materializing the whole
    [B, W·ps, kv, hd] KV view must be measurable from the parsed op."""
    b, wps, kv, hd = 2, 40, 2, 16
    n = b * wps * kv * hd
    txt = f"  %g = f32[{b},{wps},{kv},{hd}]{{3,2,1,0}} gather(%pool, %idx)\n"
    (name, nbytes), = ops_of_kind(txt, "gather")
    assert name == "g" and nbytes == n * 4
