"""End-to-end CLI tests for ``python -m repro.analysis.check``: a freshly
frozen smoke artifact passes (exit 0) and gets its verdict recorded in the
manifest; structural failure modes exit nonzero."""
import dataclasses
import json
import os

import pytest

from repro.analysis.check import main, verdict_of
from repro.analysis.findings import Finding


@pytest.fixture(scope="module")
def smoke_artifact(tmp_path_factory):
    import jax

    from repro.configs.registry import ARCHS, reduce_for_smoke
    from repro.core.da import DAConfig
    from repro.core.freeze import freeze_model, save_artifact
    from repro.models.model import init_model

    cfg = dataclasses.replace(reduce_for_smoke(ARCHS["qwen3-8b"]),
                              moe_dropless=True)
    params = init_model(jax.random.key(0), cfg)
    art = freeze_model(params, DAConfig(x_signed=True),
                       mode="da_bitplane_stacked", model_cfg=cfg)
    directory = str(tmp_path_factory.mktemp("art") / "smoke_da")
    save_artifact(directory, art)
    return directory


@pytest.mark.slow
def test_cli_passes_on_smoke_artifact_and_records_verdict(
        smoke_artifact, tmp_path):
    out = str(tmp_path / "findings.json")
    rc = main(["--artifact", smoke_artifact, "--json", out])
    assert rc == 0
    with open(os.path.join(smoke_artifact, "manifest.json")) as f:
        verdict = json.load(f)["analysis"]
    assert verdict["ok"] is True and verdict["errors"] == 0
    assert "decode[fused]" in verdict["steps_checked"]
    assert "spec_draft[fused]" in verdict["steps_checked"]
    with open(out) as f:
        assert json.load(f) == []
    # the recorded verdict round-trips through load_artifact
    from repro.core.freeze import load_artifact

    assert load_artifact(smoke_artifact).analysis["ok"] is True


@pytest.mark.slow
def test_cli_no_record_leaves_manifest_alone(smoke_artifact):
    from repro.core.freeze import record_analysis

    record_analysis(smoke_artifact, {"ok": True, "marker": "before"})
    rc = main(["--artifact", smoke_artifact, "--no-record", "--no-lint",
               "--no-hlo"])
    assert rc == 0
    with open(os.path.join(smoke_artifact, "manifest.json")) as f:
        assert json.load(f)["analysis"]["marker"] == "before"


def test_cli_lint_only_is_fast_and_clean():
    assert main(["--lint-only"]) == 0


def test_cli_artifact_without_model_cfg_fails(tmp_path):
    """An artifact whose manifest lacks model_cfg cannot be traced — that
    is an error finding and a nonzero exit, not a silent skip."""
    import jax.numpy as jnp

    from repro.core.da import DAConfig
    from repro.core.freeze import freeze_model, save_artifact

    params = {"mixer": {"wq": jnp.zeros((32, 16), jnp.float32)}}
    art = freeze_model(params, DAConfig(x_signed=True), mode="da_bitplane")
    directory = str(tmp_path / "bare_da")
    save_artifact(directory, art)
    rc = main(["--artifact", directory, "--no-lint"])
    assert rc == 1


def test_verdict_of_counts_by_severity():
    findings = [
        Finding(pass_name="graph/x", severity="error", op="a", hint=""),
        Finding(pass_name="graph/x", severity="warning", op="b", hint=""),
        Finding(pass_name="lint/y", severity="note", op="c", hint=""),
    ]
    v = verdict_of(findings, ["decode[fused]"])
    assert v["ok"] is False
    assert (v["errors"], v["warnings"], v["notes"]) == (1, 1, 1)
    assert v["findings_by_pass"] == {"graph/x": 2, "lint/y": 1}
    assert v["steps_checked"] == ["decode[fused]"]


def test_verdict_of_clean():
    v = verdict_of([], ["decode[gather]"])
    assert v["ok"] is True and v["schema"] == 1
