"""Quickstart: the paper's technique in five minutes.

1. Build a weight matrix, quantize it (post-training symmetric INT8, §II-C).
2. Pre-VMM: compute all 2^8 weight sums per 8-row group and 'write the PMAs'
   (pack_quantized / pack_weights — the once-in-a-lifetime step, §III-A).
3. Run a bit-serial, multiplier-free, ADC-free VMM through the unified engine
   (§II) — every registered backend is bit-exact against the integer matmul,
   and mode="auto" picks the backend from the activation/layer shape.
4. Ask the calibrated hardware model what this costs on a ReRAM engine vs the
   bit-slicing baseline (Table I).

Run: PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    DAConfig,
    da_matmul,
    da_vmm,
    pack_quantized,
    pack_weights,
    registered_backends,
    select_backend,
)
from repro.core.hwmodel import table1


def main():
    rng = np.random.default_rng(0)

    # --- the paper's CONV1 workload: 1×25 vector · 25×6 matrix -------------
    x = rng.integers(0, 256, (1, 25)).astype(np.int32)      # 8-bit image patch
    w = rng.integers(-128, 128, (25, 6)).astype(np.int32)   # INT8 weights

    cfg = DAConfig(group_size=8, x_bits=8, x_signed=False)
    packed = pack_quantized(w, cfg=cfg)                      # pre-VMM (once!)
    print(f"PMAs: {packed.luts.shape[0]} arrays of 2^8={packed.luts.shape[1]} "
          f"weight-sums x {packed.luts.shape[2]} columns")

    y = da_vmm(jnp.asarray(x), packed, mode="lut")           # 8 bit-serial cycles
    print("DA result:      ", np.asarray(y)[0])
    print("integer matmul: ", (x @ w)[0])
    assert (np.asarray(y) == x @ w).all(), "DA must be bit-exact"
    print("bit-exact ✓ — no multiplier, no DAC, no ADC")

    # every eligible engine backend computes the same integers (int8 is
    # signed-only, so it sits this unsigned-activation demo out)
    verified = []
    for name, spec in sorted(registered_backends().items()):
        if spec.supports(cfg, packed.has_luts):
            assert (np.asarray(da_vmm(jnp.asarray(x), packed, mode=name))
                    == x @ w).all(), name
            verified.append(name)
    print(f"…and so does every eligible engine backend: "
          f"{', '.join(verified)}\n")

    # --- float end-to-end (LM-style linear layer) ---------------------------
    xf = rng.normal(size=(4, 64)).astype(np.float32)
    wf = rng.normal(size=(64, 32)).astype(np.float32)
    pw = pack_weights(jnp.asarray(wf))                       # codes + scale + LUTs
    y_da = da_matmul(jnp.asarray(xf), pw, mode="auto")       # shape-aware dispatch
    chosen = select_backend(4, 64, 32, DAConfig(x_signed=True), pw.has_luts)
    rel = np.abs(np.asarray(y_da) - xf @ wf).max() / np.abs(xf @ wf).max()
    print(f"float linear via DA engine (auto -> {chosen}): "
          f"rel err {rel:.4f} (int8 quantization only)\n")

    # --- what does it cost in silicon? (paper Table I) ----------------------
    t = table1(k=25, n=6)
    print("Table I (model ↔ paper):")
    print(f"  DA        : {t['da']['latency_ns']:.0f} ns, "
          f"{t['da']['energy_vmm_pj']:.1f} pJ   (paper: 88 ns, 110.2 pJ)")
    print(f"  bit-slice : {t['bitslice']['latency_ns']:.0f} ns, "
          f"{t['bitslice']['energy_vmm_pj']:.1f} pJ  (paper: 400 ns, 1421.5 pJ)")
    print(f"  DA is {t['latency_ratio']:.1f}x faster, "
          f"{t['energy_ratio']:.1f}x more energy-efficient, "
          f"uses {t['cell_ratio']:.0f}x more memory cells and "
          f"{t['transistor_ratio']:.1f}x fewer transistors.")


if __name__ == "__main__":
    main()
