"""Registry of assigned architectures and their input-shape sets.

Every entry is from public literature — source tags inline. Shapes:
  train_4k     seq 4096,   global batch 256  (train_step)
  prefill_32k  seq 32768,  global batch 32   (prefill)
  decode_32k   seq 32768,  global batch 128  (single-token decode, KV cache)
  long_500k    seq 524288, global batch 1    (long-context decode; runs only
               for sub-quadratic mixers: ssm/hybrid — see DESIGN.md)
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


LM_SHAPES = (
    ShapeSpec("train_4k", 4_096, 256, "train"),
    ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    ShapeSpec("decode_32k", 32_768, 128, "decode"),
    ShapeSpec("long_500k", 524_288, 1, "decode"),
)


def _sub_quadratic(cfg: ModelConfig) -> bool:
    return cfg.family in ("ssm", "hybrid")


def shapes_for(cfg: ModelConfig):
    """long_500k is skipped for pure full-attention archs (quadratic attention
    and a >100 TB KV cache at 524k are not deployable — DESIGN.md §4)."""
    return tuple(
        s for s in LM_SHAPES if s.name != "long_500k" or _sub_quadratic(cfg)
    )


ARCHS: Dict[str, ModelConfig] = {}


def reduce_for_smoke(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests (per the assignment:
    small layers/width, few experts, tiny vocab; one fwd/train step)."""
    changes: dict = dict(
        n_layers=cfg.period if cfg.period > 1 else 2,
        d_model=64,
        vocab=97,
        param_dtype="float32",
        compute_dtype="float32",
        remat=False,
    )
    if cfg.n_heads:
        changes.update(n_heads=4, n_kv_heads=2 if cfg.n_kv_heads < cfg.n_heads else 4)
        changes["head_dim"] = 32 if cfg.mrope_sections else 16
    if cfg.mrope_sections:
        changes["mrope_sections"] = (4, 6, 6)  # sums to head_dim/2 = 16
    if cfg.d_ff:
        changes["d_ff"] = 128
    if cfg.n_experts:
        changes.update(n_experts=6, top_k=2, moe_d_ff=32)
        if cfg.n_shared_experts:
            changes["n_shared_experts"] = 2
    if cfg.ssm_state:
        changes.update(ssm_state=16, ssm_head_dim=8, ssm_chunk=8)
    return dataclasses.replace(cfg, **changes)


def register(cfg: ModelConfig) -> ModelConfig:
    ARCHS[cfg.name] = cfg
    return cfg


def get(name: str) -> ModelConfig:
    return ARCHS[name]


def _load_all() -> None:
    from repro.configs import (  # noqa: F401
        jamba_1_5_large_398b,
        mamba2_780m,
        minitron_8b,
        mistral_nemo_12b,
        moonshot_v1_16b_a3b,
        musicgen_large,
        phi3_medium_14b,
        qwen2_moe_a2_7b,
        qwen2_vl_72b,
        qwen3_8b,
    )


_load_all()
