"""Pure-jnp oracles for the Pallas kernels (bit-exact integer references)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.da import DAConfig, bit_coefs, da_vmm_lut


def da_vmm_ref(xq, luts, cfg: DAConfig):
    """Oracle for kernels/da_vmm.py: faithful LUT-gather DA VMM → int32."""
    return da_vmm_lut(xq, luts, cfg)


def bitplane_vmm_ref(xq, wq, cfg: DAConfig):
    """Oracle for kernels/bitplane_vmm.py: Σ_b coef(b)·(xbit_b @ W) → int32."""
    mask = (1 << cfg.x_bits) - 1
    xm = jnp.bitwise_and(xq.astype(jnp.int32), mask)
    coefs = bit_coefs(cfg.x_bits, cfg.x_signed)
    acc = jnp.zeros(xq.shape[:-1] + (wq.shape[-1],), dtype=jnp.int32)
    for b in range(cfg.x_bits):
        plane = jnp.bitwise_and(jnp.right_shift(xm, b), 1)
        mr = jnp.matmul(plane, wq.astype(jnp.int32), preferred_element_type=jnp.int32)
        acc = acc + int(coefs[b]) * mr
    return acc
