"""Serve a small LM with batched requests through the DA-quantized engine —
the paper's setting end-to-end: weights are frozen after training, the
pre-VMM step builds the integer DA artifacts, and every linear layer of the
serving graph runs the multiplier-free datapath.

Run: PYTHONPATH=src python examples/serve_da.py [--requests 8] [--mode auto]

``--mode auto`` exercises the engine's shape-aware dispatch: layers whose
LUTs fit memory read the PMAs on decode-like shapes, everything else runs the
stacked bit-plane matmul — all behind one verified surface.
"""
import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs.registry import ARCHS
from repro.models.model import count_params, init_model
from repro.serve.engine import Request, ServeEngine
from repro.serve.quantize import da_memory_report


def build_cfg():
    return dataclasses.replace(
        ARCHS["qwen3-8b"],
        name="qwen3-20m",
        n_layers=4,
        d_model=256,
        n_heads=4,
        n_kv_heads=2,
        head_dim=64,
        d_ff=768,
        vocab=8000,
        param_dtype="float32",
        compute_dtype="float32",
        remat=False,
        moe_dropless=True,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--mode", default="auto",
                    choices=["auto", "lut", "onehot", "bitplane",
                             "bitplane_stacked", "int8", "float",
                             "da_lut", "da_bitplane"])  # legacy aliases
    args = ap.parse_args()

    cfg = build_cfg()
    params = init_model(jax.random.key(0), cfg)
    print(f"model: {count_params(cfg)/1e6:.1f}M params")

    t0 = time.perf_counter()
    eng = ServeEngine(cfg, params, batch_size=args.batch, max_len=96,
                      da_mode=args.mode)  # freezes through the unified engine
    if args.mode != "float":
        rep = da_memory_report(eng.params)
        print(f"pre-VMM freeze ({args.mode}) in {time.perf_counter()-t0:.1f}s: "
              f"{rep['da_matrices']} weight matrices -> DA form, "
              f"LUT blow-up {rep['cell_blowup']:.0f}x" if rep["lut_cells"]
              else f"pre-VMM freeze ({args.mode}): {rep['da_matrices']} matrices")
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for uid in range(args.requests):
        eng.submit(Request(
            uid=uid,
            prompt=rng.integers(0, cfg.vocab, rng.integers(4, 24)),
            max_new_tokens=int(rng.integers(8, 24)),
        ))
    done = eng.run()
    dt = time.perf_counter() - t0
    total_toks = sum(len(r.generated) for r in done.values())
    print(f"\nserved {len(done)} requests / {total_toks} tokens in {dt:.1f}s "
          f"({total_toks/dt:.1f} tok/s on CPU, continuous batching, "
          f"batch={args.batch})")
    for uid in sorted(done)[:4]:
        print(f"  req {uid}: {len(done[uid].generated)} tokens -> "
              f"{done[uid].generated[:8]}...")


if __name__ == "__main__":
    main()
