"""DA-quantized linear layer — the paper's technique as a first-class feature.

Training uses float matmuls (DA requires one *constant* operand; weights change
every step — the paper targets inference, §II-A). For serving, ``freeze_da``
converts a float weight into the DA artifact (int8 codes + per-column scale +
optionally the materialized weight-sum LUTs), and ``apply`` dispatches:

  mode="float"     x @ W                          (training / baseline serving)
  mode="int8"      int8×int8 reference matmul     (quantization-only baseline)
  mode="da_lut"    faithful DA (LUT readout)      (paper's architecture)
  mode="da_bitplane" storage-free DA              (deployable at LM scale)

``da_lut`` costs 2^L/L× the weight storage (the paper's 56×-more-cells
trade-off), so it is the default only for layers below ``lut_limit`` weights.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.da import (
    DAConfig,
    build_luts,
    da_vmm_bitplane,
    da_vmm_bitplane_stacked,
    da_vmm_lut,
)
from repro.core.quant import QTensor, quantize_acts_signed, quantize_weights


@dataclasses.dataclass(frozen=True)
class DAFrozenLinear:
    """Inference-frozen DA linear: the PMA contents for one weight matrix."""

    wq: jax.Array                 # [K, N] int32 codes
    w_scale: jax.Array            # [1, N]
    luts: Optional[jax.Array]     # [G, 2^L, N] or None (bitplane mode)
    cfg: DAConfig
    mode: str

    def __call__(self, x: jax.Array) -> jax.Array:
        lead = x.shape[:-1]
        x2 = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
        xq = quantize_acts_signed(x2, bits=self.cfg.x_bits)
        cfg = dataclasses.replace(self.cfg, x_signed=True)
        if self.mode == "da_lut":
            acc = da_vmm_lut(xq.q, self.luts, cfg)
        elif self.mode == "da_bitplane":
            acc = da_vmm_bitplane(xq.q, self.wq.astype(jnp.int32), cfg)
        elif self.mode == "da_bitplane_stacked":
            acc = da_vmm_bitplane_stacked(xq.q, self.wq, cfg)
        elif self.mode == "int8":
            acc = jnp.matmul(
                xq.q.astype(jnp.int8), self.wq.astype(jnp.int8),
                preferred_element_type=jnp.int32,
            )
        else:
            raise ValueError(self.mode)
        y = acc.astype(jnp.float32) * xq.scale * self.w_scale
        return y.reshape(lead + (self.wq.shape[-1],))


def freeze_da(
    w: jax.Array,
    cfg: DAConfig = DAConfig(x_signed=True),
    mode: str = "auto",
    lut_limit: int = 1 << 22,
) -> DAFrozenLinear:
    """Pre-VMM procedure (§III-A): quantize, sum weights, 'write the PMAs'.

    2-D weights [K, N] or batched 3-D [E, K, N] (per-expert PMAs for MoE).
    """
    wq: QTensor = quantize_weights(w, bits=8, axis=w.ndim - 2)
    if mode == "auto":
        per_mat = w.shape[-2] * w.shape[-1]
        mode = "da_lut" if per_mat <= lut_limit else "da_bitplane"
    if mode == "da_lut":
        build = build_luts
        for _ in range(w.ndim - 2):
            build = jax.vmap(build, in_axes=(0,), out_axes=0)
        luts = build(wq.q)
    else:
        luts = None
    # int8 storage: the codes are the deployable artifact (4× smaller reads)
    return DAFrozenLinear(
        wq=wq.q.astype(jnp.int8), w_scale=wq.scale, luts=luts, cfg=cfg,
        mode=mode,
    )


def dense(x: jax.Array, w) -> jax.Array:
    """Weight application that dispatches on the leaf type: a plain array is
    a float matmul (training); a DAFrozenLinear runs the paper's multiplier-
    free datapath (serving). MoE-style batched weights ([E,K,N] against
    [E,C,K]) vmap the DA path per expert."""
    if isinstance(w, DAFrozenLinear):
        if w.wq.ndim == 3:  # per-expert PMAs
            if x.ndim == 4:  # grouped MoE activations [G, E, C, D]
                return jax.vmap(lambda xg: dense(xg, w))(x)
            assert x.ndim == 3, x.shape
            if w.luts is None:
                y = jax.vmap(
                    lambda xe, wqe, se: dataclasses.replace(w, wq=wqe, w_scale=se)(xe)
                )(x, w.wq, w.w_scale)
            else:
                y = jax.vmap(
                    lambda xe, wqe, se, le: dataclasses.replace(
                        w, wq=wqe, w_scale=se, luts=le
                    )(xe)
                )(x, w.wq, w.w_scale, w.luts)
            return y.astype(x.dtype)
        return w(x).astype(x.dtype)
    if w.ndim == 3 and x.ndim == 4:
        return jnp.einsum("gecd,edf->gecf", x, w)
    if w.ndim == 3 and x.ndim == 3:
        return jnp.einsum("ecd,edf->ecf", x, w)
    return x @ w


jax.tree_util.register_pytree_with_keys(
    DAFrozenLinear,
    lambda t: (
        (("wq", t.wq), ("w_scale", t.w_scale), ("luts", t.luts)),
        (t.cfg, t.mode),
    ),
    lambda aux, ch: DAFrozenLinear(
        wq=ch[0], w_scale=ch[1], luts=ch[2], cfg=aux[0], mode=aux[1]
    ),
)
