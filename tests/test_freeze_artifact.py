"""The DA artifact pipeline: plan → pack → serialize → serve.

Covers the model-level planner (per-layer, measured + analytic fallback),
bit-exact PackedWeights persistence through the checkpoint layer (crc
verified), and the freeze-once/serve-many end-to-end: an artifact written to
disk and reloaded in a fresh, template-free path (no float weights in scope)
serves greedy decode identically to the in-memory frozen model.
"""
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.configs.registry import ARCHS
from repro.core import engine
from repro.core.da import DAConfig
from repro.core.engine import PackedWeights, da_matmul, shape_bucket
from repro.core.freeze import (
    DAArtifact,
    LayerPlan,
    analytic_costs,
    da_memory_report,
    freeze_model,
    load_artifact,
    plan_layer,
    plan_model,
    save_artifact,
)

KEY = jax.random.key(0)


@pytest.fixture(autouse=True)
def _isolate_cost_table():
    """Planner tests install their own cost tables; restore lazy state."""
    yield
    engine.set_cost_table(None)


def _serve_cfg(**kw):
    """Tiny qwen3-like serving config with two distinct VMM shape buckets:
    attention/MLP mats land in dec:s, the lm head (vocab 503) in dec:m."""
    base = dict(
        name="qwen3-tiny", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=128, vocab=503, param_dtype="float32",
        compute_dtype="float32", remat=False, moe_dropless=True,
    )
    base.update(kw)
    return dataclasses.replace(ARCHS["qwen3-8b"], **base)


def _two_bucket_table(m_hint: int, cfg):
    """Deterministic cost table: stacked wins the small bucket, lut the
    lm-head bucket — so a correct per-layer planner MUST differ by shape."""
    small = shape_bucket(m_hint, cfg.d_model, cfg.d_model, 8)
    head = shape_bucket(m_hint, cfg.d_model, cfg.vocab, 8)
    assert small != head, "test premise: two distinct buckets"
    return {
        small: {"bitplane_stacked": 1.0, "lut": 50.0, "bitplane": 40.0},
        head: {"lut": 1.0, "bitplane_stacked": 50.0, "bitplane": 60.0},
    }


# ---------------------------------------------------------------------------
# planner
# ---------------------------------------------------------------------------

def test_plan_layer_measured_beats_analytic():
    cfg = DAConfig(x_signed=True)
    table = {shape_bucket(4, 64, 64, 8): {"bitplane": 1.0, "lut": 9.0}}
    p = plan_layer(64, 64, cfg, m_hint=4, cost_table=table)
    assert p.mode == "bitplane" and p.source == "measured"
    assert p.est_cost == 1.0 and p.with_luts


def test_plan_layer_analytic_fallback_uses_hwmodel():
    """No measurement for the bucket: ranking comes from the analytic
    hardware model — PMA readout when LUTs exist, stacked bit-planes when
    the LUT blow-up is over budget."""
    cfg = DAConfig(x_signed=True)
    with_luts = plan_layer(64, 64, cfg, m_hint=4, cost_table={})
    assert with_luts.source == "analytic" and with_luts.mode == "lut"
    no_luts = plan_layer(64, 64, cfg, m_hint=4, cost_table={},
                         lut_cell_limit=100)
    assert not no_luts.with_luts and no_luts.mode == "bitplane_stacked"
    costs = analytic_costs(4, 64, 64, cfg, has_luts=True)
    assert costs["lut"] < costs["bitplane_stacked"] < costs["bitplane"]


def test_plan_model_is_per_layer_not_constant():
    """The acceptance property: plans differ across layer shapes."""
    cfg = _serve_cfg()
    params = jax.tree.map(jnp.asarray, {
        "mixer": {"wq": np.random.default_rng(0).normal(
            size=(2, cfg.d_model, cfg.d_model)).astype(np.float32)},
        "lm_head": {"w": np.random.default_rng(1).normal(
            size=(cfg.d_model, cfg.vocab)).astype(np.float32)},
    })
    plans = plan_model(params, DAConfig(x_signed=True), m_hint=2,
                       cost_table=_two_bucket_table(2, cfg))
    assert set(plans) == {"mixer/wq", "lm_head/w"}
    assert plans["mixer/wq"].mode == "bitplane_stacked"
    assert plans["lm_head/w"].mode == "lut"


def test_freeze_model_pinned_mode_matches_legacy():
    w = jnp.asarray(np.random.default_rng(2).normal(size=(32, 16)),
                    jnp.float32)
    art = freeze_model({"w": w}, DAConfig(x_signed=True), mode="da_lut")
    leaf = art.params["w"]
    assert isinstance(leaf, PackedWeights)
    assert leaf.mode == "lut" and leaf.has_luts
    assert art.plan["w"].source == "pinned"


def test_pinned_freeze_drops_dead_luts():
    """pin_modes=True with a storage-free winner writes no PMAs (the LUTs
    would be dead bytes in every artifact); pin_modes=False keeps feasible
    LUTs so runtime dispatch can still read them at other shapes."""
    cfg = DAConfig(x_signed=True)
    table = {shape_bucket(4, 64, 64, 8): {"bitplane_stacked": 1.0,
                                          "lut": 9.0}}
    w = {"wq": jnp.asarray(np.random.default_rng(7).normal(size=(64, 64)),
                           jnp.float32)}
    pinned = freeze_model(w, cfg, m_hint=4, cost_table=table)
    assert pinned.params["wq"].mode == "bitplane_stacked"
    assert not pinned.params["wq"].has_luts
    assert not pinned.plan["wq"].with_luts
    loose = freeze_model(w, cfg, m_hint=4, cost_table=table, pin_modes=False)
    assert loose.params["wq"].mode == "auto" and loose.params["wq"].has_luts


def test_skip_context_subtrees_stay_float():
    """A weight-named leaf under a router/conv/table subtree is not a VMM
    and must not be frozen (ancestor names gate, not just the leaf name)."""
    w = jnp.ones((8, 4), jnp.float32)
    art = freeze_model({"router": {"w": w}, "head": {"w": w}},
                       DAConfig(x_signed=True), mode="lut")
    assert not isinstance(art.params["router"]["w"], PackedWeights)
    assert isinstance(art.params["head"]["w"], PackedWeights)
    assert set(art.plan) == {"head/w"}


def test_group_size_candidates_recover_luts():
    """A layer whose LUTs bust the budget at L=8 can shrink its PMAs to
    L=4 (16-row tables) and keep the readout path — per-layer group size."""
    cfg = DAConfig(x_signed=True)
    # 2^8/8 = 32 cells/weight at L=8; 2^4/4 = 4 at L=4. Pick a budget between.
    k, n = 64, 64
    limit = 8 * k * n  # admits L=4 (4x), rejects L=8 (32x)
    p8 = plan_layer(k, n, cfg, cost_table={}, lut_cell_limit=limit)
    assert not p8.with_luts
    p48 = plan_layer(k, n, cfg, cost_table={}, lut_cell_limit=limit,
                     group_size_candidates=(8, 4))
    assert p48.with_luts and p48.group_size == 4
    assert p48.mode == "lut"


# ---------------------------------------------------------------------------
# persistence: checkpoint round-trip of PackedWeights
# ---------------------------------------------------------------------------

def _bare_frozen_tree():
    rng = np.random.default_rng(3)
    params = {
        "proj": {"wq": jnp.asarray(rng.normal(size=(24, 16)), jnp.float32)},
        "experts": {"w_up": jnp.asarray(
            rng.normal(size=(3, 16, 8)), jnp.float32)},  # stacked [E, K, N]
        "norm": {"scale": jnp.ones((16,), jnp.float32)},  # stays float
    }
    return freeze_model(params, DAConfig(x_signed=True), mode="lut")


def test_artifact_roundtrip_bit_exact(tmp_path):
    art = _bare_frozen_tree()
    d = str(tmp_path / "art")
    save_artifact(d, art)
    back = load_artifact(d)
    for key in ("proj", "experts"):
        name = next(iter(art.params[key]))
        a, b = art.params[key][name], back.params[key][name]
        assert isinstance(b, PackedWeights)
        np.testing.assert_array_equal(np.asarray(a.wq), np.asarray(b.wq))
        assert b.wq.dtype == jnp.int8
        np.testing.assert_array_equal(
            np.asarray(a.w_scale), np.asarray(b.w_scale))
        np.testing.assert_array_equal(np.asarray(a.luts), np.asarray(b.luts))
        assert b.cfg == a.cfg and b.mode == a.mode
    np.testing.assert_array_equal(
        np.asarray(art.params["norm"]["scale"]),
        np.asarray(back.params["norm"]["scale"]))
    assert back.plan == art.plan
    assert back.da_cfg == art.da_cfg


def test_artifact_crc_detects_corruption(tmp_path):
    art = _bare_frozen_tree()
    d = str(tmp_path / "art")
    save_artifact(d, art)
    man_path = os.path.join(d, "manifest.json")
    man = json.load(open(man_path))
    man["arrays"]["proj/wq/wq"]["crc32"] ^= 0xBAD
    json.dump(man, open(man_path, "w"))
    with pytest.raises(IOError, match="checksum"):
        load_artifact(d)


def test_restored_artifact_identical_outputs_jit_and_vmap(tmp_path):
    """The restored codes/scales/LUTs drive da_matmul to the exact same
    floats as the originals — under jit and under expert-stacked vmap."""
    art = _bare_frozen_tree()
    d = str(tmp_path / "art")
    save_artifact(d, art)
    back = load_artifact(d)
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(size=(5, 24)), jnp.float32)
    f = jax.jit(lambda p, xs: da_matmul(xs, p))
    np.testing.assert_array_equal(
        np.asarray(f(art.params["proj"]["wq"], x)),
        np.asarray(f(back.params["proj"]["wq"], x)))
    xe = jnp.asarray(rng.normal(size=(3, 4, 16)), jnp.float32)  # [E, M, K]
    g = jax.jit(lambda p, xs: jax.vmap(lambda pe, xs_e: pe(xs_e))(p, xs))
    np.testing.assert_array_equal(
        np.asarray(g(art.params["experts"]["w_up"], xe)),
        np.asarray(g(back.params["experts"]["w_up"], xe)))


def test_ckpt_template_restore_keeps_packedweights(tmp_path):
    """The classic template path (elastic restarts) round-trips frozen
    trees too: PackedWeights leaves restore bit-exactly into the template."""
    art = _bare_frozen_tree()
    ckpt.save(str(tmp_path), 7, art.params)
    out = ckpt.restore(str(tmp_path), 7, art.params)
    leaf = out["proj"]["wq"]
    assert isinstance(leaf, PackedWeights) and leaf.mode == "lut"
    np.testing.assert_array_equal(
        np.asarray(leaf.luts), np.asarray(art.params["proj"]["wq"].luts))


def test_load_artifact_rejects_non_artifact(tmp_path):
    ckpt.save_tree(str(tmp_path / "plain"), {"a": jnp.zeros((2,))})
    with pytest.raises(IOError, match="not a DA artifact"):
        load_artifact(str(tmp_path / "plain"))


def test_load_artifact_demotes_stale_backend_modes(tmp_path):
    """An artifact planned against a backend this build doesn't register
    degrades to mode='auto' with a warning — never KeyError at dispatch."""
    art = _bare_frozen_tree()
    d = str(tmp_path / "art")
    save_artifact(d, art)
    man_path = os.path.join(d, "manifest.json")
    man = json.load(open(man_path))
    for meta in man["packed"].values():
        meta["mode"] = "warp_drive"
    for plan in man["plan"].values():
        plan["mode"] = "warp_drive"
    json.dump(man, open(man_path, "w"))
    with pytest.warns(UserWarning, match="not registered"):
        back = load_artifact(d)
    assert back.params["proj"]["wq"].mode == "auto"
    assert back.plan["proj/wq"].mode == "auto"
    x = jnp.asarray(np.random.default_rng(5).normal(size=(2, 24)), jnp.float32)
    assert np.asarray(da_matmul(x, back.params["proj"]["wq"])).shape == (2, 16)


# ---------------------------------------------------------------------------
# per-layer memory report
# ---------------------------------------------------------------------------

def test_memory_report_per_layer_plan_rows():
    art = _bare_frozen_tree()
    rep = da_memory_report(art.params)
    assert rep["da_matrices"] == 2 and len(rep["layers"]) == 2
    by_name = {r["layer"]: r for r in rep["layers"]}
    row = by_name["proj/wq"]
    assert row["mode"] == "lut" and row["group_size"] == 8
    assert row["code_bytes"] == 24 * 16          # int8 codes
    assert row["lut_bytes"] == 3 * 256 * 16 * 4  # [G=3, 2^8, N=16] int32
    assert row["cell_blowup"] == pytest.approx(32.0)
    # aggregate keys unchanged (legacy surface)
    assert rep["weight_cells"] == 24 * 16 + 3 * 16 * 8
    assert rep["cell_blowup"] > 0


# ---------------------------------------------------------------------------
# end-to-end: freeze once, serve many
# ---------------------------------------------------------------------------

def test_serve_from_artifact_matches_in_memory(tmp_path):
    """The acceptance path: freeze a smoke model to a DAArtifact on disk,
    reload it template-free (zero float weights in scope), serve greedy
    decode through ServeEngine, and match the in-memory frozen model's
    tokens.  The plan must be per-layer: at least two layer shapes get
    different backends."""
    from repro.models.model import init_model
    from repro.serve.engine import Request, ServeEngine

    cfg = _serve_cfg()
    engine.set_cost_table(_two_bucket_table(2, cfg))
    params = init_model(KEY, cfg)
    eng_mem = ServeEngine(cfg, params, batch_size=2, max_len=32,
                          da_mode="auto")
    del params  # floats out of scope — everything below is packed

    # planner actually differed across layer shapes
    plans = eng_mem.artifact.plan
    assert len({(p.mode, p.with_luts) for p in plans.values()}) >= 2
    modes = {p.mode for p in plans.values()}
    assert {"lut", "bitplane_stacked"} <= modes

    d = str(tmp_path / "artifact")
    eng_mem.save_artifact(d)

    prompts = {uid: np.random.default_rng(10 + uid).integers(
        0, cfg.vocab, 5 + uid) for uid in range(3)}

    def serve(eng):
        for uid, pr in prompts.items():
            eng.submit(Request(uid=uid, prompt=pr, max_new_tokens=6))
        done = eng.run()
        return {uid: r.generated for uid, r in done.items()}

    got_mem = serve(eng_mem)

    # cold boot: fresh engine from disk only — no float params anywhere
    eng_disk = ServeEngine.from_artifact(d, batch_size=2, max_len=32)
    assert eng_disk.cfg.vocab == cfg.vocab
    rep = da_memory_report(eng_disk.params)
    assert rep["da_matrices"] == len(plans)
    got_disk = serve(eng_disk)

    assert got_mem.keys() == got_disk.keys()
    for uid in got_mem:
        assert got_mem[uid] == got_disk[uid], uid


def test_artifact_plan_survives_roundtrip_with_model_cfg(tmp_path):
    from repro.models.model import init_model

    cfg = _serve_cfg(n_layers=2)
    engine.set_cost_table(_two_bucket_table(2, cfg))
    art = freeze_model(init_model(KEY, cfg), DAConfig(x_signed=True),
                       m_hint=2, model_cfg=cfg)
    d = str(tmp_path / "a")
    save_artifact(d, art)
    back = load_artifact(d)
    assert back.model_cfg == cfg
    assert back.plan == art.plan
    assert isinstance(back, DAArtifact)
    assert all(isinstance(p, LayerPlan) for p in back.plan.values())
