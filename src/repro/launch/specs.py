"""Abstract input specs (ShapeDtypeStruct) and parameter PartitionSpecs.

``input_specs`` builds weak-type-correct, shardable stand-ins for every model
input — no device allocation; the dry-run lowers against these.

``state_pspecs`` / ``cache_pspecs`` map every parameter / cache leaf to a
PartitionSpec through the logical-axis rules (launch/sharding.py), including
the leading stacked-periods axis. Leaf names → logical axes:
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.registry import ShapeSpec
from repro.launch import sharding as shd
from repro.models.config import ModelConfig

# leaf name → logical axes, keyed by (name, ndim-without-stacking)
PARAM_LOGICAL: Dict[Tuple[str, int], Tuple[Optional[str], ...]] = {
    ("table", 2): ("vocab", "embed"),
    ("w", 2): ("embed", "vocab"),            # lm head
    ("wq", 2): ("embed", "heads"),
    ("wk", 2): ("embed", "heads"),           # flat kv dim (divisible even
    ("wv", 2): ("embed", "heads"),           #  when the kv-head count isn't)
    ("wo", 2): ("heads", "embed"),
    ("bq", 1): ("heads",),
    ("bk", 1): ("heads",),
    ("bv", 1): ("heads",),
    ("w_up", 2): ("embed", "ffn"),
    ("w_gate", 2): ("embed", "ffn"),
    ("w_down", 2): ("ffn", "embed"),
    ("w_up", 3): ("expert", "embed", "expert_ffn"),
    ("w_gate", 3): ("expert", "embed", "expert_ffn"),
    ("w_down", 3): ("expert", "expert_ffn", "embed"),
    ("router", 2): ("embed", "expert"),
    ("in_proj", 2): ("embed", "inner"),
    ("out_proj", 2): ("inner", "embed"),
    ("conv_w", 2): (None, "inner"),
}


DA_FIELDS = ("wq", "w_scale", "luts")


def _leaf_logical(path_names, shape) -> Tuple[Optional[str], ...]:
    name = path_names[-1]
    stacked = "periods" in path_names
    ndim = len(shape) - (1 if stacked else 0)
    if name in DA_FIELDS and len(path_names) >= 2:
        # DA-frozen linear: shard each artifact like the weight it derives
        # from. wq matches the parent weight's logical axes; the per-column
        # scale and the [.., G, 2^L, N] LUTs inherit only the output axis.
        parent = path_names[-2]
        base_ndim = ndim if name in ("wq", "w_scale") else ndim - 1
        base = PARAM_LOGICAL.get((parent, base_ndim))
        if base is not None:
            lead = base[:-2] if len(base) > 2 else ()
            out_ax = base[-1]
            if name == "wq":
                logical = base
            elif name == "w_scale":
                logical = lead + (None, out_ax)
            else:  # luts [.., G, 2^L, N]
                logical = lead + (None, "lut_addr", out_ax)
            if stacked:
                logical = (None,) + logical
            return logical
    logical = PARAM_LOGICAL.get((name, ndim), (None,) * ndim)
    if stacked:
        logical = (None,) + logical
    return logical


def _entry_name(entry) -> str:
    for attr in ("key", "name", "idx"):
        if hasattr(entry, attr):
            return str(getattr(entry, attr))
    return str(entry)


def tree_pspecs(tree: Any) -> Any:
    """PartitionSpec tree mirroring ``tree`` (under active mesh rules)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    specs = []
    for path, leaf in flat:
        names = [_entry_name(p) for p in path]
        logical = _leaf_logical(names, leaf.shape)
        specs.append(shd.pspec(logical, leaf.shape))
    return jax.tree_util.tree_unflatten(treedef, specs)


# ---------------------------------------------------------------------------
# cache specs
# ---------------------------------------------------------------------------
CACHE_LOGICAL = {
    "k": (None, "batch", "kv_seq", "kv_heads", "head_dim"),
    "v": (None, "batch", "kv_seq", "kv_heads", "head_dim"),
    "length": (None,),
    "conv": (None, "batch", None, "inner"),
    "ssm": (None, "batch", "ssm_heads", None, None),
}


def cache_pspecs(caches: Any) -> Any:
    flat, treedef = jax.tree_util.tree_flatten_with_path(caches)
    specs = []
    for path, leaf in flat:
        name = _entry_name(path[-1])
        logical = CACHE_LOGICAL.get(name, (None,) * leaf.ndim)
        specs.append(shd.pspec(logical, leaf.shape))
    return jax.tree_util.tree_unflatten(treedef, specs)


# ---------------------------------------------------------------------------
# abstract inputs
# ---------------------------------------------------------------------------
def batch_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, jax.ShapeDtypeStruct]:
    """Abstract train batch: tokens or stub embeddings + labels."""
    b, t = shape.global_batch, shape.seq_len
    if cfg.modality == "text":
        inputs = jax.ShapeDtypeStruct((b, t), jnp.int32)
    else:  # [audio]/[vlm]: precomputed frame/patch embeddings (frontend stub)
        inputs = jax.ShapeDtypeStruct((b, t, cfg.d_model), jnp.bfloat16)
    out = {"inputs": inputs, "labels": jax.ShapeDtypeStruct((b, t), jnp.int32)}
    if cfg.mrope_sections:
        out["positions"] = jax.ShapeDtypeStruct((b, t, 3), jnp.int32)
    return out


BATCH_LOGICAL = {
    "inputs": ("batch", "seq", "embed"),
    "labels": ("batch", "seq"),
    "positions": ("batch", "seq", None),
}


def batch_pspecs(batch: Dict[str, jax.ShapeDtypeStruct]) -> Dict[str, P]:
    out = {}
    for k, v in batch.items():
        logical = BATCH_LOGICAL[k][: v.ndim]
        out[k] = shd.pspec(logical, v.shape)
    return out


def decode_specs(cfg: ModelConfig, shape: ShapeSpec):
    """Abstract decode inputs: one new token per row + positions."""
    b = shape.global_batch
    if cfg.modality == "text":
        tok = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    else:
        tok = jax.ShapeDtypeStruct((b, 1, cfg.d_model), jnp.bfloat16)
    pos_shape = (b, 1, 3) if cfg.mrope_sections else (b, 1)
    return tok, jax.ShapeDtypeStruct(pos_shape, jnp.int32)


def prefill_specs(cfg: ModelConfig, shape: ShapeSpec):
    b, t = shape.global_batch, shape.seq_len
    if cfg.modality == "text":
        tok = jax.ShapeDtypeStruct((b, t), jnp.int32)
    else:
        tok = jax.ShapeDtypeStruct((b, t, cfg.d_model), jnp.bfloat16)
    pos_shape = (b, t, 3) if cfg.mrope_sections else (b, t)
    return tok, jax.ShapeDtypeStruct(pos_shape, jnp.int32)


def shardings_of(specs_tree: Any, mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs_tree,
                        is_leaf=lambda x: isinstance(x, P))
