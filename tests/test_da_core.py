"""Property tests: Distributed-Arithmetic VMM is bit-exact (paper §II).

Randomized coverage is seeded-numpy + parametrize (no hypothesis dependency):
each case draws shapes and data from its own deterministic generator, so the
sweep is reproducible and stdlib-only while covering the same space the old
property tests did (shape × signedness × group size × bit width).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.da import (
    DAConfig,
    bit_coefs,
    build_luts,
    da_matmul,
    da_vmm_bitplane,
    da_vmm_lut,
    da_vmm_onehot,
    group_addresses,
)
from repro.core.quant import quantize_weights


@pytest.mark.parametrize("seed", [
    s if s < 8 else pytest.param(s, marks=pytest.mark.slow) for s in range(24)
])
def test_da_modes_exact(seed):
    """All three core DA execution modes equal the integer matmul exactly,
    for randomized shape / signedness / group size / bit width."""
    rng = np.random.default_rng(seed)
    m = int(rng.integers(1, 9))
    k = int(rng.integers(1, 41))
    n = int(rng.integers(1, 13))
    signed = bool(rng.integers(0, 2))
    group = int(rng.choice([4, 8]))
    bits = int(rng.choice([4, 8]))
    lo, hi = (-(1 << (bits - 1)), 1 << (bits - 1)) if signed else (0, 1 << bits)
    x = rng.integers(lo, hi, (m, k)).astype(np.int32)
    w = rng.integers(-128, 128, (k, n)).astype(np.int32)
    ref = x @ w
    cfg = DAConfig(group_size=group, x_bits=bits, x_signed=signed)
    luts = build_luts(jnp.asarray(w), group)
    np.testing.assert_array_equal(np.asarray(da_vmm_lut(jnp.asarray(x), luts, cfg)), ref)
    np.testing.assert_array_equal(np.asarray(da_vmm_onehot(jnp.asarray(x), luts, cfg)), ref)
    np.testing.assert_array_equal(
        np.asarray(da_vmm_bitplane(jnp.asarray(x), jnp.asarray(w), cfg)), ref
    )


@pytest.mark.parametrize("m,k,n,signed,group,bits", [
    (1, 1, 1, False, 4, 4),       # minimal everything
    (1, 1, 1, True, 8, 8),
    (8, 40, 12, True, 8, 8),      # K a multiple of the group
    (8, 37, 12, True, 8, 8),      # K NOT a multiple (padding path)
    (3, 4, 5, False, 8, 8),       # K smaller than one group
    (5, 25, 6, False, 8, 8),      # the paper's CONV1 shape
    (2, 17, 3, True, 4, 4),       # odd K, small group, 4-bit inputs
])
def test_da_modes_exact_edges(m, k, n, signed, group, bits):
    """Pinned edge shapes the random sweep might miss on any given seed."""
    rng = np.random.default_rng(m * 1000 + k * 10 + n)
    lo, hi = (-(1 << (bits - 1)), 1 << (bits - 1)) if signed else (0, 1 << bits)
    x = rng.integers(lo, hi, (m, k)).astype(np.int32)
    w = rng.integers(-128, 128, (k, n)).astype(np.int32)
    ref = x @ w
    cfg = DAConfig(group_size=group, x_bits=bits, x_signed=signed)
    luts = build_luts(jnp.asarray(w), group)
    np.testing.assert_array_equal(np.asarray(da_vmm_lut(jnp.asarray(x), luts, cfg)), ref)
    np.testing.assert_array_equal(np.asarray(da_vmm_onehot(jnp.asarray(x), luts, cfg)), ref)
    np.testing.assert_array_equal(
        np.asarray(da_vmm_bitplane(jnp.asarray(x), jnp.asarray(w), cfg)), ref
    )


def test_lut_structure():
    """LUT[g, a] = sum of group rows whose address bit is set (paper Fig. 4:
    at address 10101100 the value w8+w6+w4+w3 is stored)."""
    w = jnp.arange(1, 9, dtype=jnp.int32)[:, None]  # K=8, N=1
    luts = np.asarray(build_luts(w, 8))  # [1, 256, 1]
    for addr in (0, 0b1, 0b10101100, 0xFF):
        expect = sum((i + 1) for i in range(8) if addr >> i & 1)
        assert luts[0, addr, 0] == expect
    # 2^L entries, all possible sums
    assert luts.shape == (1, 256, 1)


def test_group_addresses_bit_order():
    cfg = DAConfig(group_size=8, x_bits=8, x_signed=False)
    x = jnp.asarray([[1, 0, 1, 0, 0, 1, 0, 1]], dtype=jnp.int32) * 255
    addr = np.asarray(group_addresses(x, cfg))  # [1, 8, 1]
    # every bit-plane of 255 is 1 → address has bits set where x row is 255
    assert addr.shape == (1, 8, 1)
    assert all(a == 0b10100101 for a in addr[0, :, 0])


def test_sign_bit_coefficient():
    coefs = bit_coefs(8, True)
    assert coefs[-1] == -128 and coefs[0] == 1
    assert bit_coefs(8, False)[-1] == 128


def test_da_matmul_quant_roundtrip(rng):
    """Float end-to-end: DA ≈ float matmul within int8 quant error, and
    lut/bitplane modes agree bit-exactly."""
    x = rng.normal(size=(6, 64)).astype(np.float32)
    w = rng.normal(size=(64, 32)).astype(np.float32)
    wq = quantize_weights(jnp.asarray(w))
    luts = build_luts(wq.q)
    cfg = DAConfig(x_signed=True)
    y_lut = da_matmul(jnp.asarray(x), wq.q, wq.scale, cfg, mode="lut", luts=luts)
    y_bp = da_matmul(jnp.asarray(x), wq.q, wq.scale, cfg, mode="bitplane")
    ref = x @ w
    np.testing.assert_array_equal(np.asarray(y_lut), np.asarray(y_bp))
    rel = np.abs(np.asarray(y_lut) - ref).max() / np.abs(ref).max()
    assert rel < 0.03


def test_lut_memory_blowup():
    """The paper's 56×-more-cells trade-off: LUT cells = 2^L/L × weights."""
    w = jnp.ones((64, 16), dtype=jnp.int32)
    luts = build_luts(w, 8)
    assert luts.size / w.size == 256 / 8


def test_stacked_mode_exact(rng):
    """L7 stacked bit-plane DA (leading batch axis) == serial == int matmul."""
    from repro.core.da import da_vmm_bitplane_stacked

    for signed in (False, True):
        lo, hi = (-128, 128) if signed else (0, 256)
        x = rng.integers(lo, hi, (9, 77)).astype(np.int32)
        w = rng.integers(-128, 128, (77, 11)).astype(np.int32)
        cfg = DAConfig(x_signed=signed)
        got = np.asarray(
            da_vmm_bitplane_stacked(jnp.asarray(x), jnp.asarray(w), cfg))
        np.testing.assert_array_equal(got, x @ w)
