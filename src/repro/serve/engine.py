"""Serving engine: batched prefill/decode with KV caches and DA-quantized
weights (the paper's inference setting — weights constant, the DA precondition).

``serve_step`` (single-token decode over the whole batch) is what the
decode_32k / long_500k dry-run cells lower. The engine adds continuous
batching on top: a slot-based scheduler admits requests into free batch rows,
decodes all active rows each step, and retires rows on EOS/max-len.

DA quantization is wired through the artifact pipeline (repro.core.freeze):
pass ``da_mode`` — ``"auto"`` plans a backend/group-size/LUT decision per
layer from measured + analytic costs; a registered backend name pins every
layer — and float params are frozen into PackedWeights artifacts whose every
linear runs the multiplier-free datapath.  ``ServeEngine.from_artifact``
boots the same engine from a persisted artifact directory with zero float
weights and zero re-packing; ``save_artifact`` writes one.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.model import forward, init_caches


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # [T0] int32
    max_new_tokens: int = 32
    eos_id: int = -1              # -1 → never stops early
    generated: Optional[List[int]] = None

    def __post_init__(self):
        if self.generated is None:
            self.generated = []


def make_prefill_step(cfg: ModelConfig):
    """(params, caches, tokens [B,T], positions) → (logits_last [B,V], caches)."""

    def prefill(params, caches, tokens, positions):
        logits, caches = forward(
            params, tokens, cfg, positions=positions, caches=caches,
            update_cache=True, last_logit_only=cfg.prefill_last_only,
        )
        return logits[:, -1], caches

    return prefill


def make_serve_step(cfg: ModelConfig):
    """Single-token decode: (params, caches, token [B,1], pos [B,1]) →
    (logits [B,V], caches). This is the dry-run's decode workload."""

    def serve_step(params, caches, token, positions):
        logits, caches = forward(
            params, token, cfg, positions=positions, caches=caches
        )
        return logits[:, 0], caches

    return serve_step


def _mk_positions(cfg: ModelConfig, pos: jax.Array) -> jax.Array:
    if cfg.mrope_sections:
        return jnp.stack([pos, pos, pos], axis=-1)
    return pos


class ServeEngine:
    """Slot-based continuous batching over a fixed decode batch."""

    def __init__(
        self,
        cfg: ModelConfig,
        params: Any,
        batch_size: int,
        max_len: int,
        greedy: bool = True,
        da_mode: Optional[str] = None,
        da_pin_modes: bool = True,
    ):
        # da_mode: freeze float params through the DA artifact pipeline
        # ("auto" plans a backend per layer from measured + analytic costs;
        # a registered backend name pins every layer).  Params that already
        # carry PackedWeights leaves (a loaded artifact) are never re-packed.
        # da_pin_modes=False keeps runtime shape dispatch on the frozen
        # artifact (prefill and decode may pick different backends) instead
        # of baking in the decode-bucket plan.
        self.artifact = None
        if (da_mode is not None and da_mode != "float"
                and not _is_frozen(params)):
            from repro.core.da import DAConfig
            from repro.core.freeze import freeze_model

            self.artifact = freeze_model(
                params, DAConfig(x_signed=True), mode=da_mode,
                m_hint=batch_size, model_cfg=cfg, pin_modes=da_pin_modes,
            )
            params = self.artifact.params
        # the engine always uses the sliced prefill head (strictly better)
        cfg = dataclasses.replace(cfg, prefill_last_only=True)
        self.cfg = cfg
        self.params = params
        self.b = batch_size
        self.max_len = max_len
        self.greedy = greedy
        self.caches = init_caches(cfg, batch_size, max_len, cfg.dtype())
        self._prefill_one = jax.jit(make_prefill_step(cfg))
        self._decode = jax.jit(make_serve_step(cfg))
        self.slots: List[Optional[Request]] = [None] * batch_size
        self.slot_len = np.zeros(batch_size, dtype=np.int64)
        self.cur_token = np.zeros(batch_size, dtype=np.int32)
        self.queue: List[Request] = []
        self.done: Dict[int, Request] = {}

    # -- freeze-once, serve-many ---------------------------------------------
    @classmethod
    def from_artifact(
        cls,
        directory: str,
        batch_size: int,
        max_len: int,
        greedy: bool = True,
    ) -> "ServeEngine":
        """Boot a serving engine from a persisted DA artifact: the packed
        weights come straight off disk — no float params, no re-packing (the
        paper's freeze-once premise, operationally)."""
        from repro.core.freeze import load_artifact

        art = load_artifact(directory)
        if art.model_cfg is None:
            raise ValueError(
                f"artifact {directory} carries no model config; freeze with "
                "freeze_model(..., model_cfg=cfg) to make it servable"
            )
        eng = cls(art.model_cfg, art.params, batch_size, max_len,
                  greedy=greedy)
        eng.artifact = art
        return eng

    def save_artifact(self, directory: str) -> str:
        """Persist this engine's frozen weights + plan for later cold boots."""
        from repro.core.freeze import save_artifact

        if self.artifact is None:
            raise ValueError(
                "engine holds no DAArtifact (constructed without da_mode and "
                "not from_artifact) — nothing to save"
            )
        return save_artifact(directory, self.artifact)

    # -- admission -----------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        for i in range(self.b):
            if self.slots[i] is None and self.queue:
                req = self.queue.pop(0)
                self._prefill_slot(i, req)

    def _prefill_slot(self, i: int, req: Request) -> None:
        """Per-slot prefill (batch=1 caches then scatter into slot i).

        A production engine prefills in a separate batched pass; here each
        admission runs a b=1 prefill and copies the KV into the slot — simple
        and exact."""
        cfg = self.cfg
        t0 = len(req.prompt)
        caches1 = init_caches(cfg, 1, self.max_len, cfg.dtype())
        toks = jnp.asarray(req.prompt, dtype=jnp.int32)[None]
        pos = _mk_positions(cfg, jnp.arange(t0, dtype=jnp.int32)[None])
        logits, caches1 = self._prefill_one(self.params, caches1, toks, pos)
        self.caches = _scatter_slot(self.caches, caches1, i)
        tok = int(jnp.argmax(logits[0])) if self.greedy else int(
            jax.random.categorical(jax.random.key(req.uid), logits[0])
        )
        req.generated.append(tok)
        self.slots[i] = req
        self.slot_len[i] = t0 + 1
        self.cur_token[i] = tok

    # -- decode --------------------------------------------------------------
    def step(self) -> int:
        """One batched decode step over all active slots; returns #active."""
        self._admit()
        active = [i for i in range(self.b) if self.slots[i] is not None]
        if not active:
            return 0
        toks = jnp.asarray(self.cur_token, dtype=jnp.int32)[:, None]
        pos = _mk_positions(
            self.cfg, jnp.asarray(self.slot_len - 1, dtype=jnp.int32)[:, None]
        )
        logits, self.caches = self._decode(self.params, self.caches, toks, pos)
        nxt = np.asarray(jnp.argmax(logits, axis=-1), dtype=np.int32)
        for i in active:
            req = self.slots[i]
            tok = int(nxt[i])
            req.generated.append(tok)
            self.slot_len[i] += 1
            self.cur_token[i] = tok
            exhausted = len(req.generated) >= req.max_new_tokens
            if tok == req.eos_id or exhausted or self.slot_len[i] >= self.max_len:
                self.done[req.uid] = req
                self.slots[i] = None
        return len(active)

    def run(self, max_steps: int = 10_000) -> Dict[int, Request]:
        for _ in range(max_steps):
            if not self.step() and not self.queue:
                break
        return self.done


def _is_frozen(params: Any) -> bool:
    """Does the tree already carry PackedWeights leaves (a DA artifact)?"""
    from repro.core.engine import PackedWeights

    return any(
        isinstance(leaf, PackedWeights)
        for leaf in jax.tree.leaves(
            params, is_leaf=lambda x: isinstance(x, PackedWeights)
        )
    )


def _scatter_slot(caches: Any, caches1: Any, slot: int) -> Any:
    """Copy batch row 0 of caches1 into row ``slot`` of the engine caches.

    Cache layouts: KVCache k/v [P, B, S, kv, hd]; MambaCache conv [P, B, C-1,
    ch], ssm [P, B, H, Pd, S]; KVCache.length [P] is global (max over slots
    drives nothing — per-slot lengths are tracked host-side and masked via
    positions), so we take the elementwise max.
    """

    def one(big, small):
        if big.ndim == 1:  # stacked scalar lengths [n_periods]
            return jnp.maximum(big, small)
        return jax.lax.dynamic_update_slice(
            big, small.astype(big.dtype), (0, slot) + (0,) * (big.ndim - 2)
        )

    return jax.tree.map(one, caches, caches1)
