"""Benchmark regression gate: a fresh BENCH_*.json vs the committed copy.

Usage (what nightly CI runs after re-generating a benchmark)::

    python -m repro.obs.regress fresh.json artifacts/BENCH_energy.json \
        [--tolerance 0.25] [--key workloads.greedy.hw.ratios.energy ...]

Both files must be stamped metrics payloads (``metrics_schema_version``)
of the SAME schema version — a version drift is a schema change, not a
noise band, and fails loudly.  The keys compared are the payload's own
``regress_keys`` list (dotted paths into the nested JSON; every stamped
benchmark that wants guarding declares which of its numbers are
load-bearing), extendable/overridable with ``--key``.  A key missing from
either file, or whose values differ by more than ``--tolerance`` relative
(absolute, when the committed value is 0), is a regression: exit 1.

The check is symmetric — an "improvement" outside the band also fails,
because an unexplained jump in a calibrated analytic model is a bug in the
model, not a win.  Exit codes: 0 ok, 1 regression, 2 usage/parse error.
"""
from __future__ import annotations

import argparse
import json
import math
import sys
from typing import Any, List, Optional, Tuple


def _resolve(obj: Any, dotted: str) -> Tuple[bool, Any]:
    """Follow a dotted path through dicts (and list indices); returns
    (found, value)."""
    cur = obj
    for part in dotted.split("."):
        if isinstance(cur, dict) and part in cur:
            cur = cur[part]
        elif isinstance(cur, list) and part.lstrip("-").isdigit():
            idx = int(part)
            if -len(cur) <= idx < len(cur):
                cur = cur[idx]
            else:
                return False, None
        else:
            return False, None
    return True, cur


def _is_num(v: Any) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def compare(fresh: dict, committed: dict, keys: List[str],
            tolerance: float) -> List[str]:
    """Returns a list of regression messages (empty = accepted)."""
    errs: List[str] = []
    fv = fresh.get("metrics_schema_version")
    cv = committed.get("metrics_schema_version")
    if fv != cv:
        errs.append(f"schema version mismatch: fresh={fv} committed={cv}")
        return errs
    for key in keys:
        f_ok, f = _resolve(fresh, key)
        c_ok, c = _resolve(committed, key)
        if not f_ok or not c_ok:
            errs.append(f"{key}: missing from "
                        f"{'fresh' if not f_ok else 'committed'} file")
            continue
        if not _is_num(f) or not _is_num(c):
            if f != c:
                errs.append(f"{key}: non-numeric mismatch {f!r} != {c!r}")
            continue
        if not (math.isfinite(f) and math.isfinite(c)):
            # NaN compares False against any band — without this, a NaN
            # metric would sail through the gate
            errs.append(f"{key}: non-finite value fresh={f} committed={c}")
            continue
        if c == 0:
            delta, band = abs(f), f"abs {tolerance}"
        else:
            delta, band = abs(f - c) / abs(c), f"rel {tolerance}"
        if delta > tolerance:
            errs.append(f"{key}: fresh={f} committed={c} "
                        f"delta={delta:.4g} > {band}")
    return errs


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.regress",
        description="compare a fresh stamped BENCH_*.json against the "
                    "committed copy; exit nonzero on regression")
    ap.add_argument("fresh", help="freshly generated benchmark JSON")
    ap.add_argument("committed", help="committed reference JSON")
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="relative tolerance per key (default 0.25)")
    ap.add_argument("--key", action="append", default=[],
                    help="dotted path to compare (repeatable); adds to the "
                         "payload's own regress_keys")
    args = ap.parse_args(argv)
    payloads = []
    for path in (args.fresh, args.committed):
        try:
            with open(path) as f:
                obj = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"ERROR {path}: {e}")
            return 2
        if not isinstance(obj, dict) or "metrics_schema_version" not in obj:
            print(f"ERROR {path}: not a stamped metrics payload")
            return 2
        payloads.append(obj)
    fresh, committed = payloads
    declared = committed.get("regress_keys", [])
    if not isinstance(declared, list):
        print(f"ERROR {args.committed}: regress_keys must be a list")
        return 2
    keys = list(dict.fromkeys([*declared, *args.key]))
    if not keys:
        print(f"ERROR {args.committed}: no keys to compare — the payload "
              "declares no regress_keys and no --key was given")
        return 2
    errs = compare(fresh, committed, keys, args.tolerance)
    if errs:
        print(f"REGRESSION {args.fresh} vs {args.committed}")
        for e in errs:
            print(f"  - {e}")
        return 1
    print(f"OK {args.fresh} vs {args.committed} "
          f"({len(keys)} keys within {args.tolerance})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
