"""Serving engine: continuous batching with KV caches and DA-quantized
weights (the paper's inference setting — weights constant, the DA
precondition).

``ServeEngine`` is a thin facade over two runtimes:

* ``runtime="paged"`` (default for attention stacks) — the continuous-
  batching scheduler in ``repro.serve.scheduler``: paged KV cache, admission
  queue with token-budget policy, chunked prefill coalesced into the decode
  batch, preemption, streaming callbacks and latency metrics.
* ``runtime="slots"`` — the legacy fixed-slot runtime kept for architectures
  whose mixers hold O(1) state (Mamba/hybrid stacks gain nothing from KV
  paging) and as the benchmark baseline. Its per-slot prefill pads prompts
  to power-of-two length buckets (O(log max_len) compilations instead of one
  per prompt length) and scatters the fresh KV into the batch tree inside
  the same jitted call.

DA quantization is wired through the artifact pipeline (repro.core.freeze):
pass ``da_mode`` — ``"auto"`` plans a backend/group-size/LUT decision per
layer from measured + analytic costs; a registered backend name pins every
layer — and float params are frozen into PackedWeights artifacts whose every
linear runs the multiplier-free datapath.  ``ServeEngine.from_artifact``
boots the same engine from a persisted artifact directory with zero float
weights and zero re-packing; ``save_artifact`` writes one.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.model import forward, init_caches
from repro.obs import Observability, write_chrome_trace, write_prometheus
from repro.obs.trace import request_track
from repro.serve.scheduler import (  # noqa: F401  (Request re-exported)
    PagedScheduler,
    Request,
    base_metrics,
    latency_metrics,
    mk_positions,
    pow2_bucket,
)


def make_prefill_step(cfg: ModelConfig):
    """(params, caches, tokens [B,T], positions) → (logits_last [B,V], caches)."""

    def prefill(params, caches, tokens, positions):
        logits, caches = forward(
            params, tokens, cfg, positions=positions, caches=caches,
            update_cache=True, last_logit_only=cfg.prefill_last_only,
        )
        return logits[:, -1], caches

    return prefill


def make_serve_step(cfg: ModelConfig):
    """Single-token decode: (params, caches, token [B,1], pos [B,1]) →
    (logits [B,V], caches). This is the dry-run's decode workload."""

    def serve_step(params, caches, token, positions):
        logits, caches = forward(
            params, token, cfg, positions=positions, caches=caches
        )
        return logits[:, 0], caches

    return serve_step


def scatter_cache_row(caches, c1, slot):
    """Copy batch row 0 of the batch-1 cache tree ``c1`` into row ``slot``
    (python int or traced scalar) of the batch tree. Cache layouts: KVCache
    k/v [P, B, S, kv, hd]; MambaCache conv [P, B, C-1, ch], ssm [P, B, H,
    Pd, S]; stacked scalar KVCache.length [P] takes the elementwise max
    (per-slot lengths are tracked host-side and masked via positions)."""

    def one(big, small):
        if big.ndim == 1:
            return jnp.maximum(big, small)
        return jax.lax.dynamic_update_slice(
            big, small.astype(big.dtype), (0, slot) + (0,) * (big.ndim - 2)
        )

    return jax.tree.map(one, caches, c1)


def make_prefill_into_slot(cfg: ModelConfig, max_len: int):
    """Slot prefill, one compilation per length bucket: (params, caches,
    tokens [1,T_bucket], positions, last_idx [1], slot) → (logits [1,V],
    caches). The batch-1 prefill caches are zeros created inside the trace
    and the fresh KV is scattered into row ``slot`` of the batch tree with
    one dynamic_update_slice per leaf — no host-side batch-1 cache init, no
    O(tree) host round-trip, and ``slot`` is a traced operand so every slot
    shares the compilation."""

    def prefill(params, caches, tokens, positions, last_idx, slot):
        c1 = init_caches(cfg, 1, max_len, cfg.dtype())
        logits, c1 = forward(params, tokens, cfg, positions=positions,
                             caches=c1, update_cache=True, last_idx=last_idx)
        return logits[:, 0], scatter_cache_row(caches, c1, slot)

    return prefill


class _SlotRuntime:
    """Fixed-slot continuous batching over a dense [B, max_len] cache."""

    def __init__(self, cfg: ModelConfig, params: Any, batch_size: int,
                 max_len: int, greedy: bool = True,
                 obs: Optional[Observability] = None):
        self.cfg = cfg
        self.params = params
        self.b = batch_size
        self.max_len = max_len
        self.greedy = greedy
        self.caches = init_caches(cfg, batch_size, max_len, cfg.dtype())
        # prompt padding is only sound for attention mixers (pad KV rows stay
        # masked until decode overwrites them); the Mamba/SSD recurrence has
        # no position mask, so pad tokens would corrupt the carried conv/ssm
        # state — those archs prefill at exact prompt length
        self._bucketed = all(cfg.mixer_kind(p) == "attn"
                             for p in range(cfg.period))
        # same registry homing as the paged scheduler (prefill_compiles
        # survives as a property — tests read it as an attribute)
        self.obs = obs if obs is not None else Observability.make()
        reg = self.obs.registry
        self._tr = self.obs.tracer
        self._c_prefill_compiles = reg.counter(
            "slot_prefill_compiles", "per-slot prefill shape compiles")
        self._c_out = reg.counter("sched_out_tokens", "tokens emitted")
        self._h_ttft = reg.histogram(
            "req_ttft_seconds", "submit to first token")
        self._h_itl = reg.histogram(
            "req_itl_seconds", "inter-token latency")
        base = make_prefill_into_slot(cfg, max_len)

        def counted(*a):
            # trace-time side effect = 1 / bucket
            self._c_prefill_compiles.inc()
            return base(*a)

        self._prefill_into = jax.jit(counted)
        self._decode = jax.jit(make_serve_step(cfg))
        self.slots: List[Optional[Request]] = [None] * batch_size
        self.slot_len = np.zeros(batch_size, dtype=np.int64)
        self.cur_token = np.zeros(batch_size, dtype=np.int32)
        self.queue: List[Request] = []
        self.done: Dict[int, Request] = {}

    @property
    def prefill_compiles(self) -> int:
        return int(self._c_prefill_compiles.total)

    # -- admission -----------------------------------------------------------
    def submit(self, req: Request) -> None:
        if len(req.prompt) >= self.max_len:
            raise ValueError(
                f"request {req.uid}: prompt of {len(req.prompt)} tokens does "
                f"not fit max_len={self.max_len}"
            )
        req.submit_t = time.perf_counter()
        self.queue.append(req)
        if self._tr.enabled:
            self._tr.instant("submit", request_track(req.uid),
                             ts=req.submit_t, prompt_tokens=len(req.prompt),
                             max_new_tokens=req.max_new_tokens)

    def _admit(self) -> None:
        for i in range(self.b):
            if self.slots[i] is None and self.queue:
                req = self.queue.pop(0)
                self._prefill_slot(i, req)

    def _prefill_slot(self, i: int, req: Request) -> None:
        """Bucketed per-slot prefill straight into slot ``i``.

        For attention stacks the prompt is padded to the next power-of-two
        length (capped at max_len); pad tokens write cache rows past the
        real length, which stay masked (`kpos <= tpos`) until decode
        overwrites them — so 10 distinct prompt lengths cost O(log)
        compilations, not 10. Mamba/hybrid stacks use the exact length."""
        cfg = self.cfg
        t0 = len(req.prompt)
        if self._tr.enabled:
            self._tr.begin("running", request_track(req.uid), slot=i,
                           prompt_tokens=t0)
        t_pf = time.perf_counter()
        bucket = min(pow2_bucket(t0, lo=4), self.max_len) if self._bucketed \
            else t0
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :t0] = req.prompt
        pos = mk_positions(cfg, jnp.arange(bucket, dtype=jnp.int32)[None])
        logits, self.caches = self._prefill_into(
            self.params, self.caches, jnp.asarray(toks), pos,
            jnp.asarray([t0 - 1], dtype=jnp.int32),
            jnp.asarray(i, dtype=jnp.int32),
        )
        tok = int(jnp.argmax(logits[0])) if self.greedy else int(
            jax.random.categorical(jax.random.key(req.uid), logits[0])
        )
        now = time.perf_counter()
        req.first_token_t = now
        self._h_ttft.observe(now - req.submit_t)
        req.token_times.append(now)
        req.generated.append(tok)
        self._c_out.inc()
        if self._tr.enabled:
            track = request_track(req.uid)
            self._tr.complete("prefill", track, t_pf, now - t_pf,
                              tokens=t0, bucket=bucket)
            self._tr.instant("token", track, ts=now, n=1)
        if req.on_token is not None:
            req.on_token(req.uid, tok)
        self.slots[i] = req
        self.slot_len[i] = t0 + 1
        self.cur_token[i] = tok

    # -- decode --------------------------------------------------------------
    def step(self) -> int:
        """One batched decode step over all active slots; returns #active."""
        self._admit()
        active = [i for i in range(self.b) if self.slots[i] is not None]
        if not active:
            return 0
        toks = jnp.asarray(self.cur_token, dtype=jnp.int32)[:, None]
        pos = mk_positions(
            self.cfg, jnp.asarray(self.slot_len - 1, dtype=jnp.int32)[:, None]
        )
        logits, self.caches = self._decode(self.params, self.caches, toks, pos)
        nxt = np.asarray(jnp.argmax(logits, axis=-1), dtype=np.int32)
        now = time.perf_counter()
        for i in active:
            req = self.slots[i]
            if self.greedy:
                tok = int(nxt[i])
            else:
                key = jax.random.key((req.uid << 20) + len(req.generated))
                tok = int(jax.random.categorical(key, logits[i]))
            if req.token_times:
                self._h_itl.observe(now - req.token_times[-1])
            req.token_times.append(now)
            req.generated.append(tok)
            self._c_out.inc()
            if self._tr.enabled:
                self._tr.instant("token", request_track(req.uid), ts=now,
                                 n=len(req.generated))
            if req.on_token is not None:
                req.on_token(req.uid, tok)
            self.slot_len[i] += 1
            self.cur_token[i] = tok
            exhausted = len(req.generated) >= req.max_new_tokens
            if tok == req.eos_id or exhausted or self.slot_len[i] >= self.max_len:
                req.finish_t = now
                self.done[req.uid] = req
                self.slots[i] = None
                if self._tr.enabled:
                    track = request_track(req.uid)
                    self._tr.instant("finish", track, ts=now,
                                     tokens=len(req.generated))
                    self._tr.end("running", track, ts=now)
        return len(active)

    def run(self, max_steps: int = 10_000) -> Dict[int, Request]:
        for _ in range(max_steps):
            if not self.step() and not self.queue:
                break
        return self.done

    def warmup(self) -> int:
        """Pre-compile the prefill length buckets + the decode step; outputs
        are discarded, engine caches are left untouched. Non-bucketed archs
        (Mamba/hybrid prefill at exact prompt length) warm the decode step
        only — their prefill shapes are not knowable in advance."""
        buckets, b = [], 4
        while self._bucketed and b < self.max_len:
            buckets.append(b)
            b *= 2
        if self._bucketed:
            buckets.append(self.max_len)
        for t in dict.fromkeys(buckets):
            toks = jnp.zeros((1, t), jnp.int32)
            pos = mk_positions(self.cfg, jnp.arange(t, dtype=jnp.int32)[None])
            self._prefill_into(self.params, self.caches, toks, pos,
                               jnp.asarray([t - 1], dtype=jnp.int32),
                               jnp.asarray(0, dtype=jnp.int32))
        self._decode(self.params, self.caches,
                     jnp.zeros((self.b, 1), jnp.int32),
                     mk_positions(self.cfg, jnp.zeros((self.b, 1), jnp.int32)))
        return len(buckets) + 1

    def metrics(self) -> Dict[str, Any]:
        return {
            **base_metrics("slots", self.done, int(self._c_out.total)),
            "prefill_compiles": self.prefill_compiles,
        }


class ServeEngine:
    """Facade: freeze-once DA weights in front, one of two serving runtimes
    behind (``PagedScheduler`` or the legacy slot runtime)."""

    def __init__(
        self,
        cfg: ModelConfig,
        params: Any,
        batch_size: int,
        max_len: int,
        greedy: bool = True,
        da_mode: Optional[str] = None,
        da_pin_modes: bool = True,
        runtime: str = "auto",
        page_size: int = 16,
        n_pages: Optional[int] = None,
        prefill_chunk: int = 16,
        prefill_lanes: Optional[int] = None,
        token_budget: Optional[int] = None,
        admission: str = "reserve",
        spec=None,
        prefix_cache: bool = False,
        paged_attn: Optional[str] = None,
        kv_dtype: Optional[str] = None,
        kv_dtypes: Optional[Dict[str, str]] = None,
        trace: bool = False,
        obs: Optional[Observability] = None,
        hw=None,
        analysis_debug: bool = False,
    ):
        # paged_attn: the paged-attention read backend — "gather" (XLA
        # page-table gather), "fused" (Pallas in-kernel page walk; interpret
        # mode off-TPU) or "auto" (cost-table / platform dispatch per shape
        # bucket).  None inherits cfg.paged_attn.  Decoded tokens are
        # bit-identical across backends at the default float32 softmax.
        # kv_dtype: KV page precision — "fp16" (compute-dtype pages, today's
        # layout), "int8" or "int4" (quantized codes with in-page dequant
        # scales).  None inherits cfg.kv_dtype.  kv_dtypes overrides per
        # layer position ({"pos_i": dtype}, missing positions follow
        # kv_dtype) — the freeze planner's per-layer escape hatch.
        # spec: speculative decoding over the paged runtime — a
        # repro.spec.SpecConfig, or a provider-name shorthand
        # ("bitplane" | "layerskip" | "artifact" → defaults).  Drafts gamma
        # tokens with the provider's cheap pass, verifies them in one
        # batched full-precision step; greedy output is token-identical to
        # non-speculative decoding.
        # prefix_cache: shared-prefix caching over the paged KV pool —
        # requests sharing a prompt prefix (system prompts, few-shot
        # headers) reuse its KV pages instead of re-prefilling them;
        # refcounted pages with copy-on-write keep decoded tokens
        # bit-identical to caching off.
        # da_mode: freeze float params through the DA artifact pipeline
        # ("auto" plans a backend per layer from measured + analytic costs;
        # a registered backend name pins every layer).  Params that already
        # carry PackedWeights leaves (a loaded artifact) are never re-packed.
        # da_pin_modes=False keeps runtime shape dispatch on the frozen
        # artifact (prefill and decode may pick different backends) instead
        # of baking in the decode-bucket plan.
        # trace: turn on the structured event recorder (request lifecycle +
        # scheduler tick spans; export with write_trace()).  The metrics
        # registry is always on — tracing is the opt-in half.  obs= hands in
        # a pre-built Observability bundle instead (overrides trace=); each
        # engine otherwise builds its own, so two engines in one process
        # never share series.
        # hw: a repro.obs.hwcost.HardwareCostModel pricing the serving work
        # on the paper's DA circuits.  None derives it from the artifact
        # being frozen/loaded (or the already-frozen params); float-weight
        # engines have no DA geometry, so attribution stays off.
        # Bake the KV precision into cfg BEFORE freezing, so the artifact's
        # model config and plan record the precision this engine serves at
        # (from_artifact then rebuilds a matching pool without being told).
        if kv_dtype is not None and kv_dtype != cfg.kv_dtype:
            cfg = dataclasses.replace(cfg, kv_dtype=kv_dtype)
        self.artifact = None
        if (da_mode is not None and da_mode != "float"
                and not _is_frozen(params)):
            from repro.core.da import DAConfig
            from repro.core.freeze import freeze_model

            self.artifact = freeze_model(
                params, DAConfig(x_signed=True), mode=da_mode,
                m_hint=batch_size, model_cfg=cfg, pin_modes=da_pin_modes,
                kv_dtype_overrides=kv_dtypes,
            )
            params = self.artifact.params
        if hw is None:
            if self.artifact is not None:
                hw = self.artifact.hwcost
            elif _is_frozen(params):
                from repro.obs.hwcost import HardwareCostModel

                hw = HardwareCostModel.from_frozen(params)
        self.hw = hw if hw else None
        # the engine always uses the sliced prefill head (strictly better)
        cfg = dataclasses.replace(cfg, prefill_last_only=True)
        self.cfg = cfg
        self.params = params
        self.b = batch_size
        self.max_len = max_len
        if runtime == "auto":
            all_attn = all(cfg.mixer_kind(p) == "attn"
                           for p in range(cfg.period))
            runtime = "paged" if all_attn else "slots"
        self.runtime = runtime
        self.obs = obs if obs is not None else Observability.make(trace=trace)
        if isinstance(spec, str):
            from repro.spec import SpecConfig

            spec = SpecConfig(provider=spec)
        if runtime == "paged":
            self._rt = PagedScheduler(
                cfg, params, batch_size=batch_size, max_len=max_len,
                greedy=greedy, page_size=page_size, n_pages=n_pages,
                prefill_chunk=prefill_chunk, prefill_lanes=prefill_lanes,
                token_budget=token_budget, admission=admission, spec=spec,
                prefix_cache=prefix_cache, paged_attn=paged_attn,
                kv_dtypes=kv_dtypes, obs=self.obs, hw=self.hw,
                analysis_debug=analysis_debug,
            )
        elif runtime == "slots":
            quantized = cfg.kv_dtype != "fp16" or any(
                dt != "fp16" for dt in (kv_dtypes or {}).values())
            if quantized:
                raise ValueError(
                    "quantized KV (kv_dtype/kv_dtypes) lives in the paged "
                    "runtime's page pool; the dense slot runtime has no "
                    "pages — drop kv_dtype= or use runtime='paged'"
                )
            if paged_attn not in (None, "auto"):
                raise ValueError(
                    "paged_attn selects the paged runtime's attention read; "
                    "the dense slot runtime has no page tables — drop "
                    "paged_attn= or use runtime='paged'"
                )
            if spec is not None:
                raise ValueError(
                    "speculative decoding runs on the paged runtime only "
                    "(draft rollback needs page tables); drop spec= or use "
                    "runtime='paged'"
                )
            if prefix_cache:
                raise ValueError(
                    "prefix caching shares physical KV pages between "
                    "requests; the dense slot runtime has no page tables to "
                    "share — drop prefix_cache= or use runtime='paged'"
                )
            if analysis_debug:
                raise ValueError(
                    "analysis_debug validates paged-pool launch plans; the "
                    "dense slot runtime has no pages — drop analysis_debug= "
                    "or use runtime='paged'"
                )
            self._rt = _SlotRuntime(cfg, params, batch_size, max_len, greedy,
                                    obs=self.obs)
        else:
            raise ValueError(f"unknown runtime {runtime!r} "
                             "(expected auto | paged | slots)")

    # -- freeze-once, serve-many ---------------------------------------------
    @classmethod
    def from_artifact(
        cls,
        directory: str,
        batch_size: int,
        max_len: int,
        greedy: bool = True,
        **runtime_kw,
    ) -> "ServeEngine":
        """Boot the full serving runtime from a persisted DA artifact: the
        packed weights come straight off disk — no float params, no
        re-packing (the paper's freeze-once premise, operationally).

        KV precision follows the artifact: the plan's wk entries record the
        per-position page dtype the model was frozen for, and the pool is
        built to match — an artifact frozen at int8 cannot silently boot an
        fp16 pool.  An explicit ``kv_dtype=`` in ``runtime_kw`` overrides a
        HOMOGENEOUS plan (re-serving an old fp16 artifact quantized, or
        vice versa — decode is cache-precision-, not weight-, dependent);
        overriding a plan with per-layer escape hatches would silently
        flatten them, so that raises instead."""
        from repro.core.freeze import load_artifact

        art = load_artifact(directory)
        if art.model_cfg is None:
            raise ValueError(
                f"artifact {directory} carries no model config; freeze with "
                "freeze_model(..., model_cfg=cfg) to make it servable"
            )
        plan_kv: Dict[str, str] = {}
        for key, p in art.plan.items():
            if p.kv_dtype is not None and key.endswith("/wk"):
                seg = next((s for s in key.split("/")
                            if s.startswith("pos_")), None)
                if seg is not None:
                    plan_kv[seg] = p.kv_dtype
        explicit = (runtime_kw.get("kv_dtype") is not None
                    or bool(runtime_kw.get("kv_dtypes")))
        if explicit and len(set(plan_kv.values())) > 1:
            raise ValueError(
                f"artifact {directory} was frozen with per-layer KV dtypes "
                f"{plan_kv}; overriding them with a global kv_dtype= would "
                "silently flatten the plan — drop the override or re-freeze"
            )
        if not explicit and plan_kv:
            runtime_kw = dict(runtime_kw, kv_dtypes=plan_kv)
        runtime_kw.setdefault("hw", art.hwcost)  # the manifest's cost table
        eng = cls(art.model_cfg, art.params, batch_size, max_len,
                  greedy=greedy, **runtime_kw)
        eng.artifact = art
        return eng

    def save_artifact(self, directory: str) -> str:
        """Persist this engine's frozen weights + plan for later cold boots."""
        from repro.core.freeze import save_artifact

        if self.artifact is None:
            raise ValueError(
                "engine holds no DAArtifact (constructed without da_mode and "
                "not from_artifact) — nothing to save"
            )
        return save_artifact(directory, self.artifact)

    # -- runtime delegation --------------------------------------------------
    @property
    def queue(self) -> List[Request]:
        return self._rt.queue

    @property
    def done(self) -> Dict[int, Request]:
        return self._rt.done

    @property
    def caches(self):
        return self._rt.caches

    def submit(self, req: Request) -> None:
        self._rt.submit(req)

    def step(self) -> int:
        return self._rt.step()

    def run(self, max_steps: int = 100_000) -> Dict[int, Request]:
        return self._rt.run(max_steps)

    def warmup(self) -> int:
        """Pre-compile every step-shape bucket of the active runtime."""
        return self._rt.warmup()

    def metrics(self) -> Dict[str, Any]:
        return self._rt.metrics()

    # -- observability export ------------------------------------------------
    def metrics_snapshot(self) -> Dict[str, Any]:
        """Flat registry snapshot (every counter/gauge/histogram series) —
        the schema BENCH_*.json and the Prometheus exporter share."""
        return self.obs.registry.snapshot()

    def write_trace(self, path: str) -> str:
        """Dump the recorded events as Chrome trace_event JSON (load the
        file in Perfetto / chrome://tracing).  Requires trace=True (or an
        enabled recorder via obs=) — an empty trace is written otherwise."""
        return write_chrome_trace(path, self.obs.tracer)

    def write_metrics(self, path: str) -> str:
        """Dump the registry in Prometheus text exposition format."""
        return write_prometheus(path, self.obs.registry)

    def write_hw_metrics(self, path: str) -> str:
        """Dump ``metrics()["hw"]`` — the DA hardware-cost block — as
        schema-stamped JSON (what ``repro.obs.check`` validates and the
        ``--hw-metrics`` launcher knob writes).  ``hw`` is null when the
        engine has no cost model (float weights)."""
        import json

        from repro.obs.metrics import METRICS_SCHEMA_VERSION

        payload = {"metrics_schema_version": METRICS_SCHEMA_VERSION,
                   "hw": self.metrics().get("hw")}
        with open(path, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        return path


def _is_frozen(params: Any) -> bool:
    """Does the tree already carry PackedWeights leaves (a DA artifact)?"""
    from repro.core.engine import PackedWeights

    return any(
        isinstance(leaf, PackedWeights)
        for leaf in jax.tree.leaves(
            params, is_leaf=lambda x: isinstance(x, PackedWeights)
        )
    )
