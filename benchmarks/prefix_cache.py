"""Shared-prefix caching benchmark: TTFT and pages-in-use on a
shared-system-prompt workload, prefix cache off vs on.

    PYTHONPATH=src python benchmarks/prefix_cache.py           # full
    PYTHONPATH=src python benchmarks/prefix_cache.py --quick   # CI-sized

Writes ``artifacts/BENCH_prefix_cache.json`` (override with ``--out``).

The workload is the ROADMAP's "millions of users" scenario in miniature:
every request opens with the same system prompt (several KV pages worth)
followed by a short unique tail.  One priming request carries the system
prompt through first (run identically in both configurations), then the
measured fleet arrives at once.  Without caching the runtime re-prefills
the identical prefix once per request and the pool holds one private copy
per concurrent lane; with caching the prefix is computed once, every fleet
request's prefill shrinks to its tail, and all lanes share one physical copy
of the prefix pages.  Reported per configuration:

* ``ttft_p50_ms`` / ``ttft_p95_ms`` — time to first token (the metric
  prefix caching exists to cut: admission-to-first-sample includes the
  prefill the cache skips).
* ``peak_pages`` — high-water pool occupancy over the run (the page-budget
  saving: shared prefixes are resident once, not once per lane).
* ``cached_tokens`` / ``hit_rate`` — how much prefill the trie absorbed.

Decoded tokens are asserted identical between the two configurations (the
cache is an optimization, never a behavior change); engines are warmed
before the measured window.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

try:  # run as `python benchmarks/prefix_cache.py` (script dir on path)
    from stamp import stamp_and_write
except ImportError:  # imported as a module from the repo root
    from benchmarks.stamp import stamp_and_write

from repro.configs.registry import ARCHS
from repro.core.da import DAConfig
from repro.core.freeze import freeze_model
from repro.models.model import init_model
from repro.serve.engine import Request, ServeEngine


def build_cfg():
    # same runtime-sized model as benchmarks/serve_throughput.py: this
    # instruments scheduling + paging, not BLAS time
    return dataclasses.replace(
        ARCHS["qwen3-8b"],
        name="qwen3-serve-bench",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab=4000,
        param_dtype="float32",
        compute_dtype="float32",
        remat=False,
        moe_dropless=True,
    )


def workload(cfg, n_requests, sys_len, tail, max_new, base_uid=0):
    rng = np.random.default_rng(3)
    shared = rng.integers(0, cfg.vocab, sys_len)
    prime = Request(uid=base_uid + 50_000,
                    prompt=np.concatenate(
                        [shared, rng.integers(0, cfg.vocab, tail)]),
                    max_new_tokens=2)
    fleet = [
        Request(uid=base_uid + u,
                prompt=np.concatenate(
                    [shared, rng.integers(0, cfg.vocab, tail)]),
                max_new_tokens=max_new)
        for u in range(n_requests)
    ]
    return prime, fleet


def run_once(cfg, frozen, prime, reqs, prefix_cache, batch, max_len,
             page_size, kv_dtype=None):
    eng = ServeEngine(cfg, frozen, batch_size=batch, max_len=max_len,
                      runtime="paged", page_size=page_size,
                      prefix_cache=prefix_cache, kv_dtype=kv_dtype)
    eng.warmup()
    # warm the host loop too (uids far from the measured workload; a fresh
    # engine per configuration keeps the trie cold for the measured window)
    rng = np.random.default_rng(9)
    for w in range(2):
        eng.submit(Request(uid=10_000 + w,
                           prompt=rng.integers(0, cfg.vocab, 6),
                           max_new_tokens=2))
    eng.run()
    # prime: ONE request carries the system prompt through first (its pages
    # land in the trie when caching is on) — run identically in both
    # configurations so the measured fleet is compared apples to apples
    eng.submit(prime)
    eng.run()
    ctx0 = eng.metrics()["ctx_tokens"]

    t0 = time.perf_counter()
    for r in reqs:
        eng.submit(r)
    peak_pages = 0
    while eng.step() or eng.queue:
        peak_pages = max(peak_pages, eng._rt.pool.used_pages)
    wall = time.perf_counter() - t0
    done = eng.done
    ttft = [(done[r.uid].first_token_t - done[r.uid].submit_t) * 1e3
            for r in reqs]
    m = eng.metrics()
    out = {
        "prefix_cache": prefix_cache,
        "requests": len(reqs),
        "wall_s": round(wall, 3),
        "out_tokens": sum(len(done[r.uid].generated) for r in reqs),
        "tokens_per_s": round(
            sum(len(done[r.uid].generated) for r in reqs) / wall, 2),
        "ttft_p50_ms": round(float(np.percentile(ttft, 50)), 3),
        "ttft_p95_ms": round(float(np.percentile(ttft, 95)), 3),
        "peak_pages": peak_pages,
        "ctx_tokens": m["ctx_tokens"] - ctx0,  # model-visible tokens, fleet only
    }
    if m["prefix_cache"] is not None:
        out["cached_tokens"] = m["prefix_cache"]["cached_tokens"]
        out["hit_rate"] = round(m["prefix_cache"]["hit_rate"], 4)
        out["cow_copies"] = m["prefix_cache"]["cow_copies"]
        out["evictions"] = m["prefix_cache"]["evictions"]
    tokens = {r.uid: list(done[r.uid].generated) for r in reqs}
    return out, tokens


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="CI-sized run")
    ap.add_argument("--kv-dtype", default=None,
                    choices=["fp16", "int8", "int4"],
                    help="KV page precision for both configurations (prefix "
                         "sharing works unchanged on quantized pages: scales "
                         "ride inside the page, so trie hits, COW forks and "
                         "evictions never consult the dtype)")
    ap.add_argument("--out", default="artifacts/BENCH_prefix_cache.json")
    args = ap.parse_args()

    cfg = build_cfg()
    params = init_model(jax.random.key(0), cfg)
    art = freeze_model(params, DAConfig(x_signed=True), mode="auto",
                       m_hint=8, model_cfg=cfg, pin_modes=False)
    del params

    n_requests = 8 if args.quick else 24
    sys_len, tail = (48, 8)          # 3 shared pages + a unique tail
    max_new = 4 if args.quick else 16
    batch, max_len, page_size = 8, 128, 16

    results = {}
    tokens = {}
    for pc in (False, True):
        key = "on" if pc else "off"
        prime, fleet = workload(cfg, n_requests, sys_len, tail, max_new)
        results[key], tokens[key] = run_once(
            cfg, art.params, prime, fleet, pc, batch, max_len, page_size,
            kv_dtype=args.kv_dtype)
        print(f"prefix_cache={key}: {results[key]}")
    assert tokens["on"] == tokens["off"], \
        "prefix caching changed decoded tokens — correctness bug"

    result = {
        "bench": "prefix_cache",
        "model": cfg.name,
        "da_mode": "auto",
        "quick": args.quick,
        "kv_dtype": args.kv_dtype or "fp16",
        "workload": {"requests": n_requests, "system_prompt_tokens": sys_len,
                     "tail_tokens": tail, "max_new": max_new, "batch": batch,
                     "page_size": page_size},
        "off": results["off"],
        "on": results["on"],
        "ttft_p50_speedup": round(
            results["off"]["ttft_p50_ms"]
            / max(results["on"]["ttft_p50_ms"], 1e-9), 2),
        "peak_pages_saved": (results["off"]["peak_pages"]
                             - results["on"]["peak_pages"]),
        "tokens_identical": True,
    }
    stamp_and_write(args.out, result, seed=3)
    print(f"ttft_p50 speedup: {result['ttft_p50_speedup']}x, "
          f"peak pages saved: {result['peak_pages_saved']}")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
