"""AST lint rules encoding the repo's hard-won serving conventions.

Each rule is a bug class that has actually bitten (or nearly bitten) a PR:

* ``L001 interpret-hardcoded`` — ``interpret=True`` literal at a kernel
  call site.  Pallas interpret mode must be platform-derived (the PR 6
  bug class: a hardcoded flag ships the interpreter to TPU or breaks CPU
  CI), e.g. ``interpret=jax.default_backend() != "tpu"``.
* ``L002 raw-clock`` — ``time.time()`` in scheduler/observability code.
  Spans, latency metrics and the trace recorder all share one
  ``time.perf_counter`` clock; mixing in wall-clock time skews TTFT/ITL
  reconstruction across the two.
* ``L003 metrics-bypass`` — assigning/augmenting a metric's read-side
  attributes (``.total``, ``.value``) instead of going through
  ``MetricsRegistry`` mutators (``inc``/``set``/``observe``); bypass
  writes dodge the registry's export and schema accounting.
* ``L004 bench-writer`` — opening a ``BENCH_*.json`` for writing anywhere
  but ``benchmarks/stamp.py``.  Every benchmark artifact must carry the
  provenance stamp (git sha, seed, device, schema version) that
  ``stamp.stamp_and_write()`` applies; raw writers produce artifacts the
  nightly regression gate cannot trust.
"""
from __future__ import annotations

import ast
import os
from typing import Iterable, List, Optional, Sequence, Tuple

from repro.analysis.findings import Finding

#: Attributes on metric objects that are read-side views; assigning them
#: bypasses the registry.
_METRIC_READ_ATTRS = ("total", "value")

#: (rule id, path substrings the rule applies to — empty = everywhere)
_CLOCK_SCOPES = ("serve/", "obs/")

#: L001 exempts tests: kernel unit tests pin ``interpret=True`` on purpose
#: (the oracle comparisons must run the interpreter regardless of host).
_INTERPRET_EXEMPT = ("tests/",)


def _finding(rule: str, severity: str, path: str, node: ast.AST, op: str,
             hint: str) -> Finding:
    return Finding(
        pass_name=f"lint/{rule}", severity=severity, op=op, hint=hint,
        where=f"{path}:{getattr(node, 'lineno', 0)}",
    )


def _scoped(path: str, scopes: Sequence[str]) -> bool:
    norm = path.replace(os.sep, "/")
    return any(s in norm for s in scopes)


def lint_source(src: str, path: str) -> List[Finding]:
    """Run every rule over one file's source text."""
    try:
        tree = ast.parse(src, filename=path)
    except SyntaxError as e:
        return [Finding(
            pass_name="lint/parse", severity="error",
            op=f"SyntaxError: {e.msg}", hint="file does not parse",
            where=f"{path}:{e.lineno or 0}",
        )]
    findings: List[Finding] = []
    exempt_stamp = path.replace(os.sep, "/").endswith("benchmarks/stamp.py")
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            if not _scoped(path, _INTERPRET_EXEMPT):
                findings += _check_interpret(node, path)
            if _scoped(path, _CLOCK_SCOPES):
                findings += _check_raw_clock(node, path)
            if not exempt_stamp:
                findings += _check_bench_writer(node, path)
        elif isinstance(node, (ast.Assign, ast.AugAssign)):
            findings += _check_metrics_bypass(node, path)
    return findings


def _check_interpret(node: ast.Call, path: str) -> List[Finding]:
    for kw in node.keywords:
        if kw.arg == "interpret" and isinstance(kw.value, ast.Constant) \
                and kw.value.value is True:
            return [_finding(
                "interpret-hardcoded", "error", path, node,
                "interpret=True at a kernel call site",
                "derive the flag from the platform (e.g. "
                "jax.default_backend() != 'tpu' / _default_interpret()); "
                "a hardcoded True ships the Pallas interpreter to TPU",
            )]
    return []


def _check_raw_clock(node: ast.Call, path: str) -> List[Finding]:
    fn = node.func
    if isinstance(fn, ast.Attribute) and fn.attr == "time" \
            and isinstance(fn.value, ast.Name) and fn.value.id == "time":
        return [_finding(
            "raw-clock", "error", path, node,
            "time.time() in scheduler/observability code",
            "use time.perf_counter() — spans, latency metrics and traces "
            "share one monotonic clock; wall time skews reconstruction",
        )]
    return []


def _check_metrics_bypass(node: ast.AST, path: str) -> List[Finding]:
    targets: List[ast.expr]
    if isinstance(node, ast.Assign):
        targets = list(node.targets)
    elif isinstance(node, ast.AugAssign):
        targets = [node.target]
    else:
        return []
    out: List[Finding] = []
    for tgt in targets:
        if isinstance(tgt, ast.Attribute) and tgt.attr in _METRIC_READ_ATTRS:
            out.append(_finding(
                "metrics-bypass", "error", path, node,
                f"assignment to .{tgt.attr} on a metric object",
                "mutate through MetricsRegistry (counter.inc() / "
                "gauge.set() / histogram.observe()); attribute writes "
                "bypass export and schema accounting",
            ))
    return out


def _string_args(node: ast.Call) -> Iterable[Tuple[str, ast.AST]]:
    for arg in list(node.args) + [kw.value for kw in node.keywords]:
        if isinstance(arg, ast.Constant) and isinstance(arg.value, str):
            yield arg.value, arg
        elif isinstance(arg, ast.JoinedStr):
            # join the constant fragments so a name split around an
            # interpolation (f"BENCH_{name}.json") still matches
            parts = [part.value for part in arg.values
                     if isinstance(part, ast.Constant)
                     and isinstance(part.value, str)]
            if parts:
                yield "".join(parts), arg


def _write_mode(node: ast.Call) -> bool:
    mode: Optional[str] = None
    if len(node.args) >= 2 and isinstance(node.args[1], ast.Constant):
        mode = str(node.args[1].value)
    for kw in node.keywords:
        if kw.arg == "mode" and isinstance(kw.value, ast.Constant):
            mode = str(kw.value.value)
    return mode is None or any(c in mode for c in "wax+")


def _check_bench_writer(node: ast.Call, path: str) -> List[Finding]:
    fn = node.func
    if not (isinstance(fn, ast.Name) and fn.id == "open"):
        return []
    for text, _ in _string_args(node):
        if "BENCH_" in text and text.endswith(".json") and _write_mode(node):
            return [_finding(
                "bench-writer", "error", path, node,
                f"raw open() writer for {text!r}",
                "benchmark artifacts must go through "
                "benchmarks/stamp.stamp_and_write() so every BENCH_*.json "
                "carries provenance (git sha, seed, device, schema)",
            )]
    return []


#: Directories linted by default, relative to the repo root.
DEFAULT_LINT_DIRS = ("src/repro", "benchmarks", "examples", "tests")


def repo_root() -> Optional[str]:
    """The checkout root, inferred from this file's location (None when the
    package is installed without its repo layout)."""
    here = os.path.abspath(os.path.dirname(__file__))
    root = os.path.dirname(os.path.dirname(os.path.dirname(here)))
    if os.path.isdir(os.path.join(root, "src", "repro")):
        return root
    return None


def lint_paths(paths: Iterable[str]) -> List[Finding]:
    """Lint every ``.py`` file under the given files/directories."""
    findings: List[Finding] = []
    for path in paths:
        if os.path.isfile(path):
            files = [path]
        else:
            files = [
                os.path.join(dirpath, f)
                for dirpath, _, names in os.walk(path)
                for f in sorted(names) if f.endswith(".py")
            ]
        for fname in sorted(files):
            with open(fname, encoding="utf-8") as fh:
                findings += lint_source(fh.read(), fname)
    return findings


def lint_repo(root: Optional[str] = None) -> List[Finding]:
    """Lint the default directory set under the repo root."""
    root = root if root is not None else repo_root()
    if root is None:
        return []
    dirs = [os.path.join(root, d) for d in DEFAULT_LINT_DIRS]
    return lint_paths([d for d in dirs if os.path.isdir(d)])
