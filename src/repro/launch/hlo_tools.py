"""HLO-text diagnostics for the §Perf loop: where do the bytes/collectives go?"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import List, Tuple

from repro.launch.roofline import _COLLECTIVE_RE, _shape_bytes

_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|\S+)\s*([\w\-]+)\("
)


def top_collectives(hlo_text: str, k: int = 15) -> List[Tuple[str, str, int]]:
    """Largest collective ops: (name, kind, result bytes)."""
    out = []
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_RE.match(line)
        if not m:
            continue
        if "-done" in line.split("=", 1)[1].split("(")[0]:
            continue
        om = _OP_RE.match(line)
        name = om.group(1) if om else "?"
        out.append((name, m.group(2), _shape_bytes(m.group(1))))
    return sorted(out, key=lambda t: -t[2])[:k]


def bytes_by_op_kind(hlo_text: str, k: int = 20) -> List[Tuple[str, int, int]]:
    """Result-shape bytes aggregated by HLO op kind (a proxy for which op
    family dominates traffic): (kind, total bytes, count)."""
    agg = defaultdict(lambda: [0, 0])
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        kind = m.group(3)
        if kind in ("tuple", "parameter", "constant", "get-tuple-element"):
            continue
        b = _shape_bytes(m.group(2))
        agg[kind][0] += b
        agg[kind][1] += 1
    rows = [(kind, v[0], v[1]) for kind, v in agg.items()]
    return sorted(rows, key=lambda t: -t[1])[:k]


def ops_of_kind(hlo_text: str, kind: str) -> List[Tuple[str, int]]:
    """Every op of one HLO kind, fusion bodies included: (name, result
    bytes), largest first.  E.g. ``ops_of_kind(txt, "gather")`` checks a
    lowering for full-page-table KV gathers — the fused paged-attention
    path must not contain one at the [B, W·ps, kv, hd] view size."""
    out = []
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if m and m.group(3) == kind:
            out.append((m.group(1), _shape_bytes(m.group(2))))
    return sorted(out, key=lambda t: -t[1])


def top_ops(hlo_text: str, k: int = 20) -> List[Tuple[str, str, int]]:
    """Largest individual op results (fusion outputs usually dominate)."""
    out = []
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        kind = m.group(3)
        if kind in ("tuple", "parameter", "get-tuple-element"):
            continue
        out.append((m.group(1), kind, _shape_bytes(m.group(2))))
    return sorted(out, key=lambda t: -t[2])[:k]
