"""Model configuration covering all assigned architecture families."""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid
    n_layers: int
    d_model: int
    vocab: int
    modality: str = "text"         # text | audio | vlm (stub frontends)

    # attention
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0              # 0 → d_model // n_heads
    qk_norm: bool = False
    attn_bias: bool = False
    rope_theta: float = 10_000.0
    mrope_sections: Optional[Tuple[int, int, int]] = None  # M-RoPE (qwen2-vl)

    # dense MLP
    d_ff: int = 0
    mlp_act: str = "swiglu"        # swiglu | gelu | relu2
    norm_type: str = "rmsnorm"     # rmsnorm | layernorm

    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    n_shared_experts: int = 0
    moe_period: int = 1            # MoE replaces MLP every k-th layer
    moe_offset: int = 0
    capacity_factor: float = 1.25
    moe_dropless: bool = False     # capacity = group size (exact; serving/tests)
    moe_group_size: int = 1024     # GShard token-group size: dispatch cost is
                                   # O(N·S), capacity is per-group

    # Mamba2 / SSD
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_groups: int = 1
    ssm_chunk: int = 256

    # hybrid interleave (jamba): 1 attention layer per attn_period layers
    attn_period: int = 0
    attn_offset: int = 0

    # numerics / execution
    norm_eps: float = 1e-5
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    remat_policy: str = "nothing"  # nothing|dots — what the checkpoint saves
    attn_chunk_q: int = 0          # 0 → naive attention; else flash-style chunk
    tie_embeddings: bool = False
    scan_unroll: bool = False      # unroll all scans (dry-run cost probes:
                                   # HloCostAnalysis counts while bodies once)
    # §Perf levers (defaults = paper-faithful baseline behavior)
    prefill_last_only: bool = False   # L2: slice hidden before LM head
    attn_mask_mode: str = "where"     # L3a: where | additive
    softmax_dtype: str = "float32"    # L3b: float32 | bfloat16 score pipeline
    moe_impl: str = "dense"           # L4: dense (GShard one-hot) | sorted
    attn_impl: str = "reference"      # L8: reference | lean (minimal-pass
                                      # softmax, replicated bias, late divide)
    cache_mode: str = "scatter"       # L9: scatter (ragged rows, general) |
                                      # slice (uniform positions — GSPMD-local
                                      # dynamic_update_slice, no gather)
    paged_attn: str = "auto"          # paged-attention read: auto (cost-table
                                      # / platform dispatch) | gather (XLA
                                      # page-table gather) | fused (Pallas
                                      # in-kernel page walk)
    kv_dtype: str = "fp16"            # paged KV page storage: fp16 (compute-
                                      # dtype pages, today's layout) | int8 |
                                      # int4 (packed nibbles) with in-page
                                      # per-(slot, head) dequant scales

    # ---- derived ------------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim or (self.d_model // max(1, self.n_heads))

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim_

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim_

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def conv_channels(self) -> int:
        # mamba2 convolves x together with B and C streams
        return self.d_inner + 2 * self.ssm_groups * self.ssm_state

    @property
    def period(self) -> int:
        """Layer-pattern period (1 for homogeneous stacks)."""
        p = 1
        if self.family == "hybrid" and self.attn_period:
            p = self.attn_period
        if self.n_experts and self.moe_period > 1:
            p = math.lcm(p, self.moe_period)
        return p

    @property
    def n_periods(self) -> int:
        assert self.n_layers % self.period == 0, (self.n_layers, self.period)
        return self.n_layers // self.period

    def mixer_kind(self, pos: int) -> str:
        """Mixer of layer-position ``pos`` within a period: attn | mamba."""
        if self.family == "ssm":
            return "mamba"
        if self.family == "hybrid":
            return "attn" if pos % self.attn_period == self.attn_offset else "mamba"
        return "attn"

    def ffn_kind(self, pos: int) -> str:
        """FFN of layer-position ``pos``: mlp | moe | none."""
        if self.family == "ssm":
            return "none"
        if self.n_experts and pos % self.moe_period == self.moe_offset:
            return "moe"
        return "mlp"

    def dtype(self) -> jnp.dtype:
        return jnp.dtype(self.compute_dtype)

    def pdtype(self) -> jnp.dtype:
        return jnp.dtype(self.param_dtype)
