"""Checkpointing: atomic roundtrip, checksum verification, async writer, GC,
restore-into-template (elastic restart path)."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt as C


def _tree():
    return {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "nested": {"b": jnp.ones((4,), dtype=jnp.bfloat16),
                   "step": jnp.asarray(7, dtype=jnp.int32)},
    }


def test_roundtrip(tmp_path):
    tree = _tree()
    C.save(str(tmp_path), 3, tree)
    assert C.all_steps(str(tmp_path)) == [3]
    out = C.restore(str(tmp_path), 3, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_gc_keeps_last_k(tmp_path):
    tree = _tree()
    for s in range(6):
        C.save(str(tmp_path), s, tree, keep=2)
    assert C.all_steps(str(tmp_path)) == [4, 5]
    assert C.latest_step(str(tmp_path)) == 5


def test_checksum_detects_corruption(tmp_path):
    tree = _tree()
    path = C.save(str(tmp_path), 1, tree)
    man = json.load(open(os.path.join(path, "manifest.json")))
    man["arrays"]["a"]["crc32"] ^= 0xDEAD
    json.dump(man, open(os.path.join(path, "manifest.json"), "w"))
    with pytest.raises(IOError, match="checksum"):
        C.restore(str(tmp_path), 1, tree)


def test_async_checkpointer(tmp_path):
    tree = _tree()
    ac = C.AsyncCheckpointer(str(tmp_path), keep=3)
    for s in (1, 2, 3):
        ac.submit(s, tree)
    ac.wait()
    ac.close()
    assert C.all_steps(str(tmp_path)) == [1, 2, 3]


def test_restore_different_dtype_template(tmp_path):
    """Elastic/precision-change restarts: restore casts into the template."""
    tree = {"w": jnp.ones((4,), jnp.float32)}
    C.save(str(tmp_path), 0, tree)
    out = C.restore(str(tmp_path), 0, {"w": jnp.zeros((4,), jnp.bfloat16)})
    assert out["w"].dtype == jnp.bfloat16


def test_partial_write_is_invisible(tmp_path):
    """A crash mid-write (tmp dir left behind) must not surface as a valid
    checkpoint."""
    tree = _tree()
    C.save(str(tmp_path), 1, tree)
    os.makedirs(str(tmp_path / "step_00000002.tmp"))
    assert C.latest_step(str(tmp_path)) == 1
