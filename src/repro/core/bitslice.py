"""Bit-slicing in-memory VMM — the paper's comparison baseline (§IV, Fig. 10).

ISAAC-style [Shafiee et al., ISCA'16]: the 8-bit weights are stored in binary
form across 8 columns (one bit per column); inputs are fed bit-serially over 8
cycles through 1-bit DACs. Each cycle, every column's bit-line current is the
*count* of rows where (input bit == 1 AND stored weight bit == 1); a 5-bit ADC
(for ≤25 rows) digitizes that count. Two shift-and-add stages then undo the
weight slicing (×2^bw, with the weight's sign column carrying −2^7 for two's
complement) and the input slicing (×2^bx).

This module is the *exact digital emulation* of that datapath, used both as a
functional baseline (must equal X @ W exactly when the ADC has enough
resolution) and as the workload descriptor for the hardware cost model.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.da import bit_coefs


@dataclasses.dataclass(frozen=True)
class BitSliceConfig:
    w_bits: int = 8
    x_bits: int = 8
    w_signed: bool = True
    x_signed: bool = False
    adc_bits: int | None = None  # None → exact (enough resolution for #rows)


def weight_bit_columns(wq: jax.Array, cfg: BitSliceConfig) -> jax.Array:
    """Binary storage of W: [K, N, w_bits] of {0,1} (two's-complement bits)."""
    mask = (1 << cfg.w_bits) - 1
    wu = jnp.bitwise_and(wq.astype(jnp.int32), mask)
    bits = [jnp.bitwise_and(jnp.right_shift(wu, b), 1) for b in range(cfg.w_bits)]
    return jnp.stack(bits, axis=-1)


def bitslice_vmm(xq: jax.Array, wq: jax.Array, cfg: BitSliceConfig) -> jax.Array:
    """Exact emulation of the bit-sliced analog VMM datapath.

    xq: [M, K] integer codes; wq: [K, N] integer codes.
    Returns int32 [M, N] == xq @ wq when the ADC resolution suffices.
    """
    wcols = weight_bit_columns(wq, cfg)  # [K, N, w_bits]
    xmask = (1 << cfg.x_bits) - 1
    xu = jnp.bitwise_and(xq.astype(jnp.int32), xmask)

    w_coef = jnp.asarray(bit_coefs(cfg.w_bits, cfg.w_signed), dtype=jnp.int32)
    x_coef = jnp.asarray(bit_coefs(cfg.x_bits, cfg.x_signed), dtype=jnp.int32)

    acc = jnp.zeros(xq.shape[:-1] + (wq.shape[-1],), dtype=jnp.int32)
    for bx in range(cfg.x_bits):
        xplane = jnp.bitwise_and(jnp.right_shift(xu, bx), 1)  # [M, K] DAC inputs
        # Column currents: counts[m, n, bw] = Σ_k xbit·wbit  (the ADC reading)
        counts = jnp.einsum(
            "mk,knb->mnb", xplane, wcols, preferred_element_type=jnp.int32
        )
        if cfg.adc_bits is not None:
            counts = jnp.clip(counts, 0, (1 << cfg.adc_bits) - 1)
        # First shift-and-add: undo weight slicing.
        col = jnp.einsum("mnb,b->mn", counts, w_coef)
        # Second shift-and-add: undo input slicing.
        acc = acc + x_coef[bx] * col
    return acc


def adc_bits_required(rows: int) -> int:
    """Minimum ADC resolution to digitize a column of ``rows`` 1-bit products
    without clipping (paper: 5-bit for 25 rows)."""
    import math

    return max(1, math.ceil(math.log2(rows + 1)))
