"""Paper Fig. 5: scaling the weight matrix 8×8 → 16×16 → 32×32 (and beyond,
to LM-layer sizes) — latency stays read-dominated; memory/energy grow
linearly in sensed columns. Also sweeps the latency-vs-x_bits trade
(the paper's core claim: cycles = input bit width, independent of columns)."""
from __future__ import annotations

from repro.core.hwmodel import BitSliceDesign, DADesign


def run() -> list:
    rows = []
    for k, n in [(8, 8), (16, 16), (32, 32), (64, 64), (128, 128),
                 (25, 6), (4096, 4096), (4096, 12288)]:
        d = DADesign(k=k, n=n)                              # paper's chain
        dt = DADesign(k=k, n=n, adder_topology="tree")      # beyond-paper
        b = BitSliceDesign(k=k, n=n)  # ADC resolution scales with K (§I)
        rows.append((
            f"{k}x{n}",
            d.n_arrays,
            d.latency_ns(),
            dt.latency_ns(),
            dt.energy_vmm_j() * 1e12,
            d.memory_cells,
            b.latency_ns(),
            b.energy_vmm_j() * 1e12,
            b.latency_ns() / dt.latency_ns(),
            b.energy_vmm_j() / dt.energy_vmm_j(),
        ))
    return rows


def run_bitwidth() -> list:
    """Latency ∝ x_bits (bit-serial cycles), not matrix columns."""
    rows = []
    for x_bits in (2, 4, 6, 8):
        for n in (8, 64):
            d = DADesign(k=8, n=n, x_bits=x_bits)
            rows.append((f"b{x_bits}_n{n}", d.latency_ns()))
    return rows


def main():
    print("# Fig.5 scaling: KxN, n_arrays, DA(chain) ns, DA(tree) ns, "
          "DA(tree) pJ, DA cells, BS ns, BS pJ, lat_ratio(tree), "
          "energy_ratio(tree)")
    for r in run():
        print(",".join(f"{v:.4g}" if isinstance(v, float) else str(v) for v in r))
    print("# latency vs input bit width (columns don't matter)")
    for name, ns in run_bitwidth():
        print(f"{name},{ns:.4g}")


if __name__ == "__main__":
    main()
