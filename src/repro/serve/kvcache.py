"""Block/paged KV cache for the continuous-batching serving runtime.

The paper's premise (freeze-once serve-many) puts all serving cost in the
decode hot loop, and the dominant state there is the KV cache. The dense
slot layout (``[B, max_len, kv, hd]`` per layer) reserves worst-case memory
for every batch row; this module replaces it with a vLLM-style paged layout:

* **Page pool** — each attention layer owns ``k``/``v`` pools of shape
  ``[n_pages, page_size, n_kv, hd]``. Pages are the allocation unit; a
  request's KV lives on whichever physical pages the allocator handed it.
* **Page table** — per request, a host-side list of physical page ids; the
  device sees an int32 ``[B, table_width]`` array each step. Attention
  *writes* scatter ``(page_id, offset)``-addressed rows into the pool and
  *reads* gather the table back into a contiguous ``[B, S, kv, hd]`` view —
  models index the cache through the table, never through dense slots.
* **Garbage page** — physical page 0 is reserved. Pad tokens (batch lanes
  that carry fewer real tokens than the step bucket) and unallocated table
  entries point at it, so one fixed-shape jitted step serves any mix of
  chunked-prefill and decode lanes: pad writes land in garbage, and the
  per-row position mask keeps garbage out of every real row's softmax.

The pool is functional state (threaded through jit like any cache); the
allocator and tables are host state owned by the scheduler.
"""
from __future__ import annotations

import dataclasses
from collections import Counter, deque
from typing import (
    Dict, FrozenSet, Iterator, List, Optional, Sequence, Tuple,
)

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.attention import PagedKVCache  # noqa: F401  (re-export)
from repro.models.config import ModelConfig
from repro.models.kv_quant import KV_DTYPES, KV_SCALE_DTYPE

#: Physical page reserved for pad-token writes and unallocated table slots.
GARBAGE_PAGE = 0


def resolve_kv_dtypes(cfg: ModelConfig,
                      kv_dtypes=None) -> Dict[str, str]:
    """Per-period-position KV page dtypes, validated loudly.

    ``kv_dtypes`` may be ``None`` (every position follows ``cfg.kv_dtype``),
    one dtype string, or a ``{"pos_i": dtype}`` dict whose missing positions
    fall back to ``cfg.kv_dtype`` — the shape the freeze planner's per-layer
    escape hatch produces (``LayerPlan.kv_dtype``).  Validation happens here,
    once, at pool-build time: an unknown dtype or an int4 request against an
    odd head_dim raises with the offending position named, instead of
    failing deep inside a kernel trace.
    """
    base = getattr(cfg, "kv_dtype", "fp16")
    if isinstance(kv_dtypes, str):
        out = {f"pos_{p}": kv_dtypes for p in range(cfg.period)}
    else:
        kv_dtypes = kv_dtypes or {}
        unknown = set(kv_dtypes) - {f"pos_{p}" for p in range(cfg.period)}
        if unknown:
            raise ValueError(
                f"kv_dtypes names positions {sorted(unknown)} outside this "
                f"model's period ({cfg.period} layer position(s))")
        out = {f"pos_{p}": kv_dtypes.get(f"pos_{p}", base)
               for p in range(cfg.period)}
    for key, dt in out.items():
        if dt not in KV_DTYPES:
            raise ValueError(f"{key}: unknown kv_dtype {dt!r}; expected one "
                             f"of {KV_DTYPES}")
        if dt == "int4" and cfg.head_dim_ % 2:
            raise ValueError(
                f"{key}: kv_dtype='int4' packs two nibbles per byte along "
                f"head_dim, which requires an even head_dim (got "
                f"{cfg.head_dim_})")
    return out


def init_paged_caches(cfg: ModelConfig, n_pages: int, page_size: int,
                      dtype, kv_dtypes=None) -> Dict[str, PagedKVCache]:
    """Paged decode caches stacked over periods: {pos_i: [P, n_pages, ...]}.

    Only attention mixers page (KV grows with the sequence); Mamba state is
    O(1) per request and gains nothing from paging — models with mamba
    mixers serve through the dense-slot runtime instead.

    ``kv_dtypes`` (see :func:`resolve_kv_dtypes`) picks each position's KV
    page dtype: ``"fp16"`` keeps compute-dtype pages (today's layout, no
    scales), ``"int8"``/``"int4"`` store quantized codes with per-(slot,
    head) dequant scales riding inside the page allocation.
    """
    if page_size < 1:
        raise ValueError(f"page_size must be >= 1, got {page_size}")
    resolved = resolve_kv_dtypes(cfg, kv_dtypes)
    caches: Dict[str, PagedKVCache] = {}
    for pos in range(cfg.period):
        if cfg.mixer_kind(pos) != "attn":
            raise ValueError(
                f"paged KV caches cover attention mixers only; layer position "
                f"{pos} is {cfg.mixer_kind(pos)!r} (serve this arch with the "
                f"slot runtime)"
            )
        template = PagedKVCache.zeros(cfg, n_pages, page_size, dtype,
                                      kv_dtype=resolved[f"pos_{pos}"])
        caches[f"pos_{pos}"] = jax.tree.map(
            lambda a: jnp.zeros((cfg.n_periods,) + a.shape, a.dtype), template
        )
    return caches


# ---------------------------------------------------------------------------
# byte accounting: what a page / a token actually costs in pool memory
# ---------------------------------------------------------------------------


def kv_token_bytes(cfg: ModelConfig, kv_dtype: str, dtype=None) -> int:
    """KV pool bytes ONE token costs at ONE layer under ``kv_dtype``.

    fp pages: ``2 * kv * hd * itemsize(compute dtype)``.  Quantized pages:
    one byte per code element (int4 packs two per byte) plus the two in-page
    float16 scales per (token, kv head) — selfspec-calculator's
    ``value_bytes_per_elem: 1, scale_bytes: 2`` memory model.
    """
    kv, hd = cfg.n_kv_heads, cfg.head_dim_
    if kv_dtype == "fp16":
        itemsize = jnp.dtype(dtype if dtype is not None
                             else cfg.compute_dtype).itemsize
        return 2 * kv * hd * itemsize
    codes = hd // 2 if kv_dtype == "int4" else hd
    scale = jnp.dtype(KV_SCALE_DTYPE).itemsize
    return 2 * kv * (codes + scale)


def kv_page_bytes(cfg: ModelConfig, page_size: int, kv_dtypes=None,
                  dtype=None) -> int:
    """Bytes ONE physical page costs across ALL layers (k+v+scales).

    The pool allocates every layer's slice of a page together (one page id
    indexes every per-position pool), so this is the allocator's true
    granularity — what ``PagePool.stats()`` byte accounting is based on.
    """
    resolved = resolve_kv_dtypes(cfg, kv_dtypes)
    per_layer = {k: kv_token_bytes(cfg, dt, dtype=dtype)
                 for k, dt in resolved.items()}
    return page_size * cfg.n_periods * sum(per_layer.values())


def kv_cache_nbytes(caches) -> int:
    """Actual device bytes of a paged-cache tree (every leaf, scales in)."""
    return sum(leaf.size * leaf.dtype.itemsize
               for leaf in jax.tree.leaves(caches))


def pages_for(n_tokens: int, page_size: int) -> int:
    """Pages needed to hold ``n_tokens`` tokens."""
    return -(-n_tokens // page_size)


def table_width(max_len: int, page_size: int) -> int:
    """Device page-table width: pages covering ``max_len`` + the garbage
    column (the last logical page, where pad positions point)."""
    return pages_for(max_len, page_size) + 1


def pad_position(max_len: int, page_size: int) -> int:
    """The logical position pad tokens write to — start of the garbage
    column. Strictly greater than every real position (< max_len rounded up
    to pages), so ``kpos <= tpos`` masks it out of every real row."""
    return (table_width(max_len, page_size) - 1) * page_size


def table_array(tables: Sequence[Sequence[int]], width: int) -> np.ndarray:
    """Host page-table lists → dense int32 [B, width] device operand.

    Unallocated entries (and the trailing garbage column) point at
    GARBAGE_PAGE; logical positions beyond a row's allocation are never
    admitted by the position mask, so the placeholder is read-safe.
    """
    out = np.full((len(tables), width), GARBAGE_PAGE, dtype=np.int32)
    for i, t in enumerate(tables):
        if len(t) > width - 1:
            raise ValueError(f"row {i} holds {len(t)} pages > table width "
                             f"{width} (garbage column excluded)")
        out[i, : len(t)] = t
    return out


class PagePool:
    """Host-side physical-page allocator (free list + refcounts + stats).

    ``alloc`` returns ``None`` on exhaustion instead of raising — the
    scheduler turns that into queue backpressure (requests wait) or
    preemption, never a crash.

    Pages are **refcounted**: ``alloc`` hands a page out with one reference,
    shared-prefix caching adds more (``incref`` — one per page table that
    names the page, plus one for the prefix trie), and ``free`` *releases one
    reference*; the page returns to the free list only when its last owner
    lets go. Releasing a reference that was never taken (freeing a page
    twice) raises — a double-freed page would enter the free list twice and
    get handed to two requests, silently corrupting both requests' KV.
    """

    def __init__(self, n_pages: int, page_bytes: int = 0):
        if n_pages < 2:
            raise ValueError("pool needs >= 2 pages (page 0 is the garbage page)")
        self.n_pages = n_pages
        # device bytes one physical page costs across every layer's pools
        # (codes + in-page scales); 0 = unpriced (see kv_page_bytes). The
        # scheduler sets it so stats() can report byte-level occupancy.
        self.page_bytes = page_bytes
        self._free: deque = deque(range(1, n_pages))  # page 0 reserved
        self._ref: List[int] = [0] * n_pages
        self._allocs = 0
        self._frees = 0

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.n_pages - 1 - len(self._free)

    @property
    def shared_pages(self) -> int:
        return sum(1 for r in self._ref if r > 1)

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def free_page_ids(self) -> FrozenSet[int]:
        """Snapshot of the free list as a set (race-checker ledger view)."""
        return frozenset(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        """n physical pages (one reference each), or None (backpressure) if
        the pool can't cover the request — partial allocations are never
        handed out."""
        if n > len(self._free):
            return None
        self._allocs += n
        out = []
        for _ in range(n):
            p = self._free.popleft()
            self._ref[p] = 1
            out.append(p)
        return out

    def refcount(self, page: int) -> int:
        return self._ref[page]

    def incref(self, pages: Sequence[int]) -> None:
        """Add one reference per page (a new sharer of already-live pages)."""
        for p in pages:
            if not 1 <= p < self.n_pages or self._ref[p] < 1:
                raise ValueError(f"incref on non-live page {p}")
        for p in pages:
            self._ref[p] += 1

    def free(self, pages: Sequence[int]) -> None:
        """Release one reference per page; a page whose last reference drops
        returns to the free list.  Raises on double-free (more releases than
        live references, duplicates within one call included) BEFORE any
        state moves, so an error never half-frees a batch."""
        need = Counter(pages)
        for p, c in need.items():
            if not 1 <= p < self.n_pages:
                raise ValueError(f"freeing invalid page {p}")
            if self._ref[p] < c:
                raise ValueError(
                    f"double-free of page {p}: {c} release(s) requested but "
                    f"only {self._ref[p]} reference(s) held"
                )
        for p in pages:
            self._ref[p] -= 1
            if self._ref[p] == 0:
                self._free.append(p)
        self._frees += len(pages)

    def stats(self) -> Dict[str, int]:
        return {
            "n_pages": self.n_pages,
            "free_pages": self.free_pages,
            "used_pages": self.used_pages,
            "shared_pages": self.shared_pages,
            "alloc_count": self._allocs,
            "free_count": self._frees,
            "page_bytes": self.page_bytes,
            "pool_bytes": self.page_bytes * self.n_pages,
            "used_bytes": self.page_bytes * self.used_pages,
            "free_bytes": self.page_bytes * self.free_pages,
        }


# ---------------------------------------------------------------------------
# checkpoint / rollback: undo speculative page growth without leaks
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PageCheckpoint:
    """Snapshot of one request's page-table length + the pool counters,
    taken before a speculative (draft) allocation burst.

    Rolling back frees exactly the pages allocated since the checkpoint —
    pushed back onto the *head* of the free list in reverse allocation
    order, so with no interleaved alloc/free the pool's free list, counters
    and the page table end up bit-identical to never having speculated.
    Stale KV written into the rolled-back pages needs no scrubbing: the
    per-row position mask (``kpos <= tpos``) keeps unaccepted positions out
    of every softmax, and any future owner overwrites a page's rows before
    its positions become readable.
    """

    n_pages: int   # len(table) at checkpoint


def checkpoint(pool: PagePool, table: Sequence[int]) -> PageCheckpoint:
    """Snapshot ``table`` (one request's physical-page list) against ``pool``."""
    del pool  # kept in the signature so the snapshot point is explicit
    return PageCheckpoint(n_pages=len(table))


def rollback(pool: PagePool, table: List[int], ckpt: PageCheckpoint,
             keep: Optional[int] = None) -> List[int]:
    """Release pages allocated after ``ckpt``, keeping the first ``keep``.

    ``keep`` defaults to the checkpointed length (full rollback); a spec
    round that accepted some tokens passes ``keep=pages_for(accepted_ctx)``
    to retain the prefix that now holds verified KV.  Returns the freed
    pages.  The free list is restored head-first in reverse allocation
    order and the allocation counter is un-counted (a rolled-back draft was
    never an allocation, not an alloc+free pair), so with no interleaved
    activity a full rollback leaves the pool state bit-identical to the
    checkpoint — the leak-proofness the rollback test asserts, including
    across a later defrag.  Under interleaved allocations from other
    requests the free-list *order* may differ, but membership and counters
    stay exact.
    """
    keep = ckpt.n_pages if keep is None else max(keep, ckpt.n_pages)
    if keep > len(table):
        # accepted context claims pages that were never allocated — an
        # accounting error upstream; masking it with [] would let the caller
        # decode into pages it does not own
        raise ValueError(
            f"rollback keep={keep} exceeds the table's {len(table)} pages: "
            f"accepted context covers pages that were never allocated"
        )
    dropped = table[keep:]
    for p in dropped:  # validate BEFORE mutating: error → state untouched
        if not 1 <= p < pool.n_pages:
            raise ValueError(f"rolling back invalid page {p}")
        if pool._ref[p] != 1:
            raise ValueError(
                f"rolling back shared page {p} (refcount {pool._ref[p]}): "
                f"draft growth must own its pages exclusively — a rollback "
                f"would yank KV out from under the other sharers"
            )
    del table[keep:]
    for p in reversed(dropped):
        pool._ref[p] = 0
        pool._free.appendleft(p)
    pool._allocs -= len(dropped)
    return dropped


# ---------------------------------------------------------------------------
# defrag: compact live pages into the low-index prefix of the pool
# ---------------------------------------------------------------------------
def _remap_pages(leaf: jax.Array, src: jax.Array, dst: jax.Array) -> jax.Array:
    """Move pool pages src[i] → dst[i] on the pages axis (axis 0 for a
    per-layer pool, axis 1 under the period stack)."""
    axis = leaf.ndim - 4  # [..., n_pages, page_size, kv, hd]
    moved = jnp.take(leaf, src, axis=axis)
    if axis == 0:
        return leaf.at[dst].set(moved)
    if axis == 1:
        return leaf.at[:, dst].set(moved)
    raise ValueError(f"unexpected pool rank {leaf.ndim}")


def copy_page(caches, src: int, dst: int):
    """Duplicate physical page ``src``'s rows into page ``dst`` across every
    pool leaf — the device half of copy-on-write: a lane about to write into
    a shared page gets a private copy first (the caller rewrites its table
    and moves the refcounts)."""
    s = jnp.asarray([src], dtype=jnp.int32)
    d = jnp.asarray([dst], dtype=jnp.int32)
    return jax.tree.map(lambda leaf: _remap_pages(leaf, s, d), caches)


def defrag(caches, pool: PagePool, tables: List[List[int]], trie=None):
    """Compact live pages to the front of the pool.

    With full page-table indirection, pool fragmentation never costs decode
    time — this exists to shrink the live footprint (snapshot / pool resize:
    after compaction the high-water mark is ``used_pages + 1``). Returns the
    remapped cache tree and rewrites ``pool``/``tables`` host state in place.
    Decode output is bit-identical before and after (pages move, the tables
    move with them).

    ``trie`` — an optional :class:`PrefixCache`: its cached pages are live
    too (they hold reusable prefix KV with no owning lane) and are remapped
    alongside the page tables.  Because defrag walks every owner, it doubles
    as a leak check: a page holding references that no table and no trie
    node can account for has been lost by its owner and is reported, not
    silently compacted away.
    """
    held = [] if trie is None else trie.pages()
    live_set = {p for t in tables for p in t} | set(held)
    live = sorted(live_set)
    orphans = [p for p in range(1, pool.n_pages)
               if pool._ref[p] > 0 and p not in live_set]
    if orphans:
        raise ValueError(
            f"defrag found leaked pages {orphans}: live refcounts with no "
            f"owning page table or prefix-cache node"
        )
    mapping = {src: dst for dst, src in enumerate(live, start=1)}
    moves = [(s, d) for s, d in mapping.items() if s != d]
    if moves:
        src = jnp.asarray([s for s, _ in moves], dtype=jnp.int32)
        dst = jnp.asarray([d for _, d in moves], dtype=jnp.int32)
        caches = jax.tree.map(lambda leaf: _remap_pages(leaf, src, dst), caches)
    for t in tables:
        t[:] = [mapping[p] for p in t]
    if trie is not None:
        trie.remap(mapping)
    ref = [0] * pool.n_pages
    for s, d in mapping.items():
        ref[d] = pool._ref[s]
    pool._ref = ref
    pool._free = deque(range(len(live) + 1, pool.n_pages))
    return caches


# ---------------------------------------------------------------------------
# shared-prefix cache: a radix/trie index over page-granular token prefixes
# ---------------------------------------------------------------------------


class _TrieNode:
    """One cached physical page: ``key`` is the page's full token tuple,
    ``page`` the physical page whose KV holds exactly those tokens (given
    the ancestor chain as context)."""

    __slots__ = ("key", "page", "parent", "children", "last_used")

    def __init__(self, key: Tuple[int, ...], page: int,
                 parent: Optional["_TrieNode"]):
        self.key = key
        self.page = page
        self.parent = parent
        self.children: Dict[Tuple[int, ...], "_TrieNode"] = {}
        self.last_used = 0


class PrefixCache:
    """Host-side radix index over token prefixes, at page granularity.

    The freeze-once premise applied to KV: a prompt prefix's KV depends only
    on the prefix tokens, so two requests sharing a system prompt can share
    the physical pages that hold it.  Each trie node is one *full* page of
    tokens; a path from the root spells a prefix and names the pages holding
    its KV.  The trie owns one refcount per cached page (so pages survive
    their originating request); every admitted lane that reuses a node adds
    its own reference via :meth:`claim`.

    Writes into a shared page are forbidden — the scheduler copies the page
    first (:func:`copy_page`, COW), which is only ever needed on the *last,
    partially-consumed* page of a hit (a hit is capped at ``len(prompt)-1``
    tokens so at least one token remains to prefill — its logits seed the
    first sampled token — and that cap can land mid-page).

    Eviction is LRU over leaf nodes whose page only the trie references
    (refcount 1): interior nodes keep their subtree reachable, and pages a
    live lane still shares are merely unindexed (the lane's reference keeps
    them alive).  All state is host-side; the KV itself never moves on a
    hit, an insert, or an eviction.
    """

    def __init__(self, page_size: int):
        self.page_size = page_size
        self.root = _TrieNode((), GARBAGE_PAGE, None)
        self._tick = 0
        self.evictions = 0
        self.cached_tokens = 0   # cumulative tokens served from the cache
        self.lookup_tokens = 0   # cumulative prompt tokens looked up

    # -- traversal -----------------------------------------------------------
    def nodes(self) -> Iterator[_TrieNode]:
        stack = list(self.root.children.values())
        while stack:
            nd = stack.pop()
            yield nd
            stack.extend(nd.children.values())

    def pages(self) -> List[int]:
        return [nd.page for nd in self.nodes()]

    @property
    def n_pages(self) -> int:
        return sum(1 for _ in self.nodes())

    def _touch(self, node: _TrieNode) -> None:
        self._tick += 1
        node.last_used = self._tick

    # -- lookup / claim ------------------------------------------------------
    def match(self, tokens: Sequence[int]) -> Tuple[List[_TrieNode], int]:
        """Longest cached page-chain prefix of ``tokens``.

        Returns ``(nodes, hit_tokens)``.  ``hit_tokens`` is capped at
        ``len(tokens) - 1`` — the final token always prefills so its logits
        can seed sampling; when the cap lands inside the last matched page,
        that page is handed over anyway (its KV for the covered positions is
        valid) and the lane's first write COWs it.  Read-only: refcounts
        move in :meth:`claim`, once admission actually goes through.
        """
        ps = self.page_size
        limit = len(tokens) - 1
        nodes: List[_TrieNode] = []
        node, i = self.root, 0
        while i + ps <= len(tokens) and i < limit:
            child = node.children.get(tuple(int(t) for t in tokens[i:i + ps]))
            if child is None:
                break
            nodes.append(child)
            node, i = child, i + ps
        return nodes, min(i, limit)

    def claim(self, nodes: Sequence[_TrieNode], pool: PagePool) -> List[int]:
        """Pin a matched chain for an admitted lane: one reference per page
        plus an LRU touch. Returns the pages in prefix order."""
        pages = [nd.page for nd in nodes]
        pool.incref(pages)
        for nd in nodes:
            self._touch(nd)
        return pages

    # -- insert --------------------------------------------------------------
    def insert(self, tokens: Sequence[int], pages: Sequence[int],
               pool: PagePool) -> int:
        """Index every full page of ``tokens`` (a lane's fully-ingested
        prompt, KV written).  Prefixes already cached keep the trie's copy
        (two physical pages may hold identical KV; dedup is not worth a
        device copy); new nodes take one trie-owned reference on the lane's
        page. Returns the number of nodes created."""
        ps = self.page_size
        node, new = self.root, 0
        for j in range(len(tokens) // ps):
            key = tuple(int(t) for t in tokens[j * ps:(j + 1) * ps])
            child = node.children.get(key)
            if child is None:
                pool.incref([pages[j]])
                child = _TrieNode(key, pages[j], node)
                node.children[key] = child
                new += 1
            self._touch(child)
            node = child
        return new

    # -- eviction ------------------------------------------------------------
    def reclaimable(self, pool: PagePool) -> int:
        """Pages eviction could return to the free list right now (cached
        pages no live lane shares)."""
        return sum(1 for nd in self.nodes() if pool.refcount(nd.page) == 1)

    def evict_one(self, pool: PagePool) -> bool:
        """Drop the least-recently-used *reclaimable* leaf (page owned by
        the trie alone — its page returns to the free list).  A pinned leaf
        (live lanes still share its page) is only unindexed when it shields
        a reclaimable interior node; with nothing reclaimable anywhere this
        returns False instead of draining the hot shared-prefix index for
        zero freed pages."""
        if not any(pool.refcount(nd.page) == 1 for nd in self.nodes()):
            return False
        leaves = [nd for nd in self.nodes() if not nd.children]
        free = [nd for nd in leaves if pool.refcount(nd.page) == 1]
        if not free:
            # every reclaimable page sits on an interior node: unindex only
            # leaves whose ancestor chain holds one (never an unrelated hot
            # chain that would lose its cache for zero freed pages)
            def shields(nd):
                a = nd.parent
                while a is not None and a.parent is not None:
                    if pool.refcount(a.page) == 1:
                        return True
                    a = a.parent
                return False

            free = [nd for nd in leaves if shields(nd)]
        victim = min(free, key=lambda nd: nd.last_used)
        del victim.parent.children[victim.key]
        pool.free([victim.page])
        self.evictions += 1
        return True

    def evict_until(self, pool: PagePool, n_free: int) -> bool:
        """Evict LRU leaves until ``n_free`` pages are free; True on success
        (interior nodes become leaves as their subtrees drain, so every
        trie-only page is eventually reachable)."""
        while pool.free_pages < n_free:
            if not self.evict_one(pool):
                return False
        return True

    def clear(self, pool: PagePool) -> None:
        """Unindex everything and release the trie's references (pool
        shutdown / tests); pages live lanes share stay live through the
        lanes' own references."""
        for nd in list(self.nodes()):
            pool.free([nd.page])
        self.root.children = {}

    # -- defrag hook ---------------------------------------------------------
    def remap(self, mapping: Dict[int, int]) -> None:
        for nd in self.nodes():
            nd.page = mapping[nd.page]
