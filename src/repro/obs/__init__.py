"""Observability layer for the serving runtime.

``Observability`` bundles the two halves every instrumented component
takes: a :class:`MetricsRegistry` (always-on counters/gauges/histograms;
cheap enough to leave enabled) and a :class:`TraceRecorder` (structured
event ring buffer; opt-in, off by default).  Engines build their own
bundle so parallel engines in one process never share series.
"""
from __future__ import annotations

import dataclasses

from repro.obs.metrics import (
    COUNT_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    METRICS_SCHEMA_VERSION,
    MetricsRegistry,
    TIME_BUCKETS,
    default_registry,
)
from repro.obs.trace import (
    SCHED_TRACK,
    TraceEvent,
    TraceRecorder,
    default_tracer,
    device_span,
    request_track,
)
from repro.obs.export import (
    chrome_trace,
    prometheus_text,
    validate_chrome_trace,
    validate_hw_block,
    validate_metrics_json,
    validate_prometheus_text,
    write_chrome_trace,
    write_prometheus,
)

#: hwcost names resolve lazily (PEP 562): the CLI tools (check / regress)
#: import this package and must stay importable without the core stack.
_HWCOST_NAMES = {"HardwareCostModel", "LayerGeom", "bitslice_design",
                 "da_design", "draft_price"}


def __getattr__(name):
    if name in _HWCOST_NAMES:
        from repro.obs import hwcost

        return getattr(hwcost, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


@dataclasses.dataclass
class Observability:
    """Registry + tracer pair threaded through a serving stack."""

    registry: MetricsRegistry
    tracer: TraceRecorder

    @classmethod
    def make(cls, metrics: bool = True, trace: bool = False,
             trace_capacity: int = 65536) -> "Observability":
        return cls(registry=MetricsRegistry(enabled=metrics),
                   tracer=TraceRecorder(capacity=trace_capacity,
                                        enabled=trace))


__all__ = [
    "COUNT_BUCKETS",
    "Counter",
    "Gauge",
    "HardwareCostModel",
    "Histogram",
    "LayerGeom",
    "METRICS_SCHEMA_VERSION",
    "MetricsRegistry",
    "Observability",
    "SCHED_TRACK",
    "TIME_BUCKETS",
    "TraceEvent",
    "TraceRecorder",
    "bitslice_design",
    "chrome_trace",
    "da_design",
    "default_registry",
    "default_tracer",
    "device_span",
    "draft_price",
    "prometheus_text",
    "request_track",
    "validate_chrome_trace",
    "validate_hw_block",
    "validate_metrics_json",
    "validate_prometheus_text",
    "write_chrome_trace",
    "write_prometheus",
]
