"""minitron-8b [arXiv:2407.14679; hf] — width-pruned Nemotron-4.

32L d_model=4096 32H (GQA kv=8) d_ff=16384 vocab=256000. Nemotron family:
squared-ReLU MLP (non-gated), LayerNorm.
"""
from repro.configs.registry import register
from repro.models.config import ModelConfig

CONFIG = register(ModelConfig(
    name="minitron-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=16384,
    vocab=256000,
    mlp_act="relu2",
    norm_type="layernorm",
))
