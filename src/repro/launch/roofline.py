"""Three-term roofline from a compiled dry-run artifact (DESIGN.md §5).

  compute    = HLO_FLOPs   / (chips · 197 TFLOP/s bf16)
  memory     = HLO_bytes   / (chips · 819 GB/s HBM)
  collective = coll_bytes  / (chips · 50 GB/s/link ICI)

``cost_analysis()`` supplies flops / bytes accessed for the *per-partition*
SPMD module; collective bytes are not in cost_analysis — we parse the
optimized HLO and sum the result-shape payload of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute op. All terms
are reported as *per-chip seconds per step*, so the dominant term is directly
the step-time lower bound.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

# TPU v5e-class hardware constants (per chip)
PEAK_FLOPS = 197e12      # bf16
HBM_BW = 819e9           # bytes/s
ICI_BW = 50e9            # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes per collective kind from optimized HLO text.

    ``-start`` ops are counted, matching ``-done`` twins are not (the pair
    describes one transfer)."""
    out: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_RE.match(line)
        if not m:
            continue
        if "-done" in line.split("=", 1)[1].split("(")[0]:
            continue
        shape_str, kind = m.group(1), m.group(2)
        out[kind] = out.get(kind, 0) + _shape_bytes(shape_str)
    return out


@dataclasses.dataclass(frozen=True)
class Roofline:
    flops_per_chip: float
    bytes_per_chip: float
    coll_bytes_per_chip: float
    chips: int
    model_flops_global: float = 0.0

    @property
    def t_compute(self) -> float:
        return self.flops_per_chip / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_chip / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_chip / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs (global) — remat/redundancy waste check."""
        hlo_global = self.flops_per_chip * self.chips
        return self.model_flops_global / hlo_global if hlo_global else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute fraction of the step-time bound: the score.

        = (MODEL_FLOPS / chips / PEAK) / max(t_c, t_m, t_coll)."""
        t_useful = self.model_flops_global / self.chips / PEAK_FLOPS
        return t_useful / self.t_bound if self.t_bound else 0.0

    def as_dict(self) -> dict:
        return {
            "chips": self.chips,
            "flops_per_chip": self.flops_per_chip,
            "bytes_per_chip": self.bytes_per_chip,
            "coll_bytes_per_chip": self.coll_bytes_per_chip,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops_global": self.model_flops_global,
            "useful_flops_fraction": self.useful_flops_fraction,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops(cfg, shape, n_params: int, n_active: int) -> float:
    """MODEL_FLOPS: 6·N·D train, 2·N·D prefill, 2·N·B decode (N = active)."""
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch  # decode: one token per row


def from_compiled(compiled, chips: int, model_flops_global: float) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    coll = sum(collective_bytes(compiled.as_text()).values())
    return Roofline(
        flops_per_chip=flops,
        bytes_per_chip=byts,
        coll_bytes_per_chip=float(coll),
        chips=chips,
        model_flops_global=model_flops_global,
    )
