"""qwen2-vl-72b [arXiv:2409.12191; hf] — M-RoPE, dynamic-resolution VLM.

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064. The vision frontend
(ViT) is a STUB per the assignment: input_specs provide precomputed patch
embeddings [B, T, d_model] plus 3-D M-RoPE position ids (t, h, w); sections
(16, 24, 24) over head_dim/2 = 64 per the published config. qkv biases on.
"""
from repro.configs.registry import register
from repro.models.config import ModelConfig

CONFIG = register(ModelConfig(
    name="qwen2-vl-72b",
    family="dense",
    modality="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab=152064,
    attn_bias=True,
    mrope_sections=(16, 24, 24),
    rope_theta=1_000_000.0,
))
