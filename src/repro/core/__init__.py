# The paper's primary contribution: Distributed-Arithmetic VMM as a
# composable JAX library (quantization, LUT construction, the unified
# execution engine, bit-slicing baseline, calibrated hardware cost model).
from repro.core.da import (  # noqa: F401
    DAConfig,
    build_luts,
    da_vmm_bitplane,
    da_vmm_lut,
    da_vmm_onehot,
)
from repro.core.engine import (  # noqa: F401
    BackendSpec,
    PackedWeights,
    da_matmul,
    da_vmm,
    dense,
    pack_quantized,
    pack_weights,
    registered_backends,
    select_backend,
)
from repro.core.freeze import (  # noqa: F401
    DAArtifact,
    LayerPlan,
    da_memory_report,
    freeze_model,
    load_artifact,
    plan_model,
    save_artifact,
)
from repro.core.linear import DAFrozenLinear, freeze_da  # noqa: F401
from repro.core.quant import (  # noqa: F401
    QTensor,
    int_matmul,
    quantize_acts_signed,
    quantize_acts_unsigned,
    quantize_weights,
)
