"""Serving launcher: batched continuous-batching engine, optional DA mode.

  python -m repro.launch.serve --arch qwen3-8b --smoke --quant da8 \
      --requests 16 --batch 4
"""
import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--quant", default="none",
                    choices=["none", "int8", "da8", "da8-lut"])
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    import dataclasses

    import jax
    import numpy as np

    from repro.configs.registry import ARCHS, reduce_for_smoke
    from repro.core.da import DAConfig
    from repro.models.model import count_params, init_model
    from repro.serve.engine import Request, ServeEngine
    from repro.serve.quantize import da_memory_report, freeze_model_da

    cfg = ARCHS[args.arch]
    if args.smoke:
        cfg = reduce_for_smoke(cfg)
    cfg = dataclasses.replace(cfg, moe_dropless=True)
    if cfg.modality != "text":
        raise SystemExit(f"{cfg.name} has a stub frontend; serve text archs")

    params = init_model(jax.random.key(0), cfg)
    print(f"arch={cfg.name} params={count_params(cfg)/1e6:.1f}M quant={args.quant}")
    if args.quant != "none":
        mode = {"int8": "int8", "da8": "da_bitplane", "da8-lut": "da_lut"}[args.quant]
        params = freeze_model_da(params, DAConfig(x_signed=True), mode=mode)
        rep = da_memory_report(params)
        print(f"pre-VMM freeze: {rep['da_matrices']} matrices"
              + (f", LUT blow-up {rep['cell_blowup']:.0f}x"
                 if rep["lut_cells"] else ""))

    eng = ServeEngine(cfg, params, batch_size=args.batch, max_len=args.max_len)
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for uid in range(args.requests):
        eng.submit(Request(uid=uid,
                           prompt=rng.integers(0, cfg.vocab, rng.integers(4, 32)),
                           max_new_tokens=args.max_new))
    done = eng.run()
    dt = time.perf_counter() - t0
    toks = sum(len(r.generated) for r in done.values())
    print(f"served {len(done)} requests / {toks} tokens in {dt:.1f}s "
          f"({toks/dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
