"""qwen2-moe-a2.7b [hf:Qwen/Qwen1.5-MoE-A2.7B; hf] — 4 shared + 60 routed top-4.

24L d_model=2048 16H (MHA kv=16) expert d_ff=1408 vocab=151936, MoE 60e top-4,
shared-expert intermediate 4×1408=5632. 60 experts are padded to 64 on the
16-way model axis (EP divisibility) with -inf router logits — exact numerics.
"""
from repro.configs.registry import register
from repro.models.config import ModelConfig

CONFIG = register(ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    vocab=151936,
    n_experts=60,
    top_k=4,
    moe_d_ff=1408,
    n_shared_experts=4,
    attn_bias=True,
))
