"""moonshot-v1-16b-a3b [hf:moonshotai/Moonlight-16B-A3B; hf] — 64e top-6.

48L d_model=2048 16H (MHA kv=16) expert d_ff=1408 vocab=163840, MoE 64e top-6.
(The published model keeps its first layer dense; we keep the stack uniform
for the scan structure — negligible roofline effect, noted in DESIGN.md.)
"""
from repro.configs.registry import register
from repro.models.config import ModelConfig

CONFIG = register(ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    vocab=163840,
    n_experts=64,
    top_k=6,
    moe_d_ff=1408,
))
