"""Quantized KV cache at EQUAL pool bytes: how many more tokens stay
resident, how much less the scheduler preempts, and what greedy decode pays.

    PYTHONPATH=src python benchmarks/kv_quant.py           # full
    PYTHONPATH=src python benchmarks/kv_quant.py --quick   # CI-sized

Writes ``artifacts/BENCH_kv_quant.json`` (override with ``--out``).

Setup: the serve_throughput mixed fleet (16 staggered requests, varied
prompt/output lengths) against a deliberately tight fp16 page pool — total
fleet demand ≈ 2× the fp16 pool's token capacity, so the fp16 baseline
queues and preempts.  Each quantized dtype then gets a pool of the SAME
byte budget (more pages per byte: ~2x for int8+scales at hd=32, ~3.6x for
int4), and the fleet is replayed.  Reported per dtype:

* ``pool_tokens`` / ``capacity_ratio`` — token capacity at equal bytes;
* ``peak_resident_tokens`` / ``admitted_tokens_ratio`` — the largest number
  of KV token-rows simultaneously live during the run (the measured
  admission win; acceptance bar: int8 ≥ 1.8× fp16);
* ``peak_resident_requests`` — concurrently decoding lanes at that peak;
* ``preemptions`` — evictions the tight pool forced;
* ``token_match_rate`` / ``exact_streams`` — greedy-token fidelity vs the
  fp16 cache (mean matched-prefix fraction; int8 is near-lossless on this
  model, int4 visibly lossier — the accuracy/capacity dial).
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np

try:  # run as `python benchmarks/kv_quant.py` (script dir on path)
    from stamp import stamp_and_write
except ImportError:  # imported as a module from the repo root
    from benchmarks.stamp import stamp_and_write

from repro.configs.registry import ARCHS
from repro.core.da import DAConfig
from repro.core.freeze import freeze_model
from repro.models.model import init_model
from repro.serve.engine import Request, ServeEngine
from repro.serve.kvcache import kv_page_bytes, pages_for

KV_DTYPES = ("fp16", "int8", "int4")


def build_cfg():
    # the serve_throughput runtime-benchmark model: small enough that the
    # scheduler, not BLAS, dominates, with hd=32 so int4 packs evenly
    return dataclasses.replace(
        ARCHS["qwen3-8b"],
        name="qwen3-serve-bench",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab=4000,
        param_dtype="float32",
        compute_dtype="float32",
        remat=False,
        moe_dropless=True,
    )


def workload(cfg, n_requests):
    # fleet demand ~16 x 36 token rows ~= 3x the fp16 pool: the baseline is
    # genuinely memory-bound while the quantized pools can hold the fleet
    r = np.random.default_rng(2)
    return [Request(uid=u,
                    prompt=r.integers(0, cfg.vocab, int(r.integers(4, 12))),
                    max_new_tokens=int(r.integers(24, 32)))
            for u in range(n_requests)]


def run_fleet(frozen, cfg, kv_dtype, n_pages, page_size, max_len,
              n_requests):
    """Serve the mixed fleet on one pool precision; track peak residency."""
    eng = ServeEngine(cfg, frozen, batch_size=16, max_len=max_len,
                      runtime="paged", page_size=page_size, n_pages=n_pages,
                      admission="optimistic", prefill_lanes=8,
                      prefill_chunk=4, kv_dtype=kv_dtype)
    eng.warmup()
    for req in workload(cfg, n_requests):
        eng.submit(req)
    sched = eng._rt
    peak_tokens = peak_requests = 0
    for _ in range(100_000):
        active = eng.step()
        live = [l for l in sched.lanes if l is not None]
        peak_tokens = max(peak_tokens, sum(l.pos for l in live))
        peak_requests = max(peak_requests, len(live))
        if not active and not eng.queue:
            break
    m = eng.metrics()
    return {
        "kv_dtype": kv_dtype,
        "n_pages": n_pages,
        "pool_tokens": (n_pages - 1) * page_size,  # page 0 is garbage
        "pool_bytes": m["pool"]["pool_bytes"],
        "bytes_per_token": m["kv"]["bytes_per_token"],
        "peak_resident_tokens": peak_tokens,
        "peak_resident_requests": peak_requests,
        "preemptions": m["preemptions"],
        "out_tokens": m["out_tokens"],
        "tokens_per_s": round(m["tokens_per_s"], 2),
    }, {u: r.generated for u, r in eng.done.items()}


def match_rate(base, other):
    """Mean matched-prefix fraction of greedy streams vs the fp16 cache."""
    fracs, exact = [], 0
    for uid, ref in base.items():
        got = other.get(uid, [])
        n = 0
        for a, b in zip(ref, got):
            if a != b:
                break
            n += 1
        fracs.append(n / max(1, len(ref)))
        exact += int(list(got) == list(ref))
    return round(float(np.mean(fracs)), 4), exact


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="CI-sized run")
    ap.add_argument("--requests", type=int, default=None,
                    help="fleet size (default 16)")
    ap.add_argument("--out", default="artifacts/BENCH_kv_quant.json")
    args = ap.parse_args()
    n_requests = args.requests or 16

    cfg = build_cfg()
    params = init_model(jax.random.key(0), cfg)
    art = freeze_model(params, DAConfig(x_signed=True), mode="auto",
                       m_hint=8, model_cfg=cfg, pin_modes=False)
    del params

    # Equal-bytes pools: the fp16 budget is ONE dense-slot lane of max_len
    # (the serve_throughput geometry halved — fleet demand of ~16×24 token
    # rows is ~2× this pool's capacity, so the fp16 baseline is genuinely
    # memory-bound); every other dtype gets the same byte budget.
    page_size, max_len = 8, 192
    n_pages_fp = 1 * pages_for(max_len, page_size) + 1
    budget = n_pages_fp * kv_page_bytes(cfg, page_size, "fp16")

    results, streams = {}, {}
    for dt in KV_DTYPES:
        n_pages = max(2, budget // kv_page_bytes(cfg, page_size, dt))
        results[dt], streams[dt] = run_fleet(
            art.params, cfg, dt, int(n_pages), page_size, max_len,
            n_requests)
        print(f"{dt:>5s}: pages={results[dt]['n_pages']:<4d} "
              f"peak_tokens={results[dt]['peak_resident_tokens']:<5d} "
              f"peak_reqs={results[dt]['peak_resident_requests']:<3d} "
              f"preempt={results[dt]['preemptions']}")

    fp = results["fp16"]
    for dt in ("int8", "int4"):
        r = results[dt]
        r["capacity_ratio"] = round(r["pool_tokens"] / fp["pool_tokens"], 2)
        r["admitted_tokens_ratio"] = round(
            r["peak_resident_tokens"] / max(1, fp["peak_resident_tokens"]),
            2)
        r["token_match_rate"], r["exact_streams"] = match_rate(
            streams["fp16"], streams[dt])
        print(f"{dt}: capacity={r['capacity_ratio']}x "
              f"admitted={r['admitted_tokens_ratio']}x "
              f"match={r['token_match_rate']} "
              f"exact={r['exact_streams']}/{n_requests}")

    # acceptance: at equal pool bytes, int8 admits >= 1.8x the fp16 tokens
    assert results["int8"]["admitted_tokens_ratio"] >= 1.8, results["int8"]

    result = {
        "bench": "kv_quant",
        "model": cfg.name,
        "quick": args.quick,
        "requests": n_requests,
        "page_size": page_size,
        "max_len": max_len,
        "equal_pool_bytes": int(budget),
        "fleets": results,
    }
    stamp_and_write(args.out, result, seed=0)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
