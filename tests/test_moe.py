"""MoE: dropless dispatch == per-token loop reference; expert padding is an
exact no-op; capacity drops tokens deterministically."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.config import ModelConfig
from repro.models.layers import apply_mlp
from repro.models.moe import init_moe, moe_forward, padded_experts

KEY = jax.random.key(7)


def _cfg(**kw):
    base = dict(
        name="t", family="moe", n_layers=2, d_model=16, vocab=11,
        n_heads=2, n_kv_heads=2, n_experts=6, top_k=2, moe_d_ff=8,
        param_dtype="float32", compute_dtype="float32", moe_dropless=True,
    )
    base.update(kw)
    return ModelConfig(**base)


def _loop_reference(p, x, cfg):
    """Per-token top-k expert mixture, computed with plain loops."""
    b, t, d = x.shape
    logits = np.array(x.reshape(-1, d) @ p["router"])
    logits[:, cfg.n_experts:] = -np.inf
    gates = jax.nn.softmax(jnp.asarray(logits), axis=-1)
    out = np.zeros((b * t, d), np.float32)
    xf = np.asarray(x.reshape(-1, d))
    for i in range(b * t):
        topi = np.argsort(-np.asarray(gates[i]))[: cfg.top_k]
        topw = np.asarray(gates[i])[topi]
        topw = topw / topw.sum()
        for wgt, e in zip(topw, topi):
            gate_e = xf[i] @ np.asarray(p["w_gate"][e])
            up_e = xf[i] @ np.asarray(p["w_up"][e])
            act = (gate_e / (1 + np.exp(-gate_e))) * up_e  # silu(gate)*up
            out[i] += wgt * (act @ np.asarray(p["w_down"][e]))
    return out.reshape(b, t, d)


def test_dropless_matches_loop_reference():
    cfg = _cfg()
    p = init_moe(KEY, cfg)
    x = jax.random.normal(jax.random.key(1), (2, 5, cfg.d_model))
    got = np.asarray(moe_forward(p, x, cfg))
    want = _loop_reference(p, x, cfg)
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-4)


def test_expert_padding_exact():
    """60 experts padded to 64: padded experts are never routed to and carry
    zero weights — identical output to the unpadded count."""
    cfg = _cfg(n_experts=6)
    p = init_moe(KEY, cfg)
    e_pad = padded_experts(cfg)
    assert e_pad == 16  # 6 → 16 on the default 16-way axis
    # padded expert weights are exactly zero
    assert float(jnp.abs(p["w_up"][cfg.n_experts:]).max()) == 0.0
    x = jax.random.normal(jax.random.key(2), (2, 4, cfg.d_model))
    y = moe_forward(p, x, cfg)
    # route probability mass only on real experts:
    logits = x.reshape(-1, cfg.d_model) @ p["router"]
    pad_mask = jnp.where(jnp.arange(e_pad) < cfg.n_experts, 0.0, -jnp.inf)
    gates = jax.nn.softmax(logits + pad_mask, -1)
    assert float(gates[:, cfg.n_experts:].max()) == 0.0
    assert bool(jnp.all(jnp.isfinite(y)))


def test_capacity_drops_when_overloaded():
    """With capacity_factor far below 1, overflow tokens are dropped (their
    expert contribution is zero) — GShard semantics."""
    cfg = _cfg(moe_dropless=False, capacity_factor=0.1)
    p = init_moe(KEY, cfg)
    x = jax.random.normal(jax.random.key(3), (2, 16, cfg.d_model))
    y_small = np.asarray(moe_forward(p, x, cfg))
    y_full = np.asarray(moe_forward(p, x, dataclasses.replace(cfg, moe_dropless=True)))
    assert np.abs(y_small - y_full).max() > 1e-6  # something was dropped
    assert np.isfinite(y_small).all()


def test_shared_experts_add():
    cfg = _cfg(n_shared_experts=2)
    p = init_moe(KEY, cfg)
    x = jax.random.normal(jax.random.key(4), (1, 3, cfg.d_model))
    y = np.asarray(moe_forward(p, x, cfg))
    y_shared = np.asarray(apply_mlp(p["shared"], x, cfg))
    no_shared = dict(p)
    del no_shared["shared"]
    y_routed = np.asarray(moe_forward(no_shared, x, cfg))
    np.testing.assert_allclose(y, y_routed + y_shared, atol=1e-5, rtol=1e-5)


@pytest.mark.slow
def test_moe_grad_finite():
    cfg = _cfg(moe_dropless=False)
    p = init_moe(KEY, cfg)
    x = jax.random.normal(jax.random.key(5), (2, 8, cfg.d_model))
    g = jax.grad(lambda pp: jnp.sum(moe_forward(pp, x, cfg) ** 2))(p)
    assert all(bool(jnp.all(jnp.isfinite(l))) for l in jax.tree.leaves(g))


def test_sorted_dispatch_matches_dense():
    """L4: sort-based dispatch == dense one-hot dispatch (dropless)."""
    cfg = _cfg(moe_dropless=True)
    p = init_moe(KEY, cfg)
    x = jax.random.normal(jax.random.key(9), (2, 9, cfg.d_model))
    yd = moe_forward(p, x, cfg)
    ys = moe_forward(p, x, dataclasses.replace(cfg, moe_impl="sorted"))
    np.testing.assert_allclose(np.asarray(ys), np.asarray(yd), atol=1e-5)


def test_sorted_dispatch_capacity_drops():
    """Sorted dispatch drops overflow tokens exactly at capacity."""
    cfg = _cfg(moe_dropless=False, capacity_factor=0.1, moe_impl="sorted")
    p = init_moe(KEY, cfg)
    x = jax.random.normal(jax.random.key(10), (2, 16, cfg.d_model))
    y = np.asarray(moe_forward(p, x, cfg))
    assert np.isfinite(y).all()
    y_full = np.asarray(moe_forward(
        p, x, dataclasses.replace(cfg, moe_dropless=True)))
    assert np.abs(y - y_full).max() > 1e-6
