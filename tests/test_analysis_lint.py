"""AST lint rule tests (repro.analysis.lint): each rule catches its seeded
mutation, each exemption holds, and the live repo is clean."""
import textwrap

from repro.analysis.lint import lint_repo, lint_source


def _lint(src, path="src/repro/serve/example.py"):
    return lint_source(textwrap.dedent(src), path)


# -- L001 interpret-hardcoded ------------------------------------------------


def test_hardcoded_interpret_true_is_caught():
    findings = _lint("""
        import jax.experimental.pallas as pl
        out = pl.pallas_call(kernel, out_shape=shape, interpret=True)(x)
    """, path="src/repro/kernels/foo.py")
    assert any(f.pass_name == "lint/interpret-hardcoded" for f in findings)


def test_platform_derived_interpret_is_fine():
    findings = _lint("""
        out = pl.pallas_call(kernel, out_shape=shape,
                             interpret=jax.default_backend() != "tpu")(x)
    """, path="src/repro/kernels/foo.py")
    assert findings == []


def test_tests_may_pin_interpret():
    """Kernel-vs-oracle unit tests pin interpret=True on purpose."""
    findings = _lint(
        "out = pl.pallas_call(kernel, interpret=True)(x)\n",
        path="tests/test_kernels.py",
    )
    assert findings == []


# -- L002 raw-clock ----------------------------------------------------------


def test_time_time_in_scheduler_is_caught():
    findings = _lint("""
        import time
        t0 = time.time()
    """, path="src/repro/serve/scheduler.py")
    assert any(f.pass_name == "lint/raw-clock" for f in findings)


def test_perf_counter_is_fine():
    findings = _lint("""
        import time
        t0 = time.perf_counter()
    """, path="src/repro/obs/trace.py")
    assert findings == []


def test_time_time_outside_obs_scope_is_not_flagged():
    findings = _lint("""
        import time
        stamp = time.time()
    """, path="benchmarks/stamp.py")
    assert findings == []


# -- L003 metrics-bypass -----------------------------------------------------


def test_counter_total_assignment_is_caught():
    findings = _lint("self._c_steps.total = 0\n")
    assert any(f.pass_name == "lint/metrics-bypass" for f in findings)


def test_counter_total_augassign_is_caught():
    findings = _lint("self._c_steps.total += 1\n")
    assert any(f.pass_name == "lint/metrics-bypass" for f in findings)


def test_registry_mutators_are_fine():
    findings = _lint("""
        self._c_steps.inc()
        self._g_lanes.set(3)
        self._h_ttft.observe(0.5)
    """)
    assert findings == []


# -- L004 bench-writer -------------------------------------------------------


def test_raw_bench_json_writer_is_caught():
    findings = _lint(
        'f = open("artifacts/BENCH_energy.json", "w")\n',
        path="benchmarks/energy_report.py",
    )
    assert any(f.pass_name == "lint/bench-writer" for f in findings)


def test_fstring_bench_writer_is_caught():
    findings = _lint(
        'f = open(f"{outdir}/BENCH_{name}.json", mode="w")\n',
        path="benchmarks/energy_report.py",
    )
    assert any(f.pass_name == "lint/bench-writer" for f in findings)


def test_bench_json_read_is_fine():
    findings = _lint(
        'payload = open("artifacts/BENCH_energy.json", "r").read()\n',
        path="benchmarks/run.py",
    )
    assert findings == []


def test_stamp_module_is_exempt():
    findings = _lint(
        'f = open("artifacts/BENCH_energy.json", "w")\n',
        path="benchmarks/stamp.py",
    )
    assert findings == []


# -- parse failures and the live tree ---------------------------------------


def test_syntax_error_is_a_finding_not_a_crash():
    findings = _lint("def broken(:\n")
    assert len(findings) == 1 and findings[0].pass_name == "lint/parse"


def test_live_repo_is_lint_clean():
    findings = lint_repo()
    assert findings == [], "\n".join(f.format() for f in findings)
