"""Unified Distributed-Arithmetic execution engine (backend registry + dispatch).

The paper proves one identity — ``Y = X @ W`` computed multiplier-free via
precomputed weight-sum LUTs and bit-serial shift-and-add (§II–III) — and this
repo carries several equivalent executions of it: the faithful LUT gather, the
one-hot MXU readout, the storage-free bit-plane forms, and the Pallas TPU
kernels.  This module puts all of them behind ONE entry point::

    y = da_matmul(x, packed, mode="auto")          # float in → float out
    acc = da_vmm(xq, packed, mode="bitplane")      # integer codes → int32

with three pieces of machinery:

**1. The backend registry.**  Every execution mode is a :class:`BackendSpec`
registered under a canonical name with a *capability spec*: does it need
materialized LUTs?  What group sizes can it address?  Does it run on the int8
MXU path?  Does it handle K that is not a multiple of the group size (the
padding rule)?  ``registered_backends()`` is the single source of truth the
differential test suite sweeps, so a new backend is verified the moment it is
registered.

===================  =========  ======================================
name                 needs LUTs  execution
===================  =========  ======================================
``lut``              yes        faithful PMA readout: gather + shift-add
``onehot``           yes        one-hot(addr) @ LUT on the MXU
``pallas_lut``       yes        Pallas kernel (in-VMEM LUT readout)
``bitplane``         no         Σ_b 2^b · (xbit_b @ W), serial cycles
``bitplane_stacked`` no         bit-planes stacked on M: ONE int8 matmul
``pallas_bitplane``  no         Pallas kernel (bit-plane streaming)
``int8``             no         int8×int8 reference matmul (baseline,
                                not multiplier-free — never auto-picked)
===================  =========  ======================================

**2. The ``"auto"`` policy.**  ``mode="auto"`` picks the backend from the
``(M, K, N, x_bits)`` shape: shapes are folded into coarse buckets
(:func:`shape_bucket`), and a measured cost table — produced by
``benchmarks/engine_autotune.py``, which times every backend per bucket and
writes a JSON cache — maps each bucket to per-backend µs.  The cheapest
*eligible* backend wins (LUT modes are only eligible when the packed weights
carry LUTs; the ``int8`` baseline is never auto-picked because it is not
multiplier-free).  Without a cache the engine falls back to a deterministic
heuristic: decode-like shapes (M ≤ 8) with LUTs available read the PMAs
(``lut``); everything else runs the one-matmul ``bitplane_stacked`` form.
Regenerate the cache with::

    PYTHONPATH=src python benchmarks/engine_autotune.py        # full
    PYTHONPATH=src python benchmarks/engine_autotune.py --quick

The cache lives at ``artifacts/engine_autotune.json`` (override with the
``REPRO_ENGINE_AUTOTUNE`` env var) and is loaded lazily on first dispatch.

**3. ``PackedWeights``.**  The single frozen-weight artifact: int8 codes +
per-column scale + optional LUTs, built ONCE by :func:`pack_weights` (the
paper's pre-VMM step, §III-A) and shared by every backend.  It is a pytree
(leaf names ``wq`` / ``w_scale`` / ``luts`` — stable for sharding rules), it
is callable (``packed(x)`` runs the engine), and MoE-style stacked experts
``[E, K, N]`` vmap through it unchanged.

This module is the *per-matrix* engine.  The **model-level** entry — walk a
params pytree, plan a backend/group-size/LUT decision per layer from measured
+ analytic costs, pack every weight matrix, and serialize the result as a
servable on-disk artifact — is :mod:`repro.core.freeze` (plan → pack →
serialize → shard → serve).
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import os
import pathlib
import warnings
import zlib
from functools import partial
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.da import (
    DAConfig,
    build_luts,
    da_vmm_bitplane,
    da_vmm_bitplane_stacked,
    da_vmm_lut,
    da_vmm_onehot,
    num_groups,
    truncate_codes,
)
from repro.core.quant import QTensor, quantize_acts_signed, quantize_weights

# ---------------------------------------------------------------------------
# PackedWeights — the one frozen-weight artifact every backend reads
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PackedWeights:
    """Frozen DA linear weights: the PMA contents for one weight matrix.

    wq:      [K, N] (or stacked experts [E, K, N]) integer codes, int8 storage.
    w_scale: [1, N] (or [E, 1, N]) per-output-column float32 scale.
    luts:    [G, 2^L, N] weight-sum tables from build_luts, or None.
    cfg:     DAConfig the artifact was packed under (group_size, x_bits).
    mode:    default execution mode for ``packed(x)`` ("auto" → dispatch).
    """

    wq: jax.Array
    w_scale: jax.Array
    luts: Optional[jax.Array]
    cfg: DAConfig
    mode: str = "auto"

    @property
    def k(self) -> int:
        return self.wq.shape[-2]

    @property
    def n(self) -> int:
        return self.wq.shape[-1]

    @property
    def has_luts(self) -> bool:
        return self.luts is not None

    def __call__(self, x: jax.Array) -> jax.Array:
        return da_matmul(x, self)  # mode=None → this artifact's default


jax.tree_util.register_pytree_with_keys(
    PackedWeights,
    lambda t: (
        (("wq", t.wq), ("w_scale", t.w_scale), ("luts", t.luts)),
        (t.cfg, t.mode),
    ),
    lambda aux, ch: PackedWeights(
        wq=ch[0], w_scale=ch[1], luts=ch[2], cfg=aux[0], mode=aux[1]
    ),
)


def lut_cells(k: int, n: int, group_size: int) -> int:
    """Memory cells a materialized LUT costs (the 2^L/L× blow-up, Table I)."""
    return num_groups(k, group_size) * (1 << group_size) * n


def path_entry_name(entry) -> str:
    """Canonical string for one pytree path entry (DictKey / GetAttrKey /
    SequenceKey / raw str key).  The single implementation shared by
    checkpoint keys, freeze plan keys and the sharding rules — serialized
    key paths must never drift between writers and readers."""
    for attr in ("key", "name", "idx"):
        if hasattr(entry, attr):
            return str(getattr(entry, attr))
    return str(entry)


#: Default LUT budget in cells per matrix, shared by the serving freeze
#: (pack_weights / freeze_da / freeze_model_da) AND the autotune benchmark —
#: one constant so "which layers carry LUTs" and "which buckets time LUT
#: backends" can't drift apart.
DEFAULT_LUT_LIMIT = 1 << 24


def pack_weights(
    w: jax.Array,
    cfg: DAConfig = DAConfig(x_signed=True),
    mode: str = "auto",
    lut_cell_limit: int = DEFAULT_LUT_LIMIT,
    with_luts: Optional[bool] = None,
) -> PackedWeights:
    """Pre-VMM procedure (§III-A): quantize once, sum weights, 'write the PMAs'.

    Accepts 2-D float weights [K, N] or batched experts [E, K, N].  LUTs are
    built exactly once, here, and shared by every LUT-reading backend:
    when ``mode`` names a LUT backend, or under ``mode="auto"`` whenever the
    blow-up stays within ``lut_cell_limit``.

    NOTE ``lut_cell_limit`` is measured in LUT **cells** per matrix (the paper's
    2^L/L× blow-up: ``lut_cells(k, n, group_size)``), not in weights — the
    seed's ``freeze_da`` bounded weight count instead; at group_size 8 one
    weight costs 32 cells, so the default 2^24 cells ≈ 64 MB of int32 LUTs
    admits layers up to ~512K weights.

    ``with_luts`` (when not None) overrides the LUT decision outright — the
    model-level planner (:mod:`repro.core.freeze`) decides lut-or-not per
    layer and passes its verdict down here.
    """
    mode = canonical_mode(mode)
    wq: QTensor = quantize_weights(w, bits=8, axis=w.ndim - 2)
    k, n = w.shape[-2], w.shape[-1]
    if with_luts is None:
        if mode == "auto":
            with_luts = lut_cells(k, n, cfg.group_size) <= lut_cell_limit
        else:
            with_luts = get_backend(mode).needs_luts
    luts = None
    if with_luts:
        build = partial(build_luts, group_size=cfg.group_size)
        for _ in range(w.ndim - 2):
            build = jax.vmap(build, in_axes=(0,), out_axes=0)
        luts = build(wq.q)
    # int8 storage: the codes are the deployable artifact (4× smaller reads)
    return PackedWeights(
        wq=wq.q.astype(jnp.int8), w_scale=wq.scale, luts=luts, cfg=cfg,
        mode=mode,
    )


def pack_quantized(
    wq: jax.Array,
    w_scale=1.0,
    cfg: DAConfig = DAConfig(),
    mode: str = "auto",
    with_luts: bool = True,
) -> PackedWeights:
    """Wrap already-integer weight codes [K, N] as a PackedWeights artifact."""
    mode = canonical_mode(mode)
    wq = jnp.asarray(wq)
    luts = build_luts(wq.astype(jnp.int32), cfg.group_size) if with_luts else None
    return PackedWeights(
        wq=wq, w_scale=jnp.asarray(w_scale, dtype=jnp.float32), luts=luts,
        cfg=cfg, mode=mode,
    )


# ---------------------------------------------------------------------------
# Backend registry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BackendSpec:
    """Capability spec + implementation of one DA execution mode.

    fn:             (xq int32 [M,K], packed, cfg) → int32 [M,N] == xq @ wq.
    needs_luts:     reads materialized weight-sum LUTs from the artifact.
    is_da:          multiplier-free DA datapath (auto-dispatch only considers
                    these; baselines like int8 must be requested explicitly).
    int8_path:      contracts on the int8 MXU path (operands must fit int8).
    signed_only:    requires two's-complement activation codes.
    max_group_size: LUT addressability bound (2^L rows per PMA).
    pads_k:         handles K not a multiple of group_size by zero-padding.
    """

    name: str
    fn: Callable[[jax.Array, PackedWeights, DAConfig], jax.Array]
    description: str = ""
    needs_luts: bool = False
    is_da: bool = True
    #: Advisory, not an eligibility gate: the backend contracts on the int8
    #: MXU path (weight codes must fit int8 — guaranteed by the 8-bit
    #: quantizer). Drives TPU tiling choices and is recorded for autotuning.
    int8_path: bool = False
    signed_only: bool = False
    max_group_size: int = 16
    pads_k: bool = True

    def supports(self, cfg: DAConfig, has_luts: bool,
                 k: Optional[int] = None) -> bool:
        """Is this backend eligible for an artifact packed under ``cfg``?

        ``k`` (the contraction dim) is checked against the padding rule when
        known: a backend with ``pads_k=False`` only takes K that is a
        multiple of the group size."""
        if self.needs_luts and not has_luts:
            return False
        if self.signed_only and not cfg.x_signed:
            return False
        if cfg.group_size > self.max_group_size:
            return False
        if (k is not None and not self.pads_k
                and k % cfg.group_size != 0):
            return False
        return True


_REGISTRY: Dict[str, BackendSpec] = {}

#: Legacy / call-site mode spellings → canonical registry names.
MODE_ALIASES = {
    "da_lut": "lut",
    "da_onehot": "onehot",
    "da_bitplane": "bitplane",
    "da_bitplane_stacked": "bitplane_stacked",
    "stacked": "bitplane_stacked",
    "pallas": "pallas_lut",
}


def canonical_mode(mode: str) -> str:
    return MODE_ALIASES.get(mode, mode)


def register_backend(name: str, **caps):
    """Decorator: register ``fn(xq, packed, cfg) → int32`` under ``name``."""

    def deco(fn):
        if name in _REGISTRY:
            raise ValueError(f"backend {name!r} already registered")
        _REGISTRY[name] = BackendSpec(name=name, fn=fn, **caps)
        return fn

    return deco


def registered_backends() -> Dict[str, BackendSpec]:
    """Name → spec of every registered backend (the differential-test sweep)."""
    return dict(_REGISTRY)


#: Bump when a backend's *implementation* changes performance-relevantly
#: without a rename — invalidates every autotune cache.
REGISTRY_VERSION = 1


def registry_fingerprint() -> str:
    """Fingerprint of the backend registry (sorted names + version).

    Stamped into ``artifacts/engine_autotune.json`` by the autotune benchmark;
    a cache whose fingerprint disagrees was tuned against a different backend
    set (renamed / added / removed) and its numbers can't be trusted to rank
    today's registry — the loader warns and falls back to the heuristic
    instead of raising ``KeyError`` at dispatch time.
    """
    blob = f"v{REGISTRY_VERSION}:" + ",".join(sorted(_REGISTRY))
    return f"{zlib.crc32(blob.encode()):08x}"


def get_backend(mode: str) -> BackendSpec:
    name = canonical_mode(mode)
    if name not in _REGISTRY:
        raise ValueError(
            f"unknown DA mode {mode!r}; registered backends: "
            f"{', '.join(sorted(_REGISTRY))} (plus 'auto' for shape-based "
            f"dispatch)"
        )
    return _REGISTRY[name]


@register_backend(
    "lut", needs_luts=True,
    description="faithful PMA readout: LUT gather + bit-serial shift-and-add",
)
def _lut_backend(xq, packed, cfg):
    return da_vmm_lut(xq, packed.luts, cfg)


@register_backend(
    "onehot", needs_luts=True,
    description="address decoder as one-hot; LUT readout on the MXU",
)
def _onehot_backend(xq, packed, cfg):
    return da_vmm_onehot(xq, packed.luts, cfg)


@register_backend(
    "pallas_lut", needs_luts=True,
    description="Pallas TPU kernel: in-VMEM LUT readout (interpret on CPU)",
)
def _pallas_lut_backend(xq, packed, cfg):
    from repro.kernels.ops import da_vmm as _kernel_da_vmm

    return _kernel_da_vmm(xq, packed.luts, cfg, backend="pallas")


@register_backend(
    "bitplane",
    description="storage-free serial DA: Σ_b 2^b · (xbit_b @ W)",
)
def _bitplane_backend(xq, packed, cfg):
    return da_vmm_bitplane(xq, packed.wq.astype(jnp.int32), cfg)


@register_backend(
    "bitplane_stacked", int8_path=True,
    description="bit-planes stacked on M: one int8 matmul, W read once",
)
def _stacked_backend(xq, packed, cfg):
    return da_vmm_bitplane_stacked(xq, packed.wq, cfg)


@register_backend(
    "pallas_bitplane",
    description="Pallas TPU kernel: bit-plane streaming (interpret on CPU)",
)
def _pallas_bitplane_backend(xq, packed, cfg):
    from repro.kernels.ops import bitplane_vmm as _kernel_bitplane_vmm

    # int8 storage passes through uncast: the kernel sizes its fp32-exact K
    # tile from the storage dtype (a pre-cast int32 tracer would hide it).
    return _kernel_bitplane_vmm(xq, packed.wq, cfg, backend="pallas")


@register_backend(
    "int8", is_da=False, int8_path=True, signed_only=True,
    description="int8×int8 reference matmul (quantization baseline, not DA)",
)
def _int8_backend(xq, packed, cfg):
    return jnp.matmul(
        xq.astype(jnp.int8), packed.wq.astype(jnp.int8),
        preferred_element_type=jnp.int32,
    )


def timeable_backends(cfg: DAConfig, has_luts: bool,
                      include_baselines: bool = False):
    """Backends worth timing on this host (shared by engine_autotune and
    kernel_micro so their eligibility rules cannot drift): capability-
    eligible, DA-only unless baselines are requested, and skipping the
    Pallas kernels off-TPU, where interpret mode is a correctness tool
    rather than a timing."""
    on_tpu = jax.default_backend() == "tpu"
    for name, spec in sorted(_REGISTRY.items()):
        if not spec.supports(cfg, has_luts):
            continue
        if not (spec.is_da or include_baselines):
            continue
        if name.startswith("pallas") and not on_tpu:
            continue
        yield spec


def jit_backend(spec: BackendSpec, cfg: DAConfig):
    """jit-compiled ``fn(xq, packed)`` for one backend.  ``packed`` is a jit
    *argument*: closing over it would bake the (possibly multi-GB) LUT array
    into the compiled executable."""
    return jax.jit(lambda xq, p, _f=spec.fn: _f(xq, p, cfg))


# ---------------------------------------------------------------------------
# Shape buckets + measured cost table (the "auto" policy)
# ---------------------------------------------------------------------------

_M_EDGES: Tuple[Tuple[int, str], ...] = ((8, "dec"), (256, "mid"))
_KN_EDGES: Tuple[Tuple[int, str], ...] = ((1 << 14, "s"), (1 << 20, "m"))

#: One representative (M, K, N) per (m-bucket, kn-bucket) cell, shared by the
#: autotune benchmark (what it times) and the dispatch tests (what they probe).
BUCKET_SHAPES: Dict[str, Tuple[int, int, int]] = {
    "dec:s": (4, 64, 128),
    "dec:m": (4, 512, 1024),
    "dec:l": (4, 2048, 2048),
    "mid:s": (64, 64, 128),
    "mid:m": (64, 512, 1024),
    "mid:l": (64, 2048, 2048),
    "big:s": (512, 64, 128),
    "big:m": (512, 512, 1024),
    "big:l": (512, 2048, 2048),
}


def shape_bucket(m: int, k: int, n: int, x_bits: int) -> str:
    """Fold (M, K, N, x_bits) into a coarse cost-table key.

    M buckets: decode-like (≤8) / mid (≤256) / big.  K·N buckets: small
    (≤2^14) / mid (≤2^20) / large.  x_bits is kept exact (4-bit inputs halve
    the bit-serial cycle count, which shifts the backend ranking).
    """
    mb = next((tag for edge, tag in _M_EDGES if m <= edge), "big")
    kb = next((tag for edge, tag in _KN_EDGES if k * n <= edge), "l")
    return f"{mb}:{kb}:b{x_bits}"


def default_cache_path() -> pathlib.Path:
    env = os.environ.get("REPRO_ENGINE_AUTOTUNE")
    if env:
        return pathlib.Path(env)
    return (
        pathlib.Path(__file__).resolve().parents[3]
        / "artifacts" / "engine_autotune.json"
    )


_COST_TABLE: Optional[Dict[str, Dict[str, float]]] = None  # None → not loaded


def load_cost_table(path: Optional[os.PathLike] = None) -> Dict[str, Dict[str, float]]:
    """Lazily load the autotune cache: {bucket: {backend: µs}}.

    Missing or unreadable caches degrade to the heuristic fallback — the
    engine never *requires* autotuning to run.  A cache recording a
    ``device`` other than the current ``jax.default_backend()`` is rejected
    (a TPU-tuned table would steer CPU dispatch into interpret-mode Pallas
    kernels, and vice versa); buckets are tuned at one ``group_size`` (the
    ranking of the storage-free backends is group-independent, and LUT
    eligibility is re-checked per artifact at dispatch time).

    Only default-path loads populate the process-wide table that ``auto``
    dispatch reads; loading an explicit ``path`` is read-only (use
    :func:`set_cost_table` to install such a table deliberately).
    """
    global _COST_TABLE
    if _COST_TABLE is not None and path is None:
        return _COST_TABLE
    p = pathlib.Path(path) if path is not None else default_cache_path()
    table: Dict[str, Dict[str, float]] = {}
    unknown: set = set()
    try:
        raw = json.loads(p.read_text())
        entries = raw.get("table", raw)
        device = raw.get("device") if isinstance(raw, dict) else None
        if device is not None and device != jax.default_backend():
            entries = {}  # tuned on different hardware: fall back to heuristic
        stamp = raw.get("registry") if isinstance(raw, dict) else None
        if stamp is not None and stamp != registry_fingerprint():
            warnings.warn(
                f"autotune cache {p} was tuned against a different backend "
                f"registry (stamp {stamp!r} != {registry_fingerprint()!r}); "
                "ignoring it — re-run benchmarks/engine_autotune.py",
                stacklevel=2,
            )
            entries = {}
        for bucket, costs in entries.items():
            if isinstance(costs, dict):
                # "attn:*" buckets rank attention-read backends, the rest
                # rank DA VMM backends — each filtered against its registry.
                reg = _ATTN_REGISTRY if bucket.startswith("attn:") else _REGISTRY
                unknown.update(b for b in costs if b not in reg)
                table[bucket] = {
                    b: float(us) for b, us in costs.items()
                    if b in reg and isinstance(us, (int, float))
                }
        if unknown:
            warnings.warn(
                f"autotune cache {p} names unregistered backends "
                f"{sorted(unknown)}; their timings are dropped (heuristic "
                "fallback where no eligible backend was timed)",
                stacklevel=2,
            )
    except (OSError, ValueError, AttributeError):
        table = {}
    if path is None:
        _COST_TABLE = table
    return table


def set_cost_table(table: Optional[Dict[str, Dict[str, float]]]) -> None:
    """Install a cost table in-process (tests / autotune); None → reload."""
    global _COST_TABLE
    _COST_TABLE = dict(table) if table is not None else None
    _BUCKET_MISS_WARNED.clear()  # a new table resets the warn-once dedup


#: (bucket, fallback backend) pairs already warned about — the bucket-miss
#: diagnostic fires once per pair per process, not once per da_matmul call
#: (a decode loop hits the same bucket thousands of times per second).
_BUCKET_MISS_WARNED: set = set()


def select_backend(
    m: int, k: int, n: int, cfg: DAConfig, has_luts: bool = True
) -> str:
    """The ``"auto"`` policy: cheapest measured eligible DA backend, else the
    deterministic heuristic.  Always returns a registered, eligible name."""
    eligible = [
        s for s in _REGISTRY.values()
        if s.is_da and s.supports(cfg, has_luts, k=k)
    ]
    if not eligible:  # unreachable with the built-in backends, but be loud
        raise ValueError(
            f"no DA backend supports cfg={cfg} has_luts={has_luts}"
        )
    table = load_cost_table()
    bucket = shape_bucket(m, k, n, cfg.x_bits)
    costs = table.get(bucket, {})
    timed = [s for s in eligible if s.name in costs]
    if timed:
        return min(timed, key=lambda s: costs[s.name]).name
    choice = _fallback_backend(m, cfg, has_luts, eligible)
    if table and (bucket, choice) not in _BUCKET_MISS_WARNED:
        # an autotune cache exists but never timed this bucket's eligible
        # backends: dispatch is running on the heuristic, which is worth one
        # loud diagnostic — not one per call
        _BUCKET_MISS_WARNED.add((bucket, choice))
        warnings.warn(
            f"autotune cache has no timings for bucket {bucket!r} (eligible: "
            f"{', '.join(sorted(s.name for s in eligible))}); using the "
            f"heuristic fallback {choice!r} — re-run "
            "benchmarks/engine_autotune.py to tune it (warned once per "
            "bucket/backend)",
            stacklevel=2,
        )
    return choice


def _fallback_backend(m, cfg, has_luts, eligible) -> str:
    """No measurements: decode-like reads the PMAs, everything else runs the
    one-matmul stacked bit-plane form (W read once — the TPU-shaped mapping)."""
    names = {s.name for s in eligible}
    if has_luts and m <= 8 and "lut" in names:
        return "lut"
    if "bitplane_stacked" in names:
        return "bitplane_stacked"
    return sorted(names)[0]


# ---------------------------------------------------------------------------
# Execution entry points
# ---------------------------------------------------------------------------

#: Process-wide draft precision (see :func:`x_bits_override`); None → full.
_X_BITS_EFF: Optional[int] = None


@contextlib.contextmanager
def x_bits_override(x_bits_eff: Optional[int]):
    """Trace-time partial-precision context (the DA-native draft pass).

    Inside this context every :func:`da_matmul` / :func:`da_vmm` call that
    does not pass an explicit ``x_bits_eff`` evaluates only the top
    ``x_bits_eff`` bit-planes of its activations against the *same* packed
    weights — no second model, no extra weight memory (see
    :func:`repro.core.da.truncate_codes`).  The override is read at **trace
    time**: wrap the function body you hand to ``jax.jit`` (a distinct
    callable per precision), not the call of an already-compiled function.
    ``None`` restores full precision.  This is what the speculative-decoding
    subsystem's truncated-bitplane self-draft provider uses to run a whole
    model forward at draft precision without threading a parameter through
    every layer.
    """
    global _X_BITS_EFF
    prev = _X_BITS_EFF
    _X_BITS_EFF = x_bits_eff
    try:
        yield
    finally:
        _X_BITS_EFF = prev


def effective_x_bits(cfg: DAConfig, x_bits_eff: Optional[int]) -> int:
    """Resolve a call-site ``x_bits_eff`` against the override context and
    the packed config; validates the range."""
    eff = x_bits_eff if x_bits_eff is not None else _X_BITS_EFF
    if eff is None:
        return cfg.x_bits
    eff = min(int(eff), cfg.x_bits)
    if eff < 1:
        raise ValueError(f"x_bits_eff={eff} must be >= 1")
    return eff


def _resolve_spec(
    mode: Optional[str], m: int, k: int, n: int, cfg: DAConfig, has_luts: bool,
    default_mode: str,
) -> BackendSpec:
    """Resolve a call-site mode to a backend spec, enforcing capabilities.

    ``None`` defers to the artifact's packed default; ``"auto"`` always runs
    shape-based dispatch (even on artifacts packed with a concrete mode).
    Explicit modes are checked against the backend's capability spec so a
    mismatch errors instead of silently computing wrong integers (e.g. the
    int8 baseline fed unsigned 8-bit codes would wrap at 128).
    """
    mode = canonical_mode(default_mode if mode is None else mode)
    if mode == "auto":
        return _REGISTRY[select_backend(m, k, n, cfg, has_luts)]
    spec = get_backend(mode)
    if not spec.supports(cfg, has_luts, k=k):
        why = (
            "reads materialized LUTs but the PackedWeights artifact has none"
            " — pack with a LUT mode or raise lut_cell_limit"
            if spec.needs_luts and not has_luts
            else "requires two's-complement (signed) activation codes"
            if spec.signed_only and not cfg.x_signed
            else f"supports group_size ≤ {spec.max_group_size}, got "
            f"{cfg.group_size}"
            if cfg.group_size > spec.max_group_size
            else f"does not pad K: {k} is not a multiple of group_size "
            f"{cfg.group_size}"
        )
        raise ValueError(f"backend {mode!r} {why}")
    return spec


def _check_lut_shape(spec: BackendSpec, packed: PackedWeights,
                     cfg: DAConfig) -> None:
    """A cfg override whose group_size disagrees with the packed LUTs would
    silently gather wrong rows (addresses clamp/broadcast) — error instead."""
    if spec.needs_luts and packed.luts.shape[-2] != (1 << cfg.group_size):
        raise ValueError(
            f"backend {spec.name!r}: LUTs were packed with "
            f"{packed.luts.shape[-2]} rows per PMA but cfg.group_size="
            f"{cfg.group_size} addresses {1 << cfg.group_size} — repack the "
            f"weights or use the packed cfg"
        )


def da_vmm(
    xq: jax.Array, packed: PackedWeights, mode: Optional[str] = None,
    cfg: Optional[DAConfig] = None, x_bits_eff: Optional[int] = None,
) -> jax.Array:
    """Integer-level engine entry: int codes [.., K] → int32 [.., N] == xq @ wq.

    ``mode``: None → the artifact's packed default; ``"auto"`` → shape-based
    dispatch; otherwise a registered backend name (capability-checked).
    ``cfg`` overrides the packed config (e.g. to flip x_signed for unsigned
    image inputs); group_size must match the packed LUTs.

    ``x_bits_eff < cfg.x_bits`` evaluates only the top bit-planes (fewer
    bit-serial cycles against the same artifact — the draft pass); defaults
    to the :func:`x_bits_override` context, else full precision.
    """
    cfg = cfg if cfg is not None else packed.cfg
    eff = effective_x_bits(cfg, x_bits_eff)
    ecfg = dataclasses.replace(cfg, x_bits=eff)
    m = 1
    for d in xq.shape[:-1]:
        m *= int(d)
    spec = _resolve_spec(mode, m, packed.k, packed.n, ecfg, packed.has_luts,
                         default_mode=packed.mode)
    _check_lut_shape(spec, packed, ecfg)
    lead = xq.shape[:-1]
    x2 = xq.reshape(-1, xq.shape[-1]).astype(jnp.int32)
    x2, rcfg, drop = truncate_codes(x2, cfg, eff)
    acc = spec.fn(x2, packed, rcfg)
    if drop:
        acc = acc * (1 << drop)
    return acc.reshape(lead + (packed.n,))


@partial(jax.jit, static_argnames=("cfg", "backend", "x_bits_eff"))
def _da_matmul_jit(x2, packed, cfg, backend, x_bits_eff):
    # named_scope stamps the backend into the HLO metadata, so an XLA
    # profiler capture attributes device time to the DA backend that spent it
    with jax.named_scope(f"da_{backend}"):
        xqt = quantize_acts_signed(x2, bits=cfg.x_bits)
        xq, rcfg, drop = truncate_codes(xqt.q, cfg, x_bits_eff)
        acc = _REGISTRY[backend].fn(xq, packed, rcfg)
        if drop:
            acc = acc * (1 << drop)
        return acc.astype(jnp.float32) * xqt.scale * packed.w_scale


def da_matmul(
    x: jax.Array,
    weights: PackedWeights,
    cfg: Optional[DAConfig] = None,
    mode: Optional[str] = None,
    x_bits_eff: Optional[int] = None,
) -> jax.Array:
    """Float-level engine entry: quantize → DA integer VMM → dequantize.

    x: [.., K] float; weights: a PackedWeights artifact.  ``mode``: None →
    the artifact's packed default; ``"auto"`` → shape-based dispatch (always,
    even on artifacts packed with a concrete mode); otherwise a registered
    backend name or legacy alias (capability-checked).  Activations are
    dynamically quantized to signed ``x_bits``.

    ``x_bits_eff < cfg.x_bits`` truncates the quantized codes to their top
    bit-planes before the integer VMM (same scale, same weights, fewer DA
    cycles) — the truncated-bitplane draft pass.  Defaults to the
    :func:`x_bits_override` context, else full precision.
    """
    cfg = cfg if cfg is not None else weights.cfg
    scfg = dataclasses.replace(cfg, x_signed=True)
    eff = effective_x_bits(scfg, x_bits_eff)
    lead = x.shape[:-1]
    k = x.shape[-1]
    m = 1
    for d in lead:
        m *= int(d)
    rcfg = dataclasses.replace(scfg, x_bits=eff)  # dispatch sees draft cycles
    spec = _resolve_spec(mode, m, weights.k, weights.n, rcfg,
                         weights.has_luts, default_mode=weights.mode)
    _check_lut_shape(spec, weights, rcfg)
    x2 = x.reshape(-1, k).astype(jnp.float32)
    y = _da_matmul_jit(x2, weights, scfg, spec.name, eff)
    return y.reshape(lead + (weights.n,))


def dense(x: jax.Array, w) -> jax.Array:
    """Weight application dispatching on the leaf type: a plain array is a
    float matmul (training); a PackedWeights artifact runs the paper's
    multiplier-free datapath through the engine (serving).  MoE-style stacked
    experts ([E, K, N] against [E, C, K]) vmap the whole artifact per expert —
    codes, scales and LUTs alike (None LUTs contribute no leaves)."""
    if isinstance(w, PackedWeights):
        if w.wq.ndim == 3:  # per-expert PMAs
            if x.ndim == 4:  # grouped MoE activations [G, E, C, D]
                return jax.vmap(lambda xg: dense(xg, w))(x)
            assert x.ndim == 3, x.shape
            return jax.vmap(lambda xe, we: we(xe))(x, w).astype(x.dtype)
        return w(x).astype(x.dtype)
    if w.ndim == 3 and x.ndim == 4:
        return jnp.einsum("gecd,edf->gecf", x, w)
    if w.ndim == 3 and x.ndim == 3:
        return jnp.einsum("ecd,edf->ecf", x, w)
    return x @ w


# ---------------------------------------------------------------------------
# Paged-attention read backends — the decode-attention analogue of the DA
# registry.  The paged runtime has two interchangeable executions of the same
# attention read over the page pool; dispatch picks per shape bucket.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AttnBackendSpec:
    """One execution of the paged-attention read.

    fn: ``(q [B,T,H,hd], k_pool, v_pool [P,ps,kv,hd], page_table [B,W],
    tpos [B,T], *, softmax_dtype, mask_mode, k_scale=None, v_scale=None) →
    [B,T,H,hd]`` — the attention context over an already-written pool,
    ragged-masked by ``tpos``.  Quantized pools (int8 codes / packed int4)
    pass their in-page dequant scales ``[P, ps, kv, 1]``; both backends
    dequantize with the same elementwise formula (``kv_quant``), so decoded
    tokens stay bit-identical across backends on quantized pages too.
    """

    name: str
    fn: Callable[..., jax.Array]
    description: str = ""


_ATTN_REGISTRY: Dict[str, AttnBackendSpec] = {}


def register_attn_backend(name: str, **caps):
    """Decorator: register a paged-attention read under ``name``."""

    def deco(fn):
        if name in _ATTN_REGISTRY:
            raise ValueError(f"attention backend {name!r} already registered")
        _ATTN_REGISTRY[name] = AttnBackendSpec(name=name, fn=fn, **caps)
        return fn

    return deco


def registered_attn_backends() -> Dict[str, AttnBackendSpec]:
    """Name → spec of every paged-attention read (differential-test sweep)."""
    return dict(_ATTN_REGISTRY)


def get_attn_backend(mode: str) -> AttnBackendSpec:
    if mode not in _ATTN_REGISTRY:
        raise ValueError(
            f"unknown paged-attention backend {mode!r}; registered: "
            f"{', '.join(sorted(_ATTN_REGISTRY))} (plus 'auto' for "
            "cost-table / platform dispatch)"
        )
    return _ATTN_REGISTRY[mode]


def attn_shape_bucket(batch: int, t: int, kv_len: int) -> str:
    """Fold a paged-attention call shape into a coarse cost-table key.

    Namespaced ``attn:`` so the same autotune JSON can carry VMM buckets and
    attention buckets side by side.  T buckets decode-like steps (plain
    decode and spec draft/verify staging, T ≤ 8) apart from prefill chunks;
    the KV extent (table width · page size) buckets the read volume.
    """
    phase = "dec" if t <= 8 else "pre"
    kb = "s" if kv_len <= 256 else ("m" if kv_len <= 2048 else "l")
    return f"attn:{phase}:{kb}"


def select_attn_backend(mode: Optional[str], *, batch: int, t: int,
                        kv_len: int) -> str:
    """Resolve a ``cfg.paged_attn`` mode to a registered backend name.

    ``"auto"`` reads the autotune cost table's ``attn:*`` bucket for this
    shape (populated by ``benchmarks/paged_decode.py``); untimed buckets fall
    back to the platform heuristic — the fused Pallas walk on TPU, the XLA
    gather read elsewhere (off-TPU the kernel runs in interpreter mode, a
    correctness tool rather than a fast path).
    """
    mode = "auto" if mode is None else mode
    if mode != "auto":
        return get_attn_backend(mode).name
    costs = load_cost_table().get(attn_shape_bucket(batch, t, kv_len), {})
    timed = {n: c for n, c in costs.items() if n in _ATTN_REGISTRY}
    if timed:
        return min(timed, key=timed.get)
    return "fused" if jax.default_backend() == "tpu" else "gather"


@register_attn_backend(
    "gather",
    description="XLA read: page-table gather to [B,S,kv,hd] + masked softmax",
)
def _gather_attn_backend(q, k_pool, v_pool, page_table, tpos, **kw):
    from repro.models.attention import paged_gather_read

    return paged_gather_read(q, k_pool, v_pool, page_table, tpos, **kw)


@register_attn_backend(
    "fused",
    description="Pallas kernel: in-kernel page walk + online softmax "
    "(interpret off-TPU)",
)
def _fused_attn_backend(q, k_pool, v_pool, page_table, tpos, **kw):
    from repro.kernels.paged_attention import paged_attention

    return paged_attention(q, k_pool, v_pool, page_table, tpos, **kw)


# ---------------------------------------------------------------------------
# Fused QKV projection — one DA pass per layer over three PackedWeights
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("cfg", "backends", "x_bits_eff", "splits"))
def _da_qkv_jit(x2, packs, cfg, backends, x_bits_eff, splits):
    # backend set in the HLO metadata → profiler attributes the fused pass
    with jax.named_scope("da_qkv_" + "_".join(dict.fromkeys(backends))):
        return _da_qkv_impl(x2, packs, cfg, backends, x_bits_eff, splits)


def _da_qkv_impl(x2, packs, cfg, backends, x_bits_eff, splits):
    xqt = quantize_acts_signed(x2, bits=cfg.x_bits)
    xq, rcfg, drop = truncate_codes(xqt.q, cfg, x_bits_eff)
    if len(set(backends)) == 1 and not _REGISTRY[backends[0]].needs_luts:
        # One storage-free backend serves all three: concatenate the code
        # matrices on N and run ONE integer VMM.  Each output column is an
        # independent exact integer dot, so the split accumulators are the
        # very integers three separate calls would produce.
        merged = PackedWeights(
            wq=jnp.concatenate([p.wq for p in packs], axis=-1),
            w_scale=jnp.ones((1, 1), jnp.float32), luts=None,
            cfg=cfg, mode=backends[0],
        )
        accs = jnp.split(_REGISTRY[backends[0]].fn(xq, merged, rcfg),
                         list(splits), axis=-1)
    else:
        accs = [_REGISTRY[b].fn(xq, p, rcfg) for b, p in zip(backends, packs)]
    outs = []
    for acc, p in zip(accs, packs):
        if drop:
            acc = acc * (1 << drop)
        outs.append(acc.astype(jnp.float32) * xqt.scale * p.w_scale)
    return tuple(outs)


def da_qkv_matmul(
    x: jax.Array,
    packs,
    cfg: Optional[DAConfig] = None,
    mode: Optional[str] = None,
    x_bits_eff: Optional[int] = None,
):
    """Fused multi-head projection: one DA pass over several PackedWeights.

    ``x [.., K]`` against ``packs`` (e.g. the q/k/v artifacts of one layer,
    all packed under one DAConfig with the same K).  The activations are
    quantized and bit-plane-decomposed ONCE, and when every matrix resolves
    to the same storage-free backend the three VMMs run as a single
    concatenated pass — the weights stream through the datapath once per
    decode step instead of three times.  Outputs are BIT-IDENTICAL to
    separate :func:`da_matmul` calls: shared quantization is the same
    quantization, the integer backends are exact, and dequantization is
    per-column.  Returns a tuple of ``[.., N_i]`` float arrays.

    ``x_bits_eff`` / the :func:`x_bits_override` context truncate the shared
    codes exactly as in :func:`da_matmul` (the draft pass fuses too).
    """
    packs = tuple(packs)
    if not packs:
        raise ValueError("da_qkv_matmul needs at least one PackedWeights")
    base = cfg if cfg is not None else packs[0].cfg
    for p in packs:
        if not isinstance(p, PackedWeights) or p.wq.ndim != 2:
            raise ValueError("da_qkv_matmul fuses 2-D PackedWeights only")
        if cfg is None and p.cfg != base:
            raise ValueError(
                "da_qkv_matmul: packs disagree on DAConfig — pass cfg= to "
                "override, or fall back to separate da_matmul calls"
            )
        if p.k != packs[0].k:
            raise ValueError(
                f"da_qkv_matmul: contraction dims differ ({p.k} vs "
                f"{packs[0].k})"
            )
    scfg = dataclasses.replace(base, x_signed=True)
    eff = effective_x_bits(scfg, x_bits_eff)
    rcfg = dataclasses.replace(scfg, x_bits=eff)  # dispatch sees draft cycles
    lead = x.shape[:-1]
    k = x.shape[-1]
    m = 1
    for d in lead:
        m *= int(d)
    backends = []
    for p in packs:
        spec = _resolve_spec(mode, m, p.k, p.n, rcfg, p.has_luts,
                             default_mode=p.mode)
        _check_lut_shape(spec, p, rcfg)
        backends.append(spec.name)
    splits = []
    for p in packs[:-1]:
        splits.append((splits[-1] if splits else 0) + p.n)
    x2 = x.reshape(-1, k).astype(jnp.float32)
    ys = _da_qkv_jit(x2, packs, scfg, tuple(backends), eff, tuple(splits))
    return tuple(y.reshape(lead + (p.n,)) for y, p in zip(ys, packs))
