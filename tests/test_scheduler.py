"""Continuous-batching scheduler invariants over the paged KV cache.

The load-bearing properties: no cross-request token leakage under
interleaved admit/finish/preempt, paged-attention reads bit-identical to the
dense cache, page exhaustion → queue backpressure (never a crash), and
length-bucketed compilation counts for both runtimes."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS, reduce_for_smoke
from repro.models.model import forward, init_model
from repro.serve.engine import Request, ServeEngine

KEY = jax.random.key(0)
MAX_NEW = 4


@pytest.fixture(scope="module")
def setup():
    """Smoke model + 8 staggered prompts (lengths 3..10) + offline greedy
    references — the ground truth every engine configuration must hit."""
    cfg = dataclasses.replace(reduce_for_smoke(ARCHS["qwen3-8b"]),
                              moe_dropless=True)
    params = init_model(KEY, cfg)
    rng = np.random.default_rng(7)
    prompts = {uid: rng.integers(0, cfg.vocab, 3 + uid) for uid in range(8)}
    refs = {}
    for uid, pr in prompts.items():
        toks = list(pr)
        for _ in range(MAX_NEW):
            lg, _ = forward(params, jnp.asarray(toks, dtype=jnp.int32)[None],
                            cfg)
            toks.append(int(jnp.argmax(lg[0, -1])))
        refs[uid] = toks[len(pr):]
    return cfg, params, prompts, refs


def _submit_all(eng, prompts, **kw):
    for uid, pr in prompts.items():
        eng.submit(Request(uid=uid, prompt=pr, max_new_tokens=MAX_NEW, **kw))


def test_paged_matches_offline_and_streams(setup):
    """8 staggered requests through 2 lanes: continuous batching with
    chunked prefill coalesced into decode, every request token-identical to
    its own offline greedy decode (no leakage), stream callbacks in order,
    step compilations bounded by the power-of-two buckets."""
    cfg, params, prompts, refs = setup
    streamed = {}
    eng = ServeEngine(cfg, params, batch_size=2, max_len=32, page_size=8)
    assert eng.runtime == "paged"
    for uid, pr in prompts.items():
        eng.submit(Request(
            uid=uid, prompt=pr, max_new_tokens=MAX_NEW,
            on_token=lambda u, t: streamed.setdefault(u, []).append(t),
        ))
    done = eng.run()
    assert sorted(done) == sorted(prompts)
    for uid in prompts:
        assert done[uid].generated == refs[uid], uid
        assert streamed[uid] == refs[uid], uid
    m = eng.metrics()
    # step shapes are pow2-bucketed (decode width × prefill chunk length)
    # → O(log) compilations regardless of the prompt-length mix
    assert m["step_compiles"] <= 6, m["step_compiles"]
    assert m["out_tokens"] == 8 * MAX_NEW
    assert m["requests_done"] == 8 and m["tokens_per_s"] > 0
    assert m["pool"]["used_pages"] == 0  # finished lanes freed their pages


def test_interleaved_admit_finish_preempt_no_leakage(setup):
    """Admissions mid-flight + a forced preemption + pool-pressure
    preemptions: every request still reproduces its offline tokens exactly
    (preemption recomputes KV by replayed prefill; greedy decode makes the
    replay token-exact)."""
    cfg, params, prompts, refs = setup
    eng = ServeEngine(cfg, params, batch_size=4, max_len=32, page_size=4,
                      n_pages=13, admission="optimistic")
    first = {u: prompts[u] for u in list(prompts)[:4]}
    rest = {u: prompts[u] for u in list(prompts)[4:]}
    _submit_all(eng, first)
    eng.step()  # one tick: chunked prefill + first decode, lanes still live
    # force one deterministic preemption of an occupied lane
    sched = eng._rt
    victims = [i for i, l in enumerate(sched.lanes) if l is not None]
    assert victims, "tick finished every request; nothing left to preempt"
    sched._preempt(victims[-1])
    _submit_all(eng, rest)  # interleaved admits
    done = eng.run()
    assert sorted(done) == sorted(prompts)
    for uid in prompts:
        assert done[uid].generated == refs[uid], uid
    m = eng.metrics()
    assert m["preemptions"] >= 1
    assert m["pool"]["used_pages"] == 0


def test_page_exhaustion_is_backpressure_not_crash(setup):
    """A pool that fits ~one request at a time: reservation admission parks
    the rest in the queue (observable backpressure) and everything still
    completes correctly."""
    cfg, params, prompts, refs = setup
    # worst case per request: pages_for(10 + 4, 4) = 4 pages; capacity 5
    eng = ServeEngine(cfg, params, batch_size=4, max_len=32, page_size=4,
                      n_pages=6, admission="reserve")
    subset = {u: prompts[u] for u in list(prompts)[:5]}
    _submit_all(eng, subset)
    saw_backpressure = False
    while eng.step() or eng.queue:
        concurrent = sum(l is not None for l in eng._rt.lanes)
        saw_backpressure |= (len(eng.queue) > 0 and concurrent >= 1)
        assert concurrent <= 2  # the pool cannot host more side by side
    done = eng.done
    assert sorted(done) == sorted(subset)
    for uid in subset:
        assert done[uid].generated == refs[uid], uid
    assert saw_backpressure


def test_impossible_requests_raise(setup):
    cfg, params, _, _ = setup
    eng = ServeEngine(cfg, params, batch_size=2, max_len=32, page_size=4,
                      n_pages=4)
    with pytest.raises(ValueError):  # needs more pages than the pool owns
        eng.submit(Request(uid=0, prompt=np.arange(20), max_new_tokens=8))
    with pytest.raises(ValueError):  # prompt beyond max_len
        eng.submit(Request(uid=1, prompt=np.arange(40), max_new_tokens=1))


def test_preempted_oversized_request_readmits(setup):
    """Regression: a preempted request whose full context + headroom exceeds
    the whole pool must still re-admit once the pool drains — it must not
    wait forever on a condition that can never hold."""
    cfg, params, _, _ = setup
    rng = np.random.default_rng(13)
    eng = ServeEngine(cfg, params, batch_size=2, max_len=32, page_size=4,
                      n_pages=6, admission="optimistic", prefill_chunk=4)
    eng.submit(Request(uid=1, prompt=rng.integers(0, cfg.vocab, 8),
                       max_new_tokens=11))
    eng.submit(Request(uid=2, prompt=rng.integers(0, cfg.vocab, 16),
                       max_new_tokens=4))
    done = eng.run(max_steps=500)
    assert sorted(done) == [1, 2]
    assert len(done[2].generated) == 4


def test_slot_prefill_compile_count(setup):
    """Satellite: 10 distinct prompt lengths → ≤ 4 prefill compilations
    (power-of-two length buckets, slot index is a traced operand)."""
    cfg, params, _, _ = setup
    rng = np.random.default_rng(11)
    eng = ServeEngine(cfg, params, batch_size=2, max_len=32, runtime="slots")
    for uid in range(10):
        eng.submit(Request(uid=uid, prompt=rng.integers(0, cfg.vocab, 3 + uid),
                           max_new_tokens=2))
    done = eng.run()
    assert sorted(done) == list(range(10))
    assert eng._rt.prefill_compiles <= 4, eng._rt.prefill_compiles


def test_paged_tokens_identical_to_slot_engine(setup):
    """Acceptance: on the same frozen DA artifact, the paged runtime and the
    dense-slot runtime emit identical tokens for the same request set."""
    from repro.core.da import DAConfig
    from repro.core.freeze import freeze_model

    cfg, params, prompts, _ = setup
    art = freeze_model(params, DAConfig(x_signed=True),
                       mode="bitplane_stacked", model_cfg=cfg)
    subset = {u: prompts[u] for u in list(prompts)[:3]}
    outs = {}
    for runtime in ("slots", "paged"):
        eng = ServeEngine(cfg, art.params, batch_size=2, max_len=32,
                          runtime=runtime)
        _submit_all(eng, subset)
        outs[runtime] = {u: r.generated for u, r in eng.run().items()}
    assert outs["paged"] == outs["slots"]


def test_defrag_mid_serve_is_transparent(setup):
    cfg, params, prompts, refs = setup
    eng = ServeEngine(cfg, params, batch_size=2, max_len=32, page_size=4)
    subset = {u: prompts[u] for u in list(prompts)[:3]}
    _submit_all(eng, subset)
    for _ in range(3):
        eng.step()
    eng._rt.defrag()  # pages move, tables move with them
    done = eng.run()
    for uid in subset:
        assert done[uid].generated == refs[uid], uid


def test_auto_runtime_falls_back_to_slots_for_ssm():
    """Mamba state is O(1) per request — nothing to page; auto picks the
    slot runtime, and asking for paging explicitly is a clear error."""
    cfg = reduce_for_smoke(ARCHS["mamba2-780m"])
    params = init_model(KEY, cfg)
    eng = ServeEngine(cfg, params, batch_size=2, max_len=16)
    assert eng.runtime == "slots"
    with pytest.raises(ValueError):
        ServeEngine(cfg, params, batch_size=2, max_len=16, runtime="paged")


def test_ssm_slot_prefill_not_padded():
    """Regression: the Mamba/SSD recurrence has no position mask, so padded
    prefill would fold pad tokens into the carried conv/ssm state. SSM
    archs prefill at exact prompt length and must match offline greedy."""
    cfg = reduce_for_smoke(ARCHS["mamba2-780m"])
    params = init_model(KEY, cfg)
    eng = ServeEngine(cfg, params, batch_size=1, max_len=16)
    prompt = np.random.default_rng(17).integers(0, cfg.vocab, 5)  # pad-prone
    eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=3))
    done = eng.run()
    toks = list(prompt)
    for _ in range(3):
        lg, _ = forward(params, jnp.asarray(toks, dtype=jnp.int32)[None], cfg)
        toks.append(int(jnp.argmax(lg[0, -1])))
    assert done[0].generated == toks[len(prompt):]


def test_paged_attention_bit_identical_to_dense_cache():
    """The gather-based paged read is EXACT: with identical cache content
    and matching gathered shapes, decode outputs are bit-identical to the
    dense [B, S] cache path."""
    from repro.models.attention import KVCache, attention_forward, \
        init_attention
    from repro.serve.kvcache import PagedKVCache, pad_position, pages_for, \
        table_array, table_width

    cfg = dataclasses.replace(reduce_for_smoke(ARCHS["qwen3-8b"]),
                              moe_dropless=True)
    p = init_attention(jax.random.key(1), cfg)
    b, ps, max_len = 2, 8, 24
    w = table_width(max_len, ps)
    s = w * ps  # dense cache sized to the gathered view → same op shapes
    lens = [13, 7]
    kv, hd = cfg.n_kv_heads, cfg.head_dim_
    k_rows = jax.random.normal(jax.random.key(2), (b, max(lens), kv, hd))
    v_rows = jax.random.normal(jax.random.key(3), (b, max(lens), kv, hd))

    dense_k = jnp.zeros((b, s, kv, hd))
    dense_v = jnp.zeros((b, s, kv, hd))
    n_pages = 1 + b * pages_for(max_len, ps)
    pool_k = jnp.zeros((n_pages, ps, kv, hd))
    pool_v = jnp.zeros((n_pages, ps, kv, hd))
    tables, nxt = [], 1
    for i, ln in enumerate(lens):
        dense_k = dense_k.at[i, :ln].set(k_rows[i, :ln])
        dense_v = dense_v.at[i, :ln].set(v_rows[i, :ln])
        pages = list(range(nxt, nxt + pages_for(ln, ps)))
        nxt += len(pages)
        tables.append(pages)
        for j, pg in enumerate(pages):
            n = min(ps, ln - j * ps)
            pool_k = pool_k.at[pg, :n].set(k_rows[i, j * ps : j * ps + n])
            pool_v = pool_v.at[pg, :n].set(v_rows[i, j * ps : j * ps + n])

    x = jax.random.normal(jax.random.key(4), (b, 1, cfg.d_model))
    pos = jnp.asarray([[ln] for ln in lens], dtype=jnp.int32)
    y_dense, _ = attention_forward(
        p, x, cfg, pos,
        cache=KVCache(k=dense_k, v=dense_v, length=jnp.asarray(max(lens))),
    )
    y_paged, _ = attention_forward(
        p, x, cfg, pos, cache=PagedKVCache(k=pool_k, v=pool_v),
        page_table=jnp.asarray(table_array(tables, w)),
    )
    assert pad_position(max_len, ps) >= max_len  # pads land past real rows
    np.testing.assert_array_equal(np.asarray(y_dense), np.asarray(y_paged))
