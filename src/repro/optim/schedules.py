"""LR schedules (pure functions of the step, elastic-restart friendly)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, warmup: int, total: int, floor: float = 0.1):
    """Returns a multiplier in [floor, 1]. step may be traced."""
    s = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(s / max(1, warmup), 1.0)
    prog = jnp.clip((s - warmup) / max(1, total - warmup), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return warm * (floor + (1.0 - floor) * cos)


def constant(step, value: float = 1.0):
    del step
    return value
