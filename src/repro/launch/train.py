"""Distributed training launcher.

  python -m repro.launch.train --arch qwen3-8b --steps 100 \
      --mesh 2x2 --axes data,model --batch 32 --seq 512

On real hardware the mesh comes from the TPU topology (jax.devices()); on
this CPU container pass --fake-devices N to request placeholder devices
(must be the first thing the process does — handled below before jax import).
Fault tolerance: --ckpt-dir enables async checkpoints + crash resume.
"""
import argparse
import os


def _parse():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--mesh", default="1x1", help="e.g. 16x16 or 2x16x16")
    ap.add_argument("--axes", default="data,model")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config for the arch")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--fake-devices", type=int, default=0)
    return ap.parse_args()


def main():
    args = _parse()
    if args.fake_devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.fake_devices} "
            + os.environ.get("XLA_FLAGS", "")
        )

    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs.registry import ARCHS, reduce_for_smoke
    from repro.data.pipeline import batch_at, for_model
    from repro.launch import specs as SP
    from repro.launch.mesh import make_test_mesh
    from repro.launch.sharding import use_mesh_rules
    from repro.models.model import count_params
    from repro.optim.adamw import AdamWConfig
    from repro.train.trainer import TrainConfig, Trainer, init_state

    cfg = ARCHS[args.arch]
    if args.smoke:
        cfg = reduce_for_smoke(cfg)
    print(f"arch={cfg.name} params={count_params(cfg)/1e6:.1f}M "
          f"devices={len(jax.devices())}")

    shape = tuple(int(x) for x in args.mesh.split("x"))
    axes = tuple(args.axes.split(","))
    assert len(shape) == len(axes)
    mesh = make_test_mesh(shape, axes)

    dc = for_model(cfg, seq_len=args.seq, global_batch=args.batch, packed=True)
    tcfg = TrainConfig(
        opt=AdamWConfig(lr=args.lr),
        total_steps=args.steps,
        microbatches=args.microbatches,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every,
    )

    with use_mesh_rules(mesh):
        state = init_state(jax.random.key(0), cfg)
        sspec = SP.tree_pspecs(state)
        to_ns = lambda t: jax.tree.map(
            lambda s: NamedSharding(mesh, s), t,
            is_leaf=lambda x: isinstance(x, P))
        state = jax.device_put(state, to_ns(sspec))

        def data_fn(step):
            batch = jax.tree.map(jnp.asarray, batch_at(dc, step))
            bspec = SP.batch_pspecs(batch)
            return jax.device_put(batch, to_ns(bspec))

        trainer = Trainer(cfg, tcfg, data_fn)
        state, hist = trainer.run(state, args.steps)

    for h in hist[:: max(1, len(hist) // 20)]:
        print(f"step {h['step']:5d} loss {h['loss']:.4f} "
              f"gnorm {h['grad_norm']:.2f} {h['time_s']*1e3:.0f} ms"
              + (" STRAGGLER" if h.get("straggler") else ""))
    if trainer.monitor.flagged:
        print(f"stragglers flagged: {trainer.monitor.flagged}")
    print(f"done at step {int(state.step)}")


if __name__ == "__main__":
    main()
