"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production meshes, record memory/cost analysis + collective schedule.

This is the proof that the distribution config is coherent without hardware:
a sharding mismatch, OOM-at-compile or unsupported collective fails here.

Usage:
  python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out artifacts/dryrun
Artifacts: one JSON per cell (cached — reruns skip completed cells).

NOTE: the XLA_FLAGS assignment below MUST run before any other import —
jax locks the device count on first initialization.
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse
import dataclasses
import json
import time
import traceback
from typing import Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.registry import ARCHS, LM_SHAPES, ShapeSpec, get, shapes_for
from repro.launch import roofline as rl
from repro.launch import specs as SP
from repro.launch.mesh import make_production_mesh
from repro.launch.sharding import LM_RULES, use_mesh_rules
from repro.models.config import ModelConfig
from repro.models.model import (
    count_active_params,
    count_params,
    init_caches,
    init_model,
)
from repro.serve.engine import make_prefill_step, make_serve_step
from repro.train.trainer import TrainConfig, init_state, make_train_step


def _abstract(fn, *args):
    """eval_shape → ShapeDtypeStruct pytree (no allocation)."""
    return jax.eval_shape(fn, *args)


def _ns(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def lower_cell(
    cfg: ModelConfig,
    shape: ShapeSpec,
    mesh,
    rules=LM_RULES,
    extra_cfg: Optional[dict] = None,
    quant: Optional[str] = None,
):
    """Lower the cell's step function with full shardings. Returns (lowered,
    aux) — aux carries chips and MODEL_FLOPS for the roofline.

    quant: None, "auto", or any registered engine backend name (legacy
    "da_bitplane"/"da_lut" spellings accepted) — serve the DA-frozen model
    (the paper's technique inside the distributed serving graph)."""
    if extra_cfg:
        cfg = dataclasses.replace(cfg, **extra_cfg)
    chips = mesh.size
    n_params = count_params(cfg)
    n_active = count_active_params(cfg)
    mf = rl.model_flops(cfg, shape, n_params, n_active)
    aux = {
        "chips": chips,
        "model_flops": mf,
        "n_params": n_params,
        "n_active": n_active,
    }

    with use_mesh_rules(mesh, rules):
        if shape.kind == "train":
            tcfg = TrainConfig()
            state_shape = _abstract(
                lambda: init_state(jax.random.key(0), cfg)
            )
            state_specs = SP.tree_pspecs(state_shape)
            batch = SP.batch_specs(cfg, shape)
            batch_specs_ = SP.batch_pspecs(batch)
            step = make_train_step(cfg, tcfg)
            jitted = jax.jit(
                step,
                in_shardings=(_ns(mesh, state_specs), _ns(mesh, batch_specs_)),
                out_shardings=(_ns(mesh, state_specs), None),
                donate_argnums=(0,),
            )
            lowered = jitted.lower(state_shape, batch)
        else:
            if quant:
                from repro.core.da import DAConfig
                from repro.core.freeze import freeze_model_da

                params_shape = _abstract(
                    lambda: freeze_model_da(
                        init_model(jax.random.key(0), cfg),
                        DAConfig(x_signed=True),
                        mode=quant,
                    )
                )
            else:
                params_shape = _abstract(
                    lambda: init_model(jax.random.key(0), cfg)
                )
            param_specs = SP.tree_pspecs(params_shape)
            max_len = shape.seq_len
            caches_shape = _abstract(
                lambda: init_caches(cfg, shape.global_batch, max_len, cfg.dtype())
            )
            cache_specs = SP.cache_pspecs(caches_shape)
            if shape.kind == "prefill":
                fn = make_prefill_step(cfg)
                tok, pos = SP.prefill_specs(cfg, shape)
            else:
                fn = make_serve_step(cfg)
                tok, pos = SP.decode_specs(cfg, shape)
            from repro.launch import sharding as shd

            tok_spec = shd.pspec(("batch", "seq", "embed")[: tok.ndim], tok.shape)
            pos_spec = shd.pspec(("batch", None, None)[: pos.ndim], pos.shape)
            # pin the logits sharding: leaving it to XLA makes the GSPMD
            # strategy (and hence probe costs) unstable across probe compiles
            logits_spec = shd.pspec(("batch", "vocab"),
                                    (shape.global_batch, cfg.vocab))
            jitted = jax.jit(
                fn,
                in_shardings=(
                    _ns(mesh, param_specs),
                    _ns(mesh, cache_specs),
                    NamedSharding(mesh, tok_spec),
                    NamedSharding(mesh, pos_spec),
                ),
                out_shardings=(NamedSharding(mesh, logits_spec),
                               _ns(mesh, cache_specs)),
                donate_argnums=(1,),
            )
            lowered = jitted.lower(params_shape, caches_shape, tok, pos)
    return lowered, aux


def _cost_of(compiled) -> dict:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    coll = rl.collective_bytes(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": float(sum(coll.values())),
        "coll_by_kind": coll,
    }


def probe_costs(cfg: ModelConfig, shape: ShapeSpec, mesh, rules,
                extra_cfg: Optional[dict], quant: Optional[str] = None) -> dict:
    """Trip-count-corrected per-chip costs.

    HloCostAnalysis counts while-loop (scan) bodies ONCE; every per-layer
    cost is affine in the period count, so two fully-unrolled probes recover
    exact totals. Probe points are 2 and 3 periods — a 1-period compile can
    trigger degenerate GSPMD strategies that corrupt the slope:
        cost(P) = c2 + (P−2) · (c3 − c2).
    """
    period = cfg.period
    ks = (2, 3)
    probes = []
    for k in ks:
        extra = dict(extra_cfg or {})
        extra.update(n_layers=k * period, scan_unroll=True)
        lowered, _ = lower_cell(cfg, shape, mesh, rules=rules, extra_cfg=extra,
                                quant=quant)
        probes.append(_cost_of(lowered.compile()))
    p = cfg.n_layers // period
    out = {}
    for key in ("flops", "bytes", "coll"):
        c2, c4 = probes[0][key], probes[1][key]
        out[key] = c2 + (p - ks[0]) * (c4 - c2) / (ks[1] - ks[0])
    out["probe_1"] = probes[0]
    out["probe_2"] = probes[1]
    return out


def run_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    out_dir: Optional[str] = None,
    extra_cfg: Optional[dict] = None,
    tag: str = "",
    rules=LM_RULES,
    skip_full: bool = False,
    do_probes: bool = True,
    quant: Optional[str] = None,
) -> dict:
    cfg = get(arch)
    shape = next(s for s in LM_SHAPES if s.name == shape_name)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cell_id = f"{arch}__{shape_name}__{mesh_name}" + (f"__{tag}" if tag else "")
    out_path = os.path.join(out_dir, cell_id + ".json") if out_dir else None
    if out_path and os.path.exists(out_path):
        with open(out_path) as f:
            return json.load(f)

    record = {"cell": cell_id, "arch": arch, "shape": shape_name,
              "mesh": mesh_name, "ok": False}
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        aux = None
        if not skip_full:
            # 1) full-config compile: proves the sharding config is coherent
            #    and yields the memory analysis.
            lowered, aux = lower_cell(cfg, shape, mesh, rules=rules,
                                      extra_cfg=extra_cfg, quant=quant)
            compiled = lowered.compile()
            mem = compiled.memory_analysis()
            raw = _cost_of(compiled)
            record.update(
                memory={
                    "argument_bytes": mem.argument_size_in_bytes,
                    "output_bytes": mem.output_size_in_bytes,
                    "temp_bytes": mem.temp_size_in_bytes,
                    "alias_bytes": mem.alias_size_in_bytes,
                },
                raw_full_cost=raw,
            )
        # 2) trip-count-corrected cost probes → the roofline terms.
        if not do_probes:
            record.update(ok=True,
                          lower_compile_s=round(time.time() - t0, 1),
                          n_params=aux["n_params"], n_active=aux["n_active"])
            if out_path:
                os.makedirs(out_dir, exist_ok=True)
                with open(out_path, "w") as f:
                    json.dump(record, f, indent=1)
            return record
        costs = probe_costs(cfg, shape, mesh, rules, extra_cfg, quant=quant)
        if aux is None:
            ecfg = dataclasses.replace(cfg, **(extra_cfg or {}))
            aux = {
                "chips": mesh.size,
                "model_flops": rl.model_flops(
                    ecfg, shape, count_params(ecfg), count_active_params(ecfg)
                ),
                "n_params": count_params(ecfg),
                "n_active": count_active_params(ecfg),
            }
        roof = rl.Roofline(
            flops_per_chip=costs["flops"],
            bytes_per_chip=costs["bytes"],
            coll_bytes_per_chip=costs["coll"],
            chips=aux["chips"],
            model_flops_global=aux["model_flops"],
        )
        record.update(
            ok=True,
            lower_compile_s=round(time.time() - t0, 1),
            n_params=aux["n_params"],
            n_active=aux["n_active"],
            probes={k: costs[k] for k in ("probe_1", "probe_2")},
            roofline=roof.as_dict(),
        )
    except Exception as e:  # the dry-run's job is to surface these
        record.update(error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-2000:],
                      lower_compile_s=round(time.time() - t0, 1))
    if out_path:
        os.makedirs(out_dir, exist_ok=True)
        with open(out_path, "w") as f:
            json.dump(record, f, indent=1)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    cells = []
    if args.all:
        for arch, cfg in sorted(ARCHS.items()):
            for s in shapes_for(cfg):
                for mp in meshes:
                    cells.append((arch, s.name, mp))
    else:
        assert args.arch and args.shape
        for mp in meshes:
            cells.append((args.arch, args.shape, mp))

    n_ok = 0
    for arch, shape_name, mp in cells:
        # multi-pod cells prove compile coherence; the roofline table (probe
        # costs) is single-pod per EXPERIMENTS.md §Roofline.
        rec = run_cell(arch, shape_name, mp, out_dir=args.out,
                       do_probes=not mp)
        ok = rec.get("ok")
        n_ok += bool(ok)
        r = rec.get("roofline", {})
        print(
            f"{rec['cell']:64s} ok={ok} "
            f"t_c={r.get('t_compute_s', 0):.3e} t_m={r.get('t_memory_s', 0):.3e} "
            f"t_coll={r.get('t_collective_s', 0):.3e} "
            f"bottleneck={r.get('bottleneck', '-'):10s} "
            f"frac={r.get('roofline_fraction', 0):.3f}",
            flush=True,
        )
        if not ok:
            print("   ERROR:", rec.get("error"), flush=True)
    print(f"\n{n_ok}/{len(cells)} cells ok")


if __name__ == "__main__":
    main()
