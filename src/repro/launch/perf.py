"""§Perf hillclimbing driver.

Lowers a (arch × shape) cell with a named variant (a set of config levers /
DA quant mode), computes the trip-count-corrected roofline, and appends the
before/after record to artifacts/perf/. Also offers an HLO diagnosis mode
that prints the top collectives / op-kind byte breakdown of a probe compile.

  python -m repro.launch.perf --arch mistral-nemo-12b --shape prefill_32k \
      --variant L3_additive_bf16
  python -m repro.launch.perf --arch mistral-nemo-12b --shape prefill_32k \
      --diagnose
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse
import json

# Named §Perf variants: config levers (+ optional quant) per experiment.
VARIANTS = {
    "baseline": dict(extra={}, quant=None),
    # L2: slice hidden state before the LM head in prefill
    "L2_last_only": dict(extra={"prefill_last_only": True}, quant=None),
    # L3: additive mask (one fused pass) + bf16 score pipeline
    "L3_additive": dict(extra={"attn_mask_mode": "additive"}, quant=None),
    "L3_additive_bf16": dict(
        extra={"attn_mask_mode": "additive", "softmax_dtype": "bfloat16"},
        quant=None,
    ),
    # L4: sort-based MoE dispatch
    "L4_sorted_moe": dict(extra={"moe_impl": "sorted"}, quant=None),
    "L4_sorted_small_groups": dict(
        extra={"moe_impl": "sorted", "moe_group_size": 256}, quant=None
    ),
    # L5: remat policy saving matmul outputs (train)
    "L5_remat_dots": dict(extra={"remat_policy": "dots"}, quant=None),
    # L8: structurally-lean attention (minimal score-tensor passes)
    "L8_lean_attn": dict(extra={"attn_impl": "lean"}, quant=None),
    # L9: uniform-position KV-cache write via dynamic_update_slice
    "L9_cache_slice": dict(extra={"cache_mode": "slice"}, quant=None),
    "L89_lean_slice": dict(
        extra={"attn_impl": "lean", "cache_mode": "slice"}, quant=None),
    "DA_stacked_slice": dict(
        extra={"cache_mode": "slice"}, quant="da_bitplane_stacked"),
    # L6: flash-style chunked attention for long prefill
    "L6_chunked_attn": dict(extra={"attn_chunk_q": 2048}, quant=None),
    # DA-quantized serving (the paper's technique in the serving graph).
    # quant names are engine backends (repro.core.engine registry; legacy
    # da_* spellings are canonicalized there).
    "DA_bitplane": dict(extra={}, quant="da_bitplane"),       # faithful serial
    "DA_stacked": dict(extra={}, quant="da_bitplane_stacked"),  # L7: one dot
    "DA_int8": dict(extra={}, quant="int8"),
    # shape-aware engine dispatch: each layer picks its backend per (M,K,N)
    "DA_auto": dict(extra={}, quant="auto"),
    "DA_stacked_combo": dict(
        extra={"attn_mask_mode": "additive", "softmax_dtype": "bfloat16"},
        quant="da_bitplane_stacked",
    ),
    # combos
    "combo_serve": dict(
        extra={"attn_mask_mode": "additive", "softmax_dtype": "bfloat16",
               "prefill_last_only": True},
        quant=None,
    ),
    "combo_moe_serve": dict(
        extra={"attn_mask_mode": "additive", "softmax_dtype": "bfloat16",
               "moe_impl": "sorted"},
        quant=None,
    ),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", default=None, choices=sorted(VARIANTS))
    ap.add_argument("--extra", default=None,
                    help="JSON dict of raw config overrides")
    ap.add_argument("--quant", default=None)
    ap.add_argument("--diagnose", action="store_true")
    ap.add_argument("--fsdp", action="store_true",
                    help="use FSDP_RULES (2-D weight sharding over data+model)")
    ap.add_argument("--full", action="store_true",
                    help="also run the full compile (memory analysis)")
    ap.add_argument("--out", default="artifacts/perf")
    args = ap.parse_args()

    from repro.configs.registry import LM_SHAPES, get
    from repro.launch.dryrun import lower_cell, run_cell
    from repro.launch.mesh import make_production_mesh

    if args.diagnose:
        from repro.launch.hlo_tools import bytes_by_op_kind, top_collectives

        cfg = get(args.arch)
        shape = next(s for s in LM_SHAPES if s.name == args.shape)
        mesh = make_production_mesh()
        extra = json.loads(args.extra) if args.extra else {}
        extra.update(n_layers=2 * cfg.period, scan_unroll=True)
        lowered, _ = lower_cell(cfg, shape, mesh, extra_cfg=extra,
                                quant=args.quant)
        txt = lowered.compile().as_text()
        print("== top collectives (2-period probe, per-chip result bytes) ==")
        for name, kind, b in top_collectives(txt):
            print(f"  {b/1e9:9.3f} GB  {kind:20s} {name}")
        print("== result bytes by op kind ==")
        for kind, b, n in bytes_by_op_kind(txt):
            print(f"  {b/1e9:9.3f} GB  n={n:5d}  {kind}")
        return

    assert args.variant or args.extra
    if args.variant:
        v = VARIANTS[args.variant]
        extra, quant = dict(v["extra"]), v["quant"]
        tag = args.variant
    else:
        extra, quant = json.loads(args.extra), args.quant
        tag = "custom"
    from repro.launch.sharding import FSDP_RULES, LM_RULES

    rules = FSDP_RULES if args.fsdp else LM_RULES
    if args.fsdp:
        tag = tag + "_fsdp"
    rec = run_cell(args.arch, args.shape, multi_pod=False, out_dir=args.out,
                   extra_cfg=extra, tag=tag, skip_full=not args.full,
                   quant=quant, rules=rules)
    r = rec.get("roofline", {})
    print(json.dumps({k: r.get(k) for k in (
        "t_compute_s", "t_memory_s", "t_collective_s", "bottleneck",
        "useful_flops_fraction", "roofline_fraction")}, indent=1))
    if rec.get("memory"):
        print("memory:", json.dumps(rec["memory"]))
    if not rec.get("ok"):
        print("ERROR:", rec.get("error"))


if __name__ == "__main__":
    main()
