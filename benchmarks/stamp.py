"""Provenance stamp shared by the benchmark writers: git sha, seed, device,
timestamp — so a BENCH_*.json trajectory is comparable across PRs (same
workload, which build, which hardware, which randomness)."""
from __future__ import annotations

import pathlib
import subprocess
import time
from typing import Optional


def git_sha() -> Optional[str]:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=5,
            cwd=pathlib.Path(__file__).resolve().parent,
        )
        return out.stdout.strip() or None
    except Exception:
        return None


def bench_stamp(seed: Optional[int] = None) -> dict:
    """The common stamp block every benchmark JSON carries."""
    import jax

    return {
        "git_sha": git_sha(),
        "seed": seed,
        "device": jax.default_backend(),
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
    }
