"""Provenance stamp shared by the benchmark writers: git sha, seed, device,
Pallas execution mode, metrics schema version, timestamp — so a BENCH_*.json
trajectory is comparable across PRs (same workload, which build, which
hardware, which randomness, which kernel path).

Every benchmark writes through :func:`stamp_and_write` — one stamping path,
so a result file missing its provenance can't happen by forgetting a field.
"""
from __future__ import annotations

import json
import os
import pathlib
import subprocess
import time
from typing import Optional


def git_sha() -> Optional[str]:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=5,
            cwd=pathlib.Path(__file__).resolve().parent,
        )
        return out.stdout.strip() or None
    except Exception:
        return None


def bench_stamp(seed: Optional[int] = None) -> dict:
    """The common stamp block every benchmark JSON carries."""
    import jax

    from repro.obs.metrics import METRICS_SCHEMA_VERSION

    backend = jax.default_backend()
    return {
        "git_sha": git_sha(),
        "seed": seed,
        "device": backend,
        # whether Pallas kernels ran interpreted (CPU/GPU correctness path)
        # or compiled (TPU) — interpret-mode timings are NOT comparable to
        # compiled ones, so the flag rides every result file
        "pallas_interpret": backend != "tpu",
        "metrics_schema_version": METRICS_SCHEMA_VERSION,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
    }


def stamp_and_write(path: str, payload: dict,
                    seed: Optional[int] = None) -> str:
    """The one writer every benchmark result goes through: merge the
    provenance stamp into ``payload`` (payload keys win on collision, so a
    benchmark can pin e.g. its own seed field), create the artifacts
    directory, dump pretty JSON.  Returns ``path``."""
    result = {**bench_stamp(seed=seed), **payload}
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(result, f, indent=2)
    return path
