"""Shared layers: norms, rotary embeddings (RoPE / M-RoPE), MLP variants."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.engine import dense
from repro.launch.sharding import constrain
from repro.models.config import ModelConfig


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def init_norm(cfg: ModelConfig, dim: int):
    p = {"scale": jnp.ones((dim,), dtype=cfg.pdtype())}
    if cfg.norm_type == "layernorm":
        p["bias"] = jnp.zeros((dim,), dtype=cfg.pdtype())
    return p


def apply_norm(p, x, cfg: ModelConfig):
    xf = x.astype(jnp.float32)
    if cfg.norm_type == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        xf = xf - mu
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + cfg.norm_eps)
    y = y * p["scale"].astype(jnp.float32)
    if cfg.norm_type == "layernorm":
        y = y + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_norm_headwise(x, scale, eps: float):
    """Per-head RMS norm over head_dim (qwen3 qk-norm)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings (1-D RoPE and qwen2-vl M-RoPE)
# ---------------------------------------------------------------------------
def rope_angles(positions, head_dim: int, theta: float,
                sections: Optional[tuple] = None):
    """positions: [B, T] (1-D RoPE) or [B, T, 3] (M-RoPE). → [B, T, hd/2]."""
    half = head_dim // 2
    inv = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    if sections is None:
        if positions.ndim == 3:
            positions = positions[..., 0]
        return positions.astype(jnp.float32)[..., None] * inv
    if positions.ndim == 2:  # text-only input: t == h == w (1-D equivalent)
        positions = jnp.stack([positions] * len(sections), axis=-1)
    assert positions.ndim == 3 and positions.shape[-1] == len(sections)
    parts, off = [], 0
    for i, sec in enumerate(sections):
        p = positions[..., i].astype(jnp.float32)
        parts.append(p[..., None] * inv[off : off + sec])
        off += sec
    assert off == half, (off, half)
    return jnp.concatenate(parts, axis=-1)


def apply_rope(x, angles):
    """x: [B, T, H, hd]; angles: [B, T, hd/2] (broadcast over heads)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    cos = jnp.cos(angles)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(angles)[:, :, None, :].astype(x.dtype)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GELU / squared-ReLU)
# ---------------------------------------------------------------------------
def init_mlp(key, cfg: ModelConfig, d_model: int, d_ff: int):
    ks = jax.random.split(key, 3)
    dt = cfg.pdtype()
    s_in = 1.0 / (d_model ** 0.5)
    s_out = 1.0 / (d_ff ** 0.5)
    p = {
        "w_up": (jax.random.normal(ks[0], (d_model, d_ff)) * s_in).astype(dt),
        "w_down": (jax.random.normal(ks[1], (d_ff, d_model)) * s_out).astype(dt),
    }
    if cfg.mlp_act == "swiglu":
        p["w_gate"] = (jax.random.normal(ks[2], (d_model, d_ff)) * s_in).astype(dt)
    return p


def apply_mlp(p, x, cfg: ModelConfig):
    up = dense(x, p["w_up"])
    up = constrain(up, ("batch", "seq", "ffn"))
    if cfg.mlp_act == "swiglu":
        gate = dense(x, p["w_gate"])
        gate = constrain(gate, ("batch", "seq", "ffn"))
        h = jax.nn.silu(gate) * up
    elif cfg.mlp_act == "gelu":
        h = jax.nn.gelu(up)
    elif cfg.mlp_act == "relu2":
        h = jnp.square(jax.nn.relu(up))
    else:
        raise ValueError(cfg.mlp_act)
    y = dense(h, p["w_down"])
    return constrain(y, ("batch", "seq", "embed"))


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------
def init_embed(key, cfg: ModelConfig):
    dt = cfg.pdtype()
    table = jax.random.normal(key, (cfg.vocab, cfg.d_model)) * 0.02
    return {"table": table.astype(dt)}


def apply_embed(p, tokens):
    return constrain(jnp.take(p["table"], tokens, axis=0),
                     ("batch", "seq", "embed"))


def init_lm_head(key, cfg: ModelConfig):
    dt = cfg.pdtype()
    s = 1.0 / (cfg.d_model ** 0.5)
    return {"w": (jax.random.normal(key, (cfg.d_model, cfg.vocab)) * s).astype(dt)}


def apply_lm_head(p, x):
    logits = dense(x, p["w"])
    return constrain(logits, ("batch", "seq", "vocab"))
