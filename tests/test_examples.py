"""The runnable examples stay runnable (fast ones, executed in-process)."""
import runpy
import sys

import pytest


@pytest.mark.parametrize("example", [
    "examples/quickstart.py",
    "examples/lenet_da_inference.py",
    pytest.param("examples/lenet_full_da.py", marks=pytest.mark.slow),
])
def test_example_runs(example, capsys):
    runpy.run_path(example, run_name="__main__")
    out = capsys.readouterr().out
    assert "✓" in out or "Table I" in out
