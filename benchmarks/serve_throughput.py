"""Serving-runtime throughput: decode tokens/s and per-token latency through
the DA engine (``mode="auto"``), plus the paged-vs-slot comparison at equal
KV memory.

    PYTHONPATH=src python benchmarks/serve_throughput.py           # full
    PYTHONPATH=src python benchmarks/serve_throughput.py --quick   # CI-sized

Writes ``artifacts/BENCH_serve_decode.json`` (override with ``--out``):

* ``decode``    — tokens/s and p50/p99 inter-token latency for the paged
  runtime at batch 1 / 8 / 32, uniform prompts (pure decode hot loop).
* ``mixed_16``  — a mixed workload of 16 staggered requests with varied
  prompt/output lengths, served by the slot runtime (its dense cache sets
  the memory budget) and by the paged runtime given the SAME number of KV
  token-rows as a page pool but 4× the lanes. ``speedup`` is the paged
  decode-throughput multiple; the acceptance bar is ≥ 2×.

Both engines are warmed (jit caches populated on a prelude workload) before
the measured window, so the numbers are steady-state serving throughput,
not compile time.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

try:  # run as `python benchmarks/serve_throughput.py` (script dir on path)
    from stamp import stamp_and_write
except ImportError:  # imported as a module from the repo root
    from benchmarks.stamp import stamp_and_write

from repro.configs.registry import ARCHS
from repro.core.da import DAConfig
from repro.core.freeze import freeze_model
from repro.models.model import init_model
from repro.serve.engine import Request, ServeEngine
from repro.serve.kvcache import pages_for


def build_cfg():
    # one small serving model for quick and full runs: this benchmark
    # instruments the RUNTIME (scheduling, paging, batching overheads), so
    # the model is sized to keep per-step dispatch+datapath in the regime
    # where runtime efficiency is visible, not buried under BLAS time;
    # quick/full differ in workload volume only
    return dataclasses.replace(
        ARCHS["qwen3-8b"],
        name="qwen3-serve-bench",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab=4000,
        param_dtype="float32",
        compute_dtype="float32",
        remat=False,
        moe_dropless=True,
    )


def _warm(eng, cfg, rng):
    """Compile every step-shape bucket, then exercise the host loop once —
    the measured window is steady-state serving, not XLA compile time."""
    eng.warmup()
    for w in range(2):
        eng.submit(Request(uid=10_000 + w,
                           prompt=rng.integers(0, cfg.vocab, 6),
                           max_new_tokens=2))
    eng.run()


def _measure(eng, cfg, requests):
    uids = [r.uid for r in requests]
    t0 = time.perf_counter()
    for r in requests:
        eng.submit(r)
    done = eng.run()
    wall = time.perf_counter() - t0
    toks = sum(len(done[u].generated) for u in uids)
    itl = []
    for u in uids:
        ts = done[u].token_times
        itl.extend(b - a for a, b in zip(ts, ts[1:]))

    def pct(q):
        return float(np.percentile(itl, q)) * 1e3 if itl else 0.0

    out = {
        "requests": len(uids),
        "out_tokens": toks,
        "wall_s": round(wall, 3),
        "tokens_per_s": round(toks / wall, 2),
        "itl_p50_ms": round(pct(50), 3),
        "itl_p99_ms": round(pct(99), 3),
    }
    spec = eng.metrics().get("spec")
    if spec:  # speculation on: report acceptance + draft/verify effort
        out["spec"] = {
            "provider": spec["provider"],
            "acceptance_rate": round(spec["acceptance_rate"], 4),
            "draft_steps": spec["draft_steps"],
            "verify_steps": spec["verify_steps"],
            "disabled_requests": spec["disabled_requests"],
            "enabled_requests": spec["enabled_requests"],
        }
    return out


def bench_decode(frozen, cfg, batch, max_new, max_len, kv_dtype=None):
    eng = ServeEngine(cfg, frozen, batch_size=batch, max_len=max_len,
                      runtime="paged", kv_dtype=kv_dtype)
    rng = np.random.default_rng(0)
    _warm(eng, cfg, rng)
    reqs = [Request(uid=u, prompt=rng.integers(0, cfg.vocab, 8),
                    max_new_tokens=max_new) for u in range(batch)]
    return _measure(eng, cfg, reqs)


def bench_mixed(frozen, cfg, repeats: int, kv_dtype=None):
    """16 staggered requests, varied prompt/output lengths, both runtimes at
    equal KV memory.

    The production-shaped scenario: ``max_len`` is provisioned for the
    worst-case request, most requests are far shorter. The dense slot cache
    must reserve ``max_len`` rows per lane no matter what — at this memory
    budget that is 2 lanes. The paged pool holds exactly the same KV
    token-rows, but 16 lanes share it page-by-page, so short requests only
    occupy what they actually use and ~8× more requests decode
    concurrently. Engines are measured in interleaved repeats (CPU wall
    clocks are noisy); the best run of each is compared."""
    # geometry note: total page demand (16 × pages(prompt+max_new)) is kept
    # at ≈ pool capacity — overcommitting a pool this small just converts
    # throughput into preemption replays for both admission policies
    slot_batch, page_size, max_len = 2, 8, 192
    plo, phi, olo, ohi = (4, 12, 12, 20)
    rng = np.random.default_rng(1)

    def workload(base_uid):
        r = np.random.default_rng(2)
        return [Request(uid=base_uid + u,
                        prompt=r.integers(0, cfg.vocab,
                                          int(r.integers(plo, phi))),
                        max_new_tokens=int(r.integers(olo, ohi)))
                for u in range(16)]

    eng_s = ServeEngine(cfg, frozen, batch_size=slot_batch, max_len=max_len,
                        runtime="slots")
    _warm(eng_s, cfg, rng)
    n_pages = slot_batch * pages_for(max_len, page_size) + 1
    eng_p = ServeEngine(cfg, frozen, batch_size=16, max_len=max_len,
                        runtime="paged", page_size=page_size, n_pages=n_pages,
                        admission="optimistic", prefill_lanes=8,
                        prefill_chunk=4, kv_dtype=kv_dtype)
    _warm(eng_p, cfg, rng)

    runs = {"slots": [], "paged": []}
    for rep in range(repeats):
        runs["slots"].append(_measure(eng_s, cfg, workload(1000 * (rep + 1))))
        pe0 = eng_p.metrics()["preemptions"]
        m = _measure(eng_p, cfg, workload(1000 * (rep + 1)))
        m["preemptions"] = eng_p.metrics()["preemptions"] - pe0
        runs["paged"].append(m)

    out = {
        "slots": max(runs["slots"], key=lambda m: m["tokens_per_s"]),
        "paged": max(runs["paged"], key=lambda m: m["tokens_per_s"]),
        "slots_runs": [m["tokens_per_s"] for m in runs["slots"]],
        "paged_runs": [m["tokens_per_s"] for m in runs["paged"]],
    }
    out["kv_token_rows"] = slot_batch * max_len
    out["speedup"] = round(
        out["paged"]["tokens_per_s"] / out["slots"]["tokens_per_s"], 2)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="CI-sized run")
    ap.add_argument("--repeats", type=int, default=None,
                    help="interleaved measurement repeats (default 3; 2 quick)")
    ap.add_argument("--kv-dtype", default=None,
                    choices=["fp16", "int8", "int4"],
                    help="KV page precision for the paged engines (the "
                         "dedicated equal-bytes sweep is benchmarks/"
                         "kv_quant.py; this re-times the runtime at one "
                         "precision)")
    ap.add_argument("--out", default="artifacts/BENCH_serve_decode.json")
    args = ap.parse_args()
    repeats = args.repeats or (2 if args.quick else 3)

    cfg = build_cfg()
    params = init_model(jax.random.key(0), cfg)
    # pin_modes=False keeps shape-aware dispatch live on the frozen artifact:
    # each serving shape (decode [B,1], chunked prefill [Bp,chunk]) picks its
    # own backend instead of inheriting the m_hint decode-bucket plan
    art = freeze_model(params, DAConfig(x_signed=True), mode="auto",
                       m_hint=8, model_cfg=cfg, pin_modes=False)
    del params

    max_new = 8 if args.quick else 32
    decode = {}
    for batch in (1, 8, 32):
        decode[f"b{batch}"] = bench_decode(art.params, cfg, batch, max_new,
                                           max_len=64,
                                           kv_dtype=args.kv_dtype)
        print(f"decode b={batch:<3d} {decode[f'b{batch}']}")

    mixed = bench_mixed(art.params, cfg, repeats, kv_dtype=args.kv_dtype)
    print(f"mixed slots  {mixed['slots']}  runs={mixed['slots_runs']}")
    print(f"mixed paged  {mixed['paged']}  runs={mixed['paged_runs']}")
    print(f"speedup (equal KV memory, 16 staggered requests): "
          f"{mixed['speedup']}x")

    result = {
        "bench": "serve_decode",
        "model": cfg.name,
        "da_mode": "auto",
        "quick": args.quick,
        "kv_dtype": args.kv_dtype or "fp16",
        "decode": decode,
        "mixed_16": mixed,
    }
    stamp_and_write(args.out, result, seed=0)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
