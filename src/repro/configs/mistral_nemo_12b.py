"""mistral-nemo-12b [hf:mistralai/Mistral-Nemo-Base-2407; hf] — 128k ctx.

40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072; head_dim is an
explicit 128 (q_dim 4096 != d_model), rope_theta 1e6.
"""
from repro.configs.registry import register
from repro.models.config import ModelConfig

CONFIG = register(ModelConfig(
    name="mistral-nemo-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=131072,
    rope_theta=1_000_000.0,
))
