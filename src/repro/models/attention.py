"""GQA attention with RoPE/M-RoPE, qk-norm, KV caches (dense-slot KVCache or
page-table-indexed PagedKVCache), flash-style chunking."""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.engine import PackedWeights as _Packed
from repro.core.engine import da_qkv_matmul
from repro.core.linear import dense
from repro.launch.sharding import constrain
from repro.models.config import ModelConfig
from repro.models import kv_quant as _kvq
from repro.models.layers import apply_rope, rms_norm_headwise, rope_angles

NEG_INF = -1e30


class KVCache(NamedTuple):
    """Static-shape decode cache. k/v: [B, S_max, n_kv, hd]; length: scalar."""

    k: jax.Array
    v: jax.Array
    length: jax.Array  # int32 tokens already written

    @staticmethod
    def zeros(cfg: ModelConfig, batch: int, max_len: int, dtype):
        shape = (batch, max_len, cfg.n_kv_heads, cfg.head_dim_)
        return KVCache(
            k=jnp.zeros(shape, dtype=dtype),
            v=jnp.zeros(shape, dtype=dtype),
            length=jnp.zeros((), dtype=jnp.int32),
        )


class PagedKVCache(NamedTuple):
    """Paged decode cache for one attention layer (or a period stack).

    k/v: ``[n_pages, page_size, n_kv, hd]`` — batch-free; rows of a request
    live on the physical pages its page table names. The host-side pool
    allocator / page tables / defrag live in ``repro.serve.kvcache``; this
    container sits beside :class:`KVCache` because attention indexes it.

    Quantized pools (``kv_dtype`` int8/int4) store int8 codes in k/v (int4
    packs two nibbles per byte along hd) and carry per-(slot, head) dequant
    scales ``[n_pages, page_size, n_kv, 1]`` float16 in k_scale/v_scale —
    rank-4 pool leaves like k/v, so every page-granular pool operation
    (remap, COW copy, defrag, sharding) moves scales together with values
    with zero special-casing.  Unquantized pools leave the scales ``None``
    (an empty pytree subtree: today's layout, byte-for-byte).  Pages are
    self-describing — readers infer the format from the arrays via
    :func:`repro.models.kv_quant.kv_format`, never from config plumbing.
    """

    k: jax.Array
    v: jax.Array
    k_scale: Optional[jax.Array] = None
    v_scale: Optional[jax.Array] = None

    @staticmethod
    def zeros(cfg: ModelConfig, n_pages: int, page_size: int, dtype,
              kv_dtype: str = "fp16"):
        hd = cfg.head_dim_
        if kv_dtype not in _kvq.KV_DTYPES:
            raise ValueError(f"unknown kv_dtype {kv_dtype!r}; expected one "
                             f"of {_kvq.KV_DTYPES}")
        if kv_dtype == "fp16":  # escape hatch: compute-dtype pages, no scales
            shape = (n_pages, page_size, cfg.n_kv_heads, hd)
            return PagedKVCache(k=jnp.zeros(shape, dtype=dtype),
                                v=jnp.zeros(shape, dtype=dtype))
        if kv_dtype == "int4" and hd % 2:
            raise ValueError(
                f"kv_dtype='int4' packs two nibbles per byte along head_dim; "
                f"head_dim={hd} is odd and cannot pack")
        hd_p = hd // 2 if kv_dtype == "int4" else hd
        shape = (n_pages, page_size, cfg.n_kv_heads, hd_p)
        sshape = (n_pages, page_size, cfg.n_kv_heads, 1)
        return PagedKVCache(
            k=jnp.zeros(shape, dtype=jnp.int8),
            v=jnp.zeros(shape, dtype=jnp.int8),
            k_scale=jnp.zeros(sshape, dtype=_kvq.KV_SCALE_DTYPE),
            v_scale=jnp.zeros(sshape, dtype=_kvq.KV_SCALE_DTYPE),
        )

    @property
    def page_size(self) -> int:
        return self.k.shape[-3]


def init_attention(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    dt = cfg.pdtype()
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    s = 1.0 / (d ** 0.5)
    so = 1.0 / (qd ** 0.5)
    p = {
        "wq": (jax.random.normal(ks[0], (d, qd)) * s).astype(dt),
        "wk": (jax.random.normal(ks[1], (d, kvd)) * s).astype(dt),
        "wv": (jax.random.normal(ks[2], (d, kvd)) * s).astype(dt),
        "wo": (jax.random.normal(ks[3], (qd, d)) * so).astype(dt),
    }
    if cfg.attn_bias:
        p["bq"] = jnp.zeros((qd,), dtype=dt)
        p["bk"] = jnp.zeros((kvd,), dtype=dt)
        p["bv"] = jnp.zeros((kvd,), dtype=dt)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((cfg.head_dim_,), dtype=dt)
        p["k_norm"] = jnp.ones((cfg.head_dim_,), dtype=dt)
    return p


def _fusable_qkv(*ws) -> bool:
    """The q/k/v artifacts can share one DA pass: all PackedWeights, 2-D,
    one DAConfig, one contraction dim (always true for a frozen attention
    layer; MoE-stacked or mixed float/packed params fall back)."""
    return (
        all(isinstance(w, _Packed) and w.wq.ndim == 2 for w in ws)
        and len({w.cfg for w in ws}) == 1
        and len({w.k for w in ws}) == 1
    )


def _project_qkv(p, x, cfg: ModelConfig, positions):
    b, t, _ = x.shape
    hd = cfg.head_dim_
    if _fusable_qkv(p["wq"], p["wk"], p["wv"]):
        # Frozen DA layer: quantize/decompose the activations once and run
        # the three projections as one fused engine pass (bit-identical to
        # the separate dense() calls — see da_qkv_matmul).
        yq, yk, yv = da_qkv_matmul(x, (p["wq"], p["wk"], p["wv"]))
        q = yq.astype(x.dtype) + (p.get("bq", 0))
        k = yk.astype(x.dtype) + (p.get("bk", 0))
        v = yv.astype(x.dtype) + (p.get("bv", 0))
    else:
        q = dense(x, p["wq"]) + (p.get("bq", 0))
        k = dense(x, p["wk"]) + (p.get("bk", 0))
        v = dense(x, p["wv"]) + (p.get("bv", 0))
    q = q.reshape(b, t, cfg.n_heads, hd)
    k = k.reshape(b, t, cfg.n_kv_heads, hd)
    v = v.reshape(b, t, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm_headwise(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm_headwise(k, p["k_norm"], cfg.norm_eps)
    ang = rope_angles(positions, hd, cfg.rope_theta, cfg.mrope_sections)
    q = apply_rope(q, ang)
    k = apply_rope(k, ang)
    q = constrain(q, ("batch", "seq", "heads", "head_dim"))
    k = constrain(k, ("batch", "seq", "kv_heads", "head_dim"))
    v = constrain(v, ("batch", "seq", "kv_heads", "head_dim"))
    return q, k, v


def _repeat_kv(k, n_heads: int):
    """[B,S,Kv,hd] → [B,S,H,hd]. Materializing full heads keeps the score
    tensor [B,H,T,S] cleanly shardable on the 16-way model axis (H divides;
    the raw kv-head count usually doesn't) — train/prefill only; decode keeps
    the grouped form to avoid inflating KV-cache reads."""
    kv = k.shape[2]
    if kv == n_heads:
        return k
    return jnp.repeat(k, n_heads // kv, axis=2)


def _gqa_scores(q, k):
    """q: [B,T,H,hd], k: [B,S,Kv,hd] → scores [B,Kv,G,T,S] (H = Kv·G)."""
    b, t, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    qg = q.reshape(b, t, kv, g, hd)
    return jnp.einsum("btkgd,bskd->bkgts", qg, k) / (hd ** 0.5)


def _gqa_out(probs, v):
    """probs: [B,Kv,G,T,S], v: [B,S,Kv,hd] → [B,T,H,hd]."""
    b, kv, g, t, s = probs.shape
    out = jnp.einsum("bkgts,bskd->btkgd", probs, v)
    return out.reshape(b, t, kv * g, v.shape[-1])


def _masked_softmax(scores, mask, softmax_dtype, mask_mode: str):
    """Mask + softmax with the §Perf levers, cfg-free (the engine's
    paged-attention backends pass the levers as plain arguments).

    L3a additive: one fused add of a ±0/−inf bias instead of compare+select
    (one fewer full-tensor pass, no bool materialization).
    L3b softmax_dtype: bf16 score pipeline halves every pass's bytes; the
    row-max subtraction keeps it stable (|exp arg| ≤ ~40 in bf16)."""
    sd = jnp.dtype(softmax_dtype)
    scores = scores.astype(sd)
    if mask_mode == "additive":
        bias = jnp.where(mask, jnp.array(0.0, sd), jnp.array(NEG_INF, sd))
        scores = scores + bias
    else:
        scores = jnp.where(mask, scores, jnp.array(NEG_INF, sd))
    return jax.nn.softmax(scores, axis=-1)


def _apply_mask_softmax(scores, mask, cfg: ModelConfig):
    return _masked_softmax(scores, mask, cfg.softmax_dtype, cfg.attn_mask_mode)


def _decode_attention(q, k, v, mask, cfg: ModelConfig):
    """Grouped GQA attention over the cache (decode: T small)."""
    scores = _gqa_scores(q, k)
    probs = _apply_mask_softmax(scores, mask[:, None, None], cfg).astype(q.dtype)
    return _gqa_out(probs, v)


def _naive_attention(q, k, v, mask, cfg: ModelConfig):
    """Full-head attention. q [B,T,H,hd], k/v [B,S,Kv,hd]; mask [..,T,S]."""
    h, hd = q.shape[2], q.shape[3]
    kf = _repeat_kv(k, h)
    vf = _repeat_kv(v, h)
    scores = jnp.einsum("bthd,bshd->bhts", q, kf) / (hd ** 0.5)
    probs = _apply_mask_softmax(scores, mask, cfg).astype(q.dtype)
    return jnp.einsum("bhts,bshd->bthd", probs, vf)


def causal_bias(t: int, dtype=jnp.float32) -> jax.Array:
    """Additive causal bias [T, T], built ONCE per step (L8): inside the
    layer scan GSPMD re-materializes it with a 4 GB all-gather per layer;
    hoisted, it is computed/gathered once and reused by every layer."""
    pos = jnp.arange(t)
    bias = jnp.where(pos[None, :] <= pos[:, None], 0.0, NEG_INF)
    return constrain(bias.astype(dtype), (None, None))


def _lean_attention(q, k, v, cfg: ModelConfig, bias):
    """§Perf lever L8: structurally minimal causal attention.

      * pre-scales q (the 1/√d multiply lands on the small [B,T,H,hd] tensor),
      * ONE hoisted additive causal bias (no per-layer mask construction),
      * max/sub-exp/sum,
      * the 1/l normalization lands on the [B,T,H,hd] *output* (S× smaller).
    """
    h, hd = q.shape[2], q.shape[3]
    kf = _repeat_kv(k, h)
    vf = _repeat_kv(v, h)
    qs = (q * (hd ** -0.5)).astype(q.dtype)
    scores = jnp.einsum("bthd,bshd->bhts", qs, kf).astype(jnp.float32)
    scores = scores + bias[None, None]
    m = jnp.max(scores, axis=-1)
    p = jnp.exp(scores - m[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhts,bshd->bthd", p.astype(q.dtype), vf)
    return o / l.transpose(0, 2, 1)[..., None].astype(o.dtype)


def _chunked_attention(q, k, v, q_offset: int, chunk: int, unroll: bool = False):
    """Flash-style online-softmax over KV chunks (pure JAX, differentiable).

    Causal: query at absolute position q_offset+i attends to kv ≤ that pos.
    Full-head form (kv repeated) so every tensor shards on the heads axis.
    """
    b, t, h, hd = q.shape
    kf = _repeat_kv(k, h)
    vf = _repeat_kv(v, h)
    s = kf.shape[1]
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        kf = jnp.pad(kf, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_chunks = kf.shape[1] // chunk
    kc = kf.reshape(b, n_chunks, chunk, h, hd).transpose(1, 0, 2, 3, 4)
    vc = vf.reshape(b, n_chunks, chunk, h, hd).transpose(1, 0, 2, 3, 4)
    qpos = q_offset + jnp.arange(t)

    def body(carry, xs):
        m, l, acc = carry
        ci, kb, vb = xs
        kpos = ci * chunk + jnp.arange(chunk)
        sc = jnp.einsum("bthd,bshd->bhts", q, kb).astype(jnp.float32)
        sc = sc / (hd ** 0.5)
        valid = (kpos[None, :] <= qpos[:, None]) & (kpos[None, :] < s)
        sc = jnp.where(valid[None, None], sc, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(sc, axis=-1))
        p = jnp.exp(sc - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhts,bshd->bhtd", p.astype(q.dtype), vb)
        acc_new = acc * corr[..., None].astype(acc.dtype) + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h, t), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((b, h, t), dtype=jnp.float32)
    a0 = jnp.zeros((b, h, t, hd), dtype=q.dtype)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (jnp.arange(n_chunks), kc, vc),
        unroll=n_chunks if unroll else 1,
    )
    out = acc / jnp.maximum(l, 1e-20)[..., None].astype(acc.dtype)
    return out.transpose(0, 2, 1, 3)  # [B,T,H,hd]


def paged_gather_read(q, k_pool, v_pool, page_table, tpos, *,
                      softmax_dtype="float32", mask_mode: str = "where",
                      k_scale=None, v_scale=None):
    """Gather-based paged-attention read (the ``"gather"`` engine backend).

    Gathers each row's page table back into a contiguous ``[B, S, kv, hd]``
    view of the batch-free pool and runs masked grouped-GQA attention over
    it — the XLA-native execution the fused Pallas kernel is measured
    against. ``kpos <= tpos`` masks unwritten cache, pad lanes and the
    garbage column in one comparison.

    Quantized pools pass the in-page scales (``[P, ps, kv, 1]``); the codes
    and their scales ride the SAME gather and dequantize elementwise
    (``kv_quant.dequantize_kv``) before the unchanged attention math — an
    elementwise map commutes with the gather, so each gathered element is
    bitwise the value the fused kernel dequantizes in-register.
    """
    b = q.shape[0]
    fmt = _kvq.kv_format(k_pool, k_scale, q.shape[-1])
    kg = k_pool[page_table]            # [B, W, ps, kv, hd(/2 for int4)]
    vg = v_pool[page_table]
    if fmt != "fp":
        kg = _kvq.dequantize_kv(kg, k_scale[page_table], fmt, q.dtype)
        vg = _kvq.dequantize_kv(vg, v_scale[page_table], fmt, q.dtype)
    kg = kg.reshape(b, -1, kg.shape[-2], kg.shape[-1])
    vg = vg.reshape(b, -1, vg.shape[-2], vg.shape[-1])
    kg = constrain(kg, ("batch", "kv_seq", "kv_heads", "head_dim"))
    vg = constrain(vg, ("batch", "kv_seq", "kv_heads", "head_dim"))
    kpos = jnp.arange(kg.shape[1])
    mask = kpos[None, None, :] <= tpos[:, :, None]    # [B, T, S] causal+length
    scores = _gqa_scores(q, kg)
    probs = _masked_softmax(scores, mask[:, None, None], softmax_dtype,
                            mask_mode).astype(q.dtype)
    return _gqa_out(probs, vg)


def _paged_attention(q, k, v, cache: PagedKVCache, page_table, tpos,
                     cfg: ModelConfig):
    """Page-table-indexed cache write + backend-dispatched attention read.

    Writes each token's K/V row at ``(page_table[b, pos // ps], pos % ps)``
    in the batch-free pool, then runs the attention read through the engine's
    paged-attention backend registry — ``cfg.paged_attn`` picks the XLA
    gather read or the fused Pallas page-walk kernel (``"auto"`` defers to
    the autotune cost table / platform heuristic per shape bucket). One code
    path serves decode (T=1), chunked prefill (T=chunk, earlier chunks
    visible through the pool) and any coalesced mix — pad lanes carry
    positions inside the garbage column, whose logical positions exceed
    every real ``tpos``, so ``kpos <= tpos`` masks them out of real rows
    exactly as it masks unwritten cache beyond a row's length.
    """
    from repro.core.engine import get_attn_backend, select_attn_backend

    b, t = tpos.shape
    ps = cache.page_size
    fmt = _kvq.kv_format(cache.k, cache.k_scale, q.shape[-1])
    b_idx = jnp.arange(b)[:, None]
    page_ids = page_table[b_idx, tpos // ps]          # [B, T] physical pages
    off = tpos % ps
    pool_axes = ("page", "page_slot", "kv_heads", "head_dim")
    if fmt == "fp":
        ck = cache.k.at[page_ids, off].set(k.astype(cache.k.dtype))
        cv = cache.v.at[page_ids, off].set(v.astype(cache.v.dtype))
        cks = cvs = None
    else:
        # quantize at scatter time: each row gets its own per-head absmax
        # scale (write-once — see repro.models.kv_quant), and the scales
        # scatter to the same (page, slot) the codes do
        qk, sk = _kvq.quantize_kv(k, fmt)
        qv, sv = _kvq.quantize_kv(v, fmt)
        ck = cache.k.at[page_ids, off].set(qk)
        cv = cache.v.at[page_ids, off].set(qv)
        cks = cache.k_scale.at[page_ids, off].set(sk)
        cvs = cache.v_scale.at[page_ids, off].set(sv)
        cks = constrain(cks, pool_axes)
        cvs = constrain(cvs, pool_axes)
    ck = constrain(ck, pool_axes)
    cv = constrain(cv, pool_axes)
    new_cache = PagedKVCache(k=ck, v=cv, k_scale=cks, v_scale=cvs)
    name = select_attn_backend(getattr(cfg, "paged_attn", "auto"),
                               batch=b, t=t,
                               kv_len=page_table.shape[1] * ps)
    y = get_attn_backend(name).fn(
        q, ck, cv, page_table, tpos,
        softmax_dtype=cfg.softmax_dtype, mask_mode=cfg.attn_mask_mode,
        k_scale=cks, v_scale=cvs,
    )
    return y, new_cache


def attention_forward(
    p,
    x,
    cfg: ModelConfig,
    positions,
    cache: Optional[KVCache] = None,
    update_cache: bool = False,
    attn_bias: Optional[jax.Array] = None,
    page_table: Optional[jax.Array] = None,
):
    """Train fwd (cache=None), prefill (update_cache), or decode (T small,
    cache holds the past). Returns (y, new_cache)."""
    b, t, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg, positions)
    new_cache = None
    if isinstance(cache, PagedKVCache):
        if page_table is None:
            raise ValueError("a PagedKVCache requires a page_table operand")
        tpos = positions[..., 0] if positions.ndim == 3 else positions  # [B,T]
        y, new_cache = _paged_attention(q, k, v, cache, page_table, tpos, cfg)
        y = dense(y.reshape(b, t, cfg.q_dim), p["wo"])
        return constrain(y, ("batch", "seq", "embed")), new_cache
    if cache is not None:
        # Position-driven cache writes: each batch row writes its own segment
        # (continuous batching → ragged per-slot lengths). positions[..., 0]
        # is the temporal coordinate under M-RoPE.
        tpos = positions[..., 0] if positions.ndim == 3 else positions  # [B,T]
        if cfg.cache_mode == "slice":
            # L9: uniform positions — dynamic_update_slice at a scalar start
            # is GSPMD-local; the per-row scatter below makes the partitioner
            # all-gather the full-batch update per layer.
            start = tpos[0, 0]
            ck = jax.lax.dynamic_update_slice(
                cache.k, k.astype(cache.k.dtype), (0, start, 0, 0)
            )
            cv = jax.lax.dynamic_update_slice(
                cache.v, v.astype(cache.v.dtype), (0, start, 0, 0)
            )
        else:
            b_idx = jnp.arange(b)[:, None]
            ck = cache.k.at[b_idx, tpos].set(k.astype(cache.k.dtype))
            cv = cache.v.at[b_idx, tpos].set(v.astype(cache.v.dtype))
        ck = constrain(ck, ("batch", "kv_seq", "kv_heads", "head_dim"))
        cv = constrain(cv, ("batch", "kv_seq", "kv_heads", "head_dim"))
        new_cache = KVCache(k=ck, v=cv, length=cache.length + t)
        if update_cache and t > 1:
            # Prefill: the segment attention below sees ONLY the fresh
            # segment, so it is correct iff the cache was empty. A second
            # chunk against a warm dense cache would silently attend past
            # nothing before itself — error instead of returning garbage.
            # (cache.length is concrete on the eager path; under jit it is
            # a tracer and the fresh-cache invariant is the caller's
            # contract, as in the slot runtime's in-trace prefill.)
            try:
                warm = int(cache.length) > 0
            except (jax.errors.ConcretizationTypeError,
                    jax.errors.TracerIntegerConversionError, TypeError):
                warm = False
            if warm:
                raise ValueError(
                    "chunked prefill into a warm dense KVCache is not "
                    f"supported: the cache already holds {int(cache.length)} "
                    "tokens the fresh-segment attention cannot see. Prefill "
                    "the whole prompt in one call, or use the paged runtime "
                    "(PagedKVCache), whose attention read covers earlier "
                    "chunks through the page pool."
                )
        else:
            # decode: attend over the whole cache with a per-row length mask
            s = ck.shape[1]
            kpos = jnp.arange(s)
            mask = kpos[None, None, :] <= tpos[:, :, None]  # [B,T,S]
            y = _decode_attention(q, ck, cv, mask, cfg)
            y = dense(y.reshape(b, t, cfg.q_dim), p["wo"])
            return constrain(y, ("batch", "seq", "embed")), new_cache

    # train / prefill self-attention over the current segment
    if cfg.attn_impl == "lean":
        bias = attn_bias if attn_bias is not None else causal_bias(t)
        y = _lean_attention(q, k, v, cfg, bias)
    elif cfg.attn_chunk_q and t > cfg.attn_chunk_q:
        y = _chunked_attention(
            q, k, v, q_offset=0, chunk=cfg.attn_chunk_q, unroll=cfg.scan_unroll
        )
    else:
        tpos_c = jnp.arange(t)
        mask = (tpos_c[None, :] <= tpos_c[:, None])[None, None]  # [1,1,T,S]
        y = _naive_attention(q, k, v, mask, cfg)
    y = dense(y.reshape(b, t, cfg.q_dim), p["wo"])
    return constrain(y, ("batch", "seq", "embed")), new_cache
