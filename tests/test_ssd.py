"""Mamba-2 SSD: chunked algorithm == naive recurrence == step chain.

Randomized coverage is seeded-numpy + parametrize (no hypothesis dependency):
sequence lengths are drawn per seed so every chunk-boundary regime (t <
chunk, t == chunk, ragged tail) is exercised deterministically.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.mamba2 import ssd_chunked, ssd_step


def _naive(x, dt, a, bm, cm):
    b, t, h, p = x.shape
    g, s = bm.shape[2], bm.shape[3]
    rep = h // g
    state = np.zeros((b, h, p, s), np.float32)
    ys = np.zeros_like(x)
    for i in range(t):
        bf = np.repeat(bm[:, i], rep, axis=1)
        cf = np.repeat(cm[:, i], rep, axis=1)
        decay = np.exp(dt[:, i] * a[None, :])
        state = state * decay[:, :, None, None] + np.einsum(
            "bhp,bhs->bhps", x[:, i] * dt[:, i][..., None], bf
        )
        ys[:, i] = np.einsum("bhps,bhs->bhp", state, cf)
    return ys, state


@pytest.mark.parametrize("chunk", [1, 4, 16, 64])
@pytest.mark.parametrize("t,groups", [(1, 1), (16, 2), (33, 1), (33, 2)])
def test_ssd_chunked_matches_recurrence(t, chunk, groups):
    # t spans every chunk-boundary regime: t < chunk, t == chunk, ragged tail
    rng = np.random.default_rng(t * 97 + chunk * 7 + groups)
    b, h, p, s = 2, 4, 8, 8
    x = rng.normal(size=(b, t, h, p)).astype(np.float32)
    dt = (np.abs(rng.normal(size=(b, t, h))) * 0.2).astype(np.float32)
    a = -np.abs(rng.normal(size=(h,))).astype(np.float32)
    bm = rng.normal(size=(b, t, groups, s)).astype(np.float32)
    cm = rng.normal(size=(b, t, groups, s)).astype(np.float32)
    want_y, want_state = _naive(x, dt, a, bm, cm)
    y, state = ssd_chunked(*map(jnp.asarray, (x, dt, a, bm, cm)), chunk)
    np.testing.assert_allclose(np.asarray(y), want_y, atol=2e-5, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(state), want_state, atol=2e-5, rtol=2e-4)


def test_ssd_continuation_and_step(rng):
    """prefill(0:t0) + step-by-step decode == full scan (the long_500k path)."""
    b, t, h, p, g, s = 1, 20, 2, 4, 1, 8
    x = rng.normal(size=(b, t, h, p)).astype(np.float32)
    dt = (np.abs(rng.normal(size=(b, t, h))) * 0.2).astype(np.float32)
    a = -np.abs(rng.normal(size=(h,))).astype(np.float32)
    bm = rng.normal(size=(b, t, g, s)).astype(np.float32)
    cm = rng.normal(size=(b, t, g, s)).astype(np.float32)
    want_y, want_state = _naive(x, dt, a, bm, cm)
    t0 = 11
    y0, st0 = ssd_chunked(*map(jnp.asarray, (x[:, :t0], dt[:, :t0], a, bm[:, :t0], cm[:, :t0])), 4)
    st = st0
    ys = [np.asarray(y0)]
    for i in range(t0, t):
        y1, st = ssd_step(st, *map(jnp.asarray, (x[:, i], dt[:, i], a, bm[:, i], cm[:, i])))
        ys.append(np.asarray(y1)[:, None])
    got = np.concatenate(ys, axis=1)
    np.testing.assert_allclose(got, want_y, atol=2e-5, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(st), want_state, atol=2e-5, rtol=2e-4)


def test_ssd_gradients_finite(rng):
    import jax

    b, t, h, p, g, s = 1, 16, 2, 4, 1, 4
    x = jnp.asarray(rng.normal(size=(b, t, h, p)), dtype=jnp.float32)
    dt = jnp.asarray(np.abs(rng.normal(size=(b, t, h))) * 0.2)
    a = -jnp.abs(jnp.asarray(rng.normal(size=(h,)), dtype=jnp.float32))
    bm = jnp.asarray(rng.normal(size=(b, t, g, s)), dtype=jnp.float32)
    cm = jnp.asarray(rng.normal(size=(b, t, g, s)), dtype=jnp.float32)
    grad = jax.grad(lambda xx: jnp.sum(ssd_chunked(xx, dt, a, bm, cm, 4)[0] ** 2))(x)
    assert bool(jnp.all(jnp.isfinite(grad)))
