"""Block/paged KV cache for the continuous-batching serving runtime.

The paper's premise (freeze-once serve-many) puts all serving cost in the
decode hot loop, and the dominant state there is the KV cache. The dense
slot layout (``[B, max_len, kv, hd]`` per layer) reserves worst-case memory
for every batch row; this module replaces it with a vLLM-style paged layout:

* **Page pool** — each attention layer owns ``k``/``v`` pools of shape
  ``[n_pages, page_size, n_kv, hd]``. Pages are the allocation unit; a
  request's KV lives on whichever physical pages the allocator handed it.
* **Page table** — per request, a host-side list of physical page ids; the
  device sees an int32 ``[B, table_width]`` array each step. Attention
  *writes* scatter ``(page_id, offset)``-addressed rows into the pool and
  *reads* gather the table back into a contiguous ``[B, S, kv, hd]`` view —
  models index the cache through the table, never through dense slots.
* **Garbage page** — physical page 0 is reserved. Pad tokens (batch lanes
  that carry fewer real tokens than the step bucket) and unallocated table
  entries point at it, so one fixed-shape jitted step serves any mix of
  chunked-prefill and decode lanes: pad writes land in garbage, and the
  per-row position mask keeps garbage out of every real row's softmax.

The pool is functional state (threaded through jit like any cache); the
allocator and tables are host state owned by the scheduler.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.attention import PagedKVCache  # noqa: F401  (re-export)
from repro.models.config import ModelConfig

#: Physical page reserved for pad-token writes and unallocated table slots.
GARBAGE_PAGE = 0


def init_paged_caches(cfg: ModelConfig, n_pages: int, page_size: int,
                      dtype) -> Dict[str, PagedKVCache]:
    """Paged decode caches stacked over periods: {pos_i: [P, n_pages, ...]}.

    Only attention mixers page (KV grows with the sequence); Mamba state is
    O(1) per request and gains nothing from paging — models with mamba
    mixers serve through the dense-slot runtime instead.
    """
    caches: Dict[str, PagedKVCache] = {}
    for pos in range(cfg.period):
        if cfg.mixer_kind(pos) != "attn":
            raise ValueError(
                f"paged KV caches cover attention mixers only; layer position "
                f"{pos} is {cfg.mixer_kind(pos)!r} (serve this arch with the "
                f"slot runtime)"
            )
        template = PagedKVCache.zeros(cfg, n_pages, page_size, dtype)
        caches[f"pos_{pos}"] = jax.tree.map(
            lambda a: jnp.zeros((cfg.n_periods,) + a.shape, a.dtype), template
        )
    return caches


def pages_for(n_tokens: int, page_size: int) -> int:
    """Pages needed to hold ``n_tokens`` tokens."""
    return -(-n_tokens // page_size)


def table_width(max_len: int, page_size: int) -> int:
    """Device page-table width: pages covering ``max_len`` + the garbage
    column (the last logical page, where pad positions point)."""
    return pages_for(max_len, page_size) + 1


def pad_position(max_len: int, page_size: int) -> int:
    """The logical position pad tokens write to — start of the garbage
    column. Strictly greater than every real position (< max_len rounded up
    to pages), so ``kpos <= tpos`` masks it out of every real row."""
    return (table_width(max_len, page_size) - 1) * page_size


def table_array(tables: Sequence[Sequence[int]], width: int) -> np.ndarray:
    """Host page-table lists → dense int32 [B, width] device operand.

    Unallocated entries (and the trailing garbage column) point at
    GARBAGE_PAGE; logical positions beyond a row's allocation are never
    admitted by the position mask, so the placeholder is read-safe.
    """
    out = np.full((len(tables), width), GARBAGE_PAGE, dtype=np.int32)
    for i, t in enumerate(tables):
        if len(t) > width - 1:
            raise ValueError(f"row {i} holds {len(t)} pages > table width "
                             f"{width} (garbage column excluded)")
        out[i, : len(t)] = t
    return out


class PagePool:
    """Host-side physical-page allocator (free list + stats).

    ``alloc`` returns ``None`` on exhaustion instead of raising — the
    scheduler turns that into queue backpressure (requests wait) or
    preemption, never a crash.
    """

    def __init__(self, n_pages: int):
        if n_pages < 2:
            raise ValueError("pool needs >= 2 pages (page 0 is the garbage page)")
        self.n_pages = n_pages
        self._free: deque = deque(range(1, n_pages))  # page 0 reserved
        self._allocs = 0
        self._frees = 0

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.n_pages - 1 - len(self._free)

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        """n physical pages, or None (backpressure) if the pool can't cover
        the request — partial allocations are never handed out."""
        if n > len(self._free):
            return None
        self._allocs += n
        return [self._free.popleft() for _ in range(n)]

    def free(self, pages: Sequence[int]) -> None:
        for p in pages:
            if not 1 <= p < self.n_pages:
                raise ValueError(f"freeing invalid page {p}")
            self._free.append(p)
        self._frees += len(pages)

    def stats(self) -> Dict[str, int]:
        return {
            "n_pages": self.n_pages,
            "free_pages": self.free_pages,
            "used_pages": self.used_pages,
            "alloc_count": self._allocs,
            "free_count": self._frees,
        }


# ---------------------------------------------------------------------------
# checkpoint / rollback: undo speculative page growth without leaks
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PageCheckpoint:
    """Snapshot of one request's page-table length + the pool counters,
    taken before a speculative (draft) allocation burst.

    Rolling back frees exactly the pages allocated since the checkpoint —
    pushed back onto the *head* of the free list in reverse allocation
    order, so with no interleaved alloc/free the pool's free list, counters
    and the page table end up bit-identical to never having speculated.
    Stale KV written into the rolled-back pages needs no scrubbing: the
    per-row position mask (``kpos <= tpos``) keeps unaccepted positions out
    of every softmax, and any future owner overwrites a page's rows before
    its positions become readable.
    """

    n_pages: int   # len(table) at checkpoint


def checkpoint(pool: PagePool, table: Sequence[int]) -> PageCheckpoint:
    """Snapshot ``table`` (one request's physical-page list) against ``pool``."""
    del pool  # kept in the signature so the snapshot point is explicit
    return PageCheckpoint(n_pages=len(table))


def rollback(pool: PagePool, table: List[int], ckpt: PageCheckpoint,
             keep: Optional[int] = None) -> List[int]:
    """Release pages allocated after ``ckpt``, keeping the first ``keep``.

    ``keep`` defaults to the checkpointed length (full rollback); a spec
    round that accepted some tokens passes ``keep=pages_for(accepted_ctx)``
    to retain the prefix that now holds verified KV.  Returns the freed
    pages.  The free list is restored head-first in reverse allocation
    order and the allocation counter is un-counted (a rolled-back draft was
    never an allocation, not an alloc+free pair), so with no interleaved
    activity a full rollback leaves the pool state bit-identical to the
    checkpoint — the leak-proofness the rollback test asserts, including
    across a later defrag.  Under interleaved allocations from other
    requests the free-list *order* may differ, but membership and counters
    stay exact.
    """
    keep = ckpt.n_pages if keep is None else max(keep, ckpt.n_pages)
    if keep > len(table):
        return []
    dropped = table[keep:]
    for p in dropped:  # validate BEFORE mutating: error → state untouched
        if not 1 <= p < pool.n_pages:
            raise ValueError(f"rolling back invalid page {p}")
    del table[keep:]
    for p in reversed(dropped):
        pool._free.appendleft(p)
    pool._allocs -= len(dropped)
    return dropped


# ---------------------------------------------------------------------------
# defrag: compact live pages into the low-index prefix of the pool
# ---------------------------------------------------------------------------
def _remap_pages(leaf: jax.Array, src: jax.Array, dst: jax.Array) -> jax.Array:
    """Move pool pages src[i] → dst[i] on the pages axis (axis 0 for a
    per-layer pool, axis 1 under the period stack)."""
    axis = leaf.ndim - 4  # [..., n_pages, page_size, kv, hd]
    moved = jnp.take(leaf, src, axis=axis)
    if axis == 0:
        return leaf.at[dst].set(moved)
    if axis == 1:
        return leaf.at[:, dst].set(moved)
    raise ValueError(f"unexpected pool rank {leaf.ndim}")


def defrag(caches, pool: PagePool, tables: List[List[int]]):
    """Compact live pages to the front of the pool.

    With full page-table indirection, pool fragmentation never costs decode
    time — this exists to shrink the live footprint (snapshot / pool resize:
    after compaction the high-water mark is ``used_pages + 1``). Returns the
    remapped cache tree and rewrites ``pool``/``tables`` host state in place.
    Decode output is bit-identical before and after (pages move, the tables
    move with them).
    """
    live = sorted({p for t in tables for p in t})
    mapping = {src: dst for dst, src in enumerate(live, start=1)}
    moves = [(s, d) for s, d in mapping.items() if s != d]
    if moves:
        src = jnp.asarray([s for s, _ in moves], dtype=jnp.int32)
        dst = jnp.asarray([d for _, d in moves], dtype=jnp.int32)
        caches = jax.tree.map(lambda leaf: _remap_pages(leaf, src, dst), caches)
    for t in tables:
        t[:] = [mapping[p] for p in t]
    pool._free = deque(range(len(live) + 1, pool.n_pages))
    return caches
