"""Compat shim: model-level DA freezing moved to :mod:`repro.core.freeze`.

The old surface — ``freeze_model_da(params, cfg, mode=...)`` threading one
execution mode through every layer — is preserved for existing call sites,
but it now delegates to the artifact pipeline's planner: under
``mode="auto"`` each layer gets its own (backend, group size, lut-or-not)
plan from measured autotune timings with the analytic hardware model as the
cache-less fallback.  New code should use :func:`repro.core.freeze.freeze_model`
directly — it returns the full :class:`~repro.core.freeze.DAArtifact`
(plan included) which :func:`repro.core.freeze.save_artifact` persists for
serve-from-disk boots.

Importing this module emits a :class:`DeprecationWarning`; every in-repo
call site now imports from :mod:`repro.core.freeze`.
"""
from __future__ import annotations

import warnings

warnings.warn(
    "repro.serve.quantize is a compat shim; import from repro.core.freeze "
    "instead (the shim will be removed once external callers migrate)",
    DeprecationWarning,
    stacklevel=2,
)

from repro.core.freeze import (  # noqa: E402,F401
    DA_LEAF_NAMES,
    SKIP_CONTEXT,
    DAArtifact,
    LayerPlan,
    da_memory_report,
    freeze_model,
    freeze_model_da,
    load_artifact,
    plan_model,
    save_artifact,
)
