"""Mamba-2 (SSD, state-space duality) block [arXiv:2405.21060].

Chunked SSD algorithm: intra-chunk quadratic (attention-like) term + an
inter-chunk state recurrence, so memory stays O(T·Q) instead of O(T·H·P·S).
Decode is the O(1) single-step recurrence on (conv_state, ssm_state) — this is
what makes the ssm/hybrid archs runnable at seq 524 288 (long_500k).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.linear import dense
from repro.launch.sharding import constrain
from repro.models.config import ModelConfig


class MambaCache(NamedTuple):
    conv: jax.Array  # [B, conv-1, conv_channels] rolling window
    ssm: jax.Array   # [B, H, P, S] state

    @staticmethod
    def zeros(cfg: ModelConfig, batch: int, dtype):
        return MambaCache(
            conv=jnp.zeros((batch, cfg.ssm_conv - 1, cfg.conv_channels), dtype),
            ssm=jnp.zeros(
                (batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
                jnp.float32,
            ),
        )


def init_mamba(key, cfg: ModelConfig):
    ks = jax.random.split(key, 5)
    dt = cfg.pdtype()
    d = cfg.d_model
    di, h = cfg.d_inner, cfg.ssm_heads
    gs = cfg.ssm_groups * cfg.ssm_state
    proj_out = 2 * di + 2 * gs + h  # z, x, B, C, dt
    s = 1.0 / (d ** 0.5)
    return {
        "in_proj": (jax.random.normal(ks[0], (d, proj_out)) * s).astype(dt),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, cfg.conv_channels))
                   * 0.2).astype(dt),
        "conv_b": jnp.zeros((cfg.conv_channels,), dtype=dt),
        "A_log": jnp.log(
            jnp.linspace(1.0, 16.0, h, dtype=jnp.float32)
        ),
        "D": jnp.ones((h,), dtype=jnp.float32),
        "dt_bias": jnp.zeros((h,), dtype=jnp.float32),
        "norm_scale": jnp.ones((di,), dtype=dt),
        "out_proj": (jax.random.normal(ks[4], (di, d)) / (di ** 0.5)).astype(dt),
    }


def _split_proj(cfg: ModelConfig, zxbcdt):
    di, gs, h = cfg.d_inner, cfg.ssm_groups * cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di : di + di + 2 * gs]
    dt = zxbcdt[..., di + di + 2 * gs :]
    assert dt.shape[-1] == h
    return z, xbc, dt


def _causal_conv(xbc, w, b, cache_conv=None):
    """Depthwise causal conv over time. xbc: [B,T,C]; w: [K,C]."""
    k = w.shape[0]
    if cache_conv is not None:
        ctx = jnp.concatenate([cache_conv.astype(xbc.dtype), xbc], axis=1)
    else:
        ctx = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    new_conv = ctx[:, -(k - 1) :, :] if k > 1 else None
    windows = [ctx[:, i : i + xbc.shape[1], :] for i in range(k)]
    y = sum(wi[None, None] * win for wi, win in zip(w, windows)) + b[None, None]
    return jax.nn.silu(y), new_conv


def _gated_norm(y, z, scale, eps):
    g = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(g), axis=-1, keepdims=True)
    return g * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)


def ssd_chunked(x, dt, a, bmat, cmat, chunk: int,
                init_state: Optional[jax.Array] = None, unroll: bool = False):
    """Chunked SSD scan.

    x:    [B,T,H,P] (already dt-scaled NOT applied; raw head inputs)
    dt:   [B,T,H]   (positive step sizes)
    a:    [H]       (negative decay rates)
    bmat: [B,T,G,S]; cmat: [B,T,G,S]
    Returns (y [B,T,H,P], final_state [B,H,P,S]).
    """
    btot, t, h, p = x.shape
    g = bmat.shape[2]
    rep = h // g
    q = min(chunk, t)
    pad = (-t) % q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
    tt = t + pad
    nc = tt // q

    xf = x.astype(jnp.float32).reshape(btot, nc, q, h, p)
    dtf = dt.astype(jnp.float32).reshape(btot, nc, q, h)
    bf = bmat.astype(jnp.float32).reshape(btot, nc, q, g, 1, bmat.shape[-1])
    cf = cmat.astype(jnp.float32).reshape(btot, nc, q, g, 1, cmat.shape[-1])
    bf = jnp.broadcast_to(bf, bf.shape[:3] + (g, rep, bf.shape[-1])).reshape(
        btot, nc, q, h, -1
    )
    cf = jnp.broadcast_to(cf, cf.shape[:3] + (g, rep, cf.shape[-1])).reshape(
        btot, nc, q, h, -1
    )

    dta = dtf * a[None, None, None, :]              # [B,C,Q,H] (negative)
    cs = jnp.cumsum(dta, axis=2)                    # inclusive cumsum
    total = cs[:, :, -1, :]                         # [B,C,H]
    dtx = xf * dtf[..., None]                       # dt-scaled inputs

    # intra-chunk: Y_ij = exp(cs_i - cs_j) · (C_i·B_j) · dtx_j   (j ≤ i)
    li = cs[:, :, :, None, :] - cs[:, :, None, :, :]      # [B,C,Q,Q,H]
    tri = jnp.tril(jnp.ones((q, q), dtype=bool))
    # mask BEFORE exp: upper-triangle li is positive (cs is decreasing), and
    # exp(+big) would poison gradients through the where.
    li = jnp.where(tri[None, None, :, :, None], li, -jnp.inf)
    decay = jnp.exp(li)
    cb = jnp.einsum("bcihs,bcjhs->bcijh", cf, bf)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", cb * decay, dtx)

    # chunk summary states: S_c = Σ_j exp(total − cs_j) dtx_j ⊗ B_j
    decay_out = jnp.exp(total[:, :, None, :] - cs)         # [B,C,Q,H]
    s_c = jnp.einsum("bcjh,bcjhp,bcjhs->bchps", decay_out, dtx, bf)

    # inter-chunk recurrence over the (few) chunks
    h0 = (
        init_state.astype(jnp.float32)
        if init_state is not None
        else jnp.zeros((btot, h, p, bf.shape[-1]), jnp.float32)
    )

    def body(carry, xs):
        tot_c, s_cc = xs  # [B,H], [B,H,P,S]
        new = carry * jnp.exp(tot_c)[:, :, None, None] + s_cc
        return new, carry  # emit state at *start* of chunk

    (h_last, h_starts) = jax.lax.scan(
        body,
        h0,
        (total.transpose(1, 0, 2), s_c.transpose(1, 0, 2, 3, 4)),
        unroll=nc if unroll else 1,
    )
    h_starts = h_starts.transpose(1, 0, 2, 3, 4)  # [B,C,H,P,S]

    # inter-chunk contribution: C_i · (H_start · exp(cs_i))
    y_inter = jnp.einsum("bcihs,bchps,bcih->bcihp", cf, h_starts, jnp.exp(cs))

    y = (y_intra + y_inter).reshape(btot, tt, h, p)[:, :t]
    return y, h_last


def ssd_step(state, x, dt, a, bmat, cmat):
    """Single decode step. state: [B,H,P,S]; x: [B,H,P]; dt: [B,H];
    bmat/cmat: [B,G,S]. Returns (y [B,H,P], new_state)."""
    h = x.shape[1]
    g = bmat.shape[1]
    rep = h // g
    bf = jnp.repeat(bmat.astype(jnp.float32), rep, axis=1)  # [B,H,S]
    cf = jnp.repeat(cmat.astype(jnp.float32), rep, axis=1)
    dta = jnp.exp(dt.astype(jnp.float32) * a[None, :])      # [B,H]
    upd = jnp.einsum("bhp,bhs->bhps", x.astype(jnp.float32) * dt[..., None], bf)
    new_state = state * dta[:, :, None, None] + upd
    y = jnp.einsum("bhps,bhs->bhp", new_state, cf)
    return y, new_state


def mamba_forward(
    p,
    x,
    cfg: ModelConfig,
    cache: Optional[MambaCache] = None,
    update_cache: bool = False,
) -> Tuple[jax.Array, Optional[MambaCache]]:
    """Mamba-2 block. Train (cache=None), prefill (update_cache), or decode."""
    b, t, _ = x.shape
    h, pdim, s = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    zxbcdt = dense(x, p["in_proj"])
    zxbcdt = constrain(zxbcdt, ("batch", "seq", "inner"))
    z, xbc, dt_raw = _split_proj(cfg, zxbcdt)

    conv_in = cache.conv if cache is not None else None
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_in)
    gs = cfg.ssm_groups * cfg.ssm_state
    xs = xbc[..., : cfg.d_inner]
    bmat = xbc[..., cfg.d_inner : cfg.d_inner + gs].reshape(
        b, t, cfg.ssm_groups, s
    )
    cmat = xbc[..., cfg.d_inner + gs :].reshape(b, t, cfg.ssm_groups, s)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["A_log"])
    xh = xs.reshape(b, t, h, pdim)

    if cache is not None and t == 1 and not update_cache:
        y1, new_ssm = ssd_step(
            cache.ssm, xh[:, 0], dt[:, 0], a, bmat[:, 0], cmat[:, 0]
        )
        y = y1[:, None]
    else:
        init = cache.ssm if cache is not None else None
        y, new_ssm = ssd_chunked(
            xh, dt, a, bmat, cmat, cfg.ssm_chunk, init, unroll=cfg.scan_unroll
        )

    y = y + p["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(b, t, cfg.d_inner)
    y = _gated_norm(y, z, p["norm_scale"], cfg.norm_eps).astype(x.dtype)
    out = dense(y, p["out_proj"])
    out = constrain(out, ("batch", "seq", "embed"))
    new_cache = None
    if cache is not None:
        new_cache = MambaCache(conv=new_conv.astype(cache.conv.dtype), ssm=new_ssm)
    return out, new_cache
