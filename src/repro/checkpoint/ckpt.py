"""Fault-tolerant checkpointing: atomic, checksummed, async, elastic.

Layout: ``<dir>/step_<n>/arrays.npz`` + ``manifest.json`` (tree structure,
shapes, dtypes, crc32 per array, step). Writes go to ``step_<n>.tmp`` and are
renamed only after fsync — a crash mid-write never corrupts the latest valid
checkpoint. ``restore`` device_puts each leaf with the *target* sharding, so
a run can restart on a different mesh (elastic re-scaling) or a different
device count: resharding is a device_put, not a format concern.

Async mode hands the (host-side) arrays to a writer thread so the train loop
only blocks for the device→host copy, not the disk write.
"""
from __future__ import annotations

import json
import os
import queue
import re
import shutil
import threading
import zlib
from typing import Any, Optional

import jax
import numpy as np

_SEP = "/"


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = leaf
    return flat


def _path_str(entry) -> str:
    if hasattr(entry, "key"):
        return str(entry.key)
    if hasattr(entry, "idx"):
        return str(entry.idx)
    if hasattr(entry, "name"):
        return str(entry.name)
    return str(entry)


def _np_dtype(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes  # bfloat16/fp8 — numpy custom dtypes (ships w/ jax)

        return np.dtype(getattr(ml_dtypes, name))


def _savable(v: np.ndarray) -> np.ndarray:
    """npz can't store ml_dtypes (bf16/fp8) — byte-view them; the manifest
    records the true dtype for restore."""
    if v.dtype.kind == "V" or v.dtype.name not in np.sctypeDict:
        return np.ascontiguousarray(v).view(np.uint8)
    return v


def save(directory: str, step: int, tree: Any, keep: int = 3) -> str:
    """Synchronous atomic save. Returns the checkpoint path."""
    flat = {k: np.asarray(v) for k, v in _flatten(tree).items()}
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    npz_path = os.path.join(tmp, "arrays.npz")
    np.savez(npz_path, **{k: _savable(v) for k, v in flat.items()})
    manifest = {
        "step": step,
        "arrays": {
            k: {
                "shape": list(v.shape),
                "dtype": str(v.dtype),
                "crc32": zlib.crc32(np.ascontiguousarray(v).tobytes()),
            }
            for k, v in flat.items()
        },
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(directory, keep)
    return final


def _gc(directory: str, keep: int) -> None:
    steps = sorted(all_steps(directory))
    for s in steps[:-keep] if keep else []:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"), ignore_errors=True)


def all_steps(directory: str):
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(directory, name, "manifest.json")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(directory: str) -> Optional[int]:
    steps = all_steps(directory)
    return steps[-1] if steps else None


def restore(directory: str, step: int, template: Any, shardings: Any = None) -> Any:
    """Restore into ``template``'s tree structure; verify checksums; place
    each leaf with the matching entry of ``shardings`` (or template sharding)
    — this is the elastic-restart path."""
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    flat_t = _flatten(template)
    flat_s = _flatten(shardings) if shardings is not None else {}
    out = {}
    for key, tmpl in flat_t.items():
        arr = data[key]
        meta = manifest["arrays"][key]
        true_dtype = _np_dtype(meta["dtype"])
        if arr.dtype != true_dtype:  # byte-viewed exotic dtype
            arr = arr.view(true_dtype).reshape(meta["shape"])
        crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
        if crc != meta["crc32"]:
            raise IOError(f"checksum mismatch for {key} in {path}")
        arr = arr.astype(tmpl.dtype) if hasattr(tmpl, "dtype") else arr
        sh = flat_s.get(key)
        if sh is None and hasattr(tmpl, "sharding"):
            sh = tmpl.sharding
        out[key] = jax.device_put(arr, sh) if sh is not None else arr
    leaves_keys = list(_flatten(template).keys())
    treedef = jax.tree_util.tree_structure(template)
    return jax.tree_util.tree_unflatten(treedef, [out[k] for k in leaves_keys])


class AsyncCheckpointer:
    """Background writer thread; the caller only pays device→host copy time."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._q: "queue.Queue" = queue.Queue(maxsize=2)
        self._exc: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        while True:
            item = self._q.get()
            try:
                if item is None:
                    return
                step, tree = item
                save(self.directory, step, tree, keep=self.keep)
            except BaseException as e:  # surfaced on next submit/close
                self._exc = e
            finally:
                self._q.task_done()

    def submit(self, step: int, tree: Any) -> None:
        if self._exc:
            raise self._exc
        host_tree = jax.tree.map(np.asarray, tree)  # device→host now
        self._q.put((step, host_tree))

    def wait(self) -> None:
        self._q.join()
        if self._exc:
            raise self._exc

    def close(self) -> None:
        self._q.put(None)
        self._thread.join()
        if self._exc:
            raise self._exc
