"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads artifacts/dryrun/*.json (written by repro.launch.dryrun) and prints the
per-cell three-term roofline, dominant bottleneck, MODEL_FLOPS/HLO ratio and
roofline fraction. Does not compile anything itself.

``--artifact DIR`` instead prints the frozen artifact's per-layer DA
hardware cost table (the same ``HardwareCostModel`` rows the scheduler
prices serving with — geometry, pJ/ns per token, bit-slicing
counterfactual): the roofline view of the paper's hardware rather than of
the XLA compile.
"""
from __future__ import annotations

import argparse
import glob
import json
import os

ART = os.environ.get("DRYRUN_DIR", "artifacts/dryrun")


def print_hw_table(artifact_dir: str) -> None:
    from repro.core.freeze import load_artifact

    art = load_artifact(artifact_dir)
    hwm = art.hwcost
    if not hwm:
        print(f"# {artifact_dir}: artifact carries no DA cost model")
        return
    print("# layer,k,n,mode,vmms_per_token,da_pj,da_ns,bs_pj,bs_ns,"
          "energy_ratio,latency_ratio")
    for r in hwm.layer_table():
        print(f"{r['path']},{r['k']},{r['n']},{r['mode']},"
              f"{r['vmms_per_token']},{r['da_pj']:.4g},{r['da_ns']:.4g},"
              f"{r['bs_pj']:.4g},{r['bs_ns']:.4g},"
              f"{r['bs_pj']/r['da_pj']:.3g},{r['bs_ns']/r['da_ns']:.3g}")
    s = hwm.summary()
    print(f"# total: {s['pj_per_token']:.4g} pJ/token "
          f"{s['ns_per_token']:.4g} ns/token over {s['layers']} layers; "
          f"vs bit-sliced x{s['ratios']['energy']:.2f} energy "
          f"x{s['ratios']['latency']:.2f} latency")


def load_cells(pattern: str = "*.json") -> list:
    cells = []
    for path in sorted(glob.glob(os.path.join(ART, pattern))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--artifact", default=None, metavar="DIR",
                    help="print the per-layer DA hardware cost table of a "
                         "frozen artifact instead of the dry-run roofline")
    args = ap.parse_args()
    if args.artifact:
        print_hw_table(args.artifact)
        return
    cells = load_cells()
    if not cells:
        print(f"# no dry-run artifacts under {ART} — run "
              "`python -m repro.launch.dryrun --all` first")
        return
    print("# cell,ok,t_compute_s,t_memory_s,t_collective_s,bottleneck,"
          "useful_flops_frac,roofline_frac")
    n_ok = 0
    for c in cells:
        r = c.get("roofline", {})
        ok = c.get("ok", False)
        n_ok += bool(ok)
        print(
            f"{c['cell']},{ok},"
            f"{r.get('t_compute_s', 0):.3e},{r.get('t_memory_s', 0):.3e},"
            f"{r.get('t_collective_s', 0):.3e},{r.get('bottleneck', '-')},"
            f"{r.get('useful_flops_fraction', 0):.3f},"
            f"{r.get('roofline_fraction', 0):.4f}"
        )
    print(f"# {n_ok}/{len(cells)} cells ok")


if __name__ == "__main__":
    main()
