# One function per paper table/figure. Prints ``name,us_per_call,derived``-
# style CSV blocks per benchmark.
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (
        da_model_scale,
        kernel_micro,
        lenet_conv1,
        roofline_table,
        scaling,
        table1_comparison,
    )

    benches = [
        ("table1_comparison (paper Table I)", table1_comparison.main),
        ("scaling (paper Fig. 5)", scaling.main),
        ("lenet_conv1 (paper Fig. 3, §III-C)", lenet_conv1.main),
        ("kernel_micro", kernel_micro.main),
        ("da_model_scale (beyond-paper)", da_model_scale.main),
        ("roofline_table (§Roofline)", roofline_table.main),
    ]
    failures = 0
    for name, fn in benches:
        print(f"\n===== {name} =====")
        try:
            fn()
        except Exception:
            failures += 1
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
