"""Shared-prefix caching over the paged serving runtime.

The load-bearing properties: decoded tokens are bit-identical with the
cache on or off (greedy, spec on and off) on the same frozen artifact; a
request sharing a ≥2-page prefix performs zero prefill model work for the
shared pages (step/token counters); copy-on-write isolates forks of a
shared prefix; trie eviction converts pool pressure into reclaimed pages
instead of backpressure; defrag keeps cached prefixes hitting; and a mixed
admit/preempt/evict/defrag/rollback run leaks nothing and double-frees
nothing (defrag's refcount-ledger check runs mid-flight)."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.registry import ARCHS, reduce_for_smoke
from repro.core.da import DAConfig
from repro.core.freeze import freeze_model
from repro.models.model import init_model
from repro.serve.engine import Request, ServeEngine
from repro.serve.scheduler import latency_metrics
from repro.spec import SpecConfig

KEY = jax.random.key(0)
MAX_NEW = 4
PS = 8  # page size used throughout: an 18-token shared prefix = 2 full pages


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(reduce_for_smoke(ARCHS["qwen3-8b"]),
                              moe_dropless=True)
    params = init_model(KEY, cfg)
    art = freeze_model(params, DAConfig(x_signed=True),
                       mode="bitplane_stacked", model_cfg=cfg)
    rng = np.random.default_rng(11)
    shared = rng.integers(0, cfg.vocab, 18)
    prompts = {u: np.concatenate([shared, rng.integers(0, cfg.vocab, 3 + u)])
               for u in range(6)}
    return cfg, params, art, prompts


def _run(cfg, params, prompts, prefix_cache, spec=None, **kw):
    kw.setdefault("batch_size", 2)
    kw.setdefault("max_len", 48)
    kw.setdefault("page_size", PS)
    eng = ServeEngine(cfg, params, prefix_cache=prefix_cache, spec=spec, **kw)
    for u, p in prompts.items():
        eng.submit(Request(uid=u, prompt=p, max_new_tokens=MAX_NEW))
    done = eng.run()
    return {u: list(r.generated) for u, r in done.items()}, eng


def _spec():
    return SpecConfig(provider="bitplane", gamma=2, draft_x_bits=6,
                      disable_below=0.0)


def test_tokens_identical_cache_on_off(setup):
    """Acceptance: with prefix caching ON, decoded tokens are bit-identical
    to caching OFF on the same frozen artifact (greedy), and the trie
    actually absorbed the shared prefix."""
    cfg, _, art, prompts = setup
    off, _ = _run(cfg, art.params, prompts, False)
    on, eng = _run(cfg, art.params, prompts, True)
    assert on == off
    m = eng.metrics()
    assert m["prefix_cache"]["cached_tokens"] >= 2 * 16  # ≥2 pages, ≥2 hits
    assert m["prefix_cache"]["hits"] >= 2
    assert 0 < m["prefix_cache"]["hit_rate"] < 1
    # finished requests released everything except the trie's cached pages
    assert m["pool"]["used_pages"] == m["prefix_cache"]["trie_pages"]


def test_tokens_identical_with_spec_and_shared_checkpoints(setup):
    """Acceptance: identity also holds with speculative decoding on — and
    with two IDENTICAL prompts in the mix, spec rounds run on lanes whose
    tables still start with shared pages (checkpoints straddle them); the
    rollback path must only ever touch exclusively-owned draft growth."""
    cfg, _, art, prompts = setup
    prompts = dict(prompts)
    prompts[6] = prompts[5].copy()  # a full-prompt twin → COW + sharing
    off, _ = _run(cfg, art.params, prompts, False, spec=_spec())
    on, eng = _run(cfg, art.params, prompts, True, spec=_spec())
    assert on == off
    m = eng.metrics()
    assert m["spec"]["rounds"] > 0  # speculation actually ran
    assert m["prefix_cache"]["cached_tokens"] > 0
    assert m["pool"]["used_pages"] == m["prefix_cache"]["trie_pages"]


def test_second_request_zero_prefill_for_shared_pages(setup):
    """Acceptance: the second of two requests sharing a ≥2-page prefix runs
    zero prefill model calls for the shared pages — its measured context
    work is exactly the unshared tail plus decode."""
    cfg, _, art, _ = setup
    rng = np.random.default_rng(5)
    shared = rng.integers(0, cfg.vocab, 2 * PS)  # exactly 2 full pages
    eng = ServeEngine(cfg, art.params, batch_size=2, max_len=48,
                      page_size=PS, prefix_cache=True)
    eng.submit(Request(uid=0,
                       prompt=np.concatenate(
                           [shared, rng.integers(0, cfg.vocab, 6)]),
                       max_new_tokens=MAX_NEW))
    eng.run()
    ctx0 = eng.metrics()["ctx_tokens"]
    tail = 5
    eng.submit(Request(uid=1,
                       prompt=np.concatenate(
                           [shared, rng.integers(0, cfg.vocab, tail)]),
                       max_new_tokens=MAX_NEW))
    eng.run()
    m = eng.metrics()
    assert m["prefix_cache"]["cached_tokens"] == 2 * PS
    # request 1 processed ONLY its tail during prefill (the final generated
    # token is emitted, never re-fed): not one model call covered a shared
    # page's tokens
    assert m["ctx_tokens"] - ctx0 == tail + MAX_NEW - 1


def test_cow_divergence_after_shared_prefix_fork(setup):
    """Two requests with the SAME page-aligned prompt: the hit caps at
    len-1, landing inside the last shared page, so the second lane's first
    write copy-on-writes it. Both decodes match the cache-off baseline —
    the fork never scribbles on the sharer's KV."""
    cfg, _, art, _ = setup
    rng = np.random.default_rng(6)
    prompt = rng.integers(0, cfg.vocab, 2 * PS)
    prompts = {0: prompt, 1: prompt.copy()}
    off, _ = _run(cfg, art.params, prompts, False)
    eng = ServeEngine(cfg, art.params, batch_size=2, max_len=48,
                      page_size=PS, prefix_cache=True)
    # serialize so request 1 sees request 0's pages in the trie
    eng.submit(Request(uid=0, prompt=prompts[0], max_new_tokens=MAX_NEW))
    eng.run()
    eng.submit(Request(uid=1, prompt=prompts[1], max_new_tokens=MAX_NEW))
    done = eng.run()
    assert {u: list(r.generated) for u, r in done.items()} == off
    m = eng.metrics()
    assert m["prefix_cache"]["cow_copies"] >= 1
    assert m["prefix_cache"]["cached_tokens"] == 2 * PS - 1
    assert m["pool"]["used_pages"] == m["prefix_cache"]["trie_pages"]


def test_trie_eviction_under_pool_pressure(setup):
    """A pool crowded by cached-but-idle prefixes: a new unrelated request
    reclaims trie pages (LRU) instead of waiting on backpressure forever."""
    cfg, _, art, _ = setup
    rng = np.random.default_rng(8)
    eng = ServeEngine(cfg, art.params, batch_size=1, max_len=32, page_size=4,
                      n_pages=9, prefix_cache=True)
    eng.submit(Request(uid=0, prompt=rng.integers(0, cfg.vocab, 16),
                       max_new_tokens=2))
    eng.run()
    assert eng.metrics()["prefix_cache"]["trie_pages"] == 4
    eng.submit(Request(uid=1, prompt=rng.integers(0, cfg.vocab, 20),
                       max_new_tokens=4))
    done = eng.run()
    assert len(done[1].generated) == 4
    assert eng.metrics()["prefix_cache"]["evictions"] >= 1


def test_defrag_keeps_cached_prefixes_hitting(setup):
    """Defrag renumbers physical pages; trie-held pages move under the same
    remap, so later requests still hit and still decode their exact
    baseline tokens."""
    cfg, _, art, prompts = setup
    off, _ = _run(cfg, art.params, prompts, False)
    eng = ServeEngine(cfg, art.params, batch_size=2, max_len=48,
                      page_size=PS, prefix_cache=True)
    for u in (0, 1):
        eng.submit(Request(uid=u, prompt=prompts[u], max_new_tokens=MAX_NEW))
    eng.run()
    eng._rt.defrag()  # also a ledger check: raises on any leaked page
    cached0 = eng.metrics()["prefix_cache"]["cached_tokens"]
    for u in (2, 3):
        eng.submit(Request(uid=u, prompt=prompts[u], max_new_tokens=MAX_NEW))
    done = eng.run()
    assert {u: list(done[u].generated) for u in (2, 3)} \
        == {u: off[u] for u in (2, 3)}
    assert eng.metrics()["prefix_cache"]["cached_tokens"] - cached0 >= 2 * 16


def test_ownership_stress_no_leaks_no_double_frees(setup):
    """Acceptance: a mixed admit/preempt/evict/defrag/rollback run over a
    tight pool with sharing AND speculation on — tokens stay exactly the
    baseline's, the periodic defrag ledger check never finds a leak, and at
    the end every page is accounted for (lanes empty, trie holds the rest,
    clearing the trie drains the pool to zero)."""
    cfg, _, art, prompts = setup
    off, _ = _run(cfg, art.params, prompts, False, spec=_spec())
    eng = ServeEngine(cfg, art.params, batch_size=3, max_len=48, page_size=4,
                      n_pages=12, admission="optimistic", prefill_chunk=4,
                      prefix_cache=True, spec=_spec())
    for u, p in prompts.items():
        eng.submit(Request(uid=u, prompt=p, max_new_tokens=MAX_NEW))
    ticks = 0
    while eng.step() or eng.queue:
        ticks += 1
        if ticks % 5 == 0:
            eng._rt.defrag()
    assert {u: list(r.generated) for u, r in eng.done.items()} == off
    sched = eng._rt
    m = eng.metrics()
    assert m["pool"]["used_pages"] == m["prefix_cache"]["trie_pages"]
    sched.prefix.clear(sched.pool)
    assert sched.pool.used_pages == 0
    assert sum(sched.pool._ref) == 0  # not one dangling reference


def test_latency_metrics_counts_zero_epoch_first_token():
    """Regression: a first token stamped at wall-clock 0.0 exactly used to
    be dropped by truthiness; and an all-unfinished set must yield zeroed
    keys, not a crash."""
    r = Request(uid=0, prompt=np.arange(3), submit_t=-0.05)
    r.first_token_t = 0.0
    r.token_times = [0.0, 0.01]
    m = latency_metrics([r])
    assert m["ttft_p50_ms"] == pytest.approx(50.0)
    assert m["itl_p50_ms"] == pytest.approx(10.0)
    fresh = Request(uid=1, prompt=np.arange(3))  # no token landed yet
    assert latency_metrics([fresh]) == {
        "ttft_p50_ms": 0.0, "itl_p50_ms": 0.0, "itl_p99_ms": 0.0}
    assert latency_metrics([]) == {
        "ttft_p50_ms": 0.0, "itl_p50_ms": 0.0, "itl_p99_ms": 0.0}


def test_slot_runtime_rejects_prefix_cache(setup):
    cfg, params, _, _ = setup
    with pytest.raises(ValueError, match="prefix"):
        ServeEngine(cfg, params, batch_size=2, max_len=16, runtime="slots",
                    prefix_cache=True)


def test_from_artifact_plumbs_prefix_cache(setup, tmp_path):
    from repro.core.freeze import save_artifact

    cfg, _, art, prompts = setup
    d = save_artifact(str(tmp_path / "art"), art)
    eng = ServeEngine.from_artifact(d, batch_size=2, max_len=48,
                                    page_size=PS, prefix_cache=True)
    assert eng._rt.prefix is not None
    for u in (0, 1):
        eng.submit(Request(uid=u, prompt=prompts[u], max_new_tokens=2))
    eng.run()
    assert eng.metrics()["prefix_cache"]["lookups"] == 2
