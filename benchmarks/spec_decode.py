"""Speculative-decoding throughput: the truncated-bitplane self-draft vs the
non-speculative paged baseline, on one frozen DA artifact.

    PYTHONPATH=src python benchmarks/spec_decode.py            # full
    PYTHONPATH=src python benchmarks/spec_decode.py --quick    # CI-sized

Writes ``artifacts/BENCH_spec_decode.json`` (override with ``--out``):
decode tokens/s at batch 1 and 8 for the baseline paged runtime and for
spec decoding with the ``bitplane`` drafter (plus a ``layerskip`` reference
point), the per-batch ``speedup`` multiples, and the acceptance statistics
the scheduler tracks (acceptance rate, draft/verify step counts,
speculation on/off state).  Everything is stamped with git sha / seed /
device via ``stamp.py`` so the trajectory is comparable across PRs.

Regime notes (what the numbers mean):

* The artifact is pinned to the **serial ``bitplane`` backend** — the
  paper-faithful bit-serial execution, one weight pass per input bit-plane.
  That is the regime the drafter targets: truncating to ``draft_x_bits``
  of ``x_bits`` planes cuts the draft's weight traffic proportionally
  (exactly the paper's cycle-count trade), and a gamma+1-token verify step
  re-reads the same weights once for the whole window.
* The bar (≥ 1.3×) is expected to clear at **batch 1** — the
  weight-read-bound, latency-dominated regime speculative decoding exists
  for.  At batch 8 the XLA-CPU integer matmuls are row-compute-bound (no
  int BLAS), so the verify window pays ~linearly for its rows and the
  measured speedup honestly degrades toward (and below) 1×; the JSON
  records that crossover rather than hiding it.
* The bench model is initialized tied-and-damped (LM head = scaled
  embedding table, attenuated mixer outputs) so its greedy decoding has
  the peaked-logit margins of a *trained* LM.  A raw random init has
  near-zero top-1 margins, every drafter's acceptance collapses to ~0, and
  the auto-disable floor simply switches speculation off — true, but it
  benchmarks nothing.  Acceptance is reported; judge speedup jointly
  with it.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

try:  # run as `python benchmarks/spec_decode.py` (script dir on sys.path)
    from stamp import stamp_and_write
except ImportError:  # imported as a module from the repo root
    from benchmarks.stamp import stamp_and_write

from repro.configs.registry import ARCHS
from repro.core.da import DAConfig
from repro.core.freeze import freeze_model
from repro.models.model import init_model
from repro.serve.engine import Request, ServeEngine
from repro.spec import SpecConfig

SEED = 0


def build_artifact(quick: bool):
    d = 256 if quick else 512
    cfg = dataclasses.replace(
        ARCHS["qwen3-8b"],
        name="qwen3-spec-bench",
        n_layers=4,
        d_model=d,
        n_heads=8,
        n_kv_heads=4,
        head_dim=d // 8,
        d_ff=2 * d,
        vocab=2000 if quick else 8000,
        param_dtype="float32",
        compute_dtype="float32",
        remat=False,
        moe_dropless=True,
    )
    params = init_model(jax.random.key(SEED), cfg)
    # peaked-logit shaping (see module docstring): tie the LM head to a
    # boosted embedding table and damp the mixer/FFN outputs so the
    # residual stream keeps trained-LM-like greedy margins
    params["embed"]["table"] = params["embed"]["table"] * 4.0
    params["lm_head"]["w"] = params["embed"]["table"].T
    for pos in params["periods"]:
        blk = params["periods"][pos]
        blk["mixer"]["wo"] = blk["mixer"]["wo"] * 0.1
        blk["ffn"]["w_down"] = blk["ffn"]["w_down"] * 0.1
    art = freeze_model(params, DAConfig(x_signed=True), mode="bitplane",
                       model_cfg=cfg)
    return cfg, art


def _measure(eng, cfg, batch: int, max_new: int, rng, uid0: int) -> dict:
    reqs = [Request(uid=uid0 + u, prompt=rng.integers(0, cfg.vocab, 8),
                    max_new_tokens=max_new) for u in range(batch)]
    t0 = time.perf_counter()
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    wall = time.perf_counter() - t0
    toks = sum(len(done[r.uid].generated) for r in reqs)
    out = {
        "requests": batch,
        "out_tokens": toks,
        "wall_s": round(wall, 3),
        "tokens_per_s": round(toks / wall, 2),
    }
    spec = eng.metrics().get("spec")
    if spec:
        out["spec"] = {
            "provider": spec["provider"],
            "gamma": spec["gamma"],
            "acceptance_rate": round(spec["acceptance_rate"], 4),
            "draft_steps": spec["draft_steps"],
            "verify_steps": spec["verify_steps"],
            "bonus_tokens": spec["bonus_tokens"],
            "disabled_requests": spec["disabled_requests"],
            "enabled_requests": spec["enabled_requests"],
        }
    return out


def bench(cfg, frozen, batch, max_new, max_len, spec_cfg, repeats, rng):
    """Interleaved repeats (CPU wall clocks are noisy); best run of each."""
    engines = {}
    for key, sc in (("baseline", None), ("spec", spec_cfg)):
        eng = ServeEngine(cfg, frozen, batch_size=batch, max_len=max_len,
                          runtime="paged", spec=sc)
        eng.warmup()
        _measure(eng, cfg, batch, 2, rng, uid0=90_000)  # host-loop warm pass
        engines[key] = eng
    runs = {"baseline": [], "spec": []}
    for rep in range(repeats):
        for key in ("baseline", "spec"):
            runs[key].append(_measure(engines[key], cfg, batch, max_new, rng,
                                      uid0=1000 * (rep + 1)))
    out = {
        "baseline": max(runs["baseline"], key=lambda m: m["tokens_per_s"]),
        "spec": max(runs["spec"], key=lambda m: m["tokens_per_s"]),
        "baseline_runs": [m["tokens_per_s"] for m in runs["baseline"]],
        "spec_runs": [m["tokens_per_s"] for m in runs["spec"]],
    }
    out["speedup"] = round(
        out["spec"]["tokens_per_s"] / out["baseline"]["tokens_per_s"], 3)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="CI-sized run")
    ap.add_argument("--gamma", type=int, default=3,
                    help="draft tokens per round (gamma+1 = verify window; "
                         "3 keeps the window an exact pow2 bucket)")
    ap.add_argument("--draft-bits", type=int, default=4)
    ap.add_argument("--repeats", type=int, default=None,
                    help="interleaved repeats (default 3; 2 quick)")
    ap.add_argument("--out", default="artifacts/BENCH_spec_decode.json")
    args = ap.parse_args()
    repeats = args.repeats or (2 if args.quick else 3)
    max_new = 16 if args.quick else 32
    max_len = 64

    cfg, art = build_artifact(args.quick)
    rng = np.random.default_rng(SEED)
    bp = SpecConfig(provider="bitplane", gamma=args.gamma,
                    draft_x_bits=args.draft_bits)
    ls = SpecConfig(provider="layerskip", gamma=args.gamma)

    result = {
        "bench": "spec_decode",
        "model": cfg.name,
        "da_mode": "bitplane",
        "quick": args.quick,
        "gamma": args.gamma,
        "draft_bits": args.draft_bits,
        "max_new": max_new,
        "bitplane": {},
        "layerskip": {},
    }
    for batch in (1, 8):
        result["bitplane"][f"b{batch}"] = bench(
            cfg, art.params, batch, max_new, max_len, bp, repeats, rng)
        print(f"bitplane  b={batch}: {result['bitplane'][f'b{batch}']}")
    # one layerskip reference point (not part of the acceptance bar)
    result["layerskip"]["b1"] = bench(
        cfg, art.params, 1, max_new, max_len, ls, repeats, rng)
    print(f"layerskip b=1: {result['layerskip']['b1']}")

    stamp_and_write(args.out, result, seed=SEED)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
