"""Bit-slicing baseline emulation (§IV): exact when the ADC has enough
resolution; clips (accuracy loss) when it doesn't.

Randomized coverage is seeded-numpy + parametrize (no hypothesis dependency).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bitslice import BitSliceConfig, adc_bits_required, bitslice_vmm


@pytest.mark.parametrize("seed", range(10))
def test_bitslice_exact_with_sufficient_adc(seed):
    rng = np.random.default_rng(seed)
    m = int(rng.integers(1, 7))
    k = int(rng.integers(1, 31))
    n = int(rng.integers(1, 9))
    signed = bool(rng.integers(0, 2))
    x = (rng.integers(-128, 128, (m, k)) if signed
         else rng.integers(0, 256, (m, k))).astype(np.int32)
    w = rng.integers(-128, 128, (k, n)).astype(np.int32)
    cfg = BitSliceConfig(x_signed=signed, adc_bits=adc_bits_required(k))
    got = np.asarray(bitslice_vmm(jnp.asarray(x), jnp.asarray(w), cfg))
    np.testing.assert_array_equal(got, x @ w)


@pytest.mark.parametrize("k,signed", [
    (1, False), (1, True),        # single-row columns
    (25, False), (25, True),      # the paper's CONV1 depth
    (30, False), (30, True),      # sweep upper bound
])
def test_bitslice_exact_edges(k, signed):
    """Pinned column depths: exactness holds at the resolution boundary."""
    rng = np.random.default_rng(k)
    x = (rng.integers(-128, 128, (4, k)) if signed
         else rng.integers(0, 256, (4, k))).astype(np.int32)
    w = rng.integers(-128, 128, (k, 5)).astype(np.int32)
    cfg = BitSliceConfig(x_signed=signed, adc_bits=adc_bits_required(k))
    got = np.asarray(bitslice_vmm(jnp.asarray(x), jnp.asarray(w), cfg))
    np.testing.assert_array_equal(got, x @ w)


def test_adc_bits_required():
    assert adc_bits_required(25) == 5  # the paper's 5-bit ADC for 25 rows
    assert adc_bits_required(1) == 1
    assert adc_bits_required(255) == 8


def test_insufficient_adc_clips():
    """With all-ones inputs/weights the column count hits K — an ADC below
    log2(K+1) bits must clip and the result must be wrong (this is the
    resolution-pressure the paper's DA approach eliminates)."""
    k = 25
    x = np.full((1, k), 255, dtype=np.int32)
    w = np.full((k, 1), 1, dtype=np.int32)
    exact = bitslice_vmm(jnp.asarray(x), jnp.asarray(w),
                         BitSliceConfig(adc_bits=5))
    clipped = bitslice_vmm(jnp.asarray(x), jnp.asarray(w),
                           BitSliceConfig(adc_bits=3))
    assert np.asarray(exact)[0, 0] == 255 * k
    assert np.asarray(clipped)[0, 0] < 255 * k
