"""Freeze a trained model into DA serving form (the paper's pre-VMM step,
applied model-wide).

Every weight-matrix leaf becomes a :class:`~repro.core.engine.PackedWeights`
artifact: int8 codes + per-column scale (+ materialized weight-sum LUTs below
``lut_cell_limit`` — the paper's PMA contents), built once and shared by every
engine backend.  ``mode`` is any registered engine backend (legacy ``da_*``
spellings are accepted) or ``"auto"`` — then the engine's shape-aware dispatch
picks the backend per layer shape at run time, which is exactly the DAISM-
style "choose the in-memory multiply strategy per layer" policy.  Routers,
norms, biases, embeddings and scalar SSM params stay float: they are not VMMs
(gather / elementwise), noted in DESIGN.md.
"""
from __future__ import annotations

from typing import Any

import jax

from repro.core.da import DAConfig
from repro.core.engine import PackedWeights
from repro.core.linear import freeze_da

# Param leaf names that are weight matrices (x @ W shaped [in, out] or
# batched expert weights [E, in, out]).
DA_LEAF_NAMES = {
    "wq", "wk", "wv", "wo",          # attention projections
    "w_up", "w_gate", "w_down",      # MLP / MoE experts / shared experts
    "in_proj", "out_proj",           # mamba projections
    "w",                             # lm head
}
SKIP_CONTEXT = {"router", "conv_w", "table"}


def freeze_model_da(
    params: Any,
    da_cfg: DAConfig = DAConfig(x_signed=True),
    mode: str = "auto",
    lut_cell_limit: int = 1 << 24,
) -> Any:
    """Walk the param tree; replace weight leaves with packed DA artifacts.

    ``lut_cell_limit`` bounds the LUT blow-up in **cells** per matrix (see
    ``engine.pack_weights``)."""

    def walk(path, leaf):
        names = [_entry_name(p) for p in path]
        last = names[-1] if names else ""
        if last in DA_LEAF_NAMES and last not in SKIP_CONTEXT and leaf.ndim >= 2:
            return freeze_da(leaf, da_cfg, mode=mode, lut_cell_limit=lut_cell_limit)
        return leaf

    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    return jax.tree_util.tree_unflatten(
        treedef, [walk(path, leaf) for path, leaf in flat]
    )


def _entry_name(entry) -> str:
    for attr in ("key", "name", "idx"):
        if hasattr(entry, attr):
            return str(getattr(entry, attr))
    return str(entry)


def da_memory_report(frozen_params: Any) -> dict:
    """The paper's Table-I trade-off at model scale: LUT cells vs weights."""
    weights = luts = mats = 0
    for leaf in jax.tree.leaves(
        frozen_params, is_leaf=lambda x: isinstance(x, PackedWeights)
    ):
        if isinstance(leaf, PackedWeights):
            mats += 1
            weights += leaf.wq.size
            if leaf.luts is not None:
                luts += leaf.luts.size
    return {
        "da_matrices": mats,
        "weight_cells": weights,
        "lut_cells": luts,
        "cell_blowup": (luts / weights) if weights else 0.0,
    }
