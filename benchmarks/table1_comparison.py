"""Paper Table I: DA vs bit-slicing for the 1×25 · 25×6 CONV1 VMM.

Reports latency / energy / area from the calibrated hardware model next to
the paper's values, plus the functional verification that both datapaths
compute the exact integer product.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core.bitslice import BitSliceConfig, adc_bits_required, bitslice_vmm
from repro.core.da import DAConfig
from repro.core.engine import da_vmm, pack_quantized
from repro.core.hwmodel import table1

PAPER = {
    "da_latency_ns": 88.0,
    "bs_latency_ns": 400.0,
    "da_energy_pj": 110.2,
    "da_energy_amortized_pj": 117.0,
    "bs_energy_pj": 1421.5,
    "da_cells": 67584,
    "bs_cells": 1200,
    "da_transistors": 20622,
    "bs_transistors": 47286,
    "bs_resistors": 1584,
    "latency_ratio": 4.5,
    "energy_ratio": 12.0,
}


def run() -> list:
    t = table1(k=25, n=6)
    da, bs = t["da"], t["bitslice"]

    # functional verification on the paper's workload
    rng = np.random.default_rng(0)
    x = rng.integers(0, 256, (784, 25)).astype(np.int32)  # all CONV1 strides
    w = rng.integers(-128, 128, (25, 6)).astype(np.int32)
    packed = pack_quantized(w, cfg=DAConfig())  # pre-VMM: write the PMAs once
    t0 = time.perf_counter()
    got_da = np.asarray(da_vmm(jnp.asarray(x), packed, mode="lut"))
    dt_da = (time.perf_counter() - t0) * 1e6
    got_bs = np.asarray(
        bitslice_vmm(jnp.asarray(x), jnp.asarray(w),
                     BitSliceConfig(adc_bits=adc_bits_required(25)))
    )
    exact = bool((got_da == x @ w).all() and (got_bs == x @ w).all())

    rows = []

    def row(name, model_val, paper_val):
        err = abs(model_val - paper_val) / abs(paper_val) * 100 if paper_val else 0
        rows.append((name, model_val, paper_val, err))

    row("da_latency_ns", da["latency_ns"], PAPER["da_latency_ns"])
    row("bitslice_latency_ns", bs["latency_ns"], PAPER["bs_latency_ns"])
    row("da_energy_pj", da["energy_vmm_pj"], PAPER["da_energy_pj"])
    row("da_energy_amortized_pj", da["energy_amortized_pj"],
        PAPER["da_energy_amortized_pj"])
    row("bitslice_energy_pj", bs["energy_vmm_pj"], PAPER["bs_energy_pj"])
    row("da_memory_cells", da["memory_cells"], PAPER["da_cells"])
    row("bitslice_memory_cells", bs["memory_cells"], PAPER["bs_cells"])
    row("da_transistors", da["transistors"], PAPER["da_transistors"])
    row("bitslice_transistors", bs["transistors"], PAPER["bs_transistors"])
    row("bitslice_resistors", bs["resistors"], PAPER["bs_resistors"])
    row("latency_ratio_x", t["latency_ratio"], PAPER["latency_ratio"])
    row("energy_ratio_x", t["energy_ratio"], PAPER["energy_ratio"])
    rows.append(("functional_exact_784_vmm", float(exact), 1.0, 0.0))
    rows.append(("da_784vmm_wall_us_cpu", dt_da, float("nan"), 0.0))
    return rows


def main(csv=True):
    print("# Table I reproduction (model vs paper)")
    print("name,model,paper,pct_err")
    for name, model, paper, err in run():
        print(f"{name},{model:.4g},{paper:.4g},{err:.2f}")


if __name__ == "__main__":
    main()
