"""Beyond-paper: the DA trade-off at LM scale.

For each assigned architecture: freeze a reduced model through the DA
artifact pipeline (per-layer planner — the DAISM-style policy), report the
LUT-cell blow-up (paper's 56× at CONV1 scale → 32× asymptotically for L=8)
per layer and in aggregate, projected per-VMM energy/latency of a DA ReRAM
engine for each distinct linear-layer shape, and the end-to-end top-1
agreement of DA serving vs float serving on random prompts.

Everything runs through ``repro.core.engine`` / ``repro.core.freeze`` — the
registry is the single execution entry point; no direct ``core.da`` calls.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCHS, reduce_for_smoke
from repro.core import DAConfig
from repro.core.freeze import da_memory_report, freeze_model
from repro.models.model import forward, init_model
from repro.obs.hwcost import HardwareCostModel, da_design


def run(archs=("qwen3-8b", "qwen2-moe-a2.7b", "mamba2-780m")) -> list:
    rows = []
    key = jax.random.key(0)
    for name in archs:
        cfg = dataclasses.replace(reduce_for_smoke(ARCHS[name]),
                                  moe_dropless=True)
        params = init_model(key, cfg)
        art = freeze_model(params, DAConfig(x_signed=True), mode="lut",
                           model_cfg=cfg)
        rep = da_memory_report(art.params)
        toks = jax.random.randint(jax.random.key(1), (2, 12), 0, cfg.vocab)
        ref, _ = forward(params, toks, cfg)
        got, _ = forward(art.params, toks, cfg)
        agree = float(np.mean(np.asarray(
            jnp.argmax(ref, -1) == jnp.argmax(got, -1))))
        rows.append((name, rep["da_matrices"], rep["cell_blowup"], agree))

    # per-layer plan of one planned freeze (mode chosen per shape, LUT bytes
    # vs code bytes — the Table-I trade-off, inspectable per layer)
    cfg = dataclasses.replace(reduce_for_smoke(ARCHS["qwen3-8b"]),
                              moe_dropless=True)
    art = freeze_model(init_model(key, cfg), DAConfig(x_signed=True),
                       mode="auto", m_hint=4)
    for row in da_memory_report(art.params)["layers"]:
        rows.append((
            f"plan_{row['layer']}",
            row["mode"],
            row["lut_bytes"] / 1e3,
            row["code_bytes"] / 1e3,
        ))

    # hardware projection for the real (full-size) layer shapes of qwen3-8b,
    # priced by the same HardwareCostModel the serving scheduler uses
    full = ARCHS["qwen3-8b"]
    shapes = [
        ("qkv_proj", full.d_model, full.q_dim + 2 * full.kv_dim),
        ("mlp_up", full.d_model, full.d_ff),
        ("mlp_down", full.d_ff, full.d_model),
        ("lm_head", full.d_model, full.vocab),
    ]
    hwm = HardwareCostModel.from_shapes(shapes)
    for row in hwm.layer_table():
        d = da_design(row["k"], row["n"])
        rows.append((
            f"hw_{row['path']}_{row['k']}x{row['n']}",
            d.n_arrays,
            row["da_ns"],
            row["da_pj"] * 1e-3,  # nJ
        ))
    return rows


def main():
    print("# DA at LM scale: arch, da_matrices|n_arrays|mode, "
          "blowup|latency_ns|lut_kB, top1_agree|energy_nJ|code_kB")
    for r in run():
        print(",".join(f"{v:.4g}" if isinstance(v, float) else str(v) for v in r))


if __name__ == "__main__":
    main()
