"""Structured findings shared by every analysis layer.

A finding is one violated invariant: which pass raised it, how bad it is,
the offending op (or source line, for lint), the byte payload when the
pass is about data movement, and a hint that tells the reader what the
sanctioned alternative is.  All three layers (graph passes, race checker,
lint) emit these, so the CLI and CI render one table.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Iterable, List, Sequence

#: Finding severities, worst first.  ``error`` fails the CLI/CI gate;
#: ``warning`` is reported but does not gate; ``note`` is informational
#: (e.g. a config the graph passes cannot trace yet).
SEVERITIES = ("error", "warning", "note")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One violated invariant, as ``{pass, severity, op, bytes, hint}``."""

    pass_name: str
    severity: str
    op: str
    hint: str
    bytes: int = 0
    where: str = ""
    step: str = ""

    def __post_init__(self) -> None:
        if self.severity not in SEVERITIES:
            raise ValueError(
                f"severity {self.severity!r} not in {SEVERITIES}"
            )

    def to_json(self) -> Dict[str, Any]:
        return {
            "pass": self.pass_name,
            "severity": self.severity,
            "op": self.op,
            "bytes": self.bytes,
            "hint": self.hint,
            "where": self.where,
            "step": self.step,
        }

    @staticmethod
    def from_json(d: Dict[str, Any]) -> "Finding":
        return Finding(
            pass_name=str(d["pass"]),
            severity=str(d["severity"]),
            op=str(d["op"]),
            hint=str(d.get("hint", "")),
            bytes=int(d.get("bytes", 0)),
            where=str(d.get("where", "")),
            step=str(d.get("step", "")),
        )

    def format(self) -> str:
        loc = f" @ {self.where}" if self.where else ""
        stp = f" [{self.step}]" if self.step else ""
        byt = f" ({self.bytes} B)" if self.bytes else ""
        return (
            f"{self.severity.upper():7s} {self.pass_name}{stp}: "
            f"{self.op}{byt}{loc}\n        hint: {self.hint}"
        )


def errors(findings: Iterable[Finding]) -> List[Finding]:
    """The gate-failing subset."""
    return [f for f in findings if f.severity == "error"]


def render(findings: Sequence[Finding]) -> str:
    """Human-readable report, worst findings first."""
    if not findings:
        return "no findings"
    order = {s: i for i, s in enumerate(SEVERITIES)}
    ranked = sorted(findings, key=lambda f: (order[f.severity], f.pass_name))
    lines = [f.format() for f in ranked]
    n_err = len(errors(findings))
    lines.append(f"{len(findings)} finding(s), {n_err} error(s)")
    return "\n".join(lines)


def dump_json(findings: Sequence[Finding], path: str) -> str:
    """Write findings as a JSON list (the nightly CI upload format)."""
    with open(path, "w") as f:
        json.dump([x.to_json() for x in findings], f, indent=2, sort_keys=True)
        f.write("\n")
    return path
