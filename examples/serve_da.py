"""Serve a small LM with batched requests through the DA-quantized engine —
the paper's setting end-to-end: weights are frozen after training, the
pre-VMM step builds the integer DA artifacts, and every linear layer of the
serving graph runs the multiplier-free datapath.

Run: PYTHONPATH=src python examples/serve_da.py [--requests 8] [--mode auto]

``--mode auto`` runs the per-layer planner: each weight matrix gets its own
(backend, group size, lut-or-not) decision from measured autotune timings
with the analytic hardware model as fallback.

Freeze-once, serve-many::

    # freeze, persist the artifact, then serve from it
    python examples/serve_da.py --save-artifact artifacts/qwen3_20m_da
    # later / elsewhere: cold boot straight off disk — no float weights,
    # no re-packing, the pre-VMM step never runs again
    python examples/serve_da.py --artifact artifacts/qwen3_20m_da
"""
import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs.registry import ARCHS
from repro.models.model import count_params, init_model
from repro.serve.engine import Request, ServeEngine
from repro.core.freeze import da_memory_report


def build_cfg():
    return dataclasses.replace(
        ARCHS["qwen3-8b"],
        name="qwen3-20m",
        n_layers=4,
        d_model=256,
        n_heads=4,
        n_kv_heads=2,
        head_dim=64,
        d_ff=768,
        vocab=8000,
        param_dtype="float32",
        compute_dtype="float32",
        remat=False,
        moe_dropless=True,
    )


def print_plan(eng):
    rep = da_memory_report(eng.params, model_cfg=eng.cfg)
    print(f"{rep['da_matrices']} weight matrices in DA form, "
          f"LUT blow-up {rep['cell_blowup']:.1f}x aggregate")
    for row in rep["layers"][:8]:
        print(f"  {row['layer']:34s} {row['k']}x{row['n']:<6d} "
              f"mode={row['mode']:<17s} codes={row['code_bytes']/1e3:.0f}kB "
              f"luts={row['lut_bytes']/1e3:.0f}kB")
    if len(rep["layers"]) > 8:
        print(f"  ... {len(rep['layers']) - 8} more layers")
    kv = rep.get("kv")
    if kv:
        dts = ",".join(sorted(set(kv["kv_dtypes"].values())))
        print(f"  kv cache [{dts}]: {kv['bytes_per_token']} B/token "
              f"({kv['capacity_multiplier']:.1f}x capacity vs compute-dtype "
              f"pages)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--mode", default="auto",
                    choices=["auto", "lut", "onehot", "bitplane",
                             "bitplane_stacked", "int8", "float",
                             "da_lut", "da_bitplane"])  # legacy aliases
    ap.add_argument("--artifact", default=None, metavar="DIR",
                    help="boot from a persisted DA artifact (no float "
                         "weights, no re-packing)")
    ap.add_argument("--save-artifact", default=None, metavar="DIR",
                    help="after freezing, persist the artifact to DIR")
    ap.add_argument("--runtime", default="auto",
                    choices=["auto", "paged", "slots"],
                    help="serving runtime (auto: paged KV + continuous "
                         "batching for attention stacks)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="KV page size (tokens) for the paged runtime")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="shared-prefix caching: requests sharing a prompt "
                         "prefix (the demo gives every request one) reuse "
                         "its KV pages instead of re-prefilling them")
    ap.add_argument("--paged-attn", default="auto",
                    choices=["auto", "gather", "fused"],
                    help="paged-attention read: XLA gather or the fused "
                         "Pallas page-walk kernel (auto picks per shape "
                         "bucket; tokens identical either way)")
    ap.add_argument("--kv-dtype", default=None,
                    choices=["fp16", "int8", "int4"],
                    help="KV page precision: int8/int4 store quantized codes "
                         "with in-page dequant scales; fp16 keeps compute-"
                         "dtype pages (default: model config / artifact)")
    ap.add_argument("--spec", default=None,
                    choices=["bitplane", "layerskip"],
                    help="self-speculative decoding: draft with a truncated-"
                         "bitplane or early-exit pass over the SAME weights, "
                         "verify in one batched full-precision step (greedy "
                         "output is token-identical)")
    ap.add_argument("--spec-gamma", type=int, default=4,
                    help="draft tokens per speculative round")
    ap.add_argument("--spec-draft-bits", type=int, default=4,
                    help="bit-planes the truncated-bitplane draft evaluates")
    ap.add_argument("--trace-out", default=None, metavar="FILE",
                    help="record lifecycle spans and write a Chrome "
                         "trace_event JSON (loadable in Perfetto)")
    ap.add_argument("--metrics-out", default=None, metavar="FILE",
                    help="write the metrics registry in Prometheus text "
                         "exposition format after the run")
    ap.add_argument("--hw-metrics", nargs="?", const="-", default=None,
                    metavar="FILE",
                    help="print what the run would have cost on the paper's "
                         "DA hardware (metrics()['hw']); with FILE, also "
                         "write the block as schema-stamped JSON")
    args = ap.parse_args()
    if args.save_artifact and args.mode == "float":
        raise SystemExit("--save-artifact requires a DA --mode (not float)")
    if args.artifact and args.save_artifact:
        raise SystemExit("--artifact and --save-artifact are mutually "
                         "exclusive (the artifact already exists on disk)")

    spec = None
    if args.spec:
        from repro.spec import SpecConfig

        if args.spec == "bitplane" and args.mode == "float":
            raise SystemExit("--spec bitplane truncates DA bit-planes; it "
                             "needs a DA --mode (not float)")
        spec = SpecConfig(provider=args.spec, gamma=args.spec_gamma,
                          draft_x_bits=args.spec_draft_bits)

    trace = args.trace_out is not None
    t0 = time.perf_counter()
    if args.artifact:
        eng = ServeEngine.from_artifact(args.artifact, batch_size=args.batch,
                                        max_len=96, runtime=args.runtime,
                                        page_size=args.page_size, spec=spec,
                                        prefix_cache=args.prefix_cache,
                                        paged_attn=args.paged_attn,
                                        kv_dtype=args.kv_dtype, trace=trace)
        cfg = eng.cfg
        print(f"cold boot from {args.artifact} in "
              f"{time.perf_counter()-t0:.1f}s (zero float weights, "
              f"runtime={eng.runtime}, kv_dtype={cfg.kv_dtype})")
        print_plan(eng)
    else:
        cfg = build_cfg()
        params = init_model(jax.random.key(0), cfg)
        print(f"model: {count_params(cfg)/1e6:.1f}M params")
        t0 = time.perf_counter()
        eng = ServeEngine(cfg, params, batch_size=args.batch, max_len=96,
                          da_mode=args.mode,  # per-layer planned freeze
                          runtime=args.runtime, page_size=args.page_size,
                          spec=spec, prefix_cache=args.prefix_cache,
                          paged_attn=args.paged_attn,
                          kv_dtype=args.kv_dtype, trace=trace)
        if args.mode != "float":
            print(f"pre-VMM freeze ({args.mode}) in "
                  f"{time.perf_counter()-t0:.1f}s:")
            print_plan(eng)
        if args.save_artifact:
            path = eng.save_artifact(args.save_artifact)
            print(f"artifact persisted to {path} — re-serve with "
                  f"--artifact {path}")
    rng = np.random.default_rng(0)
    # a shared "system prompt" prefix gives --prefix-cache its workload; off
    # the flag, requests stay fully independent (the PR-3/4 demo shape)
    shared = rng.integers(0, cfg.vocab, 32 if args.prefix_cache else 0)
    t0 = time.perf_counter()
    for uid in range(args.requests):
        eng.submit(Request(
            uid=uid,
            prompt=np.concatenate(
                [shared, rng.integers(0, cfg.vocab, rng.integers(4, 24))]),
            max_new_tokens=int(rng.integers(8, 24)),
        ))
    done = eng.run()
    dt = time.perf_counter() - t0
    total_toks = sum(len(r.generated) for r in done.values())
    print(f"\nserved {len(done)} requests / {total_toks} tokens in {dt:.1f}s "
          f"({total_toks/dt:.1f} tok/s on CPU, continuous batching, "
          f"runtime={eng.runtime}, batch={args.batch})")
    sm = eng.metrics().get("spec")
    if sm:
        print(f"spec[{sm['provider']}]: gamma={sm['gamma']} "
              f"acceptance={sm['acceptance_rate']:.2f} "
              f"rounds={sm['rounds']} bonus={sm['bonus_tokens']} "
              f"disabled={sm['disabled_requests']}")
    pm = eng.metrics().get("prefix_cache")
    if pm:
        print(f"prefix-cache: hit_rate={pm['hit_rate']:.2f} "
              f"cached_tokens={pm['cached_tokens']} hits={pm['hits']}/"
              f"{pm['lookups']} cow={pm['cow_copies']} "
              f"evictions={pm['evictions']}")
    for uid in sorted(done)[:4]:
        print(f"  req {uid}: {len(done[uid].generated)} tokens -> "
              f"{done[uid].generated[:8]}...")
    if args.hw_metrics:
        hm = eng.metrics().get("hw")
        if hm is None:
            print("hw: no DA cost model (--mode float has no DA geometry)")
        else:
            live = hm["live"]
            print(f"hw: {hm['pj_per_token']:.3e} pJ/token over "
                  f"{hm['layers']} DA layers; this run "
                  f"{live['da_pj']:.3e} pJ vs bit-sliced "
                  f"{live['bitslice_pj']:.3e} pJ "
                  f"(x{live['energy_ratio']:.1f} energy, "
                  f"x{live['latency_ratio']:.2f} latency)")
        if args.hw_metrics != "-":
            print(f"hw metrics -> {eng.write_hw_metrics(args.hw_metrics)}")
    if args.trace_out:
        print(f"trace -> {eng.write_trace(args.trace_out)} "
              f"({len(eng.obs.tracer)} events; open in Perfetto)")
    if args.metrics_out:
        print(f"metrics -> {eng.write_metrics(args.metrics_out)}")


if __name__ == "__main__":
    main()
