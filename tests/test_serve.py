"""Serving engine + DA quantized serving (the paper's end-to-end setting)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS, reduce_for_smoke
from repro.core.da import DAConfig
from repro.core.linear import DAFrozenLinear
from repro.models.model import forward, init_model
from repro.serve.engine import Request, ServeEngine
from repro.core.freeze import da_memory_report, freeze_model_da

KEY = jax.random.key(0)


def _cfg(name="qwen3-8b", **kw):
    cfg = dataclasses.replace(reduce_for_smoke(ARCHS[name]), moe_dropless=True)
    return dataclasses.replace(cfg, **kw) if kw else cfg


@pytest.mark.slow
@pytest.mark.parametrize("runtime", ["paged", "slots"])
def test_continuous_batching_matches_offline(runtime):
    cfg = _cfg()
    params = init_model(KEY, cfg)
    eng = ServeEngine(cfg, params, batch_size=2, max_len=32, runtime=runtime)
    rng = np.random.default_rng(1)
    prompts = {uid: rng.integers(0, cfg.vocab, 4 + uid) for uid in range(4)}
    for uid, pr in prompts.items():
        eng.submit(Request(uid=uid, prompt=pr, max_new_tokens=5))
    done = eng.run()
    assert sorted(done) == [0, 1, 2, 3]
    for uid, pr in prompts.items():
        toks = list(pr)
        for _ in range(5):
            lg, _ = forward(params, jnp.asarray(toks, dtype=jnp.int32)[None], cfg)
            toks.append(int(jnp.argmax(lg[0, -1])))
        assert done[uid].generated == toks[len(pr):], uid


@pytest.mark.slow
def test_freeze_model_da_replaces_weights():
    cfg = _cfg()
    params = init_model(KEY, cfg)
    frozen = freeze_model_da(params, DAConfig(x_signed=True), mode="da_lut")
    leaves = jax.tree.leaves(
        frozen, is_leaf=lambda x: isinstance(x, DAFrozenLinear))
    assert any(isinstance(l, DAFrozenLinear) for l in leaves)
    rep = da_memory_report(frozen)
    assert rep["da_matrices"] > 0
    assert rep["cell_blowup"] == pytest.approx(32.0, rel=0.01)  # 2^8/8


@pytest.mark.parametrize("mode", [
    pytest.param("da_lut", marks=pytest.mark.slow), "da_bitplane", "int8",
])
def test_da_serving_close_to_float(mode):
    """DA-frozen model output ≈ float model (int8 quantization error only),
    and the three integer modes are mutually bit-exact."""
    cfg = _cfg()
    params = init_model(KEY, cfg)
    toks = jax.random.randint(jax.random.key(3), (2, 10), 0, cfg.vocab)
    ref, _ = forward(params, toks, cfg)
    frozen = freeze_model_da(params, DAConfig(x_signed=True), mode=mode)
    got, _ = forward(frozen, toks, cfg)
    # top-1 agreement on most positions (quantization-level differences)
    agree = np.mean(
        np.asarray(jnp.argmax(ref, -1) == jnp.argmax(got, -1)))
    assert agree > 0.8, agree
    rel = float(jnp.abs(got - ref).max() / jnp.abs(ref).max())
    assert rel < 0.2


def test_da_modes_mutually_exact():
    cfg = _cfg()
    params = init_model(KEY, cfg)
    toks = jax.random.randint(jax.random.key(4), (1, 6), 0, cfg.vocab)
    outs = []
    for mode in ("da_lut", "da_bitplane", "int8"):
        frozen = freeze_model_da(params, DAConfig(x_signed=True), mode=mode)
        outs.append(np.asarray(forward(frozen, toks, cfg)[0]))
    np.testing.assert_allclose(outs[0], outs[1], atol=1e-5)
    np.testing.assert_allclose(outs[0], outs[2], atol=1e-5)


def test_da_serving_end_to_end_generation():
    """The full paper pipeline: train-time float params → pre-VMM freeze →
    multiplier-free generation through the engine."""
    cfg = _cfg()
    params = init_model(KEY, cfg)
    frozen = freeze_model_da(params, DAConfig(x_signed=True), mode="da_bitplane")
    eng = ServeEngine(cfg, frozen, batch_size=2, max_len=24)
    rng = np.random.default_rng(5)
    eng.submit(Request(uid=0, prompt=rng.integers(0, cfg.vocab, 4),
                       max_new_tokens=4))
    done = eng.run()
    assert len(done[0].generated) == 4


def test_moe_da_serving():
    """Per-expert PMAs: MoE arch serves under DA quantization."""
    cfg = _cfg("qwen2-moe-a2.7b")
    params = init_model(KEY, cfg)
    toks = jax.random.randint(jax.random.key(6), (2, 6), 0, cfg.vocab)
    ref, _ = forward(params, toks, cfg)
    frozen = freeze_model_da(params, DAConfig(x_signed=True), mode="da_bitplane")
    got, _ = forward(frozen, toks, cfg)
    assert bool(jnp.all(jnp.isfinite(got)))
    agree = np.mean(np.asarray(jnp.argmax(ref, -1) == jnp.argmax(got, -1)))
    assert agree > 0.6, agree
