"""Optimized-HLO text parser: the structural view the graph passes read.

Grew out of ``repro.launch.hlo_tools`` (which now re-exports from here).
The original ``_OP_RE`` was a single line-anchored regex; it missed

* multi-line op definitions (a long ``%name =`` wrapped before the result
  type or the op kind),
* tuple result types with *nested* tuples — ``(f32[2], (s32[], u8[]))``
  ended the old ``\\([^)]*\\)`` group at the first ``)``,
* layout-annotated types whose layout carries parenthesized tile
  suffixes (``f32[8,128]{1,0:T(8,128)}``), and
* ops on lines carrying leading region syntax (a computation opener
  ``{`` preceding the first body op on the same line).

This parser scans logical ops instead: physical lines are joined until an
op head (``name = <type> <kind>(``) parses, with balanced-delimiter scans
for tuple types and layouts.  Everything downstream (byte accounting per
op kind, the no-big-gather pass, collective tallies) reads
:func:`iter_ops`.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, Iterator, List, NamedTuple, Optional, Tuple

_DTYPE_BYTES: Dict[str, int] = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_HEAD_RE = re.compile(r"^\s*[{]?\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*")
_KIND_RE = re.compile(r"\s*([\w\-]+)\s*\(")
_TOKEN_TYPE_RE = re.compile(r"\w+\[[\d,]*\]")

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

#: Op kinds that are bookkeeping, not data movement or compute.
_BOOKKEEPING = ("tuple", "parameter", "constant", "get-tuple-element")


class HloOp(NamedTuple):
    """One parsed HLO op: name, kind, result type text, source line."""

    name: str
    kind: str
    type_str: str
    line_no: int
    text: str

    @property
    def result_bytes(self) -> int:
        return shape_bytes(self.type_str)


def shape_bytes(shape_str: str) -> int:
    """Total bytes of every array shape named in ``shape_str`` (tuples sum
    their elements; unknown dtypes contribute nothing)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def shape_dtypes(shape_str: str) -> set:
    """The set of array dtypes named in a result-type string."""
    return {d for d, _ in _SHAPE_RE.findall(shape_str)}


def _balanced_end(text: str, opener: str, closer: str) -> Optional[int]:
    """Index of the delimiter closing ``text[0]``, counting nesting of both
    parens and braces (layouts nest parens inside braces and vice versa)."""
    depth = 0
    for i, ch in enumerate(text):
        if ch in "({":
            depth += 1
        elif ch in ")}":
            depth -= 1
            if depth == 0:
                return i if ch == closer else None
    return None


def _parse_op(text: str, line_no: int) -> Optional[HloOp]:
    """Parse one logical op line; None when ``text`` is not an op."""
    m = _HEAD_RE.match(text)
    if not m:
        return None
    name = m.group(1)
    rest = text[m.end():].lstrip()
    if rest.startswith("("):  # tuple result type (possibly nested)
        end = _balanced_end(rest, "(", ")")
        if end is None:
            return None
        type_str, rest = rest[: end + 1], rest[end + 1:]
    else:  # dtype[dims] with optional layout {..} (tiles nest parens)
        tm = _TOKEN_TYPE_RE.match(rest)
        if not tm:
            return None
        j = tm.end()
        if j < len(rest) and rest[j] == "{":
            end = _balanced_end(rest[j:], "{", "}")
            if end is None:
                return None
            j += end + 1
        type_str, rest = rest[:j], rest[j:]
    km = _KIND_RE.match(rest)
    if not km:
        return None
    return HloOp(name=name, kind=km.group(1), type_str=type_str,
                 line_no=line_no, text=text.strip())


def _starts_op(line: str) -> bool:
    """A physical line opens a new logical op iff its head parses as
    ``name =`` followed by something that can start a result type.  This
    rejects wrapped attribute lines (``metadata={...}``,
    ``backend_config="..."``) whose ``key=`` would fool a bare regex."""
    m = _HEAD_RE.match(line)
    if not m:
        return False
    rest = line[m.end():].lstrip()
    return (not rest or rest.startswith("(")
            or _TOKEN_TYPE_RE.match(rest) is not None)


def iter_ops(hlo_text: str) -> Iterator[HloOp]:
    """Every op in the module, fusion/region bodies included."""
    buf: List[str] = []
    buf_line = 0
    for i, line in enumerate(hlo_text.splitlines(), start=1):
        if _starts_op(line):
            if buf:
                op = _parse_op(" ".join(buf), buf_line)
                if op is not None:
                    yield op
            buf, buf_line = [line], i
        elif buf:
            joined = " ".join(buf)
            if _parse_op(joined, buf_line) is not None:
                # head already complete; trailing operand/attribute lines
                # of a wrapped op carry nothing the parser reads
                continue
            buf.append(line)
    if buf:
        op = _parse_op(" ".join(buf), buf_line)
        if op is not None:
            yield op


def op_kinds(hlo_text: str) -> Dict[str, int]:
    """Op count per kind — the census view the passes branch on."""
    out: Dict[str, int] = defaultdict(int)
    for op in iter_ops(hlo_text):
        out[op.kind] += 1
    return dict(out)


def ops_of_kind(hlo_text: str, kind: str) -> List[Tuple[str, int]]:
    """Every op of one HLO kind, fusion bodies included: (name, result
    bytes), largest first.  E.g. ``ops_of_kind(txt, "gather")`` checks a
    lowering for full-page-table KV gathers — the fused paged-attention
    path must not contain one at the [B, W·ps, kv, hd] view size."""
    out = [(op.name, op.result_bytes) for op in iter_ops(hlo_text)
           if op.kind == kind]
    return sorted(out, key=lambda t: -t[1])


def bytes_by_op_kind(hlo_text: str, k: int = 20) -> List[Tuple[str, int, int]]:
    """Result-shape bytes aggregated by HLO op kind (a proxy for which op
    family dominates traffic): (kind, total bytes, count)."""
    agg: Dict[str, List[int]] = defaultdict(lambda: [0, 0])
    for op in iter_ops(hlo_text):
        if op.kind in _BOOKKEEPING:
            continue
        agg[op.kind][0] += op.result_bytes
        agg[op.kind][1] += 1
    rows = [(kind, v[0], v[1]) for kind, v in agg.items()]
    return sorted(rows, key=lambda t: -t[1])[:k]


def top_ops(hlo_text: str, k: int = 20) -> List[Tuple[str, str, int]]:
    """Largest individual op results (fusion outputs usually dominate)."""
    out = []
    for op in iter_ops(hlo_text):
        if op.kind in ("tuple", "parameter", "get-tuple-element"):
            continue
        out.append((op.name, op.kind, op.result_bytes))
    return sorted(out, key=lambda t: -t[2])[:k]


def top_collectives(hlo_text: str, k: int = 15) -> List[Tuple[str, str, int]]:
    """Largest collective ops: (name, kind, result bytes).  ``-start`` ops
    are counted, their ``-done`` twins are not (the pair is one transfer)."""
    out = []
    for op in iter_ops(hlo_text):
        for base in _COLLECTIVES:
            if op.kind == base or op.kind == base + "-start":
                out.append((op.name, base, op.result_bytes))
                break
    return sorted(out, key=lambda t: -t[2])[:k]


def custom_call_target(op: HloOp) -> str:
    """The ``custom_call_target="..."`` attribute of a custom-call op."""
    m = re.search(r'custom_call_target="([^"]*)"', op.text)
    return m.group(1) if m else ""
