"""Differential verification of the unified DA engine (Lynchpin-style):
EVERY backend in the registry vs the ``xq @ wq`` int32 oracle, over the full
signed/unsigned × x_bits × group_size × K-padding sweep — and all mutually
identical.  A backend added to the registry is swept here automatically.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.da import DAConfig
from repro.core.engine import (
    PackedWeights,
    da_matmul,
    da_vmm,
    pack_quantized,
    pack_weights,
    registered_backends,
)

# K values per group size: a multiple of the group and a non-multiple (the
# zero-padding path through group_addresses / build_luts / the Pallas kernel).
SWEEP = [
    pytest.param(signed, bits, group, k,
                 id=f"{'s' if signed else 'u'}{bits}_g{group}_k{k}")
    for signed in (False, True)
    for bits in (4, 8)
    for group in (4, 8)
    for k in (2 * group, 2 * group + 3)
]


def _case(signed, bits, group, k, m=5, n=7, seed=None):
    rng = np.random.default_rng(
        seed if seed is not None else (signed * 1000 + bits * 100 + group * 10 + k)
    )
    lo, hi = (-(1 << (bits - 1)), 1 << (bits - 1)) if signed else (0, 1 << bits)
    x = rng.integers(lo, hi, (m, k)).astype(np.int32)
    w = rng.integers(-128, 128, (k, n)).astype(np.int32)
    cfg = DAConfig(group_size=group, x_bits=bits, x_signed=signed)
    packed = pack_quantized(w, cfg=cfg, with_luts=True)
    return x, w, cfg, packed


@pytest.mark.parametrize("signed,bits,group,k", SWEEP)
def test_all_backends_bit_exact_vs_oracle(signed, bits, group, k):
    """Every registered backend == integer-matmul oracle, bit for bit."""
    x, w, cfg, packed = _case(signed, bits, group, k)
    oracle = x @ w
    ran = []
    for name, spec in sorted(registered_backends().items()):
        if not spec.supports(cfg, packed.has_luts):
            continue  # capability-gated (e.g. int8 baseline on unsigned codes)
        got = np.asarray(da_vmm(jnp.asarray(x), packed, mode=name, cfg=cfg))
        np.testing.assert_array_equal(
            got, oracle, err_msg=f"backend {name} diverged from the oracle"
        )
        ran.append(name)
    # the sweep must actually exercise the registry, incl. every DA backend
    assert set(ran) >= {
        "lut", "onehot", "bitplane", "bitplane_stacked", "pallas_lut",
        "pallas_bitplane",
    }, ran


def test_capability_specs_honoured():
    """The registry's capability flags describe the backends truthfully."""
    specs = registered_backends()
    # LUT readers declare it; storage-free modes don't
    assert all(specs[n].needs_luts for n in ("lut", "onehot", "pallas_lut"))
    assert not any(
        specs[n].needs_luts
        for n in ("bitplane", "bitplane_stacked", "pallas_bitplane")
    )
    # the int8 baseline is not a DA datapath and never handles unsigned codes
    assert not specs["int8"].is_da
    ucfg = DAConfig(x_signed=False)
    assert not specs["int8"].supports(ucfg, True)
    assert specs["bitplane"].supports(ucfg, False)
    # a needs_luts backend without LUTs is ineligible and refused loudly
    assert not specs["lut"].supports(DAConfig(x_signed=True), False)
    # padding rule: every built-in backend pads ragged K; a non-padding spec
    # would be ineligible there and eligible at group multiples
    scfg = DAConfig(x_signed=True)
    assert all(s.supports(scfg, True, k=13) for s in specs.values())
    rigid = dataclasses.replace(specs["lut"], pads_k=False)
    assert not rigid.supports(scfg, True, k=13)
    assert rigid.supports(scfg, True, k=16)
    x, w, cfg, _ = _case(True, 8, 8, 16)
    no_luts = pack_quantized(w, cfg=cfg, with_luts=False)
    with pytest.raises(ValueError, match="LUTs"):
        da_vmm(jnp.asarray(x), no_luts, mode="lut", cfg=cfg)
    # a cfg override whose group_size disagrees with the packed LUT shape
    # would gather wrong rows — refused loudly instead
    packed8 = pack_quantized(w, cfg=cfg, with_luts=True)
    with pytest.raises(ValueError, match="rows per PMA"):
        da_vmm(jnp.asarray(x), packed8, mode="lut",
               cfg=dataclasses.replace(cfg, group_size=4))


@pytest.mark.parametrize("mode", ["auto", "lut", "bitplane_stacked"])
def test_float_path_through_engine(mode):
    """da_matmul: quantize → backend → dequantize ≈ float matmul, and every
    mode (incl. auto dispatch) lands on the same quantized integers."""
    rng = np.random.default_rng(7)
    x = rng.normal(size=(6, 40)).astype(np.float32)
    w = rng.normal(size=(40, 24)).astype(np.float32)
    packed = pack_weights(jnp.asarray(w))
    y = np.asarray(da_matmul(jnp.asarray(x), packed, mode=mode))
    ref = x @ w
    rel = np.abs(y - ref).max() / np.abs(ref).max()
    assert rel < 0.05, (mode, rel)
    y_bp = np.asarray(da_matmul(jnp.asarray(x), packed, mode="bitplane"))
    np.testing.assert_array_equal(y, y_bp)


def test_moe_vmap_through_engine():
    """Stacked per-expert artifacts [E, K, N] vmap through the engine with
    and without LUTs, matching the per-expert float reference."""
    from repro.core.engine import dense

    rng = np.random.default_rng(3)
    e, c, k, n = 3, 4, 16, 8
    x = jnp.asarray(rng.normal(size=(e, c, k)), dtype=jnp.float32)
    w = jnp.asarray(rng.normal(size=(e, k, n)), dtype=jnp.float32)
    ref = np.asarray(jnp.einsum("ecd,edf->ecf", x, w))
    for mode in ("lut", "bitplane", "auto"):
        packed = pack_weights(w, mode=mode)
        got = np.asarray(dense(x, packed))
        rel = np.abs(got - ref).max() / np.abs(ref).max()
        assert rel < 0.06, (mode, rel)
    # LUT-free artifact still serves the storage-free modes
    packed = pack_weights(w, mode="bitplane", lut_cell_limit=0)
    assert packed.luts is None
    got = np.asarray(dense(x, packed))
    assert np.abs(got - ref).max() / np.abs(ref).max() < 0.06


def test_luts_built_once_and_shared():
    """PackedWeights carries the LUTs; backends read the same object (the
    pre-VMM step is not repeated per call site)."""
    _, w, cfg, packed = _case(True, 8, 8, 16)
    assert packed.has_luts
    x = np.arange(3 * 16, dtype=np.int32).reshape(3, 16) % 100 - 50
    a = da_vmm(jnp.asarray(x), packed, mode="lut", cfg=cfg)
    b = da_vmm(jnp.asarray(x), packed, mode="onehot", cfg=cfg)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # replacing LUTs (different dataclass) is the only way to change them
    assert isinstance(packed, PackedWeights)
    assert dataclasses.replace(packed, luts=None).luts is None


def test_wide_accumulation_exact():
    """Deep K (21-bit accumulator growth, §II): still bit-exact everywhere."""
    rng = np.random.default_rng(11)
    k = 1024
    x = rng.integers(-128, 128, (2, k)).astype(np.int32)
    w = rng.integers(-128, 128, (k, 3)).astype(np.int32)
    cfg = DAConfig(x_signed=True)
    packed = pack_quantized(w, cfg=cfg, with_luts=True)
    oracle = x @ w
    for name, spec in sorted(registered_backends().items()):
        if not spec.supports(cfg, True):
            continue
        got = np.asarray(da_vmm(jnp.asarray(x), packed, mode=name, cfg=cfg))
        np.testing.assert_array_equal(got, oracle, err_msg=name)
