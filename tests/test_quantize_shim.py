"""The repro.serve.quantize compat shim: deprecation warning on import,
surface identity with repro.core.freeze."""
import importlib
import sys
import warnings


def test_import_emits_deprecation_warning():
    sys.modules.pop("repro.serve.quantize", None)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        import repro.serve.quantize  # noqa: F401
    assert any(issubclass(w.category, DeprecationWarning)
               and "repro.core.freeze" in str(w.message) for w in caught)


def test_shim_reexports_are_identical():
    import repro.core.freeze as canonical

    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        sys.modules.pop("repro.serve.quantize", None)
        shim = importlib.import_module("repro.serve.quantize")
    for name in ("freeze_model", "freeze_model_da", "da_memory_report",
                 "save_artifact", "load_artifact", "DAArtifact",
                 "LayerPlan", "plan_model"):
        assert getattr(shim, name) is getattr(canonical, name), name
