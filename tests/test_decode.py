"""Serving-path equivalences: prefill+decode == full forward (every arch);
chunked (flash-style) attention == naive attention."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS, reduce_for_smoke
from repro.models.model import forward, init_caches, init_model

KEY = jax.random.key(1)

# Default (fast) runs check the attention rep; SSM decode equivalence is
# covered by test_ssd's continuation test, and the full per-arch sweep rides
# behind `-m slow` (multi-second jit compiles per config).
REPRESENTATIVE = {"qwen3-8b"}
ARCH_PARAMS = [
    name if name in REPRESENTATIVE
    else pytest.param(name, marks=pytest.mark.slow)
    for name in sorted(ARCHS)
]


def _mk_pos(cfg, p1):
    return jnp.stack([p1, p1, p1], -1) if cfg.mrope_sections else p1


@pytest.mark.parametrize("name", ARCH_PARAMS)
def test_prefill_decode_matches_forward(name):
    cfg = dataclasses.replace(
        reduce_for_smoke(ARCHS[name]), moe_dropless=True
    )
    params = init_model(KEY, cfg)
    b, t, t0 = 2, 12, 8
    if cfg.modality == "text":
        inp = jax.random.randint(KEY, (b, t), 0, cfg.vocab)
    else:
        inp = jax.random.normal(KEY, (b, t, cfg.d_model), dtype=jnp.float32)
    full, _ = forward(params, inp, cfg)
    caches = init_caches(cfg, b, 20, jnp.float32)
    lg, caches = forward(
        params, inp[:, :t0], cfg,
        positions=_mk_pos(cfg, jnp.broadcast_to(jnp.arange(t0)[None], (b, t0))),
        caches=caches, update_cache=True,
    )
    np.testing.assert_allclose(
        np.asarray(lg), np.asarray(full[:, :t0]), atol=2e-4, rtol=2e-3
    )
    for step in range(t0, t):
        lg, caches = forward(
            params, inp[:, step : step + 1], cfg,
            positions=_mk_pos(cfg, jnp.full((b, 1), step, dtype=jnp.int32)),
            caches=caches,
        )
        np.testing.assert_allclose(
            np.asarray(lg[:, 0]), np.asarray(full[:, step]),
            atol=2e-4, rtol=2e-3,
        )


@pytest.mark.parametrize("name", [
    "qwen3-8b",
    pytest.param("phi3-medium-14b", marks=pytest.mark.slow),
    pytest.param("jamba-1.5-large-398b", marks=pytest.mark.slow),
])
@pytest.mark.parametrize("chunk", [4, 5, 16])
def test_chunked_attention_equals_naive(name, chunk):
    cfg = dataclasses.replace(reduce_for_smoke(ARCHS[name]), moe_dropless=True)
    params = init_model(KEY, cfg)
    b, t = 2, 16
    inp = jax.random.randint(KEY, (b, t), 0, cfg.vocab)
    naive, _ = forward(params, inp, cfg)
    chunked, _ = forward(
        params, inp, dataclasses.replace(cfg, attn_chunk_q=chunk)
    )
    np.testing.assert_allclose(
        np.asarray(naive), np.asarray(chunked), atol=2e-4, rtol=2e-3
    )


@pytest.mark.slow
def test_ragged_decode_positions():
    """Per-row cache positions: rows at different lengths decode exactly as
    their own full-forward would (continuous batching invariant)."""
    cfg = dataclasses.replace(reduce_for_smoke(ARCHS["qwen3-8b"]))
    params = init_model(KEY, cfg)
    p1 = jax.random.randint(jax.random.key(2), (1, 5), 0, cfg.vocab)
    p2 = jax.random.randint(jax.random.key(3), (1, 9), 0, cfg.vocab)
    # batched caches: row 0 prefilled with p1 (len 5), row 1 with p2 (len 9)
    caches = init_caches(cfg, 2, 24, jnp.float32)
    lg1, c1 = forward(params, p1, cfg, caches=init_caches(cfg, 1, 24, jnp.float32), update_cache=True)
    lg2, c2 = forward(params, p2, cfg, caches=init_caches(cfg, 1, 24, jnp.float32), update_cache=True)
    from repro.serve.engine import scatter_cache_row
    caches = scatter_cache_row(caches, c1, 0)
    caches = scatter_cache_row(caches, c2, 1)
    tok = jnp.asarray([[int(jnp.argmax(lg1[0, -1]))], [int(jnp.argmax(lg2[0, -1]))]], dtype=jnp.int32)
    pos = jnp.asarray([[5], [9]], dtype=jnp.int32)
    lg, _ = forward(params, tok, cfg, positions=pos, caches=caches)
    # reference: each row independently
    ref1, _ = forward(params, jnp.concatenate([p1, tok[:1]], 1), cfg)
    ref2, _ = forward(params, jnp.concatenate([p2, tok[1:]], 1), cfg)
    np.testing.assert_allclose(np.asarray(lg[0, 0]), np.asarray(ref1[0, -1]), atol=2e-4, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(lg[1, 0]), np.asarray(ref2[0, -1]), atol=2e-4, rtol=2e-3)


@pytest.mark.parametrize("lever", [
    dict(attn_mask_mode="additive"),
    dict(attn_mask_mode="additive", softmax_dtype="bfloat16"),
])
def test_perf_levers_preserve_forward(lever):
    """§Perf levers: additive mask is exact; bf16 softmax within quant noise."""
    cfg = reduce_for_smoke(ARCHS["qwen3-8b"])
    params = init_model(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 12), 0, cfg.vocab)
    base, _ = forward(params, toks, cfg)
    got, _ = forward(params, toks, dataclasses.replace(cfg, **lever))
    exact = lever.get("softmax_dtype", "float32") == "float32"
    tol = 0.0 if exact else 0.1
    assert float(jnp.abs(got - base).max()) <= tol
    # top-1 predictions unchanged (bf16 softmax is intentionally lossy, so
    # near-tied logits of a random-init model may flip on a few positions)
    agree = float(jnp.mean(jnp.argmax(got, -1) == jnp.argmax(base, -1)))
    assert agree == 1.0 if exact else agree >= 0.9, agree


def test_last_logit_only_matches():
    cfg = reduce_for_smoke(ARCHS["phi3-medium-14b"])
    params = init_model(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 12), 0, cfg.vocab)
    base, _ = forward(params, toks, cfg)
    last, _ = forward(params, toks, cfg, last_logit_only=True)
    np.testing.assert_allclose(np.asarray(last[:, 0]), np.asarray(base[:, -1]),
                               atol=1e-6)


@pytest.mark.slow
def test_lean_attention_matches_reference():
    """L8 lean attention (hoisted bias, late divide) == reference softmax."""
    for name in ("qwen3-8b", "mistral-nemo-12b", "jamba-1.5-large-398b"):
        cfg = dataclasses.replace(reduce_for_smoke(ARCHS[name]),
                                  moe_dropless=True)
        params = init_model(KEY, cfg)
        toks = jax.random.randint(KEY, (2, 14), 0, cfg.vocab)
        base, _ = forward(params, toks, cfg)
        lean, _ = forward(params, toks,
                          dataclasses.replace(cfg, attn_impl="lean"))
        np.testing.assert_allclose(np.asarray(lean), np.asarray(base),
                                   atol=2e-4, rtol=2e-3)


@pytest.mark.slow
def test_cache_slice_mode_matches_scatter():
    """L9: uniform-position dynamic_update_slice cache == scatter cache."""
    cfg0 = dataclasses.replace(reduce_for_smoke(ARCHS["qwen3-8b"]))
    params = init_model(KEY, cfg0)
    b, t = 2, 12
    inp = jax.random.randint(KEY, (b, t), 0, cfg0.vocab)
    outs = {}
    for mode in ("scatter", "slice"):
        cfg = dataclasses.replace(cfg0, cache_mode=mode)
        caches = init_caches(cfg, b, 20, jnp.float32)
        lg, caches = forward(
            params, inp[:, :8], cfg,
            positions=jnp.broadcast_to(jnp.arange(8)[None], (b, 8)),
            caches=caches, update_cache=True)
        seq = [np.asarray(lg)]
        for step in range(8, t):
            lg, caches = forward(
                params, inp[:, step:step + 1], cfg,
                positions=jnp.full((b, 1), step, dtype=jnp.int32),
                caches=caches)
            seq.append(np.asarray(lg))
        outs[mode] = seq
    for a, c in zip(outs["scatter"], outs["slice"]):
        np.testing.assert_allclose(a, c, atol=1e-5)
