"""Continuous-batching scheduler over the paged KV cache.

The paper's freeze-once/serve-many premise puts all serving cost in the
decode hot loop; this module is the periphery engineering around the
constant-weight DA arrays — the piece DAISM and the RRAM benchmarking
framework both identify as where in-memory VMM wins are made or lost.

One fixed decode batch of ``batch_size`` lanes runs every tick. Because the
page pool is batch-free (requests own pages, not batch rows), one tick can
issue TWO economically-shaped calls of the same unified jitted step instead
of one padded monolith: a compact chunked-prefill sub-batch
(``[prefill_lanes, chunk]``, lanes still ingesting their prompt) and a pure
decode batch (``[batch_size, 1]``) — chunked prefill proceeds beside the
decode batch every tick without inflating its width, and a lane that
finishes its prompt mid-tick starts decoding the same tick. Step shapes are
length-bucketed to powers of two, so prefill compiles O(log chunk) shapes,
not O(#prompt-lengths).

Host-side state (the scheduler) vs device state (the paged pools):

* admission queue with a token-budget policy — ``token_budget`` caps tokens
  processed per step (decode lanes are reserved first; prefill chunks fill
  the remainder), and ``admission="reserve"`` only admits a request when its
  worst-case page demand fits beside the reservations of every running lane
  (pure backpressure: the queue waits, nothing crashes);
* ``admission="optimistic"`` admits on first-chunk fit and relies on
  preemption — when a decoding lane cannot get a page, the youngest lane is
  evicted back to the queue head (pages freed, KV recomputed on
  re-admission, exactly reproducing its tokens under greedy decoding);
* finished lanes free their pages immediately; lanes that make no progress
  for ``stall_patience`` consecutive steps are preempted too;
* per-request streaming callbacks (``Request.on_token``) and wall-clock
  latency/throughput metrics (TTFT, inter-token p50/p99) come for free from
  the host loop;
* ``prefix_cache=True`` turns on shared-prefix caching: fully-ingested
  prompt pages are indexed in a host-side trie (``kvcache.PrefixCache``),
  admission looks the new prompt up and skips prefill for every cached page
  (the pages are shared by refcount; the hit shrinks both the chunk plan
  and the admission reservation), writes into a still-shared last page
  copy-on-write first, and pool pressure evicts LRU cached prefixes before
  backpressure kicks in.  Decoded tokens are bit-identical with the cache
  on or off — hits reuse KV a previous request computed over the exact
  same prefix.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.sharding import shard_paged_caches
from repro.models.config import ModelConfig
from repro.models.model import forward
from repro.obs import Observability
from repro.obs.hwcost import HardwareCostModel, draft_price
from repro.obs.metrics import ENERGY_BUCKETS
from repro.obs.trace import SCHED_TRACK, device_span, request_track
from repro.serve.kvcache import (
    GARBAGE_PAGE,
    PagePool,
    PrefixCache,
    checkpoint as kv_checkpoint,
    copy_page,
    defrag,
    init_paged_caches,
    kv_cache_nbytes,
    kv_page_bytes,
    kv_token_bytes,
    pad_position,
    pages_for,
    resolve_kv_dtypes,
    rollback as kv_rollback,
    table_width,
)
from repro.spec import (
    SpecConfig,
    breakeven_acceptance,
    greedy_accept,
    make_provider,
    make_verify_step,
)
from repro.spec.decode import make_fused_draft, mk_positions  # noqa: F401
# (mk_positions re-exported: serve.engine and the examples import it here)


@dataclasses.dataclass
class Request:
    """One generation request (re-exported by ``repro.serve.engine``)."""

    uid: int
    prompt: np.ndarray            # [T0] int32
    max_new_tokens: int = 32
    eos_id: int = -1              # -1 → never stops early
    on_token: Optional[Callable[[int, int], None]] = None  # stream (uid, tok)
    generated: Optional[List[int]] = None
    # wall-clock metrics, stamped by the runtime (first_token_t is None until
    # the first token lands — perf_counter can legally return exactly 0.0, so
    # "unset" must not be encoded as a float value)
    submit_t: float = 0.0
    first_token_t: Optional[float] = None
    finish_t: float = 0.0
    token_times: Optional[List[float]] = None
    # estimated DA-hardware cost of this request's executed work (pJ /
    # model-ns), accumulated by the scheduler when a HardwareCostModel is
    # attached; stays 0.0 otherwise
    hw_pj: float = 0.0
    hw_ns: float = 0.0

    def __post_init__(self):
        if self.generated is None:
            self.generated = []
        if self.token_times is None:
            self.token_times = []


def latency_metrics(reqs) -> Dict[str, float]:
    """TTFT and inter-token latency percentiles (ms) over finished requests.

    Zeroed keys (never a crash) when nothing has finished yet; a request
    whose first token landed at wall-clock 0.0 exactly still counts — the
    unset sentinel is None, not falsiness."""
    itl: List[float] = []
    for r in reqs:
        itl.extend(b - a for a, b in zip(r.token_times, r.token_times[1:]))
    ttft = [r.first_token_t - r.submit_t for r in reqs
            if r.first_token_t is not None]

    def pct(xs, q):
        return float(np.percentile(xs, q)) * 1e3 if xs else 0.0

    return {
        "ttft_p50_ms": pct(ttft, 50),
        "itl_p50_ms": pct(itl, 50),
        "itl_p99_ms": pct(itl, 99),
    }


def base_metrics(runtime: str, done: Dict[int, Request],
                 out_tokens: int) -> Dict[str, Any]:
    """The ``metrics()`` core shared by both serving runtimes (the paged
    scheduler and the legacy slot runtime): runtime tag, completion and
    token totals, and the latency percentiles.  Runtime-specific sections
    layer on top of this one dict — the two implementations must never
    drift on the common keys."""
    return {
        "runtime": runtime,
        "requests_done": len(done),
        "out_tokens": out_tokens,
        **latency_metrics(done.values()),
    }


def pow2_bucket(n: int, lo: int = 1) -> int:
    """Smallest power of two ≥ n (and ≥ lo) — the step-length buckets."""
    b = lo
    while b < n:
        b *= 2
    return b


def width_buckets(b: int) -> List[int]:
    """Batch-width ladder {1, 2, 3, 4, 6, 8, 12, …, b}: pow2 plus the
    1.5× midpoints — a decode batch with 9 live lanes pays for 12 rows,
    not 16. Still O(log) shapes."""
    out, w = [], 1
    while w < b:
        out.append(w)
        mid = w + w // 2
        if w > 1 and mid < b:
            out.append(mid)
        w *= 2
    out.append(b)
    return out


def width_bucket(n: int, b: int) -> int:
    """Smallest ladder width ≥ n (capped at b)."""
    for w in width_buckets(b):
        if w >= n:
            return w
    return b


def make_paged_step(cfg: ModelConfig):
    """The unified serve step: (params, caches, tokens [B,T], positions,
    page_table [B,W], last_idx [B]) → (logits [B,V], caches). T=1 is pure
    decode; T>1 coalesces prefill chunks with decoding lanes (their single
    real token rides in column 0, pad columns write to the garbage page)."""

    def step(params, caches, tokens, positions, page_table, last_idx):
        logits, caches = forward(
            params, tokens, cfg, positions=positions, caches=caches,
            update_cache=True, page_table=page_table, last_idx=last_idx,
        )
        return logits[:, 0], caches

    return step


@dataclasses.dataclass
class _Lane:
    """Host state of one occupied batch row."""

    req: Request
    pages: List[int]              # physical pages, in logical order
    ctx: List[int]                # prompt + generated-so-far token ids
    pos: int = 0                  # ctx tokens already written to the KV pool
    admitted_t: float = 0.0
    stalled_steps: int = 0
    cached: bool = False          # prompt pages already offered to the trie
    draft_pos: int = 0            # ctx tokens the DRAFT model has ingested
    #                               (own-cache providers only; self-draft
    #                               providers read the target's verified KV)

    @property
    def remaining(self) -> int:   # 1 → decoding; >1 → still prefilling
        return len(self.ctx) - self.pos


class PagedScheduler:
    """Continuous batching + paged KV: the serving runtime behind
    ``ServeEngine(runtime="paged")``."""

    def __init__(
        self,
        cfg: ModelConfig,
        params: Any,
        batch_size: int,
        max_len: int,
        greedy: bool = True,
        page_size: int = 16,
        n_pages: Optional[int] = None,
        prefill_chunk: int = 16,
        prefill_lanes: Optional[int] = None,
        token_budget: Optional[int] = None,
        admission: str = "reserve",
        stall_patience: int = 64,
        spec: Optional[SpecConfig] = None,
        prefix_cache: bool = False,
        paged_attn: Optional[str] = None,
        kv_dtype: Optional[str] = None,
        kv_dtypes: Optional[Dict[str, str]] = None,
        obs: Optional[Observability] = None,
        hw: Optional[HardwareCostModel] = None,
        analysis_debug: bool = False,
    ):
        if admission not in ("reserve", "optimistic"):
            raise ValueError(f"unknown admission policy {admission!r}")
        if paged_attn is not None and paged_attn != cfg.paged_attn:
            # the runtime knob overrides the model config's paged-attention
            # backend; bake it in before any step/provider closure captures
            # cfg (plain decode, spec draft/verify and warmup all trace it)
            cfg = dataclasses.replace(cfg, paged_attn=paged_attn)
        if kv_dtype is not None and kv_dtype != cfg.kv_dtype:
            # same override pattern for the KV page precision: baked into cfg
            # so spec draft providers and any cfg-derived pool agree with the
            # scheduler's own pool
            cfg = dataclasses.replace(cfg, kv_dtype=kv_dtype)
        # resolve + validate per-position KV dtypes ONCE, loudly, before any
        # pool memory is allocated (unknown dtype / int4 with odd head_dim)
        self.kv_dtypes = resolve_kv_dtypes(cfg, kv_dtypes)
        if spec is not None and not greedy:
            raise ValueError(
                "speculative decoding verifies drafts by greedy acceptance; "
                "it requires greedy=True (sampling would need lossless "
                "rejection sampling, which this runtime does not implement)"
            )
        if n_pages is None:
            # dense-slot-equivalent footprint: every lane can hold max_len
            n_pages = batch_size * pages_for(max_len, page_size) + 1
        self.cfg = cfg
        self.params = params
        self.b = batch_size
        self.max_len = max_len
        self.greedy = greedy
        self.page_size = page_size
        self.prefill_chunk = prefill_chunk
        self.prefill_lanes = prefill_lanes or min(4, batch_size)
        self.token_budget = token_budget or (batch_size + 2 * prefill_chunk)
        self.admission = admission
        self.stall_patience = stall_patience
        self.W = table_width(max_len, page_size)
        self.pad_pos = pad_position(max_len, page_size)
        self.pool = PagePool(
            n_pages,
            page_bytes=kv_page_bytes(cfg, page_size, self.kv_dtypes))
        self.caches = shard_paged_caches(
            init_paged_caches(cfg, n_pages, page_size, cfg.dtype(),
                              kv_dtypes=self.kv_dtypes)
        )
        self.lanes: List[Optional[_Lane]] = [None] * batch_size
        self.queue: List[Request] = []
        self.done: Dict[int, Request] = {}
        self._preempted: set = set()  # uids waiting on a full-ctx re-admit
        # shared-prefix caching: a host-side trie over page-granular prompt
        # prefixes; hits skip prefill for cached pages and share them by
        # refcount (COW guards the last partial page)
        self.prefix = PrefixCache(page_size) if prefix_cache else None
        # analysis_debug: every launch's (page, offset) write plan goes
        # through repro.analysis.races.check_plan BEFORE the device call;
        # a violated aliasing invariant raises PageRaceError instead of
        # silently corrupting KV.  plans_checked is a plain attribute, not a
        # registry counter — a debug-only metric would churn the exported
        # schema the obs regression validators pin.
        self.analysis_debug = bool(analysis_debug)
        self.plans_checked = 0
        # counters — registry-homed so metrics(), the Prometheus exporter,
        # and BENCH_*.json all read one source; the former plain attributes
        # (self.steps, self.out_tokens, ...) survive as read-only properties
        self.obs = obs if obs is not None else Observability.make()
        reg = self.obs.registry
        self._tr = self.obs.tracer
        self._c_steps = reg.counter("sched_ticks", "scheduler ticks run")
        self._c_out = reg.counter("sched_out_tokens", "tokens emitted")
        self._c_ctx = reg.counter(
            "sched_ctx_tokens", "context tokens written to the KV pool")
        self._c_preempt = reg.counter(
            "sched_preemptions", "lanes evicted back to the queue")
        self._c_compiles = reg.counter(
            "sched_step_compiles", "unified-step shape compiles")
        self._c_pref_lookups = reg.counter(
            "prefix_lookups", "admissions that consulted the prefix trie")
        self._c_pref_hits = reg.counter(
            "prefix_hits", "admissions that reused cached prefix pages")
        self._c_cow = reg.counter(
            "kv_cow_copies", "copy-on-write page copies")
        self._g_lanes = reg.gauge("sched_live_lanes", "occupied batch rows")
        self._g_queue = reg.gauge(
            "sched_queue_depth", "requests waiting for admission")
        self._g_used_pages = reg.gauge("kv_used_pages", "pool pages in use")
        self._h_ttft = reg.histogram(
            "req_ttft_seconds", "submit to first token")
        self._h_itl = reg.histogram(
            "req_itl_seconds", "inter-token latency")
        self._h_tick = reg.histogram(
            "sched_tick_seconds", "wall time of one scheduler tick")
        self._c_draft_steps = reg.counter(
            "spec_draft_steps", "draft-model steps issued")
        self._c_verify_steps = reg.counter(
            "spec_verify_steps", "batched verify calls issued")
        self._c_spec_rounds = reg.counter(
            "spec_rounds", "speculative rounds completed")
        self._c_drafted = reg.counter(
            "spec_drafted_tokens", "tokens proposed by the draft model")
        self._c_accepted = reg.counter(
            "spec_accepted_drafts", "draft tokens accepted by verify")
        self._c_bonus = reg.counter(
            "spec_bonus_tokens", "bonus tokens from fully-accepted windows")
        self._c_spec_off = reg.counter(
            "spec_disabled_requests", "requests whose speculation auto-off'd")
        self._c_draft_compiles = reg.counter(
            "spec_draft_compiles", "draft-step shape compiles")
        self._c_verify_compiles = reg.counter(
            "spec_verify_compiles", "verify-step shape compiles")
        self._start_t: Optional[float] = None
        base = make_paged_step(cfg)

        def counted(*a):
            # trace-time side effect = 1 per bucket
            self._c_compiles.inc()
            return base(*a)

        self._step = jax.jit(counted)

        # -- speculative decoding (draft -> batched verify) -------------------
        self.spec = spec
        self._provider = None
        self.draft_caches = None
        self._spec_state: Dict[int, Dict[str, Any]] = {}  # uid → EMA state
        if spec is not None:
            self._provider = make_provider(spec, cfg, params)
            own = self._provider.init_caches(self.pool.n_pages, page_size)
            if own is not None:
                self.draft_caches = shard_paged_caches(own)
            self._spec_floor = (
                spec.disable_below if spec.disable_below is not None
                else min(1.0, breakeven_acceptance(
                    spec.gamma, self._provider.cost_ratio) + 0.05)
            )
            # the whole gamma-token draft loop is ONE device call: catch-up
            # feed + a lax.scan of gamma-1 greedy proposals (host dispatch
            # per round, not per draft token)
            dbase = make_fused_draft(self._provider.make_step(),
                                     self._provider.cfg, spec.gamma)

            def counted_draft(*a):
                self._c_draft_compiles.inc()
                return dbase(*a)

            self._draft_step = jax.jit(counted_draft)
            if not self._provider.shared_cache:
                # chunked draft-side context ingestion (logits discarded) so
                # long catch-ups ride prefill_chunk-bucketed shapes instead
                # of a one-shot full-context fused call
                ibase = self._provider.make_step()

                def counted_ingest(*a):
                    self._c_draft_compiles.inc()
                    return ibase(*a)

                self._draft_ingest = jax.jit(counted_ingest)
            vbase = make_verify_step(cfg)

            def counted_verify(*a):
                self._c_verify_compiles.inc()
                return vbase(*a)

            self._verify_step = jax.jit(counted_verify)

        # -- hardware cost attribution (repro.obs.hwcost) ---------------------
        # Per-token-pass prices by phase, fixed at init: prefill / decode /
        # verify run the full-precision model; draft and draft-side ingest
        # run at the provider's price (truncated bit-planes → exactly
        # proportionally fewer read cycles; own-artifact drafts get their
        # own cost table; layer-skip scales by cost_ratio).
        self.hw = hw if hw else None  # empty cost table ⇒ no attribution
        self._hw_prices: Dict[str, Tuple[float, float]] = {}
        self._hw_bs: Dict[str, Tuple[float, float]] = {}
        self._hw_draft: Optional[Dict[str, Any]] = None
        if self.hw is not None:
            full = (self.hw.pj_per_token(), self.hw.ns_per_token())
            bs_full = (self.hw.bitslice_pj_per_token(),
                       self.hw.bitslice_ns_per_token())
            for ph in ("prefill", "decode", "verify"):
                self._hw_prices[ph] = full
                self._hw_bs[ph] = bs_full
            if self._provider is not None:
                dp = draft_price(self.hw, self._provider, self.params)
                self._hw_draft = dp
                for ph in ("draft", "draft_ingest"):
                    self._hw_prices[ph] = (dp["pj"], dp["ns"])
                    self._hw_bs[ph] = (dp["bs_pj"], dp["bs_ns"])
            self._c_hw_tokens = reg.counter(
                "hw_tokens", "token-passes priced by the DA hardware model")
            self._c_hw_pj = reg.counter(
                "hw_est_pj", "estimated DA energy of executed work (pJ)")
            self._c_hw_ns = reg.counter(
                "hw_est_ns",
                "estimated serialized DA latency of executed work (ns)")
            self._h_req_pj = reg.histogram(
                "req_hw_pj", "per-request estimated DA energy (pJ)",
                buckets=ENERGY_BUCKETS)

    # -- registry-backed counter views ---------------------------------------
    # The pre-registry attribute surface (tests and external tooling read
    # e.g. ``sched.steps``) kept alive as int views over the registry series.
    @property
    def steps(self) -> int:
        return int(self._c_steps.total)

    @property
    def out_tokens(self) -> int:
        return int(self._c_out.total)

    @property
    def ctx_tokens(self) -> int:
        return int(self._c_ctx.total)

    @property
    def preemptions(self) -> int:
        return int(self._c_preempt.total)

    @property
    def step_compiles(self) -> int:
        return int(self._c_compiles.total)

    @property
    def prefix_lookups(self) -> int:
        return int(self._c_pref_lookups.total)

    @property
    def prefix_hits(self) -> int:
        return int(self._c_pref_hits.total)

    @property
    def cow_copies(self) -> int:
        return int(self._c_cow.total)

    @property
    def draft_steps(self) -> int:
        return int(self._c_draft_steps.total)

    @property
    def verify_steps(self) -> int:
        return int(self._c_verify_steps.total)

    @property
    def spec_rounds(self) -> int:
        return int(self._c_spec_rounds.total)

    @property
    def drafted_tokens(self) -> int:
        return int(self._c_drafted.total)

    @property
    def accepted_drafts(self) -> int:
        return int(self._c_accepted.total)

    @property
    def bonus_tokens(self) -> int:
        return int(self._c_bonus.total)

    @property
    def spec_disabled(self) -> int:
        return int(self._c_spec_off.total)

    @property
    def draft_compiles(self) -> int:
        return int(self._c_draft_compiles.total)

    @property
    def verify_compiles(self) -> int:
        return int(self._c_verify_compiles.total)

    # -- admission -----------------------------------------------------------
    def submit(self, req: Request) -> None:
        t0 = len(req.prompt)
        if t0 >= self.max_len:
            raise ValueError(
                f"request {req.uid}: prompt of {t0} tokens does not fit "
                f"max_len={self.max_len}"
            )
        worst = self._worst_pages(t0 + len(req.generated), req.max_new_tokens
                                  - len(req.generated))
        if worst > self.pool.n_pages - 1:
            raise ValueError(
                f"request {req.uid} can never be served: needs {worst} pages "
                f"but the pool holds {self.pool.n_pages - 1}"
            )
        req.submit_t = time.perf_counter()
        self.queue.append(req)
        if self._tr.enabled:
            self._tr.instant("submit", request_track(req.uid),
                             ts=req.submit_t, prompt_tokens=t0,
                             max_new_tokens=req.max_new_tokens)

    def _worst_pages(self, ctx_len: int, rem_new: int) -> int:
        return pages_for(min(ctx_len + max(rem_new, 0), self.max_len),
                         self.page_size)

    def _lane_reservation(self, lane: _Lane) -> int:
        return self._worst_pages(
            len(lane.ctx), lane.req.max_new_tokens - len(lane.req.generated)
        )

    def _admit(self) -> None:
        for i in range(self.b):
            if not self.queue:
                return
            if self.lanes[i] is not None:
                continue
            req = self.queue[0]
            ctx = list(int(t) for t in req.prompt) + list(req.generated)
            hit_nodes, hit = ([], 0)
            if self.prefix is not None:
                hit_nodes, hit = self.prefix.match(ctx)
            # a hit mid-page means the lane's first write COWs the last
            # shared page — one extra allocation the reservation must carry
            cow_extra = 1 if hit % self.page_size else 0
            if self.admission == "reserve":
                held = sum(self._lane_reservation(l)
                           for l in self.lanes if l is not None)
                # discount only hit pages a RUNNING lane also holds (those
                # are already inside `held`, so the shared page would be
                # counted twice); trie-only hit pages occupy pool capacity
                # no reservation covers, so they stay in this lane's worst —
                # the reserve invariant (worst-case always fits) survives
                live = {p for l in self.lanes if l is not None
                        for p in l.pages}
                discount = sum(1 for nd in hit_nodes if nd.page in live)
                worst = (self._worst_pages(
                    len(ctx), req.max_new_tokens - len(req.generated))
                    - discount + cow_extra)
                if held + worst > self.pool.n_pages - 1:
                    return  # backpressure: head-of-line waits for pages
            else:
                # optimistic: first chunk must fit now, plus a few headroom
                # pages for decode growth (anti-thrash watermark — without
                # it a preempted request is re-admitted next tick and
                # preempted again, replaying its prefill forever). A
                # PREEMPTED request re-admits only when its whole
                # accumulated context fits: resuming it on a first-chunk
                # sliver would just replay-and-evict in a loop.  Cached
                # prefix pages are already resident: only the uncovered
                # remainder needs fresh pages.
                need = (len(ctx) - hit if req.uid in self._preempted
                        else min(len(ctx) - hit, self.prefill_chunk))
                headroom = max(2, self.pool.n_pages // 16)
                # cap at pool capacity: a request whose ctx+headroom exceeds
                # the whole pool must still admit once the pool drains, or
                # it would wait forever on a condition that cannot occur
                want = min(pages_for(hit + need, self.page_size)
                           - len(hit_nodes) + cow_extra + headroom,
                           self.pool.n_pages - 1 - len(hit_nodes))
                if not self._can_cover(want):
                    return
                self._preempted.discard(req.uid)
            self.queue.pop(0)
            pages: List[int] = []
            if self.prefix is not None:
                self._c_pref_lookups.inc()
                # denominator of hit_rate: prompt tokens only — generated
                # tokens of a re-admitted preempted request are never
                # cacheable, so counting them would deflate the rate
                self.prefix.lookup_tokens += len(req.prompt)
                if hit_nodes:
                    pages = self.prefix.claim(hit_nodes, self.pool)
                    self._c_pref_hits.inc()
                    self.prefix.cached_tokens += hit
            lane = _Lane(req=req, pages=pages, ctx=ctx, pos=hit,
                         admitted_t=time.perf_counter())
            self.lanes[i] = lane
            if self._tr.enabled:
                # one "running" span per residency period: begun here, ended
                # by _preempt or the finish paths — span balance over a
                # drained run is a tested invariant
                self._tr.begin("running", request_track(req.uid),
                               ts=lane.admitted_t, lane=i, ctx_tokens=len(ctx),
                               prefix_hit_tokens=hit)

    # -- preemption / eviction -----------------------------------------------
    def _preempt(self, i: int) -> None:
        """Evict lane i back to the queue head: pages freed now, KV rebuilt
        by replayed chunked prefill on re-admission (greedy decoding makes
        the replay token-exact)."""
        lane = self.lanes[i]
        self.pool.free(lane.pages)
        self.queue.insert(0, lane.req)
        self._preempted.add(lane.req.uid)
        self.lanes[i] = None
        self._c_preempt.inc()
        if self._tr.enabled:
            track = request_track(lane.req.uid)
            self._tr.instant("preempt", track,
                             generated=len(lane.req.generated))
            self._tr.end("running", track)

    def _youngest_other(self, i: int) -> Optional[int]:
        cands = [(j, l) for j, l in enumerate(self.lanes)
                 if l is not None and j != i]
        if not cands:
            return None
        return max(cands, key=lambda t: t[1].admitted_t)[0]

    def _alloc(self, n: int) -> Optional[List[int]]:
        """Pool allocation with prefix-cache spill: on exhaustion, evict LRU
        trie nodes (cached prefixes nobody is running) before giving up."""
        got = self.pool.alloc(n)
        if (got is None and self.prefix is not None
                and self.prefix.evict_until(self.pool, n)):
            got = self.pool.alloc(n)
        return got

    def _can_cover(self, n: int) -> bool:
        """Could ``n`` pages be produced right now (free + evictable)?"""
        free = self.pool.free_pages
        if self.prefix is not None:
            free += self.prefix.reclaimable(self.pool)
        return n <= free

    def _cow_shared_page(self, lane: _Lane) -> bool:
        """Copy-on-write guard, called before KV rows are written at
        ``lane.pos``: if that position lands in a page other owners (the
        prefix trie, or lanes sharing the prefix) still reference, give the
        lane a private copy first — a write into a shared page would corrupt
        every other reader's KV. Only the last, partially-consumed page of a
        prefix hit can be in this state; pages past it are always exclusive.
        Returns False when no page can be found for the copy (backpressure).
        """
        if self.prefix is None:
            return True
        idx = lane.pos // self.page_size
        if idx >= len(lane.pages):
            return True
        src = lane.pages[idx]
        if self.pool.refcount(src) <= 1:
            return True
        got = self._alloc(1)
        if got is None:
            return False
        dst = got[0]
        if self.draft_caches is not None:
            # an own-cache draft provider indexes its pools with the SAME
            # page tables — its copy rides the same COW
            both = copy_page({"t": self.caches, "d": self.draft_caches},
                             src, dst)
            self.caches, self.draft_caches = both["t"], both["d"]
        else:
            self.caches = copy_page(self.caches, src, dst)
        lane.pages[idx] = dst
        self.pool.free([src])  # drop the lane's reference on the shared page
        self._c_cow.inc()
        return True

    def _maybe_cache_prefix(self, lane: _Lane) -> None:
        """Offer a lane's prompt pages to the trie once the prompt is fully
        ingested (every full prompt page then holds valid KV)."""
        if (self.prefix is None or lane.cached
                or lane.pos < len(lane.req.prompt)):
            return
        lane.cached = True
        self.prefix.insert(lane.ctx[: len(lane.req.prompt)], lane.pages,
                           self.pool)

    def _ensure_pages(self, lane: _Lane, n: int) -> int:
        """Grow lane.pages to cover pos+n tokens; returns the n actually
        covered — a prefill chunk shrinks to what free pages allow, 0 means
        fully deferred (backpressure, not a crash)."""
        if n > 0 and not self._cow_shared_page(lane):
            return 0
        while n > 0:
            need = pages_for(lane.pos + n, self.page_size) - len(lane.pages)
            if need <= 0:
                return n
            got = self._alloc(need)
            if got is not None:
                lane.pages.extend(got)
                return n
            fit = ((len(lane.pages) + self.pool.free_pages) * self.page_size
                   - lane.pos)
            n = min(n - 1, max(fit, 0))
        return 0

    # -- the tick ------------------------------------------------------------
    def step(self) -> int:
        """One scheduler tick: admit, run a compact chunked-prefill sub-batch
        (if any lane is still ingesting its prompt), then one pure decode
        step over the full batch. Returns the number of active lanes."""
        self._admit()
        active = [(i, l) for i, l in enumerate(self.lanes) if l is not None]
        if not active:
            return 0
        if self._start_t is None:
            self._start_t = time.perf_counter()
        self._c_steps.inc()
        t_tick = time.perf_counter()
        allocs0, cow0 = self.pool._allocs, self._c_cow.total
        evict0 = self.prefix.evictions if self.prefix is not None else 0

        progressed: set = set()
        decode_count = sum(1 for _, l in active if l.remaining == 1)
        prefill = [(i, l) for i, l in active if l.remaining > 1]
        if prefill:
            progressed |= self._prefill_phase(prefill, decode_count)
        decode = [(i, l) for i, l in enumerate(self.lanes)
                  if l is not None and l.remaining == 1]
        if decode:
            staged, plain = self._partition_spec(decode)
            if staged:
                progressed |= self._spec_phase(staged)
            if plain:
                progressed |= self._decode_phase(plain)

        active = [(i, l) for i, l in enumerate(self.lanes) if l is not None]
        if active and not progressed:
            # pool jammed: keep only the oldest lane (guaranteed servable by
            # the submit-time capacity check), requeue the rest
            oldest = min(active, key=lambda t: t[1].admitted_t)[0]
            for i, _ in active:
                if i != oldest:
                    self._preempt(i)
        for i, l in ((i, l) for i, l in enumerate(self.lanes)
                     if l is not None):
            if i in progressed:
                l.stalled_steps = 0
            else:
                l.stalled_steps += 1
                if l.stalled_steps > self.stall_patience:
                    self._preempt(i)  # stalled: hand its pages to the rest
        live = sum(l is not None for l in self.lanes)
        now = time.perf_counter()
        self._h_tick.observe(now - t_tick)
        self._g_lanes.set(live)
        self._g_queue.set(len(self.queue))
        self._g_used_pages.set(self.pool.used_pages)
        if self._tr.enabled:
            evict1 = (self.prefix.evictions if self.prefix is not None
                      else 0)
            self._tr.complete(
                "tick", SCHED_TRACK, t_tick, now - t_tick,
                lanes=live, decode_lanes=decode_count,
                prefill_lanes=len(prefill), queue=len(self.queue),
                pages_allocated=self.pool._allocs - allocs0,
                cow_copies=int(self._c_cow.total - cow0),
                prefix_evictions=evict1 - evict0,
                used_pages=self.pool.used_pages)
        return live

    def _submit_plan(self, phase: str, rows, poss) -> None:
        """analysis_debug gate: prove the page-aliasing invariants for one
        launch before it reaches the device.  ``rows`` = [(batch_row,
        lane_idx, lane)]; ``poss`` maps lane index -> the token positions
        this launch writes for that lane.  Positions past the lane's page
        table (the pad position) land in the garbage page on device, and
        the checker exempts garbage-page aliasing by design."""
        from repro.analysis.races import PageWrite, TickPlan, assert_plan_ok

        ps = self.page_size
        writes = []
        for _, i, l in rows:
            for pos in poss[i]:
                pi = pos // ps
                page = l.pages[pi] if 0 <= pi < len(l.pages) else GARBAGE_PAGE
                writes.append(PageWrite(
                    lane=i, uid=l.req.uid, page=page, offset=pos % ps))
        touched = {w.page for w in writes}
        plan = TickPlan.build(
            phase=phase, page_size=ps, writes=writes,
            refcounts={p: self.pool.refcount(p) for p in touched
                       if 0 <= p < self.pool.n_pages},
            trie_pages=self.prefix.pages() if self.prefix is not None else (),
            free_pages=self.pool.free_page_ids(),
            garbage_page=GARBAGE_PAGE,
        )
        assert_plan_ok(plan)
        self.plans_checked += 1

    def _run_batch(self, rows, plan, n_rows: int, t_step: int,
                   phase: str = "step") -> np.ndarray:
        """Issue one call of the unified step for ``rows`` = [(batch_row,
        lane_idx, lane)]. Pad rows/columns carry the garbage position, so
        their writes land in the garbage page and every real row's
        ``kpos <= tpos`` mask excludes them."""
        if self.analysis_debug:
            self._submit_plan(phase, rows, {
                i: range(l.pos, l.pos + plan[i]) for _, i, l in rows})
        tokens = np.zeros((n_rows, t_step), np.int32)
        positions = np.full((n_rows, t_step), self.pad_pos, np.int32)
        last_idx = np.zeros((n_rows,), np.int32)
        table = np.full((n_rows, self.W), GARBAGE_PAGE, np.int32)
        for r, i, l in rows:
            n = plan[i]
            tokens[r, :n] = l.ctx[l.pos : l.pos + n]
            positions[r, :n] = np.arange(l.pos, l.pos + n)
            last_idx[r] = n - 1
            table[r, : len(l.pages)] = l.pages
        with device_span(f"paged_step[{n_rows}x{t_step}]", self._tr.enabled):
            logits, self.caches = self._step(
                self.params, self.caches, jnp.asarray(tokens),
                mk_positions(self.cfg, jnp.asarray(positions)),
                jnp.asarray(table), jnp.asarray(last_idx),
            )
        return np.asarray(logits)

    def _hw_charge(self, req: Request, phase: str, n: int) -> float:
        """Price ``n`` executed token-passes of ``phase`` work on the DA
        hardware model: registry counters (labeled by phase) plus the
        request's own running total.  Returns the pJ charged (0.0 with no
        cost model attached) — callers may stamp it on trace spans.  Purely
        host-side float math; never touches device state, so accounting is
        identical with tracing on or off."""
        if self.hw is None or n <= 0:
            return 0.0
        pj_tok, ns_tok = self._hw_prices[phase]
        pj, ns = pj_tok * n, ns_tok * n
        self._c_hw_tokens.inc(n, phase=phase)
        self._c_hw_pj.inc(pj, phase=phase)
        self._c_hw_ns.inc(ns, phase=phase)
        req.hw_pj += pj
        req.hw_ns += ns
        return pj

    def _prefill_phase(self, prefill, decode_count: int) -> set:
        """Up to ``prefill_lanes`` ingesting lanes advance by one chunk each
        in a compact [prefill_lanes, T_bucket] sub-batch — the page pool is
        batch-free, so prefill never has to ride (and widen) the decode
        batch. The token budget is what's left after the decode lanes take
        their 1 token each."""
        # with no decode lanes, budget == token_budget >= 1 here, so prefill
        # always advances
        budget = self.token_budget - decode_count
        if budget <= 0 and decode_count > 0:
            return set()  # decode saturates the budget this tick
        sel = sorted(prefill, key=lambda t: t[1].admitted_t)
        sel = sel[: self.prefill_lanes]
        plan: Dict[int, int] = {}
        for i, l in sel:
            n = min(l.remaining, self.prefill_chunk, budget)
            n = self._ensure_pages(l, n)  # may shrink or defer: backpressure
            plan[i] = n
            budget -= n
        rows = [(r, i, l) for r, (i, l) in enumerate(
            (i, l) for i, l in sel if plan[i] > 0)]
        if not rows:
            return set()
        # cap at prefill_chunk so a non-pow2 chunk size uses the shape
        # warmup() compiled, not a one-off pow2 round-up
        t_step = min(pow2_bucket(max(plan[i] for _, i, _ in rows)),
                     self.prefill_chunk)
        t0 = time.perf_counter()
        logits = self._run_batch(rows, plan, self.prefill_lanes, t_step,
                                 phase="prefill")
        now = time.perf_counter()
        if self._tr.enabled:
            for r, i, l in rows:
                extra = ({"est_pj": self._hw_prices["prefill"][0] * plan[i]}
                         if self.hw is not None else {})
                self._tr.complete("prefill_chunk", request_track(l.req.uid),
                                  t0, now - t0, tokens=plan[i], pos=l.pos,
                                  **extra)
            self._tr.complete("prefill", SCHED_TRACK, t0, now - t0,
                              lanes=len(rows), t_step=t_step)
        for r, i, l in rows:
            l.pos += plan[i]
            self._hw_charge(l.req, "prefill", plan[i])
            self._c_ctx.inc(plan[i])
            self._maybe_cache_prefix(l)  # before _sample can free the pages
            if l.remaining == 0:  # chunk covered the last unseen token
                self._sample(i, l, logits[r], now)
        return {i for _, i, _ in rows}

    def _decode_phase(self, decode) -> set:
        """All decoding lanes advance one token in a [batch, 1] step; a lane
        that cannot get its next page preempts the youngest other lane."""
        ready = set()
        for i, l in sorted(decode, key=lambda t: t[1].admitted_t):
            if self.lanes[i] is not l:
                continue  # preempted as a victim earlier in this loop
            got = self._ensure_pages(l, 1)
            while got == 0:
                victim = self._youngest_other(i)
                if victim is None:
                    break
                self._preempt(victim)
                got = self._ensure_pages(l, 1)
            if got:
                ready.add(i)
        live = [(i, l) for i, l in decode
                if i in ready and self.lanes[i] is l]
        if not live:
            return set()
        plan = {i: 1 for i, _ in live}
        # lanes compact into a bucketed width (requests own pages, not
        # batch rows, so a half-empty batch never pays full-width compute)
        width = width_bucket(len(live), self.b)
        rows = [(r, i, l) for r, (i, l) in enumerate(live)]
        t0 = time.perf_counter()
        logits = self._run_batch(rows, plan, width, 1, phase="decode")
        now = time.perf_counter()
        if self._tr.enabled:
            extra = ({"est_pj": self._hw_prices["decode"][0] * len(live)}
                     if self.hw is not None else {})
            self._tr.complete("decode", SCHED_TRACK, t0, now - t0,
                              lanes=len(live), width=width, **extra)
        for r, i, l in rows:
            l.pos += 1
            self._hw_charge(l.req, "decode", 1)
            self._c_ctx.inc()
            self._maybe_cache_prefix(l)  # before _sample can free the pages
            self._sample(i, l, logits[r], now)
        return {i for i, _ in live}

    # -- speculative decoding ------------------------------------------------
    def _fresh_spec_state(self) -> Dict[str, Any]:
        return {"on": True, "ema": None, "rounds": 0}

    def _partition_spec(self, decode):
        """Split decode lanes into spec-staged and plain.

        A lane speculates when its request's speculation is still on, it can
        still emit ≥ 2 tokens (otherwise a round cannot beat one decode
        step), the gamma+1 verify window stays inside the addressable page
        table, and the extra pages stage in one shot — page shortage demotes
        the lane to plain decode for this tick (the plain path owns the
        preemption machinery).  Staging snapshots a page checkpoint FIRST so
        the round's growth is fully attributable and rollback-exact.
        """
        if self.spec is None:
            return [], decode
        g = self.spec.gamma
        addressable = (self.W - 1) * self.page_size
        staged, plain = [], []
        for i, l in sorted(decode, key=lambda t: t[1].admitted_t):
            st = self._spec_state.setdefault(l.req.uid,
                                             self._fresh_spec_state())
            allowance = min(l.req.max_new_tokens - len(l.req.generated),
                            self.max_len - len(l.ctx))
            ok = (st["on"] and allowance >= 2
                  and l.pos + g + 1 <= addressable)
            if ok:
                ck = kv_checkpoint(self.pool, l.pages)
                # drafts must never roll back (or write into) a SHARED page:
                # COW the last partial prefix-hit page before any draft KV
                # lands, so rollback only ever touches exclusively-owned
                # growth (page shortage demotes the lane to plain decode)
                if not self._cow_shared_page(l):
                    ok = False
                need = pages_for(l.pos + g + 1, self.page_size) - len(l.pages)
                if ok and need > 0:
                    got = self._alloc(need)
                    if got is None:
                        ok = False
                    else:
                        l.pages.extend(got)
                if ok:
                    staged.append((i, l, ck))
            if not ok:
                plain.append((i, l))
        return staged, plain

    def _pack_rows(self, rows, toks, poss, n_rows: int, t_step: int):
        """Assemble one fixed-shape batch from per-lane token/position lists
        (pad rows/columns carry the garbage position, like _run_batch)."""
        tokens = np.zeros((n_rows, t_step), np.int32)
        positions = np.full((n_rows, t_step), self.pad_pos, np.int32)
        last_idx = np.zeros((n_rows,), np.int32)
        table = np.full((n_rows, self.W), GARBAGE_PAGE, np.int32)
        for r, i, l in rows:
            seq = toks[i]
            n = len(seq)
            tokens[r, :n] = seq
            positions[r, :n] = poss[i]
            last_idx[r] = n - 1
            table[r, : len(l.pages)] = l.pages
        return tokens, positions, last_idx, table

    def _run_draft(self, rows, toks, poss, width: int,
                   t_step: int) -> np.ndarray:
        """One fused draft call → all gamma proposals [width, gamma]."""
        if self.analysis_debug and self._provider.shared_cache:
            # the fused call feeds poss[i] then scans gamma-1 single-token
            # steps, each writing the next position — the full write span is
            # poss[i] plus (gamma - 1) positions past its end.  Own-cache
            # providers write the draft pool, whose ledger the target pool's
            # refcounts/trie do not govern (catch-up deliberately rewrites
            # shared-prefix draft rows; the rewrite is idempotent).
            g = self.spec.gamma
            self._submit_plan("spec_draft", rows, {
                i: list(poss[i]) + [poss[i][-1] + 1 + k for k in range(g - 1)]
                for _, i, _ in rows})
        tokens, positions, last_idx, table = self._pack_rows(
            rows, toks, poss, width, t_step)
        caches = (self.caches if self._provider.shared_cache
                  else self.draft_caches)
        drafts, new = self._draft_step(
            self._provider.params, caches, jnp.asarray(tokens),
            mk_positions(self._provider.cfg, jnp.asarray(positions)),
            jnp.asarray(table), jnp.asarray(last_idx),
        )
        if self._provider.shared_cache:
            self.caches = new
        else:
            self.draft_caches = new
        self._c_draft_steps.inc(self.spec.gamma)
        return np.asarray(drafts)

    def _run_ingest(self, rows, toks, poss, width: int, t_step: int) -> None:
        tokens, positions, last_idx, table = self._pack_rows(
            rows, toks, poss, width, t_step)
        _, self.draft_caches = self._draft_ingest(
            self._provider.params, self.draft_caches, jnp.asarray(tokens),
            mk_positions(self._provider.cfg, jnp.asarray(positions)),
            jnp.asarray(table), jnp.asarray(last_idx),
        )

    def _draft_catch_up(self, rows) -> None:
        """Own-cache providers only: chunked ingestion of the context the
        draft model has not seen (first spec round after admission or
        preemption).  Feeds prefill_chunk-bucketed slices through the draft
        step — the target side deliberately chunks its prefill for the same
        reason, and the fused draft call afterwards always runs at its
        small warmed shapes, never a one-shot full-context feed."""
        chunk = self.prefill_chunk
        while True:
            pend = [(i, l) for _, i, l in rows
                    if l.pos - l.draft_pos >= chunk]
            if not pend:
                return
            toks: Dict[int, List[int]] = {}
            poss: Dict[int, List[int]] = {}
            for i, l in pend:
                n = min(chunk, l.pos - l.draft_pos)
                toks[i] = list(l.ctx[l.draft_pos : l.draft_pos + n])
                poss[i] = list(range(l.draft_pos, l.draft_pos + n))
            t = min(pow2_bucket(max(len(x) for x in toks.values())), chunk)
            sub = [(r, i, l) for r, (i, l) in enumerate(pend)]
            self._run_ingest(sub, toks, poss,
                             width_bucket(len(pend), self.b), t)
            for i, l in pend:
                l.draft_pos += len(toks[i])
                self._hw_charge(l.req, "draft_ingest", len(toks[i]))

    def _run_verify(self, rows, toks, poss, width: int,
                    t_step: int) -> np.ndarray:
        if self.analysis_debug:
            self._submit_plan("spec_verify", rows, poss)
        tokens, positions, _, table = self._pack_rows(
            rows, toks, poss, width, t_step)
        logits, self.caches = self._verify_step(
            self.params, self.caches, jnp.asarray(tokens),
            mk_positions(self.cfg, jnp.asarray(positions)),
            jnp.asarray(table),
        )
        self._c_verify_steps.inc()
        return np.asarray(logits)  # [width, t_step, V]

    def _spec_phase(self, staged) -> set:
        """One speculative round for the staged lanes: gamma batched draft
        steps (the first coalesces any draft-side catch-up), ONE batched
        full-precision verify over the gamma+1 window, greedy acceptance,
        then page rollback so rejected drafts leave no trace."""
        g = self.spec.gamma
        rows = [(r, i, l) for r, (i, l, _) in enumerate(staged)]
        ckpts = {i: ck for i, _, ck in staged}
        width = width_bucket(len(rows), self.b)
        shared = self._provider.shared_cache
        toks: Dict[int, List[int]] = {}
        poss: Dict[int, List[int]] = {}
        drafts: Dict[int, List[int]] = {}
        start_pos: Dict[int, int] = {}
        t0 = time.perf_counter()
        # one fused draft call: catch-up feed (own-cache providers ingest
        # what the target accepted since their last round; anything longer
        # than a prefill chunk was pre-ingested in bucketed slices) + gamma
        # greedy proposals scanned on-device
        if not shared:
            self._draft_catch_up(rows)
        for _, i, l in rows:
            start_pos[i] = l.pos
            s = l.pos if shared else min(l.draft_pos, l.pos)
            toks[i] = list(l.ctx[s : l.pos + 1])
            poss[i] = list(range(s, l.pos + 1))
        t1 = min(pow2_bucket(max(len(t) for t in toks.values())),
                 max(self.prefill_chunk, 1))
        # per-lane draft work this round: the fused call feeds len(toks[i])
        # tokens (catch-up + x_t, yielding the first proposal) then scans
        # gamma-1 more single-token steps — capture before toks is rebuilt
        # for verify below
        feed = {i: len(toks[i]) for _, i, _ in rows}
        dmat = self._run_draft(rows, toks, poss, width, t1)
        for r, i, _ in rows:
            drafts[i] = [int(t) for t in dmat[r]]
        # one batched verify over [x_t, d_1..d_g] — full precision, logits
        # at every position, exact KV overwrites the draft-quality rows
        for _, i, l in rows:
            toks[i] = [l.ctx[start_pos[i]]] + drafts[i]
            poss[i] = list(range(start_pos[i], start_pos[i] + g + 1))
        vlogits = self._run_verify(rows, toks, poss, width,
                                   pow2_bucket(g + 1))
        now = time.perf_counter()
        out = set()
        for r, i, l in rows:
            verify = [int(np.argmax(vlogits[r, j])) for j in range(g + 1)]
            m = greedy_accept(drafts[i], verify)
            # charge the round's executed work BEFORE _accept_tokens: a lane
            # finishing mid-round observes req_hw_pj with this round included
            round_pj = (self._hw_charge(l.req, "draft", feed[i] + g - 1)
                        + self._hw_charge(l.req, "verify", g + 1))
            emitted = self._accept_tokens(i, l, verify[:m], now)
            l.pos = start_pos[i] + emitted
            # own-cache draft KV is valid for the matched prefix only
            l.draft_pos = min(start_pos[i] + g, l.pos)
            self._c_ctx.inc(emitted)
            self._c_spec_rounds.inc()
            self._c_drafted.inc(g)
            self._c_accepted.inc(m - 1)
            if m == g + 1:
                self._c_bonus.inc()
            if self._tr.enabled:
                extra = ({"est_pj": round_pj}
                         if self.hw is not None else {})
                self._tr.complete("spec_round", request_track(l.req.uid),
                                  t0, now - t0, drafted=g, accepted=m - 1,
                                  emitted=emitted, **extra)
            self._update_spec_state(l.req.uid, (m - 1) / g)
            if self.lanes[i] is l:  # still running: release rejected pages
                kv_rollback(self.pool, l.pages, ckpts[i],
                            keep=pages_for(l.pos, self.page_size))
                self._maybe_cache_prefix(l)
            out.add(i)
        return out

    def _accept_tokens(self, i: int, lane: _Lane, tokens, now: float) -> int:
        """Emit verified tokens in order (stream callbacks, timing, finish
        checks); returns how many were emitted before a finish condition."""
        req = lane.req
        emitted = 0
        for tok in tokens:
            if not req.generated:
                req.first_token_t = now
                self._h_ttft.observe(now - req.submit_t)
            elif req.token_times:
                self._h_itl.observe(now - req.token_times[-1])
            req.token_times.append(now)
            req.generated.append(tok)
            lane.ctx.append(tok)
            emitted += 1
            self._c_out.inc()
            if self._tr.enabled:
                # stamped with the SAME clock value written to token_times,
                # so trace-derived TTFT/ITL equal latency_metrics() exactly
                self._tr.instant("token", request_track(req.uid), ts=now,
                                 n=len(req.generated))
            if req.on_token is not None:
                req.on_token(req.uid, tok)
            if (tok == req.eos_id
                    or len(req.generated) >= req.max_new_tokens
                    or len(lane.ctx) >= self.max_len):
                req.finish_t = now
                self.pool.free(lane.pages)
                self.done[req.uid] = req
                self.lanes[i] = None
                if self.hw is not None:
                    self._h_req_pj.observe(req.hw_pj)
                if self._tr.enabled:
                    track = request_track(req.uid)
                    self._tr.instant("finish", track, ts=now,
                                     tokens=len(req.generated))
                    self._tr.end("running", track, ts=now)
                break
        return emitted

    def _update_spec_state(self, uid: int, rate: float) -> None:
        """Per-request acceptance EMA; below-breakeven requests stop
        speculating (draft effort would cost more than it saves)."""
        st = self._spec_state[uid]
        a = self.spec.ema_alpha
        st["ema"] = rate if st["ema"] is None else a * rate + (1 - a) * st["ema"]
        st["rounds"] += 1
        if (st["on"] and st["rounds"] >= self.spec.warmup_rounds
                and st["ema"] < self._spec_floor):
            st["on"] = False
            self._c_spec_off.inc()

    def _sample(self, i: int, lane: _Lane, row: np.ndarray, now: float) -> None:
        req = lane.req
        if self.greedy:
            tok = int(np.argmax(row))
        else:
            key = jax.random.key((req.uid << 20) + len(req.generated))
            tok = int(jax.random.categorical(key, jnp.asarray(row)))
        if not req.generated:
            req.first_token_t = now
            self._h_ttft.observe(now - req.submit_t)
        elif req.token_times:
            self._h_itl.observe(now - req.token_times[-1])
        req.token_times.append(now)
        req.generated.append(tok)
        lane.ctx.append(tok)
        self._c_out.inc()
        if self._tr.enabled:
            # same clock value as token_times → exact TTFT/ITL reconstruction
            self._tr.instant("token", request_track(req.uid), ts=now,
                             n=len(req.generated))
        if req.on_token is not None:
            req.on_token(req.uid, tok)
        finished = (
            tok == req.eos_id
            or len(req.generated) >= req.max_new_tokens
            or len(lane.ctx) >= self.max_len
        )
        if finished:
            req.finish_t = now
            self.pool.free(lane.pages)
            self.done[req.uid] = req
            self.lanes[i] = None
            if self.hw is not None:
                self._h_req_pj.observe(req.hw_pj)
            if self._tr.enabled:
                track = request_track(req.uid)
                self._tr.instant("finish", track, ts=now,
                                 tokens=len(req.generated))
                self._tr.end("running", track, ts=now)

    def run(self, max_steps: int = 100_000) -> Dict[int, Request]:
        for _ in range(max_steps):
            if not self.step() and not self.queue:
                break
        return self.done

    def warmup(self) -> int:
        """Pre-compile every step-shape bucket (decode widths × prefill
        chunk buckets). The dummy batches carry only pad rows, so writes
        land in the garbage page and no live request state is touched.
        Returns the number of shapes compiled."""
        shapes = [(w, 1) for w in width_buckets(self.b)]
        t = 1
        while t < self.prefill_chunk:
            shapes.append((self.prefill_lanes, t))
            t *= 2
        shapes.append((self.prefill_lanes, self.prefill_chunk))
        shapes = list(dict.fromkeys(shapes))
        for bw, ts in shapes:
            tokens = jnp.zeros((bw, ts), jnp.int32)
            positions = jnp.full((bw, ts), self.pad_pos, dtype=jnp.int32)
            table = jnp.full((bw, self.W), GARBAGE_PAGE, dtype=jnp.int32)
            last_idx = jnp.zeros((bw,), jnp.int32)
            _, self.caches = self._step(
                self.params, self.caches, tokens,
                mk_positions(self.cfg, positions), table, last_idx,
            )
        n_spec = 0
        if self.spec is not None:
            # draft [w, 1] + verify [w, pow2(gamma+1)] per decode width
            tv = pow2_bucket(self.spec.gamma + 1)
            for bw in width_buckets(self.b):
                table = jnp.full((bw, self.W), GARBAGE_PAGE, dtype=jnp.int32)
                dcaches = (self.caches if self._provider.shared_cache
                           else self.draft_caches)
                _, new = self._draft_step(
                    self._provider.params, dcaches,
                    jnp.zeros((bw, 1), jnp.int32),
                    mk_positions(self._provider.cfg,
                                 jnp.full((bw, 1), self.pad_pos, jnp.int32)),
                    table, jnp.zeros((bw,), jnp.int32),
                )
                if self._provider.shared_cache:
                    self.caches = new
                else:
                    self.draft_caches = new
                _, self.caches = self._verify_step(
                    self.params, self.caches,
                    jnp.zeros((bw, tv), jnp.int32),
                    mk_positions(self.cfg,
                                 jnp.full((bw, tv), self.pad_pos, jnp.int32)),
                    table,
                )
                n_spec += 2
        return len(shapes) + n_spec

    # -- maintenance / observability -----------------------------------------
    def defrag(self) -> None:
        """Compact live pages to the pool's low-index prefix (the page tables
        move with them; decode output is unchanged).  An own-cache draft
        provider's pools are indexed by the SAME page tables, so they must
        move under the same remap — both trees ride one defrag call (the
        tables and pool free list are rewritten exactly once).  Trie-held
        prefix pages are live owners too: they remap alongside the tables,
        so cached prefixes keep hitting across a defrag."""
        tables = [l.pages for l in self.lanes if l is not None]
        if self.draft_caches is not None:
            both = defrag({"target": self.caches, "draft": self.draft_caches},
                          self.pool, tables, trie=self.prefix)
            self.caches, self.draft_caches = both["target"], both["draft"]
        else:
            self.caches = defrag(self.caches, self.pool, tables,
                                 trie=self.prefix)

    def metrics(self) -> Dict[str, Any]:
        wall = (time.perf_counter() - self._start_t) if self._start_t else 0.0
        spec = None
        if self.spec is not None:
            drafted = self.drafted_tokens
            spec = {
                "provider": self._provider.name,
                "gamma": self.spec.gamma,
                "cost_ratio": round(self._provider.cost_ratio, 4),
                "rounds": self.spec_rounds,
                "draft_steps": self.draft_steps,
                "verify_steps": self.verify_steps,
                "drafted_tokens": drafted,
                "accepted_drafts": self.accepted_drafts,
                "acceptance_rate": (self.accepted_drafts / drafted
                                    if drafted else 0.0),
                "bonus_tokens": self.bonus_tokens,
                "draft_compiles": self.draft_compiles,
                "verify_compiles": self.verify_compiles,
                "disable_floor": round(self._spec_floor, 4),
                "disabled_requests": self.spec_disabled,
                "enabled_requests": sum(
                    1 for s in self._spec_state.values() if s["on"]),
            }
        prefix = None
        if self.prefix is not None:
            pc = self.prefix
            prefix = {
                "lookups": self.prefix_lookups,
                "hits": self.prefix_hits,
                # token-weighted: the share of admitted prompt tokens whose
                # KV came off cached pages instead of prefill compute
                "hit_rate": (pc.cached_tokens / pc.lookup_tokens
                             if pc.lookup_tokens else 0.0),
                "cached_tokens": pc.cached_tokens,
                "evictions": pc.evictions,
                "trie_pages": pc.n_pages,
                "cow_copies": self.cow_copies,
            }
        # KV storage pricing: what a resident token costs at this pool's
        # precision, and the capacity multiplier vs compute-dtype pages at
        # equal pool bytes (1.0 when every position runs the fp16 escape
        # hatch; ~itemsize(compute)*hd/(hd+2) per quantized position).
        bpt = sum(kv_token_bytes(self.cfg, dt)
                  for dt in self.kv_dtypes.values()) * self.cfg.n_periods
        fp_bpt = (kv_token_bytes(self.cfg, "fp16") * len(self.kv_dtypes)
                  * self.cfg.n_periods)
        # one source for byte accounting: the pool's own stats feed both the
        # "pool" section and the kv section's byte keys ("pool_bytes" stays
        # the measured device-array footprint, which the sharded caches can
        # pad past page_bytes * n_pages)
        # estimated cost of the run on the paper's DA hardware: the static
        # per-token table (summary) plus LIVE workload-weighted totals —
        # executed token-passes per phase × per-phase prices, with the
        # bit-slicing counterfactual priced over the SAME executed work so
        # the live ratios answer "what did this workload save"
        hw = None
        if self.hw is not None:
            hw = self.hw.summary()
            phases = sorted(self._hw_prices)
            tokens = {p: self._c_hw_tokens.value(phase=p) for p in phases}
            est_pj = {p: self._c_hw_pj.value(phase=p) for p in phases}
            est_ns = {p: self._c_hw_ns.value(phase=p) for p in phases}
            total_pj = sum(est_pj.values())
            total_ns = sum(est_ns.values())
            bs_pj = sum(self._hw_bs[p][0] * tokens[p] for p in phases)
            bs_ns = sum(self._hw_bs[p][1] * tokens[p] for p in phases)
            out_toks = self.out_tokens
            hw.update({
                "tokens": tokens,
                "est_pj": {**est_pj, "total": total_pj},
                "est_ns": {**est_ns, "total": total_ns},
                "pj_per_out_token": (total_pj / out_toks
                                     if out_toks else 0.0),
                "live": {
                    "da_pj": total_pj,
                    "bitslice_pj": bs_pj,
                    "energy_ratio": bs_pj / total_pj if total_pj else 0.0,
                    "da_ns": total_ns,
                    "bitslice_ns": bs_ns,
                    "latency_ratio": bs_ns / total_ns if total_ns else 0.0,
                },
            })
            if self._hw_draft is not None:
                hw["draft"] = dict(self._hw_draft)
        pool_stats = self.pool.stats()
        kv = {
            "kv_dtypes": dict(self.kv_dtypes),
            "bytes_per_token": bpt,
            "fp_bytes_per_token": fp_bpt,
            "capacity_multiplier": fp_bpt / bpt if bpt else 0.0,
            "page_bytes": pool_stats["page_bytes"],
            "used_bytes": pool_stats["used_bytes"],
            "free_bytes": pool_stats["free_bytes"],
            "pool_bytes": kv_cache_nbytes(self.caches),
        }
        return {
            **base_metrics("paged", self.done, self.out_tokens),
            "ctx_tokens": self.ctx_tokens,
            "steps": self.steps,
            "preemptions": self.preemptions,
            "step_compiles": self.step_compiles,
            "wall_s": wall,
            "tokens_per_s": self.out_tokens / wall if wall > 0 else 0.0,
            "pool": pool_stats,
            "kv": kv,
            "hw": hw,
            "spec": spec,
            "prefix_cache": prefix,
        }
