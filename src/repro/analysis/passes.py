"""Graph invariant passes: certify what the paper *claims* about the graph.

The headline claim is structural — DA replaces every weight multiply with
shift-and-add over stored weight-sums.  These passes prove the compiled
serving steps honor that contract instead of trusting the numerics tests:

* ``multiplier-free`` (jaxpr taint analysis): no float ``dot_general`` /
  ``convolution`` consumes a value on the weight datapath.  Weight leaves
  are taint sources; integer codes/LUTs taint ``INT_EXACT``, raw float
  weights taint ``FLOAT``.  A float dot over a ``FLOAT``-tainted operand
  is the multiplier the paper eliminated — flagged.  An ``INT_EXACT``
  operand may reach a float dot only when the *other* operand is a 0/1
  selector (a one-hot address row or an extracted bit-plane): that dot is
  an exact gather/shift-add in MXU clothing, the sanctioned DA trick.
  Anything else (e.g. dequantized codes fed to a real matmul) is flagged.
* ``no-big-gather`` (HLO): the PR-6 structural assert, generalized — no
  gather at (or above) the ``[B, W·ps, kv, hd]`` page-table view size in
  any fused-attention lowering, quantized-scale pools included.
* ``no-host-sync`` (HLO): the jitted step must not round-trip the host —
  no callbacks, infeed/outfeed, send/recv, or f64 escapes.
* ``dtype-discipline`` (HLO): softmax accumulates in f32 (no sub-f32
  ``exponential``); DA accumulators never silently widen past 32 bits.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.analysis import hlo as hlo_mod
from repro.analysis.findings import Finding

#: Default allowlist: substrings matched against a finding's ``where``/
#: ``op``.  The bit-slicing *baseline* (``core/bitslice.py``) is the
#: paper's comparison datapath — it keeps conventional partial-product
#: multiplies by design, so its sites are exempt from ``multiplier-free``.
DEFAULT_ALLOWLIST: Tuple[str, ...] = ("bitslice_vmm", "core/bitslice.py")


# ---------------------------------------------------------------------------
# Taint lattice
# ---------------------------------------------------------------------------


class Flavor(enum.IntEnum):
    """Weight-datapath taint flavor, ordered for lattice joins."""

    NONE = 0        # not weight-derived (activations, indices, constants)
    INT_EXACT = 1   # integer weight codes / LUT sums, exact so far
    FLOAT = 2       # float weight values (raw or dequantized pre-reduce)


@dataclasses.dataclass(frozen=True)
class Taint:
    """Per-value state: weight flavor + is the value a 0/1 selector."""

    flavor: Flavor = Flavor.NONE
    selector: bool = False

    def join(self, other: "Taint") -> "Taint":
        return Taint(
            flavor=Flavor(max(self.flavor, other.flavor)),
            selector=self.selector and other.selector,
        )


UNTAINTED = Taint()
SELECTOR = Taint(flavor=Flavor.NONE, selector=True)


class _RefCell:
    """Mutable taint cell backing a Pallas ``Ref`` (monotone under join)."""

    __slots__ = ("taint",)

    def __init__(self, taint: Taint = UNTAINTED) -> None:
        self.taint = taint

    def join_in(self, t: Taint) -> bool:
        new = self.taint.join(t)  # monotone: the fixed point terminates
        changed = new != self.taint
        self.taint = new
        return changed


# Ops through which taint and selector-ness pass unchanged from the first
# (data) operand; trailing operands are indices/sizes.
_SHAPE_ONLY = {
    "reshape", "transpose", "squeeze", "expand_dims", "broadcast_in_dim",
    "slice", "dynamic_slice", "rev", "copy", "convert_element_type",
    "stop_gradient", "reduce_precision", "gather",
}
# Ops joining several data operands; selector survives iff all are selectors.
_JOIN_DATA = {"concatenate", "pad", "select_n", "select", "clamp",
              "dynamic_update_slice", "scatter", "scatter-add", "sort"}
# Bitwise / integer-exact arithmetic: flavor passes through.
_INT_EXACT_OK = {
    "add", "sub", "mul", "neg", "abs", "max", "min", "rem", "sign",
    "shift_left", "shift_right_logical", "shift_right_arithmetic",
    "right_shift", "and", "or", "xor", "not", "population_count",
    "clz", "dot_general_int",  # (marker; real dots handled separately)
}
# Comparisons: output is a fresh 0/1 selector, flavor drops.
_COMPARE = {"eq", "ne", "lt", "gt", "le", "ge"}
# Reductions that end a shift-add accumulation chain.
_REDUCE = {"reduce_sum", "reduce_max", "reduce_min", "reduce_prod",
           "reduce_and", "reduce_or", "argmax", "argmin", "reduce"}
# Value-killing ops: outputs carry no weight information.
_FRESH = {"iota", "rng_bit_generator", "rng_uniform", "program_id",
          "num_programs", "create_token"}

_MF_PASS = "graph/multiplier-free"


def _is_float(aval: Any) -> bool:
    dtype = getattr(aval, "dtype", None)
    return dtype is not None and np.issubdtype(dtype, np.floating)


def _is_ref(var: Any) -> bool:
    aval = getattr(var, "aval", None)
    return aval is not None and hasattr(aval, "inner_aval")


def _where(eqn: Any) -> str:
    try:
        from jax._src import source_info_util

        frame = source_info_util.user_frame(eqn.source_info)
        if frame is None:
            return ""
        return f"{frame.file_name}:{frame.start_line}"
    except Exception:
        return ""


def _literal_is_one(atom: Any) -> bool:
    val = getattr(atom, "val", None)
    if val is None:
        return False
    try:
        return bool(np.all(np.asarray(val) == 1))
    except Exception:
        return False


class _TaintInterpreter:
    """Abstract interpreter propagating weight taint through a jaxpr."""

    def __init__(self, findings: List[Finding], step_name: str) -> None:
        self.findings = findings
        self.step_name = step_name

    # -- environment ------------------------------------------------------

    def _read(self, env: Dict[Any, Any], atom: Any) -> Any:
        if not hasattr(atom, "aval") or type(atom).__name__ == "Literal":
            return UNTAINTED
        return env.get(atom, UNTAINTED)

    def _taint_of(self, env: Dict[Any, Any], atom: Any) -> Taint:
        val = self._read(env, atom)
        return val.taint if isinstance(val, _RefCell) else val

    # -- entry ------------------------------------------------------------

    def run(self, jaxpr: Any, args: Sequence[Any],
            consts: Sequence[Any] = ()) -> Tuple[List[Any], bool]:
        """Propagate through one jaxpr; returns (out values, changed)."""
        env: Dict[Any, Any] = {}
        for var, val in zip(jaxpr.constvars, consts):
            env[var] = val
        for var, val in zip(jaxpr.invars, args):
            env[var] = val
        changed = False
        for eqn in jaxpr.eqns:
            changed |= self._eqn(env, eqn)
        outs = [self._read(env, v) for v in jaxpr.outvars]
        return outs, changed

    # -- one equation -----------------------------------------------------

    def _eqn(self, env: Dict[Any, Any], eqn: Any) -> bool:
        prim = eqn.primitive.name
        handler = getattr(self, "_h_" + prim.replace("-", "_"), None)
        if handler is not None:
            return bool(handler(env, eqn))
        taints = [self._taint_of(env, a) for a in eqn.invars]
        out = self._default(prim, eqn, taints)
        for var in eqn.outvars:
            env[var] = out
        return False

    def _default(self, prim: str, eqn: Any, taints: List[Taint]) -> Taint:
        joined = UNTAINTED
        for t in taints:
            joined = Taint(Flavor(max(joined.flavor, t.flavor)), False)
        if prim in _FRESH:
            return UNTAINTED
        if prim in _COMPARE:
            return SELECTOR
        if prim in _SHAPE_ONLY:
            return taints[0] if taints else UNTAINTED
        if prim in _JOIN_DATA:
            sel = bool(taints) and all(
                t.selector or not t.flavor for t in taints
            ) and any(t.selector for t in taints)
            return Taint(joined.flavor, sel)
        if prim == "and" and any(_literal_is_one(a) for a in eqn.invars):
            # bit extraction: and(x >> b, 1) yields a 0/1 plane
            return Taint(joined.flavor, True)
        out_aval = eqn.outvars[0].aval if eqn.outvars else None
        if prim in _REDUCE:
            # accumulation endpoint: the shift-add chain terminates here;
            # what leaves is an inner-product value, not a weight
            return UNTAINTED
        if prim in _INT_EXACT_OK and out_aval is not None \
                and not _is_float(out_aval):
            sel = all(t.selector or not t.flavor for t in taints) and any(
                t.selector for t in taints
            )
            return Taint(joined.flavor, sel)
        if out_aval is not None and _is_float(out_aval) \
                and joined.flavor == Flavor.INT_EXACT:
            # float arithmetic on exact codes before any reduction: the
            # value is now a float weight surrogate (the dequantize-then-
            # matmul cheat) — escalate so a downstream dot flags it
            return Taint(Flavor.FLOAT, False)
        return joined

    # -- the check itself -------------------------------------------------

    def _check_dot(self, env: Dict[Any, Any], eqn: Any, kind: str) -> None:
        taints = [self._taint_of(env, a) for a in eqn.invars[:2]]
        out_aval = eqn.outvars[0].aval
        if not _is_float(out_aval) and not any(
            _is_float(a.aval) for a in eqn.invars[:2]
        ):
            return  # integer dot: shift-add by construction
        pair = list(zip(taints, reversed(taints)))
        for i, (mine, other) in enumerate(pair):
            side = "lhs" if i == 0 else "rhs"
            if mine.flavor == Flavor.FLOAT:
                self.findings.append(Finding(
                    pass_name=_MF_PASS, severity="error",
                    op=f"{kind}({side} float weight operand)",
                    hint="a float matmul consumes weight values — the "
                         "multiplier the paper eliminated; freeze the "
                         "layer (PackedWeights) or allowlist a baseline",
                    where=_where(eqn), step=self.step_name,
                ))
            elif mine.flavor == Flavor.INT_EXACT and not other.selector:
                self.findings.append(Finding(
                    pass_name=_MF_PASS, severity="error",
                    op=f"{kind}({side} integer weight codes x non-selector)",
                    hint="integer weight codes may meet a float dot only "
                         "against a 0/1 selector (one-hot LUT address or "
                         "extracted bit-plane); this operand is a general "
                         "float value — a real multiply over weights",
                    where=_where(eqn), step=self.step_name,
                ))

    def _h_dot_general(self, env: Dict[Any, Any], eqn: Any) -> bool:
        self._check_dot(env, eqn, "dot_general")
        for var in eqn.outvars:
            env[var] = UNTAINTED
        return False

    def _h_conv_general_dilated(self, env: Dict[Any, Any], eqn: Any) -> bool:
        self._check_dot(env, eqn, "convolution")
        for var in eqn.outvars:
            env[var] = UNTAINTED
        return False

    # -- higher-order primitives ------------------------------------------

    def _sub_jaxpr(self, params: Dict[str, Any]) -> Tuple[Any, List[Any]]:
        closed = params.get("jaxpr") or params.get("call_jaxpr")
        if closed is None:
            raise KeyError("no sub-jaxpr")
        if hasattr(closed, "jaxpr"):  # ClosedJaxpr
            return closed.jaxpr, [UNTAINTED] * len(closed.consts)
        return closed, []

    def _call_like(self, env: Dict[Any, Any], eqn: Any) -> bool:
        try:
            sub, consts = self._sub_jaxpr(eqn.params)
        except KeyError:
            for var in eqn.outvars:
                env[var] = UNTAINTED
            return False
        args = [self._read(env, a) for a in eqn.invars]
        outs, _ = self.run(sub, args, consts)
        for var, out in zip(eqn.outvars, outs):
            env[var] = out
        return False

    _h_pjit = _call_like
    _h_closed_call = _call_like
    _h_custom_jvp_call = _call_like
    _h_custom_vjp_call = _call_like
    _h_custom_vjp_call_jaxpr = _call_like
    _h_remat = _call_like
    _h_checkpoint = _call_like
    _h_core_call = _call_like
    _h_xla_call = _call_like

    def _h_cond(self, env: Dict[Any, Any], eqn: Any) -> bool:
        args = [self._read(env, a) for a in eqn.invars[1:]]
        outs: Optional[List[Any]] = None
        for branch in eqn.params["branches"]:
            b_outs, _ = self.run(
                branch.jaxpr, args, [UNTAINTED] * len(branch.consts)
            )
            if outs is None:
                outs = b_outs
            else:
                outs = [
                    o if isinstance(o, _RefCell) else o.join(
                        b.taint if isinstance(b, _RefCell) else b
                    )
                    for o, b in zip(outs, b_outs)
                ]
        for var, out in zip(eqn.outvars, outs or []):
            env[var] = out
        return False

    def _h_scan(self, env: Dict[Any, Any], eqn: Any) -> bool:
        params = eqn.params
        closed = params["jaxpr"]
        n_consts = params["num_consts"]
        n_carry = params["num_carry"]
        args = [self._read(env, a) for a in eqn.invars]
        consts, carry, xs = (
            args[:n_consts], args[n_consts:n_consts + n_carry],
            args[n_consts + n_carry:],
        )
        carry_t = [c.taint if isinstance(c, _RefCell) else c for c in carry]
        outs: List[Any] = []
        for _ in range(8):  # lattice height is tiny; convergence is fast
            outs, _ = self.run(
                closed.jaxpr, list(consts) + list(carry_t) + list(xs),
                [UNTAINTED] * len(closed.consts),
            )
            new_carry = [
                (o.taint if isinstance(o, _RefCell) else o).join(c)
                for o, c in zip(outs[:n_carry], carry_t)
            ]
            if new_carry == carry_t:
                break
            carry_t = new_carry
        flat = list(carry_t) + [
            o.taint if isinstance(o, _RefCell) else o for o in outs[n_carry:]
        ]
        for var, out in zip(eqn.outvars, flat):
            env[var] = out
        return False

    def _h_while(self, env: Dict[Any, Any], eqn: Any) -> bool:
        params = eqn.params
        cond_n = params["cond_nconsts"]
        body_n = params["body_nconsts"]
        body = params["body_jaxpr"]
        args = [self._read(env, a) for a in eqn.invars]
        body_consts = args[cond_n:cond_n + body_n]
        carry = args[cond_n + body_n:]
        carry_t = [c.taint if isinstance(c, _RefCell) else c for c in carry]
        for _ in range(8):
            outs, _ = self.run(
                body.jaxpr, list(body_consts) + list(carry_t),
                [UNTAINTED] * len(body.consts),
            )
            new_carry = [
                (o.taint if isinstance(o, _RefCell) else o).join(c)
                for o, c in zip(outs, carry_t)
            ]
            if new_carry == carry_t:
                break
            carry_t = new_carry
        for var, out in zip(eqn.outvars, carry_t):
            env[var] = out
        return False

    # -- Pallas kernels ----------------------------------------------------

    def _h_pallas_call(self, env: Dict[Any, Any], eqn: Any) -> bool:
        sub = eqn.params["jaxpr"]
        args = [self._read(env, a) for a in eqn.invars]
        n_out = len(eqn.outvars)
        cells: List[Any] = []
        for i, var in enumerate(sub.invars):
            if i < len(args):
                seed = args[i]
                seed_t = seed.taint if isinstance(seed, _RefCell) else seed
            else:
                seed_t = UNTAINTED
            cells.append(_RefCell(seed_t) if _is_ref(var) else seed_t)
        for _ in range(8):  # refs are monotone join cells: fixed point
            _, changed = self.run(sub, cells, [])
            if not changed:
                break
        # inner invars: [*outer operands (prefetch + inputs), *out refs,
        # *scratch refs] — outputs sit right after the operand block
        out_cells = cells[len(args):len(args) + n_out]
        for var, cell in zip(eqn.outvars, out_cells):
            env[var] = cell.taint if isinstance(cell, _RefCell) else UNTAINTED
        return False

    # -- Ref state primitives (inside Pallas bodies) -----------------------

    def _h_get(self, env: Dict[Any, Any], eqn: Any) -> bool:
        cell = self._read(env, eqn.invars[0])
        taint = cell.taint if isinstance(cell, _RefCell) else UNTAINTED
        for var in eqn.outvars:
            env[var] = taint
        return False

    def _h_swap(self, env: Dict[Any, Any], eqn: Any) -> bool:
        cell = self._read(env, eqn.invars[0])
        val = self._taint_of(env, eqn.invars[1])
        changed = False
        if isinstance(cell, _RefCell):
            changed = cell.join_in(val)
            for var in eqn.outvars:  # the joined view is the sound read
                env[var] = cell.taint
        else:
            for var in eqn.outvars:
                env[var] = val
        return changed

    def _h_addupdate(self, env: Dict[Any, Any], eqn: Any) -> bool:
        cell = self._read(env, eqn.invars[0])
        val = self._taint_of(env, eqn.invars[1])
        if isinstance(cell, _RefCell):
            return cell.join_in(val)
        return False


# ---------------------------------------------------------------------------
# Pass entry points
# ---------------------------------------------------------------------------


def multiplier_free(
    closed_jaxpr: Any,
    arg_taints: Sequence[Taint],
    step_name: str = "",
) -> List[Finding]:
    """Taint-check one traced step's jaxpr (allowlist applied by
    :func:`run_passes`)."""
    findings: List[Finding] = []
    interp = _TaintInterpreter(findings, step_name)
    jaxpr = closed_jaxpr.jaxpr
    args = list(arg_taints)
    if len(args) != len(jaxpr.invars):
        raise ValueError(
            f"{step_name}: {len(args)} arg taints for "
            f"{len(jaxpr.invars)} jaxpr inputs — seed taints with "
            "graph.arg_taints over the same flattened arguments"
        )
    interp.run(jaxpr, args, [UNTAINTED] * len(closed_jaxpr.consts))
    return findings


def no_big_gather(
    hlo_text: str,
    view_bytes: int,
    step_name: str = "",
) -> List[Finding]:
    """No gather at (or above) the re-materialized page-table KV view size
    — the op the fused Pallas page walk exists to remove."""
    findings: List[Finding] = []
    for name, nbytes in hlo_mod.ops_of_kind(hlo_text, "gather"):
        if nbytes >= view_bytes:
            findings.append(Finding(
                pass_name="graph/no-big-gather", severity="error",
                op=f"gather {name}", bytes=nbytes,
                hint=f"materializes >= the [B, W*ps, kv, hd] page-table "
                     f"view ({view_bytes} B) inside a fused-attention "
                     "lowering; the page walk must stay in-kernel",
                step=step_name,
            ))
    return findings


#: custom-call targets that stay on-device (accelerator kernels, sharding
#: annotations) — everything else is treated as a host round-trip.
_DEVICE_CUSTOM_CALLS = (
    "tpu_custom_call", "mosaic", "triton", "Sharding", "SPMD",
    "annotate_device_placement", "cu_threefry",
    # XLA's sort-free top-k kernel (MoE router lax.top_k lowers to it)
    "TopK",
)
_HOST_SYNC_KINDS = ("infeed", "outfeed", "send", "recv", "send-done",
                    "recv-done")


def no_host_sync(hlo_text: str, step_name: str = "") -> List[Finding]:
    """The jitted step must never synchronize with the host mid-step."""
    findings: List[Finding] = []
    for op in hlo_mod.iter_ops(hlo_text):
        if op.kind in _HOST_SYNC_KINDS:
            findings.append(Finding(
                pass_name="graph/no-host-sync", severity="error",
                op=f"{op.kind} {op.name}", bytes=op.result_bytes,
                hint="host transfer inside the jitted step stalls the "
                     "device every launch; stage data as arguments",
                step=step_name,
            ))
        elif op.kind == "custom-call":
            target = hlo_mod.custom_call_target(op)
            if any(tok in target for tok in ("callback", "python", "host")):
                findings.append(Finding(
                    pass_name="graph/no-host-sync", severity="error",
                    op=f"custom-call {op.name} target={target!r}",
                    bytes=op.result_bytes,
                    hint="a host callback in the hot path serializes every "
                         "step on the Python thread",
                    step=step_name,
                ))
            elif not any(tok in target for tok in _DEVICE_CUSTOM_CALLS):
                findings.append(Finding(
                    pass_name="graph/no-host-sync", severity="warning",
                    op=f"custom-call {op.name} target={target!r}",
                    bytes=op.result_bytes,
                    hint="unrecognized custom-call target; verify it stays "
                         "on-device (extend _DEVICE_CUSTOM_CALLS if so)",
                    step=step_name,
                ))
        elif op.kind == "convert" and op.type_str.startswith("f64"):
            findings.append(Finding(
                pass_name="graph/no-host-sync", severity="error",
                op=f"convert {op.name} -> {op.type_str}",
                bytes=op.result_bytes,
                hint="f64 escape in the step graph — usually a stray "
                     "Python float promoting the whole chain",
                step=step_name,
            ))
    return findings


def dtype_discipline(
    hlo_text: str,
    step_name: str = "",
    acc_bits: int = 32,
) -> List[Finding]:
    """Softmax accumulates in f32; DA accumulators stay within 32 bits."""
    findings: List[Finding] = []
    wide = {"s64", "u64", "f64"}
    for op in hlo_mod.iter_ops(hlo_text):
        dtypes = hlo_mod.shape_dtypes(op.type_str)
        if op.kind == "exponential" and dtypes & {"f16", "bf16"}:
            findings.append(Finding(
                pass_name="graph/dtype-discipline", severity="error",
                op=f"exponential {op.name} ({op.type_str})",
                bytes=op.result_bytes,
                hint="softmax must exponentiate/accumulate in f32 — "
                     "sub-f32 exp breaks the fused==gather bit-identity",
                step=step_name,
            ))
        elif op.kind in ("dot", "convolution") and dtypes & {"s64", "u64"}:
            findings.append(Finding(
                pass_name="graph/dtype-discipline", severity="error",
                op=f"{op.kind} {op.name} ({op.type_str})",
                bytes=op.result_bytes,
                hint=f"DA accumulator widened past acc_bits={acc_bits} "
                     "(64-bit dot) — the shift-add chain silently "
                     "outgrew its hardware accumulator",
                step=step_name,
            ))
        elif dtypes & wide and op.kind not in ("dot", "convolution"):
            findings.append(Finding(
                pass_name="graph/dtype-discipline", severity="error",
                op=f"{op.kind} {op.name} ({op.type_str})",
                bytes=op.result_bytes,
                hint="64-bit value in the step graph; the serving stack "
                     "is 32-bit end to end (jax x64 must stay off)",
                step=step_name,
            ))
    return findings


# ---------------------------------------------------------------------------
# Pipeline
# ---------------------------------------------------------------------------


def apply_allowlist(
    findings: Sequence[Finding],
    allow: Sequence[str],
) -> List[Finding]:
    """Drop findings whose ``where``/``op`` matches an allowlist entry."""
    if not allow:
        return list(findings)
    return [
        f for f in findings
        if not any(tok in f.where or tok in f.op for tok in allow)
    ]


def run_passes(
    steps: Sequence[Any],
    allow: Sequence[str] = DEFAULT_ALLOWLIST,
    acc_bits: int = 32,
) -> List[Finding]:
    """Run the full pass pipeline over traced steps (see
    :func:`repro.analysis.graph.trace_serving_steps`)."""
    findings: List[Finding] = []
    for step in steps:
        findings += multiplier_free(
            step.closed_jaxpr, step.arg_taints, step_name=step.name
        )
        if step.hlo:
            if step.fused:
                findings += no_big_gather(
                    step.hlo, step.view_bytes, step_name=step.name
                )
            findings += no_host_sync(step.hlo, step_name=step.name)
            findings += dtype_discipline(
                step.hlo, step_name=step.name, acc_bits=acc_bits
            )
    return apply_allowlist(findings, allow)
