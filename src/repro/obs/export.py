"""Exporters for the observability layer: Chrome trace_event JSON and
Prometheus text exposition, plus the tiny schema checkers CI runs against
the emitted artifacts.

Chrome trace — ``chrome_trace(recorder)`` maps every :class:`TraceEvent`
onto the Trace Event Format (the JSON Perfetto and ``chrome://tracing``
load): one process (pid 0, named after the run), one *thread per track*
(``scheduler``, ``req:<uid>``, …) so request lifecycles render as parallel
swimlanes with spans nested by B/E pairing.  Timestamps convert from
perf_counter seconds to integer-precision microseconds.

Prometheus — ``prometheus_text(registry)`` renders the registry in the text
exposition format (``# HELP`` / ``# TYPE`` + samples; histograms as
cumulative ``_bucket{le=...}`` series with ``_sum``/``_count``), so a
scrape-style pipeline or ``promtool`` ingests serving metrics without a
custom parser.

The validators are deliberately small — structural schema checks (required
fields, known phases, balanced spans, parseable samples), not a Perfetto
re-implementation — and they are what the CI smoke runs over the artifacts
a traced serve emits.
"""
from __future__ import annotations

import json
import math
import os
import re
from typing import Any, Dict, List, Optional

from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    METRICS_SCHEMA_VERSION,
    MetricsRegistry,
)
from repro.obs.trace import TraceRecorder

_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")


# ---------------------------------------------------------------------------
# Chrome trace_event JSON
# ---------------------------------------------------------------------------


def chrome_trace(recorder: TraceRecorder,
                 process_name: str = "repro-serve") -> Dict[str, Any]:
    """Recorder → Trace Event Format dict (``json.dump`` it and load in
    Perfetto).  Tracks map to tids; metadata events name them."""
    tids: Dict[str, int] = {}
    events: List[Dict[str, Any]] = [{
        "ph": "M", "pid": 0, "tid": 0, "name": "process_name",
        "args": {"name": process_name},
    }]

    def tid(track: str) -> int:
        t = tids.get(track)
        if t is None:
            t = tids[track] = len(tids)
            events.append({
                "ph": "M", "pid": 0, "tid": t, "name": "thread_name",
                "args": {"name": track},
            })
            # sort_index keeps the scheduler lane on top, requests below in
            # uid order (tracks are created in first-use order)
            events.append({
                "ph": "M", "pid": 0, "tid": t, "name": "thread_sort_index",
                "args": {"sort_index": t},
            })
        return t

    for ev in recorder.events:
        rec: Dict[str, Any] = {
            "name": ev.name,
            "ph": ev.ph,
            "pid": 0,
            "tid": tid(ev.track),
            "ts": round(ev.ts * 1e6, 3),  # seconds → microseconds
        }
        if ev.ph == "X":
            rec["dur"] = round(ev.dur * 1e6, 3)
        if ev.ph == "i":
            rec["s"] = "t"  # thread-scoped instant
        if ev.args:
            rec["args"] = ev.args
        events.append(rec)
    return {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": {
            "metrics_schema_version": METRICS_SCHEMA_VERSION,
            "dropped_events": recorder.dropped,
        },
    }


def write_chrome_trace(path: str, recorder: TraceRecorder,
                       process_name: str = "repro-serve") -> str:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(chrome_trace(recorder, process_name), f)
    return path


def validate_chrome_trace(obj: Any) -> List[str]:
    """Structural checks on a Chrome trace dict; returns a list of problems
    (empty = valid).  Checks: the traceEvents container, per-event required
    fields, known phases, B/E balance per (pid, tid), and that at least one
    nested (request-track) span exists when any request events are present.
    """
    errs: List[str] = []
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        return ["top level must be a dict with a 'traceEvents' list"]
    events = obj["traceEvents"]
    if not isinstance(events, list) or not events:
        return ["'traceEvents' must be a non-empty list"]
    known_ph = {"B", "E", "X", "i", "I", "M"}
    depth: Dict[tuple, int] = {}
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            errs.append(f"event {i}: not an object")
            continue
        for field in ("ph", "pid", "tid", "name"):
            if field not in ev:
                errs.append(f"event {i}: missing required field {field!r}")
        ph = ev.get("ph")
        if ph not in known_ph:
            errs.append(f"event {i}: unknown phase {ph!r}")
            continue
        if ph != "M" and "ts" not in ev:
            errs.append(f"event {i}: missing 'ts'")
        if ph == "X" and "dur" not in ev:
            errs.append(f"event {i}: complete event missing 'dur'")
        args = ev.get("args")
        if isinstance(args, dict):
            # energy-annotated spans (schema v2): when present, the hardware
            # estimates must be finite non-negative numbers.  Absent is fine
            # (older traces, spans outside the priced phases) — back-compat.
            for key in ("est_pj", "est_ns"):
                v = args.get(key)
                if v is not None and not _is_cost(v):
                    errs.append(f"event {i}: args[{key!r}]={v!r} is not a "
                                "finite non-negative number")
        key = (ev.get("pid"), ev.get("tid"))
        if ph == "B":
            depth[key] = depth.get(key, 0) + 1
        elif ph == "E":
            depth[key] = depth.get(key, 0) - 1
            if depth[key] < 0:
                errs.append(f"event {i}: 'E' without matching 'B' on {key}")
                depth[key] = 0
    for key, d in depth.items():
        if d != 0:
            errs.append(f"track {key}: {d} unclosed span(s)")
    return errs


# ---------------------------------------------------------------------------
# Prometheus text exposition
# ---------------------------------------------------------------------------


def _prom_name(name: str) -> str:
    return _NAME_RE.sub("_", name)


def _prom_labels(key) -> str:
    if not key:
        return ""
    body = ",".join(f'{_prom_name(k)}="{v}"' for k, v in key)
    return "{" + body + "}"


def _merge_labels(key, extra: Dict[str, str]) -> str:
    merged = dict(key)
    merged.update(extra)
    return _prom_labels(tuple(sorted(merged.items())))


def prometheus_text(registry: MetricsRegistry) -> str:
    """Registry → Prometheus text exposition format (version 0.0.4)."""
    lines: List[str] = []
    for name in sorted(registry.instruments()):
        inst = registry.instruments()[name]
        pname = _prom_name(name)
        if inst.help:
            lines.append(f"# HELP {pname} {inst.help}")
        lines.append(f"# TYPE {pname} {inst.kind}")
        if isinstance(inst, (Counter, Gauge)):
            series = sorted(inst.series()) or [((), 0.0)]
            for key, v in series:
                lines.append(f"{pname}{_prom_labels(key)} {_fmt(v)}")
        elif isinstance(inst, Histogram):
            series = sorted(inst.series()) or [((), None)]
            for key, _ in series:
                labels = dict(key)
                cum = 0
                counts = inst._counts.get(key, [0] * (len(inst.buckets) + 1))
                for ub, c in zip(inst.buckets, counts):
                    cum += c
                    lines.append(
                        f"{pname}_bucket"
                        f"{_merge_labels(key, {'le': _fmt(ub)})} {cum}")
                cum += counts[-1]
                lines.append(
                    f"{pname}_bucket{_merge_labels(key, {'le': '+Inf'})} "
                    f"{cum}")
                lines.append(f"{pname}_sum{_prom_labels(key)} "
                             f"{_fmt(inst.sum(**labels))}")
                lines.append(f"{pname}_count{_prom_labels(key)} "
                             f"{inst.count(**labels)}")
    lines.append("")
    return "\n".join(lines)


def _fmt(v: float) -> str:
    if v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


def write_prometheus(path: str, registry: MetricsRegistry) -> str:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        f.write(prometheus_text(registry))
    return path


_SAMPLE_RE = re.compile(
    r"^[a-zA-Z_:][a-zA-Z0-9_:]*(\{[^}]*\})?\s+[^\s]+$")


def validate_prometheus_text(text: str) -> List[str]:
    """Structural checks on a Prometheus exposition body (empty = valid):
    every non-comment line parses as ``name{labels} value``, every sample's
    base name was TYPE-declared, histograms carry _sum/_count, and values
    are finite numbers."""
    errs: List[str] = []
    typed: Dict[str, str] = {}
    samples: List[str] = []
    for ln, line in enumerate(text.splitlines(), start=1):
        if not line.strip():
            continue
        if line.startswith("# TYPE "):
            parts = line.split()
            if len(parts) != 4 or parts[3] not in (
                    "counter", "gauge", "histogram", "summary", "untyped"):
                errs.append(f"line {ln}: malformed TYPE declaration")
            else:
                typed[parts[2]] = parts[3]
            continue
        if line.startswith("#"):
            continue
        if not _SAMPLE_RE.match(line):
            errs.append(f"line {ln}: unparseable sample {line!r}")
            continue
        name = re.split(r"[{\s]", line, maxsplit=1)[0]
        base = re.sub(r"_(bucket|sum|count)$", "", name)
        if name not in typed and base not in typed:
            errs.append(f"line {ln}: sample {name!r} has no TYPE declaration")
        val = line.rsplit(None, 1)[-1]
        if val not in ("+Inf", "-Inf", "NaN"):
            try:
                float(val)
            except ValueError:
                errs.append(f"line {ln}: non-numeric value {val!r}")
        samples.append(name)
    for name, kind in typed.items():
        if kind == "histogram":
            for suffix in ("_bucket", "_sum", "_count"):
                if not any(s.startswith(name + suffix) for s in samples):
                    errs.append(f"histogram {name!r} missing {suffix} series")
    if not samples:
        errs.append("no samples found")
    return errs


# ---------------------------------------------------------------------------
# registry-schema helpers shared with benchmarks/stamp.py
# ---------------------------------------------------------------------------


def snapshot_with_schema(registry: Optional[MetricsRegistry]) -> Dict[str, Any]:
    """Registry snapshot in the BENCH_*.json schema (version-stamped)."""
    if registry is None:
        return {"metrics_schema_version": METRICS_SCHEMA_VERSION}
    return registry.snapshot()


# ---------------------------------------------------------------------------
# hardware-cost metrics validation (schema v2)
# ---------------------------------------------------------------------------


def _is_cost(v: Any) -> bool:
    """A finite, non-negative number (bool excluded — JSON true is not 1)."""
    return (isinstance(v, (int, float)) and not isinstance(v, bool)
            and math.isfinite(v) and v >= 0)


def validate_hw_block(hw: Any, where: str = "hw") -> List[str]:
    """Structural checks on a ``metrics()["hw"]`` block (empty = valid).

    Required: the static per-token prices (``pj_per_token``/``ns_per_token``),
    the component breakdown, the bit-slicing counterfactual, and the
    design-point ratios.  Workload keys (``tokens``/``est_pj``/``est_ns``/
    ``live``) are optional — a freshly-built engine has not served yet — but
    must be well-formed when present.  Pure dict checks: no hwcost import,
    so the CLI stays dependency-light."""
    errs: List[str] = []
    if not isinstance(hw, dict):
        return [f"{where}: must be an object, got {type(hw).__name__}"]
    for key in ("pj_per_token", "ns_per_token"):
        if not _is_cost(hw.get(key)):
            errs.append(f"{where}.{key}: missing or not a finite "
                        "non-negative number")
    comp = hw.get("components")
    if not isinstance(comp, dict):
        errs.append(f"{where}.components: missing or not an object")
    else:
        for key in ("sense_pj", "array_overhead_pj", "adder_pj"):
            if not _is_cost(comp.get(key)):
                errs.append(f"{where}.components.{key}: missing or invalid")
    bs = hw.get("bitslice")
    if not isinstance(bs, dict):
        errs.append(f"{where}.bitslice: missing or not an object")
    else:
        for key in ("pj_per_token", "ns_per_token"):
            if not _is_cost(bs.get(key)):
                errs.append(f"{where}.bitslice.{key}: missing or invalid")
    ratios = hw.get("ratios")
    if not isinstance(ratios, dict):
        errs.append(f"{where}.ratios: missing or not an object")
    else:
        for key in ("energy", "latency"):
            if not _is_cost(ratios.get(key)):
                errs.append(f"{where}.ratios.{key}: missing or invalid")
    for key in ("tokens", "est_pj", "est_ns", "live"):
        sub = hw.get(key)
        if sub is None:
            continue
        if not isinstance(sub, dict):
            errs.append(f"{where}.{key}: not an object")
            continue
        for k, v in sub.items():
            if not _is_cost(v):
                errs.append(f"{where}.{key}.{k}: invalid value {v!r}")
    if isinstance(hw.get("est_pj"), dict) and "total" not in hw["est_pj"]:
        errs.append(f"{where}.est_pj: missing 'total'")
    return errs


def _walk_hw(obj: Any, path: str, errs: List[str]) -> None:
    if isinstance(obj, dict):
        for k, v in obj.items():
            p = f"{path}.{k}" if path else k
            if k == "hw":
                if v is None:
                    errs.append(f"{p}: null (no DA cost model — served "
                                "float weights?)")
                else:
                    errs.extend(validate_hw_block(v, where=p))
            else:
                _walk_hw(v, p, errs)
    elif isinstance(obj, list):
        for i, v in enumerate(obj):
            _walk_hw(v, f"{path}[{i}]", errs)


def validate_metrics_json(obj: Any) -> List[str]:
    """Checks on a schema-stamped metrics JSON (``write_hw_metrics`` output,
    BENCH_*.json payloads).  Version 1 files predate the hardware block and
    validate with no ``hw`` requirements (back-compat); version ≥ 2 files
    must carry well-formed ``hw`` blocks wherever the key appears."""
    if not isinstance(obj, dict):
        return ["top level must be an object"]
    version = obj.get("metrics_schema_version")
    if not isinstance(version, int):
        return ["missing integer 'metrics_schema_version'"]
    if version > METRICS_SCHEMA_VERSION:
        return [f"schema version {version} is newer than this build "
                f"understands ({METRICS_SCHEMA_VERSION})"]
    errs: List[str] = []
    if version >= 2:
        _walk_hw(obj, "", errs)
    return errs
