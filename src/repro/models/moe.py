"""Mixture-of-Experts layer: top-k routing, capacity-based dense dispatch.

Expert parallelism: the expert dimension is sharded over the "model" mesh axis
(EP). Expert counts that don't divide the axis (qwen2-moe's 60 on a 16-way
axis) are zero-padded to the next multiple with −inf router logits — padded
experts are never selected and their (zero) weights contribute nothing, so
numerics are exact.

Dispatch is GShard/Switch-style with a static capacity
``C = ceil(T·k/E · capacity_factor)``: one-hot dispatch/combine tensors and
per-expert batched einsums. FLOPs therefore scale with *active* parameters
(B·T·k·D·F), not total experts — the MODEL_FLOPS/HLO check in the roofline
depends on this.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.linear import dense
from repro.launch.sharding import constrain
from repro.models.config import ModelConfig
from repro.models.layers import apply_mlp, init_mlp


def padded_experts(cfg: ModelConfig, model_axis: int = 16) -> int:
    """Experts padded up to a multiple of the model axis (EP divisibility)."""
    e = cfg.n_experts
    return -(-e // model_axis) * model_axis if e % model_axis else e


def init_moe(key, cfg: ModelConfig):
    ks = jax.random.split(key, 5)
    dt = cfg.pdtype()
    d, f = cfg.d_model, cfg.moe_d_ff
    e_pad = padded_experts(cfg)
    s_in = 1.0 / (d ** 0.5)
    s_out = 1.0 / (f ** 0.5)

    def ew(k, shape, scale):
        w = jax.random.normal(k, shape) * scale
        # zero the padded experts so they are exact no-ops
        mask = (jnp.arange(e_pad) < cfg.n_experts).astype(w.dtype)
        return (w * mask[:, None, None]).astype(dt)

    p = {
        "router": (jax.random.normal(ks[0], (d, e_pad)) * s_in).astype(jnp.float32),
        "w_gate": ew(ks[1], (e_pad, d, f), s_in),
        "w_up": ew(ks[2], (e_pad, d, f), s_in),
        "w_down": ew(ks[3], (e_pad, f, d), s_out),
    }
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(ks[4], cfg, d, cfg.n_shared_experts * f)
    return p


def capacity(cfg: ModelConfig, group: int) -> int:
    """Static per-group expert capacity. ``moe_dropless`` (serving/tests)
    uses the worst case C = group size — exact; the capacity-factor path
    (training) drops overflow tokens, GShard-style."""
    if cfg.moe_dropless:
        return group
    c = math.ceil(group * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    return max(1, min(c, group))


def _topk_dispatch(gates, k: int, cap: int):
    """gates: [G, S, E] per-group routing probabilities.

    Returns dispatch [G, S, E, C] (0/1) and combine [G, S, E, C] (weighted),
    slot-major priority within each group (all slot-0 assignments first, in
    token order). Capacity is per (group, expert)."""
    g, s, e = gates.shape
    topw, topi = jax.lax.top_k(gates, k)            # [G, S, k]
    topw = topw / jnp.maximum(jnp.sum(topw, -1, keepdims=True), 1e-9)

    onehot = jax.nn.one_hot(topi, e, dtype=jnp.float32)  # [G, S, k, E]
    # slot-major flattening → positions within each expert's capacity buffer
    flat = onehot.transpose(0, 2, 1, 3).reshape(g, k * s, e)
    pos = jnp.cumsum(flat, axis=1) - flat                # 0-based slot index
    keep = (pos < cap) * flat
    posc = jax.nn.one_hot(pos, cap, dtype=jnp.float32) * keep[..., None]
    posc = posc.reshape(g, k, s, e, cap)
    dispatch = jnp.sum(posc, axis=1)                     # [G, S, E, C]
    combine = jnp.einsum("gksec,gsk->gsec", posc, topw)
    return dispatch, combine


def _sorted_dispatch(gates, k: int, cap: int):
    """§Perf lever L4: sort-based dispatch (MegaBlocks-style, per group).

    Instead of the O(S·E·C) one-hot dispatch/combine tensors, sort the S·k
    (token, expert) assignments by expert id within each group, derive each
    assignment's slot in its expert's capacity buffer, and exchange data with
    one gather + one scatter-add of O(E·C·D) bytes. Grouping keeps the sort
    local to a data shard. Returns (token_for_slot [G, E·C] indices into the
    group's tokens with S = "none", weight_for_slot [G, E·C])."""
    g, s, e = gates.shape
    topw, topi = jax.lax.top_k(gates, k)                 # [G, S, k]
    topw = topw / jnp.maximum(jnp.sum(topw, -1, keepdims=True), 1e-9)
    flat_e = topi.reshape(g, s * k)
    flat_w = topw.reshape(g, s * k)
    flat_t = jnp.broadcast_to(
        jnp.arange(s)[:, None], (s, k)
    ).reshape(s * k)                                     # token of each slot
    order = jnp.argsort(flat_e, axis=1, stable=True)     # group-local sort
    se = jnp.take_along_axis(flat_e, order, 1)
    sw = jnp.take_along_axis(flat_w, order, 1)
    st = flat_t[order]                                   # [G, S·k]
    counts = jnp.sum(jax.nn.one_hot(flat_e, e, dtype=jnp.int32), axis=1)
    starts = jnp.cumsum(counts, axis=1) - counts         # [G, E]
    pos = jnp.arange(s * k)[None] - jnp.take_along_axis(starts, se, 1)
    keep = pos < cap
    slot = jnp.where(keep, se * cap + pos, e * cap)      # overflow → garbage
    g_idx = jnp.arange(g)[:, None]
    token_for_slot = jnp.full((g, e * cap + 1), s, jnp.int32)
    token_for_slot = token_for_slot.at[g_idx, slot].set(st)[:, : e * cap]
    weight_for_slot = jnp.zeros((g, e * cap + 1), topw.dtype)
    weight_for_slot = weight_for_slot.at[g_idx, slot].set(sw)[:, : e * cap]
    return token_for_slot, weight_for_slot


def _moe_experts(p, xe, cfg: ModelConfig):
    gate = dense(xe, p["w_gate"])
    up = dense(xe, p["w_up"])
    return dense(jax.nn.silu(gate) * up, p["w_down"])


def moe_forward_sorted(p, xg, gates, cfg: ModelConfig, cap: int):
    """Sorted-dispatch expert layer on grouped tokens xg [G, S, D]."""
    g, s, d = xg.shape
    e = gates.shape[-1]
    token_for_slot, weight_for_slot = _sorted_dispatch(gates, cfg.top_k, cap)
    xg_pad = jnp.concatenate([xg, jnp.zeros((g, 1, d), xg.dtype)], axis=1)
    xe = jnp.take_along_axis(
        xg_pad, token_for_slot[..., None], axis=1
    ).reshape(g, e, cap, d)
    xe = constrain(xe, ("batch", "expert", None, "embed"))
    ye = _moe_experts(p, xe, cfg)
    ye = constrain(ye, ("batch", "expert", None, "embed"))
    yflat = ye.reshape(g, e * cap, d) * weight_for_slot[..., None].astype(ye.dtype)
    y = jnp.zeros((g, s + 1, d), ye.dtype)
    y = y.at[jnp.arange(g)[:, None], token_for_slot].add(yflat)
    return y[:, :s]


def moe_forward(p, x, cfg: ModelConfig):
    """GShard-style grouped dispatch: tokens are split into groups of
    ``moe_group_size`` (sharded over the data axes); dispatch/combine one-hot
    einsums cost O(N·S·D) — linear in tokens — and per-expert compute scales
    with *active* parameters. ``moe_impl="sorted"`` switches to the
    sort-based dispatch (L4) with O(E·C·D) exchange tensors."""
    b, t, d = x.shape
    n = b * t
    s = min(cfg.moe_group_size, n)
    g = -(-n // s)
    pad = g * s - n
    xf = x.reshape(n, d)
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    xg = xf.reshape(g, s, d)
    xg = constrain(xg, ("batch", None, "embed"))
    cap = capacity(cfg, s)

    logits = xg.astype(jnp.float32) @ p["router"]
    # padded experts (EP divisibility) carry -inf router logits: never chosen
    e_pad = p["router"].shape[1]
    pad_mask = jnp.where(jnp.arange(e_pad) < cfg.n_experts, 0.0, -jnp.inf)
    gates = jax.nn.softmax(logits + pad_mask, axis=-1)

    if cfg.moe_impl == "sorted":
        y = moe_forward_sorted(p, xg, gates, cfg, cap)
    else:
        dispatch, combine = _topk_dispatch(gates, cfg.top_k, cap)
        xe = jnp.einsum("gsec,gsd->gecd", dispatch.astype(x.dtype), xg)
        xe = constrain(xe, ("batch", "expert", None, "embed"))
        ye = _moe_experts(p, xe, cfg)
        ye = constrain(ye, ("batch", "expert", None, "embed"))
        y = jnp.einsum("gsec,gecd->gsd", combine.astype(x.dtype), ye)
    y = y.reshape(g * s, d)[:n].reshape(b, t, d)
    if "shared" in p:
        y = y + apply_mlp(p["shared"], x, cfg)
    return constrain(y, ("batch", "seq", "embed"))
