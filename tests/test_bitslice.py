"""Bit-slicing baseline emulation (§IV): exact when the ADC has enough
resolution; clips (accuracy loss) when it doesn't."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.bitslice import BitSliceConfig, adc_bits_required, bitslice_vmm


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 6),
    k=st.integers(1, 30),
    n=st.integers(1, 8),
    signed=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_bitslice_exact_with_sufficient_adc(m, k, n, signed, seed):
    rng = np.random.default_rng(seed)
    x = (rng.integers(-128, 128, (m, k)) if signed
         else rng.integers(0, 256, (m, k))).astype(np.int32)
    w = rng.integers(-128, 128, (k, n)).astype(np.int32)
    cfg = BitSliceConfig(x_signed=signed, adc_bits=adc_bits_required(k))
    got = np.asarray(bitslice_vmm(jnp.asarray(x), jnp.asarray(w), cfg))
    np.testing.assert_array_equal(got, x @ w)


def test_adc_bits_required():
    assert adc_bits_required(25) == 5  # the paper's 5-bit ADC for 25 rows
    assert adc_bits_required(1) == 1
    assert adc_bits_required(255) == 8


def test_insufficient_adc_clips():
    """With all-ones inputs/weights the column count hits K — an ADC below
    log2(K+1) bits must clip and the result must be wrong (this is the
    resolution-pressure the paper's DA approach eliminates)."""
    k = 25
    x = np.full((1, k), 255, dtype=np.int32)
    w = np.full((k, 1), 1, dtype=np.int32)
    exact = bitslice_vmm(jnp.asarray(x), jnp.asarray(w),
                         BitSliceConfig(adc_bits=5))
    clipped = bitslice_vmm(jnp.asarray(x), jnp.asarray(w),
                           BitSliceConfig(adc_bits=3))
    assert np.asarray(exact)[0, 0] == 255 * k
    assert np.asarray(clipped)[0, 0] < 255 * k
