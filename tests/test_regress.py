"""Malformed-payload behavior of the benchmark regression gate
(``repro.obs.regress``): the gate must fail loudly — never pass — when a
payload is structurally broken (missing regress_keys, NaN values, schema
version skew, unstamped files)."""
import json
import math

from repro.obs.regress import compare, main


def _payload(**kw):
    base = {
        "metrics_schema_version": 1,
        "regress_keys": ["hw.energy_pj"],
        "hw": {"energy_pj": 100.0},
    }
    base.update(kw)
    return base


def _write(tmp_path, name, obj):
    p = tmp_path / name
    p.write_text(json.dumps(obj))
    return str(p)


def test_clean_payload_passes(tmp_path):
    fresh = _write(tmp_path, "fresh.json", _payload())
    committed = _write(tmp_path, "committed.json", _payload())
    assert main([fresh, committed]) == 0


def test_missing_regress_keys_is_usage_error(tmp_path):
    """A committed payload that declares nothing to guard (and no --key)
    exits 2 — an empty comparison must not masquerade as a green gate."""
    committed = _payload()
    del committed["regress_keys"]
    fresh = _write(tmp_path, "fresh.json", _payload())
    cpath = _write(tmp_path, "committed.json", committed)
    assert main([fresh, cpath]) == 2
    # ...unless --key supplies the comparison set explicitly
    assert main([fresh, cpath, "--key", "hw.energy_pj"]) == 0


def test_regress_keys_wrong_type_is_usage_error(tmp_path):
    fresh = _write(tmp_path, "fresh.json", _payload())
    cpath = _write(
        tmp_path, "committed.json", _payload(regress_keys="hw.energy_pj"))
    assert main([fresh, cpath]) == 2


def test_nan_value_is_a_regression(tmp_path):
    """NaN compares False against any tolerance band; the gate must treat
    a non-finite metric as a failure, not let it sail through."""
    nan_payload = _payload(hw={"energy_pj": math.nan})
    errs = compare(nan_payload, _payload(), ["hw.energy_pj"], 0.25)
    assert errs and "non-finite" in errs[0]
    # symmetric: a NaN in the committed reference also fails
    errs = compare(_payload(), nan_payload, ["hw.energy_pj"], 0.25)
    assert errs and "non-finite" in errs[0]
    # and through the CLI it exits 1 (regression), not 0
    fresh = _write(tmp_path, "fresh.json", nan_payload)
    committed = _write(tmp_path, "committed.json", _payload())
    assert main([fresh, committed]) == 1


def test_infinity_is_a_regression():
    errs = compare(_payload(hw={"energy_pj": math.inf}), _payload(),
                   ["hw.energy_pj"], 0.25)
    assert errs and "non-finite" in errs[0]


def test_schema_version_skew_fails_before_key_compare(tmp_path):
    """A version drift is a schema change, not a noise band: it must fail
    even when every compared value is identical."""
    fresh = _write(tmp_path, "fresh.json",
                   _payload(metrics_schema_version=2))
    committed = _write(tmp_path, "committed.json", _payload())
    assert main([fresh, committed]) == 1
    errs = compare(_payload(metrics_schema_version=2), _payload(),
                   ["hw.energy_pj"], 0.25)
    assert len(errs) == 1 and "schema version mismatch" in errs[0]


def test_unstamped_payload_is_usage_error(tmp_path):
    unstamped = {"hw": {"energy_pj": 100.0}}
    fresh = _write(tmp_path, "fresh.json", unstamped)
    committed = _write(tmp_path, "committed.json", _payload())
    assert main([fresh, committed]) == 2


def test_truncated_json_is_usage_error(tmp_path):
    p = tmp_path / "fresh.json"
    p.write_text('{"metrics_schema_version": 1, "hw": {')
    committed = _write(tmp_path, "committed.json", _payload())
    assert main([str(p), committed]) == 2


def test_missing_key_in_fresh_is_a_regression(tmp_path):
    fresh = _write(tmp_path, "fresh.json", _payload(hw={}))
    committed = _write(tmp_path, "committed.json", _payload())
    assert main([fresh, committed]) == 1
