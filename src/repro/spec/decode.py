"""Speculative-decoding config, acceptance math, and the verify step.

The cost model (README §Speculative decoding): one spec round spends
``gamma`` draft steps at relative cost ``c`` (the provider's
``cost_ratio``) plus one full-precision verify step over ``gamma + 1``
positions — and a decode-shaped verify step is weight-read bound, so it
costs about one ordinary decode step.  A round yields ``m`` tokens
(``1 ≤ m ≤ gamma + 1``), so::

    speedup ≈ E[m] / (gamma · c + 1)        with E[m] ≈ 1 + r · gamma

for per-draft acceptance rate ``r``.  Breakeven is therefore ``r* ≈ c``:
speculation pays exactly when drafts are accepted more often than they are
discounted.  The scheduler tracks a per-request EMA of ``r`` and disables
speculation for requests that fall below ``disable_below`` (default ``c``
plus a small margin) — heterogeneous traffic keeps the win where it exists
without taxing requests that draft poorly.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.model import forward


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    """Speculative-decoding knobs for the paged scheduler.

    provider:       ``bitplane`` | ``layerskip`` | ``artifact``.
    gamma:          draft tokens per round (the verify window is gamma+1).
    draft_x_bits:   bit-planes the bitplane self-draft evaluates.
    draft_periods:  period groups the layerskip draft runs (None → half).
    draft_artifact: directory of a frozen draft DAArtifact (``artifact``).
    draft_params / draft_model_cfg: in-memory draft model (tests / embedders
                    that already hold the artifact; wins over the directory).
    ema_alpha:      weight of the newest round in the acceptance-rate EMA.
    disable_below:  acceptance-rate floor; None → provider breakeven + 0.05.
    warmup_rounds:  rounds before the floor can disable a request.
    """

    provider: str = "bitplane"
    gamma: int = 4
    draft_x_bits: int = 4
    draft_periods: Optional[int] = None
    draft_artifact: Optional[str] = None
    draft_params: Any = None
    draft_model_cfg: Any = None
    ema_alpha: float = 0.25
    disable_below: Optional[float] = None
    warmup_rounds: int = 3

    def __post_init__(self):
        if self.gamma < 1:
            raise ValueError(f"gamma={self.gamma} must be >= 1")
        if not 0.0 < self.ema_alpha <= 1.0:
            raise ValueError(f"ema_alpha={self.ema_alpha} outside (0, 1]")


def greedy_accept(draft: Sequence[int], verify: Sequence[int]) -> int:
    """Greedy acceptance: how many verify tokens survive.

    ``verify`` holds the full model's gamma+1 greedy tokens (position ``i``
    of the verify window predicts token ``i+1``); ``draft`` holds the gamma
    draft tokens.  ``verify[i]`` is only meaningful while every earlier
    draft matched (the prefix it conditions on is then the real context),
    so the accepted run is the matched draft prefix plus one more full-model
    token — the correction where the draft diverged, or the bonus token when
    all gamma drafts survive.  Returns ``m`` in ``[1, gamma + 1]``; the
    accepted tokens are ``verify[:m]`` and every one of them is exactly what
    non-speculative greedy decoding would have emitted.
    """
    if len(verify) != len(draft) + 1:
        raise ValueError(
            f"verify window of {len(verify)} tokens does not cover "
            f"{len(draft)} drafts + 1"
        )
    m = 1
    for d, y in zip(draft, verify):
        if int(d) != int(y):
            break
        m += 1
    return m


def breakeven_acceptance(gamma: int, cost_ratio: float) -> float:
    """Per-draft acceptance rate below which a round loses throughput.

    From ``E[m] ≈ 1 + r·gamma`` and round cost ``gamma·c + 1`` (verify is
    weight-read bound — one decode step), speedup > 1 iff ``r > c``.  The
    gamma argument is kept for callers estimating with the geometric
    ``E[m] = (1 - r^{gamma+1}) / (1 - r)`` instead; the linear form is the
    conservative bound the scheduler's auto-disable uses.
    """
    del gamma
    return min(1.0, max(0.0, cost_ratio))


def mk_positions(cfg: ModelConfig, pos: jax.Array) -> jax.Array:
    """Shape positions for the model: [B, T] → [B, T, 3] under M-RoPE.

    The single implementation — the serving scheduler re-exports it (this
    package sits below the scheduler in the import graph), and it traces
    cleanly inside jit (the fused draft scan increments positions on
    device)."""
    if cfg.mrope_sections:
        return jnp.stack([pos, pos, pos], axis=-1)
    return pos


def make_fused_draft(step_fn, cfg: ModelConfig, gamma: int):
    """Fuse the whole gamma-token autoregressive draft loop into ONE device
    call: (params, caches, tokens [B,T], positions, page_table, last_idx) →
    (drafts [B, gamma] int32, caches).

    The first feed is the catch-up chunk (T ≥ 1: the last accepted token,
    plus — for own-cache providers — whatever the target accepted since the
    draft last ran); the remaining gamma−1 proposals run as a
    ``lax.scan`` with on-device greedy argmax, so a draft round costs one
    host dispatch instead of gamma (the host loop is pure overhead in the
    decode hot path).  Greedy ties break identically on device and host
    (first max index), which token-identity relies on.

    Pad rows ride along writing into the garbage column: their positions
    keep incrementing past it, where table lookups clamp to the garbage
    column and scatter drops out-of-range rows — masked out of every real
    row's softmax either way.
    """

    def fused(params, caches, tokens, positions, page_table, last_idx):
        logits, caches = step_fn(params, caches, tokens, positions,
                                 page_table, last_idx)
        d0 = jnp.argmax(logits, axis=-1).astype(jnp.int32)     # [B]
        tpos = positions[..., 0] if positions.ndim == 3 else positions
        nxt = jnp.take_along_axis(tpos, last_idx[:, None], axis=1)[:, 0] + 1
        if gamma == 1:
            return d0[:, None], caches

        def body(carry, _):
            caches, tok, pos = carry
            lg, caches = step_fn(params, caches, tok[:, None],
                                 mk_positions(cfg, pos[:, None]),
                                 page_table, jnp.zeros_like(last_idx))
            d = jnp.argmax(lg, axis=-1).astype(jnp.int32)
            return (caches, d, pos + 1), d

        (caches, _, _), rest = jax.lax.scan(
            body, (caches, d0, nxt.astype(jnp.int32)), None, length=gamma - 1
        )
        drafts = jnp.concatenate([d0[:, None], rest.T], axis=1)  # [B, gamma]
        return drafts, caches

    return fused


def make_verify_step(cfg: ModelConfig):
    """The full-precision verify step: (params, caches, tokens [B,T],
    positions, page_table) → (logits [B,T,V], caches).

    Unlike the serve step this keeps the logits of EVERY position — the
    gamma+1 verify window needs the full model's next-token argmax after
    each draft prefix.  KV for all fed positions is written at full
    precision (overwriting the draft-quality rows the draft pass left), so
    the accepted prefix needs no recompute and the rejected suffix is dead
    weight the page rollback releases.
    """

    def verify(params, caches, tokens, positions, page_table):
        logits, caches = forward(
            params, tokens, cfg, positions=positions, caches=caches,
            update_cache=True, page_table=page_table,
        )
        return logits, caches

    return verify
