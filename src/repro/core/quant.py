"""Symmetric uniform quantization (paper §II-C: post-training symmetric INT8).

Weights: per-output-channel symmetric int8 in [-128, 127] (paper quantizes the
trained float weights of LeNet-5 to 8-bit signed integers).
Activations: either unsigned 8-bit [0, 255] (grayscale image inputs, the paper's
case) or signed int8 with dynamic per-token scale (LM serving path).

All quantized tensors are carried as int32 holding the integer code plus a float
scale, so downstream integer arithmetic (DA / bit-slicing emulation) is exact.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class QTensor:
    """An integer-quantized tensor: values ≈ q * scale."""

    q: jax.Array          # integer codes, int32
    scale: jax.Array      # broadcastable float32 scale
    bits: int             # bit width of the codes
    signed: bool          # two's-complement (True) or unsigned (False)

    @property
    def qmin(self) -> int:
        return -(1 << (self.bits - 1)) if self.signed else 0

    @property
    def qmax(self) -> int:
        return (1 << (self.bits - 1)) - 1 if self.signed else (1 << self.bits) - 1

    def dequantize(self) -> jax.Array:
        return self.q.astype(jnp.float32) * self.scale


def quantize_weights(
    w: jax.Array, bits: int = 8, axis: Optional[int] = 0, eps: float = 1e-8
) -> QTensor:
    """Symmetric per-channel weight quantization.

    ``axis`` is the *contraction* axis (reduced when computing the per-channel
    max); the surviving axes get independent scales. ``axis=None`` → per-tensor.
    """
    qmax = (1 << (bits - 1)) - 1
    if axis is None:
        amax = jnp.max(jnp.abs(w))
    else:
        amax = jnp.max(jnp.abs(w), axis=axis, keepdims=True)
    scale = jnp.maximum(amax, eps) / qmax
    q = jnp.clip(jnp.round(w / scale), -qmax - 1, qmax).astype(jnp.int32)
    return QTensor(q=q, scale=scale.astype(jnp.float32), bits=bits, signed=True)


def quantize_acts_signed(x: jax.Array, bits: int = 8, eps: float = 1e-8) -> QTensor:
    """Dynamic per-row (per-token) symmetric activation quantization."""
    qmax = (1 << (bits - 1)) - 1
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, eps) / qmax
    q = jnp.clip(jnp.round(x / scale), -qmax - 1, qmax).astype(jnp.int32)
    return QTensor(q=q, scale=scale.astype(jnp.float32), bits=bits, signed=True)


def quantize_acts_unsigned(x: jax.Array, bits: int = 8, eps: float = 1e-8) -> QTensor:
    """Unsigned activation quantization (e.g. [0,255] grayscale inputs)."""
    qmax = (1 << bits) - 1
    amax = jnp.max(x, axis=-1, keepdims=True)
    scale = jnp.maximum(amax, eps) / qmax
    q = jnp.clip(jnp.round(x / scale), 0, qmax).astype(jnp.int32)
    return QTensor(q=q, scale=scale.astype(jnp.float32), bits=bits, signed=False)


def int_matmul(xq: QTensor, wq: QTensor) -> jax.Array:
    """Exact integer reference matmul; dequantized float output."""
    acc = jnp.matmul(xq.q, wq.q, preferred_element_type=jnp.int32)
    return acc.astype(jnp.float32) * xq.scale * wq.scale


jax.tree_util.register_pytree_node(
    QTensor,
    lambda t: ((t.q, t.scale), (t.bits, t.signed)),
    lambda aux, ch: QTensor(q=ch[0], scale=ch[1], bits=aux[0], signed=aux[1]),
)
