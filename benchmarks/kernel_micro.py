"""Kernel microbenchmarks (CPU wall-time): DA LUT / bitplane / int8 / float
matmul at LM-layer shapes, plus oracle-exactness spot checks.

On this CPU container the Pallas kernels run in interpret mode (a correctness
tool, not a fast path), so the *jnp reference implementations* are timed —
they are the lowering the TPU compiles. us_per_call is wall time per VMM.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.da import DAConfig, build_luts
from repro.kernels import ref
from repro.core.quant import quantize_acts_signed, quantize_weights


def _time(fn, *args, iters=5) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run() -> list:
    rng = np.random.default_rng(0)
    rows = []
    cfg = DAConfig(x_signed=True)
    for m, k, n in [(64, 512, 512), (256, 1024, 1024), (64, 4096, 4096)]:
        x = jnp.asarray(rng.normal(size=(m, k)), dtype=jnp.float32)
        w = jnp.asarray(rng.normal(size=(k, n)), dtype=jnp.float32)
        wq = quantize_weights(w)
        xq = quantize_acts_signed(x)
        luts = build_luts(wq.q)

        f_float = jax.jit(lambda a, b: a @ b)
        f_int8 = jax.jit(lambda a, b: jnp.matmul(a, b, preferred_element_type=jnp.int32))
        f_bp = jax.jit(lambda a, b: ref.bitplane_vmm_ref(a, b, cfg))
        f_lut = jax.jit(lambda a, l: ref.da_vmm_ref(a, l, cfg))

        t_float = _time(f_float, x, w)
        t_int8 = _time(f_int8, xq.q, wq.q)
        t_bp = _time(f_bp, xq.q, wq.q)
        t_lut = _time(f_lut, xq.q, luts)
        exact = bool(
            (np.asarray(f_bp(xq.q, wq.q)) == np.asarray(f_lut(xq.q, luts))).all()
        )
        shape = f"{m}x{k}x{n}"
        rows.append((f"float_matmul_{shape}", t_float, "baseline"))
        rows.append((f"int8_matmul_{shape}", t_int8, "quant baseline"))
        rows.append((f"da_bitplane_{shape}", t_bp, f"exact={exact}"))
        rows.append((f"da_lut_{shape}", t_lut, f"lut_cells={luts.size}"))
    return rows


def main():
    print("# kernel micro (CPU wall-time; TPU path = same HLO on MXU)")
    print("name,us_per_call,derived")
    for name, us, note in run():
        print(f"{name},{us:.1f},{note}")


if __name__ == "__main__":
    main()
