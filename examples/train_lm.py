"""End-to-end driver: train a ~100M-parameter qwen3-family LM for a few
hundred steps on the synthetic packed-document stream, with the full
production substrate — AdamW + warmup-cosine, microbatch accumulation,
NaN guard, straggler monitor, async checksummed checkpointing, and
crash-resume (kill it mid-run and start again: it continues).

Run: PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import dataclasses

import jax

from repro.configs.registry import ARCHS
from repro.data.pipeline import batch_at, for_model
from repro.models.model import count_params
from repro.optim.adamw import AdamWConfig
from repro.train.trainer import TrainConfig, Trainer, init_state


def build_cfg():
    # ~100M-param member of the qwen3 family (qk-norm GQA + SwiGLU)
    return dataclasses.replace(
        ARCHS["qwen3-8b"],
        name="qwen3-100m",
        n_layers=8,
        d_model=512,
        n_heads=8,
        n_kv_heads=4,
        head_dim=64,
        d_ff=1536,
        vocab=32000,
        param_dtype="float32",
        compute_dtype="float32",
        remat=False,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = build_cfg()
    print(f"model: {cfg.name}  params={count_params(cfg)/1e6:.1f}M")

    dc = for_model(cfg, seq_len=args.seq, global_batch=args.batch, packed=True)
    tcfg = TrainConfig(
        opt=AdamWConfig(lr=6e-4),
        warmup_steps=20,
        total_steps=args.steps,
        microbatches=2,
        ckpt_every=50,
        ckpt_dir=args.ckpt_dir,
    )
    trainer = Trainer(cfg, tcfg, lambda s: batch_at(dc, s))
    state = init_state(jax.random.key(0), cfg)
    state, hist = trainer.run(state, args.steps)

    for h in hist[:: max(1, len(hist) // 15)]:
        flag = " STRAGGLER" if h["straggler"] else ""
        print(f"step {h['step']:4d}  loss {h['loss']:.4f}  "
              f"gnorm {h['grad_norm']:.2f}  lr {h['lr']:.2e}  "
              f"{h['time_s']*1e3:6.0f} ms{flag}")
    if hist:
        print(f"\nfinal loss {hist[-1]['loss']:.4f} "
              f"(first {hist[0]['loss']:.4f}) over {len(hist)} steps")
    print(f"checkpoints in {args.ckpt_dir} "
          f"(restart this script to resume from the last one)")


if __name__ == "__main__":
    main()
