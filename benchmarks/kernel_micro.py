"""Kernel microbenchmarks (CPU wall-time): every registered DA engine backend
plus the float/int8 baselines at LM-layer shapes, with exactness spot checks.

On this CPU container the Pallas kernels run in interpret mode (a correctness
tool, not a fast path), so they are skipped here — the jnp backends timed are
the lowering the TPU compiles. us_per_call is wall time per VMM.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.da import DAConfig
from repro.core.engine import (
    DEFAULT_LUT_LIMIT,
    jit_backend,
    lut_cells,
    pack_quantized,
    timeable_backends,
)
from repro.core.quant import quantize_acts_signed, quantize_weights


def _time(fn, *args, iters=5) -> float:
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run() -> list:
    rng = np.random.default_rng(0)
    rows = []
    cfg = DAConfig(x_signed=True)
    for m, k, n in [(64, 512, 512), (256, 1024, 1024), (64, 4096, 4096)]:
        x = jnp.asarray(rng.normal(size=(m, k)), dtype=jnp.float32)
        w = jnp.asarray(rng.normal(size=(k, n)), dtype=jnp.float32)
        wq = quantize_weights(w)
        xq = quantize_acts_signed(x)
        with_luts = lut_cells(k, n, cfg.group_size) <= DEFAULT_LUT_LIMIT
        packed = pack_quantized(wq.q, wq.scale, cfg=cfg, with_luts=with_luts)
        shape = f"{m}x{k}x{n}"

        f_float = jax.jit(lambda a, b: a @ b)
        rows.append((f"float_matmul_{shape}", _time(f_float, x, w), "baseline"))

        outs = {}
        for spec in timeable_backends(cfg, packed.has_luts,
                                      include_baselines=True):
            fn = jit_backend(spec, cfg)
            t = _time(fn, xq.q, packed)
            outs[spec.name] = np.asarray(fn(xq.q, packed))
            note = "quant baseline" if not spec.is_da else (
                f"lut_cells={packed.luts.size}" if spec.needs_luts else "DA")
            rows.append((f"{spec.name}_{shape}", t, note))
        vals = list(outs.values())
        exact = all((v == vals[0]).all() for v in vals[1:])
        assert exact, f"backends diverged at {shape}"
    return rows


def main():
    print("# kernel micro (CPU wall-time; TPU path = same HLO on MXU)")
    print("name,us_per_call,derived")
    for name, us, note in run():
        print(f"{name},{us:.1f},{note}")


if __name__ == "__main__":
    main()
