"""Production meshes. A FUNCTION, not a module constant — importing this
module must never touch jax device state (smoke tests see 1 CPU device;
only dryrun.py requests 512 placeholder devices via XLA_FLAGS)."""
from __future__ import annotations

import jax


def shard_map_compat(f, mesh, in_specs, out_specs, check=False):
    """jax.shard_map across jax versions: 0.4.x keeps it in experimental, and
    the check flag was renamed check_rep → check_vma after the promotion, so
    sniff the actual signature rather than keying on namespace presence."""
    import inspect

    if hasattr(jax, "shard_map"):
        sm = jax.shard_map
    else:
        from jax.experimental.shard_map import shard_map as sm
    params = inspect.signature(sm).parameters
    kw = "check_vma" if "check_vma" in params else "check_rep"
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              **{kw: check})


def _make_mesh(shape, axes):
    # jax.sharding.AxisType landed after 0.4.x; Auto is the default there,
    # so on older jax the plain call is equivalent.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod (TPU v5e pod slice); 2 pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU tests (requires XLA_FLAGS host device count ≥ prod)."""
    return _make_mesh(shape, axes)
