"""musicgen-large [arXiv:2306.05284; hf] — decoder-only over EnCodec tokens.

48L d_model=2048 32H (MHA, kv=32) d_ff=8192 vocab=2048. The EnCodec audio
frontend is a STUB per the assignment: input_specs provide precomputed frame
embeddings [B, T, d_model]. MusicGen's backbone uses LayerNorm + GELU FFN.
"""
from repro.configs.registry import register
from repro.models.config import ModelConfig

CONFIG = register(ModelConfig(
    name="musicgen-large",
    family="dense",
    modality="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=2048,
    mlp_act="gelu",
    norm_type="layernorm",
))
