"""Paper §II-B/§III-C: CONV1 of LeNet-5 as 784 successive 1×25 · 25×6 VMMs.

Maps the convolution to im2col VMMs exactly as Fig. 3, runs the full layer
through the DA datapath (integer-exact vs the direct convolution), and
projects layer latency/energy through the hardware model for both DA and
bit-slicing engines.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.da import DAConfig
from repro.core.engine import da_vmm, pack_quantized
from repro.core.hwmodel import BitSliceDesign, DADesign
from repro.core.quant import quantize_weights


def im2col(img: np.ndarray, kh: int = 5, kw: int = 5) -> np.ndarray:
    """32×32 image → [784, 25] stride patches (Fig. 3 unrolling)."""
    h, w = img.shape
    oh, ow = h - kh + 1, w - kw + 1
    cols = np.empty((oh * ow, kh * kw), dtype=img.dtype)
    idx = 0
    for i in range(oh):
        for j in range(ow):
            cols[idx] = img[i : i + kh, j : j + kw].reshape(-1)
            idx += 1
    return cols


def run() -> dict:
    rng = np.random.default_rng(0)
    img = rng.integers(0, 256, (32, 32)).astype(np.int32)  # 8-bit grayscale
    filters = rng.normal(size=(6, 5, 5)).astype(np.float32)  # 6 trained 5×5

    wq = quantize_weights(jnp.asarray(filters.reshape(6, 25).T))  # [25, 6]
    cols = im2col(img)  # [784, 25]

    # DA path: 784 VMMs against the three PMAs (one packed artifact, LUT mode
    # through the unified engine — the same entry serving uses)
    packed = pack_quantized(wq.q, cfg=DAConfig(x_signed=False))
    t0 = time.perf_counter()
    acc = da_vmm(jnp.asarray(cols), packed, mode="lut")
    acc.block_until_ready()
    wall = time.perf_counter() - t0

    # reference: direct integer convolution
    ref = cols @ np.asarray(wq.q)
    exact = bool((np.asarray(acc) == ref).all())

    da = DADesign(k=25, n=6)
    bs = BitSliceDesign(k=25, n=6)
    n_vmm = 784
    return {
        "n_vmms": n_vmm,
        "exact_vs_direct_conv": exact,
        "da_layer_latency_us": n_vmm * da.latency_ns() * 1e-3,
        "bs_layer_latency_us": n_vmm * bs.latency_ns() * 1e-3,
        "da_layer_energy_nj": n_vmm * da.energy_vmm_j() * 1e9,
        "bs_layer_energy_nj": n_vmm * bs.energy_vmm_j() * 1e9,
        "da_prevmm_energy_nj": da.pre_vmm_energy_j() * 1e9,
        "output_feature_maps": 6,
        "output_shape": "6x28x28",
        "cpu_wall_ms_784vmm": wall * 1e3,
    }


def main():
    print("# LeNet-5 CONV1 = 784 VMMs (Fig. 3 mapping)")
    for k, v in run().items():
        print(f"{k},{v}")


if __name__ == "__main__":
    main()
