"""Fused Pallas paged-attention decode kernel: in-kernel page-table walk.

The serving gather path (``models/attention.paged_gather_read``) re-creates
the full ``[B, W*ps, kv, hd]`` KV view from the batch-free page pool every
decode step — an HBM gather whose traffic dwarfs the attention math at
decode shapes. This kernel walks the page table ON-CHIP instead: the grid is
(batch row, page slot) and the K/V BlockSpec index maps read the
scalar-prefetched page table, so each grid step DMAs exactly ONE physical
page into VMEM. Pages the table does not name are never touched, and the
dense ``[B, S, kv, hd]`` view never exists in HBM.

Per page the kernel computes that page's grouped-GQA score block (q stays in
its ``[kv, G]`` grouped layout; repeated KV heads are never materialized)
and folds it into a running row-max — the online-softmax accumulation
across the page walk. Masked scores and the page's V rows are staged in
VMEM scratch, which Pallas persists across the sequential grid. The final
page's step runs the fused epilogue: exp/normalize against the accumulated
max, probs cast, PV contraction — one kernel, no HBM round-trip for scores.

Ragged masking happens in-kernel: key position ``w*ps + i`` contributes to
query ``t`` iff ``kpos <= tpos[b, t]``. Pad lanes point at the garbage page
(physical page 0) with ``tpos`` beyond every real position, so garbage rows
are masked out exactly as in the gather path.

Numerics match the gather path BIT-FOR-BIT at the default
``softmax_dtype="float32"`` (CI asserts it, the same way the paged==dense
tests do): each page's score block is a slice of the same einsum the gather
path runs, the running max equals the global masked max exactly (max is
order-independent), and the epilogue replicates ``jax.nn.softmax``'s
``exp(x - max) / sum`` form with the same dtypes and casts. Deferring
exp/normalize to the epilogue — rather than rescaling a running sum at
every page like a classic flash-decode kernel — is what keeps the
roundings identical; the rescale chain would round differently at each page
boundary. The cost is VMEM scratch linear in the table width, which at
serving page counts is far below the VMEM budget. For sub-f32 softmax
dtypes (``softmax_dtype="bfloat16"``) exact bit-parity across lowerings is
not attainable in principle — XLA fuses ``exp``+``reduce`` and keeps f32
intermediates across the pair, eliding bf16 roundings an op-by-op kernel
must perform — so there the kernel is within one bf16 ulp per reduction,
not bitwise.

``interpret=None`` derives the execution mode from the backend platform:
compiled on TPU, interpreter everywhere else (the CPU CI correctness path).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.models import kv_quant as _kvq

# Matches models/attention.NEG_INF so masked lanes are bit-identical.
NEG_INF = -1e30


def _default_interpret() -> bool:
    """Platform-derived execution mode: compiled on TPU, interpret elsewhere."""
    return jax.default_backend() != "tpu"


def _paged_attn_kernel(
    table_ref,  # scalar-prefetch: [B, W] page table (SMEM)
    q_ref,      # [1, T, H, hd] query block for this batch row
    tpos_ref,   # [1, T] temporal positions for this batch row
    k_ref,      # [1, ps, kv, hd(/2)] — ONE physical K page, chosen by table
    v_ref,      # [1, ps, kv, hd(/2)] — ONE physical V page
    *rest,      # [ks_ref, vs_ref,] o_ref, s_scr, v_scr, m_scr — the scale
    #             pages [1, ps, kv, 1] ride the same table-indexed walk and
    #             are present iff the pool is quantized (kv_fmt != "fp")
    n_pages_walked: int,
    page_size: int,
    n_kv: int,
    n_groups: int,
    softmax_dtype,
    mask_mode: str,
    kv_fmt: str,
):
    del table_ref  # consumed by the BlockSpec index maps
    if kv_fmt == "fp":
        o_ref, s_scr, v_scr, m_scr = rest
    else:
        ks_ref, vs_ref, o_ref, s_scr, v_scr, m_scr = rest
    wi = pl.program_id(1)
    ps = page_size
    t = q_ref.shape[1]
    hd = q_ref.shape[3]
    sd = softmax_dtype

    # Dequantize the DMA'd page in-register: the same elementwise formula
    # the gather read applies to its gathered view (kv_quant.dequantize_kv),
    # so each element is bitwise the gather path's — the per-page score
    # block below stays a slice of the gather einsum, quantized or not.
    if kv_fmt == "fp":
        k_page = k_ref[...]
        v_page = v_ref[0]
    else:
        k_page = _kvq.dequantize_kv(k_ref[...], ks_ref[...], kv_fmt,
                                    q_ref.dtype)
        v_page = _kvq.dequantize_kv(v_ref[0], vs_ref[0], kv_fmt, q_ref.dtype)

    # Stage this page's V rows at their logical offset in the sequence.
    v_scr[pl.ds(wi * ps, ps)] = v_page

    # Grouped-GQA scores for this page: slice of the gather path's einsum
    # over the same contraction (hd), so it is bitwise the same block.
    qg = q_ref[0].reshape(t, n_kv, n_groups, hd)[None]
    scores = jnp.einsum("btkgd,bskd->bkgts", qg, k_page) / (hd ** 0.5)
    scores = scores.astype(sd)

    # Ragged/garbage masking: key position valid iff kpos <= tpos.
    kpos = wi * ps + jax.lax.broadcasted_iota(jnp.int32, (t, ps), 1)
    valid = (kpos <= tpos_ref[0][:, None])[None, None, None]
    neg = jnp.asarray(NEG_INF, sd)
    if mask_mode == "additive":
        scores = scores + jnp.where(valid, jnp.asarray(0.0, sd), neg)
    else:
        scores = jnp.where(valid, scores, neg)
    s_scr[:, :, :, pl.ds(wi * ps, ps)] = scores[0]

    # Online accumulation: running max over pages == global max, exactly.
    page_max = jnp.max(scores[0], axis=-1)

    @pl.when(wi == 0)
    def _init():
        m_scr[...] = page_max

    @pl.when(wi > 0)
    def _fold():
        m_scr[...] = jnp.maximum(m_scr[...], page_max)

    @pl.when(wi == n_pages_walked - 1)
    def _epilogue():
        # Mirror jax.nn.softmax(scores, axis=-1) bit-for-bit:
        # exp(x - max) / sum, in softmax_dtype, then cast to q dtype.
        s_all = s_scr[...]
        unnorm = jnp.exp(s_all - m_scr[...][..., None])
        probs = unnorm / jnp.sum(unnorm, axis=-1, keepdims=True)
        probs = probs.astype(q_ref.dtype)[None]
        out = jnp.einsum("bkgts,bskd->btkgd", probs, v_scr[...][None])
        o_ref[...] = out.reshape(1, t, n_kv * n_groups, hd).astype(o_ref.dtype)


def paged_attention(
    q: jax.Array,           # [B, T, H, hd]
    k_pool: jax.Array,      # [n_pages, ps, kv, hd]  (hd//2 for packed int4)
    v_pool: jax.Array,      # [n_pages, ps, kv, hd]
    page_table: jax.Array,  # [B, W] int32 physical page ids
    tpos: jax.Array,        # [B, T] int32 temporal positions (pad -> pad_pos)
    *,
    softmax_dtype="float32",
    mask_mode: str = "where",
    k_scale: jax.Array | None = None,  # [n_pages, ps, kv, 1] in-page scales
    v_scale: jax.Array | None = None,  # (quantized pools only)
    interpret: bool | None = None,
) -> jax.Array:
    """Fused paged-attention read: returns ``[B, T, H, hd]`` context.

    Drop-in replacement for the gather read over an already-written pool
    (scatter of the current step's K/V happens before either read). The
    page walk, ragged masking, online-softmax accumulation and PV
    contraction all run inside one Pallas kernel; see the module docstring
    for the bit-parity argument.

    Quantized pools (int8 codes, or int4 nibble pairs packed along hd) pass
    their in-page scales: the scale blocks ride the SAME scalar-prefetch
    index map as the page walk — each grid step DMAs one codes page plus
    its ``[ps, kv, 1]`` scale sliver — and dequantization happens
    in-register before the score einsum, with the gather backend's exact
    elementwise formula, so the two backends stay bit-identical on
    quantized pages too.
    """
    b, t, h, hd = q.shape
    _, ps, kv, hd_p = k_pool.shape
    w = page_table.shape[1]
    s = w * ps
    if h % kv:
        raise ValueError(f"n_heads={h} not divisible by n_kv_heads={kv}")
    g = h // kv
    if interpret is None:
        interpret = _default_interpret()
    sd = jnp.dtype(softmax_dtype)
    kv_fmt = _kvq.kv_format(k_pool, k_scale, hd)

    kernel = functools.partial(
        _paged_attn_kernel,
        n_pages_walked=w,
        page_size=ps,
        n_kv=kv,
        n_groups=g,
        softmax_dtype=sd,
        mask_mode=mask_mode,
        kv_fmt=kv_fmt,
    )
    page_spec = pl.BlockSpec((1, ps, kv, hd_p),
                             lambda bi, wi, tbl: (tbl[bi, wi], 0, 0, 0))
    in_specs = [
        pl.BlockSpec((1, t, h, hd), lambda bi, wi, tbl: (bi, 0, 0, 0)),
        pl.BlockSpec((1, t), lambda bi, wi, tbl: (bi, 0)),
        # The page walk: block index = table entry for (row, slot).
        page_spec,
        page_spec,
    ]
    operands = [q, tpos.astype(jnp.int32), k_pool, v_pool]
    if kv_fmt != "fp":
        scale_spec = pl.BlockSpec((1, ps, kv, 1),
                                  lambda bi, wi, tbl: (tbl[bi, wi], 0, 0, 0))
        in_specs += [scale_spec, scale_spec]
        operands += [k_scale, v_scale]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, w),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, t, h, hd), lambda bi, wi, tbl: (bi, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((kv, g, t, s), sd),
            # staged V rows are dequantized, so the scratch holds q dtype
            pltpu.VMEM((s, kv, hd),
                       v_pool.dtype if kv_fmt == "fp" else q.dtype),
            pltpu.VMEM((kv, g, t), sd),
        ],
    )
    # named_scope: the kernel shows up as one attributable op in profiler
    # captures (kv format in the name separates fp/int8/int4 dispatches)
    with jax.named_scope(f"paged_attn_fused_{kv_fmt}"):
        return pl.pallas_call(
            kernel,
            grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct((b, t, h, hd), q.dtype),
            interpret=interpret,
        )(page_table.astype(jnp.int32), *operands)
