"""Hardware cost model of a frozen model on the paper's DA circuits.

The calibrated analytic model in :mod:`repro.core.hwmodel` prices ONE VMM on
ONE K×N design point (Table I).  This module lifts it to a *served model*:
every frozen layer's geometry (K×N, group size, x bits, backend mode, how
many VMMs one token-pass issues through that leaf) maps onto a
:class:`~repro.core.hwmodel.DADesign` and its bit-slicing counterfactual
:class:`~repro.core.hwmodel.BitSliceDesign`, giving a per-layer, per-token
cost table — ns and pJ, broken into sense / adder / array-overhead
components — that the serving stack multiplies by *actual executed work*
(prefill chunk tokens, decode steps, spec-decode draft passes).

This is the Lynchpin-style discipline for in-memory VMM claims: evaluated
per workload, component-attributed, reproducible — not a single design
point.  The model is built once at ``freeze_model`` / ``from_artifact``,
recorded in the artifact manifest, and is the ONE source of geometry truth
shared by ``da_memory_report``, the planner's analytic fallback
(:func:`da_design`), ``benchmarks/roofline_table.py`` and
``metrics()["hw"]``.

Accounting conventions (documented, test-asserted):

* A "token-pass" is one token through the full stack; it issues
  ``vmms_per_token`` VMMs per leaf (the product of the leaf's stacked
  leading dims — periods, experts).  MoE leaves count every expert (the
  dropless upper bound); attention/softmax and other non-DA compute are
  outside the model.
* ``ns_per_token`` is the fully-serialized bound: every VMM's
  ``latency_ns`` summed (layers are sequential in a forward pass; intra-
  layer parallelism would only lower it).
* ``x_bits_eff`` prices a reduced-precision pass (the truncated-bitplane
  spec draft): the DA engine simply issues fewer bit-serial read cycles,
  so energy scales *exactly* linearly in the evaluated bit-planes — the
  DA-native energy story.  The bit-slicing counterfactual also scales
  (fewer DAC/input cycles), keeping the comparison honest.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterable, List, Optional, Tuple

from repro.core.hwmodel import PJ, BitSliceDesign, DADesign

#: Schema version of the serialized cost table (artifact manifest block).
HWCOST_VERSION = 1

#: Weight codes are int8 throughout the freeze pipeline.
DA_W_BITS = 8


def da_design(k: int, n: int, x_bits: int = 8, group_size: int = 8,
              w_bits: int = DA_W_BITS) -> DADesign:
    """THE layer-geometry → DA engine mapping (single source of truth —
    the freeze planner's analytic fallback and every report go through
    here, never through ad-hoc ``DADesign(...)`` construction)."""
    return DADesign(k=k, n=n, w_bits=w_bits, x_bits=x_bits,
                    base_group=group_size)


def bitslice_design(k: int, n: int, x_bits: int = 8,
                    w_bits: int = DA_W_BITS) -> BitSliceDesign:
    """The layer-geometry → bit-slicing counterfactual mapping."""
    return BitSliceDesign(k=k, n=n, w_bits=w_bits, x_bits=x_bits)


@dataclasses.dataclass(frozen=True)
class LayerGeom:
    """One frozen leaf's cost-relevant geometry (what the manifest stores)."""

    path: str
    k: int
    n: int
    group_size: int = 8
    x_bits: int = 8
    w_bits: int = DA_W_BITS
    mode: str = "auto"
    vmms_per_token: int = 1

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: dict) -> "LayerGeom":
        return cls(**d)


def _eff_bits(x_bits: int, x_bits_eff: Optional[int]) -> int:
    if x_bits_eff is None:
        return x_bits
    return max(1, min(int(x_bits_eff), x_bits))


class HardwareCostModel:
    """Per-layer, per-token DA cost table for a frozen model.

    Construct via :meth:`from_frozen` (a packed params tree),
    :meth:`from_shapes` (bare geometries — design studies, the CONV1
    check), or :meth:`from_json` (artifact manifest round-trip).
    """

    def __init__(self, layers: Iterable[LayerGeom]):
        self.layers: Tuple[LayerGeom, ...] = tuple(layers)
        # per-x_bits_eff cache of (da_pj, da_ns, bs_pj, bs_ns) totals and
        # the component breakdowns — the scheduler prices every charge from
        # these floats, so building them is O(layers) exactly once per
        # precision actually served
        self._cache: Dict[Optional[int], Dict[str, Any]] = {}

    # -- constructors --------------------------------------------------------
    @classmethod
    def from_frozen(cls, params: Any,
                    plan: Optional[Dict[str, Any]] = None
                    ) -> "HardwareCostModel":
        """Walk a packed params tree; one LayerGeom per PackedWeights leaf.

        ``vmms_per_token`` is the product of the leaf's stacked leading dims
        ([P, K, N] periods / [P, E, K, N] experts) — one token-pass issues
        that many VMMs of the trailing (K, N) shape."""
        import jax

        from repro.core.engine import PackedWeights, path_entry_name

        layers: List[LayerGeom] = []
        flat, _ = jax.tree_util.tree_flatten_with_path(
            params, is_leaf=lambda x: isinstance(x, PackedWeights))
        for path, leaf in flat:
            if not isinstance(leaf, PackedWeights):
                continue
            key = "/".join(path_entry_name(p) for p in path)
            k, n = int(leaf.k), int(leaf.n)
            mode = leaf.mode
            if plan and key in plan and mode == "auto":
                mode = plan[key].mode
            layers.append(LayerGeom(
                path=key, k=k, n=n,
                group_size=int(leaf.cfg.group_size),
                x_bits=int(leaf.cfg.x_bits),
                mode=mode,
                vmms_per_token=max(1, int(leaf.wq.size) // (k * n)),
            ))
        return cls(layers)

    @classmethod
    def from_shapes(cls, shapes: Iterable[Any], x_bits: int = 8,
                    group_size: int = 8) -> "HardwareCostModel":
        """Bare geometries: each item is ``(label, k, n)`` (or
        ``(label, k, n, count)``), or a dict of LayerGeom fields."""
        layers = []
        for s in shapes:
            if isinstance(s, dict):
                layers.append(LayerGeom(**{"x_bits": x_bits,
                                           "group_size": group_size, **s}))
            else:
                label, k, n = s[0], int(s[1]), int(s[2])
                count = int(s[3]) if len(s) > 3 else 1
                layers.append(LayerGeom(path=label, k=k, n=n, x_bits=x_bits,
                                        group_size=group_size,
                                        vmms_per_token=count))
        return cls(layers)

    # -- serialization -------------------------------------------------------
    def to_json(self) -> dict:
        return {"hwcost_version": HWCOST_VERSION,
                "layers": [g.to_json() for g in self.layers]}

    @classmethod
    def from_json(cls, d: dict) -> "HardwareCostModel":
        v = d.get("hwcost_version", 0)
        if v > HWCOST_VERSION:
            raise ValueError(
                f"hwcost table version {v} is newer than this build "
                f"understands ({HWCOST_VERSION})")
        return cls(LayerGeom.from_json(g) for g in d.get("layers", []))

    def __eq__(self, other: Any) -> bool:
        return (isinstance(other, HardwareCostModel)
                and self.layers == other.layers)

    def __bool__(self) -> bool:
        return bool(self.layers)

    # -- the per-layer table -------------------------------------------------
    def _totals(self, x_bits_eff: Optional[int] = None) -> Dict[str, Any]:
        got = self._cache.get(x_bits_eff)
        if got is not None:
            return got
        da_pj = da_ns = bs_pj = bs_ns = 0.0
        comp = {"sense_pj": 0.0, "array_overhead_pj": 0.0, "adder_pj": 0.0}
        bs_comp = {"read_pj": 0.0, "adc_pj": 0.0, "dac_pj": 0.0,
                   "adder_pj": 0.0}
        rows: List[dict] = []
        for g in self.layers:
            # The hardware is built at the layer's FULL x_bits; a reduced-
            # precision pass (x_bits_eff) runs the same circuits for fewer
            # bit-serial cycles.  Energy therefore scales by eff/x_bits
            # EXACTLY (every component is per-cycle); latency drops by the
            # skipped read cycles (same cycle time, same adder tail).
            eff = _eff_bits(g.x_bits, x_bits_eff)
            scale = eff / g.x_bits
            da = da_design(g.k, g.n, x_bits=g.x_bits,
                           group_size=g.group_size, w_bits=g.w_bits)
            bs = bitslice_design(g.k, g.n, x_bits=g.x_bits, w_bits=g.w_bits)
            m = g.vmms_per_token
            c = {f"{k}_pj": v * scale * m / PJ
                 for k, v in da.energy_components_j().items()}
            bc = {f"{k}_pj": v * scale * m / PJ
                  for k, v in bs.energy_components_j().items()}
            row = {
                "path": g.path, "k": g.k, "n": g.n, "mode": g.mode,
                "group_size": g.group_size, "x_bits": eff,
                "vmms_per_token": m,
                "da_ns": dataclasses.replace(da, x_bits=eff).latency_ns() * m,
                "da_pj": sum(c.values()),
                "da_components_pj": c,
                "bs_ns": bs.latency_ns() * scale * m,
                "bs_pj": sum(bc.values()),
                "bs_components_pj": bc,
                "memory_cells": da.memory_cells * m,
                "transistors": da.transistors() * m,
            }
            rows.append(row)
            da_pj += row["da_pj"]
            da_ns += row["da_ns"]
            bs_pj += row["bs_pj"]
            bs_ns += row["bs_ns"]
            for key in comp:
                comp[key] += c[key]
            for key in bs_comp:
                bs_comp[key] += bc[key]
        out = {"rows": rows, "da_pj": da_pj, "da_ns": da_ns,
               "bs_pj": bs_pj, "bs_ns": bs_ns,
               "components": comp, "bs_components": bs_comp}
        self._cache[x_bits_eff] = out
        return out

    def layer_table(self, x_bits_eff: Optional[int] = None) -> List[dict]:
        """Per-layer per-token costs (ns, pJ, components, counterfactual)."""
        return self._totals(x_bits_eff)["rows"]

    # -- per-token scalars (what the scheduler multiplies by work) -----------
    def pj_per_token(self, x_bits_eff: Optional[int] = None) -> float:
        """DA energy of one token-pass (pJ); ``x_bits_eff`` prices a
        truncated-bitplane pass — exactly linear in the evaluated planes."""
        return self._totals(x_bits_eff)["da_pj"]

    def ns_per_token(self, x_bits_eff: Optional[int] = None) -> float:
        """Fully-serialized DA latency of one token-pass (model ns)."""
        return self._totals(x_bits_eff)["da_ns"]

    def components(self, x_bits_eff: Optional[int] = None) -> Dict[str, float]:
        """pJ/token split into sense / array-overhead / adder energy."""
        return dict(self._totals(x_bits_eff)["components"])

    def bitslice_pj_per_token(self, x_bits_eff: Optional[int] = None) -> float:
        return self._totals(x_bits_eff)["bs_pj"]

    def bitslice_ns_per_token(self, x_bits_eff: Optional[int] = None) -> float:
        return self._totals(x_bits_eff)["bs_ns"]

    def bitslice_components(
            self, x_bits_eff: Optional[int] = None) -> Dict[str, float]:
        return dict(self._totals(x_bits_eff)["bs_components"])

    def ratios(self, x_bits_eff: Optional[int] = None) -> Dict[str, float]:
        """Design-point DA-vs-bit-slicing ratios for this model's layers
        (the paper's headline numbers, at LM geometry)."""
        t = self._totals(x_bits_eff)
        return {
            "energy": t["bs_pj"] / t["da_pj"] if t["da_pj"] else 0.0,
            "latency": t["bs_ns"] / t["da_ns"] if t["da_ns"] else 0.0,
        }

    def summary(self, x_bits_eff: Optional[int] = None) -> Dict[str, Any]:
        """The static half of ``metrics()["hw"]`` (per-token, no workload)."""
        t = self._totals(x_bits_eff)
        return {
            "layers": len(self.layers),
            "vmms_per_token": sum(g.vmms_per_token for g in self.layers),
            "pj_per_token": t["da_pj"],
            "ns_per_token": t["da_ns"],
            "components": dict(t["components"]),
            "bitslice": {
                "pj_per_token": t["bs_pj"],
                "ns_per_token": t["bs_ns"],
                "components": dict(t["bs_components"]),
            },
            "ratios": self.ratios(x_bits_eff),
        }


def draft_price(hw: HardwareCostModel, provider: Any,
                full_params: Any = None) -> Dict[str, Any]:
    """Per-token DA + bit-slicing prices of a spec-decode DRAFT pass.

    Truncated-bitplane drafts (``x_bits_eff``) reprice through the model
    exactly — proportionally fewer bit-serial read cycles.  A second-
    artifact draft with its own frozen weights gets its own cost table.
    Anything else (layer-skip) scales the full pass by the provider's
    ``cost_ratio``.  Returns ``{pj, ns, bs_pj, bs_ns, x_bits_eff}``.
    """
    xb = getattr(provider, "x_bits_eff", None)
    if xb is not None:
        return {"pj": hw.pj_per_token(x_bits_eff=xb),
                "ns": hw.ns_per_token(x_bits_eff=xb),
                "bs_pj": hw.bitslice_pj_per_token(x_bits_eff=xb),
                "bs_ns": hw.bitslice_ns_per_token(x_bits_eff=xb),
                "x_bits_eff": int(xb)}
    dparams = getattr(provider, "params", None)
    if dparams is not None and dparams is not full_params:
        own = HardwareCostModel.from_frozen(dparams)
        if own:
            return {"pj": own.pj_per_token(), "ns": own.ns_per_token(),
                    "bs_pj": own.bitslice_pj_per_token(),
                    "bs_ns": own.bitslice_ns_per_token(),
                    "x_bits_eff": None}
    r = float(getattr(provider, "cost_ratio", 1.0))
    return {"pj": hw.pj_per_token() * r, "ns": hw.ns_per_token() * r,
            "bs_pj": hw.bitslice_pj_per_token() * r,
            "bs_ns": hw.bitslice_ns_per_token() * r,
            "x_bits_eff": None}
