"""Fault-tolerant distributed trainer.

Layers of defense, designed for 1000+-node runs:
  * NaN/inf guard — non-finite grads skip the update inside the jitted step
    (optim/adamw.py), so one bad batch never poisons the parameters;
  * checkpoint/restart — async checksummed checkpoints every N steps; the
    loop catches step-level exceptions, restores the last checkpoint and
    replays (the stateless data pipeline makes replay exact);
  * straggler monitor — per-step wall-time EWMAs with a z-threshold flag;
    at scale this is the signal to evict/replace a slow host;
  * elastic re-scaling — checkpoints restore onto any mesh (ckpt.py), and the
    (seed, step) data pipeline is shard-count independent;
  * microbatching — gradient accumulation via lax.scan, constant memory in
    the number of microbatches;
  * optional int8+EF compressed data-parallel all-reduce (optim/compress.py)
    via an explicit shard_map step variant.

The dry-run lowers exactly the ``train_step`` built here.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.model import forward, init_model, lm_loss
from repro.optim import adamw
from repro.optim.schedules import warmup_cosine


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: adamw.AdamWConfig = adamw.AdamWConfig()
    warmup_steps: int = 100
    total_steps: int = 1000
    microbatches: int = 1
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    keep_ckpts: int = 3
    straggler_z: float = 3.0


class TrainState(NamedTuple):
    step: jax.Array
    params: Any
    opt: adamw.AdamWState


def init_state(key, cfg: ModelConfig) -> TrainState:
    params = init_model(key, cfg)
    return TrainState(
        step=jnp.zeros((), jnp.int32), params=params, opt=adamw.init(params)
    )


def loss_fn(params, batch, cfg: ModelConfig):
    logits, _ = forward(
        params, batch["inputs"], cfg, positions=batch.get("positions")
    )
    return lm_loss(logits, batch["labels"])


def make_train_step(
    cfg: ModelConfig, tcfg: TrainConfig
) -> Callable[[TrainState, Dict[str, jax.Array]], tuple]:
    """Build the (jittable) train step: grads (accumulated over microbatches
    via lax.scan) → clipped AdamW update with NaN guard."""

    def train_step(state: TrainState, batch):
        mb = tcfg.microbatches

        if mb > 1:
            def micro(carry, mbatch):
                loss, g = jax.value_and_grad(loss_fn)(state.params, mbatch, cfg)
                acc_loss, acc_g = carry
                return (
                    acc_loss + loss / mb,
                    jax.tree.map(lambda a, b: a + b / mb, acc_g, g),
                ), None

            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params
            )
            stacked = jax.tree.map(
                lambda x: x.reshape((mb, x.shape[0] // mb) + x.shape[1:]), batch
            )
            (loss, grads), _ = jax.lax.scan(
                micro, (jnp.zeros((), jnp.float32), zero_g), stacked
            )
        else:
            loss, grads = jax.value_and_grad(loss_fn)(state.params, batch, cfg)

        lr_scale = warmup_cosine(state.step, tcfg.warmup_steps, tcfg.total_steps)
        params, opt, metrics = adamw.update(
            grads, state.opt, state.params, tcfg.opt, lr_scale
        )
        metrics["loss"] = loss
        new_state = TrainState(step=state.step + 1, params=params, opt=opt)
        return new_state, metrics

    return train_step


class StragglerMonitor:
    """EWMA step-time tracker; flags steps slower than mean + z·std."""

    def __init__(self, z: float = 3.0, alpha: float = 0.1):
        self.z, self.alpha = z, alpha
        self.mean = None
        self.var = 0.0
        self.flagged = 0

    def observe(self, dt: float) -> bool:
        if self.mean is None:
            self.mean = dt
            return False
        slow = dt > self.mean + self.z * (self.var ** 0.5) and dt > 1.5 * self.mean
        d = dt - self.mean
        self.mean += self.alpha * d
        self.var = (1 - self.alpha) * (self.var + self.alpha * d * d)
        self.flagged += int(slow)
        return slow


class Trainer:
    """Checkpoint/restart training loop (single- or multi-host agnostic:
    everything stateful lives in (TrainState, step) and the stateless data
    pipeline)."""

    def __init__(self, cfg: ModelConfig, tcfg: TrainConfig, data_iter_fn):
        self.cfg, self.tcfg = cfg, tcfg
        self.data_iter_fn = data_iter_fn  # step → batch (pure)
        self.step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0,))
        self.monitor = StragglerMonitor(z=tcfg.straggler_z)
        self.ckpt = None
        if tcfg.ckpt_dir:
            from repro.checkpoint.ckpt import AsyncCheckpointer

            self.ckpt = AsyncCheckpointer(tcfg.ckpt_dir, keep=tcfg.keep_ckpts)

    def _maybe_restore(self, state: TrainState) -> TrainState:
        if not self.tcfg.ckpt_dir:
            return state
        from repro.checkpoint import ckpt as C

        last = C.latest_step(self.tcfg.ckpt_dir)
        if last is None:
            return state
        return C.restore(self.tcfg.ckpt_dir, last, state)

    def run(self, state: TrainState, n_steps: int, max_retries: int = 3):
        state = self._maybe_restore(state)
        history = []
        retries = 0
        while int(state.step) < n_steps:
            step = int(state.step)
            try:
                batch = self.data_iter_fn(step)
                t0 = time.perf_counter()
                state, metrics = self.step_fn(state, batch)
                metrics = jax.tree.map(float, metrics)
                dt = time.perf_counter() - t0
                slow = self.monitor.observe(dt)
                metrics.update(step=step, time_s=dt, straggler=slow)
                history.append(metrics)
                if self.ckpt and (step + 1) % self.tcfg.ckpt_every == 0:
                    self.ckpt.submit(step + 1, state)
                retries = 0
            except (FloatingPointError, RuntimeError) as e:
                # node failure / device error path: restore + replay
                retries += 1
                if retries > max_retries or not self.tcfg.ckpt_dir:
                    raise
                state = self._maybe_restore(state)
        if self.ckpt:
            self.ckpt.wait()
        return state, history
