"""AdamW with fp32 master weights, global-norm clipping, and a NaN guard.

Params may live in bf16 (the model's param_dtype); the optimizer keeps fp32
master copies and moments, computes the update in fp32, and casts back — the
standard mixed-precision training recipe. ``update`` returns a ``skipped``
flag instead of raising when gradients are non-finite (fault tolerance: a bad
batch must not kill a 1000-node run).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


class AdamWState(NamedTuple):
    step: jax.Array
    master: Any   # fp32 master params
    mu: Any
    nu: Any


def init(params) -> AdamWState:
    # copy=True: with fp32 params astype would alias the param buffer and
    # break donation (same buffer donated twice via params and master).
    f32 = lambda p: jnp.array(p, dtype=jnp.float32, copy=True)
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        master=jax.tree.map(f32, params),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def update(
    grads, state: AdamWState, params, cfg: AdamWConfig, lr_scale: jax.Array
) -> Tuple[Any, AdamWState, dict]:
    gnorm = global_norm(grads)
    finite = jnp.isfinite(gnorm)
    scale = jnp.where(
        finite, jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9)), 0.0
    )
    step = state.step + finite.astype(jnp.int32)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(g, m, v, w):
        g = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m + (1 - cfg.b1) * g
        v_new = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m_new / b1c
        vhat = v_new / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * w
        w_new = w - lr * delta
        # NaN guard: on a skipped step every state entry is unchanged.
        return (
            jnp.where(finite, m_new, m),
            jnp.where(finite, v_new, v),
            jnp.where(finite, w_new, w),
        )

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    flat_w = treedef.flatten_up_to(state.master)
    new = [upd(g, m, v, w) for g, m, v, w in zip(flat_g, flat_m, flat_v, flat_w)]
    mu = treedef.unflatten([n[0] for n in new])
    nu = treedef.unflatten([n[1] for n in new])
    master = treedef.unflatten([n[2] for n in new])
    flat_p = treedef.flatten_up_to(params)
    new_params = treedef.unflatten(
        [w.astype(p.dtype) for w, p in zip([n[2] for n in new], flat_p)]
    )
    metrics = {
        "grad_norm": gnorm,
        "skipped": (~finite).astype(jnp.float32),
        "lr": lr,
    }
    return new_params, AdamWState(step=step, master=master, mu=mu, nu=nu), metrics
