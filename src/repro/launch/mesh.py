"""Production meshes. A FUNCTION, not a module constant — importing this
module must never touch jax device state (smoke tests see 1 CPU device;
only dryrun.py requests 512 placeholder devices via XLA_FLAGS)."""
from __future__ import annotations

import jax


def _auto(axes):
    return (jax.sharding.AxisType.Auto,) * len(axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod (TPU v5e pod slice); 2 pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(axes))


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU tests (requires XLA_FLAGS host device count ≥ prod)."""
    return jax.make_mesh(shape, axes, axis_types=_auto(axes))
