"""Pallas TPU kernel: storage-free (bit-plane) Distributed Arithmetic VMM.

The deployable DA mode for large LM layers (DESIGN.md §2): instead of reading
precomputed weight sums from a materialized LUT, the MXU computes each
bit-serial cycle's weight sums on the fly —

    Y = Σ_b coef(b) · (xbit_b @ W),   xbit_b ∈ {0,1}

which is exactly the paper's per-cycle ``MR`` with the systolic array playing
the role of the processing-memory array. Multiplications involve only the
{0,1} bit operand (multiplier-free in the DA sense); accumulation is int32.

Tiling: grid = (M/bm, N/bn, K/bk). W is streamed through VMEM as int8-ranged
[bk, bn] tiles; the input tile [bm, bk] is decomposed into its 8 bit-planes
in-register. K is the reduction axis (output revisited, init at k == 0).

Exactness: per-tile dot values ≤ bk·127 < 2²⁴ for bk ≤ 2048, so fp32 MXU
passes are exact; the int32 accumulator covers the full 21-bit+ growth.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.da import DAConfig, bit_coefs


def _bitplane_kernel(x_ref, w_ref, out_ref, *, cfg: DAConfig):
    k_idx = pl.program_id(2)
    x = x_ref[...]  # [bm, bk] int32 codes
    w = w_ref[...].astype(jnp.float32)  # [bk, bn]

    mask = (1 << cfg.x_bits) - 1
    xm = jnp.bitwise_and(x, mask)
    coefs = bit_coefs(cfg.x_bits, cfg.x_signed)

    acc = jnp.zeros(out_ref.shape, dtype=jnp.int32)
    for b in range(cfg.x_bits):  # unrolled bit-serial cycles
        plane = jnp.bitwise_and(jnp.right_shift(xm, b), 1).astype(jnp.float32)
        mr = jnp.dot(plane, w, preferred_element_type=jnp.float32)
        acc = acc + jnp.int32(coefs[b]) * mr.astype(jnp.int32)

    @pl.when(k_idx == 0)
    def _init():
        out_ref[...] = acc

    @pl.when(k_idx != 0)
    def _accum():
        out_ref[...] += acc


@functools.partial(jax.jit, static_argnames=("cfg", "bm", "bn", "bk", "interpret"))
def bitplane_vmm_pallas(
    xq: jax.Array,
    wq: jax.Array,
    cfg: DAConfig,
    bm: int = 256,
    bn: int = 256,
    bk: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """Bit-plane DA VMM via Pallas. xq [M,K] int codes, wq [K,N] int codes.

    Returns int32 [M, N] == xq @ wq exactly.
    """
    m, k = xq.shape
    k2, n = wq.shape
    assert k == k2
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert bk * 127 < (1 << 24), "fp32 per-tile exactness bound"
    pm, pn, pk = (-m) % bm, (-n) % bn, (-k) % bk
    if pm or pk:
        xq = jnp.pad(xq, ((0, pm), (0, pk)))
    if pk or pn:
        wq = jnp.pad(wq, ((0, pk), (0, pn)))
    mm, nn, kk = m + pm, n + pn, k + pk

    out = pl.pallas_call(
        functools.partial(_bitplane_kernel, cfg=cfg),
        grid=(mm // bm, nn // bn, kk // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, ki: (i, ki)),
            pl.BlockSpec((bk, bn), lambda i, j, ki: (ki, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, ki: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mm, nn), jnp.int32),
        interpret=interpret,
    )(xq.astype(jnp.int32), wq.astype(jnp.int32))
    return out[:m, :n]
