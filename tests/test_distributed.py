"""Distribution tests on an 8-device CPU mesh (subprocess: jax locks the
device count at first init, so these run with their own XLA_FLAGS)."""
import os
import subprocess
import sys
import textwrap

import pytest

# Each test compiles a sharded model in a fresh subprocess — multi-second by
# construction. Run with `pytest -m slow`.
pytestmark = pytest.mark.slow

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(code: str, n: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_sharded_train_step_runs_and_matches_single_device():
    """A tiny model train step on a (2,4) mesh == the unsharded step."""
    out = run_with_devices("""
        import dataclasses, numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs.registry import ARCHS, reduce_for_smoke
        from repro.launch.mesh import make_test_mesh
        from repro.launch.sharding import use_mesh_rules
        from repro.launch import specs as SP
        from repro.train.trainer import TrainConfig, init_state, make_train_step
        from repro.data.pipeline import DataConfig, batch_at

        cfg = dataclasses.replace(reduce_for_smoke(ARCHS["qwen3-8b"]),
                                  d_model=64, d_ff=128, n_layers=2)
        dc = DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=8, seed=0)
        batch = jax.tree.map(jnp.asarray, batch_at(dc, 0))
        state = init_state(jax.random.key(0), cfg)
        step = make_train_step(cfg, TrainConfig())
        # single-device reference
        s_ref, m_ref = jax.jit(step)(state, batch)

        mesh = make_test_mesh((2, 4), ("data", "model"))
        with use_mesh_rules(mesh):
            sspec = SP.tree_pspecs(state)
            bspec = SP.batch_pspecs(batch)
            to_ns = lambda t: jax.tree.map(
                lambda s: NamedSharding(mesh, s), t,
                is_leaf=lambda x: isinstance(x, P))
            st = jax.device_put(state, to_ns(sspec))
            bt = jax.device_put(batch, to_ns(bspec))
            s_sh, m_sh = jax.jit(
                step, in_shardings=(to_ns(sspec), to_ns(bspec)),
                out_shardings=(to_ns(sspec), None))(st, bt)
        d = abs(float(m_ref["loss"]) - float(m_sh["loss"]))
        assert d < 1e-3, d
        w_ref = jax.tree.leaves(s_ref.params)[0]
        w_sh = jax.tree.leaves(s_sh.params)[0]
        np.testing.assert_allclose(np.asarray(w_ref), np.asarray(w_sh),
                                   atol=5e-3)
        print("OK", float(m_sh["loss"]))
    """)
    assert "OK" in out


def test_dryrun_cells_on_small_mesh():
    """Miniature of the production dry-run: lower+compile train/prefill/
    decode for a tiny arch on 2-D and 3-D meshes; roofline terms > 0."""
    out = run_with_devices("""
        import dataclasses, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs.registry import ARCHS, reduce_for_smoke, ShapeSpec
        from repro.launch.mesh import make_test_mesh
        from repro.launch.dryrun import lower_cell, _cost_of
        from repro.launch import roofline as rl

        cfg0 = dataclasses.replace(reduce_for_smoke(ARCHS["jamba-1.5-large-398b"]),
                                   param_dtype="bfloat16", compute_dtype="bfloat16")
        import repro.configs.registry as REG
        REG.ARCHS["tiny-jamba"] = cfg0

        for axes, shape in [(("data","model"),(2,4)), (("pod","data","model"),(2,2,2))]:
            mesh = make_test_mesh(shape, axes)
            for sname, kind, sl, gb in [("train_4k","train",32,8),
                                         ("prefill_32k","prefill",32,8),
                                         ("decode_32k","decode",32,8)]:
                spec = ShapeSpec(sname, sl, gb, kind)
                lowered, aux = lower_cell(cfg0, spec, mesh)
                compiled = lowered.compile()
                cost = _cost_of(compiled)
                assert cost["flops"] > 0
                mem = compiled.memory_analysis()
                print("OK", axes, sname, int(cost["flops"]), cost["coll"] >= 0)
    """)
    assert out.count("OK") == 6


def test_pipeline_parallel_correctness():
    """GPipe schedule over a 4-stage axis == sequential stage application."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import make_test_mesh
        from repro.train.pipeline import make_pipelined_apply

        mesh = make_test_mesh((4,), ("stage",))
        S, M, mb, D = 4, 8, 4, 16
        key = jax.random.key(0)
        ws = jax.random.normal(key, (S, D, D)) * 0.3

        def stage_fn(w, x):
            return jnp.tanh(x @ w)

        apply = make_pipelined_apply(mesh, "stage", stage_fn, n_microbatches=M)
        x = jax.random.normal(jax.random.key(1), (M * mb, D))
        sw = jax.device_put(ws, NamedSharding(mesh, P("stage")))
        y = apply(sw, x)
        ref = x
        for s in range(S):
            ref = stage_fn(ws[s], ref)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-5)
        print("OK")
    """)
    assert "OK" in out


def test_compressed_allreduce_dp():
    """int8+EF all-reduce inside shard_map: mean grad ≈ true mean; EF keeps
    the accumulated error bounded."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import make_test_mesh
        from repro.optim.compress import allreduce_compressed, init_error

        mesh = make_test_mesh((8,), ("data",))
        g = jax.random.normal(jax.random.key(0), (8, 64))

        def f(g_local, err):
            mean, new_err = allreduce_compressed({"w": g_local}, err, "data")
            return mean["w"], new_err

        from repro.launch.mesh import shard_map_compat
        f_sh = shard_map_compat(f, mesh=mesh,
                                in_specs=(P("data"), {"w": P()}),
                                out_specs=(P(), {"w": P()}),
                                check=False)
        err0 = init_error({"w": jnp.zeros((64,))})
        mean, err = f_sh(g, err0)
        true_mean = jnp.mean(g, axis=0)
        rel = float(jnp.abs(mean[0] - true_mean).max() / jnp.abs(true_mean).max())
        assert rel < 0.05, rel
        print("OK", rel)
    """)
    assert "OK" in out


def test_da_serving_under_sharding():
    """DA bitplane serving path lowers and runs under a model-parallel mesh
    (the paper's technique inside the distributed serving graph)."""
    out = run_with_devices("""
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs.registry import ARCHS, reduce_for_smoke
        from repro.core.da import DAConfig
        from repro.launch.mesh import make_test_mesh
        from repro.launch.sharding import use_mesh_rules
        from repro.models.model import forward, init_model
        from repro.core.freeze import freeze_model_da

        cfg = dataclasses.replace(reduce_for_smoke(ARCHS["qwen3-8b"]),
                                  moe_dropless=True)
        params = init_model(jax.random.key(0), cfg)
        toks = jax.random.randint(jax.random.key(1), (4, 8), 0, cfg.vocab)
        ref, _ = forward(params, toks, cfg)
        frozen = freeze_model_da(params, DAConfig(x_signed=True),
                                 mode="da_bitplane")
        mesh = make_test_mesh((2, 4), ("data", "model"))
        with use_mesh_rules(mesh):
            got, _ = jax.jit(lambda p, t: forward(p, t, cfg))(frozen, toks)
        agree = float(np.mean(np.asarray(
            jnp.argmax(ref, -1) == jnp.argmax(got, -1))))
        assert agree > 0.8, agree
        print("OK", agree)
    """)
    assert "OK" in out


def test_frozen_artifact_shards_pmas_over_model_axis():
    """The artifact pipeline's shard stage: a DA-frozen model's packed
    leaves (wq / w_scale / luts) tensor-parallel over the mesh's model axis
    — codes, scales and LUT slabs of one column slice co-located — and the
    sharded serving forward matches the unsharded one (integer DA path is
    exact; float epilogues differ only by reduction-order noise)."""
    out = run_with_devices("""
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from repro.configs.registry import ARCHS, reduce_for_smoke
        from repro.core.da import DAConfig
        from repro.core.freeze import freeze_model
        from repro.launch.mesh import make_test_mesh
        from repro.launch.sharding import shard_frozen_params, use_mesh_rules
        from repro.models.model import forward, init_model

        cfg = dataclasses.replace(reduce_for_smoke(ARCHS["qwen3-8b"]),
                                  moe_dropless=True)
        params = init_model(jax.random.key(0), cfg)
        art = freeze_model(params, DAConfig(x_signed=True), mode="lut")
        toks = jax.random.randint(jax.random.key(1), (4, 8), 0, cfg.vocab)
        ref, _ = forward(art.params, toks, cfg)

        mesh = make_test_mesh((2, 4), ("data", "model"))
        with use_mesh_rules(mesh):
            sharded = shard_frozen_params(art.params)
            # attention out-projection: [P, K, N] codes split N 4-ways,
            # with the scale and the LUT slab split the same way
            pw = sharded["periods"]["pos_0"]["mixer"]["wq"]
            for leaf, want_axis in ((pw.wq, -1), (pw.w_scale, -1),
                                    (pw.luts, -1)):
                spec = leaf.sharding.spec
                assert spec and spec[-1] == "model", (leaf.shape, spec)
                assert leaf.addressable_shards[0].data.shape[want_axis] \\
                    == leaf.shape[want_axis] // 4
            got, _ = jax.jit(lambda p, t: forward(p, t, cfg))(sharded, toks)
        np.testing.assert_allclose(np.asarray(ref), np.asarray(got),
                                   atol=1e-4, rtol=1e-4)
        assert bool(jnp.all(jnp.argmax(ref, -1) == jnp.argmax(got, -1)))
        print("OK", pw.wq.sharding.spec)
    """)
    assert "OK" in out


def test_fsdp_rules_shard_params_2d():
    """FSDP/ZeRO-style 2-D sharding: weights shard over data AND model axes;
    per-device parameter bytes shrink by the full mesh size."""
    out = run_with_devices("""
        import dataclasses, jax, jax.numpy as jnp, math
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs.registry import ARCHS, reduce_for_smoke
        from repro.launch.mesh import make_test_mesh
        from repro.launch.sharding import FSDP_RULES, use_mesh_rules
        from repro.launch import specs as SP
        from repro.train.trainer import init_state, make_train_step, TrainConfig
        from repro.data.pipeline import DataConfig, batch_at

        cfg = dataclasses.replace(reduce_for_smoke(ARCHS["qwen3-8b"]),
                                  d_model=64, d_ff=128, n_layers=2)
        mesh = make_test_mesh((2, 4), ("data", "model"))
        state = init_state(jax.random.key(0), cfg)
        with use_mesh_rules(mesh, FSDP_RULES):
            sspec = SP.tree_pspecs(state)
        # the MLP weight must now carry BOTH axes
        spec = sspec.params["periods"]["pos_0"]["ffn"]["w_up"]
        assert "data" in str(spec) and "model" in str(spec), spec
        # and the train step still runs + matches the unsharded loss
        to_ns = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                       is_leaf=lambda x: isinstance(x, P))
        dc = DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=8, seed=0)
        batch = jax.tree.map(jnp.asarray, batch_at(dc, 0))
        step = make_train_step(cfg, TrainConfig())
        _, m_ref = jax.jit(step)(state, batch)
        with use_mesh_rules(mesh, FSDP_RULES):
            sspec = SP.tree_pspecs(state)
            bspec = SP.batch_pspecs(batch)
            st = jax.device_put(state, to_ns(sspec))
            bt = jax.device_put(batch, to_ns(bspec))
            _, m_sh = jax.jit(step, in_shardings=(to_ns(sspec), to_ns(bspec)),
                              out_shardings=(to_ns(sspec), None))(st, bt)
        assert abs(float(m_ref["loss"]) - float(m_sh["loss"])) < 1e-3
        print("OK", spec)
    """)
    assert "OK" in out
