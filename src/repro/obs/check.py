"""CLI schema checker for exported observability artifacts.

Usage (what CI runs after the traced serve smoke)::

    python -m repro.obs.check trace.json metrics.prom

Each path is validated by extension: ``*.json`` as a Chrome trace_event
file, anything else as Prometheus text exposition.  Prints one line per
artifact; exits nonzero on the first invalid one.
"""
from __future__ import annotations

import json
import sys

from repro.obs.export import validate_chrome_trace, validate_prometheus_text


def check_file(path: str) -> list:
    if path.endswith(".json"):
        with open(path) as f:
            try:
                obj = json.load(f)
            except json.JSONDecodeError as e:
                return [f"invalid JSON: {e}"]
        return validate_chrome_trace(obj)
    with open(path) as f:
        return validate_prometheus_text(f.read())


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if not argv:
        print("usage: python -m repro.obs.check <trace.json|metrics.prom>...")
        return 2
    rc = 0
    for path in argv:
        errs = check_file(path)
        if errs:
            rc = 1
            print(f"FAIL {path}")
            for e in errs[:20]:
                print(f"  - {e}")
        else:
            print(f"OK   {path}")
    return rc


if __name__ == "__main__":
    sys.exit(main())
