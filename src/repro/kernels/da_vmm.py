"""Pallas TPU kernel: Distributed-Arithmetic VMM with in-VMEM LUT readout.

TPU-native mapping of the paper's PMA datapath (DESIGN.md §2):

  * the PMA *address decoder* (8-bit address → 1-of-256 wordline) becomes an
    in-register one-hot expansion ``iota == addr``;
  * the *array readout + inter-PMA adder tree* becomes a single MXU matmul
    ``onehot[bm, G·256] @ LUT[G·256, bn]`` — the systolic array sums the
    selected weight-sum rows of every PMA group in one pass;
  * the *bit-serial shift-and-add accumulator* becomes an unrolled loop over
    the 8 bit-planes with int32 accumulation (covers the 21-bit growth).

Tiling: grid = (M/bm, N/bn, G/bg); the LUT is streamed through VMEM in
``bg``-group chunks of shape [bg·256, bn] (bg=8, bn=256 → 2 MB int32, well
within the ~16 MB VMEM budget together with the [bm, bg·8] input tile and the
[bm, bn] int32 accumulator). The G axis is the reduction dimension — the
output block is revisited and accumulated, initialized at g == 0.

Exactness: one-hot (0/1) × LUT entries (|·| ≤ group·127 ≤ 2¹¹) dot products
stay far below 2²⁴, so the fp32 MXU pass is exact; accumulation is int32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.da import DAConfig, bit_coefs


def _da_vmm_kernel(x_ref, lut_ref, out_ref, *, cfg: DAConfig, bg: int):
    """One (m, n, g) tile: bg PMA groups × all bit-planes, accumulated."""
    l = cfg.group_size
    r = 1 << l
    g_idx = pl.program_id(2)

    x = x_ref[...]  # [bm, bg*L] int32 codes of this group chunk
    lut = lut_ref[...].astype(jnp.float32)  # [bg*R, bn]
    bm = x.shape[0]

    mask = (1 << cfg.x_bits) - 1
    xm = jnp.bitwise_and(x, mask).reshape(bm, bg, l)
    shifts = jax.lax.broadcasted_iota(jnp.int32, (1, 1, l), 2)

    # one-hot column index decomposition: col c ↔ (group c//R, address c%R)
    col_addr = jax.lax.broadcasted_iota(jnp.int32, (1, bg, r), 2)

    coefs = bit_coefs(cfg.x_bits, cfg.x_signed)
    acc = jnp.zeros(out_ref.shape, dtype=jnp.int32)
    for b in range(cfg.x_bits):  # the 8 bit-serial "memory cycles", unrolled
        bits = jnp.bitwise_and(jnp.right_shift(xm, b), 1)
        addr = jnp.sum(bits << shifts, axis=-1)  # [bm, bg] PMA addresses
        onehot = (addr[:, :, None] == col_addr).astype(jnp.float32)
        onehot = onehot.reshape(bm, bg * r)  # decoder output (wordlines)
        mr = jnp.dot(onehot, lut, preferred_element_type=jnp.float32)
        acc = acc + jnp.int32(coefs[b]) * mr.astype(jnp.int32)

    @pl.when(g_idx == 0)
    def _init():
        out_ref[...] = acc

    @pl.when(g_idx != 0)
    def _accum():
        out_ref[...] += acc


@functools.partial(
    jax.jit, static_argnames=("cfg", "bm", "bn", "bg", "interpret")
)
def da_vmm_pallas(
    xq: jax.Array,
    luts: jax.Array,
    cfg: DAConfig,
    bm: int = 256,
    bn: int = 256,
    bg: int = 8,
    interpret: bool | None = None,
) -> jax.Array:
    """DA VMM via Pallas. xq [M, K] int32 codes; luts [G, 2^L, N] int32.

    Returns int32 [M, N] == xq @ W exactly. ``interpret=None`` derives the
    execution mode from the platform: compiled on TPU, interpret elsewhere.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    m, k = xq.shape
    g, r, n = luts.shape
    l = cfg.group_size
    assert r == (1 << l), (r, l)
    assert g * l >= k

    # Pad every axis to tile multiples (zero rows address LUT entry 0 == 0).
    pad_k = g * l - k
    if pad_k:
        xq = jnp.pad(xq, ((0, 0), (0, pad_k)))
    bm = min(bm, m)
    bn = min(bn, n)
    bg = min(bg, g)
    pm, pn, pg = (-m) % bm, (-n) % bn, (-g) % bg
    if pm:
        xq = jnp.pad(xq, ((0, pm), (0, 0)))
    if pg:
        xq = jnp.pad(xq, ((0, 0), (0, pg * l)))
        luts = jnp.pad(luts, ((0, pg), (0, 0), (0, 0)))
    if pn:
        luts = jnp.pad(luts, ((0, 0), (0, 0), (0, pn)))
    mm, nn, gg = m + pm, n + pn, g + pg
    lut2d = luts.reshape(gg * r, nn)

    out = pl.pallas_call(
        functools.partial(_da_vmm_kernel, cfg=cfg, bg=bg),
        grid=(mm // bm, nn // bn, gg // bg),
        in_specs=[
            pl.BlockSpec((bm, bg * l), lambda i, j, gi: (i, gi)),
            pl.BlockSpec((bg * r, bn), lambda i, j, gi: (gi, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, gi: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mm, nn), jnp.int32),
        interpret=interpret,
    )(xq, lut2d)
    return out[:m, :n]
