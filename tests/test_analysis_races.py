"""Page-aliasing race detector tests (repro.analysis.races) plus the
scheduler's analysis_debug mode.

Forged-plan units prove each invariant fires on its own violation; the
@slow stress test drives a live engine — prefix-cache sharing, optimistic
admission, tight page pool (preemptions), speculative decoding with
rollback — with every launch plan submitted to the checker, and asserts
the whole schedule validates with zero findings while emitting tokens
identical to a debug-off run.
"""
import dataclasses

import numpy as np
import pytest

from repro.analysis.races import (
    PageRaceError,
    PageWrite,
    TickPlan,
    assert_plan_ok,
    check_plan,
)


def _plan(writes, refcounts, trie=(), free=(), ps=4, phase="decode"):
    return TickPlan.build(
        phase=phase, page_size=ps, writes=writes, refcounts=refcounts,
        trie_pages=trie, free_pages=free,
    )


def test_clean_plan_has_no_findings():
    plan = _plan(
        writes=[PageWrite(lane=0, uid=10, page=3, offset=1),
                PageWrite(lane=1, uid=11, page=5, offset=1)],
        refcounts={3: 1, 5: 1},
    )
    assert check_plan(plan) == []
    assert_plan_ok(plan)  # no raise


def test_double_write_same_slot_is_caught():
    plan = _plan(
        writes=[PageWrite(lane=0, uid=10, page=3, offset=2),
                PageWrite(lane=1, uid=11, page=3, offset=2)],
        refcounts={3: 1},
    )
    findings = check_plan(plan)
    assert any("double-write" in f.op for f in findings)
    with pytest.raises(PageRaceError) as ei:
        assert_plan_ok(plan)
    assert ei.value.plan is plan and ei.value.findings


def test_same_lane_rewriting_a_slot_is_not_a_race():
    """One lane touching the same slot twice in a launch (e.g. a clamped
    pad column) is not cross-lane scatter nondeterminism."""
    plan = _plan(
        writes=[PageWrite(lane=0, uid=10, page=3, offset=2),
                PageWrite(lane=0, uid=10, page=3, offset=2)],
        refcounts={3: 1},
    )
    assert check_plan(plan) == []


def test_shared_page_write_without_cow_is_caught():
    plan = _plan(
        writes=[PageWrite(lane=0, uid=10, page=3, offset=0)],
        refcounts={3: 2},
    )
    findings = check_plan(plan)
    assert any("refcount=2" in f.op for f in findings)


def test_prefix_trie_page_write_is_caught():
    plan = _plan(
        writes=[PageWrite(lane=0, uid=10, page=7, offset=0)],
        refcounts={7: 1},
        trie=[7],
    )
    findings = check_plan(plan)
    assert any("prefix-trie" in f.op for f in findings)


def test_free_page_write_is_caught():
    plan = _plan(
        writes=[PageWrite(lane=0, uid=10, page=4, offset=0)],
        refcounts={4: 0},
        free=[4],
    )
    findings = check_plan(plan)
    assert any("unallocated" in f.op for f in findings)


def test_offset_outside_page_is_caught():
    plan = _plan(
        writes=[PageWrite(lane=0, uid=10, page=3, offset=4)],  # ps=4
        refcounts={3: 1},
    )
    findings = check_plan(plan)
    assert any("offset" in f.op for f in findings)


def test_garbage_page_is_exempt():
    """Pad rows and clamped positions dump to page 0 by design — even
    'double writes' and a zero refcount there are not findings."""
    plan = _plan(
        writes=[PageWrite(lane=0, uid=10, page=0, offset=0),
                PageWrite(lane=1, uid=11, page=0, offset=0),
                PageWrite(lane=2, uid=12, page=0, offset=99)],
        refcounts={},
    )
    assert check_plan(plan) == []


def test_one_bad_write_among_good_ones_reports_only_the_bad():
    plan = _plan(
        writes=[PageWrite(lane=0, uid=10, page=3, offset=0),
                PageWrite(lane=1, uid=11, page=5, offset=0),
                PageWrite(lane=2, uid=12, page=5, offset=0)],
        refcounts={3: 1, 5: 1},
    )
    findings = check_plan(plan)
    assert len(findings) == 1 and "double-write" in findings[0].op
    assert "lane2" in findings[0].where


# -- the scheduler's analysis_debug mode (live engine stress) ----------------


@pytest.mark.slow
def test_debug_mode_validates_stress_schedule_and_preserves_tokens():
    """Prefix sharing + optimistic admission + a pool tight enough to
    preempt + speculative decode with rollback: every launch plan this
    schedule produces must pass the checker, and checking must not perturb
    a single emitted token."""
    import jax

    from repro.configs.registry import ARCHS, reduce_for_smoke
    from repro.core.da import DAConfig
    from repro.core.freeze import freeze_model
    from repro.models.model import init_model
    from repro.serve.engine import Request, ServeEngine
    from repro.spec import SpecConfig

    cfg = dataclasses.replace(reduce_for_smoke(ARCHS["qwen3-8b"]),
                              moe_dropless=True)
    params = init_model(jax.random.key(0), cfg)
    art = freeze_model(params, DAConfig(x_signed=True),
                       mode="da_bitplane_stacked", model_cfg=cfg)
    kw = dict(batch_size=3, max_len=48, page_size=4, n_pages=12,
              prefill_chunk=4, admission="optimistic", prefix_cache=True,
              spec=SpecConfig(provider="bitplane", gamma=2, draft_x_bits=6,
                              disable_below=0.0))
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(1, 90, size=n)) for n in (7, 9, 7, 5, 11, 9)]
    prompts[2] = prompts[0]  # exact shared prefix: exercises the trie + COW

    def run(debug):
        eng = ServeEngine(cfg, art.params, greedy=True,
                          analysis_debug=debug, **kw)
        for uid, p in enumerate(prompts):
            eng.submit(Request(uid=uid, prompt=p, max_new_tokens=6))
        done = eng.run()
        outs = {uid: r.generated for uid, r in sorted(done.items())}
        return outs, eng._rt.plans_checked

    debug_out, checked = run(True)        # raises PageRaceError on any race
    plain_out, unchecked = run(False)
    assert checked > 0, "debug mode must actually submit plans"
    assert unchecked == 0
    assert debug_out == plain_out, "checking must not perturb tokens"
    assert all(len(toks) == 6 for toks in debug_out.values())


@pytest.mark.slow
def test_debug_mode_rejected_on_slot_runtime():
    import jax

    from repro.configs.registry import ARCHS, reduce_for_smoke
    from repro.models.model import init_model
    from repro.serve.engine import ServeEngine

    cfg = reduce_for_smoke(ARCHS["qwen3-8b"])
    cfg = dataclasses.replace(cfg, moe_dropless=True)
    params = init_model(jax.random.key(0), cfg)
    with pytest.raises(ValueError, match="analysis_debug"):
        ServeEngine(cfg, params, batch_size=2, max_len=32,
                    runtime="slots", analysis_debug=True)
