"""Serving launcher: batched continuous-batching engine, optional DA mode.

  python -m repro.launch.serve --arch qwen3-8b --smoke --quant da8 \
      --requests 16 --batch 4

Freeze-once, serve-many: ``--quant da8-plan --save-artifact DIR`` persists
the planned DA artifact; a later ``--artifact DIR`` boots straight from disk
(no --arch, no float init, no re-packing).

Shared-prefix caching (paged runtime): ``--prefix-cache`` reuses the KV
pages of shared prompt prefixes across requests (refcounted pages,
copy-on-write on the last partial page; tokens identical to caching off).

Speculative decoding (paged runtime): ``--spec bitplane`` drafts with a
truncated-bitplane pass over the same artifact (``--spec-gamma``,
``--spec-draft-bits``); ``--spec layerskip`` early-exits after
``--spec-draft-periods`` period groups; ``--spec artifact`` drafts with a
second frozen artifact (``--spec-draft-artifact DIR``).  Greedy output is
token-identical to non-speculative serving.
"""
import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--quant", default="none",
                    choices=["none", "int8", "da8", "da8-lut", "da8-plan"])
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--artifact", default=None, metavar="DIR",
                    help="boot from a persisted DA artifact (cold serve path)")
    ap.add_argument("--save-artifact", default=None, metavar="DIR",
                    help="persist the frozen artifact after the pre-VMM step")
    ap.add_argument("--runtime", default="auto",
                    choices=["auto", "paged", "slots"],
                    help="serving runtime (auto: paged KV + continuous "
                         "batching for attention stacks)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="KV page size (tokens) for the paged runtime")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="shared-prefix caching: requests sharing a prompt "
                         "prefix reuse its KV pages (refcounted, COW; "
                         "tokens identical to caching off)")
    ap.add_argument("--paged-attn", default="auto",
                    choices=["auto", "gather", "fused"],
                    help="paged-attention read: XLA gather or the fused "
                         "Pallas page-walk kernel (auto picks per shape "
                         "bucket; tokens identical either way)")
    ap.add_argument("--kv-dtype", default=None,
                    choices=["fp16", "int8", "int4"],
                    help="KV page precision (paged runtime): int8/int4 pages "
                         "store quantized codes with in-page dequant scales "
                         "(~2x/~3.6x more resident tokens per pool byte); "
                         "fp16 keeps compute-dtype pages. Default: the model "
                         "config / artifact plan")
    ap.add_argument("--spec", default=None,
                    choices=["bitplane", "layerskip", "artifact"],
                    help="speculative decoding draft provider (paged runtime; "
                         "greedy output stays token-identical)")
    ap.add_argument("--spec-gamma", type=int, default=4,
                    help="draft tokens per speculative round")
    ap.add_argument("--spec-draft-bits", type=int, default=4,
                    help="bit-planes the truncated-bitplane self-draft "
                         "evaluates (of the artifact's x_bits)")
    ap.add_argument("--spec-draft-periods", type=int, default=None,
                    help="period groups the layer-skip draft runs "
                         "(default: half the stack)")
    ap.add_argument("--spec-draft-artifact", default=None, metavar="DIR",
                    help="frozen draft DAArtifact for --spec artifact")
    ap.add_argument("--trace-out", default=None, metavar="FILE",
                    help="record request/scheduler lifecycle spans and write "
                         "a Chrome trace_event JSON here (load the file in "
                         "Perfetto / chrome://tracing)")
    ap.add_argument("--metrics-out", default=None, metavar="FILE",
                    help="write the metrics registry here in Prometheus "
                         "text exposition format after the run")
    ap.add_argument("--hw-metrics", nargs="?", const="-", default=None,
                    metavar="FILE",
                    help="print the DA hardware-cost estimate "
                         "(metrics()['hw']: pJ/token, component breakdown, "
                         "live DA-vs-bitslice ratios) after the run; with "
                         "FILE, also write it as schema-stamped JSON "
                         "(validated by python -m repro.obs.check)")
    args = ap.parse_args()
    if args.artifact and (args.save_artifact or args.quant != "none"
                          or args.smoke or args.arch):
        raise SystemExit("--artifact boots a finished artifact; it conflicts "
                         "with --arch/--smoke/--quant/--save-artifact")
    if args.save_artifact and args.quant == "none":
        raise SystemExit("--save-artifact requires a DA --quant mode")

    import dataclasses

    import jax
    import numpy as np

    from repro.configs.registry import ARCHS, reduce_for_smoke
    from repro.models.model import count_params, init_model
    from repro.serve.engine import Request, ServeEngine
    from repro.core.freeze import da_memory_report

    spec = None
    if args.spec:
        from repro.spec import SpecConfig

        if args.spec == "artifact" and not args.spec_draft_artifact:
            raise SystemExit("--spec artifact requires --spec-draft-artifact")
        spec = SpecConfig(provider=args.spec, gamma=args.spec_gamma,
                          draft_x_bits=args.spec_draft_bits,
                          draft_periods=args.spec_draft_periods,
                          draft_artifact=args.spec_draft_artifact)

    trace = args.trace_out is not None
    if args.artifact:
        eng = ServeEngine.from_artifact(args.artifact, batch_size=args.batch,
                                        max_len=args.max_len,
                                        runtime=args.runtime,
                                        page_size=args.page_size, spec=spec,
                                        prefix_cache=args.prefix_cache,
                                        paged_attn=args.paged_attn,
                                        kv_dtype=args.kv_dtype, trace=trace)
        cfg = eng.cfg
        print(f"arch={cfg.name} cold boot from {args.artifact} "
              f"(zero float weights, runtime={eng.runtime}, "
              f"kv_dtype={cfg.kv_dtype})")
    else:
        if args.arch is None:
            raise SystemExit("--arch is required unless booting --artifact")
        cfg = ARCHS[args.arch]
        if args.smoke:
            cfg = reduce_for_smoke(cfg)
        cfg = dataclasses.replace(cfg, moe_dropless=True)
        if cfg.modality != "text":
            raise SystemExit(
                f"{cfg.name} has a stub frontend; serve text archs")

        params = init_model(jax.random.key(0), cfg)
        print(f"arch={cfg.name} params={count_params(cfg)/1e6:.1f}M "
              f"quant={args.quant}")
        mode = {"none": None, "int8": "int8", "da8": "da_bitplane",
                "da8-lut": "da_lut", "da8-plan": "auto"}[args.quant]
        eng = ServeEngine(cfg, params, batch_size=args.batch,
                          max_len=args.max_len, da_mode=mode,
                          runtime=args.runtime, page_size=args.page_size,
                          spec=spec, prefix_cache=args.prefix_cache,
                          paged_attn=args.paged_attn, kv_dtype=args.kv_dtype,
                          trace=trace)
        if mode is not None:
            rep = da_memory_report(eng.params, model_cfg=eng.cfg)
            print(f"pre-VMM freeze: {rep['da_matrices']} matrices"
                  + (f", LUT blow-up {rep['cell_blowup']:.0f}x"
                     if rep["lut_cells"] else ""))
            kv = rep.get("kv")
            if kv:
                print(f"kv cache: {kv['bytes_per_token']} B/token "
                      f"({kv['capacity_multiplier']:.1f}x capacity vs "
                      f"compute-dtype pages)")
        if args.save_artifact:
            print(f"artifact -> {eng.save_artifact(args.save_artifact)}")

    rng = np.random.default_rng(0)
    # with prefix caching on, give the workload the shape the cache is for:
    # every request opens with the same "system prompt" prefix; the unique
    # tail is capped so shared + tail always fits --max-len
    shared = (rng.integers(0, cfg.vocab, min(48, args.max_len // 2))
              if args.prefix_cache else rng.integers(0, cfg.vocab, 0))
    tail_hi = max(5, min(32, args.max_len - len(shared) - 4))
    t0 = time.perf_counter()
    for uid in range(args.requests):
        tail = rng.integers(0, cfg.vocab, rng.integers(4, tail_hi))
        eng.submit(Request(uid=uid,
                           prompt=np.concatenate([shared, tail]),
                           max_new_tokens=args.max_new))
    done = eng.run()
    dt = time.perf_counter() - t0
    toks = sum(len(r.generated) for r in done.values())
    print(f"served {len(done)} requests / {toks} tokens in {dt:.1f}s "
          f"({toks/dt:.1f} tok/s)")
    sm = eng.metrics().get("spec")
    if sm:
        print(f"spec[{sm['provider']}] gamma={sm['gamma']} "
              f"acceptance={sm['acceptance_rate']:.2f} "
              f"draft_steps={sm['draft_steps']} "
              f"verify_steps={sm['verify_steps']} "
              f"disabled={sm['disabled_requests']}")
    km = eng.metrics().get("kv")
    if km and km["capacity_multiplier"] != 1.0:
        print(f"kv[{','.join(sorted(set(km['kv_dtypes'].values())))}] "
              f"{km['bytes_per_token']} B/token "
              f"capacity={km['capacity_multiplier']:.1f}x "
              f"pool={km['pool_bytes']/1e6:.1f}MB")
    pm = eng.metrics().get("prefix_cache")
    if pm:
        print(f"prefix-cache hit_rate={pm['hit_rate']:.2f} "
              f"cached_tokens={pm['cached_tokens']} "
              f"evictions={pm['evictions']} cow={pm['cow_copies']}")
    if args.hw_metrics:
        hm = eng.metrics().get("hw")
        if hm is None:
            print("hw: no DA cost model (float weights) — freeze with a DA "
                  "--quant mode or boot an --artifact")
        else:
            live = hm["live"]
            print(f"hw: {hm['pj_per_token']:.3e} pJ/token "
                  f"{hm['ns_per_token']:.3e} ns/token over "
                  f"{hm['layers']} DA layers; executed "
                  f"{live['da_pj']:.3e} pJ "
                  f"(bit-sliced would be {live['bitslice_pj']:.3e} pJ — "
                  f"x{live['energy_ratio']:.1f} energy, "
                  f"x{live['latency_ratio']:.2f} latency)")
            comp = hm["components"]
            print("hw components/token: "
                  + " ".join(f"{k}={v:.3e}" for k, v in comp.items()))
        if args.hw_metrics != "-":
            print(f"hw metrics -> {eng.write_hw_metrics(args.hw_metrics)}")
    if args.trace_out:
        print(f"trace -> {eng.write_trace(args.trace_out)} "
              f"({len(eng.obs.tracer)} events)")
    if args.metrics_out:
        print(f"metrics -> {eng.write_metrics(args.metrics_out)}")


if __name__ == "__main__":
    main()
