"""Speculative decoding over the paged serving runtime (DA-native drafts).

The paper's DA formulation is bit-serial: the VMM is a shift-and-add over
per-bit partial products, so truncating low-order input bit-planes yields a
cheap approximate forward pass from the *same* stored weight-sums — no
second model, no extra weight memory.  This package turns that structural
property into decode throughput: draft ``gamma`` tokens with a cheap pass,
verify them in ONE batched full-precision step through the paged runtime,
and keep the verified prefix (greedy acceptance makes the output
token-identical to non-speculative decoding).

Three draft providers behind one :class:`DraftProvider` protocol:

* ``bitplane``  — truncated-bitplane self-draft: the same frozen artifact
  evaluated at ``x_bits_eff`` of its ``x_bits`` bit-planes.
* ``layerskip`` — early-exit self-draft over the first ``draft_periods``
  period groups of the same weights.
* ``artifact``  — a second, smaller frozen ``DAArtifact`` sharing the
  vocabulary.

The scheduler side (draft/verify batching, acceptance EMA, auto-disable,
page checkpoint/rollback) lives in :mod:`repro.serve.scheduler`; this
package owns the draft/verify step builders and the acceptance math.
"""
from repro.spec.decode import (  # noqa: F401
    SpecConfig,
    breakeven_acceptance,
    greedy_accept,
    make_verify_step,
)
from repro.spec.providers import (  # noqa: F401
    ArtifactDraft,
    DraftProvider,
    LayerSkipDraft,
    TruncatedBitplaneDraft,
    make_provider,
)
