"""Logical-axis sharding rules (GSPMD) with divisibility fallback.

Activations and parameters are annotated with *logical* axis names; a rules
table maps logical names → mesh axes. A logical axis is only sharded when the
dimension is divisible by the mapped mesh-axis extent — otherwise it silently
falls back to replication (the safe default that keeps every (arch × shape)
cell compilable; e.g. 8 GQA kv-heads on a 16-way model axis replicate).

Usage::

    with use_mesh_rules(mesh, LM_RULES):
        y = constrain(y, ("batch", "seq", "ffn"))
"""
from __future__ import annotations

import contextlib
import threading
from typing import Mapping, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisName = Union[str, Tuple[str, ...], None]

# Default logical→mesh mapping for the LM family. "pod" exists only in the
# multi-pod mesh; missing mesh axes are dropped automatically.
LM_RULES: Mapping[str, AxisName] = {
    "batch": ("pod", "data"),
    "seq": None,               # sequence replicated in train fwd (SP optional)
    "seq_sp": ("data",),       # sequence-parallel variant (long prefill)
    "embed": None,
    "vocab": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": None,
    "ffn": ("model",),
    "expert": ("model",),
    "expert_ffn": None,
    "inner": ("model",),       # mamba inner channels
    "ssm_heads": ("model",),
    "state": None,
    "kv_seq": ("model",),      # decode KV-cache sequence axis (seq-parallel KV)
    # Paged KV pools ([pages, page_slot, kv_heads, head_dim]): like the dense
    # cache, the kv-heads axis is the tensor-parallel one (same mesh rules as
    # the packed DA params the attention weights shard by), pages replicate —
    # every device holds its head-slice of every page, so host page tables
    # stay device-agnostic integers.
    "page": None,
    "page_slot": None,
    "lut_addr": None,
    "groups": None,
    # DA-frozen weight artifacts (PackedWeights leaves wq/w_scale/luts):
    # output columns shard over the model axis — each device holds the PMAs
    # (codes + LUT slabs) for its slice of N, the tensor-parallel mapping.
    # The contraction dim stays replicated: DA groups contract locally.
    "da_in": None,
    "da_out": ("model",),
}

# FSDP/ZeRO-3-style 2-D weight sharding: the "embed" logical axis (the
# d_model dim of every weight and the fp32 optimizer mirrors) additionally
# shards over the data axes, so parameters + optimizer state scale with the
# FULL chip count instead of the model axis alone (a 398B model's fp32
# optimizer state does not fit 256 chips otherwise). GSPMD inserts the
# FSDP all-gather before each use automatically.
FSDP_RULES: Mapping[str, AxisName] = {**LM_RULES, "embed": ("data",)}

_LOCAL = threading.local()


def _active() -> Optional[Tuple[Mesh, Mapping[str, AxisName]]]:
    return getattr(_LOCAL, "mesh_rules", None)


@contextlib.contextmanager
def use_mesh_rules(mesh: Mesh, rules: Mapping[str, AxisName] = LM_RULES):
    prev = _active()
    _LOCAL.mesh_rules = (mesh, dict(rules))
    try:
        with mesh:
            yield
    finally:
        _LOCAL.mesh_rules = prev


def _resolve(logical: str, dim: int, mesh: Mesh, rules, used: set) -> AxisName:
    axes = rules.get(logical)
    if axes is None:
        return None
    if isinstance(axes, str):
        axes = (axes,)
    # Drop mesh axes that don't exist (e.g. "pod" on the single-pod mesh)
    # or are already consumed by another dimension of this tensor.
    axes = tuple(a for a in axes if a in mesh.shape and a not in used)
    if not axes:
        return None
    extent = 1
    for a in axes:
        extent *= mesh.shape[a]
    if dim % extent != 0:
        return None  # divisibility fallback → replicate
    used.update(axes)
    return axes if len(axes) > 1 else axes[0]


def pspec(logical_axes: Sequence[Optional[str]], shape: Sequence[int]) -> P:
    """PartitionSpec for the active mesh/rules; fully replicated if none.

    Dims are assigned right-to-left (minor dims get priority for the model
    axis — e.g. a KV cache [B, S, KV, hd] shards KV heads when divisible,
    else falls back to sequence-sharding S) and each mesh axis is used at
    most once per tensor."""
    act = _active()
    if act is None:
        return P()
    mesh, rules = act
    used: set = set()
    parts: list = [None] * len(logical_axes)
    for i in range(len(logical_axes) - 1, -1, -1):
        name, dim = logical_axes[i], shape[i]
        if name is not None:
            parts[i] = _resolve(name, dim, mesh, rules, used)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def constrain(x: jax.Array, logical_axes: Sequence[Optional[str]]) -> jax.Array:
    """with_sharding_constraint via logical names; no-op without a mesh."""
    act = _active()
    if act is None:
        return x
    mesh, _ = act
    assert len(logical_axes) == x.ndim, (logical_axes, x.shape)
    spec = pspec(logical_axes, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(logical_axes: Sequence[Optional[str]], shape) -> Optional[NamedSharding]:
    act = _active()
    if act is None:
        return None
    mesh, _ = act
    return NamedSharding(mesh, pspec(logical_axes, shape))


# ---------------------------------------------------------------------------
# DA artifact sharding: tensor-parallel the PMAs across the mesh
# ---------------------------------------------------------------------------

def da_leaf_axes(name: str, ndim: int) -> Optional[Tuple[Optional[str], ...]]:
    """Logical axes for a PackedWeights leaf by its stable pytree key name.

    Leading dims (period stacks [P, ...], expert stacks [E, ...]) replicate;
    the output-column dim maps to ``da_out`` (→ model axis) on every leaf so
    codes, scales and LUT slabs of one column slice land on the same device.
    Returns None for names that are not packed-artifact leaves.
    """
    if name == "wq" and ndim >= 2:
        return (None,) * (ndim - 2) + ("da_in", "da_out")
    if name == "w_scale" and ndim >= 2:
        return (None,) * (ndim - 1) + ("da_out",)
    if name == "luts" and ndim >= 3:
        return (None,) * (ndim - 3) + ("groups", "lut_addr", "da_out")
    return None


def paged_cache_axes(ndim: int) -> Tuple[Optional[str], ...]:
    """Logical axes for a PagedKVCache pool leaf: [..., pages, page_slot,
    kv_heads, head_dim] with leading period-stack dims replicated.

    Quantized-KV scale pools ([..., pages, page_slot, kv_heads, 1]) reuse
    these axes: the kv-heads slice follows its code pool to the same device,
    and the size-1 head_dim axis replicates via the divisibility fallback —
    no separate rule needed."""
    if ndim < 4:
        raise ValueError(f"paged pool leaves are >=4-D, got ndim={ndim}")
    return (None,) * (ndim - 4) + ("page", "page_slot", "kv_heads", "head_dim")


def shard_paged_caches(caches):
    """device_put every paged-pool leaf per the active mesh rules (no-op
    without a mesh) — the serving runtime's analogue of shard_frozen_params:
    the kv-heads slice of every page lands on the device holding the same
    head-slice of the packed attention PMAs, so gather-based reads stay
    local. Divisibility fallback applies (odd kv-head counts replicate)."""
    act = _active()
    if act is None:
        return caches

    def one(leaf):
        ns = named_sharding(paged_cache_axes(leaf.ndim), leaf.shape)
        return jax.device_put(leaf, ns) if ns is not None else leaf

    return jax.tree.map(one, caches)


def shard_frozen_params(params):
    """device_put every DA-packed leaf of a frozen tree per the active mesh
    rules (no-op without a mesh; non-packed leaves are left untouched).

    This is the post-load "shard" stage of the artifact pipeline: a model
    restored by ``load_artifact`` is host-resident and replicated; this
    places its PMAs tensor-parallel across the mesh like any other param —
    the divisibility fallback applies, so a column count that doesn't divide
    the model axis replicates instead of erroring.
    """
    act = _active()
    if act is None:
        return params
    from repro.core.engine import path_entry_name

    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        axes = da_leaf_axes(path_entry_name(path[-1]),
                            getattr(leaf, "ndim", 0))
        if axes is not None:
            ns = named_sharding(axes, leaf.shape)
            if ns is not None:
                leaf = jax.device_put(leaf, ns)
        out.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, out)
