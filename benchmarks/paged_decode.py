"""Paged-decode benchmark: gather read vs fused Pallas page-walk kernel.

    PYTHONPATH=src python benchmarks/paged_decode.py           # full
    PYTHONPATH=src python benchmarks/paged_decode.py --quick   # CI-sized

Writes ``artifacts/BENCH_paged_decode.json`` (override with ``--out``).

A decode-heavy continuous-batching workload (short prompts, long
generations) is run twice through the paged serving runtime — once with
``paged_attn="gather"`` (re-materialize the logical KV view with an XLA
gather every step) and once with ``paged_attn="fused"`` (the Pallas kernel
walks the page table in-kernel, one physical page per grid step).  Decoded
tokens are asserted identical between the two (the kernel is bit-exact
against the gather read at f32 softmax; a backend swap must never be a
behavior change).  Reported per configuration:

* ``tokens_per_s`` / ``wall_s`` — end-to-end decode throughput.
* ``gather_bytes`` — result bytes of the largest HLO gather in the compiled
  paged step (via ``launch.hlo_tools.ops_of_kind``): the gather path shows
  the full ``[B, W·ps, kv, hd]`` view per layer, the fused path must not.

On CPU hosts the fused kernel executes in Pallas interpreter mode, so the
throughput column is *not* a TPU speedup estimate there — the structural
``gather_bytes`` comparison is the portable signal this benchmark tracks.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

try:  # run as `python benchmarks/paged_decode.py` (script dir on path)
    from stamp import stamp_and_write
except ImportError:  # imported as a module from the repo root
    from benchmarks.stamp import stamp_and_write

from repro.configs.registry import ARCHS
from repro.core.da import DAConfig
from repro.core.freeze import freeze_model
from repro.launch.hlo_tools import ops_of_kind
from repro.models.model import init_model
from repro.serve.engine import Request, ServeEngine


def build_cfg():
    # same runtime-sized model as benchmarks/serve_throughput.py: this
    # instruments the per-step attention read, not BLAS time
    return dataclasses.replace(
        ARCHS["qwen3-8b"],
        name="qwen3-serve-bench",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab=4000,
        param_dtype="float32",
        compute_dtype="float32",
        remat=False,
        moe_dropless=True,
    )


def workload(cfg, n_requests, prompt_len, max_new):
    rng = np.random.default_rng(11)
    return [
        Request(uid=u, prompt=rng.integers(0, cfg.vocab, prompt_len),
                max_new_tokens=max_new)
        for u in range(n_requests)
    ]


def run_once(cfg, frozen, reqs, paged_attn, batch, max_len, page_size):
    eng = ServeEngine(cfg, frozen, batch_size=batch, max_len=max_len,
                      runtime="paged", page_size=page_size,
                      paged_attn=paged_attn)
    eng.warmup()
    # warm the host loop (uids far from the measured workload)
    rng = np.random.default_rng(9)
    for w in range(2):
        eng.submit(Request(uid=10_000 + w,
                           prompt=rng.integers(0, cfg.vocab, 6),
                           max_new_tokens=2))
    eng.run()

    t0 = time.perf_counter()
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    wall = time.perf_counter() - t0
    out_tokens = sum(len(done[r.uid].generated) for r in reqs)
    tokens = {r.uid: list(done[r.uid].generated) for r in reqs}
    return {
        "paged_attn": paged_attn,
        "requests": len(reqs),
        "wall_s": round(wall, 3),
        "out_tokens": out_tokens,
        "tokens_per_s": round(out_tokens / wall, 2),
    }, tokens


def step_gather_bytes(cfg, paged_attn, batch, max_len, page_size):
    """Largest HLO gather (result bytes) in the compiled decode step."""
    from repro.serve.kvcache import init_paged_caches, pages_for, table_width
    from repro.serve.scheduler import make_paged_step

    params = init_model(jax.random.key(0), cfg)
    w = table_width(max_len, page_size)
    n_pages = 1 + batch * pages_for(max_len, page_size)
    caches = init_paged_caches(cfg, n_pages, page_size, cfg.dtype())
    args = (
        params, caches,
        jnp.zeros((batch, 1), jnp.int32), jnp.zeros((batch, 1), jnp.int32),
        jnp.zeros((batch, w), jnp.int32), jnp.zeros((batch,), jnp.int32),
    )
    step = make_paged_step(dataclasses.replace(cfg, paged_attn=paged_attn))
    hlo = jax.jit(step).lower(*args).compile().as_text()
    gathers = ops_of_kind(hlo, "gather")
    return max((b for _, b in gathers), default=0)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="CI-sized run")
    ap.add_argument("--out", default="artifacts/BENCH_paged_decode.json")
    args = ap.parse_args()

    cfg = build_cfg()
    params = init_model(jax.random.key(0), cfg)
    art = freeze_model(params, DAConfig(x_signed=True), mode="auto",
                       m_hint=8, model_cfg=cfg, pin_modes=False)
    del params

    n_requests = 4 if args.quick else 12
    prompt_len = 12
    max_new = 8 if args.quick else 48
    batch, max_len, page_size = 4, 128, 16

    results, tokens, gather_bytes = {}, {}, {}
    for mode in ("gather", "fused"):
        # fresh Request objects per mode: generated/timing state is mutable
        reqs = workload(cfg, n_requests, prompt_len, max_new)
        results[mode], tokens[mode] = run_once(
            cfg, art.params, reqs, mode, batch, max_len, page_size)
        gather_bytes[mode] = step_gather_bytes(
            cfg, mode, batch, max_len, page_size)
        results[mode]["gather_bytes"] = gather_bytes[mode]
        print(f"paged_attn={mode}: {results[mode]}")
    assert tokens["fused"] == tokens["gather"], \
        "fused paged attention changed decoded tokens — correctness bug"
    assert gather_bytes["fused"] < gather_bytes["gather"], \
        "fused step still contains the full-page-table KV gather"

    result = {
        "bench": "paged_decode",
        "model": cfg.name,
        "da_mode": "auto",
        "quick": args.quick,
        "interpret_mode": jax.default_backend() != "tpu",
        "workload": {"requests": n_requests, "prompt_tokens": prompt_len,
                     "max_new": max_new, "batch": batch,
                     "page_size": page_size, "max_len": max_len},
        "gather": results["gather"],
        "fused": results["fused"],
        "decode_speedup": round(
            results["gather"]["wall_s"]
            / max(results["fused"]["wall_s"], 1e-9), 2),
        "gather_bytes_removed": gather_bytes["gather"] - gather_bytes["fused"],
        "tokens_identical": True,
    }
    stamp_and_write(args.out, result, seed=11)
    print(f"decode speedup (fused vs gather): {result['decode_speedup']}x, "
          f"HLO gather bytes removed: {result['gather_bytes_removed']}")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
