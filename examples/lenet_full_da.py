"""Full LeNet-5 inference, every layer through the DA in-memory engine.

The paper evaluates CONV1 and notes that "the inference of any Neural
Network can be executed efficiently as a series of VMM operations" (§II-B).
This example completes that claim: all five weight layers of LeNet-5
(conv1 → pool → conv2 → pool → fc1 → fc2 → fc3) run as DA VMMs
(im2col for convs), bit-exact against the integer reference at every layer,
with per-layer hardware-model cost and the whole-network totals.

Run: PYTHONPATH=src python examples/lenet_full_da.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core.da import DAConfig
from repro.core.engine import da_vmm, pack_quantized
from repro.core.hwmodel import BitSliceDesign, DADesign
from repro.core.quant import quantize_weights


def im2col(x: np.ndarray, kh: int, kw: int) -> np.ndarray:
    """x: [C, H, W] → patches [OH·OW, C·kh·kw]."""
    c, h, w = x.shape
    oh, ow = h - kh + 1, w - kw + 1
    cols = np.empty((oh * ow, c * kh * kw), dtype=x.dtype)
    idx = 0
    for i in range(oh):
        for j in range(ow):
            cols[idx] = x[:, i : i + kh, j : j + kw].reshape(-1)
            idx += 1
    return cols


def avg_pool2(x: np.ndarray) -> np.ndarray:
    """x: [C, H, W] → 2×2 average pool (LeNet subsampling), integer-floored."""
    c, h, w = x.shape
    x = x[:, : h // 2 * 2, : w // 2 * 2]
    return (
        x.reshape(c, h // 2, 2, w // 2, 2).sum(axis=(2, 4)) // 4
    )


def da_layer(x_int: np.ndarray, w_float: np.ndarray, name: str,
             unsigned: bool, stats: list) -> np.ndarray:
    """One VMM layer through the faithful LUT datapath; returns int32 acc.

    x_int: [M, K] integer activations; w_float: [K, N] trained weights.
    """
    wq = quantize_weights(jnp.asarray(w_float))
    bits_in = 8
    cfg = DAConfig(group_size=8, x_bits=bits_in, x_signed=not unsigned)
    packed = pack_quantized(wq.q, wq.scale, cfg=cfg)  # pre-VMM, LUTs once
    # re-quantize activations to 8 bits (the inter-layer requantization any
    # integer pipeline performs; inputs are unsigned after ReLU / images)
    amax = max(1, int(np.abs(x_int).max()))
    qmax = (1 << bits_in) - 1 if unsigned else (1 << (bits_in - 1)) - 1
    xq = np.clip((x_int.astype(np.float64) * qmax / amax).round(),
                 0 if unsigned else -qmax - 1, qmax).astype(np.int32)
    acc = np.asarray(da_vmm(jnp.asarray(xq), packed, mode="lut"))
    # exactness vs direct integer matmul
    assert (acc == xq @ np.asarray(wq.q)).all(), name

    k, n = w_float.shape
    d = DADesign(k=k, n=n, adder_topology="tree" if k > 32 else "chain")
    b = BitSliceDesign(k=k, n=n)
    n_vmm = x_int.shape[0]
    stats.append((name, f"{k}x{n}", n_vmm,
                  n_vmm * d.latency_ns() * 1e-3,
                  n_vmm * d.energy_vmm_j() * 1e9,
                  n_vmm * b.latency_ns() * 1e-3,
                  n_vmm * b.energy_vmm_j() * 1e9))
    return acc


def main():
    rng = np.random.default_rng(0)
    img = rng.integers(0, 256, (1, 32, 32)).astype(np.int32)

    # LeNet-5 weights (random stand-ins with the published shapes; the
    # datapath exactness does not depend on the values)
    w_conv1 = rng.normal(size=(6, 1 * 5 * 5)).astype(np.float32).T      # 25×6
    w_conv2 = rng.normal(size=(16, 6 * 5 * 5)).astype(np.float32).T     # 150×16
    w_fc1 = rng.normal(size=(16 * 5 * 5, 120)).astype(np.float32)       # 400×120
    w_fc2 = rng.normal(size=(120, 84)).astype(np.float32)
    w_fc3 = rng.normal(size=(84, 10)).astype(np.float32)

    stats: list = []
    relu = lambda a: np.maximum(a, 0)

    # conv1: 784 VMMs of 1×25 · 25×6 (the paper's workload)
    y = da_layer(im2col(img, 5, 5), w_conv1, "conv1", True, stats)
    y = relu(y).T.reshape(6, 28, 28)
    y = avg_pool2(y)                                  # 6×14×14
    # conv2: 100 VMMs of 1×150 · 150×16
    y = da_layer(im2col(y, 5, 5), w_conv2, "conv2", True, stats)
    y = relu(y).T.reshape(16, 10, 10)
    y = avg_pool2(y)                                  # 16×5×5
    # fc layers: single VMMs
    y = da_layer(y.reshape(1, -1), w_fc1, "fc1", True, stats)
    y = da_layer(relu(y), w_fc2, "fc2", True, stats)
    logits = da_layer(relu(y), w_fc3, "fc3", True, stats)

    print("full LeNet-5 through DA: every layer bit-exact ✓")
    print(f"prediction: class {int(np.argmax(logits))}\n")
    print(f"{'layer':6s} {'KxN':9s} {'VMMs':>5s} "
          f"{'DA us':>9s} {'DA nJ':>10s} {'BS us':>9s} {'BS nJ':>10s}")
    tot = np.zeros(4)
    for name, kn, n, da_us, da_nj, bs_us, bs_nj in stats:
        print(f"{name:6s} {kn:9s} {n:5d} {da_us:9.1f} {da_nj:10.1f} "
              f"{bs_us:9.1f} {bs_nj:10.1f}")
        tot += (da_us, da_nj, bs_us, bs_nj)
    print(f"{'TOTAL':6s} {'':9s} {'':5s} {tot[0]:9.1f} {tot[1]:10.1f} "
          f"{tot[2]:9.1f} {tot[3]:10.1f}")
    print(f"\nwhole-network: DA is {tot[2]/tot[0]:.1f}x faster, "
          f"{tot[3]/tot[1]:.1f}x more energy-efficient than bit-slicing "
          f"(tree-adder PMAs for K>32, ADC-resolution-scaled baseline)")


if __name__ == "__main__":
    main()
