"""HLO-text diagnostics for the §Perf loop: where do the bytes/collectives go?

The parser moved to :mod:`repro.analysis.hlo` (hardened against multi-line
op definitions, nested tuple types, layout tiles and region syntax — and
unit-tested there); this module re-exports the same API so launch-side
callers and older scripts keep working unchanged.
"""
from __future__ import annotations

from repro.analysis.hlo import (  # noqa: F401
    HloOp,
    bytes_by_op_kind,
    iter_ops,
    op_kinds,
    ops_of_kind,
    shape_bytes,
    top_collectives,
    top_ops,
)

__all__ = [
    "HloOp",
    "bytes_by_op_kind",
    "iter_ops",
    "op_kinds",
    "ops_of_kind",
    "shape_bytes",
    "top_collectives",
    "top_ops",
]
