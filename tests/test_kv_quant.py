"""Quantized paged KV cache: numerics, backend bit-parity, pool-op
transparency (scales ride inside the page), artifact plumbing, metrics.

The invariants under test:

* fused Pallas kernel == XLA gather read, bit-for-bit, on int8 AND int4
  pools (the same parity the fp tests assert — dequant is one shared
  elementwise formula, applied in-register by the kernel);
* every pool operation (copy_page COW, defrag remap, spec rollback,
  prefix-trie sharing) moves/shares the in-page scales together with the
  codes — no dequant round-trips, no scale drift;
* the fp16 escape hatch is byte-for-byte today's cache layout;
* artifacts record the KV precision and ``from_artifact`` refuses to
  silently flatten a per-layer plan.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS, reduce_for_smoke
from repro.core.da import DAConfig
from repro.core.freeze import da_memory_report, freeze_model, load_artifact, \
    save_artifact
from repro.kernels.paged_attention import paged_attention
from repro.models import kv_quant as kvq
from repro.models.attention import PagedKVCache, paged_gather_read
from repro.models.model import init_model
from repro.serve.engine import Request, ServeEngine
from repro.serve.kvcache import (
    PagePool,
    copy_page,
    defrag,
    init_paged_caches,
    kv_page_bytes,
    kv_token_bytes,
    resolve_kv_dtypes,
)
from repro.spec import SpecConfig

KEY = jax.random.key(0)
MAX_NEW = 4


def _smoke_cfg(**kw):
    return dataclasses.replace(reduce_for_smoke(ARCHS["qwen3-8b"]),
                               moe_dropless=True, **kw)


# ---------------------------------------------------------------------------
# numerics: pack/unpack exactness, quantization error bound
# ---------------------------------------------------------------------------


def test_int4_pack_unpack_roundtrip_exact():
    """Every nibble value in [-7, 7], both lanes: pack∘unpack is identity
    (integers are exact — backend bit-parity rests on this)."""
    lo, hi = np.meshgrid(np.arange(-7, 8), np.arange(-7, 8))
    codes = jnp.asarray(np.stack([lo.ravel(), hi.ravel()], -1), jnp.int8)
    packed = kvq.pack_int4(codes)
    assert packed.shape == codes.shape[:-1] + (1,)
    np.testing.assert_array_equal(np.asarray(kvq.unpack_int4(packed)),
                                  np.asarray(codes))


@pytest.mark.parametrize("fmt", ["int8", "int4"])
def test_quantize_error_bounded_by_half_scale(fmt, rng):
    x = jnp.asarray(rng.normal(size=(5, 3, 2, 8)) * 10, jnp.float32)
    codes, scale = kvq.quantize_kv(x, fmt)
    assert codes.dtype == jnp.int8 and scale.dtype == kvq.KV_SCALE_DTYPE
    assert scale.shape == x.shape[:-1] + (1,)
    deq = kvq.dequantize_kv(codes, scale, fmt, jnp.float32)
    # symmetric rounding: |deq - x| <= scale/2 elementwise (plus fp16
    # rounding of the scale itself, covered by the 1.01 slack)
    bound = np.asarray(scale.astype(jnp.float32)) * 0.505
    assert np.all(np.abs(np.asarray(deq) - np.asarray(x)) <= bound)


def test_quantize_all_zero_rows_are_exact():
    x = jnp.zeros((2, 4, 2, 8), jnp.float32)
    for fmt in ("int8", "int4"):
        codes, scale = kvq.quantize_kv(x, fmt)
        assert not np.any(np.asarray(codes)) and not np.any(np.asarray(scale))
        np.testing.assert_array_equal(
            np.asarray(kvq.dequantize_kv(codes, scale, fmt, jnp.float32)),
            np.asarray(x))


def test_kv_format_inference_and_mismatch():
    k8 = jnp.zeros((4, 2, 2, 16), jnp.int8)
    k4 = jnp.zeros((4, 2, 2, 8), jnp.int8)
    s = jnp.zeros((4, 2, 2, 1), jnp.float16)
    assert kvq.kv_format(k8, None, 16) == "fp"
    assert kvq.kv_format(k8, s, 16) == "int8"
    assert kvq.kv_format(k4, s, 16) == "int4"
    with pytest.raises(ValueError, match="neither int8"):
        kvq.kv_format(jnp.zeros((4, 2, 2, 5), jnp.int8), s, 16)


# ---------------------------------------------------------------------------
# backend bit-parity on quantized pools (the PR-6 guarantee, extended)
# ---------------------------------------------------------------------------


def _quantized_paged_case(rng, fmt, t, lens, ps=8, n_pages=12):
    from test_paged_attention import _random_paged_case

    q, ck, cv, tbl, tpos = _random_paged_case(rng, jnp.float32, t, lens,
                                              ps=ps, n_pages=n_pages)
    kc, ks = kvq.quantize_kv(ck, fmt)
    vc, vs = kvq.quantize_kv(cv, fmt)
    return q, kc, ks, vc, vs, tbl, tpos


@pytest.mark.parametrize("fmt", ["int8", "int4"])
@pytest.mark.parametrize("t", [1, 4])
def test_fused_bitwise_equals_gather_quantized(fmt, t):
    """Fused kernel == gather read bit-for-bit on quantized pools: the scale
    pages ride the same scalar-prefetch page walk and dequantization uses
    the gather path's exact elementwise formula."""
    rng = np.random.default_rng(0)
    ps = 8
    q, kc, ks, vc, vs, tbl, tpos = _quantized_paged_case(
        rng, fmt, t, lens=[ps - 1, ps, 2 * ps + 3], ps=ps)
    ref = paged_gather_read(q, kc, vc, tbl, tpos, k_scale=ks, v_scale=vs)
    out = paged_attention(q, kc, vc, tbl, tpos, k_scale=ks, v_scale=vs)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(out))


def test_fused_quantized_ignores_unreferenced_pages():
    """NaN-poisoning the SCALES (not just the codes) of pages no table names
    must not change the fused output — the walk DMAs neither."""
    rng = np.random.default_rng(2)
    q, kc, ks, vc, vs, tbl, tpos = _quantized_paged_case(
        rng, "int8", 1, lens=[9, 17], ps=8)
    named = set(np.asarray(tbl).ravel().tolist())
    unwalked = jnp.asarray(
        [p for p in range(kc.shape[0]) if p not in named])
    out = paged_attention(q, kc, vc, tbl, tpos, k_scale=ks, v_scale=vs)
    poisoned = paged_attention(
        q, kc.at[unwalked].set(127), vc.at[unwalked].set(-127),
        tbl, tpos,
        k_scale=ks.at[unwalked].set(jnp.nan),
        v_scale=vs.at[unwalked].set(jnp.nan))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(poisoned))


# ---------------------------------------------------------------------------
# cache layout: zeros(), validation, fp16 escape hatch
# ---------------------------------------------------------------------------


def test_zeros_fp16_escape_hatch_is_todays_layout():
    cfg = _smoke_cfg()
    plain = PagedKVCache.zeros(cfg, 6, 4, jnp.float32)
    hatch = PagedKVCache.zeros(cfg, 6, 4, jnp.float32, kv_dtype="fp16")
    assert hatch.k_scale is None and hatch.v_scale is None
    assert hatch.k.shape == plain.k.shape and hatch.k.dtype == plain.k.dtype
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b),
                 plain, hatch)


@pytest.mark.parametrize("fmt", ["int8", "int4"])
def test_zeros_quantized_layout(fmt):
    cfg = _smoke_cfg()
    hd = cfg.head_dim_
    c = PagedKVCache.zeros(cfg, 6, 4, jnp.float32, kv_dtype=fmt)
    hd_p = hd // 2 if fmt == "int4" else hd
    assert c.k.shape == (6, 4, cfg.n_kv_heads, hd_p)
    assert c.k.dtype == jnp.int8
    assert c.k_scale.shape == (6, 4, cfg.n_kv_heads, 1)
    assert c.k_scale.dtype == kvq.KV_SCALE_DTYPE


def test_init_paged_caches_validation_is_loud():
    cfg = _smoke_cfg()
    with pytest.raises(ValueError, match="unknown kv_dtype"):
        init_paged_caches(cfg, 6, 4, jnp.float32, kv_dtypes="int2")
    odd = dataclasses.replace(cfg, head_dim=cfg.head_dim_ + 1)
    with pytest.raises(ValueError, match="even head_dim"):
        init_paged_caches(odd, 6, 4, jnp.float32, kv_dtypes="int4")
    with pytest.raises(ValueError, match="outside this model's period"):
        resolve_kv_dtypes(cfg, {"pos_99": "int8"})
    # per-pos dict: named positions override, the rest follow cfg.kv_dtype
    mixed = resolve_kv_dtypes(dataclasses.replace(cfg, kv_dtype="int8"),
                              {"pos_0": "fp16"})
    assert mixed["pos_0"] == "fp16"
    assert all(v == "int8" for k, v in mixed.items() if k != "pos_0")


def test_byte_accounting_matches_device_arrays():
    cfg = _smoke_cfg()
    hd, kv = cfg.head_dim_, cfg.n_kv_heads
    # fp: 2 tensors * kv * hd * itemsize; int8: codes + 2B scale per head
    assert kv_token_bytes(cfg, "fp16", dtype=jnp.float32) == 2 * kv * hd * 4
    assert kv_token_bytes(cfg, "int8") == 2 * kv * (hd + 2)
    assert kv_token_bytes(cfg, "int4") == 2 * kv * (hd // 2 + 2)
    caches = init_paged_caches(cfg, 6, 4, jnp.float32, kv_dtypes="int8")
    got = sum(leaf.size * leaf.dtype.itemsize
              for leaf in jax.tree.leaves(caches))
    assert got == 6 * kv_page_bytes(cfg, 4, "int8")


def test_pool_stats_price_pages_in_bytes():
    pool = PagePool(8, page_bytes=1000)
    pool.alloc(3)
    s = pool.stats()
    assert s["page_bytes"] == 1000
    assert s["pool_bytes"] == 8000
    assert s["used_bytes"] == 3000
    assert s["free_bytes"] == 4000  # page 0 is reserved, not free


# ---------------------------------------------------------------------------
# pool-op transparency: scales move/share with values, no dequant round-trip
# ---------------------------------------------------------------------------


def _written_quant_pool(cfg, n_pages, ps, pages):
    """Quantized pool with recognizable rows on ``pages`` (codes AND scales
    vary per page), junk elsewhere."""
    rng = np.random.default_rng(3)
    caches = init_paged_caches(cfg, n_pages, ps, jnp.float32,
                               kv_dtypes="int8")
    c = caches["pos_0"]
    rows = jnp.asarray(
        rng.normal(size=(len(pages), ps, cfg.n_kv_heads, cfg.head_dim_))
        * np.arange(1, len(pages) + 1)[:, None, None, None], jnp.float32)
    codes, scale = kvq.quantize_kv(rows, "int8")
    idx = jnp.asarray(pages)
    c = PagedKVCache(
        k=c.k.at[:, idx].set(codes), v=c.v.at[:, idx].set(-codes),
        k_scale=c.k_scale.at[:, idx].set(scale),
        v_scale=c.v_scale.at[:, idx].set(scale * 2))
    return {"pos_0": c}


def test_copy_page_moves_scales_with_codes():
    cfg = _smoke_cfg()
    caches = _written_quant_pool(cfg, 8, 4, pages=[3])
    out = copy_page(caches, src=3, dst=5)["pos_0"]
    for leaf_src, leaf_dst in ((out.k[:, 3], out.k[:, 5]),
                               (out.k_scale[:, 3], out.k_scale[:, 5]),
                               (out.v_scale[:, 3], out.v_scale[:, 5])):
        np.testing.assert_array_equal(np.asarray(leaf_src),
                                      np.asarray(leaf_dst))


def test_defrag_remaps_scales_with_codes_and_poisons_nothing_live():
    """Defrag on a quantized pool: dequantized content of every live page is
    bit-identical after compaction (codes and scales moved together), even
    with vacated source pages NaN/junk-poisoned afterwards."""
    cfg = _smoke_cfg()
    n_pages, ps = 9, 4
    pool = PagePool(n_pages)
    allocated = pool.alloc(8)
    tables = [[5, 2], [7]]
    pool.free([p for p in allocated if p not in {5, 2, 7}])
    caches = _written_quant_pool(cfg, n_pages, ps, pages=[5, 2, 7])

    def dequant_rows(caches, tables):
        c = caches["pos_0"]
        out = []
        for t in tables:
            idx = jnp.asarray(t)
            out.append(np.asarray(kvq.dequantize_kv(
                c.k[:, idx], c.k_scale[:, idx], "int8", jnp.float32)))
        return out

    before = dequant_rows(caches, tables)
    caches = defrag(caches, pool, tables)
    assert sorted(p for t in tables for p in t) == [1, 2, 3]
    # poison everything defrag vacated: live content must not reference it
    c = caches["pos_0"]
    vacated = jnp.asarray([p for p in range(4, n_pages)])
    caches = {"pos_0": PagedKVCache(
        k=c.k.at[:, vacated].set(127), v=c.v.at[:, vacated].set(127),
        k_scale=c.k_scale.at[:, vacated].set(jnp.nan),
        v_scale=c.v_scale.at[:, vacated].set(jnp.nan))}
    for b, a in zip(before, dequant_rows(caches, tables)):
        np.testing.assert_array_equal(b, a)


# ---------------------------------------------------------------------------
# serving end-to-end: token identity across cache-sharing features at int8
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def served():
    cfg = _smoke_cfg()
    params = init_model(KEY, cfg)
    rng = np.random.default_rng(7)
    shared = rng.integers(0, cfg.vocab, 8)
    prompts = {uid: np.concatenate([shared,
                                    rng.integers(0, cfg.vocab, 2 + uid)])
               for uid in range(4)}
    return cfg, params, prompts


def _serve(cfg, params, prompts, **kw):
    eng = ServeEngine(cfg, params, batch_size=2, max_len=32, page_size=4,
                      **kw)
    for uid, pr in prompts.items():
        eng.submit(Request(uid=uid, prompt=pr, max_new_tokens=MAX_NEW))
    done = eng.run()
    return {u: r.generated for u, r in done.items()}, eng.metrics()


def test_prefix_cache_token_identity_on_quantized_pages(served):
    """Trie sharing + COW forks on int8 pages: caching on == caching off
    (deterministic write-once quantization — a shared page's codes and
    scales are exactly what un-shared prefill would have written)."""
    cfg, params, prompts = served
    base, mb = _serve(cfg, params, prompts, kv_dtype="int8")
    out, m = _serve(cfg, params, prompts, kv_dtype="int8", prefix_cache=True)
    assert out == base
    assert m["prefix_cache"]["hits"] > 0  # sharing actually happened
    assert mb["kv"]["kv_dtypes"]["pos_0"] == "int8"


def test_spec_rollback_token_identity_on_quantized_pages(served):
    """Speculative decoding over int8 pages: rejected draft rows roll back
    by page bookkeeping alone (write-once scales leave no numeric trace) —
    greedy output is exactly the non-speculative stream, zero pages leak."""
    cfg, params, prompts = served
    art = freeze_model(params, DAConfig(x_signed=True),
                       mode="bitplane_stacked", model_cfg=cfg)
    spec = SpecConfig(provider="bitplane", gamma=2, draft_x_bits=6,
                      disable_below=0.0)
    base, _ = _serve(cfg, art.params, prompts, kv_dtype="int8")
    out, m = _serve(cfg, art.params, prompts, kv_dtype="int8", spec=spec)
    assert out == base
    assert m["spec"]["rounds"] > 0
    assert m["pool"]["used_pages"] == 0


def test_metrics_kv_block(served):
    cfg, params, prompts = served
    _, m = _serve(cfg, params, prompts, kv_dtype="int4")
    kv = m["kv"]
    assert set(kv["kv_dtypes"].values()) == {"int4"}
    assert kv["bytes_per_token"] == cfg.n_periods * kv_token_bytes(cfg,
                                                                   "int4")
    assert kv["capacity_multiplier"] > 1.8
    assert m["pool"]["pool_bytes"] == \
        m["pool"]["n_pages"] * m["pool"]["page_bytes"]
    # fp16 engines report the multiplier as exactly 1
    _, m0 = _serve(cfg, params, prompts)
    assert m0["kv"]["capacity_multiplier"] == 1.0


# ---------------------------------------------------------------------------
# artifact plumbing: plans record KV precision, loaders can't mismatch it
# ---------------------------------------------------------------------------


def test_artifact_records_and_restores_kv_dtype(served, tmp_path):
    cfg, params, prompts = served
    eng = ServeEngine(cfg, params, batch_size=2, max_len=32, page_size=4,
                      da_mode="bitplane_stacked", kv_dtype="int8")
    path = str(tmp_path / "art_int8")
    eng.save_artifact(path)
    art = load_artifact(path)
    assert art.model_cfg.kv_dtype == "int8"
    wk_plans = {k: p for k, p in art.plan.items() if k.endswith("/wk")}
    assert wk_plans and all(p.kv_dtype == "int8" for p in wk_plans.values())
    # non-KV leaves carry no kv dtype
    assert all(p.kv_dtype is None for k, p in art.plan.items()
               if k.endswith("/wq"))
    booted = ServeEngine.from_artifact(path, batch_size=2, max_len=32,
                                       page_size=4)
    assert booted._rt.kv_dtypes["pos_0"] == "int8"


def test_from_artifact_refuses_to_flatten_heterogeneous_plan(tmp_path):
    # a 2-position period (both attention mixers) via MoE cadence, so the
    # plan can carry two different KV dtypes
    cfg = dataclasses.replace(reduce_for_smoke(ARCHS["qwen2-moe-a2.7b"]),
                              moe_dropless=True, moe_period=2, d_ff=64)
    assert cfg.period == 2 and cfg.n_layers % 2 == 0
    params = init_model(KEY, cfg)
    art = freeze_model(params, DAConfig(x_signed=True),
                       mode="bitplane_stacked", model_cfg=cfg,
                       kv_dtype_overrides={"pos_1": "int8"})
    path = str(tmp_path / "art_mixed")
    save_artifact(path, art)
    with pytest.raises(ValueError, match="silently flatten"):
        ServeEngine.from_artifact(path, batch_size=2, max_len=32,
                                  page_size=4, kv_dtype="int8")
    # without the override the per-layer plan boots as frozen
    eng = ServeEngine.from_artifact(path, batch_size=2, max_len=32,
                                    page_size=4)
    assert eng._rt.kv_dtypes == {"pos_0": "fp16", "pos_1": "int8"}


def test_da_memory_report_prices_kv_beside_weights(served):
    cfg, params, prompts = served
    art = freeze_model(params, DAConfig(x_signed=True),
                       mode="bitplane_stacked", model_cfg=cfg)
    rep = da_memory_report(art.params,
                           model_cfg=dataclasses.replace(cfg,
                                                         kv_dtype="int8"))
    kv = rep["kv"]
    assert kv["kv_dtypes"]["pos_0"] == "int8"
    assert kv["bytes_per_token"] == cfg.n_periods * kv_token_bytes(cfg,
                                                                   "int8")
    assert kv["capacity_multiplier"] > 1.0
