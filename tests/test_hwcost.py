"""Hardware cost observability: the per-layer cost table agrees exactly with
the calibrated hwmodel (Table I untouched), truncated-bitplane repricing is
exactly linear in the evaluated planes, the table round-trips through the
artifact manifest, and — the serving acceptance property — the scheduler's
attributed energy sums EXACTLY to per-layer pJ × executed tokens."""
import dataclasses
import json

import jax
import numpy as np
import pytest

from repro.configs.registry import ARCHS, reduce_for_smoke
from repro.core.da import DAConfig
from repro.core.freeze import freeze_model, load_artifact, save_artifact
from repro.core.hwmodel import BitSliceDesign, DADesign, PJ
from repro.models.model import init_model
from repro.obs import check as obs_check
from repro.obs import regress as obs_regress
from repro.obs.export import validate_chrome_trace, validate_metrics_json
from repro.obs.hwcost import (
    HWCOST_VERSION,
    HardwareCostModel,
    LayerGeom,
    draft_price,
)
from repro.obs.metrics import METRICS_SCHEMA_VERSION
from repro.serve.engine import Request, ServeEngine
from repro.spec import SpecConfig

CONV1 = [("conv1", 25, 6)]
MAX_NEW = 4


# ---------------------------------------------------------------------------
# cost table vs the calibrated hwmodel (pure)
# ---------------------------------------------------------------------------
def test_conv1_matches_table1_exactly():
    """The table prices the paper's design point identically to the
    calibration tests in test_hwmodel — same model, lifted, not re-derived."""
    hw = HardwareCostModel.from_shapes(CONV1)
    assert hw.pj_per_token() == pytest.approx(110.2, rel=1e-6)
    assert hw.ns_per_token() == pytest.approx(88.0)
    assert hw.bitslice_pj_per_token() == pytest.approx(1421.5, rel=1e-6)
    assert hw.bitslice_ns_per_token() == pytest.approx(400.0)
    r = hw.ratios()
    assert r["energy"] == pytest.approx(1421.5 / 110.2, rel=1e-6)
    assert r["latency"] == pytest.approx(400.0 / 88.0, rel=1e-6)
    # the acceptance headline: ≥10× energy on CONV1-class geometry
    assert r["energy"] > 10.0


def test_components_sum_to_total_exactly():
    hw = HardwareCostModel.from_shapes(CONV1)
    assert sum(hw.components().values()) == hw.pj_per_token()
    assert sum(hw.bitslice_components().values()) == \
        hw.bitslice_pj_per_token()
    # and the component split is the hwmodel's own, in pJ
    d = DADesign(k=25, n=6)
    for key, joules in d.energy_components_j().items():
        assert hw.components()[f"{key}_pj"] == pytest.approx(joules / PJ)
    b = BitSliceDesign(k=25, n=6)
    for key, joules in b.energy_components_j().items():
        assert hw.bitslice_components()[f"{key}_pj"] == \
            pytest.approx(joules / PJ)


def test_vmms_per_token_stacks_linearly():
    one = HardwareCostModel.from_shapes(CONV1)
    three = HardwareCostModel.from_shapes([("conv1", 25, 6, 3)])
    assert three.pj_per_token() == pytest.approx(3 * one.pj_per_token())
    assert three.ns_per_token() == pytest.approx(3 * one.ns_per_token())
    row = three.layer_table()[0]
    assert row["vmms_per_token"] == 3
    assert row["memory_cells"] == 3 * one.layer_table()[0]["memory_cells"]


def test_x_bits_eff_exactly_linear():
    """A truncated-bitplane pass runs the SAME circuits for fewer bit-serial
    cycles: energy scales by eff/x_bits EXACTLY on every component, and
    latency drops by the skipped read cycles (CONV1: 15 + 3·10 + 3)."""
    hw = HardwareCostModel.from_shapes(CONV1)
    assert hw.pj_per_token(x_bits_eff=4) == 0.5 * hw.pj_per_token()
    for key, full in hw.components().items():
        assert hw.components(x_bits_eff=4)[key] == 0.5 * full
    assert hw.ns_per_token(x_bits_eff=4) == pytest.approx(48.0)
    # the counterfactual scales too (fewer DAC/input cycles) — the live
    # energy ratio is therefore invariant under draft truncation
    assert hw.bitslice_pj_per_token(x_bits_eff=4) == \
        0.5 * hw.bitslice_pj_per_token()
    assert hw.ratios(x_bits_eff=4)["energy"] == \
        pytest.approx(hw.ratios()["energy"])
    # clamped to [1, x_bits]
    assert hw.pj_per_token(x_bits_eff=99) == hw.pj_per_token()
    assert hw.pj_per_token(x_bits_eff=0) == hw.pj_per_token(x_bits_eff=1)


def test_json_roundtrip_and_version_gate():
    hw = HardwareCostModel.from_shapes(
        [("a", 25, 6), {"path": "b", "k": 64, "n": 32, "vmms_per_token": 2}])
    again = HardwareCostModel.from_json(hw.to_json())
    assert again == hw
    assert again.summary() == hw.summary()
    newer = {"hwcost_version": HWCOST_VERSION + 1, "layers": []}
    with pytest.raises(ValueError):
        HardwareCostModel.from_json(newer)
    assert not HardwareCostModel([])  # empty is falsy → "no cost model"


# ---------------------------------------------------------------------------
# frozen-model construction + artifact round-trip
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def frozen():
    cfg = dataclasses.replace(reduce_for_smoke(ARCHS["qwen3-8b"]),
                              moe_dropless=True)
    params = init_model(jax.random.key(0), cfg)
    art = freeze_model(params, DAConfig(x_signed=True), mode="bitplane",
                       model_cfg=cfg)
    return cfg, art


def test_from_frozen_geometry(frozen):
    cfg, art = frozen
    hw = art.hwcost
    assert hw and len(hw.layers) > 0
    for g in hw.layers:
        assert g.k > 0 and g.n > 0 and g.vmms_per_token >= 1
    # stacked period leaves fold their leading dims into vmms_per_token
    by_path = {g.path: g for g in hw.layers}
    assert any(g.vmms_per_token > 1 for g in hw.layers) or \
        all("periods" not in p for p in by_path)
    # the artifact's table is exactly what from_frozen rebuilds
    assert HardwareCostModel.from_frozen(art.params, art.plan) == hw


def test_artifact_roundtrip_and_pre_hwcost_backcompat(frozen, tmp_path):
    cfg, art = frozen
    d = str(tmp_path / "art")
    save_artifact(d, art)
    loaded = load_artifact(d)
    assert loaded.hwcost == art.hwcost
    # a pre-hwcost artifact (older writer) rebuilds the table from the
    # packed leaves on load — same geometry, same costs
    mpath = tmp_path / "art" / "manifest.json"
    manifest = json.loads(mpath.read_text())
    assert "hwcost" in manifest
    del manifest["hwcost"]
    mpath.write_text(json.dumps(manifest))
    old = load_artifact(d)
    assert old.hwcost == art.hwcost


def test_draft_price_truncated_bitplane(frozen):
    cfg, art = frozen
    hw = art.hwcost

    class P:  # the bitplane provider's cost-relevant surface
        x_bits_eff = 4

    dp = draft_price(hw, P())
    assert dp["x_bits_eff"] == 4
    assert dp["pj"] == pytest.approx(0.5 * hw.pj_per_token())
    assert dp["bs_pj"] == pytest.approx(0.5 * hw.bitslice_pj_per_token())

    class Q:  # layer-skip style: no x_bits_eff, a cost_ratio
        cost_ratio = 0.25

    dq = draft_price(hw, Q())
    assert dq["x_bits_eff"] is None
    assert dq["pj"] == pytest.approx(0.25 * hw.pj_per_token())


# ---------------------------------------------------------------------------
# serving attribution (acceptance)
# ---------------------------------------------------------------------------
def _serve(cfg, art, n_req=4, **kw):
    eng = ServeEngine(cfg, art.params, batch_size=2, max_len=32, page_size=8,
                      **kw)
    rng = np.random.default_rng(7)
    for uid in range(n_req):
        eng.submit(Request(uid=uid, prompt=rng.integers(0, cfg.vocab, 3 + uid),
                           max_new_tokens=MAX_NEW))
    done = eng.run()
    return eng, {u: r.generated for u, r in done.items()}


def test_greedy_attribution_sums_exactly(frozen):
    """The scheduler's attributed pJ equals the analytic per-token price ×
    executed token-passes — no hidden constants, no double counting."""
    cfg, art = frozen
    eng, out = _serve(cfg, art)
    m = eng.metrics()
    hw = m["hw"]
    assert hw is not None
    toks = hw["tokens"]
    assert toks["prefill"] + toks["decode"] == m["ctx_tokens"]
    price = art.hwcost.pj_per_token()
    assert hw["est_pj"]["total"] == \
        pytest.approx(m["ctx_tokens"] * price, rel=1e-9)
    assert hw["est_ns"]["total"] == \
        pytest.approx(m["ctx_tokens"] * art.hwcost.ns_per_token(), rel=1e-9)
    assert hw["pj_per_out_token"] == \
        pytest.approx(hw["est_pj"]["total"] / m["out_tokens"], rel=1e-9)
    # live counterfactual: same token counts priced on bit-slicing
    assert hw["live"]["bitslice_pj"] == pytest.approx(
        m["ctx_tokens"] * art.hwcost.bitslice_pj_per_token(), rel=1e-9)
    assert hw["live"]["energy_ratio"] == \
        pytest.approx(art.hwcost.ratios()["energy"], rel=1e-9)


def test_spec_draft_attribution(frozen):
    """Draft passes are priced at x_bits_eff (proportionally fewer bit-plane
    cycles); the total decomposes exactly into full-price phases plus
    draft-price phases."""
    cfg, art = frozen
    spec = SpecConfig(provider="bitplane", gamma=2, draft_x_bits=4,
                      disable_below=0.0)
    eng, out = _serve(cfg, art, spec=spec)
    hw = eng.metrics()["hw"]
    assert hw["draft"]["x_bits_eff"] == 4
    full = art.hwcost.pj_per_token()
    draft = art.hwcost.pj_per_token(x_bits_eff=4)
    assert hw["draft"]["pj"] == pytest.approx(draft)
    assert draft == 0.5 * full
    t = hw["tokens"]
    assert t["draft"] > 0 and t["verify"] > 0
    expect = full * (t["prefill"] + t["decode"] + t["verify"]) \
        + draft * (t["draft"] + t.get("draft_ingest", 0))
    assert hw["est_pj"]["total"] == pytest.approx(expect, rel=1e-9)


def test_attribution_identical_tracing_on_off(frozen):
    cfg, art = frozen
    eng_off, out_off = _serve(cfg, art, trace=False)
    eng_on, out_on = _serve(cfg, art, trace=True)
    assert out_on == out_off
    assert eng_on.metrics()["hw"] == eng_off.metrics()["hw"]
    # energy-annotated spans validate (est_pj/est_ns finite, non-negative)
    from repro.obs import chrome_trace

    trace = chrome_trace(eng_on.obs.tracer)
    assert validate_chrome_trace(trace) == []
    assert any("est_pj" in e.get("args", {}) for e in trace["traceEvents"])


def test_float_weights_have_no_hw_block(frozen):
    cfg, _ = frozen
    params = init_model(jax.random.key(1), cfg)
    eng = ServeEngine(cfg, params, batch_size=2, max_len=32, page_size=8)
    assert eng.metrics().get("hw") is None


# ---------------------------------------------------------------------------
# schema validation + CLI gates
# ---------------------------------------------------------------------------
def test_metrics_json_schema_backcompat(frozen, tmp_path):
    cfg, art = frozen
    eng, _ = _serve(cfg, art)
    path = str(tmp_path / "hw.json")
    eng.write_hw_metrics(path)
    obj = json.loads(open(path).read())
    assert obj["metrics_schema_version"] == METRICS_SCHEMA_VERSION
    assert validate_metrics_json(obj) == []
    assert obs_check.main([path]) == 0  # CLI routes metrics JSON by content
    # v1 files predate the hw block: no hw requirements
    assert validate_metrics_json({"metrics_schema_version": 1}) == []
    # v2 with a null hw block is a schema violation
    errs = validate_metrics_json(
        {"metrics_schema_version": 2, "hw": None})
    assert errs and "hw" in errs[0]
    # files from a newer build fail loudly, never silently half-validate
    assert validate_metrics_json(
        {"metrics_schema_version": METRICS_SCHEMA_VERSION + 1})
    # traces with malformed energy args are rejected
    bad = {"traceEvents": [
        {"name": "d", "ph": "X", "ts": 0, "dur": 1, "pid": 1, "tid": 1,
         "args": {"est_pj": -3.0}}]}
    assert any("est_pj" in e for e in validate_chrome_trace(bad))


def test_regress_cli_gate(frozen, tmp_path):
    cfg, art = frozen
    payload = {
        "metrics_schema_version": METRICS_SCHEMA_VERSION,
        "conv1": {"hw": HardwareCostModel.from_shapes(CONV1).summary()},
        "regress_keys": ["conv1.hw.pj_per_token", "conv1.hw.ratios.energy"],
    }
    committed = tmp_path / "committed.json"
    committed.write_text(json.dumps(payload))
    fresh = tmp_path / "fresh.json"
    fresh.write_text(json.dumps(payload))
    assert obs_regress.main([str(fresh), str(committed)]) == 0
    # a drifted load-bearing number is a regression — symmetric band, so an
    # unexplained "improvement" fails too
    drift = json.loads(committed.read_text())
    drift["conv1"]["hw"]["pj_per_token"] *= 2.0
    fresh.write_text(json.dumps(drift))
    assert obs_regress.main([str(fresh), str(committed)]) == 1
    # schema version drift is a schema change, not a noise band
    v1 = json.loads(committed.read_text())
    v1["metrics_schema_version"] = 1
    fresh.write_text(json.dumps(v1))
    assert obs_regress.main([str(fresh), str(committed)]) == 1
    # a payload with no regress_keys and no --key is a usage error
    bare = tmp_path / "bare.json"
    bare.write_text(json.dumps({"metrics_schema_version": 2}))
    assert obs_regress.main([str(bare), str(bare)]) == 2
