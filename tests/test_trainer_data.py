"""Trainer + data pipeline: determinism, loss goes down, checkpoint/restart
resume equivalence, straggler monitor."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS, reduce_for_smoke
from repro.data.pipeline import DataConfig, batch_at, for_model, host_shard
from repro.train.trainer import (
    StragglerMonitor,
    TrainConfig,
    Trainer,
    init_state,
    make_train_step,
)

KEY = jax.random.key(0)


def _tiny_cfg():
    return dataclasses.replace(
        reduce_for_smoke(ARCHS["qwen3-8b"]), n_layers=2, d_model=32, d_ff=64,
    )


def test_data_determinism_and_sharding():
    dc = DataConfig(vocab=101, seq_len=16, global_batch=8, seed=3)
    b1, b2 = batch_at(dc, 5), batch_at(dc, 5)
    np.testing.assert_array_equal(b1["inputs"], b2["inputs"])
    assert b1["inputs"].shape == (8, 16)
    # labels are next-token shifted
    full = batch_at(dc, 0)
    np.testing.assert_array_equal(full["labels"][:, :-1],
                                  full["inputs"][:, 1:])
    # host sharding partitions the batch exactly
    sh0 = host_shard(b1, 0, 4)["inputs"]
    sh3 = host_shard(b1, 3, 4)["inputs"]
    np.testing.assert_array_equal(sh0, b1["inputs"][:2])
    np.testing.assert_array_equal(sh3, b1["inputs"][6:])
    assert batch_at(dc, 6)["inputs"][0, 0] != b1["inputs"][0, 0] or True


def test_packed_mode_has_eos():
    dc = DataConfig(vocab=50, seq_len=64, global_batch=2, packed=True,
                    mean_doc_len=8, eos_id=0)
    b = batch_at(dc, 0)
    assert (b["inputs"] == 0).any()  # EOS separators present


@pytest.mark.slow
def test_loss_decreases_and_restart_resumes(tmp_path):
    from repro.optim.adamw import AdamWConfig

    cfg = _tiny_cfg()
    dc = for_model(cfg, seq_len=16, global_batch=8, seed=1)
    dc = dataclasses.replace(dc, packed=True)  # learnable zipf stream
    tcfg = TrainConfig(opt=AdamWConfig(lr=3e-3), ckpt_every=5,
                       ckpt_dir=str(tmp_path), total_steps=40, warmup_steps=2)
    trainer = Trainer(cfg, tcfg, lambda s: batch_at(dc, s))
    state = init_state(KEY, cfg)
    state, hist = trainer.run(state, 20)
    assert int(state.step) == 20
    first = np.mean([h["loss"] for h in hist[:3]])
    last = np.mean([h["loss"] for h in hist[-3:]])
    assert last < first  # it learns (synthetic zipf stream)

    # crash + restart: a fresh Trainer restores from step 20 and continues
    trainer2 = Trainer(cfg, tcfg, lambda s: batch_at(dc, s))
    state2 = init_state(jax.random.key(42), cfg)  # different init — replaced
    state2, hist2 = trainer2.run(state2, 25)
    assert int(state2.step) == 25
    assert hist2[0]["step"] == 20  # resumed, not restarted


@pytest.mark.slow
def test_microbatch_accumulation_matches_full_batch():
    cfg = _tiny_cfg()
    dc = for_model(cfg, seq_len=16, global_batch=8, seed=2)
    batch = jax.tree.map(jnp.asarray, batch_at(dc, 0))
    state = init_state(KEY, cfg)
    s1, m1 = jax.jit(make_train_step(cfg, TrainConfig(microbatches=1)))(state, batch)
    s2, m2 = jax.jit(make_train_step(cfg, TrainConfig(microbatches=4)))(state, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
    # same update direction (grads averaged identically up to fp error)
    w1 = jax.tree.leaves(s1.params)[0]
    w2 = jax.tree.leaves(s2.params)[0]
    np.testing.assert_allclose(np.asarray(w1), np.asarray(w2), atol=1e-5)


def test_straggler_monitor_flags():
    m = StragglerMonitor(z=2.0)
    flagged = [m.observe(1.0) for _ in range(20)]
    assert not any(flagged)
    assert m.observe(10.0) is True
    assert m.flagged == 1


def test_modality_stub_batches():
    cfg = ARCHS["qwen2-vl-72b"]
    dc = for_model(cfg, seq_len=8, global_batch=2)
    b = batch_at(dc, 0)
    assert b["inputs"].shape == (2, 8, cfg.d_model)  # patch embeddings
    assert b["positions"].shape == (2, 8, 3)         # M-RoPE ids
