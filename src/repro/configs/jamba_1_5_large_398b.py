"""jamba-1.5-large-398b [arXiv:2403.19887; hf] — Mamba+attn 1:7, MoE 16e top-2.

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536. Period of 8 layers:
1 attention (offset 4) + 7 mamba; MoE replaces the MLP every 2nd layer.
Published Jamba uses Mamba-1 mixers; we use our Mamba-2 SSD block (d_state 16,
conv 4, expand 2) — noted as a TPU adaptation in DESIGN.md.
"""
from repro.configs.registry import register
from repro.models.config import ModelConfig

CONFIG = register(ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab=65536,
    n_experts=16,
    top_k=2,
    moe_d_ff=24576,
    moe_period=2,
    moe_offset=1,
    attn_period=8,
    attn_offset=4,
    ssm_state=16,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_groups=1,
))
