"""mamba2-780m [arXiv:2405.21060; unverified] — SSD (state-space duality).

48L d_model=1536 attn-free, vocab=50280, ssm_state=128, head_dim 64,
expand 2 → d_inner 3072 → 48 SSD heads.
"""
from repro.configs.registry import register
from repro.models.config import ModelConfig

CONFIG = register(ModelConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    vocab=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_groups=1,
))
