"""Fused Pallas paged-attention kernel vs the XLA gather read, plus the
decode-path correctness fixes that rode along with it.

The load-bearing properties: the fused in-kernel page walk is bit-identical
to the gather read at the default float32 softmax (unit level across
page-boundary-straddling lengths and ragged mixed prefill+decode batches,
and end-to-end through the serving runtime — greedy, speculative, prefix
cache on/off with COW'd shared pages); the fused lowering contains no
full-page-table KV gather; the fused QKV projection equals three separate
engine calls exactly; and the three bugfixes (platform-derived interpret
default, fp32-exact bk auto-shrink, warm-dense-cache chunked prefill)
behave as documented."""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS, reduce_for_smoke
from repro.core.engine import (
    attn_shape_bucket,
    da_matmul,
    da_qkv_matmul,
    get_attn_backend,
    load_cost_table,
    registered_attn_backends,
    select_attn_backend,
    set_cost_table,
)
from repro.kernels.paged_attention import paged_attention
from repro.models.attention import paged_gather_read
from repro.models.model import forward, init_model
from repro.serve.engine import Request, ServeEngine
from repro.serve.kvcache import pages_for, table_width

KEY = jax.random.key(0)
MAX_NEW = 4


def _smoke_cfg(**kw):
    return dataclasses.replace(reduce_for_smoke(ARCHS["qwen3-8b"]),
                               moe_dropless=True, **kw)


# ---------------------------------------------------------------------------
# unit level: fused kernel vs gather read over the same pool
# ---------------------------------------------------------------------------


def _random_paged_case(rng, dtype, t, lens, ps=8, n_pages=12):
    """Pool + permuted page tables + ragged tpos for len(lens) rows.

    Pages are allocated out of order (physical != logical) and unused table
    slots point at the garbage page 0, exactly like the serving allocator.
    ``tpos`` covers the last ``t`` positions of each row — T=1 is decode,
    T>1 a coalesced mixed step whose leading columns act as pad lanes for
    short rows (clamped to 0, masked by the causal comparison).
    """
    b, kv, hd = len(lens), 2, 16
    h = 4
    w = max(pages_for(max(lens), ps) + 1, 3)
    q = jnp.asarray(rng.standard_normal((b, t, h, hd)), dtype)
    ck = jnp.asarray(rng.standard_normal((n_pages, ps, kv, hd)), dtype)
    cv = jnp.asarray(rng.standard_normal((n_pages, ps, kv, hd)), dtype)
    perm = rng.permutation(np.arange(1, n_pages))
    tbl = np.zeros((b, w), np.int32)
    tpos = np.zeros((b, t), np.int32)
    pi = 0
    for i, ln in enumerate(lens):
        npg = pages_for(ln, ps)
        tbl[i, :npg] = perm[pi:pi + npg]
        pi += npg
        tpos[i] = np.maximum(np.arange(ln - t, ln), 0)
    return q, ck, cv, jnp.asarray(tbl), jnp.asarray(tpos)


@pytest.mark.parametrize("mask_mode", ["where", "additive"])
@pytest.mark.parametrize("t", [1, 4])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_bitwise_equals_gather(dtype, t, mask_mode):
    """Page-boundary-straddling lengths (ps−1, ps, 2·ps+3), permuted
    physical pages, garbage column present: fused == gather bit-for-bit at
    float32 softmax for decode and ragged mixed steps."""
    rng = np.random.default_rng(0)
    ps = 8
    q, ck, cv, tbl, tpos = _random_paged_case(
        rng, dtype, t, lens=[ps - 1, ps, 2 * ps + 3], ps=ps)
    kw = dict(softmax_dtype="float32", mask_mode=mask_mode)
    ref = paged_gather_read(q, ck, cv, tbl, tpos, **kw)
    out = paged_attention(q, ck, cv, tbl, tpos, **kw)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(out))


def test_fused_bf16_softmax_close_not_bitwise():
    """Sub-f32 softmax dtypes: XLA fuses exp+reduce keeping f32 across the
    pair, which an op-by-op kernel cannot reproduce — documented as
    within-rounding, asserted here as allclose at bf16 tolerance."""
    rng = np.random.default_rng(1)
    q, ck, cv, tbl, tpos = _random_paged_case(
        rng, jnp.float32, 1, lens=[13, 7], ps=8)
    kw = dict(softmax_dtype="bfloat16", mask_mode="where")
    ref = paged_gather_read(q, ck, cv, tbl, tpos, **kw)
    out = paged_attention(q, ck, cv, tbl, tpos, **kw)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(out),
                               rtol=0, atol=2e-2)


def test_fused_ignores_unreferenced_and_garbage_pages():
    """NaN-poisoning pages no table names must not change the fused output
    (the walk never touches them), and rewriting the garbage page 0 with
    finite junk must not either (pad slots walk it, but its rows carry
    exactly zero softmax weight — same contract as the gather path)."""
    rng = np.random.default_rng(2)
    q, ck, cv, tbl, tpos = _random_paged_case(
        rng, jnp.float32, 1, lens=[9, 17], ps=8)
    named = set(np.asarray(tbl).ravel().tolist())
    unwalked = [p for p in range(ck.shape[0]) if p not in named]
    assert unwalked, "case must leave some pages unreferenced"
    out = paged_attention(q, ck, cv, tbl, tpos)
    ckp = ck.at[jnp.asarray(unwalked)].set(jnp.nan)
    cvp = cv.at[jnp.asarray(unwalked)].set(jnp.nan)
    ckp = ckp.at[0].set(7.5)
    cvp = cvp.at[0].set(-3.25)
    poisoned = paged_attention(q, ckp, cvp, tbl, tpos)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(poisoned))


# ---------------------------------------------------------------------------
# engine registry + dispatch
# ---------------------------------------------------------------------------


def test_attn_registry_and_dispatch():
    names = set(registered_attn_backends())
    assert {"gather", "fused"} <= names
    with pytest.raises(ValueError, match="unknown paged-attention"):
        get_attn_backend("nope")
    # off-TPU heuristic: auto resolves to the gather read
    assert select_attn_backend("auto", batch=2, t=1, kv_len=64) == "gather"
    assert select_attn_backend(None, batch=2, t=1, kv_len=64) == "gather"
    assert select_attn_backend("fused", batch=2, t=1, kv_len=64) == "fused"
    # a measured attn bucket overrides the heuristic
    bucket = attn_shape_bucket(2, 1, 64)
    assert bucket.startswith("attn:dec:")
    try:
        set_cost_table({bucket: {"fused": 1.0, "gather": 9.0}})
        assert select_attn_backend("auto", batch=2, t=1, kv_len=64) == "fused"
    finally:
        set_cost_table(None)


def test_cost_table_keeps_attn_buckets(tmp_path):
    """load_cost_table must not drop attn backend names as 'unregistered
    VMM backends' — attn:* buckets are filtered against the attn registry."""
    p = tmp_path / "autotune.json"
    p.write_text(json.dumps({
        "device": jax.default_backend(),
        "table": {
            "attn:dec:s": {"fused": 1.0, "gather": 2.0, "bogus": 3.0},
            "dec:s:b8": {"bitplane": 4.0},
        },
    }))
    with pytest.warns(UserWarning, match="bogus"):
        table = load_cost_table(p)
    assert table["attn:dec:s"] == {"fused": 1.0, "gather": 2.0}
    assert table["dec:s:b8"] == {"bitplane": 4.0}


# ---------------------------------------------------------------------------
# end-to-end: serving runtime token identity, gather vs fused
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def served():
    """Frozen smoke artifact (the fused QKV pass runs end-to-end) + prompts
    sharing a 2-page prefix (so the prefix cache has something to share and
    COW) + per-config decode helper."""
    from repro.core.da import DAConfig
    from repro.core.freeze import freeze_model

    cfg = _smoke_cfg()
    art = freeze_model(init_model(KEY, cfg), DAConfig(x_signed=True),
                       mode="bitplane_stacked", model_cfg=cfg)
    rng = np.random.default_rng(7)
    shared = rng.integers(0, cfg.vocab, 16)  # 2 pages at page_size=8
    prompts = {
        uid: np.concatenate([shared, rng.integers(0, cfg.vocab, 2 + uid)])
        for uid in range(6)
    }

    def run(**kw):
        eng = ServeEngine(cfg, art.params, batch_size=2, max_len=48,
                          page_size=8, **kw)
        for uid, pr in prompts.items():
            eng.submit(Request(uid=uid, prompt=pr, max_new_tokens=MAX_NEW))
        done = eng.run()
        return {uid: list(done[uid].generated) for uid in prompts}

    return run


def test_serve_tokens_identical_greedy(served):
    """Plain continuous batching (chunked prefill + decode through 2 lanes):
    fused backend decodes the very tokens the gather backend does."""
    assert served(paged_attn="gather") == served(paged_attn="fused")


def test_serve_tokens_identical_prefix_cache(served):
    """COW'd shared-prefix pages under the fused read: token-identical to
    the gather read, cache on and off."""
    ref = served(paged_attn="gather")
    assert served(paged_attn="fused", prefix_cache=True) == ref
    assert served(paged_attn="gather", prefix_cache=True) == ref


def test_serve_tokens_identical_speculative(served):
    """Spec staging (draft rollouts + batched T=γ+1 verify) runs the fused
    read in every stage; greedy output stays token-identical."""
    from repro.spec import SpecConfig

    spec = SpecConfig(provider="bitplane", gamma=2, draft_x_bits=6,
                      disable_below=0.0)
    ref = served(paged_attn="gather")
    assert served(paged_attn="fused", spec=spec) == ref


# ---------------------------------------------------------------------------
# lowering: the full-page-table KV gather is gone from the fused path
# ---------------------------------------------------------------------------


def test_fused_lowering_has_no_page_table_gather():
    from repro.launch.hlo_tools import ops_of_kind
    from repro.serve.kvcache import init_paged_caches
    from repro.serve.scheduler import make_paged_step

    cfg = _smoke_cfg()
    params = init_model(KEY, cfg)
    b, ps, max_len = 2, 8, 32
    w = table_width(max_len, ps)
    n_pages = 1 + b * pages_for(max_len, ps)
    caches = init_paged_caches(cfg, n_pages, ps, cfg.dtype())
    args = (
        params, caches,
        jnp.zeros((b, 1), jnp.int32), jnp.zeros((b, 1), jnp.int32),
        jnp.zeros((b, w), jnp.int32), jnp.zeros((b,), jnp.int32),
    )
    # the re-materialized KV view is [B, W, ps, kv, hd] per gather
    view_bytes = (b * w * ps * cfg.n_kv_heads * cfg.head_dim_
                  * jnp.dtype(cfg.dtype()).itemsize)

    def biggest_gather(paged_attn):
        step = make_paged_step(dataclasses.replace(cfg, paged_attn=paged_attn))
        hlo = jax.jit(step).lower(*args).compile().as_text()
        gathers = ops_of_kind(hlo, "gather")
        return max((bts for _, bts in gathers), default=0)

    assert biggest_gather("gather") >= view_bytes  # the op we are removing
    assert biggest_gather("fused") < view_bytes    # gone from the fused path


# ---------------------------------------------------------------------------
# fused QKV projection
# ---------------------------------------------------------------------------


def test_fused_qkv_bit_identical_to_separate_calls():
    from repro.core.da import DAConfig
    from repro.core.engine import pack_weights

    rng = np.random.default_rng(3)
    dacfg = DAConfig(x_signed=True)
    d, qd, kvd = 64, 64, 32
    packs = tuple(
        pack_weights(jnp.asarray(rng.standard_normal((d, n)), jnp.float32),
                     dacfg)
        for n in (qd, kvd, kvd)
    )
    x = jnp.asarray(rng.standard_normal((2, 3, d)), jnp.float32)
    fused = da_qkv_matmul(x, packs)
    for got, p in zip(fused, packs):
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(da_matmul(x, p)))


def test_fused_qkv_draft_precision_matches():
    """The truncated-bitplane draft pass fuses too: under x_bits_override
    the shared codes are truncated exactly as da_matmul truncates them."""
    from repro.core.da import DAConfig
    from repro.core.engine import pack_weights, x_bits_override

    rng = np.random.default_rng(4)
    dacfg = DAConfig(x_signed=True)
    packs = tuple(
        pack_weights(jnp.asarray(rng.standard_normal((48, n)), jnp.float32),
                     dacfg)
        for n in (32, 16, 16)
    )
    x = jnp.asarray(rng.standard_normal((4, 48)), jnp.float32)
    with x_bits_override(4):
        fused = da_qkv_matmul(x, packs)
        seps = [da_matmul(x, p) for p in packs]
    for got, ref in zip(fused, seps):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


# ---------------------------------------------------------------------------
# bugfixes
# ---------------------------------------------------------------------------


def test_pallas_interpret_default_derives_from_platform():
    """Off-TPU the kernels must default to interpreter execution (the old
    interpret=True default silently interpreted ON TPU as well)."""
    from repro.core.da import DAConfig
    from repro.kernels import bitplane_vmm, paged_attention as pa
    from repro.kernels.ref import bitplane_vmm_ref

    assert bitplane_vmm._default_interpret() is (
        jax.default_backend() != "tpu")
    assert pa._default_interpret() is (jax.default_backend() != "tpu")
    rng = np.random.default_rng(5)
    cfg = DAConfig(x_signed=True)
    xq = jnp.asarray(rng.integers(-128, 128, (4, 32)), jnp.int32)
    wq = jnp.asarray(rng.integers(-127, 128, (32, 16)), jnp.int8)
    # no interpret= argument: platform default must pick a runnable mode
    out = bitplane_vmm.bitplane_vmm_pallas(xq, wq, cfg)
    np.testing.assert_array_equal(
        np.asarray(out),
        np.asarray(bitplane_vmm_ref(xq, wq.astype(jnp.int32), cfg)))


def test_bitplane_bk_autoshrinks_for_wide_codes():
    """The fp32-exactness bound follows the actual weight-code magnitude:
    wide codes shrink bk instead of silently summing past 2^24."""
    from repro.core.da import DAConfig
    from repro.kernels.bitplane_vmm import (
        _fit_bk,
        _weight_code_bound,
        bitplane_vmm_pallas,
    )

    assert _fit_bk(2048, 127) == 2048          # int8 codes: unchanged
    assert _fit_bk(2048, 1 << 16) == 128       # 16-bit codes: shrunk
    with pytest.raises(ValueError, match="exact-integer range"):
        _fit_bk(512, 1 << 24)

    rng = np.random.default_rng(6)
    cfg = DAConfig(x_signed=True)
    xq = jnp.asarray(rng.integers(-128, 128, (4, 256)), jnp.int32)
    wq = jnp.asarray(rng.integers(-4000, 4000, (256, 16)), jnp.int32)
    # int32 storage, concrete codes: bound inspected from the values
    assert _weight_code_bound(wq, None) == int(jnp.max(jnp.abs(wq)))
    out = bitplane_vmm_pallas(xq, wq, cfg, w_maxabs=1 << 16)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(xq @ wq))
    # traced wide codes must demand an explicit bound, not guess
    with pytest.raises(ValueError, match="w_maxabs"):
        jax.jit(lambda a, b: bitplane_vmm_pallas(a, b, cfg))(xq, wq)


def test_dense_chunked_prefill_warm_cache_raises():
    """A second prefill chunk against a warm dense KVCache cannot see the
    first chunk — the branch must refuse loudly instead of attending over
    the fresh segment only."""
    from repro.models.attention import KVCache, attention_forward, \
        init_attention

    cfg = _smoke_cfg()
    p = init_attention(jax.random.key(1), cfg)
    b, s, t = 1, 32, 4
    cache = KVCache(
        k=jnp.zeros((b, s, cfg.n_kv_heads, cfg.head_dim_)),
        v=jnp.zeros((b, s, cfg.n_kv_heads, cfg.head_dim_)),
        length=jnp.asarray(8, jnp.int32),  # warm: 8 tokens already written
    )
    x = jax.random.normal(jax.random.key(2), (b, t, cfg.d_model))
    pos = jnp.asarray([[8, 9, 10, 11]], jnp.int32)
    with pytest.raises(ValueError, match="warm dense KVCache"):
        attention_forward(p, x, cfg, pos, cache=cache, update_cache=True)
    # a fresh cache (length 0) still prefills fine
    fresh = cache._replace(length=jnp.asarray(0, jnp.int32))
    y, new_cache = attention_forward(
        p, x, cfg, jnp.asarray([[0, 1, 2, 3]], jnp.int32),
        cache=fresh, update_cache=True)
    assert y.shape == (b, t, cfg.d_model)
    assert int(new_cache.length) == t
