"""Structured request/scheduler tracing for the paged serving runtime.

A :class:`TraceRecorder` is a bounded ring buffer of host-side events — the
per-request lifecycle (submit → admit → prefill-chunk × N → decode-tick × M
→ preempt/re-admit → spec rounds → finish) and the per-tick scheduler story
(batch shape bucket, lanes, pages allocated/COW'd/evicted).  Events carry
``perf_counter`` timestamps, the SAME clock the latency metrics use, so a
trace reconstructs TTFT/ITL exactly (the token events are stamped with the
very ``now`` the scheduler put into ``Request.token_times``).

Events export to Chrome ``trace_event`` JSON (``repro.obs.export``) and load
in Perfetto / ``chrome://tracing``: each request is a named track, spans
nest by B/E pairing, scheduler ticks are complete ("X") events with the
shape/page args attached.

Tracing is OFF by default (``TraceRecorder(enabled=False)`` is a no-op whose
every method is one attribute test) and must never perturb decode — token
bit-identity with tracing on/off is test-asserted.  The ring buffer bounds
memory on long serves: the newest ``capacity`` events win, and
:meth:`span_balance` is computed from lifetime depth counters, not the
buffer, so balance checks survive wraparound.

``device_span`` bridges host spans to device profiles: inside it, a
``jax.profiler.TraceAnnotation`` (host) plus ``jax.named_scope`` (trace-time
HLO metadata) make the XLA profiler's device timeline line up with the
host-side request spans when both are captured.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from collections import deque
from typing import Any, Dict, Iterator, List, Optional

import jax

#: Track name for scheduler-level (per-tick) events.
SCHED_TRACK = "scheduler"


def request_track(uid: int) -> str:
    return f"req:{uid}"


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    """One structured event.

    ``ph`` follows the Chrome trace_event phases this recorder emits:
    ``"B"``/``"E"`` span begin/end, ``"X"`` complete (carries ``dur``),
    ``"i"`` instant.  ``ts``/``dur`` are seconds on the perf_counter clock
    (export converts to microseconds).
    """

    name: str
    ph: str
    ts: float
    track: str
    dur: float = 0.0
    args: Optional[Dict[str, Any]] = None


class TraceRecorder:
    def __init__(self, capacity: int = 65536, enabled: bool = True):
        self.enabled = enabled
        self.events: deque = deque(maxlen=capacity)
        # lifetime span-depth ledger per track: +1 on begin, -1 on end.
        # Balance is judged on these, not the ring buffer, so an evicted
        # "B" event cannot fake an unbalanced trace.
        self._depth: Dict[str, int] = {}
        self.dropped = 0
        self._t0 = time.perf_counter()

    # -- emission ------------------------------------------------------------
    def _push(self, ev: TraceEvent) -> None:
        if len(self.events) == self.events.maxlen:
            self.dropped += 1
        self.events.append(ev)

    def begin(self, name: str, track: str, ts: Optional[float] = None,
              **args) -> None:
        if not self.enabled:
            return
        self._depth[track] = self._depth.get(track, 0) + 1
        self._push(TraceEvent(name, "B", self._now(ts), track,
                              args=args or None))

    def end(self, name: str, track: str, ts: Optional[float] = None,
            **args) -> None:
        if not self.enabled:
            return
        self._depth[track] = self._depth.get(track, 0) - 1
        self._push(TraceEvent(name, "E", self._now(ts), track,
                              args=args or None))

    def complete(self, name: str, track: str, t_start: float,
                 dur: float, **args) -> None:
        """One already-finished span (per-tick phases: start time + duration
        measured by the caller)."""
        if not self.enabled:
            return
        self._push(TraceEvent(name, "X", t_start, track, dur=dur,
                              args=args or None))

    def instant(self, name: str, track: str, ts: Optional[float] = None,
                **args) -> None:
        if not self.enabled:
            return
        self._push(TraceEvent(name, "i", self._now(ts), track,
                              args=args or None))

    @contextlib.contextmanager
    def span(self, name: str, track: str, **args) -> Iterator[None]:
        """B/E pair guarded by try/finally — a span opened is a span closed
        even when the body raises (the balance invariant the tests assert)."""
        self.begin(name, track, **args)
        try:
            yield
        finally:
            self.end(name, track)

    def _now(self, ts: Optional[float]) -> float:
        return time.perf_counter() if ts is None else ts

    # -- inspection ----------------------------------------------------------
    def span_balance(self) -> Dict[str, int]:
        """Track → currently-open span depth (every value should be 0 once
        serving drains; nonzero means a begin without its end)."""
        return {t: d for t, d in self._depth.items() if d != 0}

    def drain(self) -> List[TraceEvent]:
        out = list(self.events)
        self.events.clear()
        return out

    def __len__(self) -> int:
        return len(self.events)


@contextlib.contextmanager
def device_span(name: str, enabled: bool = True) -> Iterator[None]:
    """Host→device profiling bridge around a device dispatch.

    Wraps the body in ``jax.profiler.TraceAnnotation`` so an XLA profiler
    capture shows this host span on its timeline, aligned with the device
    ops it dispatched.  No-op (one branch) when disabled.
    """
    if not enabled:
        yield
        return
    with jax.profiler.TraceAnnotation(name):
        yield


# -- module default ----------------------------------------------------------
# Disabled by default: tracing is opt-in per engine (ServeEngine(trace=True)
# or --trace-out) and costs one attribute test per call site when off.
_default = TraceRecorder(enabled=False)


def default_tracer() -> TraceRecorder:
    return _default
