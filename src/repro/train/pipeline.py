"""GPipe-style pipeline parallelism over a mesh axis (shard_map + ppermute).

For multi-pod deployments the "pod" axis can run pipeline stages instead of
pure data parallelism: each stage holds ``n_layers / n_stages`` layers and
microbatches stream through with collective_permute hops. This module
implements the schedule explicitly (it cannot be expressed as a GSPMD
annotation) and is validated at small scale in tests; the production dry-run
keeps "pod" as a DP axis by default (DESIGN.md §4).

Schedule: loop-per-microbatch over (fwd hop) with bubble = (S−1)/(M+S−1);
losses are computed on the last stage and psum'd back.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_forward(
    stage_fn: Callable[[Any, jax.Array], jax.Array],
    stage_params: Any,
    x_micro: jax.Array,
    axis: str,
    n_stages: int = None,
):
    """Run inside shard_map. stage_params: this stage's layer stack;
    x_micro: [M, mb, ...] microbatches (same on every stage; only stage 0's
    input matters). Returns last stage's outputs [M, mb, ...].

    The rotating-buffer schedule: at tick t, stage s processes microbatch
    t − s (if in range), then the activations ppermute one hop right.
    """
    s_idx = jax.lax.axis_index(axis)
    if n_stages is None:
        # static stage count; jax<0.5 has no lax.axis_size — callers with a
        # mesh in hand pass it explicitly (make_pipeline_apply does)
        n_stages = jax.lax.axis_size(axis)
    m = x_micro.shape[0]
    ticks = m + n_stages - 1
    buf = jnp.zeros_like(x_micro[0])
    outs = jnp.zeros((m,) + x_micro.shape[1:], x_micro.dtype)

    def tick(carry, t):
        buf, outs = carry
        mb_idx = t - s_idx
        # stage 0 ingests a fresh microbatch at its tick
        fresh = x_micro[jnp.clip(mb_idx, 0, m - 1)]
        h = jnp.where(s_idx == 0, fresh, buf)
        active = (mb_idx >= 0) & (mb_idx < m)
        y = stage_fn(stage_params, h)
        y = jnp.where(active, y, buf)
        # last stage records finished microbatches
        record = active & (s_idx == n_stages - 1)
        outs = jax.lax.dynamic_update_index_in_dim(
            outs,
            jnp.where(record, y, outs[jnp.clip(mb_idx, 0, m - 1)]),
            jnp.clip(mb_idx, 0, m - 1),
            axis=0,
        )
        # hop activations to the next stage
        perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
        buf = jax.lax.ppermute(y, axis, perm)
        return (buf, outs), None

    (buf, outs), _ = jax.lax.scan(tick, (buf, outs), jnp.arange(ticks))
    # only the last stage recorded outputs (other stages hold zeros);
    # psum replicates the result so out_specs=P() is well-defined.
    return jax.lax.psum(outs, axis)


def make_pipelined_apply(
    mesh: Mesh,
    axis: str,
    stage_fn: Callable,
    n_microbatches: int,
):
    """Wrap a per-stage apply into a pipelined whole-model apply.

    stage_params must be sharded stage-major on ``axis`` (leading dim =
    n_stages). Inputs [B, ...] are split into microbatches host-side.
    """

    def apply(stage_params, x):
        b = x.shape[0]
        mb = b // n_microbatches
        x_micro = x.reshape((n_microbatches, mb) + x.shape[1:])

        def inner(sp, xm):
            sp = jax.tree.map(lambda a: a[0], sp)  # this stage's slice
            return pipeline_forward(stage_fn, sp, xm, axis,
                                    n_stages=mesh.shape[axis])

        from repro.launch.mesh import shard_map_compat

        shard = shard_map_compat(
            inner,
            mesh=mesh,
            in_specs=(P(axis), P()),
            out_specs=P(),
            check=False,
        )
        y_micro = shard(stage_params, x_micro)
        return y_micro.reshape((b,) + y_micro.shape[2:])

    return apply
