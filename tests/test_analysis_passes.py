"""Graph-pass tests for the static verifier (repro.analysis.passes/graph).

The load-bearing claims, each pinned with both the clean case and a seeded
mutation:

* every registered DA backend's jaxpr is multiplier-free (zero findings);
* the float baseline, the dequantize-then-matmul cheat, and a float dot on
  raw integer codes are all flagged — without any exemption allowlist;
* a gather materializing the [B, W·ps, kv, hd] page view is caught when
  the lowering claims the fused path;
* synthetic-HLO units for the host-sync and dtype-discipline passes.

The full serving-graph sweep (trace + compile of decode/prefill/spec-draft
under both attention backends) is @slow; tier-1 covers the pass engine on
per-backend da_matmul jaxprs, which trace in milliseconds.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.findings import errors
from repro.analysis.graph import arg_taints, trace_serving_steps
from repro.analysis.passes import (
    DEFAULT_ALLOWLIST,
    apply_allowlist,
    dtype_discipline,
    multiplier_free,
    no_big_gather,
    no_host_sync,
    run_passes,
)
from repro.configs.registry import ARCHS, reduce_for_smoke
from repro.core.da import DAConfig
from repro.core.engine import da_matmul, pack_weights, registered_backends
from repro.core.freeze import freeze_model


def _jaxpr_and_taints(fn, *args):
    return jax.make_jaxpr(fn)(*args), arg_taints(args)


def _check(fn, *args, allow=()):
    closed, taints = _jaxpr_and_taints(fn, *args)
    return apply_allowlist(
        multiplier_free(closed, taints, step_name="unit"), allow)


RNG = np.random.default_rng(7)
X = jnp.asarray(RNG.standard_normal((4, 32)), jnp.float32)
W = jnp.asarray(RNG.standard_normal((32, 16)) * 0.1, jnp.float32)


# -- every DA backend is multiplier-free ------------------------------------


@pytest.mark.parametrize("mode", sorted(registered_backends()))
def test_da_backend_is_multiplier_free(mode):
    packed = pack_weights(W, DAConfig(x_signed=True), mode=mode)
    findings = _check(lambda x, p: da_matmul(x, p, mode=mode), X, packed)
    assert findings == [], "\n".join(f.format() for f in findings)


# -- the cheats are flagged (no allowlist) -----------------------------------


def test_float_baseline_is_flagged():
    params = {"w": W}
    findings = _check(lambda x, p: x @ p["w"], X, params)
    assert errors(findings), "float x @ W must be flagged"


def test_dequantize_then_matmul_cheat_is_flagged():
    """Unpacking the int8 codes back to float and multiplying is the exact
    cheat the taint lattice exists to catch: INT_EXACT promotes to FLOAT
    under float arithmetic, and the dot sees a float weight operand."""
    packed = pack_weights(W, DAConfig(x_signed=True), mode="bitplane")

    def cheat(x, p):
        w = p.wq.astype(jnp.float32) * p.w_scale
        return x @ w

    findings = _check(cheat, X, packed)
    assert errors(findings), "dequant-then-matmul must be flagged"


def test_float_dot_on_integer_codes_is_flagged():
    packed = pack_weights(W, DAConfig(x_signed=True), mode="bitplane")
    findings = _check(lambda x, p: x @ p.wq.astype(jnp.float32), X, packed)
    assert errors(findings), "float dot on raw int codes must be flagged"


def test_allowlist_suppresses_by_substring():
    """The allowlist matches a finding's source location (where) — the
    same mechanism that exempts core/bitslice.py by default."""
    params = {"w": W}
    findings = _check(lambda x, p: x @ p["w"], X, params)
    assert findings
    assert apply_allowlist(findings, ("test_analysis_passes",)) == []


def test_bitslice_counterfactual_is_allowlisted_by_default():
    """The bit-slicing comparison baseline (core/bitslice.py) is integer
    eACM emulation, not a served path; the default allowlist names it."""
    assert any("bitslice" in tok for tok in DEFAULT_ALLOWLIST)


# -- structural HLO passes on synthetic modules ------------------------------

_VIEW = 2 * 40 * 2 * 16 * 4  # [B=2, W·ps=40, kv=2, hd=16] f32


def test_no_big_gather_flags_view_sized_gather():
    txt = "  %g = f32[2,40,2,16]{3,2,1,0} gather(%pool, %idx)\n"
    findings = no_big_gather(txt, _VIEW, step_name="decode[fused]")
    assert errors(findings)
    assert findings[0].bytes >= _VIEW


def test_no_big_gather_ignores_small_gathers():
    txt = "  %g = f32[2,16]{1,0} gather(%emb, %ids)\n"
    assert no_big_gather(txt, _VIEW, step_name="decode[fused]") == []


def test_no_host_sync_flags_host_callback():
    txt = ('  %cb = f32[4]{0} custom-call(%a), '
           'custom_call_target="xla_python_cpu_callback"\n')
    assert errors(no_host_sync(txt, step_name="decode"))


def test_no_host_sync_flags_infeed_outfeed():
    txt = "  %i = (f32[4]{0}, token[]) infeed(%tok)\n"
    assert errors(no_host_sync(txt, step_name="decode"))


def test_no_host_sync_accepts_device_custom_calls():
    txt = ('  %cc = f32[4]{0} custom-call(%a), '
           'custom_call_target="tpu_custom_call"\n')
    assert no_host_sync(txt, step_name="decode") == []


def test_dtype_discipline_flags_f64():
    txt = "  %c = f64[4]{0} convert(%a)\n"
    assert errors(dtype_discipline(txt, step_name="decode"))


def test_dtype_discipline_flags_sub_f32_exponential():
    txt = "  %e = bf16[4]{0} exponential(%a)\n"
    assert errors(dtype_discipline(txt, step_name="decode"))


def test_dtype_discipline_accepts_f32_softmax_and_int_dots():
    txt = (
        "  %e = f32[4]{0} exponential(%a)\n"
        "  %d = s32[4,8]{1,0} dot(%xq, %wq)\n"
    )
    assert dtype_discipline(txt, step_name="decode") == []


# -- the full serving graph (slow: freeze + trace + XLA compile) -------------


@pytest.fixture(scope="module")
def served_steps():
    from repro.models.model import init_model

    cfg = dataclasses.replace(reduce_for_smoke(ARCHS["qwen3-8b"]),
                              moe_dropless=True)
    params = init_model(jax.random.key(0), cfg)
    art = freeze_model(params, DAConfig(x_signed=True),
                       mode="da_bitplane_stacked", model_cfg=cfg)
    return trace_serving_steps(art.params, cfg, spec_gamma=2)


@pytest.mark.slow
def test_frozen_serving_graph_has_zero_findings(served_steps):
    assert [s.name for s in served_steps] == [
        "decode[gather]", "prefill[gather]",
        "decode[fused]", "prefill[fused]", "spec_draft[fused]",
    ]
    findings = run_passes(served_steps)
    assert findings == [], "\n".join(f.format() for f in findings)


@pytest.mark.slow
def test_gather_lowering_forged_as_fused_is_caught(served_steps):
    """Mutation: take the gather-backend decode lowering (which legal-ly
    materializes the page view) and claim it came from the fused path —
    the no-big-gather pass must fire."""
    gather_decode = next(s for s in served_steps
                         if s.name == "decode[gather]")
    forged = dataclasses.replace(gather_decode, fused=True,
                                 name="decode[forged-fused]")
    findings = run_passes([forged])
    gathers = [f for f in errors(findings)
               if f.pass_name == "graph/no-big-gather"]
    assert gathers, "view-sized gather forged as fused must be flagged"
