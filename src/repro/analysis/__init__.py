"""Static analysis for the DA serving stack.

Three layers, one CLI (``python -m repro.analysis.check``):

* :mod:`repro.analysis.passes` — graph invariant passes over traced
  serving steps (multiplier-free, no-big-gather, no-host-sync,
  dtype-discipline).
* :mod:`repro.analysis.races` — static page-aliasing race checker over
  ``PagedScheduler`` batch plans (also wired into the scheduler's
  ``analysis_debug`` mode).
* :mod:`repro.analysis.lint` — AST lint rules encoding repo conventions
  (platform-derived ``interpret``, shared clock, metrics registry,
  benchmark provenance).

Every layer reports through the shared :class:`repro.analysis.findings.Finding`
record, so CI and the CLI render one unified table.
"""
from repro.analysis.findings import Finding, errors, render
from repro.analysis.hlo import bytes_by_op_kind, iter_ops, ops_of_kind
from repro.analysis.races import PageRaceError, PageWrite, TickPlan, check_plan

__all__ = [
    "Finding",
    "PageRaceError",
    "PageWrite",
    "TickPlan",
    "bytes_by_op_kind",
    "check_plan",
    "errors",
    "iter_ops",
    "ops_of_kind",
    "render",
]
