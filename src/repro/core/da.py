"""Distributed-Arithmetic VMM (the paper's core contribution, §II).

The identity implemented here, bit-exactly, for integer X [M,K] and constant
integer W [K,N]::

    Y[m,n] = Σ_k X[m,k]·W[k,n]
           = Σ_b coef(b) · Σ_g  LUT_g[ addr_g(m,b), n ]

where rows of W are partitioned into groups of ``group_size`` (paper: 8, one
ReRAM processing-memory array per group), ``LUT_g[a,n] = Σ_{i: bit i of a set}
W[g·L+i, n]`` is the table of all 2^L possible weight sums (written once into
the PMA, §III-A), and ``addr_g(m,b)`` packs bit-plane ``b`` of the group's
inputs into the PMA address (§II-C).  ``coef(b) = 2^b`` except the sign bit of
two's-complement inputs which carries ``-2^(B-1)``.

Three equivalent execution modes are provided:

* ``da_vmm_lut``     — faithful: materialized LUTs + gather (the memory read).
* ``da_vmm_onehot``  — TPU-native: LUT read as one-hot(addr) @ LUT on the MXU
                       (the address decoder IS a one-hot expansion). Same math.
* ``da_vmm_bitplane``— storage-free: Σ_b coef(b)·(xbit_b @ W); the MXU computes
                       each cycle's weight sums on the fly instead of reading
                       precomputed ones.

All return the exact int32 accumulator (== X @ W in integer arithmetic).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DAConfig:
    """Configuration of the DA engine.

    group_size: rows per processing-memory array (paper: 8 → 256-row PMA).
    x_bits:     bit-serial cycles (input bit width; paper: 8).
    x_signed:   two's-complement inputs (LM activations) vs unsigned (images).
    """

    group_size: int = 8
    x_bits: int = 8
    x_signed: bool = False

    @property
    def lut_rows(self) -> int:
        return 1 << self.group_size


def num_groups(k: int, group_size: int) -> int:
    return -(-k // group_size)


def pad_to_groups(w: jax.Array, group_size: int) -> jax.Array:
    """Zero-pad the contraction dim of W [K,N] to a multiple of group_size."""
    k = w.shape[0]
    pad = (-k) % group_size
    if pad:
        w = jnp.pad(w, ((0, pad), (0, 0)))
    return w


def build_luts(w: jax.Array, group_size: int = 8) -> jax.Array:
    """Pre-VMM weight summation (paper §III-A, Fig. 6).

    Returns LUTs of shape [G, 2^L, N] with ``LUT[g, a, n] = Σ_{i<L, a_i=1}
    W[g·L+i, n]``.  Built by iterative doubling — exactly the paper's
    weight-summation adder computing "all possible sums of the weights":
    each row added once per existing table half (2^L − 1 additions/column).
    """
    l = group_size
    w = pad_to_groups(w.astype(jnp.int32), l)
    k, n = w.shape
    g = k // l
    wg = w.reshape(g, l, n)

    # Iterative doubling over rows of each group: table_{r+1} = [table_r ;
    # table_r + w_r]. Address bit r ↔ group row r (LSB-first).
    luts = jnp.zeros((g, 1, n), dtype=jnp.int32)
    for r in range(l):
        luts = jnp.concatenate([luts, luts + wg[:, r : r + 1, :]], axis=1)
    return luts  # [G, 2^L, N]


def truncate_codes(xq: jax.Array, cfg: DAConfig, x_bits_eff: int):
    """Drop the ``cfg.x_bits - x_bits_eff`` low-order bit-planes of ``xq``.

    The DA accumulation is a sum over bit-planes, so evaluating only the top
    ``x_bits_eff`` planes of the *same* weight artifact is a well-defined
    cheap approximation (the paper's precision/effort trade, §II-C: fewer
    bit-serial cycles against the same PMA contents).  Implemented as an
    arithmetic right shift: for two's-complement codes ``xq = (xq >> d)·2^d
    + (xq & (2^d−1))``, so running any backend on ``xq >> d`` under
    ``x_bits = x_bits_eff`` and scaling the accumulator by ``2^d`` computes
    exactly the top-plane partial sum — every backend (LUT gather, one-hot,
    bit-plane forms, Pallas kernels) inherits partial-bits evaluation from
    this one identity, with the per-cycle work genuinely reduced.

    Returns ``(shifted codes, cfg with x_bits=x_bits_eff, d)``.
    """
    if not 1 <= x_bits_eff <= cfg.x_bits:
        raise ValueError(
            f"x_bits_eff={x_bits_eff} outside [1, cfg.x_bits={cfg.x_bits}]"
        )
    drop = cfg.x_bits - x_bits_eff
    if drop == 0:
        return xq, cfg, 0
    if cfg.x_signed:
        # sign-extend the low cfg.x_bits bits so the arithmetic shift sees
        # the true two's-complement value even if callers carry raw patterns
        sign = 1 << (cfg.x_bits - 1)
        xq = (jnp.bitwise_and(xq, (1 << cfg.x_bits) - 1) ^ sign) - sign
    shifted = jnp.right_shift(xq, drop)
    return shifted, dataclasses.replace(cfg, x_bits=x_bits_eff), drop


def bit_plane(xq: jax.Array, b: int) -> jax.Array:
    """Bit b of the (two's-complement or unsigned) integer codes, in {0,1}."""
    return jnp.bitwise_and(jnp.right_shift(xq, b), 1)


def bit_coefs(x_bits: int, x_signed: bool) -> np.ndarray:
    """Per-bit weights; two's complement puts −2^(B−1) on the sign bit."""
    coefs = np.array([1 << b for b in range(x_bits)], dtype=np.int64)
    if x_signed:
        coefs[-1] = -coefs[-1]
    return coefs


def group_addresses(xq: jax.Array, cfg: DAConfig) -> jax.Array:
    """Pack bit-planes of X [.., K] into PMA addresses [.., B, G].

    addr[..., b, g] = Σ_i bit_b(X[..., g·L+i]) << i   (the decoder input of
    cycle b for PMA g; paper Fig. 4 applies one bit of X1..X8 per cycle).
    """
    l = cfg.group_size
    k = xq.shape[-1]
    pad = (-k) % l
    if pad:
        xq = jnp.pad(xq, [(0, 0)] * (xq.ndim - 1) + [(0, pad)])
    g = xq.shape[-1] // l
    xg = xq.reshape(xq.shape[:-1] + (g, l))
    # For signed codes, take the two's-complement bit pattern of the low B bits.
    mask = (1 << cfg.x_bits) - 1
    xg = jnp.bitwise_and(xg, mask)
    shifts = jnp.arange(l, dtype=jnp.int32)
    addrs = []
    for b in range(cfg.x_bits):
        bits = jnp.bitwise_and(jnp.right_shift(xg, b), 1)
        addrs.append(jnp.sum(bits << shifts, axis=-1))  # [.., G]
    return jnp.stack(addrs, axis=-2)  # [.., B, G]


def da_vmm_lut(xq: jax.Array, luts: jax.Array, cfg: DAConfig) -> jax.Array:
    """Faithful DA VMM: LUT gather (memory readout) + shift-and-add.

    xq:   [M, K] int32 codes (two's complement if cfg.x_signed)
    luts: [G, 2^L, N] from build_luts
    returns int32 [M, N] == xq @ W exactly.
    """
    addr = group_addresses(xq, cfg)  # [M, B, G]
    # Memory readout MR[m,b,g,:] = luts[g, addr[m,b,g], :]
    mr = jnp.take_along_axis(
        luts[None, None],  # [1,1,G,2^L,N]
        addr[..., None, None].astype(jnp.int32),  # [M,B,G,1,1]
        axis=3,
    )[..., 0, :]  # [M, B, G, N]
    per_cycle = jnp.sum(mr, axis=2)  # adder tree over PMAs → [M, B, N]
    coefs = jnp.asarray(bit_coefs(cfg.x_bits, cfg.x_signed), dtype=jnp.int32)
    # Shift-and-add accumulation (MSB-first in hardware; order-free here).
    return jnp.einsum("mbn,b->mn", per_cycle, coefs).astype(jnp.int32)


def da_vmm_onehot(xq: jax.Array, luts: jax.Array, cfg: DAConfig) -> jax.Array:
    """TPU-native DA VMM: the address decoder as one-hot, readout on the MXU.

    one-hot(addr) [M, G·2^L] @ luts [G·2^L, N] contracts groups and addresses
    in a single matmul — the systolic-array analogue of all PMAs reading and
    their adder tree summing in one cycle.
    """
    g, r, n = luts.shape
    addr = group_addresses(xq, cfg)  # [M, B, G]
    onehot = jax.nn.one_hot(addr, r, dtype=jnp.int32)  # [M, B, G, 2^L]
    m = xq.shape[0]
    b = cfg.x_bits
    flat = onehot.reshape(m * b, g * r)
    table = luts.reshape(g * r, n)
    per_cycle = jnp.matmul(flat, table, preferred_element_type=jnp.int32)
    per_cycle = per_cycle.reshape(m, b, n)
    coefs = jnp.asarray(bit_coefs(cfg.x_bits, cfg.x_signed), dtype=jnp.int32)
    return jnp.einsum("mbn,b->mn", per_cycle, coefs).astype(jnp.int32)


def da_vmm_bitplane(
    xq: jax.Array, wq: jax.Array, cfg: DAConfig, out_dtype=jnp.int32
) -> jax.Array:
    """Storage-free DA: Σ_b coef(b) · (xbit_b @ W). Bit-exact, LUT-free.

    This is the deployable mode for large LM layers (a 2^L/L× LUT blow-up per
    layer is the paper's 56×-more-cells trade-off; on TPU the MXU computes the
    per-cycle weight sums at full throughput instead).
    """
    mask = (1 << cfg.x_bits) - 1
    xm = jnp.bitwise_and(xq, mask)
    wi = wq.astype(jnp.int32)
    acc = jnp.zeros(xq.shape[:-1] + (wq.shape[-1],), dtype=jnp.int32)
    # MSB-first shift-and-add, mirroring the paper's LSIS accumulator:
    # acc ← 2·acc + (xbit_b @ W), with the sign-bit cycle subtracting.
    for b in range(cfg.x_bits - 1, -1, -1):
        plane = jnp.bitwise_and(jnp.right_shift(xm, b), 1)
        mr = jnp.matmul(plane, wi, preferred_element_type=jnp.int32)
        sign = -1 if (cfg.x_signed and b == cfg.x_bits - 1) else 1
        acc = acc + sign * (1 << b) * mr
    return acc.astype(out_dtype)


def da_vmm_bitplane_stacked(
    xq: jax.Array, wq: jax.Array, cfg: DAConfig, out_dtype=jnp.int32
) -> jax.Array:
    """Beyond-paper TPU mapping of bit-serial DA (§Perf lever L7).

    The hardware runs the 8 DA cycles serially *in time*, re-reading the PMA
    each cycle; a serial TPU port therefore reads W 8×. Stacking the 8
    bit-planes along the M dimension runs the cycles *spatially* on the MXU:

        Y = coefs · reshape( [xbit_7; …; xbit_0] @ W , (B, M, N) )

    — one int8 matmul, W read once. Bit-exact (== da_vmm_bitplane)."""
    mask = (1 << cfg.x_bits) - 1
    xm = jnp.bitwise_and(xq, mask)
    planes = jnp.stack(
        [jnp.bitwise_and(jnp.right_shift(xm, b), 1) for b in range(cfg.x_bits)]
    ).astype(jnp.int8)  # [B_bits, M, K] — bit axis is a LEADING batch dim so
    # the (data-)sharded M dim is never reshaped (a flat [8M, K] form makes
    # GSPMD all-gather the planes; einsum keeps the dot batched instead).
    mr = jnp.einsum(
        "bmk,kn->bmn", planes, wq.astype(jnp.int8),
        preferred_element_type=jnp.int32,
    )
    coefs = jnp.asarray(bit_coefs(cfg.x_bits, cfg.x_signed), dtype=jnp.int32)
    return jnp.einsum("bmn,b->mn", mr, coefs).astype(out_dtype)


def da_matmul(
    x: jax.Array,
    wq: jax.Array,
    w_scale: jax.Array,
    cfg: DAConfig,
    mode: str = "bitplane",
    luts: Optional[jax.Array] = None,
) -> jax.Array:
    """End-to-end DA linear: float in → quantize → DA integer VMM → dequantize.

    x: [.., K] float; wq int [K, N] with per-column w_scale [1, N] (or scalar).

    Legacy entry point, kept for callers holding raw (wq, w_scale, luts)
    triples; it wraps them in a PackedWeights artifact and dispatches through
    the unified engine (repro.core.engine), which owns the backend registry
    and the shape-aware ``"auto"`` policy.
    """
    from repro.core import engine  # deferred: engine imports this module

    packed = engine.PackedWeights(
        wq=wq, w_scale=jnp.asarray(w_scale, dtype=jnp.float32), luts=luts,
        cfg=cfg, mode=mode,
    )
    return engine.da_matmul(x, packed, cfg=cfg, mode=mode)
