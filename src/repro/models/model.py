"""LM assembly: scan-over-layers transformer / SSM / MoE / hybrid stacks.

Layers are grouped into *periods* (the layer-pattern repeat unit: 1 for
homogeneous stacks, 8 for jamba's attn:mamba 1:7 + MoE-every-2). Parameters of
each position within the period are stacked over ``n_periods`` and the model
scans over periods — one traced period regardless of depth, which keeps HLO
size and compile time flat for 80-layer models.

Modality stubs ([audio]/[vlm] per the assignment): the transformer backbone
accepts precomputed frame/patch embeddings [B, T, d_model] in place of token
ids; everything downstream is unchanged.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.launch.sharding import constrain
from repro.models.attention import KVCache, attention_forward, init_attention
from repro.models.config import ModelConfig
from repro.models.layers import (
    apply_embed,
    apply_lm_head,
    apply_mlp,
    apply_norm,
    init_embed,
    init_lm_head,
    init_mlp,
    init_norm,
)
from repro.models.mamba2 import MambaCache, init_mamba, mamba_forward
from repro.models.moe import init_moe, moe_forward

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# single block (mixer + ffn with pre-norms)
# ---------------------------------------------------------------------------
def init_block(key, cfg: ModelConfig, pos: int) -> Params:
    k1, k2 = jax.random.split(key)
    mixer = cfg.mixer_kind(pos)
    ffn = cfg.ffn_kind(pos)
    p: Params = {"norm_mixer": init_norm(cfg, cfg.d_model)}
    p["mixer"] = init_attention(k1, cfg) if mixer == "attn" else init_mamba(k1, cfg)
    if ffn != "none":
        p["norm_ffn"] = init_norm(cfg, cfg.d_model)
        p["ffn"] = (
            init_moe(k2, cfg) if ffn == "moe" else init_mlp(k2, cfg, cfg.d_model, cfg.d_ff)
        )
    return p


def block_forward(p, x, cfg: ModelConfig, pos: int, positions, cache,
                  update_cache, attn_bias=None, page_table=None):
    mixer = cfg.mixer_kind(pos)
    ffn = cfg.ffn_kind(pos)
    h = apply_norm(p["norm_mixer"], x, cfg)
    if mixer == "attn":
        y, new_cache = attention_forward(
            p["mixer"], h, cfg, positions, cache, update_cache,
            attn_bias=attn_bias, page_table=page_table,
        )
    else:
        y, new_cache = mamba_forward(p["mixer"], h, cfg, cache, update_cache)
    x = x + y
    if ffn != "none":
        h = apply_norm(p["norm_ffn"], x, cfg)
        y = moe_forward(p["ffn"], h, cfg) if ffn == "moe" else apply_mlp(p["ffn"], h, cfg)
        x = x + y
    return x, new_cache


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------
def init_model(key, cfg: ModelConfig) -> Params:
    keys = jax.random.split(key, 3)
    period = cfg.period
    layer_keys = jax.random.split(keys[0], cfg.n_periods * period).reshape(
        cfg.n_periods, period
    )
    periods = {}
    for pos in range(period):
        init_pos = functools.partial(init_block, cfg=cfg, pos=pos)
        periods[f"pos_{pos}"] = jax.vmap(lambda k: init_pos(k))(layer_keys[:, pos])
    params: Params = {
        "periods": periods,
        "final_norm": init_norm(cfg, cfg.d_model),
        "lm_head": init_lm_head(keys[1], cfg),
    }
    if cfg.modality == "text":
        params["embed"] = init_embed(keys[2], cfg)
    return params


def init_caches(cfg: ModelConfig, batch: int, max_len: int, dtype) -> Params:
    """Decode caches stacked over periods: {pos_i: cache[n_periods, ...]}."""

    def stack(template):
        return jax.tree.map(
            lambda a: jnp.zeros((cfg.n_periods,) + a.shape, a.dtype), template
        )

    caches = {}
    for pos in range(cfg.period):
        if cfg.mixer_kind(pos) == "attn":
            caches[f"pos_{pos}"] = stack(KVCache.zeros(cfg, batch, max_len, dtype))
        else:
            caches[f"pos_{pos}"] = stack(MambaCache.zeros(cfg, batch, dtype))
    return caches


def forward(
    params: Params,
    inputs: jax.Array,
    cfg: ModelConfig,
    positions: Optional[jax.Array] = None,
    caches: Optional[Params] = None,
    update_cache: bool = False,
    last_logit_only: bool = False,
    page_table: Optional[jax.Array] = None,
    last_idx: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[Params]]:
    """inputs: tokens [B, T] int32, or embeddings [B, T, D] (modality stubs).

    last_logit_only: slice the final hidden state BEFORE the LM head —
    prefill needs one position's logits, not T×V (§Perf lever L2).

    page_table: int32 [B, W] physical-page ids when ``caches`` hold
    PagedKVCache pools (the serving runtime's paged layout); loop-invariant
    across the layer scan, like the hoisted causal bias.  How the paged
    read executes — the XLA gather or the fused Pallas page-walk kernel —
    is ``cfg.paged_attn``, resolved per shape bucket by the engine's
    attention-backend registry (``core.engine.select_attn_backend``).

    last_idx: int32 [B] — per-row index of the last *real* token; the hidden
    state is gathered there before the LM head (the ragged-batch
    generalization of ``last_logit_only``, used by length-bucketed prefill
    and coalesced prefill+decode steps). Returns logits [B, 1, vocab].

    Returns (logits [B, T, vocab] or [B, 1, vocab], new_caches)."""
    if inputs.ndim == 2:
        h = apply_embed(params["embed"], inputs)
    else:
        h = constrain(inputs.astype(cfg.dtype()), ("batch", "seq", "embed"))
    b, t = h.shape[0], h.shape[1]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))

    period = cfg.period
    have_cache = caches is not None

    # L8: hoist the [T, T] causal bias out of the layer scan (built once,
    # reused by every attention layer — see attention.causal_bias).
    attn_bias = None
    if cfg.attn_impl == "lean" and t > 1:
        from repro.models.attention import causal_bias

        attn_bias = causal_bias(t)

    def period_fn(h, period_params, period_caches):
        new_caches = {}
        for pos in range(period):
            key = f"pos_{pos}"
            cache = period_caches[key] if have_cache else None
            h, nc = block_forward(
                period_params[key], h, cfg, pos, positions, cache,
                update_cache, attn_bias=attn_bias, page_table=page_table,
            )
            new_caches[key] = nc if nc is not None else 0
        return h, new_caches

    if cfg.remat:
        policy = {
            "nothing": jax.checkpoint_policies.nothing_saveable,
            "dots": jax.checkpoint_policies.checkpoint_dots,
        }[cfg.remat_policy]
        period_fn = jax.checkpoint(period_fn, policy=policy)

    def scan_body(h, xs):
        period_params, period_caches = xs
        h, new_caches = period_fn(h, period_params, period_caches)
        return h, new_caches

    if have_cache:
        xs = (params["periods"], caches)
    else:
        dummy = {f"pos_{i}": jnp.zeros((cfg.n_periods,)) for i in range(period)}
        xs = (params["periods"], dummy)
    h, new_caches = jax.lax.scan(
        scan_body, h, xs, unroll=cfg.n_periods if cfg.scan_unroll else 1
    )

    if last_idx is not None:
        h = jnp.take_along_axis(h, last_idx[:, None, None], axis=1)  # [B,1,D]
    elif last_logit_only:
        h = h[:, -1:]
    h = apply_norm(params["final_norm"], h, cfg)
    logits = apply_lm_head(params["lm_head"], h)
    return logits, (new_caches if have_cache else None)


# ---------------------------------------------------------------------------
# losses / parameter counting
# ---------------------------------------------------------------------------
def lm_loss(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token cross-entropy in f32.

    Measured note (§Perf F1): a one-hot-einsum gold pick was tried on the
    hypothesis that take_along_axis would make GSPMD all-gather the
    vocab-sharded logits — refuted: the partitioner handles the gather
    locally, and the materialized [B,T,V] one-hot *added* ~8% to the memory
    term. take_along_axis stands."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def count_params(cfg: ModelConfig) -> int:
    import math

    shapes = jax.eval_shape(lambda k: init_model(k, cfg), jax.random.key(0))
    return sum(math.prod(x.shape) for x in jax.tree.leaves(shapes))


def count_active_params(cfg: ModelConfig) -> int:
    """Active params for MoE (6·N_active·D roofline): routed experts count
    top_k/n_experts of their weights."""
    total = count_params(cfg)
    if not cfg.n_experts:
        return total
    from repro.models.moe import padded_experts

    e_pad = padded_experts(cfg)
    per_expert = 3 * cfg.d_model * cfg.moe_d_ff
    n_moe_layers = sum(
        1 for pos in range(cfg.period) if cfg.ffn_kind(pos) == "moe"
    ) * cfg.n_periods
    routed = n_moe_layers * e_pad * per_expert
    active = n_moe_layers * cfg.top_k * per_expert
    return total - routed + active
