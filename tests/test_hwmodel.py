"""Hardware cost model reproduces every number the paper reports (Table I,
§III-D, §IV) and scales per Fig. 5."""
import math

import pytest

from repro.core.hwmodel import (
    BitSliceDesign,
    DADesign,
    PJ,
    split_groups,
    table1,
)

CONV1 = dict(k=25, n=6)


def test_conv1_geometry():
    """§III: two 256×66 + one 512×66 arrays, 198 SAs, 12/13/21-bit adders."""
    d = DADesign(**CONV1)
    assert d.groups == [8, 8, 9]
    assert d.array_rows == [256, 256, 512]
    assert d.array_cols == 66
    assert d.memory_cells == 67584
    assert d.n_sense_amps == 198
    assert d.adder_widths == [12, 13, 21]


def test_latency_88ns():
    """§III-D: 15 + 7·10 + 3 = 88 ns."""
    assert DADesign(**CONV1).latency_ns() == pytest.approx(88.0)


def test_energy_110pj_and_amortized():
    d = DADesign(**CONV1)
    assert d.energy_vmm_j() / PJ == pytest.approx(110.2, rel=1e-6)
    # pre-VMM: 24576 adds ×52 fJ + 67584 writes ×1 pJ = 68.8 nJ → 6.88 pJ
    assert d.pre_vmm_energy_j() / 1e-9 == pytest.approx(68.8, rel=0.01)
    assert d.energy_per_vmm_amortized_j() / PJ == pytest.approx(117.0, rel=0.01)


def test_bitslice_baseline_numbers():
    """§IV: 25×48 array, 400 ns, 1421.5 pJ, 47286 T, 1584 R, 5-bit ADC."""
    b = BitSliceDesign(**CONV1)
    assert b.memory_cells == 1200
    assert b.adc_bits == 5
    assert b.latency_ns() == pytest.approx(400.0)
    assert b.energy_vmm_j() / PJ == pytest.approx(1421.5, rel=1e-6)
    assert round(b.transistors()) == 47286
    assert b.resistors() == 1584


def test_table1_ratios():
    """The paper's headline claims: 4.5× latency, 12× energy, 56× cells,
    2.3× transistors."""
    t = table1()
    assert t["latency_ratio"] == pytest.approx(4.5, rel=0.02)
    assert t["energy_ratio"] == pytest.approx(12.0, rel=0.05)
    assert t["cell_ratio"] == pytest.approx(56.0, rel=0.01)
    assert t["transistor_ratio"] == pytest.approx(2.3, rel=0.01)
    assert round(t["da"]["transistors"]) == 20622


def test_scaling_fig5():
    """Fig. 5: 16×16 → two 256-row PMAs, one extra adder stage; latency is
    still read-dominated (the stagger hides the extra stage)."""
    d8 = DADesign(k=8, n=8)
    d16 = DADesign(k=16, n=16)
    d32 = DADesign(k=32, n=32)
    assert d8.n_arrays == 1 and d16.n_arrays == 2 and d32.n_arrays == 4
    assert d16.array_cols == 16 * 11  # 176 columns (paper)
    # ≤3 PMAs: the 2 ns stagger hides inside the 10 ns read cycle → 88 ns
    assert d8.latency_ns() == pytest.approx(88.0)
    assert d16.latency_ns() == pytest.approx(88.0)
    # 4 PMAs (chain depth 3): stagger no longer fits the cycle → 15+7·11+5
    assert d32.latency_ns() == pytest.approx(97.0)
    # energy grows ~linearly with sensed columns
    assert d16.energy_vmm_j() > d8.energy_vmm_j()


def test_group_split_rules():
    assert split_groups(8) == [8]
    assert split_groups(16) == [8, 8]
    assert split_groups(25) == [8, 8, 9]
    assert split_groups(32) == [8, 8, 8, 8]
    assert split_groups(5) == [5]
    assert sum(split_groups(1000)) == 1000


def test_latency_independent_of_columns():
    """'If we had more columns (say 20 instead of 8), we will still require
    only 8 cycles' (§II-C)."""
    assert DADesign(k=8, n=8).latency_ns() == DADesign(k=8, n=20).latency_ns()


def test_energy_scales_to_lm_layer():
    """Model extends beyond the paper: a d_model×d_ff LM layer projection."""
    d = DADesign(k=4096, n=12288)
    assert d.memory_cells == sum(1 << g for g in d.groups) * 12288 * 11
    assert d.latency_ns() > 88.0  # deep adder tree stretches the tail
    assert d.energy_vmm_j() > 0


def test_tree_topology_beyond_paper():
    """Beyond-paper: pipelined adder tree keeps the cycle read-limited at any
    K (latency ~ 88 + 2.5·log2(PMAs)); the paper's chain is preserved for
    Table I. Fair ADC scaling keeps bit-slicing honest at large K."""
    d = DADesign(k=4096, n=4096, adder_topology="tree")
    assert d.latency_ns() == pytest.approx(
        88.0 + math.ceil(math.log2(512)) * 2.5
    )
    # tree never changes the CONV1 numbers (3 PMAs: same 88 ns)
    d3 = DADesign(k=25, n=6, adder_topology="tree")
    assert d3.latency_ns() == pytest.approx(88.0 + 2 * 2.5 - 0.0, abs=5.1)
    # fair ADC scaling: 4096-row bit-slicing needs a 13-bit ADC → 2^8 cost
    b = BitSliceDesign(k=4096, n=4096)
    assert b.adc_bits == 13
    b5 = BitSliceDesign(k=25, n=6)
    assert b._adc_scale == 2 ** 8 and b5._adc_scale == 1.0
    # the advantage survives at LM-layer scale with the tree design
    assert b.energy_vmm_j() / d.energy_vmm_j() > 10
    assert b.latency_ns() / d.latency_ns() > 3
