"""Draft providers: the three cheap passes behind one protocol.

A provider owns the *draft side* of speculative decoding: which parameters
the draft step consumes, whether it shares the target's paged KV pools or
needs its own, roughly what a draft step costs relative to a full step
(the breakeven input), and the step function itself.  The scheduler stays
provider-agnostic — it batches draft rounds into the same pow2-bucketed
step shapes it already compiles and hands every provider the same operands.

Step contract (all providers)::

    step(params, caches, tokens [B,T], positions [B,T], page_table [B,W],
         last_idx [B]) -> (logits [B,V], caches)

``T > 1`` is the catch-up form (a provider with its own KV ingests the
tokens the target accepted since its last draft; self-draft providers share
the target pools and never need it — the target's verified KV is *better*
draft context than their own writes).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from repro.core import engine
from repro.core.engine import PackedWeights
from repro.models.config import ModelConfig
from repro.models.model import count_params, forward
from repro.spec.decode import SpecConfig


@runtime_checkable
class DraftProvider(Protocol):
    """What the scheduler needs from a draft pass.

    name:         provider kind (metrics / logs).
    cost_ratio:   draft step cost / full step cost — the breakeven input.
    shared_cache: True → the draft writes into the TARGET's paged pools
                  (self-draft; verify overwrites its rows at full precision)
                  and never needs catch-up; False → the provider carries its
                  own pools, indexed by the same page tables.
    cfg:          ModelConfig the draft step runs under (positions /
                  M-RoPE shaping).
    params:       pytree the step consumes (jit argument, never baked in).
    """

    name: str
    cost_ratio: float
    shared_cache: bool
    cfg: ModelConfig
    params: Any

    def make_step(self) -> Callable:
        """Build the (untraced) draft step function; the scheduler jits it."""
        ...

    def init_caches(self, n_pages: int, page_size: int) -> Optional[Any]:
        """Provider-owned paged pools (None when ``shared_cache``)."""
        ...


def _artifact_x_bits(params: Any) -> Optional[int]:
    """x_bits of the first PackedWeights leaf, or None for float trees."""
    for leaf in jax.tree.leaves(
        params, is_leaf=lambda x: isinstance(x, PackedWeights)
    ):
        if isinstance(leaf, PackedWeights):
            return leaf.cfg.x_bits
    return None


class TruncatedBitplaneDraft:
    """Self-draft by bit-plane truncation (the DA-native drafter).

    Every DA linear of the *same* frozen artifact evaluates only the top
    ``x_bits_eff`` of its ``x_bits`` input bit-planes
    (:func:`repro.core.da.truncate_codes`): fewer bit-serial cycles against
    the same stored weight-sums, zero extra weight memory, works on
    artifact-frozen models straight off disk.  Draft cost scales with the
    evaluated planes, so ``cost_ratio = x_bits_eff / x_bits``.
    """

    name = "bitplane"
    shared_cache = True

    def __init__(self, cfg: ModelConfig, params: Any, x_bits_eff: int = 4):
        full = _artifact_x_bits(params)
        if full is None:
            raise ValueError(
                "truncated-bitplane self-draft needs DA-frozen params "
                "(PackedWeights leaves) — float weights have no bit-planes "
                "to truncate; freeze the model or pick another provider"
            )
        if not 1 <= x_bits_eff <= full:
            raise ValueError(
                f"draft_x_bits={x_bits_eff} outside [1, artifact x_bits={full}]"
            )
        self.cfg = cfg
        self.params = params
        self.x_bits_eff = x_bits_eff
        self.cost_ratio = x_bits_eff / full

    def make_step(self):
        cfg, bits = self.cfg, self.x_bits_eff

        def step(params, caches, tokens, positions, page_table, last_idx):
            # trace-time override: the whole forward quantizes as usual but
            # every engine backend walks only the top `bits` planes
            with engine.x_bits_override(bits):
                logits, caches = forward(
                    params, tokens, cfg, positions=positions, caches=caches,
                    update_cache=True, page_table=page_table,
                    last_idx=last_idx,
                )
            return logits[:, 0], caches

        return step

    def init_caches(self, n_pages: int, page_size: int) -> None:
        return None


class LayerSkipDraft:
    """Early-exit self-draft: run the first ``draft_periods`` period groups
    of the same weights, then the final norm + LM head (selfspec-style).

    The draft writes KV only for the layers it runs; verify overwrites every
    layer of the window at full precision, and the layers the draft reads
    hold the target's verified KV for all past positions — reusing the
    target cache is exactly what makes self-drafting cheap.
    """

    name = "layerskip"
    shared_cache = True

    def __init__(self, cfg: ModelConfig, params: Any,
                 draft_periods: Optional[int] = None):
        n = cfg.n_periods
        dp = draft_periods if draft_periods is not None else max(1, n // 2)
        if not 1 <= dp <= n:
            raise ValueError(
                f"draft_periods={dp} outside [1, n_periods={n}]"
            )
        self.cfg = cfg
        self.params = params
        self.draft_periods = dp
        self.cost_ratio = dp / n

    def make_step(self):
        cfg, dp = self.cfg, self.draft_periods
        dcfg = dataclasses.replace(cfg, n_layers=dp * cfg.period)

        def step(params, caches, tokens, positions, page_table, last_idx):
            head_params = {
                **params,
                "periods": jax.tree.map(lambda a: a[:dp], params["periods"]),
            }
            head_caches = jax.tree.map(lambda a: a[:dp], caches)
            logits, new_head = forward(
                head_params, tokens, dcfg, positions=positions,
                caches=head_caches, update_cache=True,
                page_table=page_table, last_idx=last_idx,
            )
            merged = jax.tree.map(
                lambda full, part: jnp.concatenate(
                    [part.astype(full.dtype), full[dp:]], axis=0
                ),
                caches, new_head,
            )
            return logits[:, 0], merged

        return step

    def init_caches(self, n_pages: int, page_size: int) -> None:
        return None


class ArtifactDraft:
    """A second frozen DAArtifact as the drafter (classic two-model spec).

    The draft model shares the tokenizer/vocabulary but carries its own
    paged pools — sized and page-table-indexed identically to the target's,
    so one host-side page table drives both (the lane's physical page ids
    are valid in either pool).  Catch-up: the provider has written KV up to
    the scheduler-tracked ``draft_pos``; the first draft step of a round
    feeds everything the target accepted since.
    """

    name = "artifact"
    shared_cache = False

    def __init__(self, target_cfg: ModelConfig, draft_cfg: ModelConfig,
                 draft_params: Any):
        if draft_cfg.vocab != target_cfg.vocab:
            raise ValueError(
                f"draft vocab {draft_cfg.vocab} != target vocab "
                f"{target_cfg.vocab} — spec decoding needs one token space"
            )
        for pos in range(draft_cfg.period):
            if draft_cfg.mixer_kind(pos) != "attn":
                raise ValueError(
                    "artifact draft models must be attention stacks (their "
                    "KV rides the same page tables as the target's)"
                )
        self.cfg = draft_cfg
        self.params = draft_params
        self.cost_ratio = min(
            1.0, count_params(draft_cfg) / max(1, count_params(target_cfg))
        )

    def make_step(self):
        cfg = self.cfg

        def step(params, caches, tokens, positions, page_table, last_idx):
            logits, caches = forward(
                params, tokens, cfg, positions=positions, caches=caches,
                update_cache=True, page_table=page_table, last_idx=last_idx,
            )
            return logits[:, 0], caches

        return step

    def init_caches(self, n_pages: int, page_size: int):
        from repro.serve.kvcache import init_paged_caches

        return init_paged_caches(self.cfg, n_pages, page_size,
                                 self.cfg.dtype())


def make_provider(spec: SpecConfig, cfg: ModelConfig,
                  params: Any) -> DraftProvider:
    """Resolve a SpecConfig to a constructed provider for ``(cfg, params)``."""
    if spec.provider == "bitplane":
        return TruncatedBitplaneDraft(cfg, params,
                                      x_bits_eff=spec.draft_x_bits)
    if spec.provider == "layerskip":
        return LayerSkipDraft(cfg, params, draft_periods=spec.draft_periods)
    if spec.provider == "artifact":
        if spec.draft_params is not None:
            if spec.draft_model_cfg is None:
                raise ValueError(
                    "draft_params without draft_model_cfg — pass both"
                )
            return ArtifactDraft(cfg, spec.draft_model_cfg, spec.draft_params)
        if spec.draft_artifact is None:
            raise ValueError(
                "provider='artifact' needs draft_artifact=DIR (a frozen "
                "DAArtifact directory) or in-memory draft_params + "
                "draft_model_cfg"
            )
        from repro.core.freeze import load_artifact

        art = load_artifact(spec.draft_artifact)
        if art.model_cfg is None:
            raise ValueError(
                f"draft artifact {spec.draft_artifact} carries no model "
                "config; freeze it with freeze_model(..., model_cfg=cfg)"
            )
        return ArtifactDraft(cfg, art.model_cfg, art.params)
    raise ValueError(
        f"unknown draft provider {spec.provider!r} "
        "(expected bitplane | layerskip | artifact)"
    )
