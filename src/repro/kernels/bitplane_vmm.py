"""Pallas TPU kernel: storage-free (bit-plane) Distributed Arithmetic VMM.

The deployable DA mode for large LM layers (DESIGN.md §2): instead of reading
precomputed weight sums from a materialized LUT, the MXU computes each
bit-serial cycle's weight sums on the fly —

    Y = Σ_b coef(b) · (xbit_b @ W),   xbit_b ∈ {0,1}

which is exactly the paper's per-cycle ``MR`` with the systolic array playing
the role of the processing-memory array. Multiplications involve only the
{0,1} bit operand (multiplier-free in the DA sense); accumulation is int32.

Tiling: grid = (M/bm, N/bn, K/bk). W is streamed through VMEM as int8-ranged
[bk, bn] tiles; the input tile [bm, bk] is decomposed into its 8 bit-planes
in-register. K is the reduction axis (output revisited, init at k == 0).

Exactness: each per-tile dot is a {0,1}-plane against weight codes, so its
value is ≤ bk·max|w| — kept < 2²⁴ (fp32's exact-integer range) by shrinking
the K tile to fit the actual weight-code magnitude (:func:`_fit_bk`); the
int32 accumulator covers the full growth.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.da import DAConfig, bit_coefs


def _bitplane_kernel(x_ref, w_ref, out_ref, *, cfg: DAConfig):
    k_idx = pl.program_id(2)
    x = x_ref[...]  # [bm, bk] int32 codes
    w = w_ref[...].astype(jnp.float32)  # [bk, bn]

    mask = (1 << cfg.x_bits) - 1
    xm = jnp.bitwise_and(x, mask)
    coefs = bit_coefs(cfg.x_bits, cfg.x_signed)

    acc = jnp.zeros(out_ref.shape, dtype=jnp.int32)
    for b in range(cfg.x_bits):  # unrolled bit-serial cycles
        plane = jnp.bitwise_and(jnp.right_shift(xm, b), 1).astype(jnp.float32)
        mr = jnp.dot(plane, w, preferred_element_type=jnp.float32)
        acc = acc + jnp.int32(coefs[b]) * mr.astype(jnp.int32)

    @pl.when(k_idx == 0)
    def _init():
        out_ref[...] = acc

    @pl.when(k_idx != 0)
    def _accum():
        out_ref[...] += acc


def _default_interpret() -> bool:
    """Platform-derived execution mode: compiled on TPU, interpret elsewhere."""
    return jax.default_backend() != "tpu"


def _weight_code_bound(wq: jax.Array, w_maxabs) -> int:
    """Magnitude bound on the weight codes, for the fp32-exact tile fit.

    Narrow integer storage (≤ 16 bits) bounds itself by dtype; wider storage
    is inspected when concrete, and must declare ``w_maxabs`` under tracing
    (the magnitude of a traced int32 operand is unknowable at trace time).
    """
    if w_maxabs is not None:
        w_maxabs = int(w_maxabs)
        if w_maxabs < 1:
            raise ValueError(f"w_maxabs={w_maxabs} must be >= 1")
        return w_maxabs
    if jnp.issubdtype(wq.dtype, jnp.integer) and jnp.iinfo(wq.dtype).bits <= 16:
        return int(jnp.iinfo(wq.dtype).max)
    if isinstance(wq, jax.core.Tracer):
        raise ValueError(
            f"bitplane_vmm_pallas: weight codes stored as {wq.dtype} under "
            "tracing — pass w_maxabs=<bound on |wq|> so the fp32-exact K "
            "tile can be sized"
        )
    return max(1, int(jnp.max(jnp.abs(wq))))


def _fit_bk(bk: int, w_maxabs: int) -> int:
    """Largest K tile ≤ bk with bk · w_maxabs < 2²⁴ (fp32-exact MXU pass)."""
    limit = (1 << 24) - 1
    if w_maxabs > limit:
        raise ValueError(
            f"weight-code magnitude {w_maxabs} exceeds the fp32 exact-integer "
            "range: no K tile keeps the bit-plane dot exact"
        )
    while bk > 1 and bk * w_maxabs > limit:
        bk //= 2
    return bk


def bitplane_vmm_pallas(
    xq: jax.Array,
    wq: jax.Array,
    cfg: DAConfig,
    bm: int = 256,
    bn: int = 256,
    bk: int = 512,
    interpret: bool | None = None,
    w_maxabs: int | None = None,
) -> jax.Array:
    """Bit-plane DA VMM via Pallas. xq [M,K] int codes, wq [K,N] int codes.

    Returns int32 [M, N] == xq @ wq exactly.  ``interpret=None`` derives the
    execution mode from the platform (compiled on TPU, interpret elsewhere).
    ``bk`` auto-shrinks so each {0,1}-plane dot stays within fp32's exact
    range for the actual weight-code magnitude (``w_maxabs``, defaulted from
    the storage dtype or the concrete codes).
    """
    if interpret is None:
        interpret = _default_interpret()
    bk = _fit_bk(bk, _weight_code_bound(wq, w_maxabs))
    return _bitplane_vmm_call(xq, wq, cfg, bm, bn, bk, interpret)


@functools.partial(jax.jit, static_argnames=("cfg", "bm", "bn", "bk", "interpret"))
def _bitplane_vmm_call(xq, wq, cfg, bm, bn, bk, interpret):
    m, k = xq.shape
    k2, n = wq.shape
    assert k == k2
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    pm, pn, pk = (-m) % bm, (-n) % bn, (-k) % bk
    if pm or pk:
        xq = jnp.pad(xq, ((0, pm), (0, pk)))
    if pk or pn:
        wq = jnp.pad(wq, ((0, pk), (0, pn)))
    mm, nn, kk = m + pm, n + pn, k + pk

    out = pl.pallas_call(
        functools.partial(_bitplane_kernel, cfg=cfg),
        grid=(mm // bm, nn // bn, kk // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, ki: (i, ki)),
            pl.BlockSpec((bk, bn), lambda i, j, ki: (ki, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, ki: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mm, nn), jnp.int32),
        interpret=interpret,
    )(xq.astype(jnp.int32), wq.astype(jnp.int32))
    return out[:m, :n]
