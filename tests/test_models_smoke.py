"""Per-architecture smoke tests (assignment requirement): reduced same-family
configs, one forward + one train step on CPU, asserting shapes + no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS, reduce_for_smoke, shapes_for
from repro.models.model import (
    count_active_params,
    count_params,
    forward,
    init_model,
    lm_loss,
)
from repro.train.trainer import TrainConfig, init_state, make_train_step

KEY = jax.random.key(0)

# Default (fast) runs smoke one arch per mixer family; the full per-arch
# sweep rides behind `-m slow` (multi-second jit compiles per config).
REPRESENTATIVE = {"qwen3-8b", "mamba2-780m", "qwen2-moe-a2.7b"}
ARCH_PARAMS = [
    name if name in REPRESENTATIVE
    else pytest.param(name, marks=pytest.mark.slow)
    for name in sorted(ARCHS)
]


def _inputs(cfg, b=2, t=16):
    if cfg.modality == "text":
        return jax.random.randint(KEY, (b, t), 0, cfg.vocab)
    return jax.random.normal(KEY, (b, t, cfg.d_model), dtype=jnp.float32)


@pytest.mark.parametrize("name", ARCH_PARAMS)
def test_forward_smoke(name):
    cfg = reduce_for_smoke(ARCHS[name])
    params = init_model(KEY, cfg)
    x = _inputs(cfg)
    logits, _ = forward(params, x, cfg)
    assert logits.shape == (2, 16, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


# Train-step smokes pay a bigger jit bill; default runs one arch, the rest
# ride behind -m slow.
TRAIN_ARCH_PARAMS = [
    name if name == "qwen3-8b" else pytest.param(name, marks=pytest.mark.slow)
    for name in sorted(ARCHS)
]


@pytest.mark.parametrize("name", TRAIN_ARCH_PARAMS)
def test_train_step_smoke(name):
    cfg = reduce_for_smoke(ARCHS[name])
    state = init_state(KEY, cfg)
    step = jax.jit(make_train_step(cfg, TrainConfig(total_steps=10)))
    batch = {
        "inputs": _inputs(cfg),
        "labels": jax.random.randint(KEY, (2, 16), 0, cfg.vocab),
    }
    state, metrics = step(state, batch)
    assert int(state.step) == 1
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["skipped"]) == 0.0
    gn = float(metrics["grad_norm"])
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_full_config_abstract(name):
    """FULL configs are exercised abstractly (eval_shape — no allocation):
    parameter counts in the expected band for each published size."""
    cfg = ARCHS[name]
    n = count_params(cfg)
    expected = {
        # total params incl. embeddings (untied), from the published configs
        "musicgen-large": (1.0e9, 3.0e9),
        "qwen2-vl-72b": (65e9, 80e9),
        "phi3-medium-14b": (12e9, 16e9),
        "mistral-nemo-12b": (11e9, 14e9),
        "minitron-8b": (7e9, 10.5e9),
        "qwen3-8b": (7e9, 10e9),
        "mamba2-780m": (0.6e9, 1.0e9),
        "qwen2-moe-a2.7b": (13e9, 16.5e9),   # 14.3B total / ~2.7B active
        # the assignment's 48L config (implemented verbatim) is larger than
        # the published 27L Moonlight-16B; active stays ~3-4B ("a3b")
        "moonshot-v1-16b-a3b": (26e9, 30e9),
        "jamba-1.5-large-398b": (370e9, 420e9),
    }[name]
    assert expected[0] < n < expected[1], f"{name}: {n/1e9:.2f}B params"
    a = count_active_params(cfg)
    assert a <= n
    if cfg.n_experts:
        assert a < 0.6 * n  # MoE: active ≪ total


def test_moe_active_params_sane():
    cfg = ARCHS["qwen2-moe-a2.7b"]
    a = count_active_params(cfg)
    assert 2.0e9 < a < 4.5e9  # “A2.7B”


def test_long_500k_skip_policy():
    """long_500k runs only for sub-quadratic mixers (DESIGN.md §4)."""
    for name, cfg in ARCHS.items():
        names = [s.name for s in shapes_for(cfg)]
        if cfg.family in ("ssm", "hybrid"):
            assert "long_500k" in names, name
        else:
            assert "long_500k" not in names, name
        assert {"train_4k", "prefill_32k", "decode_32k"} <= set(names)


def test_total_cells_count():
    total = sum(len(shapes_for(c)) for c in ARCHS.values())
    assert total == 32  # 10×3 + 2 long_500k (8 full-attention skips noted)
