"""Int8 gradient compression with error feedback (distributed-optimization
trick for bandwidth-bound data-parallel all-reduce).

The DP all-reduce moves ``4·N`` bytes per step in fp32; int8 compression cuts
the payload 4× at the cost of quantization noise, which error feedback (EF)
re-injects next step so the *accumulated* update is unbiased in practice
[Seide et al. 2014; Karimireddy et al. 2019]. Thematically this mirrors the
paper: both replace exact wide arithmetic with narrow integer codes plus a
correction structure (the paper's being exactness-by-construction, EF's being
exactness-in-expectation).

Used by the explicit shard_map DP path in train/trainer.py; under plain pjit
the all-reduce is GSPMD-internal and cannot be intercepted — documented.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def compress_leaf(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8. Returns (codes int8, scale f32)."""
    amax = jnp.max(jnp.abs(g))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_leaf(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init_error(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_with_ef(grads, error):
    """(grads + error) → int8 codes + new error residual."""
    def one(g, e):
        target = g.astype(jnp.float32) + e
        q, s = compress_leaf(target)
        recon = decompress_leaf(q, s)
        return (q, s), target - recon

    pairs = jax.tree.map(one, grads, error,
                         is_leaf=lambda x: isinstance(x, jax.Array))
    codes = jax.tree.map(lambda p: p[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
    new_error = jax.tree.map(lambda p: p[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
    return codes, new_error


def allreduce_compressed(grads, error, axis_name: str):
    """Inside shard_map: compress+EF with a *shared* scale (pmax of local
    amax), psum the int8 codes — the wire payload is the codes plus one
    scalar per tensor. Shared scale keeps the psum of codes exact w.r.t. the
    quantized values, so error feedback sees the true residual."""

    def one(g, e):
        target = g.astype(jnp.float32) + e
        amax = jax.lax.pmax(jnp.max(jnp.abs(target)), axis_name)
        scale = jnp.maximum(amax, 1e-12) / 127.0
        q = jnp.clip(jnp.round(target / scale), -127, 127).astype(jnp.int8)
        recon = q.astype(jnp.float32) * scale
        new_e = target - recon
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        n = jax.lax.psum(jnp.ones(()), axis_name)
        return total.astype(jnp.float32) * scale / n, new_e

    pairs = jax.tree.map(one, grads, error)
    is_pair = lambda x: isinstance(x, tuple)
    mean = jax.tree.map(lambda p: p[0], pairs, is_leaf=is_pair)
    new_error = jax.tree.map(lambda p: p[1], pairs, is_leaf=is_pair)
    return mean, new_error
