"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads artifacts/dryrun/*.json (written by repro.launch.dryrun) and prints the
per-cell three-term roofline, dominant bottleneck, MODEL_FLOPS/HLO ratio and
roofline fraction. Does not compile anything itself.
"""
from __future__ import annotations

import glob
import json
import os

ART = os.environ.get("DRYRUN_DIR", "artifacts/dryrun")


def load_cells(pattern: str = "*.json") -> list:
    cells = []
    for path in sorted(glob.glob(os.path.join(ART, pattern))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def main():
    cells = load_cells()
    if not cells:
        print(f"# no dry-run artifacts under {ART} — run "
              "`python -m repro.launch.dryrun --all` first")
        return
    print("# cell,ok,t_compute_s,t_memory_s,t_collective_s,bottleneck,"
          "useful_flops_frac,roofline_frac")
    n_ok = 0
    for c in cells:
        r = c.get("roofline", {})
        ok = c.get("ok", False)
        n_ok += bool(ok)
        print(
            f"{c['cell']},{ok},"
            f"{r.get('t_compute_s', 0):.3e},{r.get('t_memory_s', 0):.3e},"
            f"{r.get('t_collective_s', 0):.3e},{r.get('bottleneck', '-')},"
            f"{r.get('useful_flops_fraction', 0):.3f},"
            f"{r.get('roofline_fraction', 0):.4f}"
        )
    print(f"# {n_ok}/{len(cells)} cells ok")


if __name__ == "__main__":
    main()
