"""Paged KV cache unit tests: page math, the host-side pool allocator,
page-table materialization, and defrag (compaction moves pages, never
meaning)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serve.kvcache import (
    GARBAGE_PAGE,
    PagedKVCache,
    PagePool,
    defrag,
    pad_position,
    pages_for,
    table_array,
    table_width,
)


def test_page_math():
    assert pages_for(1, 8) == 1
    assert pages_for(8, 8) == 1
    assert pages_for(9, 8) == 2
    # table width = pages covering max_len + the garbage column
    assert table_width(24, 8) == 4
    assert table_width(25, 8) == 5
    # pad position sits at the start of the garbage column, strictly past
    # every legal real position
    assert pad_position(24, 8) == 24
    assert pad_position(20, 8) == 24
    assert pad_position(20, 8) > 20 - 1


def test_pool_alloc_free_exhaustion():
    pool = PagePool(6)  # page 0 reserved → 5 usable
    assert pool.free_pages == 5
    a = pool.alloc(3)
    assert a is not None and len(a) == 3 and GARBAGE_PAGE not in a
    assert pool.used_pages == 3
    # exhaustion returns None (backpressure), never a partial allocation
    assert pool.alloc(3) is None
    assert pool.free_pages == 2
    b = pool.alloc(2)
    assert pool.free_pages == 0 and pool.alloc(1) is None
    pool.free(a + b)
    assert pool.free_pages == 5
    stats = pool.stats()
    assert stats["alloc_count"] == 5 and stats["free_count"] == 5


def test_pool_rejects_bad_frees_and_tiny_pools():
    pool = PagePool(4)
    with pytest.raises(ValueError):
        pool.free([0])  # the garbage page is never allocatable
    with pytest.raises(ValueError):
        pool.free([4])  # out of range
    with pytest.raises(ValueError):
        PagePool(1)  # no room beside the garbage page


def test_table_array():
    t = table_array([[3, 1], [2], []], width=4)
    assert t.dtype == np.int32 and t.shape == (3, 4)
    np.testing.assert_array_equal(t[0], [3, 1, GARBAGE_PAGE, GARBAGE_PAGE])
    np.testing.assert_array_equal(t[2], [GARBAGE_PAGE] * 4)
    with pytest.raises(ValueError):
        # the garbage column may never be claimed by real pages
        table_array([[1, 2, 3, 4]], width=4)


def _pool_leaves(n_pages, ps, stacked: bool):
    """k/v pools whose value at (page, slot) encodes the page id — any page
    move that forgets to move the table (or vice versa) is visible."""
    kv, hd = 2, 3
    base = (
        jnp.arange(n_pages, dtype=jnp.float32)[:, None, None, None]
        * jnp.ones((n_pages, ps, kv, hd))
    )
    if stacked:
        base = jnp.stack([base, base + 100.0])  # period dim [P=2, pages, ...]
    return PagedKVCache(k=base, v=base + 0.5)


@pytest.mark.parametrize("stacked", [False, True])
def test_defrag_compacts_and_preserves_gathered_content(stacked):
    n_pages, ps = 9, 4
    pool = PagePool(n_pages)
    # simulate fragmentation: pages 1..8 allocated, then all but 5,2,7 freed
    all_pages = pool.alloc(8)
    tables = [[5, 2], [7]]
    pool.free([p for p in all_pages if p not in {5, 2, 7}])
    caches = {"pos_0": _pool_leaves(n_pages, ps, stacked)}

    def gathered(caches, tables):
        leaf = caches["pos_0"].k
        axis = leaf.ndim - 4
        return [np.asarray(jnp.take(leaf, jnp.asarray(t), axis=axis))
                for t in tables]

    before = gathered(caches, tables)
    caches = defrag(caches, pool, tables)
    after = gathered(caches, tables)
    # live pages now occupy the low-index prefix [1, 2, 3]
    assert sorted(p for t in tables for p in t) == [1, 2, 3]
    assert pool.free_pages == n_pages - 1 - 3
    for b, a in zip(before, after):
        np.testing.assert_array_equal(b, a)
