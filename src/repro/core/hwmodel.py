"""Analytic hardware cost model of the paper's circuits (§III-D, §IV, Table I).

The container is CPU-only, so the paper's transistor-level (SPICE) simulation
is replaced by a *component-calibrated analytic model*: every primitive
constant (sense time/energy, adder energy/bit, write energy, ADC/I-V cost,
transistor counts) is taken directly from the paper where stated, and the
few unstated periphery terms (decoder/WL overhead, DAC drive energy, analog
settling) are calibrated ONCE on the paper's CONV1 design point so that the
model reproduces Table I, then held fixed for every other geometry (scaling
sweeps, other layers, LM projections).

Paper-stated constants
----------------------
  precharge = discharge = sense       5 ns each (Fig. 8); first READ 15 ns,
                                      pipelined READ 10 ns (SA decouples BL)
  clocked ADD stage                   2.5 ns; final 21-bit add < 3 ns
  E_sense                             35 fJ per SA read
  E_add (11-bit weight-sum adder)     52 fJ  → 4.727 fJ/bit scaling
  E_write (ReRAM SET/RESET)           1 pJ/bit
  bit-slicing: E_read 506 fJ/col/cycle; E_IV+E_ADC ≈ 3 pJ/conversion;
  5-bit flash ADC = 679 T + 32 R; I-V op-amp + 1 R; DAC = TG 2:1 mux.

Calibrated on CONV1 (1×25 · 25×6, 8-bit):
  e_array_overhead  (decoder+WL+clock, per sensed column per cycle)
  e_dac             (WL drive per DAC toggle, bit-slicing)
  t_analog          (DAC settle + I-V + ADC conversion per cycle, bit-slicing)
  t_sa, t_adder_bit (transistor counts per SA / per adder bit)
"""
from __future__ import annotations

import dataclasses
import math
from typing import List

# ----------------------------------------------------------------------------
# Primitive constants (paper-stated unless marked CALIBRATED)
# ----------------------------------------------------------------------------
NS = 1e-9
FJ = 1e-15
PJ = 1e-12

T_PRECHARGE = 5.0  # ns
T_DISCHARGE = 5.0  # ns
T_SENSE = 5.0  # ns
T_READ_FIRST = T_PRECHARGE + T_DISCHARGE + T_SENSE  # 15 ns
T_READ_PIPE = 10.0  # ns (precharge overlapped with sensing)
T_ADD_STAGE = 2.5  # ns, clocked adder stage
T_FINAL_ADD = 3.0  # ns, last accumulate (paper: "< 3 ns")
T_STAGGER = 2.0  # ns, clk stagger between chained adder stages (Fig. 9)

E_SENSE = 35.0 * FJ  # per SA read
E_ADD_11BIT = 52.0 * FJ  # weight-summation adder
E_ADD_PER_BIT = E_ADD_11BIT / 11.0  # 4.727 fJ/bit
E_WRITE_BIT = 1.0 * PJ  # ReRAM SET/RESET per cell

# Bit-slicing primitives (§IV)
E_READ_COL_CYCLE = 506.0 * FJ  # BL current integration per column per cycle
E_ADC_IV = 3.0 * PJ  # I-V converter + 5-bit flash ADC per conversion
T_READ_BS = 10.0  # ns analog read (footnote 5: t_READ = 10 ns)
T_SHIFT = 2.5  # ns (D-flip-flop shift)

# Transistor-count library (CALIBRATED to Table I's 20622 / 47286 totals,
# using the same adder library on both sides)
T_SA = 21.0  # 9T comparator + TG + precharge + latch (Fig. 8)
T_ADDER_PER_BIT = (20622.0 - 198 * T_SA) / (6 * (12 + 13 + 21))  # = 59.652
T_DAC = 6.0  # TG-based 2:1 mux + inverter
T_ADC_5BIT = 679.0  # 31 comparators ×9T + therm-to-bin 400T (footnote 6)
R_ADC_5BIT = 32.0
R_IV = 1.0

# CALIBRATED on CONV1 so totals land exactly on the paper's simulated values:
# DA: 110.2 pJ total; reads 198·8·35fJ = 55.44 pJ; adders 8·6·46b·4.727fJ
#     = 10.44 pJ → overhead 44.32 pJ over 8 cycles × 198 cols = 27.97 fJ.
E_ARRAY_OVERHEAD = (110.2 * PJ - 198 * 8 * E_SENSE - 8 * 6 * 46 * E_ADD_PER_BIT) / (
    8 * 198
)
# Bit-slicing: 1421.5 pJ total = 8·(48·506fJ + 48·3pJ + 25·e_dac + adder/shift)
_BS_ADDER_BITS = 6 * (13 + 21)  # per-cycle shift-and-add datapath bits
E_DAC = (
    1421.5 * PJ
    - 8 * (48 * E_READ_COL_CYCLE + 48 * E_ADC_IV + _BS_ADDER_BITS * E_ADD_PER_BIT)
) / (8 * 25)
# Bit-slicing cycle: 400 ns / 8 = 50 ns = DAC+IV+ADC settling + read + 2 adds + shift
T_ANALOG = 50.0 - (T_READ_BS + 2 * T_ADD_STAGE + T_SHIFT)  # = 32.5 ns

# I-V converter transistor count calibrated so bit-slicing totals 47286.
T_IV = (
    47286.0
    - 48 * T_ADC_5BIT
    - 6 * (13 + 21) * T_ADDER_PER_BIT
    - 25 * T_DAC
) / 48.0


def _sum_bits(w_bits: int, base_group: int) -> int:
    """Width of a stored weight-sum (paper: 8 + log2(8) = 11)."""
    return w_bits + max(1, math.ceil(math.log2(max(2, base_group))))


def split_groups(k: int, base_group: int = 8) -> List[int]:
    """Partition K rows into PMA groups (paper: 25 → [8, 8, 9])."""
    if k <= base_group:
        return [k]
    g = k // base_group
    rem = k - g * base_group
    groups = [base_group] * g
    if rem:
        groups[-1] += rem  # fold remainder into the last PMA (paper's choice)
    return groups


@dataclasses.dataclass(frozen=True)
class DADesign:
    """DA in-memory VMM engine for a K×N weight matrix (§II-C, §III).

    adder_topology:
      "chain" — the paper's CONV1 design (PMA outputs added sequentially
                with 2 ns stagger; Table I). Cycle time stretches once the
                chain no longer fits a 10 ns read cycle — fine for ≤3 PMAs.
      "tree"  — beyond-paper: pipelined balanced adder tree (registers every
                level, 2.5 ns/level). Depth grows log2(PMAs); the cycle stays
                read-limited at any K, at the cost of more adders.
    """

    k: int
    n: int
    w_bits: int = 8
    x_bits: int = 8
    base_group: int = 8
    adder_topology: str = "chain"

    @property
    def groups(self) -> List[int]:
        return split_groups(self.k, self.base_group)

    @property
    def n_arrays(self) -> int:
        return len(self.groups)

    @property
    def sum_bits(self) -> int:
        return _sum_bits(self.w_bits, self.base_group)

    @property
    def array_rows(self) -> List[int]:
        return [1 << g for g in self.groups]

    @property
    def array_cols(self) -> int:
        return self.n * self.sum_bits

    @property
    def memory_cells(self) -> int:
        return sum(self.array_rows) * self.array_cols

    @property
    def n_sense_amps(self) -> int:
        return self.n_arrays * self.array_cols

    @property
    def acc_bits(self) -> int:
        """Accumulator width: full product growth (8+8+log2(25) → 21)."""
        return self.w_bits + self.x_bits + max(1, math.ceil(math.log2(self.k)))

    @property
    def adder_widths(self) -> List[int]:
        """Inter-PMA adder widths + accumulator, per output column.

        chain (CONV1, 3 PMAs): 12-bit (PMA1+PMA2), 13-bit (+PMA3), 21-bit acc.
        tree: level l has n_arrays/2^l adders of width sum_bits+l.
        """
        widths = []
        if self.adder_topology == "tree":
            remaining = self.n_arrays
            w = self.sum_bits
            while remaining > 1:
                w += 1
                widths.extend([w] * (remaining // 2))
                remaining = -(-remaining // 2)
        else:
            w = self.sum_bits
            for _ in range(self.n_arrays - 1):
                w += 1
                widths.append(w)
        widths.append(self.acc_bits)
        return widths

    @property
    def adder_chain_depth(self) -> int:
        if self.adder_topology == "tree":
            return max(0, math.ceil(math.log2(self.n_arrays))) if self.n_arrays > 1 else 0
        return self.n_arrays - 1

    # ---- latency ------------------------------------------------------------
    def latency_ns(self) -> float:
        """Single VMM latency (§III-D): 15 + (B−1)·10 + tail.

        chain: staggered 2 ns per stage inside each 10 ns cycle (Fig. 9);
        stretches the tail, and the cycle once the stagger no longer fits.
        tree: fully pipelined (register per level) — the cycle stays
        read-limited at any K; the tree depth adds latency once.
        """
        stages = self.adder_chain_depth
        if self.adder_topology == "tree":
            return (T_READ_FIRST + (self.x_bits - 1) * T_READ_PIPE
                    + T_FINAL_ADD + stages * T_ADD_STAGE)
        tail = T_FINAL_ADD + T_STAGGER * max(0, stages - 2)
        cycle = max(T_READ_PIPE, T_STAGGER * stages + T_SENSE)
        return T_READ_FIRST + (self.x_bits - 1) * cycle + tail

    # ---- energy -------------------------------------------------------------
    def energy_vmm_j(self) -> float:
        """Energy of one VMM (paper: 110.2 pJ for CONV1)."""
        reads = self.n_sense_amps * self.x_bits * (E_SENSE + E_ARRAY_OVERHEAD)
        adder_bits = self.n * sum(self.adder_widths)
        adds = self.x_bits * adder_bits * E_ADD_PER_BIT
        return reads + adds

    def energy_components_j(self) -> dict:
        """Per-VMM energy split: SA sensing, array periphery (decoder/WL/
        clock overhead, the CONV1-calibrated term), and the adder datapath.
        Every term is linear in ``x_bits`` — a truncated-bitplane pass at
        fewer input bits costs exactly proportionally less."""
        cycles = self.n_sense_amps * self.x_bits
        adder_bits = self.n * sum(self.adder_widths)
        return {
            "sense": cycles * E_SENSE,
            "array_overhead": cycles * E_ARRAY_OVERHEAD,
            "adder": self.x_bits * adder_bits * E_ADD_PER_BIT,
        }

    def pre_vmm_energy_j(self) -> float:
        """Once-in-a-lifetime weight summation + ReRAM write (§III-D).

        Adds: serial accumulator, avg popcount(L)/2 adds per LUT entry
        (paper: 24576 adds for CONV1). Write: 1 pJ/bit.
        """
        entries = sum(self.array_rows) * self.n
        n_adds = entries * (self.base_group // 2)
        return n_adds * E_ADD_11BIT + self.memory_cells * E_WRITE_BIT

    def energy_per_vmm_amortized_j(self, n_inferences: int = 10000) -> float:
        return self.energy_vmm_j() + self.pre_vmm_energy_j() / n_inferences

    # ---- area ---------------------------------------------------------------
    def transistors(self) -> float:
        sas = self.n_sense_amps * T_SA
        adders = self.n * sum(self.adder_widths) * T_ADDER_PER_BIT
        return sas + adders

    def summary(self) -> dict:
        return {
            "arrays": [f"{r}x{self.array_cols}" for r in self.array_rows],
            "memory_cells": self.memory_cells,
            "sense_amps": self.n_sense_amps,
            "adders": {f"{w}b": self.n for w in self.adder_widths},
            "latency_ns": self.latency_ns(),
            "energy_vmm_pj": self.energy_vmm_j() / PJ,
            "energy_amortized_pj": self.energy_per_vmm_amortized_j() / PJ,
            "pre_vmm_energy_nj": self.pre_vmm_energy_j() / 1e-9,
            "transistors": round(self.transistors()),
        }


@dataclasses.dataclass(frozen=True)
class BitSliceDesign:
    """ISAAC-style bit-slicing VMM engine (§IV, Fig. 10) — the baseline."""

    k: int
    n: int
    w_bits: int = 8
    x_bits: int = 8

    @property
    def array_cols(self) -> int:
        return self.n * self.w_bits

    @property
    def memory_cells(self) -> int:
        return self.k * self.array_cols

    @property
    def n_adcs(self) -> int:
        return self.array_cols

    @property
    def n_dacs(self) -> int:
        return self.k

    @property
    def adc_bits(self) -> int:
        """ADC resolution must cover the K-row column sum (§I: 'the ADC
        resolution increases with increase in the number of rows')."""
        return max(1, math.ceil(math.log2(self.k + 1)))

    @property
    def _adc_scale(self) -> float:
        """Flash-ADC cost doubles per extra bit (comparator count 2^b − 1);
        calibrated at the paper's 5-bit point."""
        return 2.0 ** (self.adc_bits - 5)

    @property
    def acc_bits(self) -> int:
        return self.w_bits + self.x_bits + max(1, math.ceil(math.log2(self.k)))

    @property
    def adder_widths(self) -> List[int]:
        # First shift-and-add undoes weight slicing (13b for CONV1);
        # second undoes input slicing (21b accumulator).
        return [self.adc_bits + self.w_bits, self.acc_bits]

    def latency_ns(self) -> float:
        cycle = T_ANALOG + T_READ_BS + 2 * T_ADD_STAGE + T_SHIFT  # 50 ns
        return self.x_bits * cycle

    def energy_vmm_j(self) -> float:
        per_cycle = (
            self.n_adcs * E_READ_COL_CYCLE
            + self.n_adcs * E_ADC_IV * self._adc_scale
            + self.n_dacs * E_DAC
            + self.n * sum(self.adder_widths) * E_ADD_PER_BIT
        )
        return self.x_bits * per_cycle

    def energy_components_j(self) -> dict:
        """Per-VMM energy split: BL reads, I-V + ADC conversions, DAC
        drive, and the shift-and-add datapath — all per input-bit cycle,
        so every term scales linearly in ``x_bits`` too."""
        return {
            "read": self.x_bits * self.n_adcs * E_READ_COL_CYCLE,
            "adc": self.x_bits * self.n_adcs * E_ADC_IV * self._adc_scale,
            "dac": self.x_bits * self.n_dacs * E_DAC,
            "adder": (self.x_bits * self.n * sum(self.adder_widths)
                      * E_ADD_PER_BIT),
        }

    def transistors(self) -> float:
        return (
            self.n_dacs * T_DAC
            + self.n_adcs * (T_IV + T_ADC_5BIT * self._adc_scale)
            + self.n * sum(self.adder_widths) * T_ADDER_PER_BIT
        )

    def resistors(self) -> int:
        return int(self.n_adcs * (R_ADC_5BIT * self._adc_scale + R_IV))

    def summary(self) -> dict:
        return {
            "array": f"{self.k}x{self.array_cols}",
            "memory_cells": self.memory_cells,
            "dacs": self.n_dacs,
            "adcs": self.n_adcs,
            "adc_bits": self.adc_bits,
            "latency_ns": self.latency_ns(),
            "energy_vmm_pj": self.energy_vmm_j() / PJ,
            "transistors": round(self.transistors()),
            "resistors": self.resistors(),
        }


def table1(k: int = 25, n: int = 6) -> dict:
    """Reproduce Table I for the CONV1 workload (or any K×N)."""
    da = DADesign(k=k, n=n)
    bs = BitSliceDesign(k=k, n=n)
    da_e = da.energy_per_vmm_amortized_j()
    return {
        "da": da.summary(),
        "bitslice": bs.summary(),
        "latency_ratio": bs.latency_ns() / da.latency_ns(),
        "energy_ratio": bs.energy_vmm_j() / da_e,
        "cell_ratio": da.memory_cells / bs.memory_cells,
        "transistor_ratio": bs.transistors() / da.transistors(),
    }
