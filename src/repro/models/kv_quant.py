"""KV-page quantization numerics shared by every paged-attention reader.

The paper stores *restructured precision* (weight-sums instead of weights) to
make inference multiplier-free; this module applies the same discipline to
the serving runtime's dominant memory consumer, the KV page pool. Pages hold
int8 codes (or two int4 nibbles packed per byte) and the dequantization
scales ride INSIDE the page allocation — shape ``[n_pages, ps, kv, 1]``
float16 beside the ``[n_pages, ps, kv, hd]`` codes — so a physical page
stays self-describing and every pool operation (COW ``copy_page``, defrag
remap, spec checkpoint/rollback, prefix-trie sharing) moves values and
scales together without ever dequantizing.

Scale granularity is one scale per (page slot, kv head) — finer than the
naive one-scale-per-page — because the runtime's exactness invariants demand
**write-once** rows:

* A per-page running absmax would either misinterpret earlier rows when a
  later row grows the scale, or force whole-page requantization on every
  write (accumulating rounding error and requiring in-step knowledge of
  which rows are live).
* Speculative decoding rolls rejected draft rows back by page-table
  bookkeeping alone; a draft row that had widened a shared page scale would
  leave a permanent numeric trace, breaking the spec==plain token-identity
  guarantee.  With per-row scales, a row is quantized exactly once, with its
  own absmax, and stale rows are masked out exactly like stale fp KV.

The storage overhead is ``2/hd`` bytes per element (~3% at hd=64) — the
``value_bytes_per_elem: 1, scale_bytes: 2`` memory model the ROADMAP prices.

Dequantization is one elementwise formula — ``codes.astype(compute) *
scale.astype(compute)`` — shared verbatim by the XLA gather read and the
fused Pallas page-walk kernel (these jnp ops trace inside Pallas), so the
two attention backends stay bit-identical on quantized pages just as they
are on fp pages.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

#: Recognized KV page dtypes. "fp16" is the escape hatch label: pages stay at
#: the model's compute dtype (fp16/bf16/f32), scales absent — byte-for-byte
#: today's layout.
KV_DTYPES = ("fp16", "int8", "int4")

#: Symmetric quantization ranges. int4 uses [-7, 7] (not -8) so the code
#: space is symmetric and the packed nibble always sign-extends cleanly.
KV_QMAX = {"int8": 127.0, "int4": 7.0}

#: Dtype the in-page scales are stored at (2 bytes per (slot, head)).
KV_SCALE_DTYPE = jnp.float16


def kv_format(k_pool: jax.Array, k_scale, head_dim: int) -> str:
    """Infer a pool's KV dtype from its arrays alone — pages self-describe.

    ``"fp"`` (unquantized, no scales), ``"int8"`` (codes at full head_dim) or
    ``"int4"`` (two nibbles per byte: codes at head_dim // 2).
    """
    if k_scale is None:
        return "fp"
    hd_p = k_pool.shape[-1]
    if hd_p == head_dim:
        return "int8"
    if 2 * hd_p == head_dim:
        return "int4"
    raise ValueError(
        f"quantized KV pool with head axis {hd_p} matches neither int8 "
        f"(head_dim={head_dim}) nor packed int4 (head_dim//2={head_dim // 2})"
    )


def pack_int4(codes: jax.Array) -> jax.Array:
    """Pack int8-held nibbles [-7, 7] pairwise along the last axis.

    ``[..., hd]`` → ``[..., hd // 2]``; element ``2i`` lands in the low
    nibble, ``2i+1`` in the high nibble of one int8 byte.
    """
    lo = jnp.bitwise_and(codes[..., 0::2].astype(jnp.int32), 0xF)
    hi = jnp.left_shift(jnp.bitwise_and(codes[..., 1::2].astype(jnp.int32),
                                        0xF), 4)
    return jnp.bitwise_or(lo, hi).astype(jnp.int8)


def unpack_int4(packed: jax.Array) -> jax.Array:
    """Inverse of :func:`pack_int4`: ``[..., hd // 2]`` int8 → ``[..., hd]``.

    Sign-extending shifts recover the exact stored integers, so any reader
    using this helper sees identical code values (ints are exact — the
    bit-parity between attention backends rests on this).
    """
    x = packed.astype(jnp.int32)
    lo = jnp.right_shift(jnp.left_shift(x, 28), 28)
    hi = jnp.right_shift(jnp.left_shift(x, 24), 28)
    both = jnp.stack([lo, hi], axis=-1)  # [..., hd//2, 2]
    return both.reshape(*packed.shape[:-1], 2 * packed.shape[-1]).astype(
        jnp.int8)


def quantize_kv(x: jax.Array, kv_dtype: str):
    """Quantize fresh KV rows ``[..., kv, hd]`` → ``(codes, scale)``.

    One symmetric absmax scale per ``[..., kv]`` row, **rounded to the
    storage dtype first** and the codes quantized against the rounded value
    — so ``codes * stored_scale`` at read time reproduces exactly what was
    intended at write time (write-once: a row is never reinterpreted under a
    different scale).  All-zero rows get scale 0 and codes 0.
    """
    qmax = KV_QMAX[kv_dtype]
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = (amax / qmax).astype(KV_SCALE_DTYPE)          # [..., kv, 1]
    s32 = scale.astype(jnp.float32)
    inv = jnp.where(s32 > 0, 1.0 / jnp.where(s32 > 0, s32, 1.0), 0.0)
    codes = jnp.clip(jnp.round(x.astype(jnp.float32) * inv), -qmax, qmax)
    codes = codes.astype(jnp.int8)
    if kv_dtype == "int4":
        codes = pack_int4(codes)
    return codes, scale


def dequantize_kv(codes: jax.Array, scale: jax.Array, kv_dtype: str,
                  out_dtype) -> jax.Array:
    """``codes [..., kv, hd(/2)]`` + ``scale [..., kv, 1]`` → fp rows.

    THE dequantization formula — both attention backends call exactly this
    (the gather read on the gathered view, the Pallas kernel on each DMA'd
    page in-register), so their dequantized elements are bitwise equal and
    the PR-6 backend bit-parity argument carries over to quantized pages.
    """
    if kv_dtype == "int4":
        codes = unpack_int4(codes)
    return codes.astype(out_dtype) * scale.astype(out_dtype)
