"""LeNet-5 CONV1 inference through the DA in-memory engine (paper §II-B, §III).

Maps each 5×5 convolution stride to a 1×25 · 25×6 VMM (Fig. 3 im2col), runs
all 784 strides through the faithful LUT datapath, verifies exactness against
the direct convolution, and prints the hardware-model cost of the full layer.

Run: PYTHONPATH=src python examples/lenet_da_inference.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core.da import DAConfig
from repro.core.engine import da_vmm, pack_quantized
from repro.core.hwmodel import BitSliceDesign, DADesign
from repro.core.quant import quantize_weights


def im2col(img: np.ndarray, kh: int = 5, kw: int = 5) -> np.ndarray:
    """32×32 image → [784, 25] stride patches (paper Fig. 3 unrolling)."""
    h, w = img.shape
    oh, ow = h - kh + 1, w - kw + 1
    cols = np.empty((oh * ow, kh * kw), dtype=img.dtype)
    idx = 0
    for i in range(oh):
        for j in range(ow):
            cols[idx] = img[i : i + kh, j : j + kw].reshape(-1)
            idx += 1
    return cols


def main():
    rng = np.random.default_rng(42)
    # A synthetic 'digit': bright strokes on dark background, 8-bit grayscale.
    img = np.zeros((32, 32), dtype=np.int32)
    img[8:24, 14:18] = 220
    img[8:12, 10:18] = 200
    img += rng.integers(0, 30, (32, 32))

    filters = rng.normal(size=(6, 5, 5)).astype(np.float32)
    wq = quantize_weights(jnp.asarray(filters.reshape(6, 25).T))

    print("pre-VMM: summing weights and writing three PMAs "
          "(two 256x66, one 512x66) ...")
    cfg = DAConfig(x_signed=False)
    packed = pack_quantized(wq.q, wq.scale, cfg=cfg)     # LUTs built once

    cols = im2col(img)                                   # 784 strides
    acc = da_vmm(jnp.asarray(cols), packed, mode="lut")  # faithful PMA readout
    feature_maps = np.asarray(acc).reshape(28, 28, 6).transpose(2, 0, 1)

    ref = (cols @ np.asarray(wq.q)).reshape(28, 28, 6).transpose(2, 0, 1)
    assert (feature_maps == ref).all()
    print(f"CONV1 done: 784 VMMs -> 6 feature maps 28x28, "
          f"bit-exact vs direct convolution ✓")

    da, bs = DADesign(k=25, n=6), BitSliceDesign(k=25, n=6)
    print(f"\nprojected on ReRAM engine (hardware model, Table I constants):")
    print(f"  DA        : {784*da.latency_ns()/1e3:8.1f} us, "
          f"{784*da.energy_vmm_j()*1e9:8.2f} nJ per image")
    print(f"  bit-slice : {784*bs.latency_ns()/1e3:8.1f} us, "
          f"{784*bs.energy_vmm_j()*1e9:8.2f} nJ per image")
    print(f"  one-time pre-VMM cost: {da.pre_vmm_energy_j()*1e9:.1f} nJ "
          f"(amortized {da.pre_vmm_energy_j()*1e12/10000:.2f} pJ over 10k inferences)")
    act = feature_maps[0]
    print(f"\nfeature map 0 stats: min={act.min()} max={act.max()} "
          f"mean={act.mean():.1f}")


if __name__ == "__main__":
    main()
