"""Trace frozen serving step functions to jaxpr + optimized HLO.

The passes need two views of every step the scheduler launches: the jaxpr
(for the taint-based ``multiplier-free`` pass — it keeps the Pallas kernel
bodies and weight-leaf structure the HLO fuses away) and the compiled HLO
text (for the structural byte/op passes).  :func:`trace_serving_steps`
builds both for the decode, chunked-prefill and speculative-draft step
functions, under the gather *and* fused attention backends, with the same
synthetic paged-cache arguments the scheduler warms up with.

Taint seeding mirrors the freeze planner's notion of "weight leaf"
(``core.freeze.DA_LEAF_NAMES`` / ``SKIP_CONTEXT``): integer ``PackedWeights``
children (codes, LUTs) seed ``INT_EXACT``; float weight matrices (the
unfrozen baseline) seed ``FLOAT``; dequant scales (``w_scale``), router/
embedding/conv leaves and everything non-weight seed nothing.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.passes import Flavor, Taint, UNTAINTED
from repro.core.engine import path_entry_name
from repro.core.freeze import DA_LEAF_NAMES, SKIP_CONTEXT


@dataclasses.dataclass
class TracedStep:
    """One serving step function, traced for the pass pipeline.

    view_bytes: size of the re-materialized ``[B, W·ps, kv, hd]`` page-
    table KV view at the narrowest pool dtype — the ``no-big-gather``
    threshold.  fused: this lowering claims the in-kernel page walk (the
    gather pass only gates fused lowerings).
    """

    name: str
    closed_jaxpr: Any
    hlo: str
    arg_taints: List[Taint]
    view_bytes: int
    fused: bool


def arg_taints(args: Any) -> List[Taint]:
    """Seed taints for one flattened argument tree (the same flattening
    order ``jax.make_jaxpr`` binds invars in)."""
    flat = jax.tree_util.tree_flatten_with_path(args)[0]
    out: List[Taint] = []
    for path, leaf in flat:
        names = [path_entry_name(p) for p in path]
        out.append(_leaf_taint(names, leaf))
    return out


def _leaf_taint(names: Sequence[str], leaf: Any) -> Taint:
    if not names or any(n in SKIP_CONTEXT for n in names):
        return UNTAINTED
    last = names[-1]
    if last == "w_scale":
        # dequant metadata: scaling an accumulated inner product is the
        # paper-sanctioned float epilogue, not a weight multiply
        return UNTAINTED
    if last in ("wq", "luts"):
        return Taint(Flavor.INT_EXACT, False)
    if last in DA_LEAF_NAMES:
        dtype = getattr(leaf, "dtype", None)
        if dtype is not None and np.issubdtype(dtype, np.integer):
            return Taint(Flavor.INT_EXACT, False)
        return Taint(Flavor.FLOAT, False)
    return UNTAINTED


def _min_pool_itemsize(caches: Any) -> int:
    """Narrowest dtype across the paged KV pools: a gather of the whole
    page-table view is a violation even at int8/int4 code width."""
    sizes = [leaf.dtype.itemsize for leaf in jax.tree_util.tree_leaves(caches)
             if hasattr(leaf, "dtype")]
    return min(sizes) if sizes else 4


def page_view_bytes(cfg: Any, batch_size: int, table_width: int,
                    page_size: int, itemsize: int) -> int:
    """Bytes of one re-materialized ``[B, W·ps, kv, hd]`` KV view."""
    return (batch_size * table_width * page_size * cfg.n_kv_heads
            * cfg.head_dim_ * itemsize)


def _trace_one(name: str, fn: Any, args: Tuple[Any, ...], view_bytes: int,
               fused: bool, compile_hlo: bool) -> TracedStep:
    closed = jax.make_jaxpr(fn)(*args)
    hlo = ""
    if compile_hlo:
        hlo = jax.jit(fn).lower(*args).compile().as_text()
    return TracedStep(
        name=name, closed_jaxpr=closed, hlo=hlo,
        arg_taints=arg_taints(args), view_bytes=view_bytes, fused=fused,
    )


def supports_paged_tracing(cfg: Any) -> bool:
    """The paged step functions cover pure-attention *text* stacks.
    SSM/hybrid configs still serve through the slot runtime (ROADMAP open
    item), and embedding-input modalities (audio frames, vision patches)
    have no token embed table for the paged token step to drive."""
    try:
        if getattr(cfg, "modality", "text") != "text":
            return False
        return all(cfg.mixer_kind(i) == "attn" for i in range(cfg.period))
    except Exception:
        return False


def trace_serving_steps(
    params: Any,
    cfg: Any,
    *,
    batch_size: int = 2,
    max_len: int = 32,
    page_size: int = 8,
    prefill_chunk: int = 8,
    spec_gamma: int = 0,
    spec_x_bits: int = 4,
    backends: Sequence[str] = ("gather", "fused"),
    compile_hlo: bool = True,
) -> List[TracedStep]:
    """Trace decode / chunked-prefill (/ spec-draft) steps for each
    attention backend, with synthetic args shaped like a live scheduler."""
    from repro.serve.kvcache import (
        init_paged_caches, pages_for, table_width,
    )
    from repro.serve.scheduler import make_paged_step
    from repro.spec.decode import mk_positions

    if not supports_paged_tracing(cfg):
        raise ValueError(
            f"config {getattr(cfg, 'name', cfg)} is outside the paged "
            "tracer's coverage (non-attention mixers, or an embedding-input "
            "modality with no token step to trace)"
        )
    b, ps = batch_size, page_size
    w = table_width(max_len, ps)
    n_pages = 1 + b * pages_for(max_len, ps)
    steps: List[TracedStep] = []
    for backend in backends:
        cfg_b = dataclasses.replace(cfg, paged_attn=backend)
        caches = init_paged_caches(cfg_b, n_pages, ps, cfg_b.dtype())
        view = page_view_bytes(cfg_b, b, w, ps, _min_pool_itemsize(caches))
        step = make_paged_step(cfg_b)
        fused = backend == "fused"

        def args_for(t: int) -> Tuple[Any, ...]:
            return (
                params, caches,
                jnp.zeros((b, t), jnp.int32),
                mk_positions(cfg_b, jnp.zeros((b, t), jnp.int32)),
                jnp.zeros((b, w), jnp.int32),
                jnp.zeros((b,), jnp.int32),
            )

        steps.append(_trace_one(
            f"decode[{backend}]", step, args_for(1), view, fused,
            compile_hlo,
        ))
        if prefill_chunk > 1:
            steps.append(_trace_one(
                f"prefill[{backend}]", step, args_for(prefill_chunk), view,
                fused, compile_hlo,
            ))
        if spec_gamma > 0 and fused:
            draft = _make_draft(cfg_b, params, spec_gamma, spec_x_bits)
            if draft is not None:
                steps.append(_trace_one(
                    f"spec_draft[{backend}]", draft, args_for(1), view,
                    fused, compile_hlo,
                ))
    return steps


def _make_draft(cfg: Any, params: Any, gamma: int,
                x_bits: int) -> Optional[Any]:
    """The fused truncated-bitplane draft loop, or None for float params
    (no bit-planes to truncate — nothing extra to trace)."""
    from repro.spec.decode import make_fused_draft
    from repro.spec.providers import TruncatedBitplaneDraft

    try:
        provider = TruncatedBitplaneDraft(cfg, params, x_bits_eff=x_bits)
    except ValueError:
        return None
    return make_fused_draft(provider.make_step(), cfg, gamma)
