"""Paged KV cache unit tests: page math, the host-side pool allocator,
page-table materialization, speculative checkpoint/rollback (rejected
drafts leave no trace), and defrag (compaction moves pages, never
meaning)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serve.kvcache import (
    GARBAGE_PAGE,
    PagedKVCache,
    PagePool,
    checkpoint,
    defrag,
    pad_position,
    pages_for,
    rollback,
    table_array,
    table_width,
)


def test_page_math():
    assert pages_for(1, 8) == 1
    assert pages_for(8, 8) == 1
    assert pages_for(9, 8) == 2
    # table width = pages covering max_len + the garbage column
    assert table_width(24, 8) == 4
    assert table_width(25, 8) == 5
    # pad position sits at the start of the garbage column, strictly past
    # every legal real position
    assert pad_position(24, 8) == 24
    assert pad_position(20, 8) == 24
    assert pad_position(20, 8) > 20 - 1


def test_pool_alloc_free_exhaustion():
    pool = PagePool(6)  # page 0 reserved → 5 usable
    assert pool.free_pages == 5
    a = pool.alloc(3)
    assert a is not None and len(a) == 3 and GARBAGE_PAGE not in a
    assert pool.used_pages == 3
    # exhaustion returns None (backpressure), never a partial allocation
    assert pool.alloc(3) is None
    assert pool.free_pages == 2
    b = pool.alloc(2)
    assert pool.free_pages == 0 and pool.alloc(1) is None
    pool.free(a + b)
    assert pool.free_pages == 5
    stats = pool.stats()
    assert stats["alloc_count"] == 5 and stats["free_count"] == 5


def test_pool_rejects_bad_frees_and_tiny_pools():
    pool = PagePool(4)
    with pytest.raises(ValueError):
        pool.free([0])  # the garbage page is never allocatable
    with pytest.raises(ValueError):
        pool.free([4])  # out of range
    with pytest.raises(ValueError):
        PagePool(1)  # no room beside the garbage page


def test_table_array():
    t = table_array([[3, 1], [2], []], width=4)
    assert t.dtype == np.int32 and t.shape == (3, 4)
    np.testing.assert_array_equal(t[0], [3, 1, GARBAGE_PAGE, GARBAGE_PAGE])
    np.testing.assert_array_equal(t[2], [GARBAGE_PAGE] * 4)
    with pytest.raises(ValueError):
        # the garbage column may never be claimed by real pages
        table_array([[1, 2, 3, 4]], width=4)


# ---------------------------------------------------------------------------
# checkpoint / rollback: speculative page growth must be fully revocable
# ---------------------------------------------------------------------------
def _pool_state(pool: PagePool):
    """Complete observable allocator state (free-list ORDER included)."""
    return (list(pool._free), pool.stats())


def test_rollback_restores_pool_and_table_bit_identical():
    """checkpoint → allocate draft pages (+ writes) → reject all → state
    bit-identical to never having speculated: same table, same free-list
    order, same counters."""
    pool = PagePool(10)
    table = pool.alloc(2)          # the lane's pre-spec pages
    before = (_pool_state(pool), list(table))
    ck = checkpoint(pool, table)
    table.extend(pool.alloc(3))    # gamma draft tokens grow 3 pages
    assert len(table) == 5
    freed = rollback(pool, table, ck)
    assert len(freed) == 3
    assert (_pool_state(pool), list(table)) == before
    # idempotent: rolling back again is a no-op
    assert rollback(pool, table, ck) == []
    assert (_pool_state(pool), list(table)) == before
    # and the next allocation hands out the same pages in the same order
    assert pool.alloc(3) == freed


def test_rollback_partial_keep_retains_accepted_prefix():
    """A round that accepted some tokens keeps the prefix covering them;
    only the rejected suffix returns to the pool (head-first)."""
    pool = PagePool(10)
    table = pool.alloc(1)
    base = list(table)
    ck = checkpoint(pool, table)
    grown = pool.alloc(4)
    table.extend(grown)
    freed = rollback(pool, table, ck, keep=3)  # accepted ctx needs 3 pages
    assert freed == grown[2:]
    assert table == base + grown[:2]
    assert pool.stats()["alloc_count"] == 3  # rejected allocs un-counted
    # keep below the checkpoint never shrinks pre-spec pages
    assert rollback(pool, table, ck, keep=0) == grown[:2]
    assert len(table) == 1


def test_rollback_invalid_page_leaves_state_untouched():
    """An invalid id in the rolled-back suffix must error BEFORE any
    mutation — a half-rolled-back pool would defeat the function's whole
    guarantee."""
    pool = PagePool(8)
    table = pool.alloc(2)
    ck = checkpoint(pool, table)
    table.extend(pool.alloc(1))
    table.append(0)  # corrupt suffix: the garbage page is never allocatable
    before = (list(pool._free), pool.stats(), list(table))
    with pytest.raises(ValueError):
        rollback(pool, table, ck)
    assert (list(pool._free), pool.stats(), list(table)) == before


def test_rollback_state_identical_across_defrag():
    """The leak-proofness bar: a checkpoint→write→reject cycle followed by a
    defrag pass ends bit-identical (pool, tables, live cache content) to a
    timeline where the speculation never happened."""
    n_pages, ps = 12, 4

    def fragmented():
        pool = PagePool(n_pages)
        t0, t1 = pool.alloc(3), pool.alloc(2)
        pool.free([t0.pop(1)])     # punch a hole: pages {1,3} + {4,5} live
        caches = {"pos_0": _pool_leaves(n_pages, ps, stacked=False)}
        return pool, [t0, t1], caches

    # timeline A: speculation on lane 0, fully rejected
    pool_a, tables_a, caches_a = fragmented()
    ck = checkpoint(pool_a, tables_a[0])
    tables_a[0].extend(pool_a.alloc(3))     # draft writes land here
    caches_a["pos_0"] = PagedKVCache(       # scribble into the draft pages
        k=caches_a["pos_0"].k.at[tables_a[0][-1]].add(99.0),
        v=caches_a["pos_0"].v,
    )
    rollback(pool_a, tables_a[0], ck)
    # timeline B: no speculation ever
    pool_b, tables_b, caches_b = fragmented()

    assert _pool_state(pool_a) == _pool_state(pool_b)
    assert tables_a == tables_b
    caches_a = defrag(caches_a, pool_a, tables_a)
    caches_b = defrag(caches_b, pool_b, tables_b)
    assert _pool_state(pool_a) == _pool_state(pool_b)
    assert tables_a == tables_b
    for ta, tb in zip(tables_a, tables_b):
        ga = np.asarray(jnp.take(caches_a["pos_0"].k, jnp.asarray(ta), axis=0))
        gb = np.asarray(jnp.take(caches_b["pos_0"].k, jnp.asarray(tb), axis=0))
        np.testing.assert_array_equal(ga, gb)


def test_rollback_interleaved_allocations_keep_membership_exact():
    """Under interleaved allocs from other lanes, rollback still frees
    exactly the rejected pages (no leak, no double-free), even though the
    free-list order may legitimately differ."""
    pool = PagePool(12)
    lane_a, lane_b = pool.alloc(2), pool.alloc(2)
    ck_a = checkpoint(pool, lane_a)
    lane_a.extend(pool.alloc(2))
    lane_b.extend(pool.alloc(2))   # interleaved growth of another lane
    rollback(pool, lane_a, ck_a)
    assert len(lane_a) == 2
    live = set(lane_a) | set(lane_b)
    assert set(pool._free) == set(range(1, 12)) - live
    assert len(pool._free) + len(live) == 11


def _pool_leaves(n_pages, ps, stacked: bool):
    """k/v pools whose value at (page, slot) encodes the page id — any page
    move that forgets to move the table (or vice versa) is visible."""
    kv, hd = 2, 3
    base = (
        jnp.arange(n_pages, dtype=jnp.float32)[:, None, None, None]
        * jnp.ones((n_pages, ps, kv, hd))
    )
    if stacked:
        base = jnp.stack([base, base + 100.0])  # period dim [P=2, pages, ...]
    return PagedKVCache(k=base, v=base + 0.5)


@pytest.mark.parametrize("stacked", [False, True])
def test_defrag_compacts_and_preserves_gathered_content(stacked):
    n_pages, ps = 9, 4
    pool = PagePool(n_pages)
    # simulate fragmentation: pages 1..8 allocated, then all but 5,2,7 freed
    all_pages = pool.alloc(8)
    tables = [[5, 2], [7]]
    pool.free([p for p in all_pages if p not in {5, 2, 7}])
    caches = {"pos_0": _pool_leaves(n_pages, ps, stacked)}

    def gathered(caches, tables):
        leaf = caches["pos_0"].k
        axis = leaf.ndim - 4
        return [np.asarray(jnp.take(leaf, jnp.asarray(t), axis=axis))
                for t in tables]

    before = gathered(caches, tables)
    caches = defrag(caches, pool, tables)
    after = gathered(caches, tables)
    # live pages now occupy the low-index prefix [1, 2, 3]
    assert sorted(p for t in tables for p in t) == [1, 2, 3]
    assert pool.free_pages == n_pages - 1 - 3
    for b, a in zip(before, after):
        np.testing.assert_array_equal(b, a)


# ---------------------------------------------------------------------------
# refcounted ownership: double-free detection, sharing, the prefix trie
# ---------------------------------------------------------------------------
def test_pool_double_free_raises_and_leaves_state_intact():
    """Regression: a double-freed page used to enter the free list twice and
    get handed to two requests (silent KV corruption). Every release is now
    checked against the refcount ledger BEFORE any state moves."""
    pool = PagePool(6)
    a = pool.alloc(2)
    pool.free([a[0]])
    before = (list(pool._free), pool.stats())
    with pytest.raises(ValueError, match="double-free"):
        pool.free([a[0]])
    with pytest.raises(ValueError, match="double-free"):
        pool.free([a[1], a[1]])  # duplicates inside one call count too
    assert (list(pool._free), pool.stats()) == before
    pool.free([a[1]])
    assert pool.used_pages == 0


def test_pool_refcount_sharing():
    pool = PagePool(6)
    (p,) = pool.alloc(1)
    pool.incref([p])
    assert pool.refcount(p) == 2 and pool.shared_pages == 1
    pool.free([p])          # one sharer lets go: page stays live
    assert pool.refcount(p) == 1 and pool.used_pages == 1
    pool.free([p])          # last owner: page returns to the free list
    assert pool.used_pages == 0
    with pytest.raises(ValueError):
        pool.incref([p])    # no longer live — nothing to share


def test_rollback_keep_beyond_table_raises():
    """Regression: keep > len(table) used to return [] silently, masking an
    upstream accounting error (accepted context claiming pages that were
    never allocated)."""
    pool = PagePool(8)
    table = pool.alloc(2)
    ck = checkpoint(pool, table)
    before = (_pool_state(pool), list(table))
    with pytest.raises(ValueError, match="never allocated"):
        rollback(pool, table, ck, keep=3)
    assert (_pool_state(pool), list(table)) == before


def test_rollback_refuses_shared_pages():
    """A draft must own its speculative growth exclusively: rolling back a
    page another owner shares would yank KV out from under the sharer."""
    pool = PagePool(8)
    table = pool.alloc(1)
    ck = checkpoint(pool, table)
    grown = pool.alloc(2)
    table.extend(grown)
    pool.incref([grown[1]])  # someone else now references a drafted page
    before = (list(pool._free), list(table))
    with pytest.raises(ValueError, match="shared page"):
        rollback(pool, table, ck)
    assert (list(pool._free), list(table)) == before


def test_prefix_trie_match_insert_claim():
    from repro.serve.kvcache import PrefixCache

    ps = 4
    pool = PagePool(12)
    trie = PrefixCache(ps)
    toks = list(range(10))        # 2 full pages + 2 tokens
    pages = pool.alloc(3)
    assert trie.match(toks) == ([], 0)
    assert trie.insert(toks, pages, pool) == 2   # full pages only
    assert pool.refcount(pages[0]) == 2          # trie's own reference
    assert pool.refcount(pages[2]) == 1          # partial page never cached
    nodes, hit = trie.match(toks)
    assert [n.page for n in nodes] == pages[:2] and hit == 8
    # an exactly-2-page prompt caps at len-1: the hit lands mid-page and
    # hands over the last page anyway (the lane COWs it before writing)
    nodes, hit = trie.match(toks[:8])
    assert hit == 7 and len(nodes) == 2
    # divergence in the second page stops the walk after one node
    nodes, hit = trie.match(toks[:4] + [99, 98, 97, 96, 95])
    assert hit == 4 and len(nodes) == 1
    assert trie.claim(nodes, pool) == [pages[0]]
    assert pool.refcount(pages[0]) == 3
    # re-inserting an indexed prefix keeps the trie's copy (no new nodes,
    # no reference on the other lane's physical pages)
    other = pool.alloc(2)
    assert trie.insert(toks[:8], other, pool) == 0
    assert pool.refcount(other[0]) == 1


def test_prefix_trie_lru_eviction_and_pinning():
    from repro.serve.kvcache import PrefixCache

    ps = 4
    pool = PagePool(12)
    trie = PrefixCache(ps)
    a = pool.alloc(2)
    trie.insert(list(range(8)), a, pool)                    # older chain
    b = pool.alloc(2)
    trie.insert([50, 51, 52, 53, 60, 61, 62, 63], b, pool)  # newer chain
    pool.free(a)
    pool.free(b)              # lanes done: the trie is now the only owner
    assert pool.used_pages == 4 and trie.reclaimable(pool) == 4
    free0 = pool.free_pages
    assert trie.evict_one(pool)            # LRU leaf = tail of chain a
    assert trie.evict_one(pool)            # its parent became a leaf
    assert pool.free_pages == free0 + 2
    nodes, hit = trie.match([50, 51, 52, 53, 60, 61, 62, 63, 70])
    assert hit == 8                        # chain b survived (more recent)
    # pinning: a lane claims chain b, then eviction empties the trie —
    # the pages stay live through the lane's references
    claimed = trie.claim(nodes, pool)
    trie.clear(pool)
    assert trie.n_pages == 0
    assert all(pool.refcount(p) == 1 for p in claimed)
    pool.free(claimed)
    assert pool.used_pages == 0


def test_defrag_remaps_trie_pages_and_detects_leaks():
    from repro.serve.kvcache import PrefixCache

    n_pages, ps = 10, 4
    pool = PagePool(n_pages)
    pages = pool.alloc(5)
    trie = PrefixCache(ps)
    trie.insert(list(range(8)), pages[3:], pool)  # cache pages 4 and 5
    pool.free(pages)        # the lane exits; only trie references remain
    caches = {"pos_0": _pool_leaves(n_pages, ps, stacked=False)}

    def gathered():
        return np.asarray(jnp.take(caches["pos_0"].k,
                                   jnp.asarray(trie.pages()), axis=0))

    before = gathered()
    caches = defrag(caches, pool, [], trie=trie)
    # trie-held pages were compacted into the low prefix and remapped
    assert sorted(trie.pages()) == [1, 2]
    assert pool.used_pages == 2
    np.testing.assert_array_equal(before, gathered())
    nodes, hit = trie.match(list(range(9)))
    assert hit == 8 and [n.page for n in nodes] == trie.pages()
    # the ledger check: a live refcount no table and no trie node accounts
    # for is a leak, reported instead of silently compacted away
    pool.alloc(1)
    with pytest.raises(ValueError, match="leak"):
        defrag(caches, pool, [], trie=trie)


def test_evict_one_skips_pinned_chains_when_nothing_reclaimable():
    """Review regression: under pool pressure with every cached page still
    shared by live lanes, eviction must report failure (backpressure handles
    it) instead of draining the hot prefix index for zero freed pages."""
    from repro.serve.kvcache import PrefixCache

    pool = PagePool(12)
    trie = PrefixCache(4)
    a = pool.alloc(2)
    trie.insert(list(range(8)), a, pool)   # the lane still holds `a`
    assert trie.reclaimable(pool) == 0
    assert trie.evict_one(pool) is False
    assert trie.evict_until(pool, pool.free_pages + 1) is False
    assert trie.n_pages == 2               # the index survived intact
    pool.free(a)                           # lane exits → pages reclaimable
    assert trie.evict_one(pool) is True


def test_evict_one_prefers_shielding_leaves_over_hot_chains():
    """Review regression: when every reclaimable page sits on an interior
    node, the fallback victim must be a leaf SHIELDING one — never an
    unrelated hot pinned chain (which would lose its cache for zero freed
    pages just for being LRU-oldest)."""
    from repro.serve.kvcache import PrefixCache

    pool = PagePool(12)
    trie = PrefixCache(4)
    b = pool.alloc(1)
    trie.insert([9, 9, 9, 9], b, pool)       # hot chain B: oldest LRU, pinned
    a = pool.alloc(2)
    trie.insert(list(range(8)), a, pool)     # chain A: interior a0 → leaf a1
    pool.free([a[0]])                        # a0 now trie-only (reclaimable)
    free0 = pool.free_pages
    assert trie.evict_one(pool)              # unindexes a1 (shields a0) ...
    assert trie.match([9, 9, 9, 9, 1])[1] == 4   # ... chain B still hits
    assert pool.free_pages == free0          # a1 was pinned: nothing freed
    assert trie.evict_one(pool)              # a0 is a reclaimable leaf now
    assert pool.free_pages == free0 + 1
