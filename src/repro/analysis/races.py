"""Static page-aliasing race checker over ``PagedScheduler`` batch plans.

One device launch scatter-writes KV rows at ``(page, offset)`` coordinates
derived from each lane's page table and position span.  The pool invariants
that make COW prefix sharing, speculative rollback and defrag sound are:

* no two lanes write the same physical ``(page, offset)`` in one launch —
  the scatter would be order-dependent;
* a written page is exclusively owned (``refcount == 1``): writing a
  ``refcount > 1`` page mutates someone else's history absent a COW copy;
* a written page is never in the prefix trie — trie pages are immutable
  shared history until evicted (spec staging must COW before drafting);
* a written page is allocated (not on the pool free list) and the offset
  is inside the page.

:func:`check_plan` proves them for one planned tick.  The scheduler's
``analysis_debug`` mode submits every launch's plan here *before* the
device call and raises :class:`PageRaceError` on any finding; tests replay
recorded admit→preempt→defrag→rollback stress schedules through it.

The garbage page (page 0) is exempt from aliasing: pad rows and clamped
out-of-range positions deliberately dump writes there.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, FrozenSet, List, Mapping, Sequence, Tuple

from repro.analysis.findings import Finding

PASS = "races/page-aliasing"


@dataclasses.dataclass(frozen=True)
class PageWrite:
    """One lane's planned KV write: token at ``offset`` of physical ``page``."""

    lane: int
    uid: int
    page: int
    offset: int


@dataclasses.dataclass(frozen=True)
class TickPlan:
    """Everything one launch is about to scatter, plus the pool ledger.

    refcounts:  pool refcount per page the plan touches (missing → 0).
    trie_pages: pages currently owned by the prefix trie.
    free_pages: pages currently on the pool free list.
    """

    phase: str
    page_size: int
    writes: Tuple[PageWrite, ...]
    refcounts: Mapping[int, int]
    trie_pages: FrozenSet[int]
    free_pages: FrozenSet[int]
    garbage_page: int = 0

    @staticmethod
    def build(
        phase: str,
        page_size: int,
        writes: Sequence[PageWrite],
        refcounts: Mapping[int, int],
        trie_pages: Sequence[int] = (),
        free_pages: Sequence[int] = (),
        garbage_page: int = 0,
    ) -> "TickPlan":
        return TickPlan(
            phase=phase,
            page_size=page_size,
            writes=tuple(writes),
            refcounts=dict(refcounts),
            trie_pages=frozenset(trie_pages),
            free_pages=frozenset(free_pages),
            garbage_page=garbage_page,
        )


class PageRaceError(AssertionError):
    """Raised by the scheduler's debug mode when a plan fails the checker."""

    def __init__(self, plan: TickPlan, findings: List[Finding]) -> None:
        self.plan = plan
        self.findings = findings
        lines = "\n".join(f.format() for f in findings)
        super().__init__(
            f"page-aliasing race in {plan.phase!r} launch plan "
            f"({len(findings)} finding(s)):\n{lines}"
        )


def check_plan(plan: TickPlan) -> List[Finding]:
    """Prove the aliasing invariants for one planned launch; findings on
    any violation (empty list == the plan is race-free)."""
    findings: List[Finding] = []
    seen: Dict[Tuple[int, int], PageWrite] = {}
    for w in plan.writes:
        if w.page == plan.garbage_page:
            continue  # the designated dump target: aliasing is the point
        if not 0 <= w.offset < plan.page_size:
            findings.append(Finding(
                pass_name=PASS, severity="error",
                op=f"write page={w.page} offset={w.offset}",
                hint=f"offset outside [0, page_size={plan.page_size}) — "
                     "position→(page, offset) mapping is broken",
                where=f"{plan.phase}:lane{w.lane}:uid{w.uid}",
            ))
            continue
        key = (w.page, w.offset)
        prev = seen.get(key)
        if prev is not None and prev.lane != w.lane:
            findings.append(Finding(
                pass_name=PASS, severity="error",
                op=f"double-write page={w.page} offset={w.offset}",
                hint=f"lanes {prev.lane} (uid {prev.uid}) and {w.lane} "
                     f"(uid {w.uid}) both scatter this physical slot in one "
                     "launch — scatter order would decide whose KV survives",
                where=f"{plan.phase}:lane{w.lane}:uid{w.uid}",
            ))
        seen.setdefault(key, w)
        rc = plan.refcounts.get(w.page, 0)
        if w.page in plan.free_pages or rc == 0:
            findings.append(Finding(
                pass_name=PASS, severity="error",
                op=f"write to unallocated page={w.page}",
                hint="the page is on the free list / refcount 0 — a later "
                     "alloc would hand it to another lane mid-flight",
                where=f"{plan.phase}:lane{w.lane}:uid{w.uid}",
            ))
        elif rc > 1:
            findings.append(Finding(
                pass_name=PASS, severity="error",
                op=f"write to shared page={w.page} (refcount={rc})",
                hint="refcount > 1 means another lane or the prefix trie "
                     "still reads this page — copy-on-write "
                     "(_cow_shared_page) must run before the lane writes",
                where=f"{plan.phase}:lane{w.lane}:uid{w.uid}",
            ))
        if w.page in plan.trie_pages:
            findings.append(Finding(
                pass_name=PASS, severity="error",
                op=f"write aliases prefix-trie page={w.page}",
                hint="trie pages are immutable shared history; spec staging "
                     "and prefill must COW or allocate fresh pages instead",
                where=f"{plan.phase}:lane{w.lane}:uid{w.uid}",
            ))
    return findings


def assert_plan_ok(plan: TickPlan) -> None:
    """Raise :class:`PageRaceError` if the plan has any finding."""
    findings = check_plan(plan)
    if findings:
        raise PageRaceError(plan, findings)
