# One module per assigned architecture (exact public configs) + LeNet-5
# (the paper's own workload). Import repro.configs.registry for lookup.
