"""Fault-tolerant checkpointing: atomic, checksummed, async, elastic.

Layout: ``<dir>/step_<n>/arrays.npz`` + ``manifest.json`` (tree structure,
shapes, dtypes, crc32 per array, step). Writes go to ``step_<n>.tmp`` and are
renamed only after fsync — a crash mid-write never corrupts the latest valid
checkpoint. ``restore`` device_puts each leaf with the *target* sharding, so
a run can restart on a different mesh (elastic re-scaling) or a different
device count: resharding is a device_put, not a format concern.

Async mode hands the (host-side) arrays to a writer thread so the train loop
only blocks for the device→host copy, not the disk write.

DA-frozen trees round-trip too: a :class:`~repro.core.engine.PackedWeights`
node flattens to its ``wq`` / ``w_scale`` / ``luts`` arrays (crc-checked like
any leaf) and its aux data (DAConfig, default mode) is recorded in the
manifest's ``"packed"`` table, so :func:`load_tree` can reassemble the
artifact **without a template** — the serve-from-disk path never touches
float weights.  :func:`save_tree` / :func:`load_tree` are the step-agnostic
primitives ``repro.core.freeze`` builds its artifact pipeline on.
"""
from __future__ import annotations

import dataclasses
import json
import os
import queue
import re
import shutil
import threading
import zlib
from typing import Any, Dict, Optional

import jax
import numpy as np

_SEP = "/"


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = leaf
    return flat


def _path_str(entry) -> str:
    from repro.core.engine import path_entry_name

    return path_entry_name(entry)


def _np_dtype(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes  # bfloat16/fp8 — numpy custom dtypes (ships w/ jax)

        return np.dtype(getattr(ml_dtypes, name))


def _savable(v: np.ndarray) -> np.ndarray:
    """npz can't store ml_dtypes (bf16/fp8) — byte-view them; the manifest
    records the true dtype for restore."""
    if v.dtype.kind == "V" or v.dtype.name not in np.sctypeDict:
        return np.ascontiguousarray(v).view(np.uint8)
    return v


def _packed_manifest(tree: Any) -> Dict[str, dict]:
    """Manifest entries for PackedWeights nodes: path → aux data.

    The arrays themselves flow through the normal flatten (the node is a
    registered pytree with stable key names ``wq``/``w_scale``/``luts``);
    this records what the flatten drops — the DAConfig and default mode —
    keyed by the node's tree path, so a template-free load can rebuild the
    artifact exactly.
    """
    from repro.core.engine import PackedWeights

    meta: Dict[str, dict] = {}
    flat, _ = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda x: isinstance(x, PackedWeights)
    )
    for path, leaf in flat:
        if isinstance(leaf, PackedWeights):
            key = _SEP.join(_path_str(p) for p in path)
            meta[key] = {
                "cfg": dataclasses.asdict(leaf.cfg),
                "mode": leaf.mode,
                "has_luts": leaf.has_luts,
            }
    return meta


def save_tree(directory: str, tree: Any,
              extra_manifest: Optional[dict] = None) -> str:
    """Atomic, checksummed write of one pytree to ``<directory>/``.

    Writes ``arrays.npz`` + ``manifest.json`` into ``<directory>.tmp`` and
    renames after fsync.  ``extra_manifest`` entries are merged into the
    manifest (reserved keys: ``arrays``, ``packed``).  Returns ``directory``.
    """
    flat = {k: np.asarray(v) for k, v in _flatten(tree).items()}
    final = directory.rstrip(os.sep)
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    np.savez(os.path.join(tmp, "arrays.npz"),
             **{k: _savable(v) for k, v in flat.items()})
    manifest = dict(extra_manifest or {})
    manifest["arrays"] = {
        k: {
            "shape": list(v.shape),
            "dtype": str(v.dtype),
            "crc32": zlib.crc32(np.ascontiguousarray(v).tobytes()),
        }
        for k, v in flat.items()
    }
    packed = _packed_manifest(tree)
    if packed:
        manifest["packed"] = packed
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def save(directory: str, step: int, tree: Any, keep: int = 3) -> str:
    """Synchronous atomic save. Returns the checkpoint path."""
    final = save_tree(os.path.join(directory, f"step_{step:08d}"), tree,
                      extra_manifest={"step": step})
    _gc(directory, keep)
    return final


def _gc(directory: str, keep: int) -> None:
    steps = sorted(all_steps(directory))
    for s in steps[:-keep] if keep else []:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"), ignore_errors=True)


def all_steps(directory: str):
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(directory, name, "manifest.json")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(directory: str) -> Optional[int]:
    steps = all_steps(directory)
    return steps[-1] if steps else None


def _load_array(data, manifest, key: str, path: str) -> np.ndarray:
    """One array out of the npz, un-byte-viewed and crc-verified."""
    arr = data[key]
    meta = manifest["arrays"][key]
    true_dtype = _np_dtype(meta["dtype"])
    if arr.dtype != true_dtype:  # byte-viewed exotic dtype
        arr = arr.view(true_dtype).reshape(meta["shape"])
    crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
    if crc != meta["crc32"]:
        raise IOError(f"checksum mismatch for {key} in {path}")
    return arr


def load_tree(path: str, template: Any = None, shardings: Any = None) -> Any:
    """Read a tree written by :func:`save_tree`, verifying every checksum.

    With a ``template``: restore into its structure, cast to its dtypes, and
    place each leaf with the matching ``shardings`` entry (or the template's
    sharding) — the elastic-restart path.

    Without a template (``template=None``): rebuild the tree **blind** from
    the flat key paths — nested string-keyed dicts only (which is what model
    param trees are).  Paths listed in the manifest's ``"packed"`` table are
    reassembled into :class:`~repro.core.engine.PackedWeights` nodes with
    their recorded DAConfig and mode — this is how a serving process boots a
    DA artifact with zero float weights in scope.
    """
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    if template is not None:
        flat_t = _flatten(template)
        flat_s = _flatten(shardings) if shardings is not None else {}
        out = {}
        for key, tmpl in flat_t.items():
            arr = _load_array(data, manifest, key, path)
            arr = arr.astype(tmpl.dtype) if hasattr(tmpl, "dtype") else arr
            sh = flat_s.get(key)
            if sh is None and hasattr(tmpl, "sharding"):
                sh = tmpl.sharding
            out[key] = jax.device_put(arr, sh) if sh is not None else arr
        treedef = jax.tree_util.tree_structure(template)
        return jax.tree_util.tree_unflatten(
            treedef, [out[k] for k in flat_t.keys()])

    # Template-free: nested dicts from key paths + PackedWeights reassembly.
    from repro.core.da import DAConfig
    from repro.core.engine import PackedWeights

    import jax.numpy as jnp

    packed_meta = manifest.get("packed", {})
    root: dict = {}

    def insert(key: str, value) -> None:
        parts = key.split(_SEP)
        d = root
        for p in parts[:-1]:
            d = d.setdefault(p, {})
        d[parts[-1]] = value

    consumed = set()
    for prefix, meta in packed_meta.items():
        fields = {}
        for name in ("wq", "w_scale", "luts"):
            key = f"{prefix}{_SEP}{name}"
            if name == "luts" and not meta.get("has_luts", key in data):
                fields[name] = None
                continue
            fields[name] = jnp.asarray(_load_array(data, manifest, key, path))
            consumed.add(key)
        insert(prefix, PackedWeights(
            wq=fields["wq"], w_scale=fields["w_scale"], luts=fields["luts"],
            cfg=DAConfig(**meta["cfg"]), mode=meta.get("mode", "auto"),
        ))
    for key in manifest["arrays"]:
        if key not in consumed:
            insert(key, _load_array(data, manifest, key, path))
    return root


def restore(directory: str, step: int, template: Any, shardings: Any = None) -> Any:
    """Restore into ``template``'s tree structure; verify checksums; place
    each leaf with the matching entry of ``shardings`` (or template sharding)
    — this is the elastic-restart path."""
    return load_tree(os.path.join(directory, f"step_{step:08d}"),
                     template, shardings)


class AsyncCheckpointer:
    """Background writer thread; the caller only pays device→host copy time."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._q: "queue.Queue" = queue.Queue(maxsize=2)
        self._exc: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        while True:
            item = self._q.get()
            try:
                if item is None:
                    return
                step, tree = item
                save(self.directory, step, tree, keep=self.keep)
            except BaseException as e:  # surfaced on next submit/close
                self._exc = e
            finally:
                self._q.task_done()

    def submit(self, step: int, tree: Any) -> None:
        if self._exc:
            raise self._exc
        host_tree = jax.tree.map(np.asarray, tree)  # device→host now
        self._q.put((step, host_tree))

    def wait(self) -> None:
        self._q.join()
        if self._exc:
            raise self._exc

    def close(self) -> None:
        self._q.put(None)
        self._thread.join()
        if self._exc:
            raise self._exc
