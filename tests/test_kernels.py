"""Pallas kernels vs pure-jnp oracles: shape/dtype/signedness sweeps,
interpret mode (the kernel body runs on CPU — per the assignment)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.da import DAConfig, build_luts
from repro.kernels import ref
from repro.kernels.bitplane_vmm import bitplane_vmm_pallas
from repro.kernels.da_vmm import da_vmm_pallas
from repro.kernels.ops import bitplane_vmm, da_vmm

SHAPES = [
    # (M, K, N) incl. non-multiples of every tile dimension; the two largest
    # interpret-mode shapes ride behind -m slow (seconds each on CPU)
    (1, 8, 1),
    (4, 25, 6),       # the paper's CONV1 workload
    (16, 64, 32),
    (33, 100, 17),
    pytest.param(300, 130, 70, marks=pytest.mark.slow),
    pytest.param(64, 256, 128, marks=pytest.mark.slow),
]


@pytest.mark.parametrize("m,k,n", SHAPES)
@pytest.mark.parametrize("signed", [False, True])
def test_da_vmm_kernel_vs_oracle(m, k, n, signed, rng):
    x = (rng.integers(-128, 128, (m, k)) if signed
         else rng.integers(0, 256, (m, k))).astype(np.int32)
    w = rng.integers(-128, 128, (k, n)).astype(np.int32)
    cfg = DAConfig(group_size=8, x_bits=8, x_signed=signed)
    luts = build_luts(jnp.asarray(w))
    got = da_vmm_pallas(jnp.asarray(x), luts, cfg, bm=64, bn=32, bg=4,
                        interpret=True)
    want = ref.da_vmm_ref(jnp.asarray(x), luts, cfg)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    np.testing.assert_array_equal(np.asarray(got), x @ w)


@pytest.mark.parametrize("m,k,n", SHAPES)
@pytest.mark.parametrize("signed", [False, True])
def test_bitplane_kernel_vs_oracle(m, k, n, signed, rng):
    x = (rng.integers(-128, 128, (m, k)) if signed
         else rng.integers(0, 256, (m, k))).astype(np.int32)
    w = rng.integers(-128, 128, (k, n)).astype(np.int32)
    cfg = DAConfig(x_bits=8, x_signed=signed)
    got = bitplane_vmm_pallas(jnp.asarray(x), jnp.asarray(w), cfg,
                              bm=64, bn=32, bk=64, interpret=True)
    want = ref.bitplane_vmm_ref(jnp.asarray(x), jnp.asarray(w), cfg)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    np.testing.assert_array_equal(np.asarray(got), x @ w)


@pytest.mark.parametrize("bits", [2, 4, 8])
def test_kernel_bit_widths(bits, rng):
    """Lower input precisions (fewer bit-serial cycles) stay exact."""
    m, k, n = 8, 40, 8
    x = rng.integers(0, 1 << bits, (m, k)).astype(np.int32)
    w = rng.integers(-128, 128, (k, n)).astype(np.int32)
    cfg = DAConfig(group_size=8, x_bits=bits, x_signed=False)
    luts = build_luts(jnp.asarray(w))
    got = da_vmm_pallas(jnp.asarray(x), luts, cfg, bm=8, bn=8, bg=2,
                        interpret=True)
    np.testing.assert_array_equal(np.asarray(got), x @ w)


def test_tile_shape_sweep(rng):
    """Kernel output is invariant to BlockSpec tiling choices."""
    m, k, n = 48, 72, 24
    x = rng.integers(-128, 128, (m, k)).astype(np.int32)
    w = rng.integers(-128, 128, (k, n)).astype(np.int32)
    cfg = DAConfig(x_signed=True)
    luts = build_luts(jnp.asarray(w))
    outs = []
    for bm, bn, bg in [(16, 8, 1), (48, 24, 9), (32, 16, 4), (8, 8, 2)]:
        outs.append(np.asarray(
            da_vmm_pallas(jnp.asarray(x), luts, cfg, bm=bm, bn=bn, bg=bg,
                          interpret=True)))
    for o in outs[1:]:
        np.testing.assert_array_equal(o, outs[0])
    np.testing.assert_array_equal(outs[0], x @ w)


def test_ops_dispatch(rng):
    """The public wrappers route to the oracle on CPU and stay exact."""
    x = rng.integers(-128, 128, (5, 30)).astype(np.int32)
    w = rng.integers(-128, 128, (30, 7)).astype(np.int32)
    cfg = DAConfig(x_signed=True)
    luts = build_luts(jnp.asarray(w))
    np.testing.assert_array_equal(
        np.asarray(da_vmm(jnp.asarray(x), luts, cfg)), x @ w)
    np.testing.assert_array_equal(
        np.asarray(bitplane_vmm(jnp.asarray(x), jnp.asarray(w), cfg)), x @ w)
    np.testing.assert_array_equal(
        np.asarray(da_vmm(jnp.asarray(x), luts, cfg, backend="pallas")), x @ w)
