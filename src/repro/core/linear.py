"""DA-quantized linear layer — thin façade over the unified execution engine.

Training uses float matmuls (DA requires one *constant* operand; weights change
every step — the paper targets inference, §II-A).  For serving, ``freeze_da``
runs the pre-VMM step once (quantize + weight-sum LUTs) and returns the
:class:`~repro.core.engine.PackedWeights` artifact; applying it dispatches
through the engine's backend registry, so every mode the registry knows —
``lut`` / ``onehot`` / ``bitplane`` / ``bitplane_stacked`` / the Pallas
kernels / the ``int8`` baseline / shape-aware ``auto`` — is available from one
surface with no per-call-site branching.

``DAFrozenLinear`` is kept as a backward-compatible alias of PackedWeights.
"""
from __future__ import annotations

import jax

from repro.core.da import DAConfig
from repro.core.engine import (  # noqa: F401  (dense/PackedWeights re-exported)
    PackedWeights,
    dense,
    pack_weights,
)

# Backward-compatible name: the frozen artifact IS the packed-weights container.
DAFrozenLinear = PackedWeights


def freeze_da(
    w: jax.Array,
    cfg: DAConfig = DAConfig(x_signed=True),
    mode: str = "auto",
    lut_cell_limit: int = 1 << 24,
) -> PackedWeights:
    """Pre-VMM procedure (§III-A): quantize, sum weights, 'write the PMAs'.

    2-D weights [K, N] or batched 3-D [E, K, N] (per-expert PMAs for MoE).
    ``mode`` is any registered engine backend (legacy ``da_*`` spellings are
    accepted) or ``"auto"``: build LUTs when they fit ``lut_cell_limit`` — in LUT
    **cells** per matrix, not weights (see ``engine.pack_weights``) — and let
    the engine pick the backend per activation shape at run time.
    """
    return pack_weights(w, cfg, mode=mode, lut_cell_limit=lut_cell_limit)
