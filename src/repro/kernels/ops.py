"""Jit'd public wrappers dispatching DA VMMs to Pallas kernels or jnp refs.

``backend``:
  * "pallas"     — Pallas kernel, interpret-mode on CPU, compiled on TPU.
  * "reference"  — pure-jnp oracle (ref.py).
  * "auto"       — Pallas on TPU, reference elsewhere (interpret mode is a
                   correctness tool, not a fast path, on CPU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.da import DAConfig
from repro.kernels import ref
from repro.kernels.bitplane_vmm import bitplane_vmm_pallas
from repro.kernels.da_vmm import da_vmm_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def da_vmm(
    xq: jax.Array,
    luts: jax.Array,
    cfg: DAConfig,
    backend: str = "auto",
    **tiles,
) -> jax.Array:
    """Faithful LUT-readout DA VMM (int32-exact). xq [M,K], luts [G,2^L,N]."""
    if backend == "auto":
        backend = "pallas" if _on_tpu() else "reference"
    if backend == "pallas":
        return da_vmm_pallas(xq, luts, cfg, interpret=not _on_tpu(), **tiles)
    return ref.da_vmm_ref(xq, luts, cfg)


def bitplane_vmm(
    xq: jax.Array,
    wq: jax.Array,
    cfg: DAConfig,
    backend: str = "auto",
    **tiles,
) -> jax.Array:
    """Storage-free bit-plane DA VMM (int32-exact). xq [M,K], wq [K,N]."""
    if backend == "auto":
        backend = "pallas" if _on_tpu() else "reference"
    if backend == "pallas":
        return bitplane_vmm_pallas(xq, wq, cfg, interpret=not _on_tpu(), **tiles)
    return ref.bitplane_vmm_ref(xq, wq, cfg)
