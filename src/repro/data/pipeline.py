"""Deterministic, stateless, shardable data pipeline.

Every batch is a pure function of ``(seed, step)`` — no iterator state to
checkpoint, any host can materialize any shard, and elastic re-scaling (a
different number of hosts after restart) changes nothing about the stream.
This is the property that makes checkpoint/restart and elasticity trivial:
restoring a run only needs the step counter.

Two modes:
  * uniform synthetic tokens (throughput/dry-run work), and
  * packed "documents" (zipf unigram docs of random length packed to seq_len
    with EOS separators — exercises real padding/packing behavior).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    packed: bool = False
    eos_id: int = 0
    mean_doc_len: int = 512
    embed_dim: int = 0      # >0 → modality-stub embeddings instead of tokens
    mrope: bool = False     # emit 3-D position ids


def _rng(cfg: DataConfig, step: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step & 0x7FFFFFFF])
    )


def _packed_tokens(cfg: DataConfig, rng: np.random.Generator) -> np.ndarray:
    b, t = cfg.global_batch, cfg.seq_len
    out = np.empty((b, t + 1), dtype=np.int32)
    ranks = np.arange(1, cfg.vocab, dtype=np.float64)
    probs = 1.0 / ranks ** 1.1
    probs /= probs.sum()
    for i in range(b):
        row, fill = [], 0
        while fill < t + 1:
            dl = int(rng.exponential(cfg.mean_doc_len)) + 1
            doc = rng.choice(cfg.vocab - 1, size=dl, p=probs) + 1
            row.append(doc.astype(np.int32))
            row.append(np.array([cfg.eos_id], dtype=np.int32))
            fill += dl + 1
        out[i] = np.concatenate(row)[: t + 1]
    return out


def batch_at(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    """The (seed, step) → batch pure function."""
    rng = _rng(cfg, step)
    b, t = cfg.global_batch, cfg.seq_len
    if cfg.embed_dim:
        emb = rng.standard_normal((b, t, cfg.embed_dim), dtype=np.float32)
        labels = rng.integers(0, cfg.vocab, (b, t), dtype=np.int32)
        batch = {"inputs": emb, "labels": labels}
    else:
        if cfg.packed:
            toks = _packed_tokens(cfg, rng)
        else:
            toks = rng.integers(0, cfg.vocab, (b, t + 1), dtype=np.int32)
        batch = {"inputs": toks[:, :-1], "labels": toks[:, 1:]}
    if cfg.mrope:
        pos = np.broadcast_to(
            np.arange(t, dtype=np.int32)[None, :, None], (b, t, 3)
        ).copy()
        batch["positions"] = pos
    return batch


def host_shard(batch: Dict[str, np.ndarray], host_id: int, n_hosts: int):
    """Slice a global batch for one host (multi-host data loading)."""
    out = {}
    for k, v in batch.items():
        per = v.shape[0] // n_hosts
        out[k] = v[host_id * per : (host_id + 1) * per]
    return out


def iterate(cfg: DataConfig, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    step = start_step
    while True:
        yield batch_at(cfg, step)
        step += 1


def for_model(mcfg: ModelConfig, seq_len: int, global_batch: int,
              seed: int = 0, packed: bool = False) -> DataConfig:
    return DataConfig(
        vocab=mcfg.vocab,
        seq_len=seq_len,
        global_batch=global_batch,
        seed=seed,
        packed=packed,
        embed_dim=mcfg.d_model if mcfg.modality != "text" else 0,
        mrope=mcfg.mrope_sections is not None,
    )
