"""Model-level DA freeze: plan → pack → serialize → shard → serve.

The paper's premise is that the weight matrix is *constant* (§II-A): all the
expensive work — quantizing weights and precomputing the weight-sum LUTs (the
PMA contents, §III-A) — happens once, offline, and inference is shift-and-add
readout.  This module makes that premise operational at model scale:

1. **Plan** (:func:`plan_model`): for every weight-matrix leaf of a params
   pytree, choose a backend mode, group size and lut-or-not from the layer's
   (K, N) shape and the expected decode batch.  Measured autotune timings
   (``artifacts/engine_autotune.json``) rank the backends when the bucket was
   tuned on this host; otherwise the analytic hardware cost model
   (:mod:`repro.core.hwmodel`) ranks them — the DAISM-style "choose the
   in-memory multiply strategy per layer" policy, never a constant choice.
2. **Pack** (:func:`freeze_model`): run the pre-VMM step per leaf under its
   plan, producing a :class:`DAArtifact` — the packed params pytree plus the
   plan, DA config, and (optionally) the model config.
3. **Serialize** (:func:`save_artifact` / :func:`load_artifact`): persist the
   artifact via the checkpoint layer (crc-checked arrays, DAConfig + plan in
   the manifest) so a serving process boots from disk with **zero float
   weights and zero re-packing** — see ``ServeEngine.from_artifact`` and
   ``examples/serve_da.py --artifact``.
4. **Shard**: packed leaf names (``wq`` / ``w_scale`` / ``luts``) have
   sharding rules in :mod:`repro.launch.sharding`
   (``shard_frozen_params``) — a frozen model tensor-parallels its PMAs
   across the mesh like any other param.

Routers, norms, biases, embeddings and scalar SSM params stay float: they are
not VMMs (gather / elementwise).
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import warnings
from typing import Any, Dict, Optional, Sequence, Tuple

import jax

from repro.core.da import DAConfig
from repro.core.engine import (
    DEFAULT_LUT_LIMIT,
    PackedWeights,
    canonical_mode,
    get_backend,
    load_cost_table,
    lut_cells,
    pack_weights,
    path_entry_name,
    registered_backends,
    registry_fingerprint,
    shape_bucket,
)
from repro.core.hwmodel import T_ADD_STAGE, T_READ_PIPE


def _hwcost():
    """Deferred import: ``repro.obs.hwcost`` imports ``core.hwmodel``,
    and importing the ``repro.core`` package imports this module — a
    module-level import would be circular whenever ``obs.hwcost`` is
    the first thing a process imports."""
    from repro.obs import hwcost

    return hwcost

#: Artifact schema version — bumped on any layout/manifest change.
ARTIFACT_VERSION = 1
ARTIFACT_FORMAT = "da-artifact"

# Param leaf names that are weight matrices (x @ W shaped [in, out] or
# batched expert weights [E, in, out]).
DA_LEAF_NAMES = {
    "wq", "wk", "wv", "wo",          # attention projections
    "w_up", "w_gate", "w_down",      # MLP / MoE experts / shared experts
    "in_proj", "out_proj",           # mamba projections
    "w",                             # lm head
}
SKIP_CONTEXT = {"router", "conv_w", "table"}

_SEP = "/"


# ---------------------------------------------------------------------------
# Plan schema
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LayerPlan:
    """One layer's freeze decision: what to pack and how to execute it.

    mode:        concrete backend name this layer serves under.
    group_size:  rows per PMA for this layer (LUT addressability).
    with_luts:   materialize the weight-sum LUTs (the PMA write) or not.
    k, n:        the weight matrix shape the plan was made for.
    source:      "measured" (autotune bucket timing), "analytic" (hwmodel
                 fallback — no timing for this bucket on this host), or
                 "pinned" (a concrete mode was requested, no planning).
    est_cost:    the winning backend's estimated cost — µs when measured,
                 model-ns when analytic, NaN when pinned.
    kv_dtype:    KV-page precision this layer's cache serves at (recorded on
                 the wk/wv mixer leaves only — ``"fp16"`` | ``"int8"`` |
                 ``"int4"``; None for every non-KV leaf).  Artifacts carry it
                 so ``ServeEngine.from_artifact`` builds a pool matching the
                 plan instead of silently defaulting.
    """

    mode: str
    group_size: int
    with_luts: bool
    k: int
    n: int
    source: str = "analytic"
    # informational, not identity: NaN (pinned plans) would poison ==
    est_cost: float = dataclasses.field(default=float("nan"), compare=False)
    kv_dtype: Optional[str] = None

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        if not math.isfinite(d["est_cost"]):
            d["est_cost"] = None  # a bare NaN literal breaks strict JSON
        return d

    @classmethod
    def from_json(cls, d: dict) -> "LayerPlan":
        d = dict(d)
        if d.get("est_cost") is None:
            d["est_cost"] = float("nan")
        return cls(**d)


@dataclasses.dataclass
class DAArtifact:
    """The frozen, servable model: packed params + the plan that shaped them.

    params:    pytree with :class:`PackedWeights` at every weight-matrix leaf
               (non-VMM leaves stay float).
    plan:      leaf path (``periods/pos_0/mixer/wq``) → :class:`LayerPlan`.
    da_cfg:    base DAConfig the model was frozen under (per-layer group
               sizes may differ — each PackedWeights carries its own cfg).
    model_cfg: the ModelConfig needed to rebuild the serving graph, or None
               for bare trees (round-tripped through the manifest).
    hwcost:    :class:`~repro.obs.hwcost.HardwareCostModel` pricing every
               packed leaf on the paper's DA circuits (and the bit-slicing
               counterfactual).  Built at freeze time, carried in the
               manifest, rebuilt from the packed params when loading older
               artifacts that predate it.
    """

    params: Any
    plan: Dict[str, LayerPlan]
    da_cfg: DAConfig
    model_cfg: Any = None
    version: int = ARTIFACT_VERSION
    hwcost: Optional["HardwareCostModel"] = None
    #: latest ``repro.analysis.check`` verdict recorded against this artifact
    #: on disk (via :func:`record_analysis`), or None when never checked
    analysis: Optional[Dict[str, Any]] = None


# ---------------------------------------------------------------------------
# The planner: measured costs with the analytic hwmodel as fallback
# ---------------------------------------------------------------------------


def analytic_costs(
    m: int, k: int, n: int, cfg: DAConfig, has_luts: bool
) -> Dict[str, float]:
    """Analytic per-backend latency proxies (model-ns) from the hwmodel.

    Used when no autotune measurement covers a layer's bucket.  These are the
    *paper's hardware* numbers, not host timings: the PMA readout streams
    ``x_bits`` read cycles per input row (``DADesign.latency_ns``), the
    one-hot decode touches the full 2^L/L-blown-up LUT per readout, and the
    storage-free bit-plane forms pay a K·N multiply-accumulate sweep per bit
    plane (one adder stage per MAC) plus a weight-array read — once per plane
    for ``bitplane``, once total for ``bitplane_stacked``.  Only the ranking
    matters; absolute values are model-scale ns.
    """
    costs: Dict[str, float] = {}
    x_bits = cfg.x_bits
    mac_sweep = float(m) * k * n * T_ADD_STAGE
    w_read = float(k) * n * T_READ_PIPE
    if has_luts:
        d = _hwcost().da_design(k, n, x_bits=x_bits,
                                group_size=cfg.group_size)
        readout = m * d.latency_ns()
        costs["lut"] = readout
        costs["pallas_lut"] = readout
        costs["onehot"] = readout * ((1 << cfg.group_size) / cfg.group_size)
    costs["bitplane"] = x_bits * (mac_sweep + w_read)
    costs["pallas_bitplane"] = costs["bitplane"]
    costs["bitplane_stacked"] = x_bits * mac_sweep + w_read
    return costs


def plan_layer(
    k: int,
    n: int,
    da_cfg: DAConfig,
    m_hint: int = 4,
    lut_cell_limit: int = DEFAULT_LUT_LIMIT,
    cost_table: Optional[Dict[str, Dict[str, float]]] = None,
    group_size_candidates: Optional[Sequence[int]] = None,
) -> LayerPlan:
    """Choose (mode, group_size, lut-or-not) for one K×N weight matrix.

    ``m_hint`` is the expected serving batch (decode M); it selects the cost
    bucket.  For each candidate group size: decide LUT feasibility against
    ``lut_cell_limit``, rank the *eligible* DA backends by measured bucket
    timing when available (``cost_table``, default the process autotune
    table), else by :func:`analytic_costs`; the cheapest candidate wins, ties
    to the first (the base group size).  Measured and analytic costs are
    never compared against each other — a candidate set mixing both ranks
    measured candidates first (trust timings over models).  Autotune buckets
    are timed at ONE group size (the base), so only the base candidate may
    claim measurement provenance; alternative group sizes rank analytically.
    """
    table = cost_table if cost_table is not None else load_cost_table()
    candidates = tuple(group_size_candidates or (da_cfg.group_size,))
    best: Optional[Tuple[int, float, LayerPlan]] = None  # (rank, cost, plan)
    for gs in candidates:
        cfg = dataclasses.replace(da_cfg, group_size=gs)
        with_luts = lut_cells(k, n, gs) <= lut_cell_limit
        eligible = [
            s for s in registered_backends().values()
            if s.is_da and s.supports(cfg, with_luts, k=k)
        ]
        if not eligible:
            continue
        measured = (table.get(shape_bucket(m_hint, k, n, cfg.x_bits), {})
                    if gs == da_cfg.group_size else {})
        timed = {s.name: measured[s.name] for s in eligible
                 if s.name in measured}
        if timed:
            mode = min(timed, key=timed.get)
            rank, source, cost = 0, "measured", timed[mode]
        else:
            analytic = analytic_costs(m_hint, k, n, cfg, with_luts)
            scored = {s.name: analytic[s.name] for s in eligible
                      if s.name in analytic}
            if not scored:  # registry grew a backend the model doesn't know
                scored = {min(eligible, key=lambda s: s.name).name: 0.0}
            mode = min(scored, key=scored.get)
            rank, source, cost = 1, "analytic", scored[mode]
        plan = LayerPlan(mode=mode, group_size=gs, with_luts=with_luts,
                         k=k, n=n, source=source, est_cost=cost)
        if best is None or (rank, cost) < best[:2]:
            best = (rank, cost, plan)
    if best is None:  # unreachable with built-in backends, but be loud
        raise ValueError(f"no DA backend eligible for K={k} N={n} "
                         f"candidates={candidates}")
    return best[2]


def _path_key(path) -> str:
    return _SEP.join(path_entry_name(p) for p in path)


def _is_da_leaf(path, leaf) -> bool:
    names = [path_entry_name(p) for p in path]
    if not names or any(n in SKIP_CONTEXT for n in names):
        return False  # router / conv / embedding subtrees stay float
    return (names[-1] in DA_LEAF_NAMES
            and hasattr(leaf, "ndim") and leaf.ndim >= 2)


def plan_model(
    params: Any,
    da_cfg: DAConfig = DAConfig(x_signed=True),
    m_hint: int = 4,
    lut_cell_limit: int = DEFAULT_LUT_LIMIT,
    cost_table: Optional[Dict[str, Dict[str, float]]] = None,
    group_size_candidates: Optional[Sequence[int]] = None,
) -> Dict[str, LayerPlan]:
    """Per-layer plans for every weight-matrix leaf of ``params`` (no packing).

    Leaves stacked over periods/experts ([P, K, N] / [P, E, K, N]) get one
    plan from their trailing (K, N) — every period shares the layer shape.
    """
    plans: Dict[str, LayerPlan] = {}
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    for path, leaf in flat:
        if _is_da_leaf(path, leaf):
            plans[_path_key(path)] = plan_layer(
                int(leaf.shape[-2]), int(leaf.shape[-1]), da_cfg,
                m_hint=m_hint, lut_cell_limit=lut_cell_limit,
                cost_table=cost_table,
                group_size_candidates=group_size_candidates,
            )
    return plans


# ---------------------------------------------------------------------------
# Freeze: pack every planned leaf
# ---------------------------------------------------------------------------


def freeze_model(
    params: Any,
    da_cfg: DAConfig = DAConfig(x_signed=True),
    mode: str = "auto",
    m_hint: int = 4,
    lut_cell_limit: int = DEFAULT_LUT_LIMIT,
    model_cfg: Any = None,
    cost_table: Optional[Dict[str, Dict[str, float]]] = None,
    group_size_candidates: Optional[Sequence[int]] = None,
    pin_modes: bool = True,
    kv_dtype_overrides: Optional[Dict[str, str]] = None,
) -> DAArtifact:
    """Walk the param tree; pack every weight leaf under its per-layer plan.

    ``mode="auto"`` runs the planner (measured + analytic costs).  A concrete
    ``mode`` (any registered backend, legacy ``da_*`` spellings accepted)
    pins every layer to it — the one-size-fits-all escape hatch.

    When ``model_cfg`` is given, the plan's wk/wv mixer entries additionally
    record the KV-page precision their cache serves at:
    ``model_cfg.kv_dtype`` globally, overridable per layer position via
    ``kv_dtype_overrides`` (``{"pos_i": "fp16"|"int8"|"int4"}`` — the
    per-layer escape hatch).  The artifact manifest then carries the KV
    precision alongside every DA packing decision, so a serving process
    booting ``from_artifact`` builds a matching pool or fails loudly.

    ``pin_modes=True`` bakes each layer's planned backend into its
    ``PackedWeights`` default, so serving needs no dispatch machinery (and a
    cold process reproduces the planner's choices exactly); LUTs are then
    only materialized when the pinned backend actually reads them.
    ``pin_modes=False`` packs per the plan but leaves ``mode="auto"`` for
    runtime shape dispatch (prefill and decode may then use different
    backends on the same artifact), keeping every feasible LUT.
    """
    mode = canonical_mode(mode)
    planned = mode == "auto"
    plans: Dict[str, LayerPlan] = {}
    base_kv = getattr(model_cfg, "kv_dtype", None) if model_cfg else None
    for key, dt in (kv_dtype_overrides or {}).items():
        from repro.models.kv_quant import KV_DTYPES

        if dt not in KV_DTYPES:
            raise ValueError(f"kv_dtype_overrides[{key!r}]={dt!r}; expected "
                             f"one of {KV_DTYPES}")

    def walk(path, leaf):
        if not _is_da_leaf(path, leaf):
            return leaf
        k, n = int(leaf.shape[-2]), int(leaf.shape[-1])
        if planned:
            plan = plan_layer(
                k, n, da_cfg, m_hint=m_hint, lut_cell_limit=lut_cell_limit,
                cost_table=cost_table,
                group_size_candidates=group_size_candidates,
            )
            if pin_modes and not get_backend(plan.mode).needs_luts:
                # The pinned backend never reads PMAs: materializing them
                # would write up to 2^L/L× dead cells into every artifact.
                # (Un-pinned artifacts keep feasible LUTs — runtime dispatch
                # may still pick a LUT backend at other shapes.)
                plan = dataclasses.replace(plan, with_luts=False)
        else:
            plan = LayerPlan(
                mode=mode, group_size=da_cfg.group_size,
                with_luts=get_backend(mode).needs_luts, k=k, n=n,
                source="pinned",
            )
        names = [path_entry_name(p) for p in path]
        if base_kv is not None and names[-1] in ("wk", "wv"):
            pos_seg = next((s for s in names if s.startswith("pos_")), None)
            plan = dataclasses.replace(
                plan,
                kv_dtype=(kv_dtype_overrides or {}).get(pos_seg, base_kv))
        plans[_path_key(path)] = plan
        cfg = dataclasses.replace(da_cfg, group_size=plan.group_size)
        return pack_weights(
            leaf, cfg,
            mode=plan.mode if (pin_modes or not planned) else "auto",
            with_luts=plan.with_luts,
        )

    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    packed = jax.tree_util.tree_unflatten(
        treedef, [walk(path, leaf) for path, leaf in flat]
    )
    return DAArtifact(params=packed, plan=plans, da_cfg=da_cfg,
                      model_cfg=model_cfg,
                      hwcost=_hwcost().HardwareCostModel.from_frozen(
                          packed, plans))


def freeze_model_da(
    params: Any,
    da_cfg: DAConfig = DAConfig(x_signed=True),
    mode: str = "auto",
    lut_cell_limit: int = 1 << 24,
) -> Any:
    """Legacy surface: freeze and return only the packed params pytree."""
    return freeze_model(params, da_cfg, mode=mode,
                        lut_cell_limit=lut_cell_limit).params


# ---------------------------------------------------------------------------
# Serialize / load (the serve-many half of freeze-once)
# ---------------------------------------------------------------------------


def save_artifact(directory: str, artifact: DAArtifact) -> str:
    """Persist a DAArtifact: ``<dir>/arrays.npz`` + ``manifest.json``.

    Atomic (write to ``<dir>.tmp``, fsync, rename) and crc-checked per array
    via the checkpoint layer; the manifest carries the DA config, the full
    per-layer plan, the model config, and the backend-registry fingerprint
    so a loader can tell when the plan references backends that no longer
    exist.
    """
    from repro.checkpoint import ckpt

    extra = {
        "format": ARTIFACT_FORMAT,
        "artifact_version": artifact.version,
        "da_cfg": dataclasses.asdict(artifact.da_cfg),
        "plan": {k: p.to_json() for k, p in artifact.plan.items()},
        "registry": registry_fingerprint(),
    }
    if artifact.hwcost:
        extra["hwcost"] = artifact.hwcost.to_json()
    if artifact.model_cfg is not None:
        extra["model_cfg"] = dataclasses.asdict(artifact.model_cfg)
    return ckpt.save_tree(directory, artifact.params, extra_manifest=extra)


def load_artifact(directory: str) -> DAArtifact:
    """Boot a DAArtifact from disk: no float weights, no re-packing.

    The packed params are reconstructed template-free (the manifest records
    which paths are PackedWeights and their DAConfig/mode), arrays are
    crc-verified, and each layer's planned mode is validated against the
    live backend registry — a plan naming a backend that no longer exists
    degrades that layer to ``mode="auto"`` with a warning instead of raising
    ``KeyError`` at dispatch time.
    """
    from repro.checkpoint import ckpt

    with open(os.path.join(directory, "manifest.json")) as f:
        manifest = json.load(f)
    if manifest.get("format") != ARTIFACT_FORMAT:
        raise IOError(
            f"{directory} is not a DA artifact (format="
            f"{manifest.get('format')!r}); expected {ARTIFACT_FORMAT!r}"
        )
    if manifest.get("artifact_version", 0) > ARTIFACT_VERSION:
        raise IOError(
            f"artifact version {manifest['artifact_version']} is newer than "
            f"this build understands ({ARTIFACT_VERSION})"
        )
    params = ckpt.load_tree(directory)
    plan = {k: LayerPlan.from_json(p)
            for k, p in manifest.get("plan", {}).items()}
    registry = registered_backends()
    stale = sorted({p.mode for p in plan.values() if p.mode not in registry})
    if stale:
        warnings.warn(
            f"artifact {directory} was planned for backends {stale} that are "
            "not registered in this build; those layers fall back to "
            "mode='auto' dispatch", stacklevel=2,
        )
        params = _demote_stale_modes(params, set(stale))
        plan = {k: (dataclasses.replace(p, mode="auto", source="stale")
                    if p.mode in stale else p)
                for k, p in plan.items()}
    da_cfg = DAConfig(**manifest["da_cfg"])
    model_cfg = None
    if "model_cfg" in manifest:
        from repro.models.config import ModelConfig

        raw = dict(manifest["model_cfg"])
        for key in ("mrope_sections",):  # JSON lists → tuples
            if raw.get(key) is not None:
                raw[key] = tuple(raw[key])
        model_cfg = ModelConfig(**raw)
    if "hwcost" in manifest:
        hwcost = _hwcost().HardwareCostModel.from_json(
            manifest["hwcost"])
    else:  # pre-hwcost artifact: geometry is all in the packed leaves
        hwcost = _hwcost().HardwareCostModel.from_frozen(params, plan)
    return DAArtifact(params=params, plan=plan, da_cfg=da_cfg,
                      model_cfg=model_cfg,
                      version=manifest.get("artifact_version", 1),
                      hwcost=hwcost,
                      analysis=manifest.get("analysis"))


def record_analysis(directory: str, verdict: Dict[str, Any]) -> None:
    """Stamp a ``repro.analysis.check`` verdict into an artifact's manifest.

    Read-modify-write of ``manifest.json`` under the ``"analysis"`` key,
    written atomically (tmp file + fsync + rename) so a crashed checker can
    never leave a truncated manifest.  The verdict dict is the checker's
    summary — counts per pass, error/warning totals, the ``ok`` bit and the
    checker's schema version — not the full findings list (that ships as a
    separate JSON report when asked for)."""
    path = os.path.join(directory, "manifest.json")
    with open(path) as f:
        manifest = json.load(f)
    manifest["analysis"] = verdict
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def _demote_stale_modes(params: Any, stale: set) -> Any:
    def demote(leaf):
        if isinstance(leaf, PackedWeights) and leaf.mode in stale:
            return dataclasses.replace(leaf, mode="auto")
        return leaf

    return jax.tree.map(
        demote, params, is_leaf=lambda x: isinstance(x, PackedWeights)
    )


# ---------------------------------------------------------------------------
# Reporting: the Table-I trade-off, per layer
# ---------------------------------------------------------------------------


def da_memory_report(frozen_params: Any, model_cfg: Any = None,
                     kv_dtypes: Any = None) -> dict:
    """The paper's Table-I trade-off at model scale — aggregate AND per layer.

    Besides the aggregate cell counts, ``"layers"`` lists every packed matrix
    with its plan decision (mode chosen, group size) and its storage split
    (int8 code bytes vs int32 LUT bytes), so the 2^L/L blow-up is
    inspectable layer by layer, not just in aggregate.  Each layer row also
    carries its :mod:`repro.obs.hwcost` price (``da_pj`` / ``da_ns`` per
    token-pass, plus the bit-slicing counterfactual), and ``"hw"`` holds the
    model-total :meth:`HardwareCostModel.summary` — the same table serving
    ``metrics()["hw"]``, ONE source of geometry truth.

    Pass ``model_cfg`` (all-attention archs) to additionally get a ``"kv"``
    section pricing the OTHER resident tensor beside the DA weights — the
    paged KV cache: per-position page dtype, bytes per token per layer
    (codes + in-page scales), model-total bytes per token, and the capacity
    multiplier vs compute-dtype pages at equal pool bytes.
    """
    weights = luts = mats = 0
    layers = []
    hwm = _hwcost().HardwareCostModel.from_frozen(frozen_params)
    hw_rows = {r["path"]: r for r in hwm.layer_table()}
    flat, _ = jax.tree_util.tree_flatten_with_path(
        frozen_params, is_leaf=lambda x: isinstance(x, PackedWeights)
    )
    for path, leaf in flat:
        if not isinstance(leaf, PackedWeights):
            continue
        mats += 1
        weights += leaf.wq.size
        lut_sz = leaf.luts.size if leaf.luts is not None else 0
        luts += lut_sz
        hw_row = hw_rows.get(_path_key(path), {})
        layers.append({
            "layer": _path_key(path),
            "mode": leaf.mode,
            "group_size": leaf.cfg.group_size,
            "k": int(leaf.k),
            "n": int(leaf.n),
            "with_luts": leaf.has_luts,
            "code_bytes": int(leaf.wq.size) * leaf.wq.dtype.itemsize,
            "scale_bytes": int(leaf.w_scale.size) * leaf.w_scale.dtype.itemsize,
            "lut_bytes": int(lut_sz) * (leaf.luts.dtype.itemsize
                                        if leaf.luts is not None else 0),
            "cell_blowup": (lut_sz / leaf.wq.size) if leaf.wq.size else 0.0,
            "vmms_per_token": hw_row.get("vmms_per_token", 1),
            "da_pj": hw_row.get("da_pj", 0.0),
            "da_ns": hw_row.get("da_ns", 0.0),
            "bs_pj": hw_row.get("bs_pj", 0.0),
            "bs_ns": hw_row.get("bs_ns", 0.0),
        })
    report = {
        "da_matrices": mats,
        "weight_cells": weights,
        "lut_cells": luts,
        "cell_blowup": (luts / weights) if weights else 0.0,
        "layers": layers,
        "hw": hwm.summary() if hwm else None,
    }
    if model_cfg is not None and all(
            model_cfg.mixer_kind(p) == "attn"
            for p in range(model_cfg.period)):
        from repro.serve.kvcache import kv_token_bytes, resolve_kv_dtypes

        resolved = resolve_kv_dtypes(model_cfg, kv_dtypes)
        per_pos = {key: kv_token_bytes(model_cfg, dt)
                   for key, dt in resolved.items()}
        total = model_cfg.n_periods * sum(per_pos.values())
        fp_total = model_cfg.n_periods * sum(
            kv_token_bytes(model_cfg, "fp16") for _ in per_pos)
        report["kv"] = {
            "kv_dtypes": resolved,
            "token_bytes_per_layer": per_pos,
            "bytes_per_token": total,
            "fp_bytes_per_token": fp_total,
            "capacity_multiplier": fp_total / total if total else 0.0,
        }
    return report
