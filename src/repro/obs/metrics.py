"""Process-wide metrics registry for the serving runtime.

The Lynchpin-style premise: in-memory-compute performance claims are only
credible under systematic, reproducible measurement — so the runtime carries
its own telemetry substrate instead of every subsystem hand-rolling an
end-of-run snapshot dict.  Three instrument kinds, one registry:

* :class:`Counter` — monotonically increasing totals (tokens emitted,
  preemptions, COW copies), optionally labeled (``inc(1, backend="fused")``
  keeps one series per label set).
* :class:`Gauge` — last-write-wins levels (pages in use, live lanes).
* :class:`Histogram` — streaming fixed-bucket distributions.  Buckets are
  geometric, chosen at construction; p50/p99 are answerable *live* (bucket
  interpolation), not only after the run ends, and the cumulative-bucket
  layout exports directly as a Prometheus histogram.

Cost model: every instrument is a dict lookup + a float add on the hot path,
and a disabled registry (``MetricsRegistry(enabled=False)``) short-circuits
each operation to one attribute test — observability must never perturb the
decode loop it measures (token identity with metrics on/off is
test-asserted).  Instruments are created once (``registry.counter(...)`` is
get-or-create) and written many times.
"""
from __future__ import annotations

import threading
from typing import Dict, Iterable, List, Optional, Tuple

#: Version stamp for every exported snapshot / BENCH_*.json so downstream
#: consumers (dashboards, trend scripts) can detect schema drift.
#: v2: hardware-cost block (``metrics()["hw"]``, ``hw_*`` series,
#: ``req_hw_pj`` histogram, ``est_pj``/``est_ns`` trace-span args).
METRICS_SCHEMA_VERSION = 2

#: Geometric latency buckets: 10 us .. ~100 s, factor ~2.15 (21 buckets).
#: Wide enough for TTFT on a cold compile and tight enough that decode-loop
#: percentiles resolve to ~2x.
TIME_BUCKETS: Tuple[float, ...] = tuple(
    1e-5 * (2.15 ** i) for i in range(21)
)

#: Generic magnitude buckets (token counts, page counts): 1 .. ~1e6, pow2.
COUNT_BUCKETS: Tuple[float, ...] = tuple(float(2 ** i) for i in range(21))

#: Per-request estimated energy (pJ): decades from 100 pJ to ~10 mJ — a
#: single CONV1 VMM is ~1e2 pJ, a long LM request runs to ~1e10+ pJ.
ENERGY_BUCKETS: Tuple[float, ...] = tuple(
    10.0 ** (2 + 0.5 * i) for i in range(17)
)

LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Dict[str, str]) -> LabelKey:
    if not labels:
        return ()
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


class _Instrument:
    """Shared shell: name, help text, per-label-set series storage."""

    kind = "untyped"

    def __init__(self, name: str, help: str = "", enabled: bool = True):
        self.name = name
        self.help = help
        self.enabled = enabled

    def series(self):  # pragma: no cover - overridden
        raise NotImplementedError


class Counter(_Instrument):
    kind = "counter"

    def __init__(self, name: str, help: str = "", enabled: bool = True):
        super().__init__(name, help, enabled)
        self._v: Dict[LabelKey, float] = {}

    def inc(self, n: float = 1, **labels) -> None:
        if not self.enabled:
            return
        key = _label_key(labels)
        self._v[key] = self._v.get(key, 0.0) + n

    def value(self, **labels) -> float:
        return self._v.get(_label_key(labels), 0.0)

    @property
    def total(self) -> float:
        """Sum across every label set (the unlabeled common case reads the
        single () series)."""
        return sum(self._v.values())

    def series(self) -> Iterable[Tuple[LabelKey, float]]:
        return self._v.items()


class Gauge(_Instrument):
    kind = "gauge"

    def __init__(self, name: str, help: str = "", enabled: bool = True):
        super().__init__(name, help, enabled)
        self._v: Dict[LabelKey, float] = {}

    def set(self, v: float, **labels) -> None:
        if not self.enabled:
            return
        self._v[_label_key(labels)] = float(v)

    def value(self, **labels) -> float:
        return self._v.get(_label_key(labels), 0.0)

    def series(self) -> Iterable[Tuple[LabelKey, float]]:
        return self._v.items()


class Histogram(_Instrument):
    """Fixed-bucket streaming histogram with live percentile estimates.

    ``buckets`` are upper bounds (le) of each bin; observations beyond the
    last bound land in the implicit +Inf bin.  ``percentile`` finds the bin
    where the cumulative count crosses the quantile and interpolates
    linearly inside it — a t-digest-free estimate whose error is bounded by
    the bucket ratio (~2x here), available at any instant of the run.
    """

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 buckets: Tuple[float, ...] = TIME_BUCKETS,
                 enabled: bool = True):
        super().__init__(name, help, enabled)
        self.buckets = tuple(sorted(buckets))
        self._counts: Dict[LabelKey, List[int]] = {}
        self._sum: Dict[LabelKey, float] = {}
        self._n: Dict[LabelKey, int] = {}

    def observe(self, v: float, **labels) -> None:
        if not self.enabled:
            return
        key = _label_key(labels)
        counts = self._counts.get(key)
        if counts is None:
            counts = self._counts[key] = [0] * (len(self.buckets) + 1)
            self._sum[key] = 0.0
            self._n[key] = 0
        # linear scan is fine: ~21 bins, and the common observations (ITL)
        # land in the first few
        for i, ub in enumerate(self.buckets):
            if v <= ub:
                counts[i] += 1
                break
        else:
            counts[-1] += 1
        self._sum[key] += v
        self._n[key] += 1

    def count(self, **labels) -> int:
        return self._n.get(_label_key(labels), 0)

    def sum(self, **labels) -> float:
        return self._sum.get(_label_key(labels), 0.0)

    def percentile(self, q: float, **labels) -> float:
        """Live quantile estimate (q in [0, 100])."""
        key = _label_key(labels)
        counts = self._counts.get(key)
        n = self._n.get(key, 0)
        if not counts or n == 0:
            return 0.0
        target = q / 100.0 * n
        cum = 0
        for i, c in enumerate(counts):
            if c == 0:
                continue
            lo = self.buckets[i - 1] if 0 < i <= len(self.buckets) else 0.0
            hi = (self.buckets[i] if i < len(self.buckets)
                  else self.buckets[-1] * 2)
            if cum + c >= target:
                frac = (target - cum) / c
                return lo + (hi - lo) * min(max(frac, 0.0), 1.0)
            cum += c
        return self.buckets[-1] * 2

    def series(self) -> Iterable[Tuple[LabelKey, List[int]]]:
        return self._counts.items()


class MetricsRegistry:
    """Named instruments, one namespace, snapshot/export-ready.

    ``enabled=False`` builds a registry whose instruments all short-circuit:
    the serving runtime can keep its instrumentation calls unconditionally
    inline while a benchmark measures the un-instrumented hot loop.
    """

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._instruments: Dict[str, _Instrument] = {}
        self._lock = threading.Lock()

    # -- get-or-create -------------------------------------------------------
    def _get(self, cls, name: str, help: str, **kw) -> _Instrument:
        with self._lock:
            inst = self._instruments.get(name)
            if inst is None:
                inst = cls(name, help=help, enabled=self.enabled, **kw)
                self._instruments[name] = inst
            elif not isinstance(inst, cls):
                raise ValueError(
                    f"metric {name!r} already registered as {inst.kind}, "
                    f"requested {cls.kind}")
            return inst

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: Tuple[float, ...] = TIME_BUCKETS) -> Histogram:
        return self._get(Histogram, name, help, buckets=buckets)

    def get(self, name: str) -> Optional[_Instrument]:
        return self._instruments.get(name)

    def instruments(self) -> Dict[str, _Instrument]:
        return dict(self._instruments)

    # -- views ---------------------------------------------------------------
    def snapshot(self) -> Dict[str, object]:
        """One machine-readable dict of every series: counters/gauges map
        ``name`` (or ``name{k=v,...}``) to value; histograms to
        ``{count, sum, p50, p99}``.  Deterministic key order."""
        out: Dict[str, object] = {
            "metrics_schema_version": METRICS_SCHEMA_VERSION}
        for name in sorted(self._instruments):
            inst = self._instruments[name]
            if isinstance(inst, Histogram):
                for key, _ in sorted(inst.series()):
                    lbl = _fmt_labels(key)
                    out[f"{name}{lbl}"] = {
                        "count": inst.count(**dict(key)),
                        "sum": inst.sum(**dict(key)),
                        "p50": inst.percentile(50, **dict(key)),
                        "p99": inst.percentile(99, **dict(key)),
                    }
            else:
                for key, v in sorted(inst.series()):
                    out[f"{name}{_fmt_labels(key)}"] = v
        return out

    def reset(self) -> None:
        """Drop every instrument (tests; a fresh engine makes a fresh
        registry instead)."""
        with self._lock:
            self._instruments.clear()


def _fmt_labels(key: LabelKey) -> str:
    if not key:
        return ""
    return "{" + ",".join(f"{k}={v}" for k, v in key) + "}"


# -- module default ----------------------------------------------------------
# One process-wide registry for code without an engine in hand (kernel-level
# counters, ad-hoc scripts).  Engines build their OWN registry so parallel
# engines in one process (e.g. the spec-decode benchmark's paired runs) never
# share series.
_default = MetricsRegistry()


def default_registry() -> MetricsRegistry:
    return _default
