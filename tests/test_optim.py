"""Optimizer substrate: AdamW + master weights, NaN-guard, schedules,
int8+EF gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import adamw
from repro.optim.compress import compress_leaf, compress_with_ef, decompress_leaf, init_error
from repro.optim.schedules import warmup_cosine


def test_adamw_converges_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = adamw.init(params)
    cfg = adamw.AdamWConfig(lr=0.3, weight_decay=0.0)
    for _ in range(200):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state, _ = adamw.update(g, state, params, cfg, 1.0)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_nan_guard_skips_update():
    params = {"w": jnp.ones((3,))}
    state = adamw.init(params)
    cfg = adamw.AdamWConfig()
    bad = {"w": jnp.asarray([jnp.nan, 1.0, 1.0])}
    new_params, new_state, m = adamw.update(bad, state, params, cfg, 1.0)
    assert float(m["skipped"]) == 1.0
    np.testing.assert_array_equal(np.asarray(new_params["w"]), np.ones(3))
    assert int(new_state.step) == 0  # skipped steps don't advance bias corr.


def test_master_weights_bf16_params():
    params = {"w": jnp.ones((4,), dtype=jnp.bfloat16)}
    state = adamw.init(params)
    assert state.master["w"].dtype == jnp.float32
    g = {"w": jnp.full((4,), 1e-3, dtype=jnp.bfloat16)}
    new_params, state, _ = adamw.update(g, state, params, adamw.AdamWConfig(lr=1e-4), 1.0)
    assert new_params["w"].dtype == jnp.bfloat16
    # fp32 master retains sub-bf16 deltas
    assert float(jnp.abs(state.master["w"] - 1.0).max()) > 0


def test_warmup_cosine_shape():
    xs = [float(warmup_cosine(jnp.asarray(s), 10, 100)) for s in range(0, 101, 10)]
    assert xs[0] == 0.0
    assert abs(xs[1] - 1.0) < 1e-6          # end of warmup
    assert xs[-1] <= xs[1]                  # decays
    assert xs[-1] >= 0.1 - 1e-6             # floor


def test_compression_roundtrip_and_ef():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(64,)), dtype=jnp.float32)
    q, s = compress_leaf(g)
    rel = float(jnp.abs(decompress_leaf(q, s) - g).max() / jnp.abs(g).max())
    assert rel < 0.01  # int8: ~1/127 worst-case
    # EF: accumulated compressed sum tracks true sum (bias → 0)
    grads = {"w": g}
    err = init_error(grads)
    acc_true = np.zeros(64)
    acc_comp = np.zeros(64)
    for i in range(50):
        gi = {"w": jnp.asarray(rng.normal(size=(64,)), dtype=jnp.float32)}
        codes, err = compress_with_ef(gi, err)
        (q, s) = jax.tree.leaves(codes, is_leaf=lambda x: isinstance(x, tuple))[0]
        acc_comp += np.asarray(decompress_leaf(q, s))
        acc_true += np.asarray(gi["w"])
    residual = np.abs(acc_true - acc_comp).max()
    # w/o error feedback the residual would random-walk (~sqrt(50)*s/2);
    # EF keeps it to one quantum
    assert residual < float(np.asarray(s)) * 2
