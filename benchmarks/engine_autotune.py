"""Autotune the DA engine's shape-aware dispatch: time every registered
backend on one representative shape per (M, K·N) bucket and write the JSON
cost cache that ``mode="auto"`` loads at dispatch time.

    PYTHONPATH=src python benchmarks/engine_autotune.py            # full
    PYTHONPATH=src python benchmarks/engine_autotune.py --quick    # smaller reps
    PYTHONPATH=src python benchmarks/engine_autotune.py --x-bits 8 4

The cache (default ``artifacts/engine_autotune.json``, override with
``REPRO_ENGINE_AUTOTUNE``) maps shape buckets to measured µs per backend::

    {"table": {"dec:s:b8": {"lut": 120.4, "bitplane_stacked": 88.1, ...}}}

Only *eligible* backends are timed (LUT modes are skipped when the bucket's
LUT blow-up would exceed ``--lut-cell-limit`` — the same bound the serving
freeze applies), and the Pallas kernels are skipped on CPU, where interpret
mode is a correctness tool rather than a fast path.  Measurements are taken
on whatever ``jax.default_backend()`` this runs on; the cache records the
device so a CPU-tuned table is not silently trusted on TPU.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.da import DAConfig
from repro.core.engine import (
    BUCKET_SHAPES,
    DEFAULT_LUT_LIMIT,
    default_cache_path,
    jit_backend,
    lut_cells,
    pack_quantized,
    registry_fingerprint,
    set_cost_table,
    shape_bucket,
    timeable_backends,
)

try:
    from stamp import bench_stamp
except ImportError:  # running as a module from the repo root
    from benchmarks.stamp import bench_stamp

# Shrunk representatives for --quick (CI / CPU smoke): same buckets, less work.
QUICK_SHAPES = {
    "dec:s": (4, 64, 128),
    "dec:m": (4, 256, 512),
    "dec:l": (4, 1024, 1536),
    "mid:s": (32, 64, 128),
    "mid:m": (32, 256, 512),
    "mid:l": (32, 1024, 1536),
    "big:s": (384, 64, 128),
    "big:m": (384, 256, 512),
    "big:l": (384, 1024, 1536),
}


def time_backend(fn, *args, iters: int = 3) -> float:
    """Median wall-time in µs over ``iters`` timed calls (after warm-up)."""
    jax.block_until_ready(fn(*args))  # compile + warm caches
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def tune(
    x_bits_list, group_size: int, lut_cell_limit: int, quick: bool, iters: int
) -> dict:
    rng = np.random.default_rng(0)
    shapes = QUICK_SHAPES if quick else BUCKET_SHAPES
    table: dict = {}
    for x_bits in x_bits_list:
        cfg = DAConfig(group_size=group_size, x_bits=x_bits, x_signed=True)
        for cell, (m, k, n) in shapes.items():
            bucket = shape_bucket(m, k, n, x_bits)
            with_luts = lut_cells(k, n, group_size) <= lut_cell_limit
            w = rng.integers(-128, 128, (k, n)).astype(np.int32)
            lo = 1 << (x_bits - 1)
            x = rng.integers(-lo, lo, (m, k)).astype(np.int32)
            packed = pack_quantized(w, cfg=cfg, with_luts=with_luts)
            xj = jnp.asarray(x)
            costs = {}
            for spec in timeable_backends(cfg, packed.has_luts):
                fn = jit_backend(spec, cfg)
                try:
                    costs[spec.name] = round(
                        time_backend(fn, xj, packed, iters=iters), 1)
                except Exception as e:  # noqa: BLE001 — record, keep tuning
                    print(f"  {bucket} {spec.name}: failed ({e})")
            table[bucket] = costs
            best = min(costs, key=costs.get) if costs else "-"
            pretty = ", ".join(f"{b}={us:.0f}us" for b, us in costs.items())
            print(f"{bucket:12s} ({m}x{k}x{n}): {pretty}  -> {best}")
    return table


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller representative shapes (CI / CPU smoke)")
    ap.add_argument("--x-bits", type=int, nargs="+", default=[8],
                    help="input bit widths to tune (e.g. --x-bits 8 4)")
    ap.add_argument("--group-size", type=int, default=8)
    ap.add_argument("--lut-cell-limit", type=int, default=DEFAULT_LUT_LIMIT,
                    help="max LUT cells per matrix before LUT modes are "
                         "skipped (default: the serving freeze's bound, so "
                         "every bucket the freeze gives LUTs gets them timed)")
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--out", default=None,
                    help="cache path (default: engine default_cache_path())")
    args = ap.parse_args()

    table = tune(args.x_bits, args.group_size, args.lut_cell_limit, args.quick,
                 args.iters)
    out = args.out or default_cache_path()
    payload = {
        # provenance stamp (git sha, seed, device, interpret flag, schema
        # version) rides the cache like every other benchmark artifact; the
        # explicit keys below win on collision so the loader contract
        # ("version"/"registry"/"table") is unchanged
        **bench_stamp(),
        "version": 1,
        "device": jax.default_backend(),
        # Stamp the backend registry this cache was tuned against: a loader
        # seeing a different fingerprint warns and falls back to the
        # heuristic instead of trusting stale rankings (or KeyError-ing on
        # renamed backends).
        "registry": registry_fingerprint(),
        "group_size": args.group_size,
        "quick": args.quick,
        "table": table,
    }
    import pathlib

    p = pathlib.Path(out)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(payload, indent=1, sort_keys=True))
    set_cost_table(table)  # make this process dispatch on fresh numbers too
    print(f"\nwrote {p} ({len(table)} buckets, device={payload['device']})")


if __name__ == "__main__":
    main()
